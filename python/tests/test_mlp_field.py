"""bns_mlp_field emitter + mirror vs the jnp reference semantics.

The rust CPU backend replays `compile.golden`'s fixtures bit-for-bit
against `forward_mirror`; these tests pin the python side of that
contract — the deterministic weight stream, the emitter's spec shape,
and the mirror's agreement with `ref.fused_resblock` composition.
"""

import json

import numpy as np
import pytest

from compile import mlp_field as mf
from compile.kernels import ref


def test_det_values_are_exact_and_stable():
    v = mf.det_values(1234, 8)
    # every value is (int in [-500, 500)) / 256 — exact in f32
    assert v.dtype == np.float32
    assert np.all(v * 256.0 == np.round(v * 256.0))
    assert np.all(np.abs(v) <= 500.0 / 256.0)
    # stream is stable and shift-consistent: det(s)[k:] == det(s+k)
    np.testing.assert_array_equal(mf.det_values(1234, 8)[3:], mf.det_values(1237, 5))


def test_emitter_is_deterministic_and_well_shaped():
    a = mf.init_mlp_field(8, 12, 4, 3, depth=2, seed=77)
    b = mf.init_mlp_field(8, 12, 4, 3, depth=2, seed=77)
    assert json.dumps(a) == json.dumps(b)
    assert a["null_class"] == 3 and a["cfg"] is True
    assert len(a["cls_emb"]) == 4 * 4
    assert len(a["blocks"]) == 2
    blk = a["blocks"][0]
    assert len(blk["w1"]) == 8 * 12 and len(blk["mw"]) == 4 * 2 * 8
    assert len(blk["mb"]) == 2 * 8


def test_time_embed_matches_ref_oracle():
    for t in (0.0, 0.25, 0.62, 1.0):
        mine = mf.time_embed_f64(t, 16)
        want = np.asarray(ref.time_embed(np.float32(t) * 1000.0, 16))
        np.testing.assert_allclose(mine, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("d,h,batch", [(4, 6, 1), (8, 8, 7), (24, 16, 5)])
def test_resblock_mirror_matches_ref(d, h, batch):
    s = mf._Stream(555)
    x = s.take(batch * d, np.float32(1.0)).reshape(batch, d)
    scale = s.take(batch * d, np.float32(0.1)).reshape(batch, d)
    shift = s.take(batch * d, np.float32(0.1)).reshape(batch, d)
    sc = mf.weight_scales(d, h, 2)
    w1 = s.take(d * h, sc["w1"]).reshape(d, h)
    b1 = s.take(h, sc["b1"])
    w2 = s.take(h * d, sc["w2"]).reshape(h, d)
    b2 = s.take(d, sc["b2"])
    got = mf.resblock_mirror(x, np.concatenate([scale, shift], axis=1), w1, b1, w2, b2)
    want = np.asarray(ref.fused_resblock(x, w1, b1, w2, b2, scale, shift))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("cfg", [False, True])
def test_forward_mirror_matches_jnp_composition(cfg):
    spec = mf.init_mlp_field(8, 12, 4, 3, depth=2, seed=91, cfg=cfg)
    s = mf._Stream(17)
    x = s.take(5 * 8, np.float32(1.0)).reshape(5, 8)
    labels = np.arange(5) % 4
    got = mf.forward_mirror(spec, x, 0.37, 1.25, labels)
    want = mf.forward_jnp(spec, x, 0.37, 1.25, labels)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)
    # guidance weight must matter when cfg is on (labels vs null differ)
    other = mf.forward_mirror(spec, x, 0.37, 0.0, labels)
    if cfg:
        assert np.max(np.abs(got - other)) > 0
    else:
        np.testing.assert_array_equal(got, other)
