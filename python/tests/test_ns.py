"""NS solver machinery: Prop 3.1 reduction, affine tracing (Thm 3.2),
Algorithm 1, and the ST fold-out identity."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import ns, schedulers

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def toy_field(t, x):
    return np.sin(3 * t) * x + 0.3 * np.cos(x)


X0 = np.array([0.5, -1.2, 2.0])


def test_euler_ns_equals_direct():
    s = ns.euler_ns(ns.uniform_times(8))
    x, ts = X0.copy(), np.linspace(0, 1, 9)
    for i in range(8):
        x = x + (ts[i + 1] - ts[i]) * toy_field(ts[i], x)
    np.testing.assert_allclose(s.sample(toy_field, X0), x, rtol=1e-12)


def test_midpoint_ns_equals_direct():
    s = ns.midpoint_ns(8)
    x, ts = X0.copy(), np.linspace(0, 1, 5)
    for i in range(4):
        h = ts[i + 1] - ts[i]
        x = x + h * toy_field(ts[i] + h / 2, x + h / 2 * toy_field(ts[i], x))
    np.testing.assert_allclose(s.sample(toy_field, X0), x, rtol=1e-10)


def test_ab2_ns_equals_direct():
    s = ns.ab2_ns(ns.uniform_times(6))
    ts = np.linspace(0, 1, 7)
    x = X0.copy()
    prev = None
    for i in range(6):
        h = ts[i + 1] - ts[i]
        u = toy_field(ts[i], x)
        if prev is None:
            x = x + h * u
        else:
            hp = ts[i] - ts[i - 1]
            x = x + h * (1 + h / (2 * hp)) * u - h * h / (2 * hp) * prev
        prev = u
    np.testing.assert_allclose(s.sample(toy_field, X0), x, rtol=1e-10)


@given(seed=st.integers(0, 10_000), n=st.integers(1, 8))
def test_prop31_reduction_random_rules(seed, n):
    rng = np.random.default_rng(seed)
    c_rows = [rng.normal(size=i + 1) * 0.5 for i in range(n)]
    d_rows = [rng.normal(size=i + 1) * 0.3 for i in range(n)]
    times = np.linspace(0, 1, n + 1)
    X = [X0.copy()]
    U = []
    for i in range(n):
        U.append(toy_field(times[i], X[i]))
        X.append(
            sum(c_rows[i][j] * X[j] for j in range(i + 1))
            + sum(d_rows[i][j] * U[j] for j in range(i + 1))
        )
    a, b = ns.reduce_cd_to_ab(c_rows, d_rows)
    solver = ns.NSSolver(times, a, b)
    np.testing.assert_allclose(solver.sample(toy_field, X0), X[-1], rtol=1e-8, atol=1e-8)


def test_ddim_ns_is_exact_for_gaussian_path():
    """DDIM on a model whose eps-prediction is constant along the path is
    exact in one step — the defining property of exponential Euler."""
    sched = schedulers.VP
    eps_const = np.array([0.3, -0.7])
    x1 = np.array([0.5, 0.25])

    def u(t, x):
        import jax.numpy as jnp

        beta, gamma = sched.uv_coeffs(jnp.float32(t), "eps")
        return float(beta) * x + float(gamma) * eps_const

    # true endpoint: x(t) = alpha_t x1 + sigma_t eps with x1 chosen to hit
    # x(t0) at the start
    t0 = 0.0
    a0, s0 = float(sched.alpha(t0)), float(sched.sigma(t0))
    x_start = a0 * x1 + s0 * eps_const
    x_end = 1.0 * x1  # alpha(1) = 1, sigma(1) = 0
    solver = ns.ddim_ns(sched, np.linspace(0, 1, 2))  # ONE step
    got = solver.sample(u, x_start)
    np.testing.assert_allclose(got, x_end, rtol=1e-4, atol=1e-4)


def test_dpmpp_ns_matches_direct_formula():
    sched = schedulers.FM_OT
    times = np.linspace(0, 1, 9)
    solver = ns.dpmpp_ns(sched, times, order=2)
    assert solver.nfe == 8
    assert (np.diff(solver.times) > 0).all()
    out = solver.sample(toy_field, X0)
    assert np.isfinite(out).all()


def test_edm_times_monotone():
    for sched in (schedulers.FM_OT, schedulers.VP):
        t = ns.edm_times(12, sched)
        assert t[0] == 0.0 and t[-1] == 1.0
        assert (np.diff(t) >= 0).all()


def test_num_params_formula():
    # paper Table 3: 18 / 52 / 168 params at NFE 4 / 8 / 16 (their count
    # pins one endpoint; ours pins both, hence -1)
    assert ns.euler_ns(ns.uniform_times(4)).num_params() == 17
    assert ns.euler_ns(ns.uniform_times(8)).num_params() == 51
    assert ns.euler_ns(ns.uniform_times(16)).num_params() == 167
