"""Scheduler identities (eq. 4, snr monotonicity/inversion) and the
ST <-> scheduler-change correspondence (eq. 8)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import schedulers

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")

ALL = list(schedulers.SCHEDULERS.values())
BOUNDED = [schedulers.FM_OT, schedulers.COSINE, schedulers.VP]


@pytest.mark.parametrize("s", BOUNDED, ids=lambda s: s.name)
def test_boundary_conditions(s):
    # eq. 4: alpha_1 = 1, sigma_1 = 0, sigma_0 > 0, alpha_0 ~ 0
    assert float(s.alpha(1.0)) == pytest.approx(1.0, abs=1e-5)
    assert float(s.sigma(1.0)) == pytest.approx(0.0, abs=1e-3)
    assert float(s.sigma(0.0)) > 0.5
    assert float(s.alpha(0.0)) < 0.01


@pytest.mark.parametrize("s", ALL, ids=lambda s: s.name)
def test_snr_strictly_increasing(s):
    t = jnp.linspace(0.01, 0.99, 101)
    snr = np.asarray(s.snr(t))
    assert (np.diff(snr) > 0).all()


@given(t=st.floats(0.02, 0.97))
@pytest.mark.parametrize("s", ALL, ids=lambda s: s.name)
def test_snr_inv_roundtrip(s, t):
    back = float(s.snr_inv(s.snr(jnp.float32(t))))
    assert back == pytest.approx(t, abs=5e-5)


@pytest.mark.parametrize("s", ALL, ids=lambda s: s.name)
def test_derivatives_match_autodiff_fd(s):
    for t in np.linspace(0.05, 0.95, 10):
        h = 1e-4
        fd_a = (float(s.alpha(t + h)) - float(s.alpha(t - h))) / (2 * h)
        assert float(s.dalpha(t)) == pytest.approx(fd_a, rel=1e-2, abs=1e-3)
        fd_s = (float(s.sigma(t + h)) - float(s.sigma(t - h))) / (2 * h)
        assert float(s.dsigma(t)) == pytest.approx(fd_s, rel=1e-2, abs=1e-3)


@pytest.mark.parametrize("s", BOUNDED, ids=lambda s: s.name)
def test_table1_consistency(s):
    """With the *true* f (noise / data), every parametrization gives the
    same velocity as the path derivative: u = dalpha x1 + dsigma x0."""
    x1, x0 = 0.7, -0.3
    for t in np.linspace(0.05, 0.9, 8):
        t = jnp.float32(t)
        x = float(s.alpha(t)) * x1 + float(s.sigma(t)) * x0
        truth = float(s.dalpha(t)) * x1 + float(s.dsigma(t)) * x0
        for param, f in [("eps", x0), ("x", x1)]:
            beta, gamma = s.uv_coeffs(t, param)
            assert float(beta) * x + float(gamma) * f == pytest.approx(truth, rel=1e-3, abs=1e-4)


def test_st_scheduler_change_roundtrip():
    """eq. 8: converting a scheduler change to (s_r, t_r) and back must
    reproduce the new scheduler: alpha-bar = s alpha(t), sigma-bar = s sigma(t)."""
    old = schedulers.FM_OT
    sigma0 = 3.0
    new_alpha = lambda r: old.alpha(r)
    new_sigma = lambda r: sigma0 * old.sigma(r)
    st_ = schedulers.st_from_scheduler_change(old, new_alpha, new_sigma)
    for r in np.linspace(0.05, 0.95, 9):
        r = jnp.float32(r)
        s_r, t_r = float(st_.s(r)), float(st_.t(r))
        assert s_r * float(old.alpha(t_r)) == pytest.approx(float(new_alpha(r)), rel=1e-4, abs=1e-5)
        assert s_r * float(old.sigma(t_r)) == pytest.approx(float(new_sigma(r)), rel=1e-4, abs=1e-5)


def test_st_transform_recovers_sample():
    """eq. 6: x(1) = s_1^{-1} x̄(1) — integrate a toy field both ways."""
    from compile import ode

    old = schedulers.FM_OT

    def u(t, x):
        return np.sin(3 * t) * x + 0.2

    stf = schedulers.precondition(old, 2.0)

    def u_bar(r, x):
        return np.asarray(stf.transform_u(lambda tt, xx: jnp.asarray(u(float(tt), np.asarray(xx))))(jnp.float32(r), jnp.asarray(x)))

    x0 = np.array([0.5, -1.0], np.float32)
    x1, _ = ode.rk45(u, x0.copy())
    s0, s1 = float(stf.s(0.0)), float(stf.s(1.0))
    xbar1, _ = ode.rk45(u_bar, s0 * x0)
    np.testing.assert_allclose(xbar1 / s1, x1, rtol=1e-3, atol=1e-4)
