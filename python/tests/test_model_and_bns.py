"""Model contracts, Algorithm 2 behavior, the preconditioning fold-out
identity, and BST export equivalence — on tiny budgets (CI-scale)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import bns, data, model, ns, train_model


@pytest.fixture(scope="module")
def tiny():
    cfg = model.ModelConfig("tiny", data_dim=12, num_classes=4, hidden=32, depth=2, emb_dim=16)
    params = model.init_params(cfg, seed=1)
    # init_params zero-initializes the output head (residual style), which
    # makes the velocity field trivially integrable — rescale so the field
    # is genuinely nonlinear without having to train in unit tests.
    params = dict(params)
    params["out_w"] = params["out_w"] * 3e3
    params["out_b"] = params["out_b"] + 0.05
    return cfg, params


def test_model_shapes_and_determinism(tiny):
    cfg, params = tiny
    x = jnp.ones((5, cfg.data_dim))
    lab = jnp.asarray([0, 1, 2, 3, 0], jnp.int32)
    out1 = model.model_f(cfg, params, x, jnp.float32(0.3), lab, use_pallas=False)
    out2 = model.model_f(cfg, params, x, jnp.float32(0.3), lab, use_pallas=False)
    assert out1.shape == (5, cfg.data_dim)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_pallas_and_ref_paths_agree(tiny):
    cfg, params = tiny
    x = jax.random.normal(jax.random.PRNGKey(0), (6, cfg.data_dim))
    lab = jnp.zeros(6, jnp.int32)
    a = model.model_f(cfg, params, x, jnp.float32(0.5), lab, use_pallas=True)
    b = model.model_f(cfg, params, x, jnp.float32(0.5), lab, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_guided_velocity_w0_equals_conditional(tiny):
    cfg, params = tiny
    x = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.data_dim))
    lab = jnp.asarray([0, 1, 2, 3], jnp.int32)
    gw = model.guided_velocity(cfg, params, x, jnp.float32(0.4), lab, 0.0, use_pallas=False)
    cv = model.velocity(cfg, params, x, jnp.float32(0.4), lab, use_pallas=False)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(cv), rtol=1e-5, atol=1e-6)


def test_guided_velocity_interpolates(tiny):
    cfg, params = tiny
    x = jax.random.normal(jax.random.PRNGKey(2), (3, cfg.data_dim))
    lab = jnp.asarray([1, 2, 3], jnp.int32)
    null = jnp.full((3,), cfg.null_class, jnp.int32)
    u_c = model.velocity(cfg, params, x, jnp.float32(0.6), lab, use_pallas=False)
    u_n = model.velocity(cfg, params, x, jnp.float32(0.6), null, use_pallas=False)
    w = 2.5
    want = u_c + w * (u_c - u_n)
    got = model.guided_velocity(cfg, params, x, jnp.float32(0.6), lab, w, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def _mini_pairs(cfg, params, n, seed, w=0.0):
    def fnp(t, x, labels):
        return np.asarray(
            model.guided_velocity(cfg, params, jnp.asarray(x), jnp.float32(t),
                                  jnp.asarray(labels), w, use_pallas=False)
        )
    return bns.make_pairs(fnp, cfg.data_dim, n, seed=seed, num_classes=cfg.num_classes)


def test_bns_training_improves_over_init(tiny):
    cfg, params = tiny
    tr = _mini_pairs(cfg, params, 48, seed=0)
    va = _mini_pairs(cfg, params, 48, seed=1)

    def field(t, x, labels):
        return model.guided_velocity(cfg, params, x, t, labels, 0.0, use_pallas=False)

    # euler init leaves clear headroom even on this untrained tiny model
    res = bns.train_bns(field, tr, va, nfe=6, init="euler", iters=150, val_every=30,
                        log=lambda *a: None)
    assert res.val_psnr > res.init_val_psnr + 0.5, (res.val_psnr, res.init_val_psnr)
    # exported solver is valid and reproduces the val PSNR when run in numpy
    solver = res.solver
    assert (np.diff(solver.times) > 0).all()

    def fnp(t, x):
        return np.asarray(field(jnp.float32(t), jnp.asarray(x), jnp.asarray(va["labels"])))

    out = solver.sample(fnp, va["x0"])
    got = float(bns.psnr(jnp.asarray(out), jnp.asarray(va["x1"])))
    assert got == pytest.approx(res.val_psnr, abs=0.6)


def test_precondition_fold_identity(tiny):
    cfg, params = tiny

    def field(t, x, labels):
        return model.guided_velocity(cfg, params, x, t, labels, 1.5, use_pallas=False)

    for schedname in ("fm_ot", "cosine", "vp"):
        pc = bns.Precondition(schedname, sigma0=4.0)
        lab = jnp.asarray(np.arange(5) % cfg.num_classes, jnp.int32)
        u_l = lambda t, x: field(t, x, lab)
        sol_r = ns.euler_ns(ns.uniform_times(5))
        s0, s1 = float(pc.s_of_r(0.0)), float(pc.s_of_r(1.0))
        x0 = np.random.default_rng(3).standard_normal((5, cfg.data_dim)).astype(np.float32)
        xa = bns.sample_ns_jax(
            pc.transform(u_l),
            jnp.asarray(sol_r.times, jnp.float32),
            jnp.asarray(sol_r.a, jnp.float32),
            jnp.asarray(sol_r.b, jnp.float32),
            s0 * jnp.asarray(x0),
        ) / s1
        folded = bns.fold_transform(sol_r, *pc.node_values(sol_r.times))
        xb = folded.sample(lambda t, x: np.asarray(u_l(jnp.float32(t), jnp.asarray(x))), x0)
        rel = np.abs(np.asarray(xa) - xb).max() / max(1e-9, np.abs(xb).max())
        assert rel < 1e-4, f"{schedname}: {rel}"


def test_bst_training_exports_valid_ns(tiny):
    cfg, params = tiny
    tr = _mini_pairs(cfg, params, 40, seed=5)
    va = _mini_pairs(cfg, params, 40, seed=6)

    def field(t, x, labels):
        return model.guided_velocity(cfg, params, x, t, labels, 0.0, use_pallas=False)

    res = bns.train_bst(field, tr, va, nfe=6, iters=100, val_every=25, log=lambda *a: None)
    res.solver.times  # exported NS form
    assert (np.diff(res.solver.times) > 0).all()
    assert res.val_psnr >= res.init_val_psnr - 0.2  # never worse than init


def test_datasets_bounded_and_labeled():
    rng = np.random.default_rng(0)
    x, lab = data.make_images(rng, 64)
    assert x.shape == (64, data.IMG_DIM) and np.abs(x).max() <= 1.0
    assert lab.min() >= 0 and lab.max() < data.NUM_CLASSES
    xa, la = data.make_audio(rng, 64)
    assert xa.shape == (64, data.AUDIO_LEN) and np.abs(xa).max() <= 1.0


def test_training_loss_decreases_quick():
    cfg = model.ModelConfig("t2", data_dim=12, num_classes=4, hidden=32, depth=2, emb_dim=16)
    # patch data gen to the tiny dim via a monkeypatched make: reuse audio?
    # simplest: train on synthetic gaussians through the private loss path
    params = model.init_params(cfg, seed=0)
    import functools
    from compile.train_model import _loss, adam_init, adam_update, clip_global_norm

    rng = np.random.default_rng(0)
    lg = jax.jit(jax.value_and_grad(functools.partial(_loss, cfg)))
    opt = adam_init(params)
    losses = []
    for it in range(80):
        x1 = rng.standard_normal((32, cfg.data_dim)).astype(np.float32) * 0.5
        lab = rng.integers(0, 4, 32).astype(np.int32)
        x0 = rng.standard_normal((32, cfg.data_dim)).astype(np.float32)
        t = rng.random(32).astype(np.float32)
        loss, g = lg(params, jnp.asarray(x1), jnp.asarray(lab), jnp.asarray(x0), jnp.asarray(t))
        params, opt = adam_update(params, clip_global_norm(g), opt, 1e-3)
        losses.append(float(loss))
    # compare averaged windows — single batches are too noisy
    assert np.mean(losses[-10:]) < np.mean(losses[:10])
