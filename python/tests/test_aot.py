"""AOT contract tests: HLO text artifacts contain full constants and the
lowered computation is numerically identical to the jax evaluation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def tiny():
    cfg = model.ModelConfig("tiny_aot", data_dim=10, num_classes=3, hidden=24, depth=2, emb_dim=16)
    params = model.init_params(cfg, seed=2)
    return cfg, params


def test_hlo_text_has_full_constants(tiny):
    cfg, params = tiny
    text = aot.lower_model(cfg, params, 4, use_pallas=False)
    # the default printer elides big literals as `constant({...})`, which
    # would silently corrupt the baked weights — must never appear
    assert "constant({...}" not in text
    assert "f32[4,10]" in text  # entry signature present


def test_lowered_signature_matches_jit_numerics(tiny):
    """The jitted artifact function (pallas path) must equal the eager
    reference path — this is the computation the HLO text captures; the
    rust integration tests re-execute the same text through PJRT."""
    cfg, params = tiny
    batch = 4
    x = np.linspace(-1, 1, batch * cfg.data_dim).astype(np.float32).reshape(batch, cfg.data_dim)
    t = np.float32(0.37)
    w = np.float32(1.5)
    labels = np.arange(batch, dtype=np.int32) % cfg.num_classes

    want = np.asarray(
        model.guided_velocity(cfg, params, jnp.asarray(x), t, jnp.asarray(labels), w, use_pallas=False)
    )
    jitted = jax.jit(
        lambda x, t, w, l: model.guided_velocity(cfg, params, x, t, l, w, use_pallas=True)
    )
    got = np.asarray(jitted(jnp.asarray(x), t, w, jnp.asarray(labels)))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_export_writes_per_bucket_files(tmp_path, tiny):
    cfg, params = tiny
    entries = aot.export_model(cfg, params, str(tmp_path), buckets=(1, 2), use_pallas=False, log=lambda *a: None)
    assert [e["batch"] for e in entries] == [1, 2]
    for e in entries:
        p = tmp_path / e["path"]
        assert p.exists() and p.stat().st_size > 1000
