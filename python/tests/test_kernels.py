"""L1 kernels vs the pure-jnp oracle — hypothesis sweeps over shapes,
seeds, and batch tiles. This is the core correctness signal for the
Pallas layer (interpret=True; see kernels/*.py headers)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_resblock import fused_resblock
from compile.kernels.ns_update import ns_update

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


@given(
    b=st.integers(1, 17),
    d=st.integers(1, 40),
    h=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
    tile=st.sampled_from([1, 4, 8]),
)
def test_fused_resblock_matches_ref(b, d, h, seed, tile):
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    x = rand(ks[0], b, d)
    w1 = rand(ks[1], d, h, scale=0.2)
    b1 = rand(ks[2], h, scale=0.1)
    w2 = rand(ks[3], h, d, scale=0.2)
    b2 = rand(ks[4], d, scale=0.1)
    sc = rand(ks[5], b, d, scale=0.1)
    sh = rand(ks[6], b, d, scale=0.1)
    want = ref.fused_resblock(x, w1, b1, w2, b2, sc, sh)
    got = fused_resblock(x, w1, b1, w2, b2, sc, sh, batch_tile=tile)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@given(
    k=st.integers(1, 12),
    b=st.integers(1, 13),
    d=st.integers(1, 33),
    seed=st.integers(0, 2**31 - 1),
    tile=st.sampled_from([1, 4, 8]),
)
def test_ns_update_matches_ref(k, b, d, seed, tile):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x0 = rand(ks[0], b, d)
    hist = rand(ks[1], k, b, d)
    a = rand(ks[2])[()]
    bb = rand(ks[3], k)
    want = ref.ns_update(x0, hist, a, bb)
    got = ns_update(x0, hist, a, bb, batch_tile=tile)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_ns_update_zero_coefficients_masks_history():
    # rows with b_k = 0 must not contribute even if they contain garbage
    x0 = jnp.ones((2, 4))
    hist = jnp.stack([jnp.full((2, 4), 1.0), jnp.full((2, 4), jnp.nan)])
    b = jnp.asarray([2.0, 0.0])
    got = ns_update(x0, jnp.nan_to_num(hist, nan=1e30), jnp.float32(0.5), b)
    want = 0.5 * x0 + 2.0 * jnp.ones((2, 4))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_resblock_is_identity_at_zero_weights():
    b, d, h = 3, 8, 16
    x = jnp.arange(b * d, dtype=jnp.float32).reshape(b, d) / 10
    z = jnp.zeros
    got = fused_resblock(x, z((d, h)), z((h,)), z((h, d)), z((d,)), z((b, d)), z((b, d)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-6)


def test_time_embed_shape_and_range():
    e = ref.time_embed(jnp.float32(0.37) * 1000, 64)
    assert e.shape == (64,)
    assert float(jnp.abs(e).max()) <= 1.0 + 1e-6
