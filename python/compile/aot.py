"""AOT lowering: jax model -> HLO *text* artifacts for the rust runtime.

Interchange is HLO text, NOT a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that the crate's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Each model is lowered once per batch bucket with the signature

    (x [B, D] f32, t [] f32, w [] f32, labels [B] i32) -> (u_w [B, D] f32,)

where u_w is the CFG-composed velocity field (model.guided_velocity);
w = 0 recovers conditional-unguided sampling. The L1 Pallas kernels are
lowered *into* the same HLO (interpret=True), so the rust hot path runs
the exact kernel code validated against ref.py.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

BATCH_BUCKETS = (1, 8, 64)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default printer
    # elides big literals as `constant({...})`, which silently corrupts
    # the baked-in model weights on the rust side.
    return comp.as_hlo_text(print_large_constants=True)


def lower_model(cfg: model.ModelConfig, params: dict, batch: int, *, use_pallas=True) -> str:
    """Lower the guided velocity field at a fixed batch size to HLO text.

    Weights are baked in as constants (closure capture), so the artifact
    is self-contained: the rust side feeds only (x, t, w, labels).
    """

    def fn(x, t, w, labels):
        return (model.guided_velocity(cfg, params, x, t, labels, w, use_pallas=use_pallas),)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((batch, cfg.data_dim), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    )
    return to_hlo_text(lowered)


def export_model(cfg, params, out_dir, *, buckets=BATCH_BUCKETS, use_pallas=True, log=print):
    """Write one HLO artifact per batch bucket; returns manifest entries."""
    entries = []
    for b in buckets:
        path = f"models/{cfg.name}_b{b}.hlo.txt"
        full = os.path.join(out_dir, path)
        os.makedirs(os.path.dirname(full), exist_ok=True)
        if not os.path.exists(full):
            text = lower_model(cfg, params, b, use_pallas=use_pallas)
            with open(full, "w") as f:
                f.write(text)
            log(f"  [aot] {path} ({len(text)/1e6:.1f} MB)")
        entries.append({"batch": b, "path": path})
    return entries
