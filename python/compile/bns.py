"""BNS optimization (Section 3.2, Algorithm 2) and the BST ablation.

Pipeline (all build-time):

  1. `make_pairs`    — sample x0 ~ p0, integrate eq. 1 with adaptive RK45
                       to get GT pairs (x0, x(1))  [520 train / 1024 val,
                       as in App. D.1].
  2. `train_bns`     — parameterize theta = [T_n, (a_i, b_i)] (eq. 12),
                       minimize the PSNR loss (eq. 13) with Adam,
                       optionally over a sigma0-preconditioned field
                       (eq. 14); report best-validation iterate.
  3. `train_bst`     — the Scale-Time ablation (Fig. 11): same optimizer,
                       same loss, but theta restricted to per-node
                       (t, ṫ, s, ṡ) driving an Euler step on the
                       ST-transformed field (eq. 7) — the BST family of
                       Shaul et al. 2023.
  4. `fold_transform`— export: any solver trained on a transformed field
                       is folded back to plain NS coefficients over the
                       *original* field via the eq. 48-51 expansion +
                       Prop 3.1 reduction, so the rust engine only ever
                       needs the NS update rule.

PSNR convention used everywhere (python + rust): data lives in [-1, 1],
PSNR = 10 log10(4 / mse) with mse averaged per-sample over dimensions.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import ns, ode, schedulers
from .train_model import adam_init, adam_update, clip_global_norm

PEAK_SQ = 4.0  # (max - min)^2 for data in [-1, 1]


def _sanitize_grads(grads):
    """High-CFG fields can overflow single leaves of the unrolled-solver
    gradient (w amplifies a 20-step chain); replace non-finite entries
    before global-norm clipping so one bad minibatch doesn't poison Adam.
    """
    return {
        k: jnp.nan_to_num(g, nan=0.0, posinf=1e3, neginf=-1e3) for k, g in grads.items()
    }


def psnr(pred, ref):
    mse = jnp.mean((pred - ref) ** 2, axis=-1)
    return jnp.mean(10.0 * jnp.log10(PEAK_SQ / jnp.maximum(mse, 1e-20)))


# ---------------------------------------------------------------------------
# GT pair generation (the paper's 520-pair training set)
# ---------------------------------------------------------------------------


def make_pairs(field_np, dim, n_pairs, seed, num_classes=None, sigma_src=1.0, rtol=1e-5):
    """Generate (x0, labels, x1) with adaptive RK45 (Shampine 1986).

    `field_np(t, x, labels)` is a numpy-callable guided velocity field.
    Returns dict of arrays + the RK45 NFE (for Table 3 forwards
    accounting).
    """
    rng = np.random.default_rng(seed)
    x0 = (sigma_src * rng.standard_normal((n_pairs, dim))).astype(np.float32)
    labels = (
        rng.integers(0, num_classes, size=n_pairs).astype(np.int32)
        if num_classes
        else np.zeros(n_pairs, np.int32)
    )
    x1, nfe = ode.rk45(lambda t, x: field_np(t, x, labels), x0, rtol=rtol, atol=rtol)
    return {"x0": x0, "labels": labels, "x1": x1, "gt_nfe": nfe}


# ---------------------------------------------------------------------------
# Preconditioning (eq. 14) and ST-transformed fields (eq. 7)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Precondition:
    """sigma0 scheduler change: sigma̅ = sigma0 sigma, alpha̅ = alpha."""

    scheduler: str
    sigma0: float

    def t_of_r(self, r):
        """snr^{-1}(snr(r)/sigma0), in closed form where the generic
        ratio is unstable at the data endpoint (snr(1) = inf)."""
        r = jnp.asarray(r, jnp.float32)
        if self.scheduler == "fm_ot":
            return r / (r + self.sigma0 * (1.0 - r))
        if self.scheduler == "cosine":
            # atan2 form is exact and stable at r = 1 (tan blows up there).
            return (2.0 / jnp.pi) * jnp.arctan2(
                jnp.sin(0.5 * jnp.pi * r), self.sigma0 * jnp.cos(0.5 * jnp.pi * r)
            )
        sched = schedulers.SCHEDULERS[self.scheduler]
        return sched.snr_inv(sched.snr(r) / self.sigma0)

    def s_of_r(self, r):
        """sigma̅_r / sigma_{t_r} (eq. 8), in endpoint-stable form."""
        r = jnp.asarray(r, jnp.float32)
        if self.scheduler == "fm_ot":
            return r + self.sigma0 * (1.0 - r)
        if self.scheduler == "cosine":
            return jnp.hypot(
                jnp.sin(0.5 * jnp.pi * r), self.sigma0 * jnp.cos(0.5 * jnp.pi * r)
            )
        # Generic: alpha̅ = alpha gives the alpha-ratio expression, which is
        # regular wherever alpha_{t_r} is bounded away from 0; fall back to
        # the sigma-ratio near the noise endpoint.
        sched = schedulers.SCHEDULERS[self.scheduler]
        t = self.t_of_r(r)
        a_t, s_t = sched.alpha(t), sched.sigma(t)
        return jnp.where(
            a_t > s_t,
            sched.alpha(r) / jnp.maximum(a_t, 1e-20),
            self.sigma0 * sched.sigma(r) / jnp.maximum(s_t, 1e-20),
        )

    def ds_of_r(self, r):
        return jax.grad(lambda q: jnp.sum(self.s_of_r(q)))(jnp.asarray(r, jnp.float32))

    def dt_of_r(self, r):
        return jax.grad(lambda q: jnp.sum(self.t_of_r(q)))(jnp.asarray(r, jnp.float32))

    def transform(self, u):
        """eq. 7 over the original field u(t, x) -> u̅(r, x)."""

        def u_bar(r, x):
            s, ds = self.s_of_r(r), self.ds_of_r(r)
            t, dt = self.t_of_r(r), self.dt_of_r(r)
            return (ds / s) * x + dt * s * u(t, x / s)

        return u_bar

    def node_values(self, r):
        """(t, dt, s, ds) at the nodes r — for export folding."""
        r = jnp.asarray(r, jnp.float32)
        t = jax.vmap(self.t_of_r)(r)
        dt = jax.vmap(self.dt_of_r)(r)
        s = jax.vmap(self.s_of_r)(r)
        ds = jax.vmap(self.ds_of_r)(r)
        return (np.asarray(t, np.float64), np.asarray(dt, np.float64),
                np.asarray(s, np.float64), np.asarray(ds, np.float64))


def fold_transform(solver: ns.NSSolver, t_nodes, dt_nodes, s_nodes, ds_nodes) -> ns.NSSolver:
    """Fold an NS solver over a transformed field back onto the original.

    Implements the expansion of the Thm 3.2 proof (eqs. 48-51): with
    x̄_j = s_j x_j and ū_j = (ṡ_j/s_j) x̄_j + ṫ_j s_j u_j, the update
    x̄_{i+1} = a_i x̄_0 + sum_j b_ij ū_j becomes a naive (c, d) NS rule
    over the original (x_j, u_j), which `reduce_cd_to_ab` (Prop 3.1)
    reduces to the exported (a, b).

    Node arrays are indexed by the *transformed* discretization r_0..r_n;
    t_nodes gives the original-field times.
    """
    n = solver.nfe
    c_rows, d_rows = [], []
    for i in range(n):
        c = np.zeros(i + 1)
        d = np.zeros(i + 1)
        c[0] += solver.a[i] * s_nodes[0] / s_nodes[i + 1]
        for j in range(i + 1):
            c[j] += solver.b[i, j] * ds_nodes[j] / s_nodes[i + 1]
            d[j] = solver.b[i, j] * dt_nodes[j] * s_nodes[j] / s_nodes[i + 1]
        c_rows.append(c)
        d_rows.append(d)
    a, b = ns.reduce_cd_to_ab(c_rows, d_rows)
    return ns.NSSolver(np.asarray(t_nodes, np.float64), a, b)


# ---------------------------------------------------------------------------
# theta parameterization and differentiable Algorithm 1
# ---------------------------------------------------------------------------


def theta_from_solver(solver: ns.NSSolver) -> dict:
    """Invert the parameterization so optimization starts at `solver`."""
    dt = np.diff(solver.times)
    assert (dt > 0).all(), "NS times must be strictly increasing"
    n = solver.nfe
    btri = np.zeros((n, n), np.float32)
    btri[: n, : n] = solver.b
    return {
        "t_logits": jnp.asarray(np.log(dt), jnp.float32),
        "a": jnp.asarray(solver.a, jnp.float32),
        "b": jnp.asarray(btri, jnp.float32),
    }


def theta_to_coeffs(theta):
    """(times [n+1], a [n], b [n,n] masked lower-tri) from raw theta."""
    inc = jax.nn.softmax(theta["t_logits"])
    times = jnp.concatenate([jnp.zeros(1), jnp.cumsum(inc)])
    times = times / times[-1]  # exact 1.0 endpoint
    n = theta["a"].shape[0]
    mask = jnp.tril(jnp.ones((n, n), jnp.float32))
    return times, theta["a"], theta["b"] * mask


def solver_from_theta(theta) -> ns.NSSolver:
    times, a, b = theta_to_coeffs(theta)
    return ns.NSSolver(
        np.asarray(times, np.float64), np.asarray(a, np.float64), np.asarray(b, np.float64)
    )


def sample_ns_jax(u, times, a, b, x0):
    """Differentiable Algorithm 1 (unrolled; n is static)."""
    n = a.shape[0]
    x, hist = x0, []
    for i in range(n):
        hist.append(u(times[i], x))
        acc = a[i] * x0
        for j in range(i + 1):
            acc = acc + b[i, j] * hist[j]
        x = acc
    return x


# ---------------------------------------------------------------------------
# Algorithm 2: BNS training
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrainResult:
    solver: ns.NSSolver  # folded to the ORIGINAL field
    val_psnr: float
    init_val_psnr: float
    iters_run: int
    forwards: int  # model forward passes consumed (Table 3 accounting)
    history: list  # (iter, train_loss, val_psnr)


def train_bns(
    field,
    pairs_train,
    pairs_val,
    nfe,
    *,
    init="midpoint",
    precond: Precondition | None = None,
    iters=3000,
    batch=40,
    lr=1e-3,
    seed=0,
    val_every=100,
    log=print,
) -> TrainResult:
    """Algorithm 2. `field(t, x, labels)` is the original (possibly CFG)
    velocity field as a jax function over batched x; the per-pair labels
    from `pairs_*` are threaded through each evaluation.
    """
    rng = np.random.default_rng(seed)

    # --- initial solver in the (possibly transformed) r-space ----------
    if init == "euler":
        init_solver = ns.euler_ns(ns.uniform_times(nfe))
    elif init == "midpoint":
        if nfe % 2 == 0:
            init_solver = ns.midpoint_ns(nfe)
        else:
            init_solver = ns.euler_ns(ns.uniform_times(nfe))
    elif isinstance(init, ns.NSSolver):
        init_solver = init
    else:
        raise ValueError(f"unknown init {init!r}")

    s0_scale = float(precond.s_of_r(0.0)) if precond is not None else 1.0
    s1_scale = float(precond.s_of_r(1.0)) if precond is not None else 1.0

    def bound_field(labels):
        u_l = lambda t, x: field(t, x, labels)
        return precond.transform(u_l) if precond is not None else u_l

    theta = theta_from_solver(init_solver)
    opt = adam_init(theta)

    def loss_fn(theta, x0, x1, labels):
        times, a, b = theta_to_coeffs(theta)
        xn = sample_ns_jax(bound_field(labels), times, a, b, s0_scale * x0) / s1_scale
        mse = jnp.mean((xn - x1) ** 2, axis=-1)
        return jnp.mean(jnp.log(jnp.maximum(mse, 1e-20)))

    @jax.jit
    def val_psnr_fn(theta, x0, x1, labels):
        times, a, b = theta_to_coeffs(theta)
        xn = sample_ns_jax(bound_field(labels), times, a, b, s0_scale * x0) / s1_scale
        return psnr(xn, x1)

    loss_grad = jax.jit(jax.value_and_grad(loss_fn))
    update = jax.jit(lambda p, o, g, lr: adam_update(p, clip_global_norm(_sanitize_grads(g)), o, lr))

    x0_tr = jnp.asarray(pairs_train["x0"])
    x1_tr = jnp.asarray(pairs_train["x1"])
    la_tr = jnp.asarray(pairs_train["labels"])
    x0_va = jnp.asarray(pairs_val["x0"])
    x1_va = jnp.asarray(pairs_val["x1"])
    la_va = jnp.asarray(pairs_val["labels"])

    init_val = float(val_psnr_fn(theta, x0_va, x1_va, la_va))
    best = (init_val, jax.tree_util.tree_map(lambda x: x, theta), 0)
    history = []
    t_start = time.time()
    n_train = x0_tr.shape[0]
    lr_scale = 1.0
    for it in range(iters):
        idx = rng.integers(0, n_train, size=batch)
        cur_lr = lr_scale * lr * (1.0 - 0.95 * it / iters)  # polynomial decay
        loss, grads = loss_grad(theta, x0_tr[idx], x1_tr[idx], la_tr[idx])
        if not np.isfinite(float(loss)):
            # High-guidance fields occasionally blow a step up; restore the
            # best-so-far iterate and continue cooler.
            theta = jax.tree_util.tree_map(lambda x: x, best[1])
            opt = adam_init(theta)
            lr_scale *= 0.3
            if lr_scale < 1e-3:
                break
            continue
        theta, opt = update(theta, opt, grads, cur_lr)
        if (it + 1) % val_every == 0 or it == iters - 1:
            vp = float(val_psnr_fn(theta, x0_va, x1_va, la_va))
            if not np.isfinite(vp):
                continue
            history.append((it + 1, float(loss), vp))
            if vp > best[0]:
                best = (vp, jax.tree_util.tree_map(lambda x: x, theta), it + 1)
    log(
        f"    nfe={nfe} init_psnr={init_val:.2f} best_psnr={best[0]:.2f} "
        f"@it{best[2]} ({time.time()-t_start:.0f}s)"
    )

    solver = solver_from_theta(best[1])
    if precond is not None:
        solver = fold_transform(solver, *precond.node_values(solver.times))
    # forwards: nfe evals per sample per iteration (fwd+bwd counted as in
    # App. D.4: one forward per model evaluation with batch 1).
    forwards = iters * batch * nfe
    return TrainResult(solver, best[0], init_val, iters, forwards, history)


# ---------------------------------------------------------------------------
# BST ablation (Fig. 11): Scale-Time family under the same optimizer
# ---------------------------------------------------------------------------


def train_bst(
    field,
    pairs_train,
    pairs_val,
    nfe,
    *,
    precond: Precondition | None = None,
    iters=3000,
    batch=40,
    lr=5e-4,
    seed=0,
    val_every=100,
    log=print,
) -> TrainResult:
    """Bespoke Scale-Time (Shaul et al. 2023) with Euler base solver.

    theta_ST = per-node (t, ṫ, s, ṡ): 4(n+1) - constraints parameters vs
    the NS family's n(n+5)/2 + 1 — the expressiveness gap of Thm 3.2. The
    update is the eq. 49 expansion of Euler on the transformed field:
        x_{i+1} = [(s_i + h ṡ_i)/s_{i+1}] x_i + [h ṫ_i s_i / s_{i+1}] u_i.
    If `precond` is given, theta is initialized at that transform's node
    values (the paper's "Euler + preconditioning" initial solver).
    """
    rng = np.random.default_rng(seed)
    r_nodes = np.linspace(0.0, 1.0, nfe + 1)
    if precond is not None:
        t0, dt0, s0v, ds0 = precond.node_values(r_nodes)
    else:
        t0, dt0 = r_nodes.copy(), np.ones(nfe + 1)
        s0v, ds0 = np.ones(nfe + 1), np.zeros(nfe + 1)

    theta = {
        "t_logits": jnp.asarray(np.log(np.maximum(np.diff(t0), 1e-6)), jnp.float32),
        "dt_raw": jnp.asarray(np.log(np.expm1(np.maximum(dt0, 1e-6))), jnp.float32),
        "s_log": jnp.asarray(np.log(np.maximum(s0v, 1e-6)), jnp.float32),
        "ds": jnp.asarray(ds0, jnp.float32),
    }
    opt = adam_init(theta)

    def theta_to_nodes(theta):
        inc = jax.nn.softmax(theta["t_logits"])
        t = jnp.concatenate([jnp.zeros(1), jnp.cumsum(inc)])
        t = t / t[-1]
        dt = jax.nn.softplus(theta["dt_raw"])  # ṫ > 0 (monotone time map)
        s = jnp.exp(theta["s_log"])  # s > 0
        return t, dt, s, theta["ds"]

    def sample_bst(theta, x0, labels):
        t, dt, s, ds = theta_to_nodes(theta)
        h = 1.0 / nfe  # uniform r-grid; time warping is carried by (t, ṫ)
        x = x0
        for i in range(nfe):
            u_i = field(t[i], x, labels)
            cx = (s[i] + h * ds[i]) / s[i + 1]
            cu = h * dt[i] * s[i] / s[i + 1]
            x = cx * x + cu * u_i
        # NOTE on frames: we step x directly in the original frame by
        # folding s into the per-step coefficients (eq. 49): x̄_0 = s_0 x_0
        # and x_{i+1} = x̄_{i+1}/s_{i+1} are implicit, so no final unscale.
        return x

    def loss_fn(theta, x0, x1, labels):
        xn = sample_bst(theta, x0, labels)
        mse = jnp.mean((xn - x1) ** 2, axis=-1)
        return jnp.mean(jnp.log(jnp.maximum(mse, 1e-20)))

    @jax.jit
    def val_psnr_fn(theta, x0, x1, labels):
        return psnr(sample_bst(theta, x0, labels), x1)

    loss_grad = jax.jit(jax.value_and_grad(loss_fn))
    update = jax.jit(lambda p, o, g, lr: adam_update(p, clip_global_norm(_sanitize_grads(g)), o, lr))

    x0_tr, x1_tr = jnp.asarray(pairs_train["x0"]), jnp.asarray(pairs_train["x1"])
    la_tr = jnp.asarray(pairs_train["labels"])
    x0_va, x1_va = jnp.asarray(pairs_val["x0"]), jnp.asarray(pairs_val["x1"])
    la_va = jnp.asarray(pairs_val["labels"])

    best = (-np.inf, theta, 0)
    init_val = float(val_psnr_fn(theta, x0_va, x1_va, la_va))
    history = []
    t_start = time.time()
    for it in range(iters):
        idx = rng.integers(0, x0_tr.shape[0], size=batch)
        cur_lr = lr * (1.0 - 0.95 * it / iters)
        loss, grads = loss_grad(theta, x0_tr[idx], x1_tr[idx], la_tr[idx])
        theta, opt = update(theta, opt, grads, cur_lr)
        if (it + 1) % val_every == 0 or it == iters - 1:
            vp = float(val_psnr_fn(theta, x0_va, x1_va, la_va))
            history.append((it + 1, float(loss), vp))
            if vp > best[0]:
                best = (vp, jax.tree_util.tree_map(lambda x: x, theta), it + 1)
    log(
        f"    [bst] nfe={nfe} init_psnr={init_val:.2f} best_psnr={best[0]:.2f} "
        f"@it{best[2]} ({time.time()-t_start:.0f}s)"
    )

    # Export as NS coefficients over the original field (ST ⊂ NS).
    t, dt, s, ds = (np.asarray(v, np.float64) for v in theta_to_nodes(best[1]))
    h = 1.0 / nfe
    tr = ns.AffineTrace()
    x = tr.x0()
    for i in range(nfe):
        u_i = tr.eval_u(x, t[i])
        x = ((s[i] + h * ds[i]) / s[i + 1]) * x + (h * dt[i] * s[i] / s[i + 1]) * u_i
    solver = tr.finish(x, 1.0)
    # guard against non-monotone learned times (rare; clamp by sorting)
    if not (np.diff(solver.times) > 0).all():
        solver.times = np.maximum.accumulate(solver.times + 1e-9 * np.arange(len(solver.times)))
    return TrainResult(solver, best[0], init_val, iters, batch * iters * nfe, history)
