"""`bns_mlp_field` emitter: the real-compute CPU serving model.

The rust runtime's CPU backend (`rust/src/kernels/`) executes a
time-modulated residual MLP whose weights ship as plain numbers inside
the artifact JSON. This module is the build-time source of those
artifacts and of the golden parity fixtures (`compile.golden`):

  * `init_mlp_field`  — deterministic weight emission. Weights come from
    the integer hash stream in `det_values`, NOT from numpy's RNG: the
    rust golden tests regenerate the same stream bit-for-bit, so parity
    fixtures need no weight payloads.
  * `forward_jnp`     — reference semantics, composed from the same
    `ref.fused_resblock` oracle the Pallas kernels are tested against.
  * `forward_mirror`  — an f32 step-rounded mirror of the rust kernels'
    exact accumulation order (k-ascending, one rounding per multiply and
    per add, no FMA). Matches the rust output to ~1 ulp of `expf`; the
    golden fixtures store its outputs as f32 bit patterns.

Per block (depth x `ref.fused_resblock` semantics):

    cond  = time_embed(t * 1000, emb) + cls_emb[label]
    mod   = cond @ mw + mb                  # [B, 2D] -> scale | shift
    act   = fused_resblock(act, w1, b1, w2, b2, scale, shift)

Guided (cfg=True) fields run a second branch with the null class and
combine `u = u_c + w * (u_c - u_n)`; accounting-wise that is
`forwards_per_eval = 2` in the manifest.
"""

from __future__ import annotations

import numpy as np

from .kernels import ref

F32 = np.float32

# Tensor emission order inside one spec — the rust golden tests consume
# the same stream in the same order (tests/kernel_golden.rs).
BLOCK_TENSORS = ("w1", "b1", "w2", "b2", "mw", "mb")


def det_values(seed: int, n: int) -> np.ndarray:
    """Deterministic f32 stream shared bit-for-bit with rust.

    h_i = ((seed + i) * 2654435761) mod 2^32
    v_i = f32((h_i mod 1000) - 500) / 256.0

    Every value is an integer in [-500, 500) divided by a power of two,
    so it is exact in f32 on both sides; keep `seed` < 2^20 so the u64
    product in rust cannot wrap.
    """
    i = np.arange(n, dtype=np.uint64)
    h = ((np.uint64(seed) + i) * np.uint64(2654435761)) & np.uint64(0xFFFFFFFF)
    return ((h % np.uint64(1000)).astype(np.int64) - 500).astype(F32) / F32(256.0)


class _Stream:
    """Sequential consumer over one det_values stream."""

    def __init__(self, seed: int):
        self.seed = int(seed)
        self.pos = 0

    def take(self, n: int, scale: np.float32) -> np.ndarray:
        v = det_values(self.seed + self.pos, n)
        self.pos += n
        return (v * F32(scale)).astype(F32)


def weight_scales(dim: int, hidden: int, emb: int) -> dict:
    """Per-tensor f32 scales (exact-f32 arithmetic, mirrored in rust)."""
    return {
        "cls_emb": F32(0.2),
        "w1": F32(0.5) / np.sqrt(F32(dim)),
        "b1": F32(0.05),
        "w2": F32(0.25) / np.sqrt(F32(hidden)),
        "b2": F32(0.01),
        "mw": F32(0.1) / np.sqrt(F32(emb)),
        "mb": F32(0.01),
    }


def init_mlp_field(dim, hidden, emb, num_classes, depth, seed, cfg=True) -> dict:
    """Emit a `bns_mlp_field` spec dict (the artifact JSON's inner object).

    Stream order: cls_emb, then per block w1, b1, w2, b2, mw, mb.
    """
    assert emb >= 2 and emb % 2 == 0, "emb must be even and >= 2"
    assert depth >= 1
    s = _Stream(seed)
    sc = weight_scales(dim, hidden, emb)
    sizes = {
        "w1": dim * hidden, "b1": hidden, "w2": hidden * dim,
        "b2": dim, "mw": emb * 2 * dim, "mb": 2 * dim,
    }
    cls_emb = s.take((num_classes + 1) * emb, sc["cls_emb"])
    blocks = []
    for _ in range(depth):
        blocks.append({k: s.take(sizes[k], sc[k]).tolist() for k in BLOCK_TENSORS})
    return {
        "dim": dim,
        "hidden": hidden,
        "emb": emb,
        "num_classes": num_classes,
        "null_class": num_classes,
        "cfg": bool(cfg),
        "cls_emb": cls_emb.tolist(),
        "blocks": blocks,
    }


def time_embed_f64(t, emb: int) -> np.ndarray:
    """f64 sinusoidal embedding truncated to f32 — the rust mirror.

    Identical to `ref.time_embed(t * 1000, emb)` up to f64 libm ulps,
    which vanish in the f32 cast.
    """
    half = emb // 2
    k = np.arange(half, dtype=np.float64)
    freqs = np.exp(-np.log(1e4) * k / half)
    args = np.float64(t) * 1000.0 * freqs
    return np.concatenate([np.cos(args), np.sin(args)]).astype(F32)


# ---------------------------------------------------------------------------
# f32 step-rounded mirror of the rust kernels (golden-fixture oracle)
# ---------------------------------------------------------------------------


def gemm_f32(a, b, bias, res=None) -> np.ndarray:
    """k-ascending f32 accumulation: the rust GEMM's exact op order.

    acc starts at bias (or res + bias); every multiply and every add
    rounds to f32 — no FMA, no reassociation.
    """
    a = np.ascontiguousarray(a, F32)
    b = np.ascontiguousarray(b, F32)
    m, k = a.shape
    n = b.shape[1]
    acc = np.broadcast_to(np.asarray(bias, F32), (m, n)).copy()
    if res is not None:
        acc = (np.asarray(res, F32) + acc).astype(F32)
    for kk in range(k):
        acc = (acc + a[:, kk : kk + 1] * b[kk : kk + 1, :]).astype(F32)
    return acc


def silu_f32(v: np.ndarray) -> np.ndarray:
    """v * (1 / (1 + exp(-v))), all f32 — the rust op order (reciprocal
    then multiply, not a division by v-scaled denominator)."""
    v = np.asarray(v, F32)
    s = (F32(1.0) / (F32(1.0) + np.exp(-v))).astype(F32)
    return (v * s).astype(F32)


def resblock_mirror(x, modv, w1, b1, w2, b2) -> np.ndarray:
    """fused_resblock_into mirror: modulate -> GEMM -> SiLU -> GEMM+res."""
    x = np.asarray(x, F32)
    d = x.shape[1]
    scale = np.asarray(modv, F32)[:, :d]
    shift = np.asarray(modv, F32)[:, d:]
    mod = ((x * (F32(1.0) + scale)).astype(F32) + shift).astype(F32)
    h = silu_f32(gemm_f32(mod, np.asarray(w1, F32).reshape(d, -1), b1))
    return gemm_f32(h, np.asarray(w2, F32).reshape(-1, d), b2, res=x)


def ns_update_mirror(a, x0, b, hist) -> np.ndarray:
    """ns_combine_into mirror: seed a*x0, then j-ascending adds with the
    zero-coefficient skip (f32 steps)."""
    x = (F32(a) * np.asarray(x0, F32)).astype(F32)
    for j, bj in enumerate(b):
        bj32 = F32(bj)
        if bj32 == F32(0.0):
            continue
        x = (x + bj32 * np.asarray(hist[j], F32)).astype(F32)
    return x


def forward_mirror(spec: dict, x, t, w, labels) -> np.ndarray:
    """Full bns_mlp_field eval in the rust kernels' exact f32 op order."""
    d, e = spec["dim"], spec["emb"]
    cls = np.asarray(spec["cls_emb"], F32).reshape(-1, e)
    temb = time_embed_f64(t, e)
    labels = np.asarray(labels, np.int64)

    def branch(null: bool) -> np.ndarray:
        li = np.full_like(labels, spec["null_class"]) if null else labels
        cond = (temb[None, :] + cls[li]).astype(F32)
        act = np.asarray(x, F32)
        for blk in spec["blocks"]:
            mw = np.asarray(blk["mw"], F32).reshape(e, 2 * d)
            modv = gemm_f32(cond, mw, blk["mb"])
            act = resblock_mirror(act, modv, blk["w1"], blk["b1"], blk["w2"], blk["b2"])
        return act

    uc = branch(False)
    if not spec["cfg"]:
        return uc
    un = branch(True)
    return (uc + F32(w) * (uc - un).astype(F32)).astype(F32)


# ---------------------------------------------------------------------------
# jnp reference (ref.py semantics — the emitter's ground truth)
# ---------------------------------------------------------------------------


def forward_jnp(spec: dict, x, t, w, labels) -> np.ndarray:
    """Reference forward composed from `ref.fused_resblock`. Matmul order
    differs from the mirror (XLA-chosen), so agreement is approximate —
    `compile.golden` asserts it at generation time."""
    import jax.numpy as jnp

    d, e = spec["dim"], spec["emb"]
    cls = jnp.asarray(np.asarray(spec["cls_emb"], F32).reshape(-1, e))
    temb = jnp.asarray(time_embed_f64(t, e))
    labels = np.asarray(labels, np.int64)

    def branch(null: bool):
        li = np.full_like(labels, spec["null_class"]) if null else labels
        cond = temb[None, :] + cls[li]
        act = jnp.asarray(np.asarray(x, F32))
        for blk in spec["blocks"]:
            mw = jnp.asarray(np.asarray(blk["mw"], F32).reshape(e, 2 * d))
            mod = cond @ mw + jnp.asarray(np.asarray(blk["mb"], F32))
            act = ref.fused_resblock(
                act,
                jnp.asarray(np.asarray(blk["w1"], F32).reshape(d, -1)),
                jnp.asarray(np.asarray(blk["b1"], F32)),
                jnp.asarray(np.asarray(blk["w2"], F32).reshape(-1, d)),
                jnp.asarray(np.asarray(blk["b2"], F32)),
                mod[:, :d],
                mod[:, d:],
            )
        return act

    uc = branch(False)
    if not spec["cfg"]:
        return np.asarray(uc)
    un = branch(True)
    return np.asarray(uc + w * (uc - un))
