"""Adaptive RK45 (Dormand-Prince) solver used to generate ground truth.

The paper's GT samples are "high accuracy approximate solutions of eq. 1"
computed with adaptive RK45 (Shampine, 1986). This is the build-path
implementation used for (x0, x(1)) training-pair generation and for
validation references; the request-path mirror lives in
rust/src/solver/rk45.rs with identical Butcher tableau and step control,
and the two are cross-checked by integration tests.
"""

from __future__ import annotations

import numpy as np

# Dormand-Prince 5(4) tableau.
DOPRI_C = np.array([0.0, 1 / 5, 3 / 10, 4 / 5, 8 / 9, 1.0, 1.0])
DOPRI_A = [
    [],
    [1 / 5],
    [3 / 40, 9 / 40],
    [44 / 45, -56 / 15, 32 / 9],
    [19372 / 6561, -25360 / 2187, 64448 / 6561, -212 / 729],
    [9017 / 3168, -355 / 33, 46732 / 5247, 49 / 176, -5103 / 18656],
    [35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84],
]
DOPRI_B5 = np.array([35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84, 0.0])
DOPRI_B4 = np.array(
    [5179 / 57600, 0.0, 7571 / 16695, 393 / 640, -92097 / 339200, 187 / 2100, 1 / 40]
)


def rk45(u, x0, t0=0.0, t1=1.0, rtol=1e-5, atol=1e-5, h0=0.05, max_nfe=10_000):
    """Integrate dx/dt = u(t, x) from t0 to t1 adaptively.

    Args:
      u:  callable (t: float, x: array) -> array; the velocity field.
      x0: initial state, any shape (batch leading dims fine).
    Returns:
      (x1, nfe): final state and the number of velocity evaluations.
    """
    x = np.asarray(x0, np.float64)
    t, h, nfe = float(t0), float(h0), 0
    k1 = np.asarray(u(t, x), np.float64)
    nfe += 1
    while t < t1 - 1e-12:
        h = min(h, t1 - t)
        ks = [k1]
        for i in range(1, 7):
            xi = x + h * sum(a * k for a, k in zip(DOPRI_A[i], ks))
            ks.append(np.asarray(u(t + DOPRI_C[i] * h, xi), np.float64))
            nfe += 1
        x5 = x + h * sum(b * k for b, k in zip(DOPRI_B5, ks))
        x4 = x + h * sum(b * k for b, k in zip(DOPRI_B4, ks))
        scale = atol + rtol * np.maximum(np.abs(x), np.abs(x5))
        err = float(np.sqrt(np.mean(((x5 - x4) / scale) ** 2)))
        if err <= 1.0:  # accept
            t += h
            x = x5
            k1 = ks[-1]  # FSAL: k7 of the accepted step is k1 of the next
        factor = 0.9 * (max(err, 1e-10)) ** (-0.2)
        h *= min(5.0, max(0.2, factor))
        if nfe > max_nfe:
            raise RuntimeError(f"rk45 exceeded max_nfe={max_nfe} (err={err:.3g})")
    return x.astype(np.float32), nfe
