"""Golden parity fixtures for the rust CPU kernel layer.

Writes `rust/tests/golden/{resblock,ns_update,mlp_field}.json`, replayed
by `rust/tests/kernel_golden.rs` within 1e-6.

Inputs and weights are NOT stored: both sides regenerate them from the
shared integer hash stream (`mlp_field.det_values`) given the per-case
seed, so a fixture is a seed, a shape, a 4-value input checksum, and the
expected output as concatenated big-endian f32 bit patterns (8 hex chars
per value). Expected outputs come from `forward_mirror` & friends — the
f32 step-rounded mirror of the rust accumulation order — and are
cross-checked against the `ref.py` jnp oracles at generation time, so a
fixture can't encode a semantics bug without jax disagreeing.

Run:  cd python && python -m compile.golden --out ../rust/tests/golden
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from . import mlp_field as mf
from .kernels import ref

F32 = np.float32

# generation-time guard: mirror (rust op order) vs jnp oracle (XLA order)
GEN_ATOL = 2e-5
GEN_RTOL = 2e-4


def hex_f32(v: np.ndarray) -> str:
    """Concatenated big-endian u32 hex of each f32's bit pattern."""
    bits = np.ascontiguousarray(v, "<f4").reshape(-1).view("<u4")
    return "".join(format(int(u), "08x") for u in bits)


def gen_resblock(log=print) -> dict:
    cases = []
    ci = 0
    for d in (8, 64, 256):
        for h in (8, 64, 256):
            for batch in (1, 7, 64):
                seed = 10_000 + 97 * ci
                ci += 1
                s = mf._Stream(seed)
                x = s.take(batch * d, F32(1.0)).reshape(batch, d)
                scale = s.take(batch * d, F32(0.1)).reshape(batch, d)
                shift = s.take(batch * d, F32(0.1)).reshape(batch, d)
                sc = mf.weight_scales(d, h, 2)
                w1 = s.take(d * h, sc["w1"]).reshape(d, h)
                b1 = s.take(h, sc["b1"])
                w2 = s.take(h * d, sc["w2"]).reshape(h, d)
                b2 = s.take(d, sc["b2"])
                modv = np.concatenate([scale, shift], axis=1)
                out = mf.resblock_mirror(x, modv, w1, b1, w2, b2)
                want = np.asarray(ref.fused_resblock(x, w1, b1, w2, b2, scale, shift))
                np.testing.assert_allclose(out, want, rtol=GEN_RTOL, atol=GEN_ATOL)
                cases.append({
                    "d": d, "h": h, "batch": batch, "seed": seed,
                    "x_check": hex_f32(x.reshape(-1)[:4]),
                    "out": hex_f32(out),
                })
    log(f"[golden] resblock: {len(cases)} cases")
    return {"tolerance": 1e-6, "cases": cases}


def gen_ns_update(log=print) -> dict:
    cases = []
    for ci, (k, length) in enumerate([(1, 8), (4, 64), (8, 1024), (16, 2048)]):
        seed = 40_000 + 101 * ci
        s = mf._Stream(seed)
        x0 = s.take(length, F32(1.0))
        hist = s.take(k * length, F32(0.5)).reshape(k, length)
        b = s.take(k, F32(0.1)).astype(np.float64)
        if k > 1:
            b[k // 2] = 0.0  # exercise the zero-coefficient skip
        a = F32(1.0) + s.take(1, F32(0.1))[0]
        out = mf.ns_update_mirror(a, x0, b, hist)
        want = np.asarray(ref.ns_update(x0[None, :], hist[:, None, :], a, b.astype(F32)))[0]
        np.testing.assert_allclose(out, want, rtol=GEN_RTOL, atol=GEN_ATOL)
        cases.append({
            "k": k, "len": length, "seed": seed,
            "x_check": hex_f32(x0[:4]),
            "out": hex_f32(out),
        })
    log(f"[golden] ns_update: {len(cases)} cases")
    return {"tolerance": 1e-6, "cases": cases}


MLP_CASES = [
    dict(dim=8, hidden=8, emb=4, num_classes=3, depth=2, cfg=True, batch=1,
         t=0.25, w=1.5),
    dict(dim=64, hidden=64, emb=16, num_classes=8, depth=2, cfg=True, batch=7,
         t=0.62, w=0.75),
    dict(dim=256, hidden=256, emb=64, num_classes=8, depth=1, cfg=False, batch=64,
         t=0.875, w=0.0),
]


def gen_mlp_field(log=print) -> dict:
    cases = []
    for ci, c in enumerate(MLP_CASES):
        x_seed = 70_000 + 211 * ci
        spec_seed = x_seed + 50_000
        spec = mf.init_mlp_field(c["dim"], c["hidden"], c["emb"], c["num_classes"],
                                 c["depth"], spec_seed, cfg=c["cfg"])
        s = mf._Stream(x_seed)
        x = s.take(c["batch"] * c["dim"], F32(1.0)).reshape(c["batch"], c["dim"])
        labels = np.arange(c["batch"], dtype=np.int64) % (c["num_classes"] + 1)
        out = mf.forward_mirror(spec, x, c["t"], c["w"], labels)
        want = mf.forward_jnp(spec, x, c["t"], c["w"], labels)
        np.testing.assert_allclose(out, want, rtol=GEN_RTOL, atol=GEN_ATOL)
        cases.append({
            **c,
            "x_seed": x_seed, "spec_seed": spec_seed,
            "x_check": hex_f32(x.reshape(-1)[:4]),
            "out": hex_f32(out),
        })
    log(f"[golden] mlp_field: {len(cases)} cases")
    return {"tolerance": 1e-6, "cases": cases}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../rust/tests/golden")
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)
    for name, gen in [("resblock", gen_resblock), ("ns_update", gen_ns_update),
                      ("mlp_field", gen_mlp_field)]:
        path = os.path.join(out, f"{name}.json")
        json.dump(gen(), open(path, "w"))
        print(f"[golden] wrote {path} ({os.path.getsize(path)/1e3:.0f} KB)")


if __name__ == "__main__":
    main()
