"""Gaussian-path schedulers and Scale-Time (ST) transformations.

Implements the scheduler zoo of the BNS paper (Shaul et al., ICML 2024):

* FM-OT      (conditional optimal transport):  alpha_t = t,        sigma_t = 1 - t
* FM/v-CS    (cosine):                         alpha_t = sin(pi/2 t), sigma_t = cos(pi/2 t)
* VP         (variance preserving, eq. 60):    alpha_t = xi_{1-t},  sigma_t = sqrt(1 - xi^2)
* VE / EDM   (variance exploding, eq. 16):     alpha_t = 1,         sigma_t = sigma_max (1 - t)

Conventions follow the paper: t = 0 is source/noise, t = 1 is data
(eq. 4: alpha_0 ~ 0, sigma_1 = 0, alpha_1 = 1, sigma_0 > 0), and the
signal-to-noise ratio snr(t) = alpha_t / sigma_t is strictly increasing.

Also provides:
* the velocity-field coefficients (beta_t, gamma_t) of Table 1 for the
  three model parametrizations (velocity / eps-prediction / x-prediction),
* the ST <-> scheduler-change conversion of eq. 8,
* the sigma0 preconditioning of eq. 14.

Everything is written against `jax.numpy` so it is differentiable and can
be lowered into the AOT artifacts; the rust mirror lives in
rust/src/solver/scheduler.rs and is cross-checked against table values
exported by tests.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

# VP scheduler constants from eq. 60 of the paper (Song et al. 2020).
VP_BETA_MAX = 20.0
VP_BETA_MIN = 0.1
EDM_SIGMA_MAX = 80.0


@dataclasses.dataclass(frozen=True)
class Scheduler:
    """A Gaussian-path scheduler (alpha_t, sigma_t) with derivatives.

    alpha/sigma map a scalar (or array) time in [0, 1] to the path
    coefficients of eq. 3: p_t(x | x1) = N(x | alpha_t x1, sigma_t^2 I).
    """

    name: str
    alpha: Callable[[jnp.ndarray], jnp.ndarray]
    sigma: Callable[[jnp.ndarray], jnp.ndarray]
    # Optional closed-form snr^{-1}; keeps ST transforms differentiable
    # (the bisection fallback has zero gradient under jax autodiff).
    snr_inv_analytic: Callable[[jnp.ndarray], jnp.ndarray] | None = None

    def dalpha(self, t):
        return jax.grad(lambda s: jnp.sum(self.alpha(s)))(jnp.asarray(t, jnp.float32))

    def dsigma(self, t):
        return jax.grad(lambda s: jnp.sum(self.sigma(s)))(jnp.asarray(t, jnp.float32))

    def snr(self, t):
        """Signal-to-noise ratio alpha_t / sigma_t (strictly increasing)."""
        return self.alpha(t) / self.sigma(t)

    def log_snr(self, t):
        """lambda_t = log snr(t) used by exponential integrators (eq. 22)."""
        return jnp.log(self.alpha(t)) - jnp.log(self.sigma(t))

    def snr_inv(self, y, lo=0.0, hi=1.0, iters=64):
        """Invert snr: closed form when available, else bisection.

        Bisection is robust for every scheduler here because snr is
        strictly monotone (the paper's standing assumption, Section 2);
        64 steps give full float32 resolution of the interval. The
        analytic path additionally supports autodiff, which the BNS
        trainer needs when optimizing over a preconditioned field.
        """
        y = jnp.asarray(y, jnp.float32)
        if self.snr_inv_analytic is not None:
            return self.snr_inv_analytic(y)

        def body(_, ab):
            a, b = ab
            m = 0.5 * (a + b)
            below = self.snr(m) < y
            return (jnp.where(below, m, a), jnp.where(below, b, m))

        a, b = jax.lax.fori_loop(
            0, iters, body, (jnp.full_like(y, lo), jnp.full_like(y, hi))
        )
        return 0.5 * (a + b)

    # -- velocity-field coefficients of Table 1 -------------------------

    def uv_coeffs(self, t, parametrization: str):
        """Return (beta_t, gamma_t) with u_t(x) = beta_t x + gamma_t f_t(x).

        Table 1 of the paper; `parametrization` is one of
        'velocity' | 'eps' | 'x'.
        """
        t = jnp.asarray(t, jnp.float32)
        if parametrization == "velocity":
            return jnp.zeros_like(t), jnp.ones_like(t)
        a, s = self.alpha(t), self.sigma(t)
        da, ds = self.dalpha(t), self.dsigma(t)
        if parametrization == "eps":
            return da / a, (ds * a - s * da) / a
        if parametrization == "x":
            return ds / s, (s * da - ds * a) / s
        raise ValueError(f"unknown parametrization {parametrization!r}")


def _vp_xi(s):
    b, B = VP_BETA_MIN, VP_BETA_MAX
    return jnp.exp(-0.25 * s**2 * (B - b) - 0.5 * s * b)


def _vp_snr_inv(y):
    """Closed-form snr^{-1} for VP: invert xi_s (a quadratic in s)."""
    b, B = VP_BETA_MIN, VP_BETA_MAX
    # snr = xi / sqrt(1 - xi^2)  =>  xi = 1 / sqrt(1 + y^-2); this form is
    # nan-free at the data endpoint y = inf (xi -> 1).
    xi = 1.0 / jnp.sqrt(1.0 + jnp.maximum(y, 1e-30) ** -2)
    log_xi = jnp.log(jnp.clip(xi, 1e-30, 1.0))
    # 0.25 (B-b) s^2 + 0.5 b s + log xi = 0, take the positive root.
    disc = jnp.sqrt(jnp.maximum(0.25 * b**2 - (B - b) * log_xi, 0.0))
    s = (-0.5 * b + disc) / (0.5 * (B - b))
    return 1.0 - s


FM_OT = Scheduler(
    "fm_ot",
    lambda t: jnp.asarray(t, jnp.float32),
    lambda t: 1.0 - jnp.asarray(t, jnp.float32),
    # snr(t) = t / (1 - t)  =>  t = y / (1 + y); written 1 - 1/(1+y) so
    # the data endpoint y = snr(1) = inf maps to t = 1 without nan.
    snr_inv_analytic=lambda y: 1.0 - 1.0 / (1.0 + y),
)
COSINE = Scheduler(
    "cosine",
    lambda t: jnp.sin(0.5 * jnp.pi * jnp.asarray(t, jnp.float32)),
    lambda t: jnp.cos(0.5 * jnp.pi * jnp.asarray(t, jnp.float32)),
    # snr(t) = tan(pi t / 2)  =>  t = (2/pi) atan(y)
    snr_inv_analytic=lambda y: (2.0 / jnp.pi) * jnp.arctan(y),
)
VP = Scheduler(
    "vp",
    lambda t: _vp_xi(1.0 - jnp.asarray(t, jnp.float32)),
    lambda t: jnp.sqrt(jnp.maximum(1.0 - _vp_xi(1.0 - jnp.asarray(t, jnp.float32)) ** 2, 1e-12)),
    snr_inv_analytic=_vp_snr_inv,
)
VE = Scheduler(
    "ve",
    lambda t: jnp.ones_like(jnp.asarray(t, jnp.float32)),
    lambda t: EDM_SIGMA_MAX * (1.0 - jnp.asarray(t, jnp.float32)),
    # snr(t) = 1 / (sigma_max (1 - t))  =>  t = 1 - 1/(sigma_max y)
    snr_inv_analytic=lambda y: 1.0 - 1.0 / (EDM_SIGMA_MAX * jnp.maximum(y, 1e-30)),
)

SCHEDULERS = {s.name: s for s in (FM_OT, COSINE, VP, VE)}


# ---------------------------------------------------------------------------
# Scale-Time transformations (Section 2, eqs. 6-8) and preconditioning
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class STTransform:
    """A scale-time transformation x̄(r) = s_r · x(t_r) (eq. 6).

    `t` maps transformed time r to original time, `s` is the scale; both
    are callables over [0, 1]. Derivative helpers use jax autodiff so the
    transformed velocity field (eq. 7) is exact.
    """

    t: Callable[[jnp.ndarray], jnp.ndarray]
    s: Callable[[jnp.ndarray], jnp.ndarray]

    def dt(self, r):
        # derivatives evaluated a hair inside [0, 1]: the s/t maps divide
        # 0/0 *at* the endpoints and autodiff would propagate nan even
        # though the one-sided limits are finite.
        r = jnp.clip(jnp.asarray(r, jnp.float32), 1e-5, 1.0 - 1e-5)
        return jax.grad(lambda q: jnp.sum(self.t(q)))(r)

    def ds(self, r):
        r = jnp.clip(jnp.asarray(r, jnp.float32), 1e-5, 1.0 - 1e-5)
        return jax.grad(lambda q: jnp.sum(self.s(q)))(r)

    def transform_u(self, u):
        """eq. 7: ū_r(x) = (ṡ_r/s_r) x + ṫ_r s_r u_{t_r}(x / s_r)."""

        def u_bar(r, x):
            s, ds, t, dt = self.s(r), self.ds(r), self.t(r), self.dt(r)
            return (ds / s) * x + dt * s * u(t, x / s)

        return u_bar


def st_from_scheduler_change(old: Scheduler, new_alpha, new_sigma) -> STTransform:
    """eq. 8: scheduler change -> ST transform for strictly-monotone snr.

    t_r = snr^{-1}( snr̄(r) ),   s_r = sigma̅_r / sigma_{t_r}.
    """

    def t_of_r(r):
        return old.snr_inv(new_alpha(r) / new_sigma(r))

    def s_of_r(r):
        # Both ratios of eq. 8 are valid; pick the one whose denominator
        # is regular (sigma-ratio is 0/0 at the data endpoint, alpha-ratio
        # is 0/0 at the noise endpoint).
        t = t_of_r(r)
        a_t, s_t = old.alpha(t), old.sigma(t)
        return jnp.where(
            a_t > s_t,
            new_alpha(r) / jnp.maximum(a_t, 1e-20),
            new_sigma(r) / jnp.maximum(s_t, 1e-20),
        )

    return STTransform(t=t_of_r, s=s_of_r)


def precondition(old: Scheduler, sigma0: float) -> STTransform:
    """The sigma0 preconditioning of eq. 14: sigma̅_t = sigma0·sigma_t, alpha̅ = alpha.

    sigma0 = 1 is the identity transformation. Larger sigma0 corresponds to
    a wider source distribution p0 ∝ N(0, sigma0^2 I), which the paper
    found to improve BNS optimization conditioning under high CFG scale.
    """
    return st_from_scheduler_change(
        old, lambda r: old.alpha(r), lambda r: sigma0 * old.sigma(r)
    )


def edm_transform(old: Scheduler) -> STTransform:
    """EDM's variance-exploding scheduler change, eq. 16."""
    return st_from_scheduler_change(
        old, lambda r: jnp.ones_like(r), lambda r: EDM_SIGMA_MAX * (1.0 - jnp.asarray(r)) + 1e-4
    )
