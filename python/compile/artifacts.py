"""Build orchestrator: everything `make artifacts` produces, idempotently.

Stages (each skipped when its outputs already exist):

  1. weights   — train the 5 pretrained models (train_model.py)
  2. pd        — Progressive Distillation students (pd.py, Table 3)
  3. pairs     — RK45 ground-truth (x0, x(1)) sets per (model, guidance)
  4. solvers   — BNS / BST distillation (bns.py) -> solver JSONs
  5. aot       — HLO text artifacts for every model variant (aot.py)
  6. mlp       — bns_mlp_field weight JSONs for the rust CPU backend
                 (mlp_field.py; deterministic, no training)
  7. manifest  — manifest.json: model/solver index, FD-synth feature
                 extractor + reference stats, scheduler cross-check
                 tables for the rust mirror, dataset metadata

Run:  cd python && python -m compile.artifacts --out ../artifacts
Profiles: --profile full|fast (fast = CI-scale budgets).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax.numpy as jnp
import numpy as np

from . import aot, bns, data, mlp_field, model, ns, pd, schedulers, train_model

# ---------------------------------------------------------------------------
# job tables
# ---------------------------------------------------------------------------

# (model, guidance, sigma0, init, nfe list) — see DESIGN.md §7 for the
# experiment each row feeds.
BNS_JOBS = [
    ("img_fm_ot", 0.0, 1.0, "midpoint", (4, 6, 8, 10, 12, 14, 16, 18, 20)),
    ("img_fmv_cs", 0.0, 1.0, "midpoint", (4, 8, 12, 16, 20)),
    ("img_eps_vp", 0.0, 1.0, "midpoint", (4, 8, 12, 16, 20)),
    ("img_fm_ot_big", 0.5, 1.0, "midpoint", (4, 8, 16)),
    # T2I-sim: the paper uses sigma0 = 5 / 10 with Euler init for its 2.2B
    # T2I model. On our tiny stand-in that preconditioning *hurts* (the
    # transformed field is harder to integrate at this scale), so the
    # serving artifacts are trained at sigma0 = 1 / midpoint; the
    # paper-style preconditioned runs remain as the "init" ablation rows
    # (INIT_JOBS) and the divergence is documented in EXPERIMENTS.md.
    ("img_fm_ot", 2.0, 1.0, "midpoint", (12, 16, 20)),  # T2I-sim w=2
    ("img_fm_ot", 6.5, 1.0, "midpoint", (12, 16, 20)),  # T2I-sim w=6.5
    ("audio_fm_ot", 0.0, 1.0, "midpoint", (8, 12, 16, 20)),
]
BST_JOBS = [
    ("img_fm_ot", 0.0, (4, 8, 12, 16, 20)),
    ("audio_fm_ot", 0.0, (8, 12, 16, 20)),
]
# Table 5's "initial solver": Euler + sigma0 preconditioning, untrained.
INIT_JOBS = [
    ("img_fm_ot", 2.0, 5.0, (12, 16, 20)),
    ("img_fm_ot", 6.5, 10.0, (12, 16, 20)),
]

# Budgets sized for the single-core CI substrate; the paper's settings
# (15k iters, 1024-sample validation) are a --profile flag away. Val-set
# size 256 (vs paper's 1024) halves validation cost with <0.1 dB noise on
# mean PSNR for these dims.
PROFILES = {
    "paper": dict(model_steps=8000, bns_iters=15000, bst_iters=15000, pd_updates=5000,
                  n_train=520, n_val=1024),
    "full": dict(model_steps=2000, bns_iters=400, bst_iters=300, pd_updates=600,
                 n_train=520, n_val=256),
    "fast": dict(model_steps=300, bns_iters=120, bst_iters=100, pd_updates=100,
                 n_train=96, n_val=128),
}

FEAT_HIDDEN, FEAT_DIM = 64, 16

# bns_mlp_field serving models for the rust CPU backend:
# (name, hidden, emb, depth, cfg, seed, buckets). dim/classes follow the
# image dataset; weights are a deterministic hash stream (mlp_field.py),
# so this stage is pure emission — no training, bit-stable across runs.
MLP_FIELD_JOBS = [
    ("img_mlp_cpu", 256, 64, 2, True, 9001, (8, 64)),
]


def _wtag(w: float) -> str:
    return ("w%g" % w).replace(".", "p")


# ---------------------------------------------------------------------------
# stages
# ---------------------------------------------------------------------------


def stage_weights(out, prof, log=print):
    wdir = os.path.join(out, "weights")
    os.makedirs(wdir, exist_ok=True)
    meta_path = os.path.join(wdir, "train_meta.json")
    meta = json.load(open(meta_path)) if os.path.exists(meta_path) else {}
    for name, cfg in train_model.MODEL_CONFIGS.items():
        path = os.path.join(wdir, f"{name}.npz")
        if os.path.exists(path):
            continue
        log(f"[weights] training {name}")
        params, loss = train_model.train(
            cfg, steps=prof["model_steps"], lr=train_model.MODEL_LR.get(name, 1e-3)
        )
        train_model.save_params(params, path)
        meta[name] = {"loss": loss, "param_count": model.param_count(params),
                      "steps": prof["model_steps"]}
        json.dump(meta, open(meta_path, "w"), indent=1)
    return meta_path


def stage_pd(out, prof, log=print):
    wdir = os.path.join(out, "weights")
    meta_path = os.path.join(wdir, "pd_meta.json")
    if os.path.exists(meta_path):
        return meta_path
    cfg = train_model.MODEL_CONFIGS["img_fm_ot"]
    teacher = train_model.load_params(os.path.join(wdir, "img_fm_ot.npz"))
    log("[pd] distilling img_fm_ot 32->16->8->4")
    res = pd.distill(cfg, teacher, updates_per_phase=prof["pd_updates"], log=log)
    meta = {"teacher": "img_fm_ot", "updates_per_phase": prof["pd_updates"]}
    for nfe, params in res.students.items():
        train_model.save_params(params, os.path.join(wdir, f"pd_nfe{nfe}.npz"))
        meta[str(nfe)] = {
            "forwards": res.forwards[nfe],
            "updates": res.updates[nfe],
            "param_count": model.param_count(params),
        }
    json.dump(meta, open(meta_path, "w"), indent=1)
    return meta_path


def _guided_field(cfg, params, w):
    def f(t, x, labels):
        return model.guided_velocity(cfg, params, x, t, labels, w, use_pallas=False)

    return f


def _field_np(cfg, params, w):
    def f(t, x, labels):
        return np.asarray(
            model.guided_velocity(
                cfg, params, jnp.asarray(x), jnp.float32(t), jnp.asarray(labels), w,
                use_pallas=False,
            )
        )

    return f


def stage_pairs(out, prof, log=print):
    pdir = os.path.join(out, "pairs")
    os.makedirs(pdir, exist_ok=True)
    wdir = os.path.join(out, "weights")
    combos = sorted({(m, w) for (m, w, *_rest) in BNS_JOBS}
                    | {(m, w) for (m, w, _n) in BST_JOBS}
                    | {(m, w) for (m, w, _s, _n) in INIT_JOBS})
    for mname, w in combos:
        path = os.path.join(pdir, f"{mname}_{_wtag(w)}.npz")
        if os.path.exists(path):
            continue
        cfg = train_model.MODEL_CONFIGS[mname]
        params = train_model.load_params(os.path.join(wdir, f"{mname}.npz"))
        fnp = _field_np(cfg, params, w)
        t0 = time.time()
        try:
            tr = bns.make_pairs(fnp, cfg.data_dim, prof["n_train"], seed=100,
                                num_classes=cfg.num_classes)
            va = bns.make_pairs(fnp, cfg.data_dim, prof["n_val"], seed=200,
                                num_classes=cfg.num_classes)
        except RuntimeError as e:
            # One bad model/guidance combo must not sink the whole build.
            log(f"[pairs] FAILED {mname} w={w}: {e}")
            continue
        np.savez(
            path,
            x0_tr=tr["x0"], x1_tr=tr["x1"], la_tr=tr["labels"],
            x0_va=va["x0"], x1_va=va["x1"], la_va=va["labels"],
            gt_nfe=np.int32(tr["gt_nfe"]),
        )
        log(f"[pairs] {mname} w={w}: gt_nfe={tr['gt_nfe']} ({time.time()-t0:.0f}s)")


def _load_pairs(out, mname, w):
    z = np.load(os.path.join(out, "pairs", f"{mname}_{_wtag(w)}.npz"))
    tr = {"x0": z["x0_tr"], "x1": z["x1_tr"], "labels": z["la_tr"], "gt_nfe": int(z["gt_nfe"])}
    va = {"x0": z["x0_va"], "x1": z["x1_va"], "labels": z["la_va"], "gt_nfe": int(z["gt_nfe"])}
    return tr, va


def _save_solver(out, name, solver, meta):
    sdir = os.path.join(out, "solvers")
    os.makedirs(sdir, exist_ok=True)
    d = solver.to_json_dict(**meta)
    path = os.path.join(sdir, f"{name}.json")
    json.dump(d, open(path, "w"))
    return path


def stage_solvers(out, prof, log=print):
    wdir = os.path.join(out, "weights")
    sdir = os.path.join(out, "solvers")
    os.makedirs(sdir, exist_ok=True)

    for mname, w, sigma0, init, nfes in BNS_JOBS:
        cfg = train_model.MODEL_CONFIGS[mname]
        params = train_model.load_params(os.path.join(wdir, f"{mname}.npz"))
        field = _guided_field(cfg, params, w)
        try:
            tr, va = _load_pairs(out, mname, w)
        except FileNotFoundError:
            log(f"[bns] SKIP {mname} w={w}: no pairs")
            continue
        pc = bns.Precondition(cfg.scheduler, sigma0) if sigma0 != 1.0 else None
        for nfe in nfes:
            name = f"{mname}_{_wtag(w)}_nfe{nfe}_bns"
            if os.path.exists(os.path.join(sdir, f"{name}.json")):
                continue
            log(f"[bns] {name} (init={init}, sigma0={sigma0})")
            res = bns.train_bns(
                field, tr, va, nfe, init=init, precond=pc,
                iters=prof["bns_iters"], log=log,
            )
            _save_solver(out, name, res.solver, dict(
                kind="bns", model=mname, nfe=nfe, guidance=w, sigma0=sigma0,
                init=init, val_psnr=res.val_psnr, init_val_psnr=res.init_val_psnr,
                iters=res.iters_run, forwards=res.forwards, gt_nfe=tr["gt_nfe"],
                pair_count=len(tr["x0"]),
            ))

    for mname, w, nfes in BST_JOBS:
        cfg = train_model.MODEL_CONFIGS[mname]
        params = train_model.load_params(os.path.join(wdir, f"{mname}.npz"))
        field = _guided_field(cfg, params, w)
        try:
            tr, va = _load_pairs(out, mname, w)
        except FileNotFoundError:
            log(f"[bst] SKIP {mname} w={w}: no pairs")
            continue
        for nfe in nfes:
            name = f"{mname}_{_wtag(w)}_nfe{nfe}_bst"
            if os.path.exists(os.path.join(sdir, f"{name}.json")):
                continue
            log(f"[bst] {name}")
            res = bns.train_bst(field, tr, va, nfe, iters=prof["bst_iters"], log=log)
            _save_solver(out, name, res.solver, dict(
                kind="bst", model=mname, nfe=nfe, guidance=w, sigma0=1.0,
                init="euler", val_psnr=res.val_psnr, init_val_psnr=res.init_val_psnr,
                iters=res.iters_run, forwards=res.forwards, gt_nfe=tr["gt_nfe"],
                pair_count=len(tr["x0"]),
            ))

    # Table 5 baselines: untrained Euler + preconditioning, folded to NS.
    for mname, w, sigma0, nfes in INIT_JOBS:
        cfg = train_model.MODEL_CONFIGS[mname]
        pc = bns.Precondition(cfg.scheduler, sigma0)
        try:
            tr, va = _load_pairs(out, mname, w)
        except FileNotFoundError:
            log(f"[init] SKIP {mname} w={w}: no pairs")
            continue
        params = train_model.load_params(os.path.join(wdir, f"{mname}.npz"))
        field = _guided_field(cfg, params, w)
        for nfe in nfes:
            name = f"{mname}_{_wtag(w)}_nfe{nfe}_init"
            if os.path.exists(os.path.join(sdir, f"{name}.json")):
                continue
            sol_r = ns.euler_ns(ns.uniform_times(nfe))
            folded = bns.fold_transform(sol_r, *pc.node_values(sol_r.times))
            # evaluate once on the validation pairs for the manifest
            u_np = _field_np(cfg, params, w)
            xn = folded.sample(lambda t, x: u_np(t, x, va["labels"]), va["x0"])
            vp = float(bns.psnr(jnp.asarray(xn), jnp.asarray(va["x1"])))
            log(f"[init] {name} psnr={vp:.2f}")
            _save_solver(out, name, folded, dict(
                kind="init", model=mname, nfe=nfe, guidance=w, sigma0=sigma0,
                init="euler", val_psnr=vp, init_val_psnr=vp, iters=0, forwards=0,
                gt_nfe=tr["gt_nfe"], pair_count=len(tr["x0"]),
            ))


def stage_aot(out, prof, log=print):
    wdir = os.path.join(out, "weights")
    entries = {}
    for name, cfg in train_model.MODEL_CONFIGS.items():
        params = train_model.load_params(os.path.join(wdir, f"{name}.npz"))
        # bucket 1 only for the flagship model (single-sample latency
        # experiments); everything else serves from 8/64 with padding.
        buckets = (1, 8, 64) if name == "img_fm_ot" else (8, 64)
        entries[name] = aot.export_model(cfg, params, out, buckets=buckets, log=log)
    # PD students share the teacher's architecture/config.
    pd_meta = json.load(open(os.path.join(wdir, "pd_meta.json")))
    base = train_model.MODEL_CONFIGS["img_fm_ot"]
    for nfe in (4, 8, 16):
        name = f"pd_nfe{nfe}"
        cfg = model.ModelConfig(name, base.data_dim, base.num_classes,
                                scheduler=base.scheduler,
                                parametrization=base.parametrization)
        params = train_model.load_params(os.path.join(wdir, f"{name}.npz"))
        entries[name] = aot.export_model(cfg, params, out, buckets=(8, 64), log=log)
    # jnp-fused variant of the flagship model, for the L1-vs-L2 perf
    # ablation (EXPERIMENTS.md §Perf).
    params = train_model.load_params(os.path.join(wdir, "img_fm_ot.npz"))
    cfg = train_model.MODEL_CONFIGS["img_fm_ot"]
    fused = []
    for b in (8, 64):
        path = f"models/img_fm_ot_fused_b{b}.hlo.txt"
        full = os.path.join(out, path)
        if not os.path.exists(full):
            text = aot.lower_model(cfg, params, b, use_pallas=False)
            open(full, "w").write(text)
            log(f"  [aot] {path} ({len(text)/1e6:.1f} MB)")
        fused.append({"batch": b, "path": path})
    entries["img_fm_ot_fused"] = fused
    return entries


def stage_mlp(out, prof, log=print):
    """Emit bns_mlp_field artifacts (rust real-compute CPU backend)."""
    os.makedirs(os.path.join(out, "models"), exist_ok=True)
    entries = {}
    for name, hidden, emb, depth, cfg, seed, buckets in MLP_FIELD_JOBS:
        spec = mlp_field.init_mlp_field(
            data.IMG_DIM, hidden, emb, data.NUM_CLASSES, depth, seed, cfg=cfg
        )
        body = json.dumps({"bns_mlp_field": spec})
        arts = []
        for b in buckets:
            rel = f"models/{name}_b{b}.mlp.json"
            full = os.path.join(out, rel)
            if not os.path.exists(full):
                open(full, "w").write(body)
                log(f"  [mlp] {rel} ({len(body)/1e6:.1f} MB)")
            arts.append({"batch": b, "path": rel})
        entries[name] = dict(
            artifacts=arts, cfg=cfg,
            mlp=dict(hidden=hidden, emb=emb, depth=depth, seed=seed),
        )
    return entries


def feature_extractor_weights(dim: int, seed=7):
    """Frozen random MLP used by FD-synth (DESIGN.md §3)."""
    rng = np.random.default_rng(seed)
    w1 = rng.normal(0, 1.0 / np.sqrt(dim), size=(dim, FEAT_HIDDEN)).astype(np.float32)
    b1 = rng.normal(0, 0.1, size=(FEAT_HIDDEN,)).astype(np.float32)
    w2 = rng.normal(0, 1.0 / np.sqrt(FEAT_HIDDEN), size=(FEAT_HIDDEN, FEAT_DIM)).astype(np.float32)
    return w1, b1, w2


def features(x, w1, b1, w2):
    return np.tanh(x @ w1 + b1) @ w2


def stage_manifest(out, prof, aot_entries, mlp_entries=None, log=print):
    wdir = os.path.join(out, "weights")
    train_meta = json.load(open(os.path.join(wdir, "train_meta.json")))
    pd_meta = json.load(open(os.path.join(wdir, "pd_meta.json")))

    models = {}
    base = train_model.MODEL_CONFIGS["img_fm_ot"]
    for name, entry in aot_entries.items():
        if name.startswith("pd_nfe"):
            cfg = base
            extra = {"pd": pd_meta[name.removeprefix("pd_nfe")]}
        elif name == "img_fm_ot_fused":
            cfg = base
            extra = {"fused_variant_of": "img_fm_ot"}
        else:
            cfg = train_model.MODEL_CONFIGS[name]
            extra = {"train": train_meta.get(name, {})}
        models[name] = dict(
            scheduler=cfg.scheduler,
            parametrization=cfg.parametrization,
            dim=cfg.data_dim,
            num_classes=cfg.num_classes,
            null_class=cfg.null_class,
            data="audio" if cfg.name.startswith("audio") else "images",
            # guided_velocity composes cond + uncond branches per eval; the
            # rust NFE accounting multiplies by this (defaults to 2 when
            # absent for older manifests).
            forwards_per_eval=2,
            artifacts=entry,
            **extra,
        )

    # bns_mlp_field models: same manifest shape as the AOT entries; the
    # rust backend selects the artifact kind from the weight file itself.
    for name, e in (mlp_entries or {}).items():
        models[name] = dict(
            scheduler="fm_ot",
            parametrization="velocity",
            dim=data.IMG_DIM,
            num_classes=data.NUM_CLASSES,
            null_class=data.NUM_CLASSES,
            data="images",
            forwards_per_eval=2 if e["cfg"] else 1,
            artifacts=e["artifacts"],
            mlp=e["mlp"],
        )

    solvers = sorted(
        f"solvers/{f}" for f in os.listdir(os.path.join(out, "solvers")) if f.endswith(".json")
    )

    # FD-synth reference statistics over the real synthetic-image dataset.
    w1, b1, w2 = feature_extractor_weights(data.IMG_DIM)
    rng = np.random.default_rng(42)
    ref_x, _ = data.make_images(rng, 4096)
    f = features(ref_x, w1, b1, w2)
    fd = dict(
        feat_hidden=FEAT_HIDDEN, feat_dim=FEAT_DIM, dim=data.IMG_DIM,
        w1=w1.reshape(-1).tolist(), b1=b1.tolist(), w2=w2.reshape(-1).tolist(),
        ref_mean=f.mean(0).tolist(),
        ref_cov=np.cov(f, rowvar=False).reshape(-1).tolist(),
        ref_count=len(f),
    )

    # Scheduler cross-check table for the rust mirror's unit tests.
    grid = np.linspace(0.0, 1.0, 21, dtype=np.float32)
    sched_check = {}
    for sname, sch in schedulers.SCHEDULERS.items():
        sched_check[sname] = dict(
            t=grid.tolist(),
            alpha=np.asarray(sch.alpha(jnp.asarray(grid)), np.float64).tolist(),
            sigma=np.asarray(sch.sigma(jnp.asarray(grid)), np.float64).tolist(),
        )

    # Solver-coefficient cross-check: python's NS generators vs rust's
    # taxonomy module (integration test `solver_generators_match_python`).
    solver_check = {
        "euler6": ns.euler_ns(ns.uniform_times(6)).to_json_dict(),
        "midpoint6": ns.midpoint_ns(6).to_json_dict(),
        "ab2_6": ns.ab2_ns(ns.uniform_times(6)).to_json_dict(),
        "dpmpp2m_fm_ot_6": ns.dpmpp_ns(schedulers.FM_OT, ns.uniform_times(6), 2).to_json_dict(),
        "ddim_vp_6": ns.ddim_ns(schedulers.VP, ns.uniform_times(6)).to_json_dict(),
    }

    manifest = dict(
        version=1,
        models=models,
        solvers=solvers,
        fd=fd,
        scheduler_check=sched_check,
        solver_check=solver_check,
        datasets=dict(
            images=dict(side=data.IMG_SIDE, channels=data.IMG_CHANNELS,
                        dim=data.IMG_DIM, num_classes=data.NUM_CLASSES),
            audio=dict(length=data.AUDIO_LEN, families=list(data.AUDIO_FAMILIES)),
        ),
        profile=prof,
    )
    path = os.path.join(out, "manifest.json")
    json.dump(manifest, open(path, "w"), indent=1)
    log(f"[manifest] {path} ({len(models)} models, {len(solvers)} solvers)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--profile", default="full", choices=list(PROFILES))
    ap.add_argument("--stages", nargs="*",
                    default=["weights", "pd", "pairs", "solvers", "aot", "mlp", "manifest"])
    args = ap.parse_args()
    prof = PROFILES[args.profile]
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)
    t0 = time.time()
    aot_entries = None
    mlp_entries = None
    for st in args.stages:
        log = lambda *a: print(f"[{time.time()-t0:7.0f}s]", *a, flush=True)
        if st == "weights":
            stage_weights(out, prof, log)
        elif st == "pd":
            stage_pd(out, prof, log)
        elif st == "pairs":
            stage_pairs(out, prof, log)
        elif st == "solvers":
            stage_solvers(out, prof, log)
        elif st == "aot":
            aot_entries = stage_aot(out, prof, log)
        elif st == "mlp":
            mlp_entries = stage_mlp(out, prof, log)
        elif st == "manifest":
            if aot_entries is None:
                aot_entries = stage_aot(out, prof, log)
            if mlp_entries is None:
                mlp_entries = stage_mlp(out, prof, log)
            stage_manifest(out, prof, aot_entries, mlp_entries, log)
    print(f"[artifacts] done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
