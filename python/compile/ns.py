"""Non-Stationary solvers (Section 3.1) and the solver taxonomy (§3.3).

Three pieces live here:

1. `NSSolver` — the concrete n-step NS solver of eq. 11/12:
   theta = [T_n, (a_0, b_0), ..., (a_{n-1}, b_{n-1})], with Algorithm 1
   (`sample`) implemented over any velocity field.

2. `AffineTrace` — a tiny symbolic-state algebra: a solver state is kept
   as an affine expression `a * x0 + sum_j b_j u_j` with *numeric*
   coefficients. Running any baseline solver on AffineTrace states
   yields its exact NS coefficients — this is the constructive content
   of Proposition 3.1 and Theorem 3.2, and it is how BNS optimization is
   initialized from Euler/Midpoint (§3.2 "Initialization").

3. Coefficient generators for every family of Figure 3:
   Euler, Midpoint, RK4, Adams-Bashforth(2) (generic); DDIM
   (exponential-Euler on eps), DPM-Solver++ 1S/2M (exponential on x̂);
   EDM-style discretization; plus `reduce_cd_to_ab`, the explicit
   recursion (eq. 32) of the Prop 3.1 proof, used by tests.

The rust mirror is rust/src/solver/{ns,taxonomy}.rs; JSON emitted by
`NSSolver.to_json_dict` is the interchange format.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from . import schedulers


@dataclasses.dataclass
class NSSolver:
    """theta of eq. 12. `b` is stored dense lower-triangular [n, n]."""

    times: np.ndarray  # [n+1], times[0] = 0, times[n] = 1
    a: np.ndarray  # [n]
    b: np.ndarray  # [n, n], b[i, j] = 0 for j > i

    @property
    def nfe(self) -> int:
        return len(self.a)

    def num_params(self) -> int:
        """Dimension of the NS family at this step count: n(n+5)/2 + 1.

        (n-1 interior times + n coefficients a + n(n+1)/2 coefficients b.)
        """
        n = self.nfe
        return n * (n + 5) // 2 + 1 - 2  # -2: t_0 = 0 and t_n = 1 are fixed

    def sample(self, u, x0):
        """Algorithm 1: Non-Stationary sampling.

        Args:
          u:  callable (t, x) -> velocity, where x carries the batch.
          x0: [..., D] initial noise.
        Returns: x_n, the approximation to x(1).
        """
        x = x0
        hist = []
        for i in range(self.nfe):
            hist.append(u(self.times[i], x))
            x = self.a[i] * x0 + sum(self.b[i, j] * hist[j] for j in range(i + 1))
        return x

    def sample_with_history(self, u, x0):
        """Algorithm 1 keeping every iterate (for diagnostics/plots)."""
        x, xs, hist = x0, [x0], []
        for i in range(self.nfe):
            hist.append(u(self.times[i], x))
            x = self.a[i] * x0 + sum(self.b[i, j] * hist[j] for j in range(i + 1))
            xs.append(x)
        return x, xs

    def to_json_dict(self, **extra):
        d = {
            "times": [float(t) for t in self.times],
            "a": [float(v) for v in self.a],
            "b": [[float(self.b[i, j]) for j in range(i + 1)] for i in range(self.nfe)],
        }
        d.update(extra)
        return d

    @staticmethod
    def from_json_dict(d) -> "NSSolver":
        n = len(d["a"])
        b = np.zeros((n, n), np.float64)
        for i, row in enumerate(d["b"]):
            b[i, : len(row)] = row
        return NSSolver(np.asarray(d["times"], np.float64), np.asarray(d["a"], np.float64), b)


# ---------------------------------------------------------------------------
# Affine tracing: states as  a * x0 + sum_j b_j u_j  with numeric coeffs
# ---------------------------------------------------------------------------


class AffineTrace:
    """Symbolic solver execution over the affine state algebra.

    Call `eval_u(state, t)` wherever a concrete solver would evaluate the
    velocity field; each call appends one NS step. Works for any method
    whose update is a linear combination of previous states and
    velocities — i.e. exactly the NS family (Prop 3.1).
    """

    def __init__(self):
        self.times: list[float] = []
        self.rows_a: list[float] = []
        self.rows_b: list[np.ndarray] = []
        self._k = 0  # number of velocity evals so far

    def x0(self) -> "Aff":
        return Aff(1.0, np.zeros(0))

    def eval_u(self, state: "Aff", t: float) -> "Aff":
        """Record evaluation u_k := u(t, state); returns the symbol u_k.

        The *state being evaluated* becomes trajectory point x_k of the NS
        solver, so its (a, b) row is recorded (except for x_0 itself).
        """
        k = self._k
        if k == 0:
            assert state.a == 1.0 and len(state.b) == 0, "first eval must be at x0"
        else:
            self.rows_a.append(state.a)
            self.rows_b.append(np.pad(state.b, (0, k - len(state.b))))
        self.times.append(float(t))
        sym = Aff(0.0, np.zeros(k + 1))
        sym.b[k] = 1.0
        self._k += 1
        return sym

    def finish(self, final: "Aff", t_final: float = 1.0) -> NSSolver:
        self.rows_a.append(final.a)
        self.rows_b.append(np.pad(final.b, (0, self._k - len(final.b))))
        self.times.append(float(t_final))
        n = self._k
        b = np.zeros((n, n), np.float64)
        for i, row in enumerate(self.rows_b):
            b[i, : len(row)] = row[: i + 1]
        return NSSolver(np.asarray(self.times, np.float64), np.asarray(self.rows_a, np.float64), b)


class Aff:
    """a * x0 + b . (u_0 ... u_{k-1}) with numeric coefficients."""

    __slots__ = ("a", "b")

    def __init__(self, a: float, b: np.ndarray):
        self.a = float(a)
        self.b = np.asarray(b, np.float64)

    def _lift(self, other: "Aff"):
        k = max(len(self.b), len(other.b))
        return np.pad(self.b, (0, k - len(self.b))), np.pad(other.b, (0, k - len(other.b)))

    def __add__(self, other: "Aff") -> "Aff":
        sb, ob = self._lift(other)
        return Aff(self.a + other.a, sb + ob)

    def __sub__(self, other: "Aff") -> "Aff":
        sb, ob = self._lift(other)
        return Aff(self.a - other.a, sb - ob)

    def __mul__(self, c: float) -> "Aff":
        return Aff(self.a * c, self.b * c)

    __rmul__ = __mul__


# ---------------------------------------------------------------------------
# Proposition 3.1: explicit (c, d) -> (a, b) reduction (eq. 32)
# ---------------------------------------------------------------------------


def reduce_cd_to_ab(c_rows, d_rows):
    """The induction of Appendix A, eq. 32, as executable code.

    Args:
      c_rows, d_rows: lists where row i has length i+1 — the naive NS
        update rule x_{i+1} = X_i c_i + U_i d_i of eq. 10.
    Returns: (a [n], b [n,n] lower-tri) of the reduced rule eq. 11.
    """
    n = len(c_rows)
    a = np.zeros(n)
    b = np.zeros((n, n))
    for k in range(n):
        ck, dk = np.asarray(c_rows[k], float), np.asarray(d_rows[k], float)
        # a_k = c_k0 + sum_{j=0}^{k-1} c_{k,j+1} a_j   (eq. 32; the paper
        # writes (c_k)_j a_j — the index shift follows its derivation where
        # x_{j+1} = a_j x0 + ..., i.e. coefficient (c_k)_{j+1} pairs with a_j.)
        a[k] = ck[0] + sum(ck[j + 1] * a[j] for j in range(k))
        for j in range(k):
            b[k, j] = sum(ck[l + 1] * b[l, j] for l in range(j, k)) + dk[j]
        b[k, k] = dk[k]
    return a, b


# ---------------------------------------------------------------------------
# Baseline solver coefficient generators (the families of Figure 3)
# ---------------------------------------------------------------------------


def uniform_times(n: int) -> np.ndarray:
    return np.linspace(0.0, 1.0, n + 1)


def euler_ns(times) -> NSSolver:
    """Euler (RK1): x_{i+1} = x_i + h_i u_i, as NS coefficients."""
    times = np.asarray(times, np.float64)
    tr = AffineTrace()
    x = tr.x0()
    for i in range(len(times) - 1):
        u = tr.eval_u(x, times[i])
        x = x + (times[i + 1] - times[i]) * u
    return tr.finish(x, times[-1])


def midpoint_ns(nfe: int, times=None) -> NSSolver:
    """RK-Midpoint with nfe velocity evaluations (nfe must be even).

    The NS time discretization interleaves macro points and midpoints, as
    in the paper's BNS initialization.
    """
    assert nfe % 2 == 0, "midpoint needs an even NFE"
    m = nfe // 2
    s = np.linspace(0.0, 1.0, m + 1) if times is None else np.asarray(times, np.float64)
    tr = AffineTrace()
    x = tr.x0()
    for k in range(m):
        h = s[k + 1] - s[k]
        u1 = tr.eval_u(x, s[k])
        xi = x + (0.5 * h) * u1
        u2 = tr.eval_u(xi, s[k] + 0.5 * h)
        x = x + h * u2
    return tr.finish(x, s[-1])


def rk4_ns(nfe: int) -> NSSolver:
    """Classic RK4 (nfe divisible by 4), via affine tracing.

    Note the NS discretization visits t_k, t_k + h/2 twice, t_k + h; NS
    times must be *monotone increasing*, so we nudge the repeated node by
    +1e-9 (the update coefficients are unaffected).
    """
    assert nfe % 4 == 0, "rk4 needs NFE divisible by 4"
    m = nfe // 4
    s = np.linspace(0.0, 1.0, m + 1)
    tr = AffineTrace()
    x = tr.x0()
    for k in range(m):
        h = s[k + 1] - s[k]
        k1 = tr.eval_u(x, s[k])
        k2 = tr.eval_u(x + (0.5 * h) * k1, s[k] + 0.5 * h)
        # nudges keep the NS time grid strictly monotone; coefficients are
        # unaffected (the RK tableau uses the exact node internally and the
        # nudge is far below solver error).
        k3 = tr.eval_u(x + (0.5 * h) * k2, s[k] + 0.5 * h + 1e-6 * h)
        k4 = tr.eval_u(x + h * k3, s[k] + h * (1.0 - 1e-6))
        x = x + (h / 6.0) * (k1 + 2.0 * k2 + 2.0 * k3 + k4)
    return tr.finish(x, 1.0)


def ab2_ns(times) -> NSSolver:
    """2-step Adams-Bashforth (Euler bootstrap), as NS coefficients."""
    times = np.asarray(times, np.float64)
    tr = AffineTrace()
    x = tr.x0()
    prev_u = None
    for i in range(len(times) - 1):
        h = times[i + 1] - times[i]
        u = tr.eval_u(x, times[i])
        if prev_u is None:
            x = x + h * u
        else:
            hp = times[i] - times[i - 1]
            w1 = h * (1 + h / (2 * hp))
            w0 = -h * h / (2 * hp)
            x = x + w1 * u + w0 * prev_u
        prev_u = u
    return tr.finish(x, times[-1])


def _xhat_from_u(sched: schedulers.Scheduler, t: float, x: Aff, u: Aff) -> Aff:
    """Invert eq. 5 for the x-prediction: x̂ = (u - beta x) / gamma."""
    beta, gamma = sched.uv_coeffs(jnp.float32(t), "x")
    return (u - float(beta) * x) * (1.0 / float(gamma))


def _eps_from_u(sched: schedulers.Scheduler, t: float, x: Aff, u: Aff) -> Aff:
    """Invert eq. 5 for the eps-prediction: eps = (u - beta x) / gamma."""
    beta, gamma = sched.uv_coeffs(jnp.float32(t), "eps")
    return (u - float(beta) * x) * (1.0 / float(gamma))


def ddim_ns(sched: schedulers.Scheduler, times) -> NSSolver:
    """DDIM = exponential Euler on the eps-prediction (§3.3.2, eq. 22).

    x_{i+1} = (alpha_{i+1}/alpha_i) x_i + (sigma_{i+1} - alpha_{i+1}
    sigma_i / alpha_i) eps_i. Singular at alpha = 0, so for schedulers
    with alpha_0 = 0 (FM-OT, cosine) pass times with t_0 > 0.
    """
    times = np.asarray(times, np.float64)
    al = np.asarray(sched.alpha(jnp.asarray(times, jnp.float32)), np.float64)
    si = np.asarray(sched.sigma(jnp.asarray(times, jnp.float32)), np.float64)
    if al[0] <= 0:
        raise ValueError("DDIM needs alpha(t_0) > 0; shift t_0 or use dpmpp")
    tr = AffineTrace()
    x = tr.x0()
    for i in range(len(times) - 1):
        u = tr.eval_u(x, times[i])
        eps = _eps_from_u(sched, times[i], x, u)
        x = (al[i + 1] / al[i]) * x + (si[i + 1] - al[i + 1] * si[i] / al[i]) * eps
    return tr.finish(x, times[-1])


def dpmpp_ns(sched: schedulers.Scheduler, times, order: int = 2) -> NSSolver:
    """DPM-Solver++ (1S for order=1, 2M for order=2) as NS coefficients.

    Exponential integrator on the x-prediction (eq. 22 with psi = sigma,
    eta = 1), multistep form:
        h_i  = lambda_{i+1} - lambda_i          (lambda = log snr)
        D_i  = (1 + 1/(2 r_i)) x̂_i - 1/(2 r_i) x̂_{i-1},  r_i = h_{i-1}/h_i
        x_{i+1} = (sigma_{i+1}/sigma_i) x_i + alpha_{i+1} (1 - e^{-h_i}) D_i
    The final step (sigma_{n} = 0 allowed) degrades gracefully to x̂.
    """
    times = np.asarray(times, np.float64)
    tf = jnp.asarray(times, jnp.float32)
    al = np.asarray(sched.alpha(tf), np.float64)
    si = np.asarray(sched.sigma(tf), np.float64)
    lam = np.log(np.maximum(al, 1e-30)) - np.log(np.maximum(si, 1e-30))
    tr = AffineTrace()
    x = tr.x0()
    n = len(times) - 1
    prev_xhat, prev_h = None, None
    for i in range(n):
        u = tr.eval_u(x, times[i])
        xhat = _xhat_from_u(sched, times[i], x, u)
        h = lam[i + 1] - lam[i]
        # lower_order_final (as in the reference DPM-Solver++ and the rust
        # mirror): the final lambda jump is unbounded when sigma(1) = 0 and
        # second-order extrapolation across it diverges.
        if order >= 2 and prev_xhat is not None and i + 1 < n:
            r = prev_h / h
            d = (1 + 1 / (2 * r)) * xhat - (1 / (2 * r)) * prev_xhat
        else:
            d = xhat
        x = (si[i + 1] / si[i]) * x + (al[i + 1] * (1 - np.exp(-h))) * d
        prev_xhat, prev_h = xhat, h
    return tr.finish(x, times[-1])


def edm_times(n: int, sched: schedulers.Scheduler, rho: float = 7.0) -> np.ndarray:
    """EDM's rho-schedule time discretization mapped back to model time.

    EDM picks sigma-levels sigma_j = (smax^{1/rho} + j/(n-1) (smin^{1/rho}
    - smax^{1/rho}))^rho on the VE path and integrates over them; via the
    snr correspondence these map to original times t_j = snr^{-1}(1 /
    sigma_j). We return the induced monotone time grid for use with any
    solver (the paper's "EDM incorporates a particular time
    discretization" note).
    """
    smin, smax = 2e-3, float(schedulers.EDM_SIGMA_MAX)
    j = np.arange(n + 1) / n
    sig = (smax ** (1 / rho) + j * (smin ** (1 / rho) - smax ** (1 / rho))) ** rho
    t = np.asarray(sched.snr_inv(jnp.asarray(1.0 / sig, jnp.float32)), np.float64)
    t[0], t[-1] = 0.0, 1.0
    return np.maximum.accumulate(t)
