"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth against which `python/tests/test_kernels.py`
checks the Pallas implementations (hypothesis sweeps over shapes, seeds
and dtypes). They are also usable directly as a drop-in for the kernels
(`model.py` switches on `use_pallas`), which keeps the AOT path testable
independently of Pallas.
"""

from __future__ import annotations

import jax.numpy as jnp


def fused_resblock(x, w1, b1, w2, b2, scale, shift):
    """Time-modulated residual MLP block (the model's hot path).

    y = x + (silu((x * (1 + scale) + shift) @ w1 + b1)) @ w2 + b2

    Args:
      x:      [B, D] activations.
      w1:     [D, H] first projection.
      b1:     [H].
      w2:     [H, D] second projection.
      b2:     [D].
      scale:  [B, D] AdaLN-lite time/cond modulation (gain).
      shift:  [B, D] AdaLN-lite time/cond modulation (bias).
    Returns:
      [B, D] block output (includes the residual skip).
    """
    h = x * (1.0 + scale) + shift
    h = h @ w1 + b1
    h = h * jnp.reciprocal(1.0 + jnp.exp(-h))  # silu
    return x + h @ w2 + b2


def ns_update(x0, hist_u, a, b):
    """The NS solver update rule of eq. 11: x_{i+1} = a * x0 + U_i b.

    Args:
      x0:     [B, D] source sample.
      hist_u: [K, B, D] history of velocity evaluations u_0..u_{K-1}
              (rows beyond the current step are zero-padded and masked by
              a zero coefficient in b).
      a:      scalar coefficient on x0.
      b:      [K] coefficients on the velocity history.
    Returns:
      [B, D] the next iterate.
    """
    return a * x0 + jnp.einsum("k,kbd->bd", b, hist_u)


def time_embed(t, dim, max_period=1e4):
    """Sinusoidal time embedding (scalar t broadcast to [dim])."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(max_period) * jnp.arange(half) / half)
    args = t * freqs
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)
