"""L1 Pallas kernel: the NS solver combine step (eq. 11).

x_{i+1} = a * x0 + sum_k b_k * u_k over the velocity history U_i.

This is the solver-side hot op: at step i it touches (i+2) full-size
tensors. A naive implementation issues i+1 separate AXPYs, reading x
partials from HBM each time; the kernel instead streams each history row
through VMEM once and keeps the accumulator resident.

TPU mapping: grid = (K, B/bt) with the accumulator tile [bt, D] living in
the output VMEM block across the K-loop (revisiting grid dimension);
per-step VMEM = 2*bt*D floats (history tile + accumulator) — for bt=8,
D=4096 that is 256 KiB, far below VMEM, so the HBM->VMEM streams can be
double-buffered. All work is VPU multiply-adds; there is no MXU use, the
kernel is bandwidth-bound with arithmetic intensity ~= 1 FLOP / 4 bytes,
so the roofline target is HBM bandwidth, which a single linear stream of
the history buffer achieves by construction.

interpret=True as everywhere (see fused_resblock.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ns_update_kernel(x0_ref, u_ref, a_ref, b_ref, o_ref):
    k = pl.program_id(0)
    # Initialize the accumulator with a*x0 on the first history row; the
    # output block index is constant in k so it persists across the loop.
    @pl.when(k == 0)
    def _init():
        o_ref[...] = a_ref[0] * x0_ref[...]

    o_ref[...] += b_ref[0] * u_ref[0]


@functools.partial(jax.jit, static_argnames=("batch_tile",))
def ns_update(x0, hist_u, a, b, *, batch_tile=8):
    """Pallas version of `ref.ns_update` (see there for semantics).

    Args:
      x0:     [B, D].
      hist_u: [K, B, D].
      a:      scalar (rank-0 or [1]).
      b:      [K].
    """
    kk, bsz, d = hist_u.shape
    bt = min(batch_tile, bsz)
    if bsz % bt != 0:
        pad = (-bsz) % bt
        out = ns_update(
            jnp.pad(x0, ((0, pad), (0, 0))),
            jnp.pad(hist_u, ((0, 0), (0, pad), (0, 0))),
            a,
            b,
            batch_tile=bt,
        )
        return out[:bsz]

    a = jnp.reshape(a, (1,)).astype(x0.dtype)
    b = jnp.asarray(b, x0.dtype)
    grid = (kk, bsz // bt)
    return pl.pallas_call(
        _ns_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda k, i: (i, 0)),      # x0 tile
            pl.BlockSpec((1, bt, d), lambda k, i: (k, i, 0)),  # history row k
            pl.BlockSpec((1,), lambda k, i: (0,)),           # a
            pl.BlockSpec((1,), lambda k, i: (k,)),           # b_k
        ],
        out_specs=pl.BlockSpec((bt, d), lambda k, i: (i, 0)),  # accumulator
        out_shape=jax.ShapeDtypeStruct((bsz, d), x0.dtype),
        interpret=True,
    )(x0, hist_u, a, b)
