"""L1 Pallas kernel: time-modulated fused residual MLP block.

This is the model's compute hot-spot: every velocity-field evaluation runs
`depth` of these blocks, and every solver step is one such evaluation, so
NFE x depth blocks dominate end-to-end sampling cost.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): on a GPU this chain
(modulate -> matmul -> SiLU -> matmul -> add) would be fused with a
persistent-threadblock kernel keeping `h` in shared memory. The TPU
translation keeps the whole chain in VMEM for a tile of the batch:

  * grid over batch tiles of `bt` rows; each grid step owns the full
    [D, H] / [H, D] weight panels (they are small enough to be resident:
    D,H <= 512 => 2*D*H*4B <= 2 MiB << 16 MiB VMEM),
  * the two matmuls are MXU work ([bt,D]x[D,H] then [bt,H]x[H,D]); with
    bt = 8 and D,H multiples of 128 these map onto (8x128)(128x128)
    systolic passes,
  * SiLU + modulation + skip are VPU elementwise ops fused between the
    MXU passes — zero extra HBM traffic for `h`.

VMEM footprint per grid step (f32): bt*(2D + H) + D*H + H*D + H + D
floats; for bt=8, D=H=256 that is ~0.53 MiB, i.e. <4% of VMEM, leaving
room for double-buffering the activation tiles.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness is validated against `ref.fused_resblock` and
real-TPU performance is *estimated* (EXPERIMENTS.md §Perf), never measured
from interpret timings.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _resblock_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, sc_ref, sh_ref, o_ref):
    x = x_ref[...]
    h = x * (1.0 + sc_ref[...]) + sh_ref[...]
    h = jnp.dot(h, w1_ref[...]) + b1_ref[...]
    h = h * jnp.reciprocal(1.0 + jnp.exp(-h))  # silu, VPU op between MXU passes
    o_ref[...] = x + jnp.dot(h, w2_ref[...]) + b2_ref[...]


@functools.partial(jax.jit, static_argnames=("batch_tile",))
def fused_resblock(x, w1, b1, w2, b2, scale, shift, *, batch_tile=8):
    """Pallas version of `ref.fused_resblock` (see there for semantics).

    Tiles the batch dimension; weight panels are replicated to every grid
    step (index_map pins them to block (0, 0)).
    """
    bsz, d = x.shape
    h = w1.shape[1]
    bt = min(batch_tile, bsz)
    if bsz % bt != 0:  # pad to a whole number of tiles, slice after
        pad = (-bsz) % bt
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        scp = jnp.pad(scale, ((0, pad), (0, 0)))
        shp = jnp.pad(shift, ((0, pad), (0, 0)))
        out = fused_resblock(xp, w1, b1, w2, b2, scp, shp, batch_tile=bt)
        return out[:bsz]

    grid = (bsz // bt,)
    row_spec = pl.BlockSpec((bt, d), lambda i: (i, 0))
    return pl.pallas_call(
        _resblock_kernel,
        grid=grid,
        in_specs=[
            row_spec,                                   # x tile
            pl.BlockSpec((d, h), lambda i: (0, 0)),     # w1 (resident)
            pl.BlockSpec((h,), lambda i: (0,)),         # b1
            pl.BlockSpec((h, d), lambda i: (0, 0)),     # w2 (resident)
            pl.BlockSpec((d,), lambda i: (0,)),         # b2
            row_spec,                                   # scale tile
            row_spec,                                   # shift tile
        ],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, d), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2, scale, shift)
