"""L2: the velocity-field network (jax, calls the L1 Pallas kernels).

A conditional residual-MLP denoiser in the spirit of the paper's U-Nets,
scaled to the synthetic datasets (DESIGN.md §3). The same architecture
serves all three parametrizations of Table 1:

  * 'velocity' : f_t(x) = u_t(x)                      (FM-OT, FM/v-CS)
  * 'eps'      : f_t(x) = noise prediction            (eps-VP)
  * 'x'        : f_t(x) = clean-sample prediction

`velocity_from_f` applies Table 1 to turn any parametrization into the
sampling velocity field u_t(x) = beta_t x + gamma_t f_t(x), and
`guided_velocity` composes classifier-free guidance
    u_w = u(x|c) + w (u(x|c) - u(x|null)),
so w = 0 is conditional-unguided sampling, matching the paper's Table 3.

Architecture: input proj -> `depth` fused residual blocks (the L1 Pallas
kernel), each AdaLN-lite-modulated by a (time, class) embedding -> output
proj. Everything is a pure function of a params dict so the AOT path can
bake trained weights as HLO constants.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import schedulers
from .kernels import ref as kref
from .kernels.fused_resblock import fused_resblock as pallas_resblock


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    data_dim: int
    num_classes: int
    hidden: int = 256
    depth: int = 4
    emb_dim: int = 64
    scheduler: str = "fm_ot"
    parametrization: str = "velocity"  # velocity | eps | x

    @property
    def null_class(self) -> int:
        """Extra class id used as the CFG unconditional token."""
        return self.num_classes


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """He-style init; output projection near-zero (residual style)."""
    rng = np.random.default_rng(seed)

    def dense(n_in, n_out, scale=None):
        scale = scale if scale is not None else 1.0 / np.sqrt(n_in)
        return rng.normal(0, scale, size=(n_in, n_out)).astype(np.float32)

    d, h, e = cfg.data_dim, cfg.hidden, cfg.emb_dim
    params = {
        "cls_emb": rng.normal(0, 0.02, size=(cfg.num_classes + 1, e)).astype(np.float32),
        "temb_w1": dense(e, e),
        "temb_b1": np.zeros(e, np.float32),
        "temb_w2": dense(e, e),
        "temb_b2": np.zeros(e, np.float32),
        "in_w": dense(d, h),
        "in_b": np.zeros(h, np.float32),
        "out_w": dense(h, d, scale=1e-4),
        "out_b": np.zeros(d, np.float32),
    }
    for i in range(cfg.depth):
        params[f"blk{i}_w1"] = dense(h, h)
        params[f"blk{i}_b1"] = np.zeros(h, np.float32)
        params[f"blk{i}_w2"] = dense(h, h, scale=1e-2 / np.sqrt(h))
        params[f"blk{i}_b2"] = np.zeros(h, np.float32)
        # modulation: emb -> (scale, shift) per block, near-zero init so
        # the net starts as an unmodulated residual MLP.
        params[f"blk{i}_mw"] = dense(e, 2 * h, scale=1e-3)
        params[f"blk{i}_mb"] = np.zeros(2 * h, np.float32)
    return {k: jnp.asarray(v) for k, v in params.items()}


def param_count(params: dict) -> int:
    return int(sum(int(np.prod(v.shape)) for v in params.values()))


def model_f(cfg: ModelConfig, params: dict, x, t, labels, *, use_pallas: bool = True):
    """Evaluate the raw network f_t(x | labels).

    Args:
      x:      [B, D] current state.
      t:      scalar time in [0, 1].
      labels: [B] int32 class ids (cfg.null_class = unconditional).
    Returns: [B, D] model output in the configured parametrization.
    """
    emb = kref.time_embed(t * 1000.0, cfg.emb_dim)  # [e]
    emb = jnp.tanh(emb @ params["temb_w1"] + params["temb_b1"])
    emb = emb @ params["temb_w2"] + params["temb_b2"]  # [e]
    cemb = params["cls_emb"][labels]  # [B, e]
    cond = cemb + emb[None, :]  # [B, e]

    h = x @ params["in_w"] + params["in_b"]
    blk = pallas_resblock if use_pallas else kref.fused_resblock
    for i in range(cfg.depth):
        mod = cond @ params[f"blk{i}_mw"] + params[f"blk{i}_mb"]  # [B, 2h]
        scale, shift = jnp.split(mod, 2, axis=-1)
        h = blk(
            h,
            params[f"blk{i}_w1"],
            params[f"blk{i}_b1"],
            params[f"blk{i}_w2"],
            params[f"blk{i}_b2"],
            scale,
            shift,
        )
    return h @ params["out_w"] + params["out_b"]


def velocity_from_f(cfg: ModelConfig, f_val, x, t):
    """Table 1: u_t(x) = beta_t x + gamma_t f_t(x).

    For eps/x parametrizations the Table-1 coefficients are singular at a
    path endpoint (e.g. VP's sigmȧ/.. as sigma -> 0 at t = 1), so t is
    clamped to [1e-4, 1 - 1e-3] *for the coefficient computation only* —
    the standard integration-horizon trick; the network still sees the
    true t via `model_f`.
    """
    sched = schedulers.SCHEDULERS[cfg.scheduler]
    tc = t if cfg.parametrization == "velocity" else jnp.clip(t, 1e-4, 1.0 - 1e-3)
    beta, gamma = sched.uv_coeffs(tc, cfg.parametrization)
    return beta * x + gamma * f_val


def velocity(cfg: ModelConfig, params: dict, x, t, labels, *, use_pallas=True):
    """The sampling velocity field u_t(x | labels) of eq. 5."""
    f_val = model_f(cfg, params, x, t, labels, use_pallas=use_pallas)
    return velocity_from_f(cfg, f_val, x, t)


def guided_velocity(cfg: ModelConfig, params: dict, x, t, labels, w, *, use_pallas=True):
    """CFG-composed velocity: u_w = u_c + w (u_c - u_null).

    Both branches are evaluated in one batched network call (batch 2B) so
    the AOT artifact is a single fused executable; the paper notes CFG
    "increases the effective batch size" — this is that doubling.
    """
    bsz = x.shape[0]
    null = jnp.full((bsz,), cfg.null_class, dtype=labels.dtype)
    x2 = jnp.concatenate([x, x], axis=0)
    l2 = jnp.concatenate([labels, null], axis=0)
    f2 = model_f(cfg, params, x2, t, l2, use_pallas=use_pallas)
    u2 = velocity_from_f(cfg, f2, x2, t)
    u_c, u_n = u2[:bsz], u2[bsz:]
    return u_c + w * (u_c - u_n)
