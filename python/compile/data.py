"""Synthetic datasets standing in for the paper's gated assets.

DESIGN.md §3 documents the substitutions:

* images:  ImageNet-64/128 -> a 10-class procedural 8x8 RGB pattern
  dataset ("synth-images"). Classes are parametric texture families with
  continuous nuisance parameters, so the class-conditional generative
  task is non-trivial (multimodal per class) while trainable in seconds.
* audio:   Audiobox speech infilling -> 1-D length-128 waveforms drawn
  from 4 signal families ("datasets" in the sense of Fig. 6/12):
  harmonic stacks, AM tones, linear chirps, filtered noise bands.

Both are generated from a seeded PRNG; the rust side regenerates the same
evaluation sets via the shared PCG stream exported in the artifacts
manifest, so FD-synth statistics are computed over identical references.
"""

from __future__ import annotations

import numpy as np

IMG_SIDE = 8
IMG_CHANNELS = 3
IMG_DIM = IMG_SIDE * IMG_SIDE * IMG_CHANNELS  # 192
NUM_CLASSES = 10

AUDIO_LEN = 128
AUDIO_FAMILIES = ("harmonic", "am", "chirp", "noiseband")


def _grid():
    ys, xs = np.mgrid[0:IMG_SIDE, 0:IMG_SIDE].astype(np.float32)
    return xs / (IMG_SIDE - 1), ys / (IMG_SIDE - 1)


def make_images(rng: np.random.Generator, n: int, labels=None):
    """Sample `n` images; returns (x [n, IMG_DIM] in [-1,1], labels [n])."""
    xs, ys = _grid()
    if labels is None:
        labels = rng.integers(0, NUM_CLASSES, size=n)
    out = np.zeros((n, IMG_SIDE, IMG_SIDE, IMG_CHANNELS), np.float32)
    for i, c in enumerate(labels):
        # Continuous nuisances: phase, frequency jitter, base color.
        ph = rng.uniform(0, 2 * np.pi, size=2)
        fq = rng.uniform(0.8, 1.6)
        col = rng.uniform(0.3, 1.0, size=IMG_CHANNELS).astype(np.float32)
        cx, cy = rng.uniform(0.2, 0.8, size=2)
        c = int(c)
        if c == 0:  # horizontal stripes
            base = np.sin(2 * np.pi * fq * 2 * ys + ph[0])
        elif c == 1:  # vertical stripes
            base = np.sin(2 * np.pi * fq * 2 * xs + ph[0])
        elif c == 2:  # diagonal stripes
            base = np.sin(2 * np.pi * fq * 1.5 * (xs + ys) + ph[0])
        elif c == 3:  # checkerboard
            base = np.sin(2 * np.pi * fq * 2 * xs + ph[0]) * np.sin(
                2 * np.pi * fq * 2 * ys + ph[1]
            )
        elif c == 4:  # gaussian blob
            base = 2 * np.exp(-(((xs - cx) ** 2 + (ys - cy) ** 2) / 0.05) * fq) - 1
        elif c == 5:  # ring
            r = np.sqrt((xs - cx) ** 2 + (ys - cy) ** 2)
            base = 2 * np.exp(-(((r - 0.3) ** 2) / 0.01) * fq) - 1
        elif c == 6:  # radial waves
            r = np.sqrt((xs - cx) ** 2 + (ys - cy) ** 2)
            base = np.sin(2 * np.pi * fq * 3 * r + ph[0])
        elif c == 7:  # corner gradient
            base = 2 * (fq * (xs * np.cos(ph[0]) + ys * np.sin(ph[0]))) % 2 - 1
        elif c == 8:  # cross
            base = 2 * np.maximum(
                np.exp(-((xs - cx) ** 2) / 0.01), np.exp(-((ys - cy) ** 2) / 0.01)
            ) - 1
        else:  # blob pair (multimodal within image)
            b1 = np.exp(-(((xs - cx) ** 2 + (ys - cy) ** 2) / 0.02))
            b2 = np.exp(-(((xs - (1 - cx)) ** 2 + (ys - (1 - cy)) ** 2) / 0.02))
            base = 2 * np.maximum(b1, b2) - 1
        out[i] = base[..., None] * col[None, None, :]
    return out.reshape(n, IMG_DIM).clip(-1, 1), labels.astype(np.int32)


def make_audio(rng: np.random.Generator, n: int, labels=None):
    """Sample `n` waveforms; returns (x [n, AUDIO_LEN] in [-1,1], labels)."""
    t = np.arange(AUDIO_LEN, dtype=np.float32) / AUDIO_LEN
    if labels is None:
        labels = rng.integers(0, len(AUDIO_FAMILIES), size=n)
    out = np.zeros((n, AUDIO_LEN), np.float32)
    for i, c in enumerate(labels):
        f0 = rng.uniform(2.0, 8.0)
        ph = rng.uniform(0, 2 * np.pi)
        c = int(c)
        if c == 0:  # harmonic stack (speech-formant-like)
            sig = sum(
                rng.uniform(0.2, 1.0) * np.sin(2 * np.pi * f0 * (k + 1) * t + ph * k)
                for k in range(3)
            )
        elif c == 1:  # AM tone
            sig = np.sin(2 * np.pi * 4 * f0 * t + ph) * (
                0.5 + 0.5 * np.sin(2 * np.pi * f0 * 0.5 * t)
            )
        elif c == 2:  # linear chirp
            sig = np.sin(2 * np.pi * (f0 * t + 0.5 * rng.uniform(4, 16) * t**2) + ph)
        else:  # filtered noise band
            white = rng.normal(size=AUDIO_LEN).astype(np.float32)
            spec = np.fft.rfft(white)
            freqs = np.arange(spec.shape[0], dtype=np.float32)
            center = rng.uniform(8, 40)
            spec *= np.exp(-((freqs - center) ** 2) / (2 * 6.0**2))
            sig = np.fft.irfft(spec, n=AUDIO_LEN).astype(np.float32)
            sig /= max(1e-6, np.abs(sig).max())
        out[i] = sig / max(1e-6, np.abs(sig).max())
    return out.clip(-1, 1), labels.astype(np.int32)
