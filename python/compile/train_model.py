"""Build-time training of the tiny diffusion/flow models.

Trains the five pretrained models the experiments need (DESIGN.md §3):

  name            data    scheduler  parametrization  role in the paper
  --------------  ------  ---------  ---------------  --------------------
  img_fm_ot       images  fm_ot      velocity         ImageNet-64 FM-OT
  img_fmv_cs      images  cosine     velocity         ImageNet-64 FM/v-CS
  img_eps_vp      images  vp         eps              ImageNet-64 eps-VP
  img_fm_ot_big   images  fm_ot      velocity         ImageNet-128 FM-OT
  audio_fm_ot     audio   fm_ot      velocity         Audiobox speech FM

Losses follow App. E: CFM (eq. 56) for velocity models, noise prediction
(eq. 59) for the eps-VP model. Labels are dropped to the null class with
p_uncond = 0.2 so CFG works at sampling time. Optimizer: hand-rolled Adam
(optax is not in the image).

Usage: python -m compile.train_model [--steps N] [--out DIR]
"""

from __future__ import annotations

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model, schedulers

P_UNCOND = 0.2

# Per-model learning rates: the audio model is prone to late-training
# spikes at 1e-3 (see the nan guard in `train`), so it runs cooler.
MODEL_LR = {"audio_fm_ot": 3e-4}

MODEL_CONFIGS = {
    "img_fm_ot": model.ModelConfig(
        "img_fm_ot", data.IMG_DIM, data.NUM_CLASSES, scheduler="fm_ot", parametrization="velocity"
    ),
    "img_fmv_cs": model.ModelConfig(
        "img_fmv_cs", data.IMG_DIM, data.NUM_CLASSES, scheduler="cosine", parametrization="velocity"
    ),
    "img_eps_vp": model.ModelConfig(
        "img_eps_vp", data.IMG_DIM, data.NUM_CLASSES, scheduler="vp", parametrization="eps"
    ),
    "img_fm_ot_big": model.ModelConfig(
        "img_fm_ot_big",
        data.IMG_DIM,
        data.NUM_CLASSES,
        hidden=384,
        depth=6,
        scheduler="fm_ot",
        parametrization="velocity",
    ),
    "audio_fm_ot": model.ModelConfig(
        "audio_fm_ot",
        data.AUDIO_LEN,
        len(data.AUDIO_FAMILIES),
        scheduler="fm_ot",
        parametrization="velocity",
    ),
}


def adam_init(params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.zeros((), jnp.int32)}


def clip_global_norm(grads, max_norm=1.0):
    """Global-norm gradient clipping (the usual optax.clip_by_global_norm)."""
    total = jnp.sqrt(sum(jnp.sum(g**2) for g in grads.values()))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(total, 1e-12))
    return {k: g * scale for k, g in grads.items()}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    mh = {k: m[k] / (1 - b1**t) for k in params}
    vh = {k: v[k] / (1 - b2**t) for k in params}
    new = {k: params[k] - lr * mh[k] / (jnp.sqrt(vh[k]) + eps) for k in params}
    return new, {"m": m, "v": v, "t": t}


def _loss(cfg: model.ModelConfig, params, x1, labels, x0, t):
    """Per-batch training loss; t is a [B] vector of times."""
    sched = schedulers.SCHEDULERS[cfg.scheduler]
    a = sched.alpha(t)[:, None]
    s = sched.sigma(t)[:, None]
    xt = s * x0 + a * x1  # eq. 3 sample of p_t(x|x1)

    def f_one(xti, ti, li):
        return model.model_f(cfg, params, xti[None], ti, li[None], use_pallas=False)[0]

    f_val = jax.vmap(f_one)(xt, t, labels)
    if cfg.parametrization == "velocity":
        da = jax.vmap(sched.dalpha)(t)[:, None]
        ds = jax.vmap(sched.dsigma)(t)[:, None]
        target = ds * x0 + da * x1  # eq. 56
    elif cfg.parametrization == "eps":
        target = x0  # eq. 59 (x0 is the noise in the paper's convention)
    elif cfg.parametrization == "x":
        target = x1
    else:
        raise ValueError(cfg.parametrization)
    return jnp.mean((f_val - target) ** 2)


def train(cfg: model.ModelConfig, steps=3000, batch=256, lr=1e-3, seed=0, log_every=500):
    """Train one model; returns (params, final_loss)."""
    rng = np.random.default_rng(seed)
    params = model.init_params(cfg, seed=seed)
    opt = adam_init(params)
    make = data.make_audio if cfg.name.startswith("audio") else data.make_images

    # t sampled uniformly but clipped away from the eps-pred singularity
    # at alpha_t -> 0 (VP has alpha_0 ~ 6.6e-3; training there is
    # pointless and destabilizing).
    t_lo = 0.02 if cfg.parametrization == "eps" else 0.0

    loss_grad = jax.jit(jax.value_and_grad(functools.partial(_loss, cfg), argnums=0))
    step_fn = jax.jit(lambda p, o, g, lr: adam_update(p, clip_global_norm(g), o, lr))

    t_start = time.time()
    loss_val = float("nan")
    snapshot = (params, opt, 0.0)  # nan-divergence recovery point
    for it in range(steps):
        x1, labels = make(rng, batch)
        drop = rng.random(batch) < P_UNCOND
        labels = np.where(drop, cfg.null_class, labels).astype(np.int32)
        x0 = rng.standard_normal((batch, cfg.data_dim)).astype(np.float32)
        t = (t_lo + (1 - t_lo - 1e-3) * rng.random(batch)).astype(np.float32)
        cur_lr = lr * min(1.0, (it + 1) / 100) * (1.0 - 0.9 * it / steps)
        loss_val, grads = loss_grad(params, jnp.asarray(x1), jnp.asarray(labels), jnp.asarray(x0), jnp.asarray(t))
        if not np.isfinite(float(loss_val)):
            # Divergence guard: restore the last healthy snapshot and stop
            # (these tiny models occasionally spike late in training).
            params, opt, loss_val = snapshot
            print(f"  [{cfg.name}] step {it:5d} diverged (nan); restored snapshot and stopped")
            break
        params, opt = step_fn(params, opt, grads, cur_lr)
        if it % 100 == 0:
            snapshot = (params, opt, float(loss_val))
        if it % log_every == 0 or it == steps - 1:
            print(f"  [{cfg.name}] step {it:5d} loss {float(loss_val):.5f} ({time.time()-t_start:.0f}s)")
    return params, float(loss_val)


def save_params(params: dict, path: str):
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})


def load_params(path: str) -> dict:
    with np.load(path) as z:
        return {k: jnp.asarray(z[k]) for k in z.files}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=3000)
    ap.add_argument("--out", default="../artifacts/weights")
    ap.add_argument("--models", nargs="*", default=list(MODEL_CONFIGS))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for name in args.models:
        cfg = MODEL_CONFIGS[name]
        path = os.path.join(args.out, f"{name}.npz")
        if os.path.exists(path):
            print(f"[skip] {path} exists")
            continue
        print(f"[train] {name}: {cfg}")
        params, loss = train(cfg, steps=args.steps, lr=MODEL_LR.get(name, 1e-3))
        save_params(params, path)
        print(f"[done] {name} loss={loss:.5f} params={model.param_count(params):,} -> {path}")


if __name__ == "__main__":
    main()
