"""Progressive Distillation baseline (Salimans & Ho 2022) for Table 3.

The paper compares BNS against PD on FID / training forwards / training
set size / parameter count. PD is *model* distillation: starting from the
pretrained teacher, each phase trains a student (initialized at the
teacher) so one student Euler step matches two teacher Euler steps; the
student then becomes the next phase's teacher and the step count halves.

We implement the velocity-parametrization variant natural for FM-OT: for
x_t on the path and a student grid time t with step h, the target is the
average teacher velocity over [t, t + h]:

    x''      = two teacher Euler half-steps from (t, x_t)
    v_target = (x'' - x_t) / h

Forwards accounting follows App. D.4: every model evaluation with batch 1
counts as one forward; an update with batch B costs B * (2 teacher + 1
student) forwards (the student backward pass is not counted, as in the
paper).

Output: distilled student weights at NFE 4 / 8 / 16 (+ metadata), which
aot.py exports as HLO artifacts so the rust bench regenerates Table 3.
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model, schedulers
from .train_model import P_UNCOND, adam_init, adam_update


@dataclasses.dataclass
class PDResult:
    students: dict  # nfe -> params
    forwards: dict  # nfe -> cumulative training forwards to reach it
    updates: dict  # nfe -> cumulative parameter updates


def distill(
    cfg: model.ModelConfig,
    teacher_params: dict,
    *,
    start_steps=32,
    target_steps=(16, 8, 4),
    updates_per_phase=800,
    batch=64,
    lr=3e-4,
    seed=0,
    log=print,
) -> PDResult:
    """Run PD phases start_steps -> ... -> min(target_steps)."""
    assert cfg.parametrization == "velocity", "PD implemented for velocity models"
    rng = np.random.default_rng(seed)
    sched = schedulers.SCHEDULERS[cfg.scheduler]
    make = data.make_audio if cfg.name.startswith("audio") else data.make_images

    def vel(params, x, t, labels):
        return model.velocity(cfg, params, x, t, labels, use_pallas=False)

    def loss_fn(student, teacher, x_t, t, h, labels):
        # two teacher half-steps
        u1 = vel(teacher, x_t, t, labels)
        x_mid = x_t + 0.5 * h * u1
        u2 = vel(teacher, x_mid, t + 0.5 * h, labels)
        x_end = x_mid + 0.5 * h * u2
        v_target = (x_end - x_t) / h
        v_pred = vel(student, x_t, t, labels)
        return jnp.mean((v_pred - jax.lax.stop_gradient(v_target)) ** 2)

    loss_grad = jax.jit(jax.value_and_grad(loss_fn))
    update = jax.jit(lambda p, o, g, lr: adam_update(p, g, o, lr))

    teacher = teacher_params
    students, forwards_at, updates_at = {}, {}, {}
    total_forwards, total_updates = 0, 0
    steps = start_steps
    while steps > min(target_steps):
        steps //= 2
        student = jax.tree_util.tree_map(lambda x: x, teacher)
        opt = adam_init(student)
        t_start = time.time()
        for it in range(updates_per_phase):
            x1, labels = make(rng, batch)
            drop = rng.random(batch) < P_UNCOND
            labels = np.where(drop, cfg.null_class, labels).astype(np.int32)
            x0 = rng.standard_normal((batch, cfg.data_dim)).astype(np.float32)
            # x_t on the student grid
            k = rng.integers(0, steps)
            t = np.float32(k / steps)
            h = np.float32(1.0 / steps)
            a, s = float(sched.alpha(t)), float(sched.sigma(t))
            x_t = s * x0 + a * x1
            loss, grads = loss_grad(
                student, teacher, jnp.asarray(x_t), t, h, jnp.asarray(labels)
            )
            student, opt = update(student, opt, grads, lr)
            total_forwards += batch * 3  # 2 teacher + 1 student (App. D.4)
            total_updates += 1
        log(
            f"    [pd] phase ->{steps} steps, loss {float(loss):.5f} "
            f"({time.time()-t_start:.0f}s, {total_forwards/1e6:.2f}m forwards)"
        )
        teacher = student
        if steps in target_steps:
            students[steps] = student
            forwards_at[steps] = total_forwards
            updates_at[steps] = total_updates
    return PDResult(students, forwards_at, updates_at)
