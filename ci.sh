#!/usr/bin/env bash
# CI for the rust crate: format check, lint, then the tier-1 gate.
#
#   ./ci.sh             # lints advisory, tier-1 (build + test) is the gate
#   STRICT=1 ./ci.sh    # lints are also gating (fmt --check, clippy -D warnings)
#
# The tier-1 commands (`cargo build --release && cargo test -q`, then
# the repo-native `cargo run --release --bin bns_lint` pass, DESIGN.md
# §10) are always hard failures. fmt/clippy run and report, but only
# fail the script under STRICT=1 — toolchain components (rustfmt/clippy)
# may be absent in minimal images, and style drift must not mask a
# broken build. bns_lint is built from this crate by the tier-1 build,
# so it has no such availability excuse and gates unconditionally.

set -uo pipefail
cd "$(dirname "$0")/rust"

fail=0
lint_fail=0

step() {
  echo
  echo "==> $*"
}

step "cargo fmt --check"
if cargo fmt --version >/dev/null 2>&1; then
  cargo fmt --all -- --check || lint_fail=1
else
  echo "rustfmt unavailable; skipping"
fi

step "cargo clippy -- -D warnings"
if cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings || lint_fail=1
else
  echo "clippy unavailable; skipping"
fi

# Documentation gate: the coordinator module is #![warn(missing_docs)],
# so undocumented public serving API surfaces here (and rustdoc reports
# broken intra-doc links). Advisory unless STRICT=1 (shares the lint
# gate) — rustdoc may be absent in minimal images.
step "cargo doc --no-deps (rustdoc + missing_docs, advisory)"
if cargo doc --version >/dev/null 2>&1; then
  doc_log=$(mktemp)
  if cargo doc --no-deps --quiet 2>"$doc_log"; then
    if grep -q "^warning" "$doc_log"; then
      echo "cargo doc emitted warnings:"
      cat "$doc_log"
      lint_fail=1
    else
      echo "docs clean"
    fi
  else
    echo "cargo doc failed:"
    cat "$doc_log"
    lint_fail=1
  fi
  rm -f "$doc_log"
else
  echo "rustdoc unavailable; skipping"
fi

step "tier-1: cargo build --release"
cargo build --release || fail=1

step "tier-1: cargo test -q"
cargo test -q || fail=1

# Repo-native static analysis (DESIGN.md §10): panic-freedom of the
# serving plane, hot-path allocation bans, channel/lock discipline, and
# docs drift. Built by the tier-1 build above from this crate, so unlike
# fmt/clippy it can never be "unavailable; skipping" — it is GATING.
# The binary prints per-rule counts; STRICT=1 additionally pins the
# accepted-pragma count to the checked-in budget so the allowlist can
# only shrink (or be raised as an explicit, reviewed diff).
step "tier-1: cargo run --release --bin bns_lint (gating, DESIGN.md §10)"
if [ "${STRICT:-0}" = "1" ]; then
  budget=$(cat src/analysis/pragma_budget)
  cargo run --release --quiet --bin bns_lint -- --max-pragmas "$budget" || fail=1
else
  cargo run --release --quiet --bin bns_lint || fail=1
fi

# Perf trajectory: the serve_load bench runs on the stub backend (no
# artifacts needed) and writes machine-readable BENCH_serve.json at the
# repo root — evals/s, batch-row means, latency percentiles, and the
# multi-lane worker-scaling ratio, tracked PR-over-PR. Advisory unless
# STRICT=1 (shares the lint gate).
step "perf trajectory: cargo bench --bench serve_load -> BENCH_serve.json"
if BENCH_SERVE_OUT="../BENCH_serve.json" cargo bench --bench serve_load; then
  echo "wrote $(cd .. && pwd)/BENCH_serve.json"
  # fault-recovery gate: the bench's third phase wedges the only device
  # lane and records time back to service (DESIGN.md §11). The section
  # must exist with a respawn count >= 1 — a dropped phase or a
  # supervisor that never respawns would otherwise pass silently.
  echo "fault recovery: $(grep -o '"time_to_recover_ms":[0-9.eE+-]*' ../BENCH_serve.json | tr '\n' ' ')"
  echo "fault recovery: $(grep -o '"lane_respawns":[0-9.eE+-]*' ../BENCH_serve.json | tr '\n' ' ')"
  echo "fault recovery: $(grep -o '"exec_retries":[0-9.eE+-]*' ../BENCH_serve.json | tr '\n' ' ')"
  if ! grep -q '"fault_recovery":' ../BENCH_serve.json; then
    echo "WARN: BENCH_serve.json has no fault_recovery section (recovery gate vacuous)"
    lint_fail=1
  elif ! grep -o '"lane_respawns":[0-9.eE+-]*' ../BENCH_serve.json \
      | cut -d: -f2 | grep -qv '^0$'; then
    echo "WARN: fault_recovery ran but no lane respawn was recorded (supervisor inert)"
    lint_fail=1
  fi
  # tracing-plane gate: the fourth phase compares evals/s with the span
  # recorder on vs off (DESIGN.md §12). The section must exist, and the
  # measured throughput overhead must stay <= 3% — the 0-alloc checks
  # are hard asserts inside the bench itself, so they fail the bench run
  # rather than this grep.
  echo "trace overhead: $(grep -o '"overhead_pct":[0-9.eE+-]*' ../BENCH_serve.json | tr '\n' ' ')"
  if ! grep -q '"trace_overhead":' ../BENCH_serve.json; then
    echo "WARN: BENCH_serve.json has no trace_overhead section (tracing gate vacuous)"
    lint_fail=1
  else
    overhead=$(grep -o '"overhead_pct":[0-9.eE+-]*' ../BENCH_serve.json | head -n1 | cut -d: -f2)
    if ! awk -v o="${overhead:-100}" 'BEGIN { exit !(o <= 3.0) }'; then
      echo "WARN: tracing overhead ${overhead}% exceeds the 3% budget"
      lint_fail=1
    fi
  fi
  # fleet-churn gate: the fifth phase drives a multi-model shard fleet
  # through hot unload/load cycles (DESIGN.md §14). The section must
  # exist, every churned sample must stay bit-identical to a quiescent
  # engine, and no admitted request may be lost across reloads.
  echo "fleet churn: $(grep -o '"reload_cycles":[0-9.eE+-]*' ../BENCH_serve.json | tr '\n' ' ')"
  echo "fleet churn: $(grep -o '"ttfs_after_load_mean_ms":[0-9.eE+-]*' ../BENCH_serve.json | tr '\n' ' ')"
  echo "fleet churn: $(grep -o '"lost_requests":[0-9.eE+-]*' ../BENCH_serve.json | tr '\n' ' ')"
  if ! grep -q '"fleet_churn":' ../BENCH_serve.json; then
    echo "WARN: BENCH_serve.json has no fleet_churn section (fleet gate vacuous)"
    lint_fail=1
  else
    if ! grep -q '"fleet_bit_identical":true' ../BENCH_serve.json; then
      echo "WARN: fleet churn bit-identity gate missing or false"
      lint_fail=1
    fi
    if ! grep -o '"lost_requests":[0-9.eE+-]*' ../BENCH_serve.json \
        | cut -d: -f2 | grep -q '^0$'; then
      echo "WARN: fleet churn lost requests (expected 0)"
      lint_fail=1
    fi
  fi
else
  echo "serve_load bench failed (perf trajectory not updated)"
  lint_fail=1
fi

# Distillation trajectory: a smoke-sized run of the first-order trainer
# on the stub backend, emitting BENCH_distill.json at the repo root —
# PSNR-vs-NFE for rust-distilled BNS vs stationary baselines, trainer
# iters/s, NFE-to-target-PSNR, and the wavefront grad-step microbench
# (grad_steps_per_sec, jvp_round_trips, allocs_per_step), tracked
# PR-over-PR. Advisory unless STRICT=1 (shares the lint gate); STRICT=1
# additionally gates the steady-state hot-loop allocation count at 0.
step "distill trajectory: cargo bench --bench distill_bench -> BENCH_distill.json"
if BENCH_DISTILL_OUT="../BENCH_distill.json" DISTILL_BENCH_ITERS="${DISTILL_BENCH_ITERS:-80}" \
    cargo bench --bench distill_bench; then
  echo "wrote $(cd .. && pwd)/BENCH_distill.json"
  # surface the wavefront gradient-engine numbers
  echo "grad engine: $(grep -o '"grad_steps_per_sec":[0-9.eE+-]*' ../BENCH_distill.json | tr '\n' ' ')"
  echo "grad engine: $(grep -o '"jvp_round_trips":[0-9]*' ../BENCH_distill.json | tr '\n' ' ')"
  echo "grad engine: $(grep -o '"allocs_per_step":[0-9.eE+-]*' ../BENCH_distill.json | tr '\n' ' ')"
  # zero-allocation gate: every steady-state grad step must report 0 —
  # and at least one measurement must exist, so a renamed/dropped field
  # can never make the gate pass vacuously
  n_allocs=$(grep -c '"allocs_per_step":' ../BENCH_distill.json || true)
  bad_allocs=$(grep -o '"allocs_per_step":[0-9.eE+-]*' ../BENCH_distill.json \
    | cut -d: -f2 | grep -cv '^0$' || true)
  if [ "${n_allocs:-0}" -eq 0 ]; then
    echo "WARN: BENCH_distill.json has no allocs_per_step measurements (gate vacuous)"
    lint_fail=1
  elif [ "${bad_allocs:-0}" -ne 0 ]; then
    echo "WARN: $bad_allocs grad-step config(s) allocate in the hot loop (expected 0)"
    lint_fail=1
  fi
else
  echo "distill_bench failed (distill trajectory not updated)"
  lint_fail=1
fi

# Kernel roofline: the perf_layers bench times the CPU kernel layer
# (tiled GEMM, fused resblock, NS combine, pooled MLP evals) and writes
# BENCH_perf.json at the repo root — per-kernel GFLOP/s / GB/s against
# the DESIGN.md §13 cost model, plus three machine-checked gates:
# fused resblock >= 4x its naive scalar oracle at D=H=256 batch=64,
# 0 allocs per steady-state MLP eval, and bit-identity across pool
# widths {1,2,4} (the last is also a hard assert inside the bench).
# Advisory unless STRICT=1 (shares the lint gate).
step "kernel roofline: cargo bench --bench perf_layers -> BENCH_perf.json"
if BENCH_PERF_OUT="../BENCH_perf.json" cargo bench --bench perf_layers; then
  echo "wrote $(cd .. && pwd)/BENCH_perf.json"
  echo "roofline gates: $(grep -o '"fused_speedup_vs_naive":[0-9.eE+-]*' ../BENCH_perf.json | tr '\n' ' ')"
  echo "roofline gates: $(grep -o '"mlp_allocs_per_eval":[0-9.eE+-]*' ../BENCH_perf.json | tr '\n' ' ')"
  echo "roofline gates: $(grep -o '"pool_bit_identical":\(true\|false\)' ../BENCH_perf.json | tr '\n' ' ')"
  # vacuity guards: the roofline section and every gate field must exist
  if ! grep -q '"roofline":' ../BENCH_perf.json; then
    echo "WARN: BENCH_perf.json has no roofline section (kernel gates vacuous)"
    lint_fail=1
  else
    speedup=$(grep -o '"fused_speedup_vs_naive":[0-9.eE+-]*' ../BENCH_perf.json | head -n1 | cut -d: -f2)
    if ! awk -v s="${speedup:-0}" 'BEGIN { exit !(s >= 4.0) }'; then
      echo "WARN: fused resblock speedup ${speedup:-missing}x below the 4x gate"
      lint_fail=1
    fi
    allocs=$(grep -o '"mlp_allocs_per_eval":[0-9.eE+-]*' ../BENCH_perf.json | head -n1 | cut -d: -f2)
    if [ "${allocs:-missing}" != "0" ]; then
      echo "WARN: ${allocs:-missing} allocs per steady-state MLP eval (expected 0)"
      lint_fail=1
    fi
    if ! grep -q '"pool_bit_identical":true' ../BENCH_perf.json; then
      echo "WARN: pool bit-identity gate missing or false"
      lint_fail=1
    fi
  fi
else
  echo "perf_layers bench failed (kernel roofline not updated)"
  lint_fail=1
fi

echo
if [ "$fail" -ne 0 ]; then
  echo "CI FAILED (tier-1)"
  exit 1
fi
if [ "$lint_fail" -ne 0 ]; then
  if [ "${STRICT:-0}" = "1" ]; then
    echo "CI FAILED (advisory steps, STRICT=1)"
    exit 1
  fi
  echo "CI PASSED (tier-1 green; advisory steps (lints/bench) reported issues — rerun with STRICT=1 to gate)"
  exit 0
fi
echo "CI PASSED"
