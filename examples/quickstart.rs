//! Quickstart: load the artifact store, start the engine, sample with the
//! BNS-routed solver, and compare against baselines + ground truth.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use bns_serve::coordinator::{Engine, EngineConfig, SolverSpec};
use bns_serve::runtime::{ArtifactStore, Runtime};
use bns_serve::util::stats::batch_psnr;

fn main() -> anyhow::Result<()> {
    let dir = bns_serve::default_artifacts_dir();
    let store = Arc::new(ArtifactStore::load(&dir)?);
    let rt = Arc::new(Runtime::cpu()?);
    println!("platform: {}", rt.platform());
    println!("models:   {:?}", store.models.keys().collect::<Vec<_>>());

    let engine = Engine::start(store.clone(), rt, EngineConfig::default())?;

    // 8 samples of the class-conditional image model, classes 0..7.
    let model = "img_fm_ot";
    let labels: Vec<i32> = (0..8).collect();
    let seed = 7;

    // Ground truth (adaptive RK45, the paper's reference sampler)
    let gt = engine.sample_blocking(model, labels.clone(), 0.0, SolverSpec::GroundTruth, seed)?;
    println!("\nGT via {}: NFE = {}", gt.solver_used, gt.nfe);

    // BNS at NFE 8 (auto-routing picks the distilled artifact)
    for (label, spec) in [
        ("auto (BNS)", SolverSpec::Auto { nfe: 8 }),
        ("midpoint", SolverSpec::Baseline { name: "midpoint".into(), nfe: 8 }),
        ("euler", SolverSpec::Baseline { name: "euler".into(), nfe: 8 }),
    ] {
        let out = engine.sample_blocking(model, labels.clone(), 0.0, spec, seed)?;
        println!(
            "{:<12} nfe={:<3} psnr={:>6.2} dB  (solver: {}, exec {} us)",
            label,
            out.nfe,
            batch_psnr(&out.samples, &gt.samples, out.dim),
            out.solver_used,
            out.exec_us,
        );
    }

    println!("\nmetrics: {}", engine.metrics.snapshot_json().to_string());
    engine.shutdown();
    Ok(())
}
