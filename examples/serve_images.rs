//! End-to-end serving driver (the E2E validation run of EXPERIMENTS.md):
//! starts the engine *and* the TCP front-end, drives a mixed workload of
//! concurrent clients over the real socket protocol, verifies sample
//! fidelity against ground truth, and reports latency/throughput.
//!
//!     cargo run --release --example serve_images

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

use bns_serve::coordinator::{server, Engine, EngineConfig};
use bns_serve::runtime::{ArtifactStore, Runtime};
use bns_serve::util::json::Json;
use bns_serve::util::stats::{batch_psnr, Summary};

const ADDR: &str = "127.0.0.1:17878";
const CLIENTS: usize = 6;
const REQS_PER_CLIENT: usize = 8;

fn rpc(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &Json) -> anyhow::Result<Json> {
    stream.write_all(req.to_string().as_bytes())?;
    stream.write_all(b"\n")?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Json::parse(&line).map_err(|e| anyhow::anyhow!("{e}"))
}

fn main() -> anyhow::Result<()> {
    let dir = bns_serve::default_artifacts_dir();
    let store = Arc::new(ArtifactStore::load(&dir)?);
    let rt = Arc::new(Runtime::cpu()?);
    let engine = Arc::new(Engine::start(store.clone(), rt, EngineConfig::default())?);

    // server in a background thread
    {
        let engine = engine.clone();
        let store = store.clone();
        std::thread::spawn(move || {
            let _ = server::serve(ADDR, engine, store);
        });
    }
    std::thread::sleep(std::time::Duration::from_millis(300));

    // one reference client computes GT once for fidelity checking
    let mut s = TcpStream::connect(ADDR)?;
    let mut r = BufReader::new(s.try_clone()?);
    let gt = rpc(&mut s, &mut r, &Json::obj(vec![
        ("op", Json::Str("sample".into())),
        ("model", Json::Str("img_fm_ot".into())),
        ("labels", Json::Arr((0..4).map(|i| Json::Num(i as f64)).collect())),
        ("solver", Json::Str("gt".into())),
        ("seed", Json::Num(11.0)),
    ]))?;
    anyhow::ensure!(gt.get("ok").as_bool() == Some(true), "GT failed: {}", gt.to_string());
    let gt_samples = gt.get("samples").as_f32_vec().unwrap();
    let dim = gt.get("dim").as_usize().unwrap();
    println!("GT over TCP: nfe={}", gt.get("nfe").as_f64().unwrap());

    // fidelity check: BNS nfe=8 over the wire, same seed
    let bns = rpc(&mut s, &mut r, &Json::obj(vec![
        ("op", Json::Str("sample".into())),
        ("model", Json::Str("img_fm_ot".into())),
        ("labels", Json::Arr((0..4).map(|i| Json::Num(i as f64)).collect())),
        ("solver", Json::Str("auto".into())),
        ("nfe", Json::Num(8.0)),
        ("seed", Json::Num(11.0)),
    ]))?;
    let bns_samples = bns.get("samples").as_f32_vec().unwrap();
    println!(
        "BNS over TCP: solver={} psnr={:.2} dB",
        bns.get("solver_used").as_str().unwrap_or("?"),
        batch_psnr(&bns_samples, &gt_samples, dim)
    );

    // concurrent mixed workload
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
            let mut s = TcpStream::connect(ADDR)?;
            let mut r = BufReader::new(s.try_clone()?);
            let mut lat = Vec::new();
            for i in 0..REQS_PER_CLIENT {
                let nfe = [8.0, 12.0, 16.0][(c + i) % 3];
                let t = Instant::now();
                let resp = rpc(&mut s, &mut r, &Json::obj(vec![
                    ("op", Json::Str("sample".into())),
                    ("model", Json::Str("img_fm_ot".into())),
                    (
                        "labels",
                        Json::Arr((0..4).map(|k| Json::Num(((c + k + i) % 10) as f64)).collect()),
                    ),
                    ("solver", Json::Str("auto".into())),
                    ("nfe", Json::Num(nfe)),
                    ("seed", Json::Num((c * 100 + i) as f64)),
                ]))?;
                anyhow::ensure!(resp.get("ok").as_bool() == Some(true), "req failed");
                lat.push(t.elapsed().as_secs_f64() * 1000.0);
            }
            Ok(lat)
        }));
    }
    let mut lat = Summary::new();
    let mut all = Vec::new();
    for h in handles {
        for v in h.join().unwrap()? {
            lat.add(v);
            all.push(v);
        }
    }
    all.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let wall = t0.elapsed().as_secs_f64();
    let total = (CLIENTS * REQS_PER_CLIENT) as f64;
    println!("\n=== E2E serving run ===");
    println!("requests: {total:.0} over {wall:.2}s -> {:.1} req/s ({:.1} samples/s)", total / wall, total * 4.0 / wall);
    println!(
        "client-observed latency: mean {:.1} ms, p50 {:.1} ms, p95 {:.1} ms, max {:.1} ms",
        lat.mean,
        all[all.len() / 2],
        all[(all.len() as f64 * 0.95) as usize],
        lat.max
    );
    println!("server metrics: {}", engine.metrics.snapshot_json().to_string());
    Ok(())
}
