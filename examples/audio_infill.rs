//! Audio scenario (the paper's §5.4 analogue): generate waveforms from
//! the audio FM model for each signal family with the BNS solver at a
//! low NFE, compare SNR against the RK45 reference, and dump waveforms
//! as CSV for plotting.
//!
//!     cargo run --release --example audio_infill

use bns_serve::bench_util::{Bench, Table};
use bns_serve::coordinator::router::distilled;
use bns_serve::solver::{baseline, Solver};
use bns_serve::util::stats::snr_db;

const MODEL: &str = "audio_fm_ot";
const FAMILIES: [&str; 4] = ["harmonic", "am", "chirp", "noiseband"];
const NFE: usize = 12;

fn main() -> anyhow::Result<()> {
    let b = Bench::init()?;
    let info = b.store.model(MODEL)?.clone();
    let bns = distilled(&b.store, MODEL, 0.0, "bns", NFE)?;
    let midpoint = baseline("midpoint", NFE, info.scheduler)?;

    std::fs::create_dir_all("results")?;
    let mut table = Table::new(&["family", "BNS SNR(dB)", "Midpoint SNR(dB)", "csv"]);
    for (fam, fam_name) in FAMILIES.iter().enumerate() {
        let mut rng = bns_serve::util::rng::Pcg32::seeded(100 + fam as u64);
        let x0 = rng.normal_vec(4 * info.dim);
        let labels = vec![fam as i32; 4];
        let field = b.field(&info, labels.clone(), 0.0)?;
        let (gt, _) = b.ground_truth(&field, &x0)?;
        let out_bns = bns.sample(&field, &x0)?;
        let out_mid = midpoint.sample(&field, &x0)?;

        // CSV: sample 0 of this family, three columns
        let path = format!("results/audio_{fam_name}.csv");
        let mut csv = String::from("t,gt,bns,midpoint\n");
        for i in 0..info.dim {
            csv.push_str(&format!(
                "{},{},{},{}\n",
                i, gt[i], out_bns[i], out_mid[i]
            ));
        }
        std::fs::write(&path, csv)?;

        table.row(vec![
            fam_name.to_string(),
            format!("{:.2}", snr_db(&out_bns, &gt)),
            format!("{:.2}", snr_db(&out_mid, &gt)),
            path,
        ]);
    }
    println!("=== audio generation @ NFE {NFE}: BNS vs Midpoint, SNR vs RK45 GT ===");
    table.print();
    Ok(())
}
