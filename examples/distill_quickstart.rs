//! Distill → serve, end to end in-process (library-API twin of the CLI
//! quickstart in README.md):
//!
//!     make artifacts && cargo run --release --example distill_quickstart
//!
//! 1. train an NFE=8 BNS solver against the deployed model field with
//!    the first-order trainer (analytic gradients, RK45 teacher pairs),
//! 2. register the artifact (full SolverMeta provenance) in the store,
//! 3. reload and sample — the BNS-first auto router now picks it.

use std::sync::Arc;

use bns_serve::bench_util::add_solver_artifact;
use bns_serve::coordinator::{Engine, EngineConfig, SolverSpec};
use bns_serve::distill::{train, ConditionedModel, TrainConfig};
use bns_serve::runtime::{ArtifactStore, Runtime};

fn main() -> anyhow::Result<()> {
    let dir = bns_serve::default_artifacts_dir();
    let store = Arc::new(ArtifactStore::load(&dir)?);
    let rt = Arc::new(Runtime::with_lanes(2)?);
    let model = "img_fm_ot";
    let nfe = 8;
    let info = store.model(model)?.clone();

    // 1. distill: teacher pairs + minibatches are conditioned per row;
    //    `threads` fans RK45 teacher generation AND the wavefront
    //    gradient chunks (DESIGN.md §8), and `replicated` compiles the
    //    model once per device lane so those chunks drive both lanes —
    //    results are bit-identical for any threads/lanes value
    let cfg = TrainConfig { iters: 300, threads: 4, init: "midpoint".into(), ..Default::default() };
    let labels: Vec<i32> =
        (0..cfg.pairs + cfg.val_pairs).map(|i| (i % info.num_classes) as i32).collect();
    let src = ConditionedModel::replicated(&rt, &info, labels, 0.0)?;
    let (solver, report) = train(&src, info.dim, nfe, &cfg)?;
    println!(
        "distilled nfe={nfe}: val psnr {:.2} -> {:.2} dB ({} forwards)",
        report.init_val_psnr, report.final_val_psnr, report.forwards
    );

    // 2. emit + register: loads like any build-time BNS artifact
    let name = format!("{model}_w0_nfe{nfe}_bns_rs");
    add_solver_artifact(&dir, &name, &solver, &report.meta(model, 0.0))?;

    // 3. serve with it
    let store = Arc::new(ArtifactStore::load(&dir)?);
    let engine = Engine::start(store, rt, EngineConfig::default())?;
    let out = engine.sample_blocking(model, vec![0, 1, 2, 3], 0.0, SolverSpec::Auto { nfe }, 7)?;
    println!("auto-routed to '{}' (nfe {}, {} forwards)", out.solver_used, out.nfe, out.forwards);
    engine.shutdown();
    Ok(())
}
