//! Solver zoo (qualitative Figs. 1/5/7-10 analogue): run the SAME noise
//! through every solver family and dump per-solver 8x8 RGB sample grids
//! as PPM images plus a PSNR table, so the fidelity difference is
//! visible, not just numeric.
//!
//!     cargo run --release --example solver_zoo
//! writes results/zoo_<solver>.ppm

use bns_serve::bench_util::{Bench, Table};
use bns_serve::coordinator::router::distilled;
use bns_serve::solver::{baseline, Solver};
use bns_serve::util::stats::batch_psnr;

const MODEL: &str = "img_fm_ot";
const N: usize = 10; // one sample per class
const NFE: usize = 8;

/// Write a horizontal strip of n 8x8 RGB images (values in [-1, 1]).
fn write_ppm(path: &str, rows: &[f32], n: usize) -> anyhow::Result<()> {
    let (side, ch) = (8usize, 3usize);
    let scale = 4usize; // upscale for visibility
    let (w, h) = (n * side * scale + (n - 1) * 2, side * scale);
    let mut img = vec![0u8; w * h * 3];
    for i in 0..n {
        let sample = &rows[i * side * side * ch..(i + 1) * side * side * ch];
        for y in 0..side * scale {
            for x in 0..side * scale {
                let (sy, sx) = (y / scale, x / scale);
                let px = i * (side * scale + 2) + x;
                if px >= w {
                    continue;
                }
                for c in 0..3 {
                    let v = sample[(sy * side + sx) * ch + c];
                    let b = (((v + 1.0) * 0.5).clamp(0.0, 1.0) * 255.0) as u8;
                    img[(y * w + px) * 3 + c] = b;
                }
            }
        }
    }
    let mut out = format!("P6\n{w} {h}\n255\n").into_bytes();
    out.extend_from_slice(&img);
    std::fs::create_dir_all("results")?;
    std::fs::write(path, out)?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let b = Bench::init()?;
    let info = b.store.model(MODEL)?.clone();
    let mut rng = bns_serve::util::rng::Pcg32::seeded(4242);
    let x0 = rng.normal_vec(N * info.dim);
    let labels: Vec<i32> = (0..N as i32).collect();
    let field = b.field(&info, labels.clone(), 0.0)?;

    let (gt, gt_nfe) = b.ground_truth(&field, &x0)?;
    write_ppm("results/zoo_gt_rk45.ppm", &gt, N)?;
    println!("GT (rk45, NFE={gt_nfe}) -> results/zoo_gt_rk45.ppm");

    let mut zoo: Vec<(String, Box<dyn Solver>)> = vec![
        ("bns".into(), Box::new(distilled(&b.store, MODEL, 0.0, "bns", NFE)?)),
        ("midpoint".into(), baseline("midpoint", NFE, info.scheduler)?),
        ("euler".into(), baseline("euler", NFE, info.scheduler)?),
        ("dpmpp2m".into(), baseline("dpmpp2m", NFE, info.scheduler)?),
        ("ab2".into(), baseline("ab2", NFE, info.scheduler)?),
        ("rk4".into(), baseline("rk4", NFE, info.scheduler)?),
        ("heun".into(), baseline("heun", NFE, info.scheduler)?),
    ];
    if let Ok(bst) = distilled(&b.store, MODEL, 0.0, "bst", NFE) {
        zoo.insert(1, ("bst".into(), Box::new(bst)));
    }

    let mut table = Table::new(&["solver", "NFE", "PSNR(dB)", "image"]);
    for (name, solver) in &zoo {
        let out = solver.sample(&field, &x0)?;
        let path = format!("results/zoo_{name}.ppm");
        write_ppm(&path, &out, N)?;
        table.row(vec![
            name.clone(),
            NFE.to_string(),
            format!("{:.2}", batch_psnr(&out, &gt, info.dim)),
            path,
        ]);
    }
    println!("\n=== same noise, NFE = {NFE}, vs GT ===");
    table.print();
    Ok(())
}
