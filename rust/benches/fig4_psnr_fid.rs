//! Figure 4 + Table 4: PSNR vs NFE and FID vs NFE on class-conditional
//! image models, BNS vs BST / DPM++ / DDIM / RK-Midpoint / RK-Euler.
//!
//! Per model (ImageNet-64 stand-ins: img_fm_ot / img_fmv_cs / img_eps_vp
//! and the ImageNet-128 stand-in img_fm_ot_big), for every NFE with a
//! distilled BNS artifact:
//!   * PSNR of each solver's output vs the RK45 ground truth on the same
//!     noise (paper metric, eq. 13's evaluation form), and
//!   * FD-synth of each solver's sample distribution vs the dataset
//!     reference, plus the GT sampler's FD ("GT-FID" line).
//!
//! Expected shape (paper §5.1): PSNR order BNS > BST > DPM++ > Midpoint/
//! Euler; BNS FD approaches GT-FD by NFE ~16.

use bns_serve::bench_util::{write_results, Bench, Table};
use bns_serve::coordinator::router::distilled;
use bns_serve::solver::{baseline, Solver};
use bns_serve::util::json::Json;
use bns_serve::util::stats::batch_psnr;

const PSNR_EVAL_N: usize = 48;
const FD_EVAL_N: usize = 512;

fn main() -> anyhow::Result<()> {
    let b = Bench::init()?;
    let models: Vec<(&str, f64)> = vec![
        ("img_fm_ot", 0.0),
        ("img_fmv_cs", 0.0),
        ("img_eps_vp", 0.0),
        ("img_fm_ot_big", 0.5),
    ];
    let mut results = Vec::new();

    for (mname, w) in models {
        let info = b.store.model(mname)?.clone();
        let nfes: Vec<usize> = b
            .store
            .solvers_for(mname, w, "bns")
            .iter()
            .map(|s| s.solver.nfe())
            .collect();
        if nfes.is_empty() {
            eprintln!("[fig4] no BNS artifacts for {mname} w={w}; skipping");
            continue;
        }

        // PSNR eval set + ground truth (fixed noise, same for every solver)
        let (x0, labels) = b.eval_set(&info, PSNR_EVAL_N, 1234);
        let field = b.field(&info, labels.clone(), w as f32)?;
        let (gt, gt_nfe) = b.ground_truth(&field, &x0)?;
        // GT sampler distribution + its FD (the "GT-FID" row)
        let is_image = info.data == "images";
        let (gt_fd, gt_dist) = if is_image {
            let (dist, _) = b.generate_gt(&info, w as f32, FD_EVAL_N, 99)?;
            (b.store.fd.fd_to_reference(&dist), Some(dist))
        } else {
            (f64::NAN, None)
        };
        println!("\n=== {mname} (w={w}) — GT rk45 nfe={gt_nfe}, GT-FD={gt_fd:.3} ===");

        let mut table = Table::new(&["solver", "NFE", "PSNR(dB)", "FD-synth"]);
        for &nfe in &nfes {
            let mut solvers: Vec<(String, Box<dyn Solver>)> = Vec::new();
            solvers.push(("bns".into(), Box::new(distilled(&b.store, mname, w, "bns", nfe)?)));
            if let Ok(s) = distilled(&b.store, mname, w, "bst", nfe) {
                solvers.push(("bst".into(), Box::new(s)));
            }
            solvers.push(("dpmpp2m".into(), baseline("dpmpp2m", nfe, info.scheduler)?));
            if info.scheduler.alpha(0.0) > 1e-6 {
                solvers.push(("ddim".into(), baseline("ddim", nfe, info.scheduler)?));
            }
            if nfe % 2 == 0 {
                solvers.push(("midpoint".into(), baseline("midpoint", nfe, info.scheduler)?));
            }
            solvers.push(("euler".into(), baseline("euler", nfe, info.scheduler)?));

            for (label, solver) in &solvers {
                let out = solver.sample(&field, &x0)?;
                let psnr = batch_psnr(&out, &gt, info.dim);
                let fd = if is_image {
                    let dist = b.generate(&info, solver.as_ref(), w as f32, FD_EVAL_N, 99)?;
                    b.store.fd.fd_to_reference(&dist)
                } else {
                    f64::NAN
                };
                table.row(vec![
                    label.clone(),
                    nfe.to_string(),
                    format!("{psnr:.2}"),
                    format!("{fd:.3}"),
                ]);
                results.push(Json::obj(vec![
                    ("model", Json::Str(mname.into())),
                    ("guidance", Json::Num(w)),
                    ("solver", Json::Str(label.clone())),
                    ("nfe", Json::Num(nfe as f64)),
                    ("psnr", Json::Num(psnr)),
                    ("fd", Json::Num(fd)),
                    ("gt_fd", Json::Num(gt_fd)),
                    ("gt_nfe", Json::Num(gt_nfe as f64)),
                ]));
            }
        }
        table.print();
        drop(gt_dist);
    }

    let path = write_results("fig4_psnr_fid", &Json::Arr(results))?;
    println!("\nwrote {}", path.display());
    Ok(())
}
