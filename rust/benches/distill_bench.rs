//! Distillation trainer bench (fig. 4-style, stub-backed): PSNR vs NFE
//! of the rust-distilled BNS solver against stationary baselines, plus
//! trainer throughput — no compiled artifacts needed, so it runs in CI.
//!
//! Emits machine-readable `BENCH_distill.json` (path override:
//! `BENCH_DISTILL_OUT`) with the PSNR-vs-NFE trajectory, per-NFE trainer
//! stats (iters/s, forwards, init→final val PSNR) and the smallest NFE
//! reaching the target PSNR — the perf-trajectory hooks `ci.sh` tracks
//! PR-over-PR. `DISTILL_BENCH_ITERS` scales the training run (default
//! 150, smoke-sized).

use std::sync::Arc;
use std::time::Instant;

use bns_serve::bench_util::{stub_store, StubModel, Table};
use bns_serve::distill::{sample_loss, train, ConditionedModel, DistillField, TeacherSet, TrainConfig};
use bns_serve::runtime::{LoadedModel, Runtime};
use bns_serve::solver::{baseline, Solver};
use bns_serve::util::json::Json;
use bns_serve::util::stats::psnr_from_log_mse;

const DIM: usize = 6;
const TARGET_PSNR: f64 = 40.0;
const EVAL_PAIRS: usize = 16;

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::var("DISTILL_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);
    let (store, dir) = stub_store(
        "distill-bench",
        &[StubModel {
            name: "m",
            dim: DIM,
            num_classes: 4,
            forwards_per_eval: 2,
            k: -0.7,
            c: 0.1,
            label_scale: 0.15,
            cost: 1,
            buckets: &[8, 16, 32],
        }],
    )?;
    let rt = Arc::new(Runtime::with_lanes(2)?);
    let info = store.model("m")?.clone();
    let loaded = Arc::new(LoadedModel::load(&rt, &info)?);

    let mut table = Table::new(&[
        "NFE", "bns(rs)", "euler", "midpoint", "dpmpp2m", "init->final(val)", "iters/s",
    ]);
    let mut rows = Vec::new();
    let mut nfe_to_target: i64 = -1;

    for nfe in [4usize, 8] {
        // train against the deployed stub field
        let pairs = 24;
        let val_pairs = 12;
        let labels: Vec<i32> =
            (0..pairs + val_pairs).map(|i| (i % info.num_classes) as i32).collect();
        let src = ConditionedModel::new(loaded.clone(), labels, 0.0);
        let cfg = TrainConfig {
            iters,
            pairs,
            val_pairs,
            batch: 12,
            threads: 2,
            init: "auto".into(),
            ..Default::default()
        };
        let t0 = Instant::now();
        let (solver, report) = train(&src, DIM, nfe, &cfg)?;
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        let iters_per_s = report.iters as f64 / secs;

        // held-out evaluation set (fresh seed) for all solvers
        let eval_labels: Vec<i32> =
            (0..EVAL_PAIRS).map(|i| ((i + 1) % info.num_classes) as i32).collect();
        let eval_src = ConditionedModel::new(loaded.clone(), eval_labels, 0.0);
        let eval = TeacherSet::generate(&eval_src, DIM, EVAL_PAIRS, 4242, 2)?;
        let efield = eval_src.full();
        let psnr_of = |s: &dyn bns_serve::solver::Solver| -> anyhow::Result<f64> {
            let out = s.sample(efield, &eval.x0)?;
            Ok(psnr_from_log_mse(bns_serve::distill::log_mse_loss(&out, &eval.x1, DIM)))
        };
        let p_bns = psnr_from_log_mse(sample_loss(&solver, efield, &eval.x0, &eval.x1, DIM)?);
        let p_euler = psnr_of(baseline("euler", nfe, info.scheduler)?.as_ref())?;
        let p_mid = if nfe % 2 == 0 {
            psnr_of(baseline("midpoint", nfe, info.scheduler)?.as_ref())?
        } else {
            f64::NAN
        };
        let p_dpm = psnr_of(baseline("dpmpp2m", nfe, info.scheduler)?.as_ref())?;
        if nfe_to_target < 0 && p_bns >= TARGET_PSNR {
            nfe_to_target = nfe as i64;
        }

        table.row(vec![
            nfe.to_string(),
            format!("{p_bns:.2}"),
            format!("{p_euler:.2}"),
            format!("{p_mid:.2}"),
            format!("{p_dpm:.2}"),
            format!("{:.2} -> {:.2}", report.init_val_psnr, report.final_val_psnr),
            format!("{iters_per_s:.1}"),
        ]);
        rows.push(Json::obj(vec![
            ("nfe", Json::Num(nfe as f64)),
            ("psnr_bns", Json::Num(p_bns)),
            ("psnr_euler", Json::Num(p_euler)),
            ("psnr_midpoint", Json::Num(p_mid)),
            ("psnr_dpmpp2m", Json::Num(p_dpm)),
            ("init_val_psnr", Json::Num(report.init_val_psnr)),
            ("final_val_psnr", Json::Num(report.final_val_psnr)),
            ("iters", Json::Num(report.iters as f64)),
            ("iters_per_s", Json::Num(iters_per_s)),
            ("forwards", Json::Num(report.forwards as f64)),
            ("gt_nfe", Json::Num(report.gt_nfe as f64)),
            ("init", Json::Str(report.init_name.clone())),
        ]));
    }
    table.print();

    let out = Json::obj(vec![
        ("bench", Json::Str("distill".into())),
        ("dim", Json::Num(DIM as f64)),
        ("iters_config", Json::Num(iters as f64)),
        ("target_psnr", Json::Num(TARGET_PSNR)),
        // -1 = no swept NFE reached the target
        ("nfe_to_target_psnr", Json::Num(nfe_to_target as f64)),
        ("points", Json::Arr(rows)),
    ]);
    let path = std::env::var("BENCH_DISTILL_OUT")
        .unwrap_or_else(|_| "BENCH_distill.json".to_string());
    std::fs::write(&path, out.to_string())?;
    println!("\nwrote {path}");
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
