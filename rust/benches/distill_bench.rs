//! Distillation trainer bench (fig. 4-style, stub-backed): PSNR vs NFE
//! of the rust-distilled BNS solver against stationary baselines, plus
//! trainer throughput — no compiled artifacts needed, so it runs in CI.
//!
//! Runs under a **counting global allocator** (the `perf_layers` idiom)
//! so the wavefront gradient engine's zero-allocation claim is measured,
//! not asserted from reading the code: the steady-state grad-step
//! section reports `allocs_per_step` (gated at 0 by `ci.sh` under
//! STRICT=1), `grad_steps_per_sec`, and `jvp_round_trips` — asserting
//! the O(n) round-trip bound for n = 8 and 16.
//!
//! Emits machine-readable `BENCH_distill.json` (path override:
//! `BENCH_DISTILL_OUT`) with the PSNR-vs-NFE trajectory, per-NFE trainer
//! stats (iters/s, forwards, init→final val PSNR), the smallest NFE
//! reaching the target PSNR, and the `grad_steps` microbench — the
//! perf-trajectory hooks `ci.sh` tracks PR-over-PR.
//! `DISTILL_BENCH_ITERS` scales the training run (default 150,
//! smoke-sized).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use bns_serve::bench_util::{stub_store, StubModel, Table};
use bns_serve::distill::theta::{pack, unpack_into, ThetaGrad};
use bns_serve::distill::{
    sample_indices_into, sample_loss, train, Adam, ConditionedModel, DistillField, GradFan,
    TeacherSet, TrainConfig, GRAD_CHUNK,
};
use bns_serve::runtime::{LoadedModel, Runtime};
use bns_serve::solver::taxonomy::init_ns;
use bns_serve::solver::{baseline, Solver};
use bns_serve::util::json::Json;
use bns_serve::util::rng::Pcg32;
use bns_serve::util::stats::psnr_from_log_mse;

/// Counts every heap allocation in the process (all threads — the device
/// lane included, which is the point).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Steady-state wavefront grad-step microbench for one NFE: the
/// trainer's exact hot-loop body (minibatch draw → unpack → fanned
/// wavefront gradient → theta chain rule → Adam) on a bucket-aligned
/// stub model, measured after warmup. Returns the JSON row and asserts
/// the O(n) round-trip bound.
fn grad_step_bench(
    loaded: &Arc<LoadedModel>,
    dim: usize,
    nfe: usize,
    pairs: usize,
    batch: usize,
) -> anyhow::Result<Json> {
    let labels: Vec<i32> = (0..pairs).map(|i| (i % 4) as i32).collect();
    let src = ConditionedModel::new(loaded.clone(), labels, 0.0);
    let teacher = TeacherSet::generate(&src, dim, pairs, 99, 1)?;
    let solver0 = init_ns("euler", nfe)?;
    let mut theta = pack(&solver0);
    let mut adam = Adam::new(theta.len(), 4e-3);
    let mut fan = GradFan::new();
    let mut tgrad = ThetaGrad::new();
    let mut gtheta: Vec<f64> = Vec::new();
    let mut solver = solver0.clone();
    let mut idx: Vec<usize> = Vec::new();
    let mut rng = Pcg32::seeded(17);
    let nchunks = (batch + GRAD_CHUNK - 1) / GRAD_CHUNK;

    // warmup (3 steps): size every workspace/slot/pool buffer, then 20
    // measured steps of the trainer's exact hot-loop body
    let warmup = 3;
    let iters = 20;
    let mut a0 = 0u64;
    let mut t0 = Instant::now();
    let mut trips = 0u64;
    for k in 0..warmup + iters {
        if k == warmup {
            a0 = alloc_count();
            t0 = Instant::now();
        }
        sample_indices_into(&mut rng, pairs, batch, &mut idx);
        unpack_into(&theta, nfe, &mut solver);
        fan.compute(&solver, &src, &teacher, &idx, dim, 1)?;
        tgrad.apply(&theta, nfe, &fan.d_times, &fan.d_a, &fan.d_b, &mut gtheta);
        adam.step(&mut theta, &gtheta);
        trips = fan.jvp_round_trips;
    }
    let secs = t0.elapsed().as_secs_f64();
    let allocs_per_step = (alloc_count() - a0) as f64 / iters as f64;
    let steps_per_sec = iters as f64 / secs.max(1e-9);

    // the wavefront contract: O(n) device dispatches per minibatch —
    // one per interior step per chunk
    assert!(
        trips <= (nchunks * nfe) as u64,
        "nfe={nfe}: {trips} round trips > O(n) bound {}",
        nchunks * nfe
    );
    assert_eq!(trips, (nchunks * (nfe - 1)) as u64, "nfe={nfe}: exact trip count");

    println!(
        "grad step nfe={nfe}: {steps_per_sec:.1} steps/s, {trips} jvp round trips/step, \
         {allocs_per_step:.3} allocs/step"
    );
    Ok(Json::obj(vec![
        ("nfe", Json::Num(nfe as f64)),
        ("batch", Json::Num(batch as f64)),
        ("grad_steps_per_sec", Json::Num(steps_per_sec)),
        ("jvp_round_trips", Json::Num(trips as f64)),
        ("allocs_per_step", Json::Num(allocs_per_step)),
    ]))
}

const DIM: usize = 6;
const TARGET_PSNR: f64 = 40.0;
const EVAL_PAIRS: usize = 16;

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::var("DISTILL_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);
    let (store, dir) = stub_store(
        "distill-bench",
        &[StubModel {
            name: "m",
            dim: DIM,
            num_classes: 4,
            forwards_per_eval: 2,
            k: -0.7,
            c: 0.1,
            label_scale: 0.15,
            cost: 1,
            buckets: &[8, 16, 32],
        }],
    )?;
    let rt = Arc::new(Runtime::with_lanes(2)?);
    let info = store.model("m")?.clone();
    let loaded = Arc::new(LoadedModel::load(&rt, &info)?);

    let mut table = Table::new(&[
        "NFE", "bns(rs)", "euler", "midpoint", "dpmpp2m", "init->final(val)", "iters/s",
    ]);
    let mut rows = Vec::new();
    let mut phase_rows = Vec::new();
    let mut nfe_to_target: i64 = -1;

    for nfe in [4usize, 8] {
        // train against the deployed stub field
        let pairs = 24;
        let val_pairs = 12;
        let labels: Vec<i32> =
            (0..pairs + val_pairs).map(|i| (i % info.num_classes) as i32).collect();
        let src = ConditionedModel::new(loaded.clone(), labels, 0.0);
        let cfg = TrainConfig {
            iters,
            pairs,
            val_pairs,
            batch: 12,
            threads: 2,
            init: "auto".into(),
            ..Default::default()
        };
        let t0 = Instant::now();
        let (solver, report) = train(&src, DIM, nfe, &cfg)?;
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        let iters_per_s = report.iters as f64 / secs;

        // held-out evaluation set (fresh seed) for all solvers
        let eval_labels: Vec<i32> =
            (0..EVAL_PAIRS).map(|i| ((i + 1) % info.num_classes) as i32).collect();
        let eval_src = ConditionedModel::new(loaded.clone(), eval_labels, 0.0);
        let eval = TeacherSet::generate(&eval_src, DIM, EVAL_PAIRS, 4242, 2)?;
        let efield = eval_src.full();
        let psnr_of = |s: &dyn bns_serve::solver::Solver| -> anyhow::Result<f64> {
            let out = s.sample(efield, &eval.x0)?;
            Ok(psnr_from_log_mse(bns_serve::distill::log_mse_loss(&out, &eval.x1, DIM)))
        };
        let p_bns = psnr_from_log_mse(sample_loss(&solver, efield, &eval.x0, &eval.x1, DIM)?);
        let p_euler = psnr_of(baseline("euler", nfe, info.scheduler)?.as_ref())?;
        let p_mid = if nfe % 2 == 0 {
            psnr_of(baseline("midpoint", nfe, info.scheduler)?.as_ref())?
        } else {
            f64::NAN
        };
        let p_dpm = psnr_of(baseline("dpmpp2m", nfe, info.scheduler)?.as_ref())?;
        if nfe_to_target < 0 && p_bns >= TARGET_PSNR {
            nfe_to_target = nfe as i64;
        }

        table.row(vec![
            nfe.to_string(),
            format!("{p_bns:.2}"),
            format!("{p_euler:.2}"),
            format!("{p_mid:.2}"),
            format!("{p_dpm:.2}"),
            format!("{:.2} -> {:.2}", report.init_val_psnr, report.final_val_psnr),
            format!("{iters_per_s:.1}"),
        ]);
        rows.push(Json::obj(vec![
            ("nfe", Json::Num(nfe as f64)),
            ("psnr_bns", Json::Num(p_bns)),
            ("psnr_euler", Json::Num(p_euler)),
            ("psnr_midpoint", Json::Num(p_mid)),
            ("psnr_dpmpp2m", Json::Num(p_dpm)),
            ("init_val_psnr", Json::Num(report.init_val_psnr)),
            ("final_val_psnr", Json::Num(report.final_val_psnr)),
            ("iters", Json::Num(report.iters as f64)),
            ("iters_per_s", Json::Num(iters_per_s)),
            ("forwards", Json::Num(report.forwards as f64)),
            ("gt_nfe", Json::Num(report.gt_nfe as f64)),
            ("init", Json::Str(report.init_name.clone())),
        ]));
        // trainer phase spans (tracing plane, DESIGN.md §12): where a
        // distillation run's wall clock actually goes
        println!(
            "phases nfe={nfe}: teacher {:.3}s, jvp {:.3}s, adam {:.3}s, checkpoint {:.3}s \
             (wall {secs:.3}s)",
            report.teacher_gen_s, report.wavefront_jvp_s, report.adam_step_s, report.checkpoint_s
        );
        phase_rows.push(Json::obj(vec![
            ("nfe", Json::Num(nfe as f64)),
            ("teacher_gen_s", Json::Num(report.teacher_gen_s)),
            ("wavefront_jvp_s", Json::Num(report.wavefront_jvp_s)),
            ("adam_step_s", Json::Num(report.adam_step_s)),
            ("checkpoint_s", Json::Num(report.checkpoint_s)),
            ("wall_s", Json::Num(secs)),
        ]));
    }
    table.print();

    // wavefront grad-step microbench: steady-state throughput, O(n)
    // round-trip assert, and hot-loop allocations (STRICT-gated at 0 by
    // ci.sh) — bucket-aligned batch (GRAD_CHUNK rows ↔ the 8-bucket;
    // the stacked JVP rows decompose exactly into the 32/16/8 buckets)
    println!();
    let mut grad_rows = Vec::new();
    for nfe in [8usize, 16] {
        grad_rows.push(grad_step_bench(&loaded, DIM, nfe, 16, GRAD_CHUNK)?);
    }

    let out = Json::obj(vec![
        ("bench", Json::Str("distill".into())),
        ("dim", Json::Num(DIM as f64)),
        ("iters_config", Json::Num(iters as f64)),
        ("target_psnr", Json::Num(TARGET_PSNR)),
        // -1 = no swept NFE reached the target
        ("nfe_to_target_psnr", Json::Num(nfe_to_target as f64)),
        ("points", Json::Arr(rows)),
        ("grad_steps", Json::Arr(grad_rows)),
        ("phase_breakdown", Json::Arr(phase_rows)),
    ]);
    let path = std::env::var("BENCH_DISTILL_OUT")
        .unwrap_or_else(|_| "BENCH_distill.json".to_string());
    std::fs::write(&path, out.to_string())?;
    println!("\nwrote {path}");
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
