//! Figures 6 + 12 and Tables 6/7: audio generation.
//!
//! SNR (dB) of each solver's output vs the RK45 ground truth, per audio
//! "dataset" (the 4 synthetic signal families standing in for the
//! paper's 8 speech corpora — DESIGN.md §3), at NFE in {8, 12, 16, 20}.
//! Expected shape: BNS consistently above BST above Midpoint/Euler by
//! ~1-3 dB.
//!
//! Tables 6/7 substitutes: a *content-error* proxy (1 - normalized
//! cross-correlation with GT) and a *style-similarity* proxy (cosine of
//! log-band spectral envelopes). The paper's point is that these vary
//! little across solvers; we assert/report the same invariance.

use bns_serve::bench_util::{write_results, Bench, Table};
use bns_serve::coordinator::router::distilled;
use bns_serve::solver::{baseline, Solver};
use bns_serve::util::fft::{cosine, spectral_envelope};
use bns_serve::util::json::Json;
use bns_serve::util::stats::snr_db;

const MODEL: &str = "audio_fm_ot";
const PER_FAMILY_N: usize = 24;
const FAMILIES: [&str; 4] = ["harmonic", "am", "chirp", "noiseband"];

fn main() -> anyhow::Result<()> {
    let b = Bench::init()?;
    let info = b.store.model(MODEL)?.clone();
    let nfes: Vec<usize> =
        b.store.solvers_for(MODEL, 0.0, "bns").iter().map(|s| s.solver.nfe()).collect();
    anyhow::ensure!(!nfes.is_empty(), "no BNS artifacts for {MODEL}");

    let mut results = Vec::new();
    let mut snr_table = Table::new(&["solver", "NFE", "harmonic", "am", "chirp", "noiseband"]);
    let mut invariance = Table::new(&["solver", "NFE", "content-err", "style-sim"]);

    // per-family fixed noise + GT
    let mut family_sets = Vec::new();
    for (fam_id, _fam) in FAMILIES.iter().enumerate() {
        let mut rng = bns_serve::util::rng::Pcg32::seeded(9000 + fam_id as u64);
        let x0 = rng.normal_vec(PER_FAMILY_N * info.dim);
        let labels = vec![fam_id as i32; PER_FAMILY_N];
        let field = b.field(&info, labels.clone(), 0.0)?;
        let (gt, _) = b.ground_truth(&field, &x0)?;
        family_sets.push((x0, labels, gt));
    }

    for &nfe in &nfes {
        let mut solvers: Vec<(String, Box<dyn Solver>)> = Vec::new();
        solvers.push(("bns".into(), Box::new(distilled(&b.store, MODEL, 0.0, "bns", nfe)?)));
        if let Ok(s) = distilled(&b.store, MODEL, 0.0, "bst", nfe) {
            solvers.push(("bst".into(), Box::new(s)));
        }
        if nfe % 2 == 0 {
            solvers.push(("midpoint".into(), baseline("midpoint", nfe, info.scheduler)?));
        }
        solvers.push(("euler".into(), baseline("euler", nfe, info.scheduler)?));

        for (label, solver) in &solvers {
            let mut snrs = Vec::new();
            let mut content_err_acc = 0.0;
            let mut style_sim_acc = 0.0;
            let mut count = 0usize;
            for (x0, labels, gt) in &family_sets {
                let field = b.field(&info, labels.clone(), 0.0)?;
                let out = solver.sample(&field, x0)?;
                // per-sample SNR averaged over the family
                let mut s = 0.0;
                for i in 0..PER_FAMILY_N {
                    let (p, r) = (
                        &out[i * info.dim..(i + 1) * info.dim],
                        &gt[i * info.dim..(i + 1) * info.dim],
                    );
                    s += snr_db(p, r);
                    // Tables 6/7 proxies
                    let dot: f64 = p.iter().zip(r).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
                    let np: f64 = p.iter().map(|a| (*a as f64).powi(2)).sum::<f64>().sqrt();
                    let nr: f64 = r.iter().map(|a| (*a as f64).powi(2)).sum::<f64>().sqrt();
                    content_err_acc += 1.0 - (dot / (np * nr).max(1e-12)).clamp(-1.0, 1.0);
                    style_sim_acc +=
                        cosine(&spectral_envelope(p, 8), &spectral_envelope(r, 8));
                    count += 1;
                }
                snrs.push(s / PER_FAMILY_N as f64);
            }
            snr_table.row(vec![
                label.clone(),
                nfe.to_string(),
                format!("{:.2}", snrs[0]),
                format!("{:.2}", snrs[1]),
                format!("{:.2}", snrs[2]),
                format!("{:.2}", snrs[3]),
            ]);
            invariance.row(vec![
                label.clone(),
                nfe.to_string(),
                format!("{:.4}", content_err_acc / count as f64),
                format!("{:.4}", style_sim_acc / count as f64),
            ]);
            results.push(Json::obj(vec![
                ("solver", Json::Str(label.clone())),
                ("nfe", Json::Num(nfe as f64)),
                ("snr_per_family", Json::arr_f64(&snrs)),
                ("content_err", Json::Num(content_err_acc / count as f64)),
                ("style_sim", Json::Num(style_sim_acc / count as f64)),
            ]));
        }
    }

    println!("=== Fig 6/12: SNR (dB) vs RK45 GT per audio family ===");
    snr_table.print();
    println!("\n=== Tables 6/7 proxies (should vary little across solvers) ===");
    invariance.print();

    let path = write_results("fig6_audio", &Json::Arr(results))?;
    println!("\nwrote {}", path.display());
    Ok(())
}
