//! Tables 2 + 5: Text-to-Image regime — high classifier-free guidance
//! (w = 2.0 and w = 6.5) at NFE 12/16/20.
//!
//! Compares, per the paper: GT (RK45/DOPRI5), RK-Euler, RK-Midpoint, the
//! *initial solver* (Euler + sigma0 preconditioning, Table 5's ablation
//! row) and BNS. Metrics: PSNR vs GT on shared noise (the paper's
//! headline column — BNS gains >= 10 dB) and FD-synth (zero-shot-FID
//! stand-in). The paper's Pick/Clip scores have no synthetic analogue;
//! DESIGN.md §3 documents the substitution.

use bns_serve::bench_util::{write_results, Bench, Table};
use bns_serve::coordinator::router::distilled;
use bns_serve::solver::{baseline, Solver};
use bns_serve::util::json::Json;
use bns_serve::util::stats::batch_psnr;

const MODEL: &str = "img_fm_ot";
const PSNR_EVAL_N: usize = 48;
const FD_EVAL_N: usize = 384;

fn main() -> anyhow::Result<()> {
    let b = Bench::init()?;
    let info = b.store.model(MODEL)?.clone();
    let mut results = Vec::new();

    for &w in &[2.0f64, 6.5] {
        let nfes: Vec<usize> =
            b.store.solvers_for(MODEL, w, "bns").iter().map(|s| s.solver.nfe()).collect();
        if nfes.is_empty() {
            eprintln!("[table2] no BNS artifacts for w={w}; skipping");
            continue;
        }
        let (x0, labels) = b.eval_set(&info, PSNR_EVAL_N, 777);
        let field = b.field(&info, labels.clone(), w as f32)?;
        let (gt, gt_nfe) = b.ground_truth(&field, &x0)?;
        let (gt_dist, _) = b.generate_gt(&info, w as f32, FD_EVAL_N, 31)?;
        let gt_fd = b.store.fd.fd_to_reference(&gt_dist);
        println!("\n=== w = {w} — GT (rk45) NFE={gt_nfe}, FD={gt_fd:.3} ===");

        let mut table = Table::new(&["solver", "NFE", "PSNR(dB)", "FD-synth"]);
        table.row(vec!["GT (rk45)".into(), gt_nfe.to_string(), "inf".into(), format!("{gt_fd:.3}")]);

        for &nfe in &nfes {
            let mut rows: Vec<(String, Box<dyn Solver>)> = vec![
                ("rk-euler".into(), baseline("euler", nfe, info.scheduler)?),
                ("rk-midpoint".into(), baseline("midpoint", nfe, info.scheduler)?),
            ];
            if let Ok(init) = distilled(&b.store, MODEL, w, "init", nfe) {
                rows.push(("init (euler+precond)".into(), Box::new(init)));
            }
            rows.push(("bns".into(), Box::new(distilled(&b.store, MODEL, w, "bns", nfe)?)));
            for (label, solver) in rows {
                let out = solver.sample(&field, &x0)?;
                let psnr = batch_psnr(&out, &gt, info.dim);
                let dist = b.generate(&info, solver.as_ref(), w as f32, FD_EVAL_N, 31)?;
                let fd = b.store.fd.fd_to_reference(&dist);
                table.row(vec![label.clone(), nfe.to_string(), format!("{psnr:.2}"), format!("{fd:.3}")]);
                results.push(Json::obj(vec![
                    ("guidance", Json::Num(w)),
                    ("solver", Json::Str(label)),
                    ("nfe", Json::Num(nfe as f64)),
                    ("psnr", Json::Num(psnr)),
                    ("fd", Json::Num(fd)),
                    ("gt_fd", Json::Num(gt_fd)),
                    ("gt_nfe", Json::Num(gt_nfe as f64)),
                ]));
            }
        }
        table.print();
    }

    let path = write_results("table2_guidance", &Json::Arr(results))?;
    println!("\nwrote {}", path.display());
    Ok(())
}
