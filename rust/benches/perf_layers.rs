//! §Perf microbenches for L1/L2: per-eval latency of the Pallas-kernel
//! artifact vs the XLA-fused (pure-jnp) artifact of the same model, per
//! batch bucket, plus the NS-combine (Algorithm 1 linear algebra) and
//! RK45-GT cost on the rust side.
//!
//! Note: interpret=True Pallas timings are CPU-emulation numbers, NOT a
//! TPU proxy — the point of this bench is to quantify the CPU-serving
//! decision documented in EXPERIMENTS.md §Perf (which artifact the
//! request path should load on this substrate).

use std::time::Instant;

use bns_serve::bench_util::{write_results, Bench, Table};
use bns_serve::solver::field::Field;
use bns_serve::util::json::Json;
use bns_serve::util::rng::Pcg32;

fn time_eval(field: &dyn Field, rows: usize, dim: usize, iters: usize) -> anyhow::Result<f64> {
    let mut rng = Pcg32::seeded(5);
    let x = rng.normal_vec(rows * dim);
    field.eval(0.5, &x)?; // warmup / compile
    let t0 = Instant::now();
    for i in 0..iters {
        field.eval(0.1 + 0.8 * (i as f64 / iters as f64), &x)?;
    }
    Ok(t0.elapsed().as_secs_f64() / iters as f64)
}

fn main() -> anyhow::Result<()> {
    let b = Bench::init()?;
    let mut table = Table::new(&["artifact", "batch", "eval(ms)", "per-row(us)"]);
    let mut results = Vec::new();

    for (name, label) in [("img_fm_ot", "pallas-kernels"), ("img_fm_ot_fused", "xla-fused")] {
        if !b.store.models.contains_key(name) {
            eprintln!("[perf] {name} missing; skip");
            continue;
        }
        let info = b.store.model(name)?.clone();
        for bucket in info.buckets.iter().map(|bk| bk.batch) {
            let labels = vec![0i32; bucket];
            let field = b.field(&info, labels, 0.0)?;
            let dt = time_eval(&field, bucket, info.dim, 30)?;
            table.row(vec![
                label.into(),
                bucket.to_string(),
                format!("{:.3}", dt * 1e3),
                format!("{:.1}", dt * 1e6 / bucket as f64),
            ]);
            results.push(Json::obj(vec![
                ("artifact", Json::Str(label.into())),
                ("batch", Json::Num(bucket as f64)),
                ("eval_ms", Json::Num(dt * 1e3)),
            ]));
        }
    }
    println!("=== L1/L2: model-eval latency by artifact variant ===");
    table.print();

    // NS combine cost (pure rust, the L3-side ns_update analogue):
    // step i touches i+2 row-major buffers; measure the full Alg. 1
    // overhead minus field time using a free (zero-cost) field.
    struct ZeroField(usize);
    impl Field for ZeroField {
        fn dim(&self) -> usize {
            self.0
        }
        fn eval(&self, _t: f64, x: &[f32]) -> anyhow::Result<Vec<f32>> {
            Ok(x.to_vec())
        }
    }
    let dim = 192;
    let mut combine = Table::new(&["NFE", "batch", "combine-only(us)"]);
    for nfe in [8usize, 16, 20] {
        for batch in [8usize, 64] {
            let solver = bns_serve::solver::taxonomy::midpoint_ns(nfe.max(2) / 2 * 2);
            let f = ZeroField(dim);
            let mut rng = Pcg32::seeded(7);
            let x0 = rng.normal_vec(batch * dim);
            let t0 = Instant::now();
            let iters = 50;
            for _ in 0..iters {
                solver.sample(&f, &x0)?;
            }
            let dt = t0.elapsed().as_secs_f64() / iters as f64;
            combine.row(vec![
                nfe.to_string(),
                batch.to_string(),
                format!("{:.1}", dt * 1e6),
            ]);
            results.push(Json::obj(vec![
                ("artifact", Json::Str("ns-combine".into())),
                ("nfe", Json::Num(nfe as f64)),
                ("batch", Json::Num(batch as f64)),
                ("us", Json::Num(dt * 1e6)),
            ]));
        }
    }
    println!("\n=== L3: Algorithm 1 combine overhead (zero-cost field) ===");
    combine.print();

    let path = write_results("perf_layers", &Json::Arr(results))?;
    println!("\nwrote {}", path.display());
    Ok(())
}
