//! §Perf microbenches for L1/L2/L3: per-eval latency of the Pallas-kernel
//! artifact vs the XLA-fused (pure-jnp) artifact of the same model, per
//! batch bucket, plus the NS-combine (Algorithm 1 linear algebra) cost on
//! the rust side — seed allocating `sample` vs the workspace-backed
//! `sample_into` hot path.
//!
//! The L1/L2 sections need real model artifacts (`make artifacts`) and
//! are skipped with a notice when absent; the L3 sections run anywhere.
//!
//! Note: interpret=True Pallas timings are CPU-emulation numbers, NOT a
//! TPU proxy — the point of this bench is to quantify the CPU-serving
//! decision documented in EXPERIMENTS.md §Perf (which artifact the
//! request path should load on this substrate).
//!
//! This binary runs under a **counting global allocator** so the
//! zero-allocation claims are measured, not asserted from reading the
//! code: the pooled device-lane section reports allocations per
//! `eval_into` through the full solver → field → lane → backend path.
//!
//! The **roofline section** covers the CPU kernel layer (`kernels::`,
//! DESIGN.md §13): per-kernel flops, bytes, GFLOP/s, GB/s from the
//! analytic cost model in `kernels::{flops, bytes}`, the fused-vs-naive
//! resblock speedup, steady-state allocations per `bns_mlp_field` eval
//! through the pooled lane path, and bit-identity of full NS samples
//! across intra-lane pool sizes {1, 2, 4}. Machine-readable output goes
//! to `BENCH_perf.json` (path override: `BENCH_PERF_OUT`) with a flat
//! `gates` block that ci.sh greps under STRICT=1.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use bns_serve::bench_util::{
    mlp_store, stub_store, write_results, Bench, MlpModelSpec, StubModel, Table,
};
use bns_serve::kernels::{
    bytes as kbytes, flops as kflops, fused_resblock_into, gemm_bias, gemm_bias_naive,
    naive_resblock_into, ns_combine_into, TILE,
};
use bns_serve::runtime::{LoadedModel, Runtime, RuntimeConfig};
use bns_serve::solver::field::Field;
use bns_serve::solver::{NsSolver, SampleWorkspace, Solver};
use bns_serve::util::json::Json;
use bns_serve::util::rng::Pcg32;

/// Counts every heap allocation in the process (all threads — the device
/// lane included, which is the point).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Seconds per call over `iters` back-to-back invocations.
fn time_it(iters: usize, mut f: impl FnMut()) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn time_eval(field: &dyn Field, rows: usize, dim: usize, iters: usize) -> anyhow::Result<f64> {
    let mut rng = Pcg32::seeded(5);
    let x = rng.normal_vec(rows * dim);
    field.eval(0.5, &x)?; // warmup / compile
    let t0 = Instant::now();
    for i in 0..iters {
        field.eval(0.1 + 0.8 * (i as f64 / iters as f64), &x)?;
    }
    Ok(t0.elapsed().as_secs_f64() / iters as f64)
}

/// Identity field with an allocation-free `eval_into`: isolates the
/// solver-side combine cost from model time.
struct ZeroField(usize);

impl Field for ZeroField {
    fn dim(&self) -> usize {
        self.0
    }
    fn eval(&self, _t: f64, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        Ok(x.to_vec())
    }
    fn eval_into(&self, _t: f64, x: &[f32], out: &mut [f32]) -> anyhow::Result<()> {
        out.copy_from_slice(x);
        Ok(())
    }
}

/// Dense random valid NS solver — the coefficient shape a distilled BNS
/// artifact has (every b entry nonzero).
fn dense_ns(nfe: usize) -> NsSolver {
    let mut rng = Pcg32::seeded(13);
    NsSolver {
        times: (0..=nfe).map(|i| i as f64 / nfe as f64).collect(),
        a: (0..nfe).map(|_| 1.0 + 0.05 * rng.normal()).collect(),
        b: (0..nfe)
            .map(|i| (0..=i).map(|_| 0.1 * rng.normal()).collect())
            .collect(),
    }
}

fn main() -> anyhow::Result<()> {
    let mut results = Vec::new();

    // ---- L1/L2: model-eval latency by artifact variant (needs artifacts)
    match Bench::init() {
        Ok(b) => {
            let mut table = Table::new(&["artifact", "batch", "eval(ms)", "per-row(us)"]);
            for (name, label) in [("img_fm_ot", "pallas-kernels"), ("img_fm_ot_fused", "xla-fused")] {
                if !b.store.models.contains_key(name) {
                    eprintln!("[perf] {name} missing; skip");
                    continue;
                }
                let info = b.store.model(name)?.clone();
                for bucket in info.buckets.iter().map(|bk| bk.batch) {
                    let labels = vec![0i32; bucket];
                    let field = b.field(&info, labels, 0.0)?;
                    let dt = time_eval(&field, bucket, info.dim, 30)?;
                    table.row(vec![
                        label.into(),
                        bucket.to_string(),
                        format!("{:.3}", dt * 1e3),
                        format!("{:.1}", dt * 1e6 / bucket as f64),
                    ]);
                    results.push(Json::obj(vec![
                        ("artifact", Json::Str(label.into())),
                        ("batch", Json::Num(bucket as f64)),
                        ("eval_ms", Json::Num(dt * 1e3)),
                    ]));
                }
            }
            println!("=== L1/L2: model-eval latency by artifact variant ===");
            table.print();
        }
        Err(e) => {
            eprintln!("[perf] artifacts unavailable ({e:#}); skipping L1/L2 sections");
        }
    }

    // ---- L3: pooled device-lane eval — allocations per model eval ------
    //
    // The acceptance target of the device-lane rework: at steady state a
    // bucket-aligned `eval_into` performs ZERO heap allocation end-to-end
    // (solver buffer -> ModelField -> lane RPC -> stub backend and back).
    // The allocating `eval` path is timed alongside for contrast. Runs on
    // the stub backend, so it works without compiled artifacts.
    {
        let (stubs, dir) = stub_store(
            "perf-alloc",
            &[StubModel {
                name: "perf_stub",
                dim: 192,
                num_classes: 8,
                forwards_per_eval: 2,
                k: -0.7,
                c: 0.1,
                label_scale: 0.02,
                cost: 1,
                buckets: &[64],
            }],
        )?;
        let rt = Runtime::with_lanes(1)?;
        let info = stubs.model("perf_stub")?.clone();
        let model = Arc::new(LoadedModel::load(&rt, &info)?);
        let field = model.bind((0..64).map(|i| (i % 8) as i32).collect(), 0.0);
        let mut rng = Pcg32::seeded(11);
        let x = rng.normal_vec(64 * info.dim);
        let mut out = vec![0f32; x.len()];
        // warm the slot pool, lane channel, and thread parkers
        for _ in 0..16 {
            field.eval_into(0.5, &x, &mut out)?;
        }
        let iters = 2000usize;
        let a0 = alloc_count();
        let t0 = Instant::now();
        for i in 0..iters {
            field.eval_into(0.1 + 0.8 * (i as f64 / iters as f64), &x, &mut out)?;
        }
        let dt_into = t0.elapsed().as_secs_f64() / iters as f64;
        let allocs_into = (alloc_count() - a0) as f64 / iters as f64;

        for _ in 0..4 {
            field.eval(0.5, &x)?;
        }
        let a1 = alloc_count();
        let t1 = Instant::now();
        for i in 0..iters {
            field.eval(0.1 + 0.8 * (i as f64 / iters as f64), &x)?;
        }
        let dt_alloc = t1.elapsed().as_secs_f64() / iters as f64;
        let allocs_alloc = (alloc_count() - a1) as f64 / iters as f64;

        let mut pool = Table::new(&["path", "allocs/eval", "eval(us)"]);
        pool.row(vec![
            "eval (allocating)".into(),
            format!("{allocs_alloc:.3}"),
            format!("{:.1}", dt_alloc * 1e6),
        ]);
        pool.row(vec![
            "eval_into (pooled lane)".into(),
            format!("{allocs_into:.3}"),
            format!("{:.1}", dt_into * 1e6),
        ]);
        println!("\n=== L3: pooled device lane — heap allocations per model eval (batch=64) ===");
        pool.print();
        if allocs_into > 0.0 {
            eprintln!(
                "[perf] WARNING: pooled eval_into allocated {allocs_into:.3}/eval — \
                 expected 0 at steady state"
            );
        }
        results.push(Json::obj(vec![
            ("artifact", Json::Str("model-eval-pooled".into())),
            ("batch", Json::Num(64.0)),
            ("allocs_per_eval", Json::Num(allocs_into)),
            ("eval_us", Json::Num(dt_into * 1e6)),
        ]));
        results.push(Json::obj(vec![
            ("artifact", Json::Str("model-eval-allocating".into())),
            ("batch", Json::Num(64.0)),
            ("allocs_per_eval", Json::Num(allocs_alloc)),
            ("eval_us", Json::Num(dt_alloc * 1e6)),
        ]));
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---- L3: seed allocating `sample` vs workspace `sample_into` -------
    //
    // The acceptance target: the allocation-free path must beat the seed
    // implementation on NS sampling at nfe=16, batch=64 — and outputs
    // must be bit-identical (also enforced by tests/sample_into_equiv.rs).
    let dim = 192;
    let mut hot = Table::new(&[
        "solver", "NFE", "batch", "sample(us)", "sample_into(us)", "speedup",
    ]);
    let mut ws = SampleWorkspace::new();
    for nfe in [8usize, 16] {
        for batch in [8usize, 64] {
            for (tag, solver) in [
                ("midpoint_ns", bns_serve::solver::taxonomy::midpoint_ns(nfe)),
                ("bns-dense", dense_ns(nfe)),
            ] {
                let f = ZeroField(dim);
                let mut rng = Pcg32::seeded(7);
                let x0 = rng.normal_vec(batch * dim);
                let iters = 100;
                // equivalence guard before timing
                let a = solver.sample(&f, &x0)?;
                let bref = solver.sample_into(&f, &x0, &mut ws)?;
                assert_eq!(
                    a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    bref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{tag}: sample_into drifted from sample"
                );
                let t0 = Instant::now();
                for _ in 0..iters {
                    solver.sample(&f, &x0)?;
                }
                let dt_alloc = t0.elapsed().as_secs_f64() / iters as f64;
                let t0 = Instant::now();
                for _ in 0..iters {
                    solver.sample_into(&f, &x0, &mut ws)?;
                }
                let dt_ws = t0.elapsed().as_secs_f64() / iters as f64;
                hot.row(vec![
                    tag.into(),
                    nfe.to_string(),
                    batch.to_string(),
                    format!("{:.1}", dt_alloc * 1e6),
                    format!("{:.1}", dt_ws * 1e6),
                    format!("{:.2}x", dt_alloc / dt_ws),
                ]);
                results.push(Json::obj(vec![
                    ("artifact", Json::Str(format!("ns-combine-{tag}"))),
                    ("nfe", Json::Num(nfe as f64)),
                    ("batch", Json::Num(batch as f64)),
                    ("sample_us", Json::Num(dt_alloc * 1e6)),
                    ("sample_into_us", Json::Num(dt_ws * 1e6)),
                    ("speedup", Json::Num(dt_alloc / dt_ws)),
                ]));
            }
        }
    }
    println!("\n=== L3: Algorithm 1 combine — allocating sample vs workspace sample_into ===");
    hot.print();

    // ---- L3: generic steppers through the same hot path ----------------
    let mut gen = Table::new(&["solver", "NFE", "batch", "sample(us)", "sample_into(us)", "speedup"]);
    for name in ["euler", "midpoint", "rk4"] {
        let solver = bns_serve::solver::baseline(
            name,
            16,
            bns_serve::solver::scheduler::Scheduler::FmOt,
        )?;
        let f = ZeroField(dim);
        let mut rng = Pcg32::seeded(9);
        let batch = 64;
        let x0 = rng.normal_vec(batch * dim);
        let iters = 100;
        let t0 = Instant::now();
        for _ in 0..iters {
            solver.sample(&f, &x0)?;
        }
        let dt_alloc = t0.elapsed().as_secs_f64() / iters as f64;
        let t0 = Instant::now();
        for _ in 0..iters {
            solver.sample_into(&f, &x0, &mut ws)?;
        }
        let dt_ws = t0.elapsed().as_secs_f64() / iters as f64;
        gen.row(vec![
            name.into(),
            "16".into(),
            batch.to_string(),
            format!("{:.1}", dt_alloc * 1e6),
            format!("{:.1}", dt_ws * 1e6),
            format!("{:.2}x", dt_alloc / dt_ws),
        ]);
        results.push(Json::obj(vec![
            ("artifact", Json::Str(format!("stepper-{name}"))),
            ("nfe", Json::Num(16.0)),
            ("batch", Json::Num(batch as f64)),
            ("sample_us", Json::Num(dt_alloc * 1e6)),
            ("sample_into_us", Json::Num(dt_ws * 1e6)),
            ("speedup", Json::Num(dt_alloc / dt_ws)),
        ]));
    }
    println!("\n=== L3: generic steppers — allocating sample vs workspace sample_into ===");
    gen.print();

    // ---- Roofline: CPU kernel layer (kernels::, DESIGN.md §13) ----------
    //
    // Flops/bytes come from the analytic model in `kernels::{flops,
    // bytes}`; times are measured here, so GFLOP/s and GB/s place each
    // kernel against the machine's roofline. Fused and naive outputs are
    // asserted bit-identical *before* timing — the speedup gate is never
    // purchased with a numerics change.
    let mut roofline = Vec::new();
    let mut roof_table = Table::new(&[
        "kernel", "shape", "time(us)", "GFLOP/s", "GB/s", "vs-naive",
    ]);
    let mut roof_row = |name: &str,
                        shape: String,
                        dt: f64,
                        flops: f64,
                        bytes: f64,
                        speedup: Option<f64>| {
        roof_table.row(vec![
            name.into(),
            shape.clone(),
            format!("{:.1}", dt * 1e6),
            format!("{:.2}", flops / dt / 1e9),
            format!("{:.2}", bytes / dt / 1e9),
            speedup.map(|s| format!("{s:.2}x")).unwrap_or_else(|| "-".into()),
        ]);
        let mut obj = vec![
            ("kernel", Json::Str(name.into())),
            ("shape", Json::Str(shape)),
            ("time_us", Json::Num(dt * 1e6)),
            ("flops", Json::Num(flops)),
            ("bytes", Json::Num(bytes)),
            ("gflops", Json::Num(flops / dt / 1e9)),
            ("gbs", Json::Num(bytes / dt / 1e9)),
        ];
        if let Some(s) = speedup {
            obj.push(("speedup_vs_naive", Json::Num(s)));
        }
        roofline.push(Json::obj(obj));
    };

    // fused resblock vs scalar oracle at the gated shape: D=H=256, rows=64
    let fused_speedup;
    {
        let (rows, d, h) = (64usize, 256usize, 256usize);
        let mut rng = Pcg32::seeded(17);
        let sc = |v: Vec<f32>, s: f32| -> Vec<f32> { v.into_iter().map(|u| u * s).collect() };
        let x = rng.normal_vec(rows * d);
        let modv = sc(rng.normal_vec(rows * 2 * d), 0.1);
        let w1 = sc(rng.normal_vec(d * h), 0.03);
        let b1 = sc(rng.normal_vec(h), 0.05);
        let w2 = sc(rng.normal_vec(h * d), 0.03);
        let b2 = sc(rng.normal_vec(d), 0.01);
        let mut mbuf = vec![0f32; TILE * d];
        let mut hbuf = vec![0f32; TILE * h];
        let mut mrow = vec![0f32; d];
        let mut hrow = vec![0f32; h];
        let mut out_f = vec![0f32; rows * d];
        let mut out_n = vec![0f32; rows * d];
        fused_resblock_into(
            rows, d, h, &x, &modv, &w1, &b1, &w2, &b2, &mut mbuf, &mut hbuf, &mut out_f,
        );
        naive_resblock_into(
            rows, d, h, &x, &modv, &w1, &b1, &w2, &b2, &mut mrow, &mut hrow, &mut out_n,
        );
        assert_eq!(
            out_f.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            out_n.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "fused resblock drifted from the scalar oracle"
        );
        let dt_f = time_it(40, || {
            fused_resblock_into(
                rows, d, h, &x, &modv, &w1, &b1, &w2, &b2, &mut mbuf, &mut hbuf, &mut out_f,
            )
        });
        let dt_n = time_it(8, || {
            naive_resblock_into(
                rows, d, h, &x, &modv, &w1, &b1, &w2, &b2, &mut mrow, &mut hrow, &mut out_n,
            )
        });
        fused_speedup = dt_n / dt_f;
        let shape = "rows=64 d=256 h=256".to_string();
        let (fl, by) = (kflops::resblock(rows, d, h), kbytes::resblock(rows, d, h));
        roof_row("resblock-naive", shape.clone(), dt_n, fl, by, None);
        roof_row("resblock-fused", shape, dt_f, fl, by, Some(fused_speedup));

        // bare GEMM at the same shape (the resblock's dominant term)
        let mut out_g = vec![0f32; rows * h];
        let mut out_gn = vec![0f32; rows * h];
        gemm_bias(rows, d, h, &x, &w1, &b1, &mut out_g);
        gemm_bias_naive(rows, d, h, &x, &w1, &b1, &mut out_gn);
        assert_eq!(
            out_g.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            out_gn.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "tiled gemm drifted from the scalar oracle"
        );
        let dt_g = time_it(40, || gemm_bias(rows, d, h, &x, &w1, &b1, &mut out_g));
        let dt_gn = time_it(8, || gemm_bias_naive(rows, d, h, &x, &w1, &b1, &mut out_gn));
        let shape = "m=64 k=256 n=256".to_string();
        let (fl, by) = (kflops::gemm(rows, d, h), kbytes::gemm(rows, d, h));
        roof_row("gemm-naive", shape.clone(), dt_gn, fl, by, None);
        roof_row("gemm-tiled", shape, dt_g, fl, by, Some(dt_gn / dt_g));
    }

    // streamed NS combine (bandwidth-bound): nfe=16 history rows, batch=64
    {
        let (k, len) = (16usize, 64 * 256usize);
        let mut rng = Pcg32::seeded(19);
        let x0 = rng.normal_vec(len);
        let hist = rng.normal_vec(k * len);
        let b: Vec<f64> = (0..k).map(|_| 0.1 * rng.normal()).collect();
        let mut xout = vec![0f32; len];
        let dt = time_it(200, || ns_combine_into(1.02, &x0, &b, &hist, len, &mut xout));
        roof_row(
            "ns-combine",
            format!("k=16 len={len}"),
            dt,
            kflops::ns_combine(k, len),
            kbytes::ns_combine(k, len),
            None,
        );
    }
    println!("\n=== roofline: CPU kernel layer (fused vs naive, GFLOP/s, GB/s) ===");
    roof_table.print();

    // ---- bns_mlp_field exec: allocations per eval through the pool ------
    //
    // The real-compute analogue of the stub alloc section above: a full
    // `eval_into` through solver buffer -> ModelField -> lane RPC -> MLP
    // backend -> intra-lane row pool and back must allocate ZERO times at
    // steady state. This is the `mlp_allocs_per_eval` STRICT gate.
    let (mlp_allocs_per_eval, mlp_eval_us) = {
        let (store, dir) = mlp_store(
            "perf-mlp",
            &[MlpModelSpec {
                name: "perf_mlp",
                dim: 256,
                hidden: 256,
                emb: 64,
                depth: 2,
                num_classes: 8,
                cfg: true,
                seed: 101,
                buckets: &[64],
            }],
        )?;
        let rt = Runtime::with_config(RuntimeConfig {
            lanes: 1,
            mlp_pool_threads: 2,
            ..Default::default()
        })?;
        let info = store.model("perf_mlp")?.clone();
        let model = Arc::new(LoadedModel::load(&rt, &info)?);
        let field = model.bind((0..64).map(|i| (i % 8) as i32).collect(), 1.5);
        let mut rng = Pcg32::seeded(23);
        let x = rng.normal_vec(64 * info.dim);
        let mut out = vec![0f32; x.len()];
        // warm the lane slot pool, the row pool's job slots, and scratch
        for _ in 0..8 {
            field.eval_into(0.5, &x, &mut out)?;
        }
        let iters = 200usize;
        let a0 = alloc_count();
        let t0 = Instant::now();
        for i in 0..iters {
            field.eval_into(0.1 + 0.8 * (i as f64 / iters as f64), &x, &mut out)?;
        }
        let dt = t0.elapsed().as_secs_f64() / iters as f64;
        let allocs = (alloc_count() - a0) as f64 / iters as f64;
        std::fs::remove_dir_all(&dir).ok();
        (allocs, dt * 1e6)
    };
    println!(
        "\n=== bns_mlp_field exec (d=256 h=256 depth=2 cfg, batch=64, pool=2) ===\n\
         eval_into: {mlp_eval_us:.1} us/eval, {mlp_allocs_per_eval:.3} allocs/eval"
    );
    if mlp_allocs_per_eval > 0.0 {
        eprintln!(
            "[perf] WARNING: mlp eval_into allocated {mlp_allocs_per_eval:.3}/eval — \
             expected 0 at steady state"
        );
    }
    results.push(Json::obj(vec![
        ("artifact", Json::Str("mlp-eval-pooled".into())),
        ("batch", Json::Num(64.0)),
        ("allocs_per_eval", Json::Num(mlp_allocs_per_eval)),
        ("eval_us", Json::Num(mlp_eval_us)),
    ]));

    // ---- intra-lane pool: bit-identity across pool sizes {1, 2, 4} ------
    //
    // Full NS samples (dense solver, nfe=8) through complete runtimes
    // whose only difference is `mlp_pool_threads`. GradFan discipline:
    // the chunk grid is fixed, so the thread count can never change bits.
    let pool_bit_identical = {
        let (store, dir) = mlp_store(
            "perf-pool",
            &[MlpModelSpec {
                name: "pool_mlp",
                dim: 64,
                hidden: 96,
                emb: 16,
                depth: 2,
                num_classes: 8,
                cfg: true,
                seed: 7,
                buckets: &[64],
            }],
        )?;
        let info = store.model("pool_mlp")?.clone();
        let solver = dense_ns(8);
        let mut rng = Pcg32::seeded(3);
        let x0 = rng.normal_vec(64 * info.dim);
        let labels: Vec<i32> = (0..64).map(|i| (i % 8) as i32).collect();
        let mut base: Option<Vec<u32>> = None;
        let mut same = true;
        for threads in [1usize, 2, 4] {
            let rt = Runtime::with_config(RuntimeConfig {
                lanes: 1,
                mlp_pool_threads: threads,
                ..Default::default()
            })?;
            let model = Arc::new(LoadedModel::load(&rt, &info)?);
            let field = model.bind(labels.clone(), 0.3);
            let x1 = solver.sample(&field, &x0)?;
            let bits: Vec<u32> = x1.iter().map(|v| v.to_bits()).collect();
            match &base {
                None => base = Some(bits),
                Some(b) => same &= *b == bits,
            }
        }
        std::fs::remove_dir_all(&dir).ok();
        same
    };
    assert!(pool_bit_identical, "NS samples drifted across mlp pool sizes {{1, 2, 4}}");
    println!("pool bit-identity across sizes {{1, 2, 4}}: ok");

    // ---- machine-readable roofline + gates (tracked PR-over-PR) ---------
    let bench = Json::obj(vec![
        ("roofline", Json::Arr(roofline)),
        (
            "gates",
            Json::obj(vec![
                ("fused_speedup_vs_naive", Json::Num(fused_speedup)),
                ("mlp_allocs_per_eval", Json::Num(mlp_allocs_per_eval)),
                ("pool_bit_identical", Json::Bool(pool_bit_identical)),
            ]),
        ),
        ("results", Json::Arr(results.clone())),
    ]);
    let out_path =
        std::env::var("BENCH_PERF_OUT").unwrap_or_else(|_| "BENCH_perf.json".to_string());
    std::fs::write(&out_path, bench.to_string())?;
    println!("wrote {out_path}");

    let path = write_results("perf_layers", &Json::Arr(results))?;
    println!("\nwrote {}", path.display());
    Ok(())
}
