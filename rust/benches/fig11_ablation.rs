//! Figure 11: ablation — Non-Stationary vs Scale-Time family, both
//! optimized with the same Algorithm 2 budget (python/compile/bns.py
//! trains both). PSNR vs NFE on img_fm_ot; the gap is the expressiveness
//! margin Theorem 3.2 predicts (ST ⊊ NS).
//!
//! Also reports each family's parameter count at every NFE, making the
//! capacity/accuracy trade explicit.

use bns_serve::bench_util::{write_results, Bench, Table};
use bns_serve::solver::Solver;
use bns_serve::util::json::Json;
use bns_serve::util::stats::batch_psnr;

const MODEL: &str = "img_fm_ot";
const EVAL_N: usize = 48;

fn main() -> anyhow::Result<()> {
    let b = Bench::init()?;
    let info = b.store.model(MODEL)?.clone();
    let (x0, labels) = b.eval_set(&info, EVAL_N, 2024);
    let field = b.field(&info, labels, 0.0)?;
    let (gt, _) = b.ground_truth(&field, &x0)?;

    let mut table = Table::new(&["NFE", "BNS PSNR", "BST PSNR", "gap(dB)", "BNS params", "BST params"]);
    let mut results = Vec::new();

    let bst_arts = b.store.solvers_for(MODEL, 0.0, "bst");
    for art in &bst_arts {
        let nfe = art.solver.nfe();
        let bns = match b
            .store
            .solvers_for(MODEL, 0.0, "bns")
            .into_iter()
            .find(|s| s.solver.nfe() == nfe)
        {
            Some(s) => s,
            None => continue,
        };
        let p_bns = batch_psnr(&bns.solver.sample(&field, &x0)?, &gt, info.dim);
        let p_bst = batch_psnr(&art.solver.sample(&field, &x0)?, &gt, info.dim);
        // BST parameter count: per-node (t, ṫ, s, ṡ) = 4(n+1) minus pins
        let bst_params = 4 * (nfe + 1) - 3;
        table.row(vec![
            nfe.to_string(),
            format!("{p_bns:.2}"),
            format!("{p_bst:.2}"),
            format!("{:+.2}", p_bns - p_bst),
            bns.solver.num_params().to_string(),
            bst_params.to_string(),
        ]);
        results.push(Json::obj(vec![
            ("nfe", Json::Num(nfe as f64)),
            ("bns_psnr", Json::Num(p_bns)),
            ("bst_psnr", Json::Num(p_bst)),
        ]));
    }
    println!("=== Fig 11: BNS vs BST (both trained with Algorithm 2) ===");
    table.print();

    let path = write_results("fig11_ablation", &Json::Arr(results))?;
    println!("\nwrote {}", path.display());
    Ok(())
}
