//! L3 coordinator load bench (EXPERIMENTS.md §Perf): throughput and
//! latency of the serving engine under concurrent request load, with the
//! step-aligned batcher ON vs OFF (max_wait = 0 disables coalescing).
//!
//! Reports: requests/s, samples/s, model evals, mean rows per model-eval
//! batch (the continuous-batching win), queue/exec/e2e latency
//! percentiles.

use std::sync::Arc;
use std::time::{Duration, Instant};

use bns_serve::bench_util::{write_results, Bench, Table};
use bns_serve::coordinator::{Engine, EngineConfig, SolverSpec};
use bns_serve::coordinator::batcher::BatcherConfig;
use bns_serve::util::json::Json;

const MODEL: &str = "img_fm_ot";
const CLIENTS: usize = 8;
const REQS_PER_CLIENT: usize = 12;
const SAMPLES_PER_REQ: usize = 4;

fn run_load(b: &Bench, max_wait_ms: u64, label: &str) -> anyhow::Result<Json> {
    let engine = Arc::new(Engine::start(
        b.store.clone(),
        b.rt.clone(),
        EngineConfig {
            batcher: BatcherConfig {
                max_rows: 64,
                max_wait: Duration::from_millis(max_wait_ms),
                max_queued_rows: 4096,
            },
            workers: 2,
        },
    ));
    // warmup: compile executables before timing
    engine.sample_blocking(
        MODEL,
        vec![0; SAMPLES_PER_REQ],
        0.0,
        SolverSpec::Auto { nfe: 8 },
        1,
    )?;

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let engine = engine.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<()> {
            for r in 0..REQS_PER_CLIENT {
                let labels: Vec<i32> = (0..SAMPLES_PER_REQ).map(|i| ((c + i + r) % 10) as i32).collect();
                engine.sample_blocking(
                    MODEL,
                    labels,
                    0.0,
                    SolverSpec::Auto { nfe: 8 },
                    (c * 1000 + r) as u64,
                )?;
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().unwrap()?;
    }
    let wall = t0.elapsed().as_secs_f64();

    let m = engine.metrics.snapshot_json();
    let total_reqs = (CLIENTS * REQS_PER_CLIENT) as f64;
    let out = Json::obj(vec![
        ("config", Json::Str(label.to_string())),
        ("wall_s", Json::Num(wall)),
        ("req_per_s", Json::Num(total_reqs / wall)),
        ("samples_per_s", Json::Num(total_reqs * SAMPLES_PER_REQ as f64 / wall)),
        ("mean_batch_rows", m.get("mean_batch_rows").clone()),
        ("evals", m.get("evals").clone()),
        ("e2e_p50_us", m.get("e2e").get("p50_us").clone()),
        ("e2e_p95_us", m.get("e2e").get("p95_us").clone()),
        ("queue_p95_us", m.get("queue").get("p95_us").clone()),
    ]);
    Arc::try_unwrap(engine).ok().map(|e| e.shutdown());
    Ok(out)
}

fn main() -> anyhow::Result<()> {
    let b = Bench::init()?;
    let mut table = Table::new(&[
        "config", "req/s", "samples/s", "rows/eval-batch", "evals", "p50 e2e(ms)", "p95 e2e(ms)",
    ]);
    let mut results = Vec::new();
    for (wait, label) in [(0u64, "batcher-off(wait=0)"), (4, "batcher-on(wait=4ms)"), (12, "batcher-on(wait=12ms)")] {
        let r = run_load(&b, wait, label)?;
        table.row(vec![
            label.into(),
            format!("{:.1}", r.get("req_per_s").as_f64().unwrap_or(0.0)),
            format!("{:.1}", r.get("samples_per_s").as_f64().unwrap_or(0.0)),
            format!("{:.1}", r.get("mean_batch_rows").as_f64().unwrap_or(0.0)),
            format!("{:.0}", r.get("evals").as_f64().unwrap_or(0.0)),
            format!("{:.1}", r.get("e2e_p50_us").as_f64().unwrap_or(0.0) / 1000.0),
            format!("{:.1}", r.get("e2e_p95_us").as_f64().unwrap_or(0.0) / 1000.0),
        ]);
        results.push(r);
    }
    println!("=== L3 serving load (8 clients x 12 reqs x 4 samples, auto/BNS nfe=8) ===");
    table.print();
    let path = write_results("serve_load", &Json::Arr(results))?;
    println!("\nwrote {}", path.display());
    Ok(())
}
