//! L3 coordinator load bench (EXPERIMENTS.md §Perf): throughput and
//! latency of the serving engine under concurrent request load, swept
//! over device-lane / worker configurations.
//!
//! Runs entirely on the *stub* device backend (a `cost`-weighted affine
//! field emulating a heavy model), so it works offline and in CI — no
//! compiled HLO artifacts needed. For every configuration it first runs a
//! fixed sequential probe set and asserts the samples are bit-identical
//! to the single-lane reference (lane pooling must never change results),
//! then measures a concurrent load phase.
//!
//! Reports per config: evals/s, samples/s, mean rows per model-eval batch
//! (the continuous-batching win), queue/exec latency percentiles, and
//! per-lane busy time; plus the **worker-scaling ratio** (best multi-lane
//! evals/s over the single-lane configuration). Machine-readable output
//! goes to `BENCH_serve.json` (path override: `BENCH_SERVE_OUT`) so the
//! perf trajectory is tracked PR-over-PR by ci.sh.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use bns_serve::bench_util::{stub_store, write_results, StubModel, Table};
use bns_serve::coordinator::{Engine, EngineConfig, SolverSpec};
use bns_serve::runtime::Runtime;
use bns_serve::util::json::Json;

const MODEL: &str = "serve_stub";
const DIM: usize = 1024;
const CLIENTS: usize = 8;
const REQS_PER_CLIENT: usize = 16;
const ROWS_PER_REQ: usize = 8;
const PROBES: usize = 6;

fn spec() -> SolverSpec {
    SolverSpec::Auto { nfe: 8 }
}

/// Sequential fixed-seed probe; used for the cross-config bit-identity
/// check.
fn run_probes(engine: &Engine) -> anyhow::Result<Vec<Vec<u32>>> {
    let mut outs = Vec::new();
    for p in 0..PROBES {
        let labels: Vec<i32> = (0..4).map(|i| ((p + i) % 8) as i32).collect();
        let out = engine.sample_blocking(MODEL, labels, 0.0, spec(), 500 + p as u64)?;
        outs.push(out.samples.iter().map(|v| v.to_bits()).collect());
    }
    Ok(outs)
}

struct ConfigResult {
    json: Json,
    evals_per_s: f64,
    probes: Vec<Vec<u32>>,
}

fn run_config(
    store: &Arc<bns_serve::runtime::ArtifactStore>,
    label: &str,
    lanes: usize,
    workers: usize,
) -> anyhow::Result<ConfigResult> {
    let rt = Arc::new(Runtime::with_lanes(lanes)?);
    let engine = Engine::start(store.clone(), rt, EngineConfig { workers, ..Default::default() });

    // warmup compiles every bucket; probes double as the correctness set
    engine.sample_blocking(MODEL, vec![0; ROWS_PER_REQ], 0.0, spec(), 1)?;
    let probes = run_probes(&engine)?;

    let evals_before = engine.metrics.evals.load(Ordering::SeqCst);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let engine = &engine;
            s.spawn(move || {
                for r in 0..REQS_PER_CLIENT {
                    let labels: Vec<i32> =
                        (0..ROWS_PER_REQ).map(|i| ((c + i + r) % 8) as i32).collect();
                    engine
                        .sample_blocking(MODEL, labels, 0.0, spec(), (c * 1000 + r) as u64)
                        .expect("load request failed");
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let evals = (engine.metrics.evals.load(Ordering::SeqCst) - evals_before) as f64;

    let m = engine.metrics.snapshot_json();
    let total_reqs = (CLIENTS * REQS_PER_CLIENT) as f64;
    let evals_per_s = evals / wall;
    let lanes_json = m.get("lanes").clone();
    let json = Json::obj(vec![
        ("config", Json::Str(label.to_string())),
        ("lanes", Json::Num(lanes as f64)),
        ("workers", Json::Num(workers as f64)),
        ("wall_s", Json::Num(wall)),
        ("evals", Json::Num(evals)),
        ("evals_per_s", Json::Num(evals_per_s)),
        ("req_per_s", Json::Num(total_reqs / wall)),
        (
            "samples_per_s",
            Json::Num(total_reqs * ROWS_PER_REQ as f64 / wall),
        ),
        ("mean_batch_rows", m.get("mean_batch_rows").clone()),
        ("queue_p50_us", m.get("queue").get("p50_us").clone()),
        ("queue_p95_us", m.get("queue").get("p95_us").clone()),
        ("exec_p50_us", m.get("exec").get("p50_us").clone()),
        ("exec_p95_us", m.get("exec").get("p95_us").clone()),
        ("lane_stats", lanes_json),
    ]);
    engine.shutdown();
    Ok(ConfigResult { json, evals_per_s, probes })
}

fn main() -> anyhow::Result<()> {
    let (store, dir) = stub_store(
        "serve-load",
        &[StubModel {
            name: MODEL,
            dim: DIM,
            num_classes: 8,
            forwards_per_eval: 2,
            k: -0.8,
            c: 0.05,
            label_scale: 0.01,
            cost: 6,
            buckets: &[16, 64],
        }],
    )?;

    // (label, lanes, workers); index 1 is the single-lane baseline the
    // scaling ratio is measured against
    let configs: &[(&str, usize, usize)] = &[
        ("lanes=1 workers=1", 1, 1),
        ("lanes=1 workers=2", 1, 2),
        ("lanes=2 workers=2", 2, 2),
        ("lanes=4 workers=4", 4, 4),
    ];

    let mut table = Table::new(&[
        "config", "evals/s", "samples/s", "rows/eval-batch", "exec p50(ms)", "queue p95(ms)",
    ]);
    let mut results = Vec::new();
    let mut baseline_probes: Option<Vec<Vec<u32>>> = None;
    let mut single_lane_eps = 0.0f64;
    let mut best_multi_eps = 0.0f64;
    for (i, &(label, lanes, workers)) in configs.iter().enumerate() {
        let r = run_config(&store, label, lanes, workers)?;
        if baseline_probes.is_none() {
            baseline_probes = Some(r.probes.clone());
        } else {
            let want = baseline_probes.as_ref().unwrap();
            assert_eq!(
                &r.probes, want,
                "{label}: samples drifted from the single-lane reference"
            );
        }
        if i == 1 {
            single_lane_eps = r.evals_per_s;
        }
        if lanes > 1 && workers > 1 {
            best_multi_eps = best_multi_eps.max(r.evals_per_s);
        }
        table.row(vec![
            label.into(),
            format!("{:.1}", r.evals_per_s),
            format!("{:.1}", r.json.get("samples_per_s").as_f64().unwrap_or(0.0)),
            format!("{:.1}", r.json.get("mean_batch_rows").as_f64().unwrap_or(0.0)),
            format!("{:.2}", r.json.get("exec_p50_us").as_f64().unwrap_or(0.0) / 1000.0),
            format!("{:.2}", r.json.get("queue_p95_us").as_f64().unwrap_or(0.0) / 1000.0),
        ]);
        results.push(r.json);
    }
    let scaling = if single_lane_eps > 0.0 { best_multi_eps / single_lane_eps } else { 0.0 };

    println!(
        "=== L3 serving load ({CLIENTS} clients x {REQS_PER_CLIENT} reqs x {ROWS_PER_REQ} rows, \
         auto nfe=8, stub dim={DIM} cost=6) ==="
    );
    table.print();
    println!("\nworker-scaling ratio (best multi-lane / single-lane): {scaling:.2}x");
    println!("bit-identical across configs: yes (asserted)");

    let bench = Json::obj(vec![
        ("bench", Json::Str("serve_load".into())),
        (
            "workload",
            Json::obj(vec![
                ("clients", Json::Num(CLIENTS as f64)),
                ("reqs_per_client", Json::Num(REQS_PER_CLIENT as f64)),
                ("rows_per_req", Json::Num(ROWS_PER_REQ as f64)),
                ("model_dim", Json::Num(DIM as f64)),
                ("stub_cost", Json::Num(6.0)),
                ("solver", Json::Str("auto nfe=8".into())),
            ]),
        ),
        ("configs", Json::Arr(results.clone())),
        ("single_lane_evals_per_s", Json::Num(single_lane_eps)),
        ("best_multi_lane_evals_per_s", Json::Num(best_multi_eps)),
        ("worker_scaling_ratio", Json::Num(scaling)),
        ("bit_identical", Json::Bool(true)),
    ]);
    let out_path =
        std::env::var("BENCH_SERVE_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    std::fs::write(&out_path, bench.to_string())?;
    println!("wrote {out_path}");
    let path = write_results("serve_load", &Json::Arr(results))?;
    println!("wrote {}", path.display());

    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
