//! L3 coordinator load bench (EXPERIMENTS.md §Perf): throughput and
//! latency of the serving engine under concurrent request load, swept
//! over device-lane / worker configurations.
//!
//! Runs entirely on the *stub* device backend (a `cost`-weighted affine
//! field emulating a heavy model), so it works offline and in CI — no
//! compiled HLO artifacts needed. For every configuration it first runs a
//! fixed sequential probe set and asserts the samples are bit-identical
//! to the single-lane reference (lane pooling must never change results),
//! then measures a concurrent load phase.
//!
//! Reports per config: evals/s, samples/s, mean rows per model-eval batch
//! (the continuous-batching win), queue/exec latency percentiles, and
//! per-lane busy time; plus the **worker-scaling ratio** (best multi-lane
//! evals/s over the single-lane configuration). Machine-readable output
//! goes to `BENCH_serve.json` (path override: `BENCH_SERVE_OUT`) so the
//! perf trajectory is tracked PR-over-PR by ci.sh.
//!
//! A second phase drives the **event-driven TCP serving plane** into
//! sustained overload (offered rows ≫ the engine's in-flight budget) and
//! asserts the admission-control contract of DESIGN.md §9: rejects are
//! structured `err=overloaded` lines with a `retry_after_ms` hint and
//! are counted in metrics, accepted-request p95 stays bounded (no
//! unbounded queue growth), and TCP-path samples are bit-identical to
//! the in-process blocking path. Results land in the `overload` section
//! of `BENCH_serve.json`.
//!
//! A third phase measures **fault recovery** (DESIGN.md §11): a
//! deterministic fault schedule wedges the only device lane past its
//! exec timeout, and the phase records how long until the supervisor's
//! respawn restores service — plus retry/respawn/fault counters and a
//! bit-identity check of the recovered output against a fault-free
//! engine. Results land in the `fault_recovery` section, which ci.sh
//! gates on under STRICT=1.
//!
//! A fourth phase measures **tracing-plane overhead** (DESIGN.md §12):
//! the same concurrent load runs with the span recorder enabled
//! (default capacity) and disabled (`trace_capacity = 0`), reporting the
//! on-vs-off evals/s ratio, and a counting global allocator proves the
//! steady-state `record` (seqlock ring write) and `record_latency`
//! (interned per-solver histogram) hot paths allocate nothing per event.
//! Results land in the `trace_overhead` section; ci.sh gates the
//! throughput overhead at ≤3% under STRICT=1 (the 0-alloc checks are
//! hard asserts either way).
//!
//! A fifth phase measures the **fleet plane** (DESIGN.md §14): three
//! models served across two consistent-hash shards while two of them
//! churn through hot `unload`/`load` cycles. It reports evals/s under
//! churn, the reject mix, and time-to-first-sample after each reload
//! (the lazy per-lane recompile cost), and asserts zero lost requests
//! with every successful sample bit-identical to a quiescent engine.
//! Results land in the `fleet_churn` section; ci.sh gates
//! `fleet_bit_identical` and `lost_requests` under STRICT=1.

use std::alloc::{GlobalAlloc, Layout, System};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use bns_serve::bench_util::{stub_store, write_results, StubModel, Table};
use bns_serve::coordinator::metrics::Metrics;
use bns_serve::coordinator::{
    Engine, EngineConfig, Fleet, FleetConfig, Server, ServerConfig, SolverSpec,
};
use bns_serve::obs::{TraceRecorder, TraceStage};
use bns_serve::runtime::{
    FaultConfig, FaultKind, FaultPlan, FaultSpec, Runtime, RuntimeConfig,
};
use bns_serve::util::json::Json;

const MODEL: &str = "serve_stub";
const DIM: usize = 1024;
const CLIENTS: usize = 8;
const REQS_PER_CLIENT: usize = 16;
const ROWS_PER_REQ: usize = 8;
const PROBES: usize = 6;

/// Counts every heap allocation in the process (all threads), so the
/// trace_overhead phase can prove the tracing hot paths are alloc-free.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

fn spec() -> SolverSpec {
    SolverSpec::Auto { nfe: 8 }
}

/// Sequential fixed-seed probe; used for the cross-config bit-identity
/// check.
fn run_probes(engine: &Engine) -> anyhow::Result<Vec<Vec<u32>>> {
    let mut outs = Vec::new();
    for p in 0..PROBES {
        let labels: Vec<i32> = (0..4).map(|i| ((p + i) % 8) as i32).collect();
        let out = engine.sample_blocking(MODEL, labels, 0.0, spec(), 500 + p as u64)?;
        outs.push(out.samples.iter().map(|v| v.to_bits()).collect());
    }
    Ok(outs)
}

struct ConfigResult {
    json: Json,
    evals_per_s: f64,
    probes: Vec<Vec<u32>>,
}

fn run_config(
    store: &Arc<bns_serve::runtime::ArtifactStore>,
    label: &str,
    lanes: usize,
    workers: usize,
) -> anyhow::Result<ConfigResult> {
    let rt = Arc::new(Runtime::with_lanes(lanes)?);
    let engine = Engine::start(store.clone(), rt, EngineConfig { workers, ..Default::default() })?;

    // warmup compiles every bucket; probes double as the correctness set
    engine.sample_blocking(MODEL, vec![0; ROWS_PER_REQ], 0.0, spec(), 1)?;
    let probes = run_probes(&engine)?;

    let evals_before = engine.metrics.evals.load(Ordering::SeqCst);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let engine = &engine;
            s.spawn(move || {
                for r in 0..REQS_PER_CLIENT {
                    let labels: Vec<i32> =
                        (0..ROWS_PER_REQ).map(|i| ((c + i + r) % 8) as i32).collect();
                    engine
                        .sample_blocking(MODEL, labels, 0.0, spec(), (c * 1000 + r) as u64)
                        .expect("load request failed");
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let evals = (engine.metrics.evals.load(Ordering::SeqCst) - evals_before) as f64;

    let m = engine.metrics.snapshot_json();
    let total_reqs = (CLIENTS * REQS_PER_CLIENT) as f64;
    let evals_per_s = evals / wall;
    let lanes_json = m.get("lanes").clone();
    let json = Json::obj(vec![
        ("config", Json::Str(label.to_string())),
        ("lanes", Json::Num(lanes as f64)),
        ("workers", Json::Num(workers as f64)),
        ("wall_s", Json::Num(wall)),
        ("evals", Json::Num(evals)),
        ("evals_per_s", Json::Num(evals_per_s)),
        ("req_per_s", Json::Num(total_reqs / wall)),
        (
            "samples_per_s",
            Json::Num(total_reqs * ROWS_PER_REQ as f64 / wall),
        ),
        ("mean_batch_rows", m.get("mean_batch_rows").clone()),
        ("queue_p50_us", m.get("queue").get("p50_us").clone()),
        ("queue_p95_us", m.get("queue").get("p95_us").clone()),
        ("exec_p50_us", m.get("exec").get("p50_us").clone()),
        ("exec_p95_us", m.get("exec").get("p95_us").clone()),
        ("lane_stats", lanes_json),
    ]);
    engine.shutdown();
    Ok(ConfigResult { json, evals_per_s, probes })
}

// ---------------------------------------------------------------------------
// overload phase (TCP serving plane under admission control)
// ---------------------------------------------------------------------------

const OVER_CLIENTS: usize = 12;
const OVER_REQS_PER_CLIENT: usize = 25;
const OVER_MAX_INFLIGHT_ROWS: usize = 64;

/// One blocking JSON-lines client over the event-driven server.
struct TcpClient {
    w: TcpStream,
    r: BufReader<TcpStream>,
}

impl TcpClient {
    fn connect(addr: std::net::SocketAddr) -> anyhow::Result<TcpClient> {
        let w = TcpStream::connect(addr)?;
        w.set_read_timeout(Some(Duration::from_secs(60)))?;
        let r = BufReader::new(w.try_clone()?);
        Ok(TcpClient { w, r })
    }

    fn roundtrip(&mut self, line: &str) -> anyhow::Result<Json> {
        self.w.write_all(line.as_bytes())?;
        self.w.write_all(b"\n")?;
        let mut resp = String::new();
        self.r.read_line(&mut resp)?;
        Ok(Json::parse(&resp)?)
    }

    fn sample_line(labels: &[i32], seed: u64) -> String {
        format!(
            "{{\"op\":\"sample\",\"model\":\"{MODEL}\",\"labels\":{labels:?},\
             \"solver\":\"auto\",\"nfe\":8,\"seed\":{seed}}}"
        )
    }
}

fn percentile_us(sorted: &[u64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[i.min(sorted.len() - 1)] as f64
}

fn run_overload(store: &Arc<bns_serve::runtime::ArtifactStore>) -> anyhow::Result<Json> {
    let rt = Arc::new(Runtime::with_lanes(2)?);
    let engine = Arc::new(Engine::start(
        store.clone(),
        rt,
        EngineConfig {
            workers: 2,
            max_inflight_rows: OVER_MAX_INFLIGHT_ROWS,
            ..Default::default()
        },
    )?);
    let server = Server::bind(
        "127.0.0.1:0",
        ServerConfig { reactors: 2, ..Default::default() },
        engine.clone(),
        store.clone(),
    )?;
    let addr = server.local_addr();

    // 1. bit-identity: the TCP path must reproduce the in-process
    //    blocking path down to the bit for accepted requests
    let mut probe = TcpClient::connect(addr)?;
    for p in 0..4u64 {
        let labels: Vec<i32> = (0..4).map(|i| ((p as usize + i) % 8) as i32).collect();
        let want = engine.sample_blocking(MODEL, labels.clone(), 0.0, spec(), 900 + p)?;
        let j = probe.roundtrip(&TcpClient::sample_line(&labels, 900 + p))?;
        assert_eq!(j.get("ok").as_bool(), Some(true), "probe rejected: {j:?}");
        let got = j.get("samples").as_f32_vec().expect("samples");
        let want_bits: Vec<u32> = want.samples.iter().map(|v| v.to_bits()).collect();
        let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_bits, want_bits, "TCP samples drifted from the blocking path");
    }

    // 2. solo latency (idle server) — the p95 bound is expressed
    //    relative to this so the assert is hardware-independent
    let solo_us = {
        let t = Instant::now();
        let j = probe.roundtrip(&TcpClient::sample_line(&[0; ROWS_PER_REQ], 999))?;
        assert_eq!(j.get("ok").as_bool(), Some(true));
        t.elapsed().as_micros() as u64
    };
    drop(probe);

    // 3. sustained overload: 12 clients x 8 rows offered against a
    //    64-row in-flight budget; no pacing, no retries
    let accepted_us: Mutex<Vec<u64>> = Mutex::new(Vec::new());
    let rejected = std::sync::atomic::AtomicU64::new(0);
    let retry_hints: Mutex<Vec<u64>> = Mutex::new(Vec::new());
    let unexpected: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..OVER_CLIENTS {
            let accepted_us = &accepted_us;
            let rejected = &rejected;
            let retry_hints = &retry_hints;
            let unexpected = &unexpected;
            s.spawn(move || {
                let mut cl = TcpClient::connect(addr).expect("connect");
                for r in 0..OVER_REQS_PER_CLIENT {
                    let labels: Vec<i32> =
                        (0..ROWS_PER_REQ).map(|i| ((c + i + r) % 8) as i32).collect();
                    let t = Instant::now();
                    let j = cl
                        .roundtrip(&TcpClient::sample_line(&labels, (c * 1000 + r) as u64))
                        .expect("roundtrip");
                    if j.get("ok").as_bool() == Some(true) {
                        accepted_us.lock().unwrap().push(t.elapsed().as_micros() as u64);
                    } else if j.get("err").as_str() == Some("overloaded") {
                        rejected.fetch_add(1, Ordering::Relaxed);
                        match j.get("retry_after_ms").as_f64() {
                            Some(ms) => retry_hints.lock().unwrap().push(ms as u64),
                            None => unexpected
                                .lock()
                                .unwrap()
                                .push(format!("overloaded without retry_after_ms: {j:?}")),
                        }
                    } else {
                        unexpected.lock().unwrap().push(format!("{j:?}"));
                    }
                }
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let mut acc = accepted_us.into_inner().unwrap();
    acc.sort_unstable();
    let rejected = rejected.into_inner();
    let unexpected = unexpected.into_inner().unwrap();
    let retry_hints = retry_hints.into_inner().unwrap();
    assert!(unexpected.is_empty(), "non-overload errors under load: {unexpected:?}");
    assert!(
        rejected > 0,
        "overload phase produced no rejects — offered load no longer exceeds the budget"
    );
    assert!(!acc.is_empty(), "overload phase accepted nothing");
    // bounded p95 for accepted work: admission keeps the queue short, so
    // accepted latency stays within a small multiple of solo latency
    // (generous bound — this guards against unbounded queue growth, not
    // scheduler jitter)
    let p95 = percentile_us(&acc, 0.95);
    let bound = (50 * solo_us).max(2_000_000) as f64;
    assert!(
        p95 <= bound,
        "accepted p95 {p95:.0}us exceeds bound {bound:.0}us (solo {solo_us}us) — \
         queue growth under overload"
    );

    // 4. metrics surface the rejects (stats op over the same wire)
    let mut probe = TcpClient::connect(addr)?;
    let stats = probe.roundtrip("{\"op\":\"stats\"}")?;
    let m_rej = stats.get("rejected_overload").as_f64().unwrap_or(0.0);
    assert!(m_rej >= rejected as f64, "metrics missed rejects: {m_rej} < {rejected}");
    assert!(stats.get("connections").as_f64().unwrap_or(0.0) >= 1.0);
    drop(probe);

    server.shutdown();
    drop(engine); // Drop joins the engine threads

    let total = (OVER_CLIENTS * OVER_REQS_PER_CLIENT) as u64;
    let mean_retry = if retry_hints.is_empty() {
        0.0
    } else {
        retry_hints.iter().sum::<u64>() as f64 / retry_hints.len() as f64
    };
    Ok(Json::obj(vec![
        ("clients", Json::Num(OVER_CLIENTS as f64)),
        ("reqs_per_client", Json::Num(OVER_REQS_PER_CLIENT as f64)),
        ("rows_per_req", Json::Num(ROWS_PER_REQ as f64)),
        ("max_inflight_rows", Json::Num(OVER_MAX_INFLIGHT_ROWS as f64)),
        ("wall_s", Json::Num(wall_s)),
        ("offered", Json::Num(total as f64)),
        ("accepted", Json::Num(acc.len() as f64)),
        ("rejected_overload", Json::Num(rejected as f64)),
        ("reject_rate", Json::Num(rejected as f64 / total as f64)),
        ("solo_us", Json::Num(solo_us as f64)),
        ("accepted_p50_us", Json::Num(percentile_us(&acc, 0.5))),
        ("accepted_p95_us", Json::Num(p95)),
        ("mean_retry_after_ms", Json::Num(mean_retry)),
        ("bit_identical_tcp", Json::Bool(true)),
    ]))
}

// ---------------------------------------------------------------------------
// fault-recovery phase (lane wedge -> supervisor respawn -> service restored)
// ---------------------------------------------------------------------------

const FAULT_WEDGE_MS: u64 = 400;
const FAULT_LANE_TIMEOUT_MS: u64 = 100;

fn run_fault_recovery(store: &Arc<bns_serve::runtime::ArtifactStore>) -> anyhow::Result<Json> {
    // fault-free reference output for the probe request
    let labels = vec![0i32, 1, 2, 3];
    let want_bits: Vec<u32> = {
        let rt = Arc::new(Runtime::cpu()?);
        let engine = Engine::start(store.clone(), rt, EngineConfig::default())?;
        let out = engine.sample_blocking(MODEL, labels.clone(), 0.0, spec(), 4242)?;
        engine.shutdown();
        out.samples.iter().map(|v| v.to_bits()).collect()
    };

    // the very first exec on lane 0 wedges for FAULT_WEDGE_MS, well past
    // the lane exec timeout — the supervisor must respawn the lane
    let plan = Arc::new(FaultPlan::new(FaultConfig {
        schedule: vec![FaultSpec { lane: Some(0), call: 0, kind: FaultKind::Wedge }],
        wedge_ms: FAULT_WEDGE_MS,
        ..Default::default()
    }));
    let rt = Arc::new(Runtime::with_config(RuntimeConfig {
        lanes: 1,
        lane_exec_timeout: Duration::from_millis(FAULT_LANE_TIMEOUT_MS),
        fault: Some(plan),
        ..Default::default()
    })?);
    let engine = Engine::start(
        store.clone(),
        rt.clone(),
        EngineConfig {
            workers: 1,
            exec_retries: 1,
            retry_backoff_ms: 5,
            breaker_threshold: 3,
            breaker_cooldown_ms: 200,
            ..Default::default()
        },
    )?;

    // hammer the same probe until service is restored; every attempt
    // terminates (timeout -> structured error), so this loop never hangs
    let t0 = Instant::now();
    let deadline = t0 + Duration::from_secs(30);
    let mut failed_attempts = 0u64;
    let recovered = loop {
        match engine.sample_blocking(MODEL, labels.clone(), 0.0, spec(), 4242) {
            Ok(out) => break out,
            Err(e) => {
                failed_attempts += 1;
                assert!(
                    Instant::now() < deadline,
                    "service never recovered from the wedge: {e:#}"
                );
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    };
    let time_to_recover_ms = t0.elapsed().as_secs_f64() * 1000.0;
    let got_bits: Vec<u32> = recovered.samples.iter().map(|v| v.to_bits()).collect();
    assert_eq!(got_bits, want_bits, "recovered samples drifted from the fault-free reference");

    let retries = engine.metrics.exec_retries.load(Ordering::SeqCst);
    let breaker_open = engine.metrics.breaker_open.load(Ordering::SeqCst);
    let respawns = rt.respawns_total();
    let faults = rt.faults_injected();
    assert!(respawns >= 1, "wedge never triggered a lane respawn");
    assert_eq!(faults, 1, "exactly the scheduled wedge fires");
    engine.shutdown();

    Ok(Json::obj(vec![
        ("wedge_ms", Json::Num(FAULT_WEDGE_MS as f64)),
        ("lane_exec_timeout_ms", Json::Num(FAULT_LANE_TIMEOUT_MS as f64)),
        ("time_to_recover_ms", Json::Num(time_to_recover_ms)),
        ("failed_attempts", Json::Num(failed_attempts as f64)),
        ("exec_retries", Json::Num(retries as f64)),
        ("lane_respawns", Json::Num(respawns as f64)),
        ("breaker_open", Json::Num(breaker_open as f64)),
        ("faults_injected", Json::Num(faults as f64)),
        ("bit_identical_after_recovery", Json::Bool(true)),
    ]))
}

// ---------------------------------------------------------------------------
// trace_overhead phase (span recorder on-vs-off throughput + allocs/event)
// ---------------------------------------------------------------------------

const TRACE_CLIENTS: usize = 4;
const TRACE_REQS_PER_CLIENT: usize = 12;
const TRACE_EVENTS: u64 = 65_536;

/// evals/s of a fixed concurrent load at the given trace capacity
/// (0 disables the recorder entirely).
fn trace_throughput(
    store: &Arc<bns_serve::runtime::ArtifactStore>,
    trace_capacity: usize,
) -> anyhow::Result<f64> {
    let rt = Arc::new(Runtime::with_lanes(2)?);
    let engine = Engine::start(
        store.clone(),
        rt,
        EngineConfig { workers: 2, trace_capacity, ..Default::default() },
    )?;
    engine.sample_blocking(MODEL, vec![0; ROWS_PER_REQ], 0.0, spec(), 1)?;
    let evals_before = engine.metrics.evals.load(Ordering::SeqCst);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..TRACE_CLIENTS {
            let engine = &engine;
            s.spawn(move || {
                for r in 0..TRACE_REQS_PER_CLIENT {
                    let labels: Vec<i32> =
                        (0..ROWS_PER_REQ).map(|i| ((c + i + r) % 8) as i32).collect();
                    engine
                        .sample_blocking(MODEL, labels, 0.0, spec(), (c * 100 + r) as u64)
                        .expect("trace-overhead load request failed");
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let evals = (engine.metrics.evals.load(Ordering::SeqCst) - evals_before) as f64;
    engine.shutdown();
    Ok(evals / wall)
}

fn run_trace_overhead(store: &Arc<bns_serve::runtime::ArtifactStore>) -> anyhow::Result<Json> {
    // 1. steady-state allocation counts, measured while the process is
    //    otherwise quiet (all engines from earlier phases are down).
    //    After warmup, the seqlock ring write must never touch the heap…
    let rec = TraceRecorder::new(4096);
    for i in 0..1024u64 {
        rec.record(i, TraceStage::Admit, 0, 0);
    }
    let before = alloc_count();
    for i in 0..TRACE_EVENTS {
        rec.record(i, TraceStage::ExecOk, i, i * 2);
    }
    let allocs_per_record = (alloc_count() - before) as f64 / TRACE_EVENTS as f64;
    assert_eq!(
        allocs_per_record, 0.0,
        "TraceRecorder::record allocated in steady state"
    );

    // …and neither must the per-solver latency path once its key is
    // interned (the one-time String allocation lives in intern_solver)
    let metrics = Metrics::new();
    metrics.record_latency(10, 20, "bespoke_ns");
    let before = alloc_count();
    for i in 0..TRACE_EVENTS {
        metrics.record_latency(10 + i % 7, 20 + i % 11, "bespoke_ns");
    }
    let allocs_per_latency = (alloc_count() - before) as f64 / TRACE_EVENTS as f64;
    assert_eq!(
        allocs_per_latency, 0.0,
        "Metrics::record_latency allocated on an interned solver key"
    );

    // 2. throughput ratio: interleave off/on twice and keep the best of
    //    each, so a one-off scheduler hiccup doesn't read as overhead
    let mut eps_off = 0.0f64;
    let mut eps_on = 0.0f64;
    for _ in 0..2 {
        eps_off = eps_off.max(trace_throughput(store, 0)?);
        eps_on = eps_on.max(trace_throughput(store, 4096)?);
    }
    let overhead_pct =
        if eps_off > 0.0 { (100.0 * (1.0 - eps_on / eps_off)).max(0.0) } else { 0.0 };

    Ok(Json::obj(vec![
        ("trace_capacity", Json::Num(4096.0)),
        ("events_measured", Json::Num(TRACE_EVENTS as f64)),
        ("allocs_per_record_event", Json::Num(allocs_per_record)),
        ("allocs_per_record_latency", Json::Num(allocs_per_latency)),
        ("evals_per_s_tracing_off", Json::Num(eps_off)),
        ("evals_per_s_tracing_on", Json::Num(eps_on)),
        ("overhead_pct", Json::Num(overhead_pct)),
    ]))
}

// ---------------------------------------------------------------------------
// fleet_churn phase (multi-model shard fleet under hot load/unload cycles)
// ---------------------------------------------------------------------------

const FLEET_MODELS: [&str; 3] = ["fleet_a", "fleet_b", "fleet_c"];
const FLEET_SHARDS: usize = 2;
const FLEET_CLIENTS_PER_MODEL: usize = 2;
const FLEET_REQS_PER_CLIENT: usize = 20;

fn fleet_sample_line(model: &str, seed: u64, tag: &str) -> String {
    format!(
        "{{\"op\":\"sample\",\"model\":\"{model}\",\"labels\":[0,1,2],\
         \"solver\":\"euler\",\"nfe\":6,\"seed\":{seed},\"tag\":\"{tag}\"}}"
    )
}

fn run_fleet_churn() -> anyhow::Result<Json> {
    let stubs: Vec<StubModel> = FLEET_MODELS
        .iter()
        .enumerate()
        .map(|(i, name)| StubModel {
            name,
            dim: 16,
            num_classes: 4,
            forwards_per_eval: 1,
            k: -0.4 - 0.1 * i as f64,
            c: 0.05 + 0.1 * i as f64,
            label_scale: 0.02,
            cost: 1,
            buckets: &[4, 8],
        })
        .collect();
    let (store, dir) = stub_store("serve-load-fleet", &stubs)?;

    // quiescent reference: per-(model, seed) sample bits from a fresh
    // single engine with no churn anywhere near it
    let mut want: std::collections::BTreeMap<(String, u64), Vec<u32>> = Default::default();
    {
        let rt = Arc::new(Runtime::cpu()?);
        let engine = Engine::start(store.clone(), rt, EngineConfig::default())?;
        for m in FLEET_MODELS {
            for seed in 1..=4u64 {
                let out = engine.sample_blocking(
                    m,
                    vec![0, 1, 2],
                    0.0,
                    SolverSpec::Baseline { name: "euler".into(), nfe: 6 },
                    seed,
                )?;
                want.insert(
                    (m.to_string(), seed),
                    out.samples.iter().map(|v| v.to_bits()).collect(),
                );
            }
        }
        engine.shutdown();
    }

    let rt = Arc::new(Runtime::with_lanes(2)?);
    let fleet = Fleet::start(
        store.clone(),
        rt,
        FleetConfig {
            shards: FLEET_SHARDS,
            engine: EngineConfig { workers: 2, ..Default::default() },
        },
    )?;
    let server = Server::bind_fleet(
        "127.0.0.1:0",
        ServerConfig { reactors: 2, ..Default::default() },
        fleet.clone(),
    )?;
    let addr = server.local_addr();

    let evals_before: u64 = (0..fleet.num_shards())
        .filter_map(|s| fleet.engine(s))
        .map(|e| e.metrics.evals.load(Ordering::SeqCst))
        .sum();
    let stop = std::sync::atomic::AtomicBool::new(false);
    let accepted = AtomicU64::new(0);
    let rejected_unknown = AtomicU64::new(0);
    let lost = AtomicU64::new(0);
    let mismatched = AtomicU64::new(0);
    let unexpected: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let ttfs_ms: Mutex<Vec<f64>> = Mutex::new(Vec::new());
    let reload_cycles = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for (mi, model) in FLEET_MODELS.iter().enumerate() {
            for ci in 0..FLEET_CLIENTS_PER_MODEL {
                let want = &want;
                let (accepted, rejected_unknown) = (&accepted, &rejected_unknown);
                let (lost, mismatched, unexpected) = (&lost, &mismatched, &unexpected);
                s.spawn(move || {
                    let Ok(mut cl) = TcpClient::connect(addr) else {
                        lost.fetch_add(FLEET_REQS_PER_CLIENT as u64, Ordering::Relaxed);
                        return;
                    };
                    for r in 0..FLEET_REQS_PER_CLIENT as u64 {
                        let seed = 1 + (r % 4);
                        let tag = format!("m{mi}c{ci}r{r}");
                        let Ok(j) = cl.roundtrip(&fleet_sample_line(model, seed, &tag))
                        else {
                            // a dropped reply is exactly what "lost" means
                            lost.fetch_add(FLEET_REQS_PER_CLIENT as u64 - r, Ordering::Relaxed);
                            return;
                        };
                        if j.get("tag").as_str() != Some(tag.as_str()) {
                            unexpected.lock().unwrap().push(format!("cross-wired: {j:?}"));
                            continue;
                        }
                        if j.get("ok").as_bool() == Some(true) {
                            let bits: Vec<u32> = j
                                .get("samples")
                                .as_f32_vec()
                                .unwrap_or_default()
                                .iter()
                                .map(|v| v.to_bits())
                                .collect();
                            if bits != want[&(model.to_string(), seed)] {
                                mismatched.fetch_add(1, Ordering::Relaxed);
                            }
                            accepted.fetch_add(1, Ordering::Relaxed);
                        } else if j.get("err").as_str() == Some("unknown_model") {
                            rejected_unknown.fetch_add(1, Ordering::Relaxed);
                        } else {
                            unexpected.lock().unwrap().push(format!("{j:?}"));
                        }
                    }
                });
            }
        }
        // churn driver: cycle two of the three models through hot
        // unload -> load, timing first sample after each reload
        let stop = &stop;
        let (ttfs_ms, reload_cycles, unexpected) = (&ttfs_ms, &reload_cycles, &unexpected);
        s.spawn(move || {
            let Ok(mut cl) = TcpClient::connect(addr) else { return };
            while !stop.load(Ordering::Relaxed) {
                for m in ["fleet_b", "fleet_c"] {
                    let Ok(ul) = cl.roundtrip(&format!("{{\"op\":\"unload\",\"model\":\"{m}\"}}"))
                    else {
                        return;
                    };
                    if ul.get("ok").as_bool() != Some(true) {
                        unexpected.lock().unwrap().push(format!("unload: {ul:?}"));
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(2));
                    let Ok(ld) = cl.roundtrip(&format!("{{\"op\":\"load\",\"model\":\"{m}\"}}"))
                    else {
                        return;
                    };
                    if ld.get("ok").as_bool() != Some(true) {
                        unexpected.lock().unwrap().push(format!("load: {ld:?}"));
                        return;
                    }
                    // time-to-first-sample: lazy per-lane recompile cost
                    let t = Instant::now();
                    match cl.roundtrip(&fleet_sample_line(m, 1, "ttfs")) {
                        Ok(j) if j.get("ok").as_bool() == Some(true) => {
                            ttfs_ms.lock().unwrap().push(t.elapsed().as_secs_f64() * 1000.0);
                        }
                        Ok(j) => unexpected.lock().unwrap().push(format!("ttfs: {j:?}")),
                        Err(_) => return,
                    }
                }
                reload_cycles.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(5));
            }
        });
        // samplers exit on their own; then release the churn driver. The
        // scope guarantees every spawned thread joined before we leave.
        while accepted.load(Ordering::Relaxed)
            + rejected_unknown.load(Ordering::Relaxed)
            + lost.load(Ordering::Relaxed)
            + unexpected.lock().unwrap().len() as u64
            < (FLEET_MODELS.len() * FLEET_CLIENTS_PER_MODEL * FLEET_REQS_PER_CLIENT) as u64
        {
            std::thread::sleep(Duration::from_millis(10));
        }
        stop.store(true, Ordering::Relaxed);
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let evals_after: u64 = (0..fleet.num_shards())
        .filter_map(|s| fleet.engine(s))
        .map(|e| e.metrics.evals.load(Ordering::SeqCst))
        .sum();
    server.shutdown();
    drop(fleet);
    std::fs::remove_dir_all(&dir).ok();

    let total = (FLEET_MODELS.len() * FLEET_CLIENTS_PER_MODEL * FLEET_REQS_PER_CLIENT) as u64;
    let accepted = accepted.into_inner();
    let rejected_unknown = rejected_unknown.into_inner();
    let lost = lost.into_inner();
    let mismatched = mismatched.into_inner();
    let unexpected = unexpected.into_inner().unwrap();
    let ttfs = ttfs_ms.into_inner().unwrap();
    assert!(unexpected.is_empty(), "fleet_churn unexpected replies: {unexpected:?}");
    assert_eq!(lost, 0, "fleet_churn lost {lost} requests");
    assert_eq!(mismatched, 0, "fleet_churn: churned samples drifted from quiescent engine");
    assert!(accepted >= 1, "fleet_churn accepted nothing");
    assert!(
        reload_cycles.load(Ordering::Relaxed) >= 1,
        "fleet_churn never completed a reload cycle"
    );
    let (ttfs_mean, ttfs_max) = if ttfs.is_empty() {
        (0.0, 0.0)
    } else {
        (
            ttfs.iter().sum::<f64>() / ttfs.len() as f64,
            ttfs.iter().cloned().fold(0.0, f64::max),
        )
    };
    Ok(Json::obj(vec![
        ("models", Json::Num(FLEET_MODELS.len() as f64)),
        ("shards", Json::Num(FLEET_SHARDS as f64)),
        ("clients", Json::Num((FLEET_MODELS.len() * FLEET_CLIENTS_PER_MODEL) as f64)),
        ("offered", Json::Num(total as f64)),
        ("accepted", Json::Num(accepted as f64)),
        ("rejected_unknown_model", Json::Num(rejected_unknown as f64)),
        ("lost_requests", Json::Num(lost as f64)),
        ("reload_cycles", Json::Num(reload_cycles.into_inner() as f64)),
        ("wall_s", Json::Num(wall_s)),
        ("evals_per_s", Json::Num((evals_after - evals_before) as f64 / wall_s.max(1e-9))),
        ("ttfs_after_load_mean_ms", Json::Num(ttfs_mean)),
        ("ttfs_after_load_max_ms", Json::Num(ttfs_max)),
        ("fleet_bit_identical", Json::Bool(mismatched == 0)),
    ]))
}

fn main() -> anyhow::Result<()> {
    let (store, dir) = stub_store(
        "serve-load",
        &[StubModel {
            name: MODEL,
            dim: DIM,
            num_classes: 8,
            forwards_per_eval: 2,
            k: -0.8,
            c: 0.05,
            label_scale: 0.01,
            cost: 6,
            buckets: &[16, 64],
        }],
    )?;

    // (label, lanes, workers); index 1 is the single-lane baseline the
    // scaling ratio is measured against
    let configs: &[(&str, usize, usize)] = &[
        ("lanes=1 workers=1", 1, 1),
        ("lanes=1 workers=2", 1, 2),
        ("lanes=2 workers=2", 2, 2),
        ("lanes=4 workers=4", 4, 4),
    ];

    let mut table = Table::new(&[
        "config", "evals/s", "samples/s", "rows/eval-batch", "exec p50(ms)", "queue p95(ms)",
    ]);
    let mut results = Vec::new();
    let mut baseline_probes: Option<Vec<Vec<u32>>> = None;
    let mut single_lane_eps = 0.0f64;
    let mut best_multi_eps = 0.0f64;
    for (i, &(label, lanes, workers)) in configs.iter().enumerate() {
        let r = run_config(&store, label, lanes, workers)?;
        if baseline_probes.is_none() {
            baseline_probes = Some(r.probes.clone());
        } else {
            let want = baseline_probes.as_ref().unwrap();
            assert_eq!(
                &r.probes, want,
                "{label}: samples drifted from the single-lane reference"
            );
        }
        if i == 1 {
            single_lane_eps = r.evals_per_s;
        }
        if lanes > 1 && workers > 1 {
            best_multi_eps = best_multi_eps.max(r.evals_per_s);
        }
        table.row(vec![
            label.into(),
            format!("{:.1}", r.evals_per_s),
            format!("{:.1}", r.json.get("samples_per_s").as_f64().unwrap_or(0.0)),
            format!("{:.1}", r.json.get("mean_batch_rows").as_f64().unwrap_or(0.0)),
            format!("{:.2}", r.json.get("exec_p50_us").as_f64().unwrap_or(0.0) / 1000.0),
            format!("{:.2}", r.json.get("queue_p95_us").as_f64().unwrap_or(0.0) / 1000.0),
        ]);
        results.push(r.json);
    }
    let scaling = if single_lane_eps > 0.0 { best_multi_eps / single_lane_eps } else { 0.0 };

    println!(
        "=== L3 serving load ({CLIENTS} clients x {REQS_PER_CLIENT} reqs x {ROWS_PER_REQ} rows, \
         auto nfe=8, stub dim={DIM} cost=6) ==="
    );
    table.print();
    println!("\nworker-scaling ratio (best multi-lane / single-lane): {scaling:.2}x");
    println!("bit-identical across configs: yes (asserted)");

    // overload phase over the real TCP serving plane
    let overload = run_overload(&store)?;
    println!(
        "\n=== overload (TCP, {OVER_CLIENTS} clients x {OVER_REQS_PER_CLIENT} reqs x \
         {ROWS_PER_REQ} rows vs {OVER_MAX_INFLIGHT_ROWS}-row budget) ==="
    );
    println!(
        "accepted {} / rejected {} ({:.0}% rejects), accepted p50 {:.2}ms p95 {:.2}ms, \
         mean retry_after {:.0}ms",
        overload.get("accepted").as_f64().unwrap_or(0.0),
        overload.get("rejected_overload").as_f64().unwrap_or(0.0),
        100.0 * overload.get("reject_rate").as_f64().unwrap_or(0.0),
        overload.get("accepted_p50_us").as_f64().unwrap_or(0.0) / 1000.0,
        overload.get("accepted_p95_us").as_f64().unwrap_or(0.0) / 1000.0,
        overload.get("mean_retry_after_ms").as_f64().unwrap_or(0.0),
    );
    println!("structured rejects + TCP bit-identity: yes (asserted)");

    // fault-recovery phase: wedge the lane, measure time back to service
    let fault_recovery = run_fault_recovery(&store)?;
    println!(
        "\n=== fault_recovery (1 lane, wedge {FAULT_WEDGE_MS}ms vs {FAULT_LANE_TIMEOUT_MS}ms \
         exec timeout) ==="
    );
    println!(
        "time-to-recover {:.0}ms, failed attempts {}, exec retries {}, lane respawns {}, \
         faults injected {}",
        fault_recovery.get("time_to_recover_ms").as_f64().unwrap_or(0.0),
        fault_recovery.get("failed_attempts").as_f64().unwrap_or(0.0),
        fault_recovery.get("exec_retries").as_f64().unwrap_or(0.0),
        fault_recovery.get("lane_respawns").as_f64().unwrap_or(0.0),
        fault_recovery.get("faults_injected").as_f64().unwrap_or(0.0),
    );
    println!("bit-identical after recovery: yes (asserted)");

    // trace_overhead phase: span recorder on-vs-off + allocs per event
    let trace_overhead = run_trace_overhead(&store)?;
    println!(
        "\n=== trace_overhead ({TRACE_CLIENTS} clients x {TRACE_REQS_PER_CLIENT} reqs, \
         capacity 4096 vs off) ==="
    );
    println!(
        "evals/s off {:.1} vs on {:.1} ({:.2}% overhead), allocs/record {:.4}, \
         allocs/record_latency {:.4}",
        trace_overhead.get("evals_per_s_tracing_off").as_f64().unwrap_or(0.0),
        trace_overhead.get("evals_per_s_tracing_on").as_f64().unwrap_or(0.0),
        trace_overhead.get("overhead_pct").as_f64().unwrap_or(0.0),
        trace_overhead.get("allocs_per_record_event").as_f64().unwrap_or(0.0),
        trace_overhead.get("allocs_per_record_latency").as_f64().unwrap_or(0.0),
    );
    println!("zero steady-state allocs on the tracing hot paths: yes (asserted)");

    // fleet_churn phase: multi-model shard fleet under hot reload cycles
    let fleet_churn = run_fleet_churn()?;
    println!(
        "\n=== fleet_churn ({} models x {FLEET_CLIENTS_PER_MODEL} clients over \
         {FLEET_SHARDS} shards, hot unload/load cycles) ===",
        FLEET_MODELS.len()
    );
    println!(
        "accepted {} / unknown-model rejects {} / lost {}, {:.0} reload cycles, \
         {:.1} evals/s, ttfs after load mean {:.1}ms max {:.1}ms",
        fleet_churn.get("accepted").as_f64().unwrap_or(0.0),
        fleet_churn.get("rejected_unknown_model").as_f64().unwrap_or(0.0),
        fleet_churn.get("lost_requests").as_f64().unwrap_or(0.0),
        fleet_churn.get("reload_cycles").as_f64().unwrap_or(0.0),
        fleet_churn.get("evals_per_s").as_f64().unwrap_or(0.0),
        fleet_churn.get("ttfs_after_load_mean_ms").as_f64().unwrap_or(0.0),
        fleet_churn.get("ttfs_after_load_max_ms").as_f64().unwrap_or(0.0),
    );
    println!("zero lost + bit-identical under churn: yes (asserted)");

    let bench = Json::obj(vec![
        ("bench", Json::Str("serve_load".into())),
        (
            "workload",
            Json::obj(vec![
                ("clients", Json::Num(CLIENTS as f64)),
                ("reqs_per_client", Json::Num(REQS_PER_CLIENT as f64)),
                ("rows_per_req", Json::Num(ROWS_PER_REQ as f64)),
                ("model_dim", Json::Num(DIM as f64)),
                ("stub_cost", Json::Num(6.0)),
                ("solver", Json::Str("auto nfe=8".into())),
            ]),
        ),
        ("configs", Json::Arr(results.clone())),
        ("single_lane_evals_per_s", Json::Num(single_lane_eps)),
        ("best_multi_lane_evals_per_s", Json::Num(best_multi_eps)),
        ("worker_scaling_ratio", Json::Num(scaling)),
        ("bit_identical", Json::Bool(true)),
        ("overload", overload),
        ("fault_recovery", fault_recovery),
        ("trace_overhead", trace_overhead),
        ("fleet_churn", fleet_churn),
    ]);
    let out_path =
        std::env::var("BENCH_SERVE_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    std::fs::write(&out_path, bench.to_string())?;
    println!("wrote {out_path}");
    let path = write_results("serve_load", &Json::Arr(results))?;
    println!("wrote {}", path.display());

    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
