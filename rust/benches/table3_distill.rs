//! Table 3: BNS solvers vs Progressive Distillation (unguided, w = 0).
//!
//! Columns, as in the paper: FID (our FD-synth), GT-FID, training
//! Forwards (App. D.4 accounting), Training-set size, and trained
//! Parameter count. PD students were distilled at build time
//! (python/compile/pd.py) and sampled here with Euler at their phase
//! step count; BNS rows reuse the distilled artifacts.
//!
//! Expected shape: PD wins at NFE 4; BNS reaches parity by NFE 8-16
//! using orders of magnitude fewer forwards and ~10^6x fewer parameters.

use bns_serve::bench_util::{write_results, Bench, Table};
use bns_serve::coordinator::router::distilled;
use bns_serve::solver::{baseline, Solver};
use bns_serve::util::json::Json;

const MODEL: &str = "img_fm_ot";
const FD_EVAL_N: usize = 512;
const PD_PARAMS: u64 = 767_232; // student == full model (train_meta)

fn main() -> anyhow::Result<()> {
    let b = Bench::init()?;
    let info = b.store.model(MODEL)?.clone();

    // GT-FD of the teacher sampled with RK45
    let (gt_dist, gt_nfe) = b.generate_gt(&info, 0.0, FD_EVAL_N, 555)?;
    let gt_fd = b.store.fd.fd_to_reference(&gt_dist);
    println!("teacher GT (rk45, mean NFE {gt_nfe:.0}) FD = {gt_fd:.3}\n");

    let mut table =
        Table::new(&["method", "NFE", "FID(FD)", "GT-FID", "Forwards", "TrainSet", "Params"]);
    let mut results = Vec::new();

    // PD metadata lives in the manifest under models.pd_nfeK.pd
    let manifest_text =
        std::fs::read_to_string(b.store.root.join("manifest.json"))?;
    let manifest = bns_serve::util::json::Json::parse(&manifest_text)
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    for nfe in [4usize, 8, 16] {
        // --- PD row: student model sampled with Euler at its step count
        let pd_name = format!("pd_nfe{nfe}");
        if b.store.models.contains_key(&pd_name) {
            let pd_info = b.store.model(&pd_name)?.clone();
            let euler = baseline("euler", nfe, pd_info.scheduler)?;
            let dist = b.generate(&pd_info, euler.as_ref(), 0.0, FD_EVAL_N, 555)?;
            let fd = b.store.fd.fd_to_reference(&dist);
            let pdj = manifest.get("models").get(&pd_name).get("pd");
            let forwards = pdj.get("forwards").as_f64().unwrap_or(f64::NAN);
            let updates = pdj.get("updates").as_f64().unwrap_or(f64::NAN);
            table.row(vec![
                "PD".into(),
                nfe.to_string(),
                format!("{fd:.3}"),
                format!("{gt_fd:.3}"),
                format!("{:.2}m", forwards / 1e6),
                format!("{:.0} (stream)", updates * 64.0),
                format!("{}", PD_PARAMS),
            ]);
            results.push(Json::obj(vec![
                ("method", Json::Str("pd".into())),
                ("nfe", Json::Num(nfe as f64)),
                ("fd", Json::Num(fd)),
                ("forwards", Json::Num(forwards)),
                ("params", Json::Num(PD_PARAMS as f64)),
            ]));
        }

        // --- BNS row
        if let Ok(bns) = distilled(&b.store, MODEL, 0.0, "bns", nfe) {
            let art = b
                .store
                .solvers_for(MODEL, 0.0, "bns")
                .into_iter()
                .find(|s| s.solver.nfe() == nfe)
                .unwrap();
            let dist = b.generate(&info, &bns as &dyn Solver, 0.0, FD_EVAL_N, 555)?;
            let fd = b.store.fd.fd_to_reference(&dist);
            // forwards: Alg.2 training + GT pair generation (App. D.4)
            let pair_forwards = art.meta.gt_nfe * 520;
            let total_forwards = art.meta.forwards + pair_forwards;
            table.row(vec![
                "BNS".into(),
                nfe.to_string(),
                format!("{fd:.3}"),
                format!("{gt_fd:.3}"),
                format!("{:.2}m", total_forwards as f64 / 1e6),
                "520".into(),
                format!("{}", bns.num_params()),
            ]);
            results.push(Json::obj(vec![
                ("method", Json::Str("bns".into())),
                ("nfe", Json::Num(nfe as f64)),
                ("fd", Json::Num(fd)),
                ("forwards", Json::Num(total_forwards as f64)),
                ("params", Json::Num(bns.num_params() as f64)),
            ]));
        }
    }
    table.print();

    let path = write_results("table3_distill", &Json::Arr(results))?;
    println!("\nwrote {}", path.display());
    Ok(())
}
