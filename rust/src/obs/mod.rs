//! Request-scoped tracing plane (DESIGN.md §12).
//!
//! [`TraceRecorder`] is a preallocated, sharded ring buffer of fixed-size
//! span events. The write path ([`TraceRecorder::record`]) is lock-free
//! and allocation-free: a global sequence number picks a shard
//! round-robin, a per-shard cursor picks a slot, and the event is
//! published under a seqlock-style version word (odd = write in
//! progress, even = committed, 0 = never written). Readers
//! ([`TraceRecorder::snapshot`]) re-check the version after copying the
//! payload and drop any slot that changed mid-read, so tracing never
//! blocks or slows the request path — under overwrite pressure the
//! oldest events simply disappear.
//!
//! One documented imprecision: if a writer is lapped — it stalls between
//! its two version stores while other writers cycle the *entire* shard
//! ring back onto its slot — a reader can accept a payload mixed from
//! two events. The version check catches every shorter interleaving.
//! With the default capacity (4096 slots) a full-ring lap mid-write is
//! vanishingly rare, and the blast radius is one garbled diagnostic
//! event, never corruption of served data.
//!
//! Correlation model: the trace id **is** the engine-assigned request id
//! (minted at admission in `Engine::try_submit`). Inside the runtime the
//! id travels two ways: explicitly, on the lane `ExecMsg` and the
//! supervisor's `Suspect` message; and as a thread-ambient id
//! ([`set_ambient`] / [`ambient`]) for call sites below the engine that
//! predate the message construction (batch workers set it to the
//! batch-leader id before touching the runtime).

use std::cell::Cell;
use std::sync::atomic::{fence, AtomicU64, Ordering};
use std::time::Instant;

use crate::util::json::Json;

/// Sentinel trace id meaning "no request context" — events recorded
/// under it are dropped. `u64::MAX` (not 0) so real engine ids, which
/// may legitimately start at 0, are all traceable.
pub const NO_TRACE: u64 = u64::MAX;

thread_local! {
    static AMBIENT: Cell<u64> = const { Cell::new(NO_TRACE) };
}

/// Set this thread's ambient trace id (the batch-leader request id while
/// a worker drives a batch through the runtime).
pub fn set_ambient(id: u64) {
    AMBIENT.with(|c| c.set(id));
}

/// This thread's ambient trace id, or [`NO_TRACE`].
pub fn ambient() -> u64 {
    AMBIENT.with(|c| c.get())
}

/// Reset this thread's ambient trace id to [`NO_TRACE`].
pub fn clear_ambient() {
    set_ambient(NO_TRACE);
}

/// Pipeline stage of a trace event. The wire name (`as_str`) is what the
/// `trace` op and `--trace-out` emit; PROTOCOL.md documents the meaning
/// of the generic `a`/`b` payload words per stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum TraceStage {
    /// Request admitted (`a` = rows, `b` = priority: 0 high / 1 normal / 2 low).
    Admit = 0,
    /// Request's batch closed (`a` = batch rows, `b` = queue wait µs).
    BatchForm = 1,
    /// Batch popped by a worker (`a` = batch rows, `b` = µs since formed).
    Dispatch = 2,
    /// Batch execution attempt started (`a` = attempt, `b` = batch rows).
    ExecStart = 3,
    /// Attempt succeeded (`a` = attempt, `b` = exec µs).
    ExecOk = 4,
    /// Attempt failed retryably (`a` = attempt, `b` = exec µs).
    ExecRetry = 5,
    /// Backoff sleep before re-dispatch (`a` = attempt, `b` = sleep µs).
    RetryBackoff = 6,
    /// Rejected by an open circuit breaker (`b` = retry-after ms).
    BreakerReject = 7,
    /// This failure tripped the model's breaker open (`a` = attempt).
    BreakerOpen = 8,
    /// Artifact compiled/bound on a lane (`a` = lane, `b` = compile µs).
    LaneCompile = 9,
    /// Device-lane execution finished (`a` = lane, `b` = exec µs).
    LaneExec = 10,
    /// Lane exec timed out; supervisor suspected (`a` = lane, `b` = generation).
    LaneTimeout = 11,
    /// Supervisor respawned the lane (`a` = lane, `b` = new generation).
    LaneRespawn = 12,
    /// Deterministic fault injected on the lane (`a` = lane, `b` = fault kind).
    FaultInjected = 13,
    /// Result rows settled and reply sent (`a` = rows, `b` = µs since
    /// the successful attempt finished).
    Emit = 14,
    /// Terminal structured error reply after exhausting retries.
    Reject = 15,
    /// Front door routed the request to an engine shard on the
    /// consistent-hash ring (`a` = shard index).
    ShardRoute = 16,
    /// Request parked in its tenant's weighted-fair queue behind a full
    /// grouped stage (`a` = rows).
    TenantPark = 17,
}

impl TraceStage {
    /// Wire name used in `trace` frames and JSON-lines export.
    pub fn as_str(self) -> &'static str {
        match self {
            TraceStage::Admit => "admit",
            TraceStage::BatchForm => "batch_form",
            TraceStage::Dispatch => "dispatch",
            TraceStage::ExecStart => "exec_start",
            TraceStage::ExecOk => "exec_ok",
            TraceStage::ExecRetry => "exec_retry",
            TraceStage::RetryBackoff => "retry_backoff",
            TraceStage::BreakerReject => "breaker_reject",
            TraceStage::BreakerOpen => "breaker_open",
            TraceStage::LaneCompile => "lane_compile",
            TraceStage::LaneExec => "lane_exec",
            TraceStage::LaneTimeout => "lane_timeout",
            TraceStage::LaneRespawn => "lane_respawn",
            TraceStage::FaultInjected => "fault_injected",
            TraceStage::Emit => "emit",
            TraceStage::Reject => "reject",
            TraceStage::ShardRoute => "shard_route",
            TraceStage::TenantPark => "tenant_park",
        }
    }

    fn from_u64(v: u64) -> Option<TraceStage> {
        Some(match v {
            0 => TraceStage::Admit,
            1 => TraceStage::BatchForm,
            2 => TraceStage::Dispatch,
            3 => TraceStage::ExecStart,
            4 => TraceStage::ExecOk,
            5 => TraceStage::ExecRetry,
            6 => TraceStage::RetryBackoff,
            7 => TraceStage::BreakerReject,
            8 => TraceStage::BreakerOpen,
            9 => TraceStage::LaneCompile,
            10 => TraceStage::LaneExec,
            11 => TraceStage::LaneTimeout,
            12 => TraceStage::LaneRespawn,
            13 => TraceStage::FaultInjected,
            14 => TraceStage::Emit,
            15 => TraceStage::Reject,
            16 => TraceStage::ShardRoute,
            17 => TraceStage::TenantPark,
            _ => return None,
        })
    }
}

/// One committed span event, copied out of the ring by a reader.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    /// Global recorder sequence number (total order across all requests).
    pub seq: u64,
    /// Request id the event belongs to.
    pub id: u64,
    /// Microseconds since the recorder was created.
    pub t_us: u64,
    /// Pipeline stage.
    pub stage: TraceStage,
    /// Stage-specific payload word (see [`TraceStage`] docs).
    pub a: u64,
    /// Stage-specific payload word (see [`TraceStage`] docs).
    pub b: u64,
}

impl TraceEvent {
    /// JSON object for one event; `with_id` adds the request id (used by
    /// the flat JSON-lines export, omitted inside per-request frames).
    pub fn to_json(&self, with_id: bool) -> Json {
        let mut pairs = vec![
            ("seq", Json::Num(self.seq as f64)),
            ("t_us", Json::Num(self.t_us as f64)),
            ("stage", Json::Str(self.stage.as_str().to_string())),
            ("a", Json::Num(self.a as f64)),
            ("b", Json::Num(self.b as f64)),
        ];
        if with_id {
            pairs.push(("id", Json::Num(self.id as f64)));
        }
        Json::obj(pairs)
    }
}

/// One preallocated ring slot. All payload words are atomics so the
/// seqlock needs no `unsafe` (the crate denies it): a torn read is a
/// version mismatch, never UB.
#[derive(Default)]
struct Slot {
    /// 0 = empty; odd = write in progress; even = committed, encoding the
    /// writer's global sequence `s` as `2*s + 2`.
    ver: AtomicU64,
    id: AtomicU64,
    t_us: AtomicU64,
    stage: AtomicU64,
    a: AtomicU64,
    b: AtomicU64,
}

struct Shard {
    cursor: AtomicU64,
    slots: Vec<Slot>,
}

/// Sharded, preallocated span ring. See the module docs for the memory
/// model. Capacity 0 disables recording entirely (`record` becomes a
/// single branch).
pub struct TraceRecorder {
    epoch: Instant,
    seq: AtomicU64,
    shards: Vec<Shard>,
}

impl TraceRecorder {
    /// Recorder holding at least `capacity` events (rounded up to fill
    /// the shards evenly); `capacity == 0` disables recording.
    pub fn new(capacity: usize) -> TraceRecorder {
        let shards = if capacity == 0 {
            Vec::new()
        } else {
            let nshards = capacity.min(8);
            let per = (capacity + nshards - 1) / nshards;
            (0..nshards)
                .map(|_| Shard {
                    cursor: AtomicU64::new(0),
                    slots: (0..per).map(|_| Slot::default()).collect(),
                })
                .collect()
        };
        TraceRecorder { epoch: Instant::now(), seq: AtomicU64::new(0), shards }
    }

    /// A recorder that drops everything (capacity 0).
    pub fn disabled() -> TraceRecorder {
        TraceRecorder::new(0)
    }

    /// Whether events are being kept.
    pub fn is_enabled(&self) -> bool {
        !self.shards.is_empty()
    }

    /// Total preallocated slots.
    pub fn capacity(&self) -> usize {
        self.shards.iter().map(|s| s.slots.len()).sum()
    }

    /// Record one span event for request `id`. Lock-free and
    /// allocation-free; a no-op when disabled or when `id` is
    /// [`NO_TRACE`]. `a`/`b` are stage-specific payload words.
    pub fn record(&self, id: u64, stage: TraceStage, a: u64, b: u64) {
        if self.shards.is_empty() || id == NO_TRACE {
            return;
        }
        let t_us = self.epoch.elapsed().as_micros() as u64;
        let s = self.seq.fetch_add(1, Ordering::Relaxed) + 1;
        let shard = &self.shards[(s % self.shards.len() as u64) as usize];
        let idx = (shard.cursor.fetch_add(1, Ordering::Relaxed) % shard.slots.len() as u64) as usize;
        let slot = &shard.slots[idx];
        // AcqRel swap: the Acquire half keeps the payload stores below
        // from floating above the odd (write-in-progress) mark.
        slot.ver.swap(2 * s + 1, Ordering::AcqRel);
        slot.id.store(id, Ordering::Relaxed);
        slot.t_us.store(t_us, Ordering::Relaxed);
        slot.stage.store(stage as u64, Ordering::Relaxed);
        slot.a.store(a, Ordering::Relaxed);
        slot.b.store(b, Ordering::Relaxed);
        // Release: payload is visible before the committed (even) mark.
        slot.ver.store(2 * s + 2, Ordering::Release);
    }

    fn read_slot(slot: &Slot) -> Option<TraceEvent> {
        for _ in 0..4 {
            let v1 = slot.ver.load(Ordering::Acquire);
            if v1 == 0 {
                return None; // never written
            }
            if v1 % 2 == 1 {
                std::hint::spin_loop();
                continue; // mid-write; the writer is at most 6 stores away
            }
            let ev = TraceEvent {
                seq: (v1 - 2) / 2,
                id: slot.id.load(Ordering::Relaxed),
                t_us: slot.t_us.load(Ordering::Relaxed),
                stage: TraceStage::from_u64(slot.stage.load(Ordering::Relaxed))?,
                a: slot.a.load(Ordering::Relaxed),
                b: slot.b.load(Ordering::Relaxed),
            };
            fence(Ordering::Acquire);
            if slot.ver.load(Ordering::Relaxed) == v1 {
                return Some(ev);
            }
        }
        None // kept being overwritten; newer events win
    }

    /// Copy out every committed event, in global sequence order.
    /// Allocates — readers are cold paths (`trace` op, exporter, tests).
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.capacity());
        for shard in &self.shards {
            for slot in &shard.slots {
                if let Some(ev) = Self::read_slot(slot) {
                    out.push(ev);
                }
            }
        }
        out.sort_unstable_by_key(|e| e.seq);
        out
    }

    /// The still-buffered timeline of request `id`, in order.
    pub fn trace_for(&self, id: u64) -> Vec<TraceEvent> {
        let mut out = self.snapshot();
        out.retain(|e| e.id == id);
        out
    }

    /// Up to `n` distinct request ids, most recently active first.
    pub fn last_ids(&self, n: usize) -> Vec<u64> {
        let snap = self.snapshot();
        let mut out: Vec<u64> = Vec::new();
        for ev in snap.iter().rev() {
            if out.len() >= n {
                break;
            }
            if !out.contains(&ev.id) {
                out.push(ev.id);
            }
        }
        out
    }

    /// `{"id":N,"events":[...]}` frame body for one request.
    pub fn trace_json(&self, id: u64) -> Json {
        let events = self.trace_for(id).iter().map(|e| e.to_json(false)).collect();
        Json::obj(vec![("id", Json::Num(id as f64)), ("events", Json::Arr(events))])
    }

    /// Flat JSON-lines rendering of the whole ring (one event per line,
    /// each carrying its request id) — the `--trace-out` export format.
    pub fn render_jsonl(&self) -> String {
        let mut out = String::new();
        for ev in self.snapshot() {
            out.push_str(&ev.to_json(true).to_string());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn records_and_reads_in_order() {
        let r = TraceRecorder::new(64);
        assert!(r.is_enabled());
        r.record(5, TraceStage::Admit, 2, 1);
        r.record(6, TraceStage::Admit, 1, 1);
        r.record(5, TraceStage::BatchForm, 2, 40);
        r.record(5, TraceStage::Emit, 2, 900);
        let t = r.trace_for(5);
        let stages: Vec<&str> = t.iter().map(|e| e.stage.as_str()).collect();
        assert_eq!(stages, ["admit", "batch_form", "emit"]);
        assert!(t.windows(2).all(|w| w[0].seq < w[1].seq && w[0].t_us <= w[1].t_us));
        assert_eq!(t[1].b, 40);
        assert_eq!(r.last_ids(8), [5, 6], "most recently active first");
    }

    #[test]
    fn wraparound_keeps_newest() {
        // 8 slots (8 shards x 1); round-robin means the ring holds
        // exactly the 8 most recent sequence numbers after overwrite.
        let r = TraceRecorder::new(8);
        for i in 0..100u64 {
            r.record(i, TraceStage::Admit, 0, 0);
        }
        let snap = r.snapshot();
        assert_eq!(snap.len(), 8);
        let seqs: Vec<u64> = snap.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, (93..=100).collect::<Vec<u64>>());
        // ids were recorded as seq-1, so overwrite kept the newest ids
        assert_eq!(snap[0].id, 92);
        assert_eq!(snap[7].id, 99);
    }

    #[test]
    fn disabled_recorder_drops_everything() {
        let r = TraceRecorder::disabled();
        assert!(!r.is_enabled());
        assert_eq!(r.capacity(), 0);
        r.record(1, TraceStage::Admit, 0, 0);
        assert!(r.snapshot().is_empty());
        assert!(r.render_jsonl().is_empty());
    }

    #[test]
    fn no_trace_sentinel_is_dropped() {
        let r = TraceRecorder::new(16);
        r.record(NO_TRACE, TraceStage::LaneExec, 0, 0);
        r.record(0, TraceStage::Admit, 1, 1); // id 0 is a real id
        assert!(r.snapshot().iter().all(|e| e.id == 0));
        assert_eq!(r.trace_for(0).len(), 1);
    }

    /// Concurrent-writer property: after the dust settles, every
    /// readable slot is internally consistent (valid stage, an id one of
    /// the writers actually used, payload words matching that writer's
    /// scheme) and global sequence numbers are unique.
    #[test]
    fn concurrent_writers_never_produce_inconsistent_events() {
        let r = Arc::new(TraceRecorder::new(1024));
        let threads = 4u64;
        let per = 2000u64;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    for i in 0..per {
                        // payload scheme: a = thread, b = i, id = 100 + thread
                        r.record(100 + t, TraceStage::LaneExec, t, i);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let snap = r.snapshot();
        assert_eq!(snap.len(), 1024, "quiescent full ring reads completely");
        let mut seqs: Vec<u64> = snap.iter().map(|e| e.seq).collect();
        seqs.dedup();
        assert_eq!(seqs.len(), snap.len(), "sequence numbers are unique");
        for e in &snap {
            assert_eq!(e.stage, TraceStage::LaneExec);
            assert!(e.id >= 100 && e.id < 100 + threads, "id {} torn", e.id);
            assert_eq!(e.a, e.id - 100, "payload a matches its writer");
            assert!(e.b < per, "payload b in range");
        }
    }

    #[test]
    fn ambient_id_is_per_thread() {
        assert_eq!(ambient(), NO_TRACE);
        set_ambient(7);
        assert_eq!(ambient(), 7);
        let other = std::thread::spawn(|| ambient()).join().unwrap();
        assert_eq!(other, NO_TRACE, "ambient does not leak across threads");
        clear_ambient();
        assert_eq!(ambient(), NO_TRACE);
    }

    #[test]
    fn jsonl_export_parses_and_carries_ids() {
        let r = TraceRecorder::new(16);
        r.record(3, TraceStage::Admit, 1, 1);
        r.record(3, TraceStage::Emit, 1, 250);
        let lines: Vec<&str> = r.render_jsonl().lines().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            let j = Json::parse(line).expect("each line is standalone JSON");
            assert_eq!(j.get("id").as_usize(), Some(3));
            assert!(j.get("stage").as_str().is_some());
        }
        let frame = r.trace_json(3);
        assert_eq!(frame.get("id").as_usize(), Some(3));
        assert_eq!(frame.get("events").as_arr().map(|a| a.len()), Some(2));
    }
}
