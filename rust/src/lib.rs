//! # bns-serve
//!
//! Production-style reproduction of **"Bespoke Non-Stationary Solvers for
//! Fast Sampling of Diffusion and Flow Models"** (Shaul et al., ICML
//! 2024) as a three-layer rust + JAX + Pallas serving stack:
//!
//! * **L1 (build-time)** — Pallas kernels for the model's fused residual
//!   block and the NS combine step (`python/compile/kernels/`).
//! * **L2 (build-time)** — the JAX velocity-field model, schedulers, BNS
//!   solver distillation (Algorithm 2), AOT-lowered to HLO text.
//! * **L3 (this crate)** — the request path: PJRT runtime executing the
//!   AOT artifacts, the full solver taxonomy of the paper's Figure 3,
//!   and a batched sampling service with BNS-first routing.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! reproduced tables/figures.

// Crate-wide lint posture (bns-lint rules are the repo-specific layer on
// top; see DESIGN.md §10): no unsafe anywhere in this crate, and the
// debug/stub macros stay out of committed code.
#![deny(unsafe_code)]
#![warn(clippy::dbg_macro)]
#![warn(clippy::todo)]
#![warn(clippy::unimplemented)]

pub mod analysis;
pub mod bench_util;
pub mod coordinator;
pub mod distill;
pub mod kernels;
pub mod obs;
pub mod runtime;
pub mod solver;
pub mod util;

/// Default artifacts directory (overridable via --artifacts / BNS_ARTIFACTS).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("BNS_ARTIFACTS") {
        return p.into();
    }
    std::path::PathBuf::from("artifacts")
}
