//! Constructive Theorem 3.2: every solver family of Figure 3 expressed as
//! Non-Stationary coefficients.
//!
//! `AffineTrace` executes a solver symbolically over the affine state
//! algebra `a·x0 + Σ b_j·u_j` (numeric coefficients, symbolic velocity
//! evaluations). Any method whose update is a linear combination of
//! previous states and velocities — i.e. exactly the NS family by
//! Prop. 3.1 — can be traced, which yields its exact `NsSolver` form.
//! The unit + integration tests assert direct stepping == NS-form
//! sampling on nonlinear fields, for every family: that *is* the
//! inclusion chain RK ⊂ ST-RK ⊂ NS, Multistep ⊂ ST-Multistep ⊂ NS,
//! Exp-RK/Multistep ⊂ NS.

use anyhow::{bail, Result};

use super::ns::NsSolver;
use super::scheduler::{Parametrization, Scheduler};

/// Affine expression a·x0 + b·(u_0..u_{k-1}).
#[derive(Debug, Clone)]
pub struct Aff {
    pub a: f64,
    pub b: Vec<f64>,
}

impl Aff {
    fn lift(&self, k: usize) -> Vec<f64> {
        let mut b = self.b.clone();
        b.resize(k, 0.0);
        b
    }

    pub fn add(&self, other: &Aff) -> Aff {
        let k = self.b.len().max(other.b.len());
        let (mut sb, ob) = (self.lift(k), other.lift(k));
        for (x, y) in sb.iter_mut().zip(ob.iter()) {
            *x += y;
        }
        Aff { a: self.a + other.a, b: sb }
    }

    pub fn scale(&self, c: f64) -> Aff {
        Aff { a: self.a * c, b: self.b.iter().map(|x| x * c).collect() }
    }

    /// self + c * other (the workhorse).
    pub fn axpy(&self, c: f64, other: &Aff) -> Aff {
        self.add(&other.scale(c))
    }
}

/// Symbolic execution context. Call `eval_u` wherever a concrete solver
/// would evaluate the velocity field.
pub struct AffineTrace {
    times: Vec<f64>,
    rows_a: Vec<f64>,
    rows_b: Vec<Vec<f64>>,
    k: usize,
}

impl AffineTrace {
    pub fn new() -> Self {
        AffineTrace { times: Vec::new(), rows_a: Vec::new(), rows_b: Vec::new(), k: 0 }
    }

    pub fn x0(&self) -> Aff {
        Aff { a: 1.0, b: Vec::new() }
    }

    /// Record u_k := u(t, state); the state becomes trajectory point x_k.
    pub fn eval_u(&mut self, state: &Aff, t: f64) -> Aff {
        if self.k == 0 {
            assert!(state.a == 1.0 && state.b.is_empty(), "first eval must be at x0");
        } else {
            self.rows_a.push(state.a);
            self.rows_b.push(state.lift(self.k));
        }
        self.times.push(t);
        let mut b = vec![0.0; self.k + 1];
        b[self.k] = 1.0;
        self.k += 1;
        Aff { a: 0.0, b }
    }

    pub fn finish(mut self, final_state: &Aff, t_final: f64) -> NsSolver {
        self.rows_a.push(final_state.a);
        self.rows_b.push(final_state.lift(self.k));
        self.times.push(t_final);
        NsSolver {
            times: self.times,
            a: self.rows_a,
            b: self
                .rows_b
                .into_iter()
                .enumerate()
                .map(|(i, row)| row[..=i].to_vec())
                .collect(),
        }
    }
}

impl Default for AffineTrace {
    fn default() -> Self {
        Self::new()
    }
}

/// Proposition 3.1, eq. 32: reduce a naive (c, d) update rule
/// x_{i+1} = X_i c_i + U_i d_i to the (a, b) form. Used by tests and by
/// ST-transform folding.
pub fn reduce_cd_to_ab(c_rows: &[Vec<f64>], d_rows: &[Vec<f64>]) -> (Vec<f64>, Vec<Vec<f64>>) {
    let n = c_rows.len();
    let mut a = vec![0.0; n];
    let mut b: Vec<Vec<f64>> = (0..n).map(|i| vec![0.0; i + 1]).collect();
    for k in 0..n {
        let (ck, dk) = (&c_rows[k], &d_rows[k]);
        a[k] = ck[0] + (0..k).map(|j| ck[j + 1] * a[j]).sum::<f64>();
        for j in 0..k {
            b[k][j] = (j..k).map(|l| ck[l + 1] * b[l][j]).sum::<f64>() + dk[j];
        }
        b[k][k] = dk[k];
    }
    (a, b)
}

// ---------------------------------------------------------------------------
// NS-coefficient generators for each family (mirrors python/compile/ns.py)
// ---------------------------------------------------------------------------

pub fn euler_ns(times: &[f64]) -> NsSolver {
    let mut tr = AffineTrace::new();
    let mut x = tr.x0();
    for w in times.windows(2) {
        let u = tr.eval_u(&x, w[0]);
        x = x.axpy(w[1] - w[0], &u);
    }
    tr.finish(&x, *times.last().unwrap())
}

pub fn midpoint_ns(nfe: usize) -> NsSolver {
    assert!(nfe % 2 == 0);
    let s = super::generic::uniform_times(nfe / 2);
    let mut tr = AffineTrace::new();
    let mut x = tr.x0();
    for w in s.windows(2) {
        let h = w[1] - w[0];
        let u1 = tr.eval_u(&x, w[0]);
        let xi = x.axpy(0.5 * h, &u1);
        let u2 = tr.eval_u(&xi, w[0] + 0.5 * h);
        x = x.axpy(h, &u2);
    }
    tr.finish(&x, 1.0)
}

pub fn rk4_ns(nfe: usize) -> NsSolver {
    assert!(nfe % 4 == 0);
    let s = super::generic::uniform_times(nfe / 4);
    let mut tr = AffineTrace::new();
    let mut x = tr.x0();
    for w in s.windows(2) {
        let h = w[1] - w[0];
        let k1 = tr.eval_u(&x, w[0]);
        let k2 = tr.eval_u(&x.axpy(0.5 * h, &k1), w[0] + 0.5 * h);
        // +1e-6h nudges keep the NS grid strictly monotone (repeated RK
        // nodes); the coefficients themselves use the exact tableau.
        let k3 = tr.eval_u(&x.axpy(0.5 * h, &k2), w[0] + 0.5 * h + 1e-6 * h);
        let k4 = tr.eval_u(&x.axpy(h, &k3), w[0] + h * (1.0 - 1e-6));
        let sum = k1.add(&k2.scale(2.0)).add(&k3.scale(2.0)).add(&k4);
        x = x.axpy(h / 6.0, &sum);
    }
    tr.finish(&x, 1.0)
}

/// §3.1 taxonomy-based initialization for distillation: a named
/// classical family at this NFE, in NS-coefficient form. `"auto"` picks
/// the strongest family the NFE admits — the same divisibility hierarchy
/// the router's `Auto` fallback uses (RK4 when 4 | NFE, midpoint when
/// 2 | NFE, Euler otherwise).
pub fn init_ns(kind: &str, nfe: usize) -> Result<NsSolver> {
    match kind {
        "euler" => Ok(euler_ns(&super::generic::uniform_times(nfe))),
        "midpoint" => {
            if nfe % 2 != 0 {
                bail!("midpoint init needs an even NFE (got {nfe})");
            }
            Ok(midpoint_ns(nfe))
        }
        "rk4" => {
            if nfe % 4 != 0 {
                bail!("rk4 init needs NFE divisible by 4 (got {nfe})");
            }
            Ok(rk4_ns(nfe))
        }
        "auto" | "" => Ok(if nfe % 4 == 0 {
            rk4_ns(nfe)
        } else if nfe % 2 == 0 {
            midpoint_ns(nfe)
        } else {
            euler_ns(&super::generic::uniform_times(nfe))
        }),
        other => bail!("unknown distillation init '{other}' (euler|midpoint|rk4|auto)"),
    }
}

pub fn ab2_ns(times: &[f64]) -> NsSolver {
    let mut tr = AffineTrace::new();
    let mut x = tr.x0();
    let mut prev: Option<Aff> = None;
    for i in 0..times.len() - 1 {
        let h = times[i + 1] - times[i];
        let u = tr.eval_u(&x, times[i]);
        match &prev {
            None => x = x.axpy(h, &u),
            Some(pu) => {
                let hp = times[i] - times[i - 1];
                x = x.axpy(h * (1.0 + h / (2.0 * hp)), &u).axpy(-h * h / (2.0 * hp), pu);
            }
        }
        prev = Some(u);
    }
    tr.finish(&x, *times.last().unwrap())
}

/// f = (u - beta x)/gamma as an affine expression.
fn pred_from_u(sched: Scheduler, p: Parametrization, t: f64, x: &Aff, u: &Aff) -> Aff {
    let (beta, gamma) = sched.uv_coeffs(t, p);
    u.axpy(-beta, x).scale(1.0 / gamma)
}

pub fn ddim_ns(sched: Scheduler, times: &[f64]) -> NsSolver {
    assert!(sched.alpha(times[0]) > 0.0, "DDIM needs alpha(t_0) > 0");
    let mut tr = AffineTrace::new();
    let mut x = tr.x0();
    for w in times.windows(2) {
        let (a0, s0) = (sched.alpha(w[0]), sched.sigma(w[0]));
        let (a1, s1) = (sched.alpha(w[1]), sched.sigma(w[1]));
        let u = tr.eval_u(&x, w[0]);
        let eps = pred_from_u(sched, Parametrization::Eps, w[0], &x, &u);
        x = x.scale(a1 / a0).add(&eps.scale(s1 - a1 * s0 / a0));
    }
    tr.finish(&x, *times.last().unwrap())
}

pub fn dpmpp_ns(sched: Scheduler, times: &[f64], order: usize) -> NsSolver {
    let lam = |t: f64| sched.alpha(t).max(1e-30).ln() - sched.sigma(t).max(1e-30).ln();
    let n = times.len() - 1;
    let mut tr = AffineTrace::new();
    let mut x = tr.x0();
    let mut prev: Option<(Aff, f64)> = None;
    for (i, w) in times.windows(2).enumerate() {
        let (s0, s1) = (sched.sigma(w[0]), sched.sigma(w[1]));
        let a1 = sched.alpha(w[1]);
        let h = lam(w[1]) - lam(w[0]);
        let u = tr.eval_u(&x, w[0]);
        let xhat = pred_from_u(sched, Parametrization::X, w[0], &x, &u);
        // lower_order_final, mirroring exponential::DpmPp
        let use_second = order >= 2 && prev.is_some() && i + 1 < n;
        let d = match (&prev, use_second) {
            (Some((ph, phh)), true) => {
                let r = phh / h;
                xhat.scale(1.0 + 1.0 / (2.0 * r)).axpy(-1.0 / (2.0 * r), ph)
            }
            _ => xhat.clone(),
        };
        x = x.scale(s1 / s0).add(&d.scale(a1 * (1.0 - (-h).exp())));
        prev = Some((xhat, h));
    }
    tr.finish(&x, *times.last().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::exponential::{shifted_times, Ddim, DpmPp};
    use crate::solver::field::NonlinearField;
    use crate::solver::generic::{uniform_times, Ab2, Euler, Midpoint, Rk4};
    use crate::solver::Solver;

    fn assert_same(a: &[f32], b: &[f32], tol: f32) {
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    /// Each generic family, direct vs NS-form, on a nonlinear field:
    /// the inclusion "generic ⊂ NS" of Thm 3.2, computationally.
    #[test]
    fn euler_equals_ns_form() {
        let f = NonlinearField { dim: 3 };
        let x0 = vec![0.5f32, -1.0, 1.5];
        let direct = Euler::new(8).sample(&f, &x0).unwrap();
        let ns = euler_ns(&uniform_times(8)).sample(&f, &x0).unwrap();
        assert_same(&ns, &direct, 1e-6);
    }

    #[test]
    fn midpoint_equals_ns_form() {
        let f = NonlinearField { dim: 3 };
        let x0 = vec![0.5f32, -1.0, 1.5];
        let direct = Midpoint::new(8).sample(&f, &x0).unwrap();
        let ns = midpoint_ns(8).sample(&f, &x0).unwrap();
        assert_same(&ns, &direct, 1e-5);
    }

    #[test]
    fn rk4_equals_ns_form() {
        let f = NonlinearField { dim: 2 };
        let x0 = vec![0.8f32, -0.3];
        let direct = Rk4::new(8).sample(&f, &x0).unwrap();
        let ns = rk4_ns(8).sample(&f, &x0).unwrap();
        // rk4 direct uses exact nodes; ns uses 1e-6-nudged evaluation
        // times, so allow a slightly looser tolerance.
        assert_same(&ns, &direct, 1e-4);
    }

    #[test]
    fn ab2_equals_ns_form() {
        let f = NonlinearField { dim: 2 };
        let x0 = vec![0.8f32, -0.3];
        let direct = Ab2::new(8).sample(&f, &x0).unwrap();
        let ns = ab2_ns(&uniform_times(8)).sample(&f, &x0).unwrap();
        assert_same(&ns, &direct, 1e-5);
    }

    #[test]
    fn ddim_equals_ns_form() {
        let f = NonlinearField { dim: 2 };
        let x0 = vec![0.4f32, -0.9];
        let d = Ddim::new(Scheduler::Vp, 8);
        let direct = d.sample(&f, &x0).unwrap();
        let ns = ddim_ns(Scheduler::Vp, &d.times).sample(&f, &x0).unwrap();
        assert_same(&ns, &direct, 1e-4);
    }

    #[test]
    fn ddim_equals_ns_form_shifted_fm() {
        let f = NonlinearField { dim: 2 };
        let x0 = vec![0.4f32, -0.9];
        let times = shifted_times(8, 0.05);
        let direct = Ddim { sched: Scheduler::FmOt, times: times.clone() }.sample(&f, &x0).unwrap();
        let ns = ddim_ns(Scheduler::FmOt, &times).sample(&f, &x0).unwrap();
        assert_same(&ns, &direct, 1e-4);
    }

    #[test]
    fn dpmpp_equals_ns_form() {
        let f = NonlinearField { dim: 2 };
        let x0 = vec![0.4f32, -0.9];
        for order in [1, 2] {
            for sched in [Scheduler::FmOt, Scheduler::Vp, Scheduler::Cosine] {
                let d = DpmPp::new(sched, 8, order);
                let direct = d.sample(&f, &x0).unwrap();
                let ns = dpmpp_ns(sched, &d.times, order).sample(&f, &x0).unwrap();
                assert_same(&ns, &direct, 1e-4);
            }
        }
    }

    #[test]
    fn init_ns_resolves_families_and_divisibility() {
        assert_eq!(init_ns("euler", 5).unwrap().nfe(), 5);
        assert_eq!(init_ns("midpoint", 6).unwrap().nfe(), 6);
        assert_eq!(init_ns("rk4", 8).unwrap().nfe(), 8);
        assert!(init_ns("midpoint", 5).is_err());
        assert!(init_ns("rk4", 6).is_err());
        assert!(init_ns("nope", 4).is_err());
        // auto follows the router's divisibility hierarchy
        assert_eq!(init_ns("auto", 8).unwrap(), rk4_ns(8));
        assert_eq!(init_ns("auto", 6).unwrap(), midpoint_ns(6));
        assert_eq!(init_ns("auto", 5).unwrap(), euler_ns(&uniform_times(5)));
    }

    /// Prop 3.1 reduction: random naive (c, d) rule vs reduced (a, b).
    #[test]
    fn prop31_reduction() {
        let n = 6;
        // deterministic pseudo-random coefficients
        let mut state = 12345u64;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((state >> 33) as f64 / (1u64 << 31) as f64) - 0.5
        };
        let c_rows: Vec<Vec<f64>> = (0..n).map(|i| (0..=i).map(|_| next() * 0.8).collect()).collect();
        let d_rows: Vec<Vec<f64>> = (0..n).map(|i| (0..=i).map(|_| next() * 0.5).collect()).collect();
        let times = uniform_times(n);
        let f = NonlinearField { dim: 2 };
        let x0 = vec![0.7f32, -0.2];

        // naive stepping keeping all X, U
        let mut xs: Vec<Vec<f32>> = vec![x0.clone()];
        let mut us: Vec<Vec<f32>> = Vec::new();
        use crate::solver::field::Field;
        for i in 0..n {
            us.push(f.eval(times[i], &xs[i]).unwrap());
            let mut next_x = vec![0f32; 2];
            for j in 0..=i {
                for k in 0..2 {
                    next_x[k] += c_rows[i][j] as f32 * xs[j][k] + d_rows[i][j] as f32 * us[j][k];
                }
            }
            xs.push(next_x);
        }

        let (a, b) = reduce_cd_to_ab(&c_rows, &d_rows);
        let solver = NsSolver { times, a, b };
        solver.validate().unwrap();
        let reduced = solver.sample(&f, &x0).unwrap();
        assert_same(&reduced, xs.last().unwrap(), 1e-4);
    }

    /// The NS form of a k-th order method keeps its order.
    #[test]
    fn ns_form_preserves_accuracy_order() {
        let f = NonlinearField { dim: 1 };
        let x0 = vec![0.8f32];
        let reference = Rk4::new(512).sample(&f, &x0).unwrap()[0] as f64;
        let err = |s: &NsSolver| (s.sample(&f, &x0).unwrap()[0] as f64 - reference).abs();
        let p = (err(&midpoint_ns(16)) / err(&midpoint_ns(32))).log2();
        assert!((1.5..2.7).contains(&p), "order {p}");
    }
}
