//! The `Field` abstraction: anything that evaluates the sampling velocity
//! field u_t(x) over a row-major batch. The PJRT-backed model field lives
//! in `runtime::model_field`; here are the composable wrappers and the
//! analytic fields used by unit tests and benches.

use anyhow::Result;

use super::scheduler::Scheduler;

/// A batched velocity field. `x` is row-major `[batch, dim]`; returns the
/// same shape. Implementations must be deterministic.
pub trait Field: Send + Sync {
    fn dim(&self) -> usize;

    /// Evaluate u(t, x) for every row of x.
    fn eval(&self, t: f64, x: &[f32]) -> Result<Vec<f32>>;

    /// Write u(t, x) into `out` (same length as `x`) without allocating
    /// the result buffer — the hot-path entry used by `sample_into`.
    /// Must produce values bit-identical to `eval`, and must fully
    /// overwrite `out` (callers pass reused workspace buffers whose prior
    /// contents are arbitrary). Implementations should avoid per-call
    /// heap allocation: `ModelField` routes through the pooled device-lane
    /// RPC, which allocates nothing at steady state (DESIGN.md §5). The
    /// default falls back to `eval` and copies.
    fn eval_into(&self, t: f64, x: &[f32], out: &mut [f32]) -> Result<()> {
        let u = self.eval(t, x)?;
        anyhow::ensure!(
            u.len() == out.len(),
            "eval returned {} values for an output buffer of {}",
            u.len(),
            out.len()
        );
        out.copy_from_slice(&u);
        Ok(())
    }

    /// Model forward passes consumed per `eval` call *per row* (CFG-guided
    /// PJRT fields report 2). Used for NFE accounting.
    fn forwards_per_eval(&self) -> usize {
        1
    }

    /// Directional derivative (JVP) of the field along the tangent
    /// `(dt, v)`:
    ///   d/dε u(t + ε·dt, x + ε·v) |_{ε=0},
    /// batched row-major like `eval` (`v` has the same shape as `x`, `dt`
    /// is a scalar time tangent shared by the batch).
    ///
    /// The first-order distillation trainer (`distill/grad.rs`) uses this
    /// to propagate solver-parameter tangents through the field
    /// dependence of later velocities, and time-grid gradients via the
    /// `dt` component. The default is a central difference through `eval`
    /// (two extra field evaluations — exact for affine fields such as the
    /// stub backend's, O(ε²) otherwise); analytic fields override it with
    /// closed forms. The perturbation direction is normalized so large
    /// tangents never leave the linearization region, and `t ± h·dt` is
    /// evaluated unclamped (h ≤ 1e-3, and pinned endpoint times never
    /// carry a time tangent).
    fn jvp(&self, t: f64, x: &[f32], v: &[f32], dt: f64) -> Result<Vec<f32>> {
        anyhow::ensure!(v.len() == x.len(), "jvp tangent length {} != x length {}", v.len(), x.len());
        let scale = v.iter().fold(dt.abs(), |m, &vi| m.max((vi as f64).abs()));
        if scale == 0.0 {
            return Ok(vec![0.0; x.len()]);
        }
        let h = 1e-3 / scale;
        let xp: Vec<f32> = x
            .iter()
            .zip(v.iter())
            .map(|(&xv, &vv)| (xv as f64 + h * vv as f64) as f32)
            .collect();
        let xm: Vec<f32> = x
            .iter()
            .zip(v.iter())
            .map(|(&xv, &vv)| (xv as f64 - h * vv as f64) as f32)
            .collect();
        let up = self.eval(t + h * dt, &xp)?;
        let um = self.eval(t - h * dt, &xm)?;
        Ok(up
            .iter()
            .zip(um.iter())
            .map(|(&a, &b)| ((a as f64 - b as f64) / (2.0 * h)) as f32)
            .collect())
    }

    /// Batched multi-tangent JVP — the wavefront entry of the distill
    /// gradient engine (`distill::grad`): all tangents share one base
    /// point `(t, x)`, so a device-backed field can push every tangent
    /// through the model in a single bucketized dispatch instead of one
    /// round trip per tangent.
    ///
    /// `tangents` is row-major `[T, x.len()]` (tangent i in
    /// `tangents[i*len..(i+1)*len]`), `dts` holds the scalar time tangent
    /// of each, and `out` (same shape as `tangents`) receives the JVPs.
    /// Each output row must equal what [`Field::jvp`] returns for that
    /// tangent alone — the default delegates tangent-by-tangent, so any
    /// field is correct by construction; `ModelField` overrides it with a
    /// stacked central-difference eval (`runtime::model_field`), and the
    /// analytic fields with allocation-free closed-form loops.
    fn jvp_batch_into(
        &self,
        t: f64,
        x: &[f32],
        tangents: &[f32],
        dts: &[f64],
        out: &mut [f32],
    ) -> Result<()> {
        let len = x.len();
        anyhow::ensure!(
            tangents.len() == dts.len() * len && out.len() == tangents.len(),
            "jvp_batch_into: tangents [{}] / dts [{}] / out [{}] disagree with x [{len}]",
            tangents.len(),
            dts.len(),
            out.len()
        );
        for (i, &dt) in dts.iter().enumerate() {
            let u = self.jvp(t, x, &tangents[i * len..(i + 1) * len], dt)?;
            out[i * len..(i + 1) * len].copy_from_slice(&u);
        }
        Ok(())
    }

    /// Field evaluations charged for one (batched) JVP with these time
    /// tangents — the honest NFE cost of `jvp_batch_into` (and of `jvp`,
    /// via a single-entry slice). The default is the central-difference
    /// cost of two evals per tangent; closed-form fields override it with
    /// their true cost (zero for purely analytic JVPs, two per *timed*
    /// tangent when only the ∂u/∂t part falls back to differences).
    /// `CountingField` and the trainer's `forwards` bookkeeping both
    /// meter JVPs through this, so the old sequential path and the new
    /// wavefront path stay consistent.
    fn jvp_cost(&self, dts: &[f64]) -> usize {
        2 * dts.len()
    }
}

/// Counting wrapper: tracks evaluations (NFE) across a sampling run.
pub struct CountingField<'a> {
    pub inner: &'a dyn Field,
    count: std::sync::atomic::AtomicUsize,
}

impl<'a> CountingField<'a> {
    pub fn new(inner: &'a dyn Field) -> Self {
        CountingField { inner, count: std::sync::atomic::AtomicUsize::new(0) }
    }

    pub fn count(&self) -> usize {
        self.count.load(std::sync::atomic::Ordering::Relaxed)
    }
}

impl<'a> Field for CountingField<'a> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval(&self, t: f64, x: &[f32]) -> Result<Vec<f32>> {
        self.count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.inner.eval(t, x)
    }

    fn eval_into(&self, t: f64, x: &[f32], out: &mut [f32]) -> Result<()> {
        self.count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.inner.eval_into(t, x, out)
    }

    fn forwards_per_eval(&self) -> usize {
        self.inner.forwards_per_eval()
    }

    /// Counted at the inner field's true cost ([`Field::jvp_cost`]): two
    /// evals for a finite-difference JVP, zero for a closed form, two
    /// per *timed* tangent for fields whose ∂u/∂t alone needs
    /// differences.
    fn jvp(&self, t: f64, x: &[f32], v: &[f32], dt: f64) -> Result<Vec<f32>> {
        self.count
            .fetch_add(self.inner.jvp_cost(std::slice::from_ref(&dt)), std::sync::atomic::Ordering::Relaxed);
        self.inner.jvp(t, x, v, dt)
    }

    /// A batched JVP with T tangents counts as `jvp_cost(dts)` evals —
    /// 2·T under central differences — exactly what T sequential `jvp`
    /// calls would have counted, so NFE bookkeeping is identical across
    /// the sequential and wavefront gradient paths.
    fn jvp_batch_into(
        &self,
        t: f64,
        x: &[f32],
        tangents: &[f32],
        dts: &[f64],
        out: &mut [f32],
    ) -> Result<()> {
        self.count
            .fetch_add(self.inner.jvp_cost(dts), std::sync::atomic::Ordering::Relaxed);
        self.inner.jvp_batch_into(t, x, tangents, dts, out)
    }

    fn jvp_cost(&self, dts: &[f64]) -> usize {
        self.inner.jvp_cost(dts)
    }
}

/// Scale-Time transformed field (eq. 7):
///   ū_r(x) = (ṡ_r/s_r) x + ṫ_r s_r u_{t_r}(x / s_r).
/// `nodes` supplies (t, ṫ, s, ṡ) as closures so both analytic transforms
/// (preconditioning, EDM) and tabulated ones fit.
pub struct ScaleTimeField<'a> {
    pub inner: &'a dyn Field,
    pub t_of_r: Box<dyn Fn(f64) -> f64 + Send + Sync + 'a>,
    pub s_of_r: Box<dyn Fn(f64) -> f64 + Send + Sync + 'a>,
    pub dt_of_r: Box<dyn Fn(f64) -> f64 + Send + Sync + 'a>,
    pub ds_of_r: Box<dyn Fn(f64) -> f64 + Send + Sync + 'a>,
}

impl<'a> Field for ScaleTimeField<'a> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval(&self, r: f64, x: &[f32]) -> Result<Vec<f32>> {
        let s = (self.s_of_r)(r);
        let ds = (self.ds_of_r)(r);
        let t = (self.t_of_r)(r);
        let dt = (self.dt_of_r)(r);
        let scaled: Vec<f32> = x.iter().map(|&v| v / s as f32).collect();
        let u = self.inner.eval(t, &scaled)?;
        Ok(x.iter()
            .zip(u.iter())
            .map(|(&xv, &uv)| ((ds / s) * xv as f64 + dt * s * uv as f64) as f32)
            .collect())
    }

    fn forwards_per_eval(&self) -> usize {
        self.inner.forwards_per_eval()
    }
}

/// sigma0 preconditioning (eq. 14) as a ScaleTimeField, with the
/// endpoint-stable closed forms mirrored from python/compile/bns.py.
pub fn precondition_field<'a>(
    inner: &'a dyn Field,
    sched: Scheduler,
    sigma0: f64,
) -> ScaleTimeField<'a> {
    let t_of_r = move |r: f64| -> f64 {
        match sched {
            Scheduler::FmOt => r / (r + sigma0 * (1.0 - r)),
            Scheduler::Cosine => {
                let (s, c) = (0.5 * std::f64::consts::PI * r).sin_cos();
                (2.0 / std::f64::consts::PI) * s.atan2(sigma0 * c)
            }
            // For schedulers with snr(0) > 0 (VP), snr(r)/sigma0 can fall
            // below the path's snr range for small r; clamp to [0, 1] —
            // the preconditioned source then matches the path endpoint.
            _ => sched.snr_inv(sched.snr(r) / sigma0).clamp(0.0, 1.0),
        }
    };
    let s_of_r = move |r: f64| -> f64 {
        match sched {
            Scheduler::FmOt => r + sigma0 * (1.0 - r),
            Scheduler::Cosine => {
                let (s, c) = (0.5 * std::f64::consts::PI * r).sin_cos();
                (s * s + sigma0 * sigma0 * c * c).sqrt()
            }
            _ => {
                let t = t_of_r(r);
                let (a_t, s_t) = (sched.alpha(t), sched.sigma(t));
                if a_t > s_t {
                    sched.alpha(r) / a_t.max(1e-20)
                } else {
                    sigma0 * sched.sigma(r) / s_t.max(1e-20)
                }
            }
        }
    };
    // central differences for the derivatives (exactness is not needed:
    // the transform only shapes baseline solvers, BNS coefficients are
    // folded python-side)
    let h = 1e-5;
    let dt_of_r = move |r: f64| (t_of_r((r + h).min(1.0)) - t_of_r((r - h).max(0.0))) / (((r + h).min(1.0)) - ((r - h).max(0.0)));
    let ds_of_r = move |r: f64| (s_of_r((r + h).min(1.0)) - s_of_r((r - h).max(0.0))) / (((r + h).min(1.0)) - ((r - h).max(0.0)));
    ScaleTimeField {
        inner,
        t_of_r: Box::new(t_of_r),
        s_of_r: Box::new(s_of_r),
        dt_of_r: Box::new(dt_of_r),
        ds_of_r: Box::new(ds_of_r),
    }
}

// ---------------------------------------------------------------------------
// Analytic fields for tests/benches
// ---------------------------------------------------------------------------

/// Linear scalar-per-dim ODE ẋ = k(t) x + c(t), with closed-form solution
/// when k, c are constants: x(t) = (x0 + c/k) e^{kt} - c/k.
pub struct LinearField {
    pub dim: usize,
    pub k: f64,
    pub c: f64,
}

impl Field for LinearField {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval(&self, _t: f64, x: &[f32]) -> Result<Vec<f32>> {
        Ok(x.iter().map(|&v| (self.k * v as f64 + self.c) as f32).collect())
    }

    /// Closed form: ∂u/∂x = k (diagonal), ∂u/∂t = 0.
    fn jvp(&self, _t: f64, _x: &[f32], v: &[f32], _dt: f64) -> Result<Vec<f32>> {
        Ok(v.iter().map(|&vv| (self.k * vv as f64) as f32).collect())
    }

    /// Closed-form batch: one allocation-free pass over all tangents.
    fn jvp_batch_into(
        &self,
        _t: f64,
        _x: &[f32],
        tangents: &[f32],
        dts: &[f64],
        out: &mut [f32],
    ) -> Result<()> {
        anyhow::ensure!(
            out.len() == tangents.len() && tangents.len() % dts.len().max(1) == 0,
            "jvp_batch_into: tangents [{}] / dts [{}] / out [{}] disagree",
            tangents.len(),
            dts.len(),
            out.len()
        );
        for (o, &vv) in out.iter_mut().zip(tangents.iter()) {
            *o = (self.k * vv as f64) as f32;
        }
        Ok(())
    }

    /// The JVP is fully analytic — zero field evaluations.
    fn jvp_cost(&self, _dts: &[f64]) -> usize {
        0
    }
}

impl LinearField {
    /// Exact solution at t = 1 from x(0) = x0.
    pub fn exact_at_1(&self, x0: f32) -> f32 {
        let ck = self.c / self.k;
        ((x0 as f64 + ck) * self.k.exp() - ck) as f32
    }
}

/// Nonlinear smooth field for order-of-accuracy tests:
/// ẋ = sin(3t) x + 0.3 cos(x) (no closed form; reference via fine RK4).
pub struct NonlinearField {
    pub dim: usize,
}

impl Field for NonlinearField {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval(&self, t: f64, x: &[f32]) -> Result<Vec<f32>> {
        Ok(x.iter()
            .map(|&v| ((3.0 * t).sin() * v as f64 + 0.3 * (v as f64).cos()) as f32)
            .collect())
    }

    /// Closed form: ∂u/∂x = sin(3t) − 0.3 sin(x), ∂u/∂t = 3 cos(3t)·x.
    fn jvp(&self, t: f64, x: &[f32], v: &[f32], dt: f64) -> Result<Vec<f32>> {
        let (s3t, c3t) = (3.0 * t).sin_cos();
        Ok(x.iter()
            .zip(v.iter())
            .map(|(&xv, &vv)| {
                ((s3t - 0.3 * (xv as f64).sin()) * vv as f64 + 3.0 * c3t * xv as f64 * dt) as f32
            })
            .collect())
    }

    /// Closed-form batch: same math as `jvp`, no per-tangent allocation.
    fn jvp_batch_into(
        &self,
        t: f64,
        x: &[f32],
        tangents: &[f32],
        dts: &[f64],
        out: &mut [f32],
    ) -> Result<()> {
        let len = x.len();
        anyhow::ensure!(
            tangents.len() == dts.len() * len && out.len() == tangents.len(),
            "jvp_batch_into: tangents [{}] / dts [{}] / out [{}] disagree with x [{len}]",
            tangents.len(),
            dts.len(),
            out.len()
        );
        let (s3t, c3t) = (3.0 * t).sin_cos();
        for (i, &dt) in dts.iter().enumerate() {
            let v = &tangents[i * len..(i + 1) * len];
            let o = &mut out[i * len..(i + 1) * len];
            for ((ov, &xv), &vv) in o.iter_mut().zip(x.iter()).zip(v.iter()) {
                *ov = ((s3t - 0.3 * (xv as f64).sin()) * vv as f64
                    + 3.0 * c3t * xv as f64 * dt) as f32;
            }
        }
        Ok(())
    }

    /// Fully analytic JVP — zero field evaluations.
    fn jvp_cost(&self, _dts: &[f64]) -> usize {
        0
    }
}

/// The exact velocity field of a Gaussian-mixture data distribution under
/// a Gaussian path — the strongest test field: solvers integrate it and
/// the induced x(1) distribution is known. For a single Gaussian
/// N(mu, s1^2) target under scheduler (alpha, sigma):
///   p_t = N(alpha mu, (alpha s1)^2 + sigma^2), and
///   u_t(x) follows from the conditional-expectation formula.
pub struct GaussianTargetField {
    pub dim: usize,
    pub sched: Scheduler,
    pub mu: f32,
    pub s1: f64,
}

impl Field for GaussianTargetField {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval(&self, t: f64, x: &[f32]) -> Result<Vec<f32>> {
        let (a, s) = (self.sched.alpha(t), self.sched.sigma(t));
        let (da, ds) = (self.sched.dalpha(t), self.sched.dsigma(t));
        let var = (a * self.s1).powi(2) + s * s;
        // E[x1 | x_t] for scalar gaussian target
        // = (mu sigma^2 + alpha s1^2 (x)) / var … per dimension:
        Ok(x.iter()
            .map(|&xv| {
                let e_x1 = (self.mu as f64 * s * s + a * self.s1 * self.s1 * xv as f64) / var;
                let e_x0 = (xv as f64 - a * e_x1) / s.max(1e-9);
                (da * e_x1 + ds * e_x0) as f32
            })
            .collect())
    }

    /// The field is affine in x: u_t(x) = A(t)·x + B(t). The spatial part
    /// of the JVP is the closed form A(t)·v; the time part needs second
    /// derivatives of the scheduler (unavailable), so it falls back to a
    /// central difference of `eval` at fixed x — still exact in x.
    fn jvp(&self, t: f64, x: &[f32], v: &[f32], dt: f64) -> Result<Vec<f32>> {
        let (a, s) = (self.sched.alpha(t), self.sched.sigma(t));
        let (da, ds) = (self.sched.dalpha(t), self.sched.dsigma(t));
        let var = (a * self.s1).powi(2) + s * s;
        let de1 = a * self.s1 * self.s1 / var; // dE[x1|x]/dx
        let coef = da * de1 + ds * (1.0 - a * de1) / s.max(1e-9); // A(t)
        let mut out: Vec<f32> = v.iter().map(|&vv| (coef * vv as f64) as f32).collect();
        if dt != 0.0 {
            let h = 1e-4;
            let up = self.eval(t + h, x)?;
            let um = self.eval(t - h, x)?;
            for ((o, &p), &m) in out.iter_mut().zip(up.iter()).zip(um.iter()) {
                *o += (((p as f64 - m as f64) / (2.0 * h)) * dt) as f32;
            }
        }
        Ok(out)
    }

    /// Closed form in x for every tangent; the (at most once per
    /// wavefront step) timed tangent reuses one shared `t ± h` eval pair.
    fn jvp_batch_into(
        &self,
        t: f64,
        x: &[f32],
        tangents: &[f32],
        dts: &[f64],
        out: &mut [f32],
    ) -> Result<()> {
        let len = x.len();
        anyhow::ensure!(
            tangents.len() == dts.len() * len && out.len() == tangents.len(),
            "jvp_batch_into: tangents [{}] / dts [{}] / out [{}] disagree with x [{len}]",
            tangents.len(),
            dts.len(),
            out.len()
        );
        let (a, s) = (self.sched.alpha(t), self.sched.sigma(t));
        let (da, ds) = (self.sched.dalpha(t), self.sched.dsigma(t));
        let var = (a * self.s1).powi(2) + s * s;
        let de1 = a * self.s1 * self.s1 / var;
        let coef = da * de1 + ds * (1.0 - a * de1) / s.max(1e-9);
        // the time part is shared by every timed tangent (same base x)
        let timed = if dts.iter().any(|&dt| dt != 0.0) {
            let h = 1e-4;
            Some((self.eval(t + h, x)?, self.eval(t - h, x)?, h))
        } else {
            None
        };
        for (i, &dt) in dts.iter().enumerate() {
            let v = &tangents[i * len..(i + 1) * len];
            let o = &mut out[i * len..(i + 1) * len];
            for (ov, &vv) in o.iter_mut().zip(v.iter()) {
                *ov = (coef * vv as f64) as f32;
            }
            if dt != 0.0 {
                let (up, um, h) = timed.as_ref().unwrap();
                for ((ov, &p), &m) in o.iter_mut().zip(up.iter()).zip(um.iter()) {
                    *ov += (((p as f64 - m as f64) / (2.0 * h)) * dt) as f32;
                }
            }
        }
        Ok(())
    }

    /// Closed form in x; a batch with any *timed* tangent pays one
    /// shared two-eval central-difference pair for the ∂u/∂t part
    /// (`jvp_batch_into` computes it once at the common base point, so
    /// the cost does not scale with the number of timed tangents).
    fn jvp_cost(&self, dts: &[f64]) -> usize {
        if dts.iter().any(|&dt| dt != 0.0) {
            2
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::scheduler::Scheduler;

    #[test]
    fn counting_field_counts() {
        let f = LinearField { dim: 2, k: -1.0, c: 0.5 };
        let cf = CountingField::new(&f);
        let x = vec![1.0f32, 2.0];
        for _ in 0..5 {
            cf.eval(0.3, &x).unwrap();
        }
        assert_eq!(cf.count(), 5);
    }

    #[test]
    fn eval_into_matches_eval_and_counts() {
        let f = NonlinearField { dim: 2 };
        let cf = CountingField::new(&f);
        let x = vec![0.3f32, -0.7, 1.1, 0.0];
        let a = cf.eval(0.4, &x).unwrap();
        let mut b = vec![0f32; x.len()];
        cf.eval_into(0.4, &x, &mut b).unwrap();
        assert_eq!(a, b);
        assert_eq!(cf.count(), 2);
    }

    /// Strips a field's `jvp` override so the trait's central-difference
    /// default applies — lets tests pin closed forms against it.
    struct FdOnly<'a>(&'a dyn Field);

    impl Field for FdOnly<'_> {
        fn dim(&self) -> usize {
            self.0.dim()
        }

        fn eval(&self, t: f64, x: &[f32]) -> Result<Vec<f32>> {
            self.0.eval(t, x)
        }
    }

    #[test]
    fn closed_form_jvp_matches_finite_differences() {
        let lin = LinearField { dim: 3, k: -0.7, c: 0.2 };
        let nonlin = NonlinearField { dim: 3 };
        let gauss = GaussianTargetField { dim: 3, sched: Scheduler::FmOt, mu: 0.3, s1: 0.5 };
        let fields: [&dyn Field; 3] = [&lin, &nonlin, &gauss];
        let x = vec![0.4f32, -1.1, 0.9];
        let v = vec![1.3f32, -0.5, 2.0];
        for f in fields {
            for dt in [0.0, 1.0, -0.5] {
                let a = f.jvp(0.35, &x, &v, dt).unwrap();
                let b = FdOnly(f).jvp(0.35, &x, &v, dt).unwrap();
                for (u, w) in a.iter().zip(b.iter()) {
                    assert!((u - w).abs() < 2e-2 * (1.0 + w.abs()), "{u} vs {w} (dt={dt})");
                }
            }
        }
    }

    #[test]
    fn jvp_zero_tangent_is_zero() {
        let f = NonlinearField { dim: 2 };
        let x = vec![0.5f32, -0.5];
        let z = vec![0.0f32, 0.0];
        // default impl short-circuits; closed form multiplies through
        assert_eq!(FdOnly(&f).jvp(0.4, &x, &z, 0.0).unwrap(), z);
        assert_eq!(f.jvp(0.4, &x, &z, 0.0).unwrap(), z);
    }

    /// JVP accounting is metered by `jvp_cost`: finite-difference JVPs
    /// count two evals per tangent (batched T tangents -> 2·T), closed
    /// forms count their true (zero / timed-only) cost — identically
    /// across the sequential and batched paths.
    #[test]
    fn counting_field_meters_jvp_cost() {
        let f = NonlinearField { dim: 2 };
        let x = vec![1.0f32, 2.0];
        let v = vec![0.5f32, -0.5, 1.0, 0.25]; // two stacked tangents
        let dts = [0.0, 1.0];
        let mut out = vec![0f32; 4];

        // default (finite-difference) jvp: 2 evals per tangent
        let fd = FdOnly(&f);
        let cf = CountingField::new(&fd);
        cf.jvp(0.3, &x, &v[..2], 0.0).unwrap();
        assert_eq!(cf.count(), 2);
        cf.jvp_batch_into(0.3, &x, &v, &dts, &mut out).unwrap();
        assert_eq!(cf.count(), 2 + 2 * dts.len(), "T batched tangents count 2·T");

        // closed forms count their true cost: zero for fully analytic
        let lin = LinearField { dim: 2, k: -1.0, c: 0.0 };
        let cl = CountingField::new(&lin);
        cl.jvp(0.3, &x, &v[..2], 0.0).unwrap();
        cl.jvp_batch_into(0.3, &x, &v, &dts, &mut out).unwrap();
        assert_eq!(cl.count(), 0, "analytic JVPs cost no evals");

        // ... and two evals per *timed* tangent when only ∂u/∂t needs
        // differences (GaussianTargetField)
        let g = GaussianTargetField { dim: 2, sched: Scheduler::FmOt, mu: 0.1, s1: 0.4 };
        let cg = CountingField::new(&g);
        cg.jvp_batch_into(0.3, &x, &v, &dts, &mut out).unwrap();
        assert_eq!(cg.count(), 2, "one timed tangent -> one central-difference pair");
    }

    /// `jvp_batch_into` must equal tangent-by-tangent `jvp` on every
    /// field — closed-form overrides and the trait default alike.
    #[test]
    fn jvp_batch_matches_sequential_jvp() {
        let lin = LinearField { dim: 3, k: -0.7, c: 0.2 };
        let nonlin = NonlinearField { dim: 3 };
        let gauss = GaussianTargetField { dim: 3, sched: Scheduler::FmOt, mu: 0.3, s1: 0.5 };
        let fd = FdOnly(&nonlin);
        let fields: [&dyn Field; 4] = [&lin, &nonlin, &gauss, &fd];
        let x = vec![0.4f32, -1.1, 0.9, 0.2, 1.4, -0.3];
        let tangents = vec![
            1.3f32, -0.5, 2.0, 0.1, -1.0, 0.7, // tangent 0
            0.0, 0.0, 0.0, 0.0, 0.0, 0.0, // tangent 1: pure time
            -0.2, 0.9, 0.4, -1.3, 0.6, 0.05, // tangent 2
        ];
        let dts = [0.0, 1.0, -0.5];
        for f in fields {
            let mut batch = vec![f32::NAN; tangents.len()];
            f.jvp_batch_into(0.35, &x, &tangents, &dts, &mut batch).unwrap();
            for (i, &dt) in dts.iter().enumerate() {
                let seq = f.jvp(0.35, &x, &tangents[i * 6..(i + 1) * 6], dt).unwrap();
                assert_eq!(
                    seq.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    batch[i * 6..(i + 1) * 6].iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "tangent {i} (dt={dt})"
                );
            }
        }
    }

    #[test]
    fn precondition_identity_at_sigma0_one() {
        let f = NonlinearField { dim: 3 };
        let pf = precondition_field(&f, Scheduler::FmOt, 1.0);
        let x = vec![0.5f32, -1.0, 2.0];
        let a = f.eval(0.4, &x).unwrap();
        let b = pf.eval(0.4, &x).unwrap();
        for (u, v) in a.iter().zip(b.iter()) {
            assert!((u - v).abs() < 1e-4, "{u} vs {v}");
        }
    }

    #[test]
    fn precondition_endpoints_regular() {
        for sched in [Scheduler::FmOt, Scheduler::Cosine, Scheduler::Vp] {
            let f = NonlinearField { dim: 1 };
            let pf = precondition_field(&f, sched, 5.0);
            for r in [0.0, 0.5, 1.0] {
                let s = (pf.s_of_r)(r);
                let t = (pf.t_of_r)(r);
                assert!(s.is_finite() && s > 0.0, "{:?} s({r}) = {s}", sched);
                assert!((0.0..=1.0).contains(&t), "{:?} t({r}) = {t}", sched);
            }
            assert!(((pf.s_of_r)(0.0) - 5.0).abs() < 1e-6, "{:?}", sched);
            assert!(((pf.s_of_r)(1.0) - 1.0).abs() < 2e-3, "{:?}", sched);
        }
    }
}
