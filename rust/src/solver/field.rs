//! The `Field` abstraction: anything that evaluates the sampling velocity
//! field u_t(x) over a row-major batch. The PJRT-backed model field lives
//! in `runtime::model_field`; here are the composable wrappers and the
//! analytic fields used by unit tests and benches.

use anyhow::Result;

use super::scheduler::Scheduler;

/// A batched velocity field. `x` is row-major `[batch, dim]`; returns the
/// same shape. Implementations must be deterministic.
pub trait Field: Send + Sync {
    fn dim(&self) -> usize;

    /// Evaluate u(t, x) for every row of x.
    fn eval(&self, t: f64, x: &[f32]) -> Result<Vec<f32>>;

    /// Write u(t, x) into `out` (same length as `x`) without allocating
    /// the result buffer — the hot-path entry used by `sample_into`.
    /// Must produce values bit-identical to `eval`, and must fully
    /// overwrite `out` (callers pass reused workspace buffers whose prior
    /// contents are arbitrary). Implementations should avoid per-call
    /// heap allocation: `ModelField` routes through the pooled device-lane
    /// RPC, which allocates nothing at steady state (DESIGN.md §5). The
    /// default falls back to `eval` and copies.
    fn eval_into(&self, t: f64, x: &[f32], out: &mut [f32]) -> Result<()> {
        let u = self.eval(t, x)?;
        anyhow::ensure!(
            u.len() == out.len(),
            "eval returned {} values for an output buffer of {}",
            u.len(),
            out.len()
        );
        out.copy_from_slice(&u);
        Ok(())
    }

    /// Model forward passes consumed per `eval` call *per row* (CFG-guided
    /// PJRT fields report 2). Used for NFE accounting.
    fn forwards_per_eval(&self) -> usize {
        1
    }

    /// Directional derivative (JVP) of the field along the tangent
    /// `(dt, v)`:
    ///   d/dε u(t + ε·dt, x + ε·v) |_{ε=0},
    /// batched row-major like `eval` (`v` has the same shape as `x`, `dt`
    /// is a scalar time tangent shared by the batch).
    ///
    /// The first-order distillation trainer (`distill/grad.rs`) uses this
    /// to propagate solver-parameter tangents through the field
    /// dependence of later velocities, and time-grid gradients via the
    /// `dt` component. The default is a central difference through `eval`
    /// (two extra field evaluations — exact for affine fields such as the
    /// stub backend's, O(ε²) otherwise); analytic fields override it with
    /// closed forms. The perturbation direction is normalized so large
    /// tangents never leave the linearization region, and `t ± h·dt` is
    /// evaluated unclamped (h ≤ 1e-3, and pinned endpoint times never
    /// carry a time tangent).
    fn jvp(&self, t: f64, x: &[f32], v: &[f32], dt: f64) -> Result<Vec<f32>> {
        anyhow::ensure!(v.len() == x.len(), "jvp tangent length {} != x length {}", v.len(), x.len());
        let scale = v.iter().fold(dt.abs(), |m, &vi| m.max((vi as f64).abs()));
        if scale == 0.0 {
            return Ok(vec![0.0; x.len()]);
        }
        let h = 1e-3 / scale;
        let xp: Vec<f32> = x
            .iter()
            .zip(v.iter())
            .map(|(&xv, &vv)| (xv as f64 + h * vv as f64) as f32)
            .collect();
        let xm: Vec<f32> = x
            .iter()
            .zip(v.iter())
            .map(|(&xv, &vv)| (xv as f64 - h * vv as f64) as f32)
            .collect();
        let up = self.eval(t + h * dt, &xp)?;
        let um = self.eval(t - h * dt, &xm)?;
        Ok(up
            .iter()
            .zip(um.iter())
            .map(|(&a, &b)| ((a as f64 - b as f64) / (2.0 * h)) as f32)
            .collect())
    }
}

/// Counting wrapper: tracks evaluations (NFE) across a sampling run.
pub struct CountingField<'a> {
    pub inner: &'a dyn Field,
    count: std::sync::atomic::AtomicUsize,
}

impl<'a> CountingField<'a> {
    pub fn new(inner: &'a dyn Field) -> Self {
        CountingField { inner, count: std::sync::atomic::AtomicUsize::new(0) }
    }

    pub fn count(&self) -> usize {
        self.count.load(std::sync::atomic::Ordering::Relaxed)
    }
}

impl<'a> Field for CountingField<'a> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval(&self, t: f64, x: &[f32]) -> Result<Vec<f32>> {
        self.count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.inner.eval(t, x)
    }

    fn eval_into(&self, t: f64, x: &[f32], out: &mut [f32]) -> Result<()> {
        self.count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.inner.eval_into(t, x, out)
    }

    fn forwards_per_eval(&self) -> usize {
        self.inner.forwards_per_eval()
    }

    /// Counted as two evaluations — the finite-difference cost of the
    /// default `jvp`. Closed-form overrides are cheaper, so this is a
    /// conservative (upper-bound) accounting.
    fn jvp(&self, t: f64, x: &[f32], v: &[f32], dt: f64) -> Result<Vec<f32>> {
        self.count.fetch_add(2, std::sync::atomic::Ordering::Relaxed);
        self.inner.jvp(t, x, v, dt)
    }
}

/// Scale-Time transformed field (eq. 7):
///   ū_r(x) = (ṡ_r/s_r) x + ṫ_r s_r u_{t_r}(x / s_r).
/// `nodes` supplies (t, ṫ, s, ṡ) as closures so both analytic transforms
/// (preconditioning, EDM) and tabulated ones fit.
pub struct ScaleTimeField<'a> {
    pub inner: &'a dyn Field,
    pub t_of_r: Box<dyn Fn(f64) -> f64 + Send + Sync + 'a>,
    pub s_of_r: Box<dyn Fn(f64) -> f64 + Send + Sync + 'a>,
    pub dt_of_r: Box<dyn Fn(f64) -> f64 + Send + Sync + 'a>,
    pub ds_of_r: Box<dyn Fn(f64) -> f64 + Send + Sync + 'a>,
}

impl<'a> Field for ScaleTimeField<'a> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval(&self, r: f64, x: &[f32]) -> Result<Vec<f32>> {
        let s = (self.s_of_r)(r);
        let ds = (self.ds_of_r)(r);
        let t = (self.t_of_r)(r);
        let dt = (self.dt_of_r)(r);
        let scaled: Vec<f32> = x.iter().map(|&v| v / s as f32).collect();
        let u = self.inner.eval(t, &scaled)?;
        Ok(x.iter()
            .zip(u.iter())
            .map(|(&xv, &uv)| ((ds / s) * xv as f64 + dt * s * uv as f64) as f32)
            .collect())
    }

    fn forwards_per_eval(&self) -> usize {
        self.inner.forwards_per_eval()
    }
}

/// sigma0 preconditioning (eq. 14) as a ScaleTimeField, with the
/// endpoint-stable closed forms mirrored from python/compile/bns.py.
pub fn precondition_field<'a>(
    inner: &'a dyn Field,
    sched: Scheduler,
    sigma0: f64,
) -> ScaleTimeField<'a> {
    let t_of_r = move |r: f64| -> f64 {
        match sched {
            Scheduler::FmOt => r / (r + sigma0 * (1.0 - r)),
            Scheduler::Cosine => {
                let (s, c) = (0.5 * std::f64::consts::PI * r).sin_cos();
                (2.0 / std::f64::consts::PI) * s.atan2(sigma0 * c)
            }
            // For schedulers with snr(0) > 0 (VP), snr(r)/sigma0 can fall
            // below the path's snr range for small r; clamp to [0, 1] —
            // the preconditioned source then matches the path endpoint.
            _ => sched.snr_inv(sched.snr(r) / sigma0).clamp(0.0, 1.0),
        }
    };
    let s_of_r = move |r: f64| -> f64 {
        match sched {
            Scheduler::FmOt => r + sigma0 * (1.0 - r),
            Scheduler::Cosine => {
                let (s, c) = (0.5 * std::f64::consts::PI * r).sin_cos();
                (s * s + sigma0 * sigma0 * c * c).sqrt()
            }
            _ => {
                let t = t_of_r(r);
                let (a_t, s_t) = (sched.alpha(t), sched.sigma(t));
                if a_t > s_t {
                    sched.alpha(r) / a_t.max(1e-20)
                } else {
                    sigma0 * sched.sigma(r) / s_t.max(1e-20)
                }
            }
        }
    };
    // central differences for the derivatives (exactness is not needed:
    // the transform only shapes baseline solvers, BNS coefficients are
    // folded python-side)
    let h = 1e-5;
    let dt_of_r = move |r: f64| (t_of_r((r + h).min(1.0)) - t_of_r((r - h).max(0.0))) / (((r + h).min(1.0)) - ((r - h).max(0.0)));
    let ds_of_r = move |r: f64| (s_of_r((r + h).min(1.0)) - s_of_r((r - h).max(0.0))) / (((r + h).min(1.0)) - ((r - h).max(0.0)));
    ScaleTimeField {
        inner,
        t_of_r: Box::new(t_of_r),
        s_of_r: Box::new(s_of_r),
        dt_of_r: Box::new(dt_of_r),
        ds_of_r: Box::new(ds_of_r),
    }
}

// ---------------------------------------------------------------------------
// Analytic fields for tests/benches
// ---------------------------------------------------------------------------

/// Linear scalar-per-dim ODE ẋ = k(t) x + c(t), with closed-form solution
/// when k, c are constants: x(t) = (x0 + c/k) e^{kt} - c/k.
pub struct LinearField {
    pub dim: usize,
    pub k: f64,
    pub c: f64,
}

impl Field for LinearField {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval(&self, _t: f64, x: &[f32]) -> Result<Vec<f32>> {
        Ok(x.iter().map(|&v| (self.k * v as f64 + self.c) as f32).collect())
    }

    /// Closed form: ∂u/∂x = k (diagonal), ∂u/∂t = 0.
    fn jvp(&self, _t: f64, _x: &[f32], v: &[f32], _dt: f64) -> Result<Vec<f32>> {
        Ok(v.iter().map(|&vv| (self.k * vv as f64) as f32).collect())
    }
}

impl LinearField {
    /// Exact solution at t = 1 from x(0) = x0.
    pub fn exact_at_1(&self, x0: f32) -> f32 {
        let ck = self.c / self.k;
        ((x0 as f64 + ck) * self.k.exp() - ck) as f32
    }
}

/// Nonlinear smooth field for order-of-accuracy tests:
/// ẋ = sin(3t) x + 0.3 cos(x) (no closed form; reference via fine RK4).
pub struct NonlinearField {
    pub dim: usize,
}

impl Field for NonlinearField {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval(&self, t: f64, x: &[f32]) -> Result<Vec<f32>> {
        Ok(x.iter()
            .map(|&v| ((3.0 * t).sin() * v as f64 + 0.3 * (v as f64).cos()) as f32)
            .collect())
    }

    /// Closed form: ∂u/∂x = sin(3t) − 0.3 sin(x), ∂u/∂t = 3 cos(3t)·x.
    fn jvp(&self, t: f64, x: &[f32], v: &[f32], dt: f64) -> Result<Vec<f32>> {
        let (s3t, c3t) = (3.0 * t).sin_cos();
        Ok(x.iter()
            .zip(v.iter())
            .map(|(&xv, &vv)| {
                ((s3t - 0.3 * (xv as f64).sin()) * vv as f64 + 3.0 * c3t * xv as f64 * dt) as f32
            })
            .collect())
    }
}

/// The exact velocity field of a Gaussian-mixture data distribution under
/// a Gaussian path — the strongest test field: solvers integrate it and
/// the induced x(1) distribution is known. For a single Gaussian
/// N(mu, s1^2) target under scheduler (alpha, sigma):
///   p_t = N(alpha mu, (alpha s1)^2 + sigma^2), and
///   u_t(x) follows from the conditional-expectation formula.
pub struct GaussianTargetField {
    pub dim: usize,
    pub sched: Scheduler,
    pub mu: f32,
    pub s1: f64,
}

impl Field for GaussianTargetField {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval(&self, t: f64, x: &[f32]) -> Result<Vec<f32>> {
        let (a, s) = (self.sched.alpha(t), self.sched.sigma(t));
        let (da, ds) = (self.sched.dalpha(t), self.sched.dsigma(t));
        let var = (a * self.s1).powi(2) + s * s;
        // E[x1 | x_t] for scalar gaussian target
        // = (mu sigma^2 + alpha s1^2 (x)) / var … per dimension:
        Ok(x.iter()
            .map(|&xv| {
                let e_x1 = (self.mu as f64 * s * s + a * self.s1 * self.s1 * xv as f64) / var;
                let e_x0 = (xv as f64 - a * e_x1) / s.max(1e-9);
                (da * e_x1 + ds * e_x0) as f32
            })
            .collect())
    }

    /// The field is affine in x: u_t(x) = A(t)·x + B(t). The spatial part
    /// of the JVP is the closed form A(t)·v; the time part needs second
    /// derivatives of the scheduler (unavailable), so it falls back to a
    /// central difference of `eval` at fixed x — still exact in x.
    fn jvp(&self, t: f64, x: &[f32], v: &[f32], dt: f64) -> Result<Vec<f32>> {
        let (a, s) = (self.sched.alpha(t), self.sched.sigma(t));
        let (da, ds) = (self.sched.dalpha(t), self.sched.dsigma(t));
        let var = (a * self.s1).powi(2) + s * s;
        let de1 = a * self.s1 * self.s1 / var; // dE[x1|x]/dx
        let coef = da * de1 + ds * (1.0 - a * de1) / s.max(1e-9); // A(t)
        let mut out: Vec<f32> = v.iter().map(|&vv| (coef * vv as f64) as f32).collect();
        if dt != 0.0 {
            let h = 1e-4;
            let up = self.eval(t + h, x)?;
            let um = self.eval(t - h, x)?;
            for ((o, &p), &m) in out.iter_mut().zip(up.iter()).zip(um.iter()) {
                *o += (((p as f64 - m as f64) / (2.0 * h)) * dt) as f32;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::scheduler::Scheduler;

    #[test]
    fn counting_field_counts() {
        let f = LinearField { dim: 2, k: -1.0, c: 0.5 };
        let cf = CountingField::new(&f);
        let x = vec![1.0f32, 2.0];
        for _ in 0..5 {
            cf.eval(0.3, &x).unwrap();
        }
        assert_eq!(cf.count(), 5);
    }

    #[test]
    fn eval_into_matches_eval_and_counts() {
        let f = NonlinearField { dim: 2 };
        let cf = CountingField::new(&f);
        let x = vec![0.3f32, -0.7, 1.1, 0.0];
        let a = cf.eval(0.4, &x).unwrap();
        let mut b = vec![0f32; x.len()];
        cf.eval_into(0.4, &x, &mut b).unwrap();
        assert_eq!(a, b);
        assert_eq!(cf.count(), 2);
    }

    /// Strips a field's `jvp` override so the trait's central-difference
    /// default applies — lets tests pin closed forms against it.
    struct FdOnly<'a>(&'a dyn Field);

    impl Field for FdOnly<'_> {
        fn dim(&self) -> usize {
            self.0.dim()
        }

        fn eval(&self, t: f64, x: &[f32]) -> Result<Vec<f32>> {
            self.0.eval(t, x)
        }
    }

    #[test]
    fn closed_form_jvp_matches_finite_differences() {
        let lin = LinearField { dim: 3, k: -0.7, c: 0.2 };
        let nonlin = NonlinearField { dim: 3 };
        let gauss = GaussianTargetField { dim: 3, sched: Scheduler::FmOt, mu: 0.3, s1: 0.5 };
        let fields: [&dyn Field; 3] = [&lin, &nonlin, &gauss];
        let x = vec![0.4f32, -1.1, 0.9];
        let v = vec![1.3f32, -0.5, 2.0];
        for f in fields {
            for dt in [0.0, 1.0, -0.5] {
                let a = f.jvp(0.35, &x, &v, dt).unwrap();
                let b = FdOnly(f).jvp(0.35, &x, &v, dt).unwrap();
                for (u, w) in a.iter().zip(b.iter()) {
                    assert!((u - w).abs() < 2e-2 * (1.0 + w.abs()), "{u} vs {w} (dt={dt})");
                }
            }
        }
    }

    #[test]
    fn jvp_zero_tangent_is_zero() {
        let f = NonlinearField { dim: 2 };
        let x = vec![0.5f32, -0.5];
        let z = vec![0.0f32, 0.0];
        // default impl short-circuits; closed form multiplies through
        assert_eq!(FdOnly(&f).jvp(0.4, &x, &z, 0.0).unwrap(), z);
        assert_eq!(f.jvp(0.4, &x, &z, 0.0).unwrap(), z);
    }

    #[test]
    fn counting_field_counts_jvp_as_two_evals() {
        let f = LinearField { dim: 2, k: -1.0, c: 0.0 };
        let cf = CountingField::new(&f);
        let x = vec![1.0f32, 2.0];
        let v = vec![0.5f32, -0.5];
        cf.jvp(0.3, &x, &v, 0.0).unwrap();
        assert_eq!(cf.count(), 2);
    }

    #[test]
    fn precondition_identity_at_sigma0_one() {
        let f = NonlinearField { dim: 3 };
        let pf = precondition_field(&f, Scheduler::FmOt, 1.0);
        let x = vec![0.5f32, -1.0, 2.0];
        let a = f.eval(0.4, &x).unwrap();
        let b = pf.eval(0.4, &x).unwrap();
        for (u, v) in a.iter().zip(b.iter()) {
            assert!((u - v).abs() < 1e-4, "{u} vs {v}");
        }
    }

    #[test]
    fn precondition_endpoints_regular() {
        for sched in [Scheduler::FmOt, Scheduler::Cosine, Scheduler::Vp] {
            let f = NonlinearField { dim: 1 };
            let pf = precondition_field(&f, sched, 5.0);
            for r in [0.0, 0.5, 1.0] {
                let s = (pf.s_of_r)(r);
                let t = (pf.t_of_r)(r);
                assert!(s.is_finite() && s > 0.0, "{:?} s({r}) = {s}", sched);
                assert!((0.0..=1.0).contains(&t), "{:?} t({r}) = {t}", sched);
            }
            assert!(((pf.s_of_r)(0.0) - 5.0).abs() < 1e-6, "{:?}", sched);
            assert!(((pf.s_of_r)(1.0) - 1.0).abs() < 2e-3, "{:?}", sched);
        }
    }
}
