//! The solver library: every family in the paper's Figure 3 taxonomy.
//!
//! * `scheduler`   — Gaussian-path schedulers (mirror of the L2 python)
//! * `field`       — the batched velocity-field abstraction + ST wrappers
//! * `generic`     — stationary solvers: Euler / Midpoint / Heun / RK4 / AB2
//! * `exponential` — dedicated solvers: DDIM, DPM-Solver++ (1S/2M)
//! * `rk45`        — adaptive ground-truth solver
//! * `ns`          — Non-Stationary solvers (Algorithm 1) + JSON artifacts
//! * `taxonomy`    — constructive Thm 3.2: any family -> NS coefficients

pub mod exponential;
pub mod field;
pub mod generic;
pub mod ns;
pub mod rk45;
pub mod scheduler;
pub mod taxonomy;

use anyhow::Result;

use field::Field;

/// A fixed-NFE sampling solver.
pub trait Solver: Send + Sync {
    fn name(&self) -> String;

    /// Number of velocity-field evaluations one `sample` performs.
    fn nfe(&self) -> usize;

    /// Drive `x0` (row-major [batch, dim]) to an approximation of x(1).
    fn sample(&self, field: &dyn Field, x0: &[f32]) -> Result<Vec<f32>>;
}

impl Solver for ns::NsSolver {
    fn name(&self) -> String {
        format!("ns{}", self.nfe())
    }

    fn nfe(&self) -> usize {
        self.a.len()
    }

    fn sample(&self, field: &dyn Field, x0: &[f32]) -> Result<Vec<f32>> {
        NsSolver::sample(self, field, x0)
    }
}

pub use ns::NsSolver;

/// Construct a named baseline solver at a given NFE — the registry the
/// CLI, server and benches share. `sched` is the model's scheduler
/// (needed by the dedicated solvers).
pub fn baseline(
    name: &str,
    nfe: usize,
    sched: scheduler::Scheduler,
) -> Result<Box<dyn Solver>> {
    Ok(match name {
        "euler" => Box::new(generic::Euler::new(nfe)),
        "midpoint" => Box::new(generic::Midpoint::new(nfe)),
        "heun" => Box::new(generic::Heun::new(nfe)),
        "rk4" => Box::new(generic::Rk4::new(nfe)),
        "ab2" => Box::new(generic::Ab2::new(nfe)),
        "ddim" => Box::new(exponential::Ddim::new(sched, nfe)),
        "dpmpp1" => Box::new(exponential::DpmPp::new(sched, nfe, 1)),
        "dpmpp" | "dpmpp2m" => Box::new(exponential::DpmPp::new(sched, nfe, 2)),
        // Euler on EDM's rho-grid (the EDM discretization of §3.3.2)
        "euler_edm" => Box::new(generic::Euler {
            times: exponential::edm_times(nfe, sched, 7.0),
        }),
        // NS-form equivalents (exercise Algorithm 1 on the same math)
        "euler_ns" => Box::new(taxonomy::euler_ns(&generic::uniform_times(nfe))),
        "midpoint_ns" => Box::new(taxonomy::midpoint_ns(nfe)),
        other => anyhow::bail!("unknown baseline solver '{other}'"),
    })
}

/// All baseline names `baseline` accepts (for CLI help / sweeps).
pub const BASELINES: &[&str] = &[
    "euler", "midpoint", "heun", "rk4", "ab2", "ddim", "dpmpp1", "dpmpp2m",
];
