//! The solver library: every family in the paper's Figure 3 taxonomy.
//!
//! * `scheduler`   — Gaussian-path schedulers (mirror of the L2 python)
//! * `field`       — the batched velocity-field abstraction + ST wrappers
//! * `generic`     — stationary solvers: Euler / Midpoint / Heun / RK4 / AB2
//! * `exponential` — dedicated solvers: DDIM, DPM-Solver++ (1S/2M)
//! * `rk45`        — adaptive ground-truth solver
//! * `ns`          — Non-Stationary solvers (Algorithm 1) + JSON artifacts
//! * `taxonomy`    — constructive Thm 3.2: any family -> NS coefficients
//! * `workspace`   — preallocated scratch for the serving hot path
//!
//! # Workspace & buffer reuse (the serving hot path)
//!
//! The paper's efficiency claim is per-NFE: a distilled solver wins only
//! if each of its few steps is a tight fused op. The seed implementation
//! allocated on every step — `NsSolver::sample` grew a `Vec<Vec<f32>>`
//! history, the RK steppers collected fresh intermediate-state vectors,
//! and every `Field::eval` returned a new output buffer. Under serving
//! load that is an allocator round-trip per step per worker.
//!
//! The buffer-reusing design has three layers:
//!
//! 1. [`SampleWorkspace`] owns every per-step buffer: the current state,
//!    five f32 stage registers (enough for RK4's `k1..k4` plus a stage
//!    input), a flat `[nfe, batch * dim]` history arena for the NS
//!    combine, and the f64 state/stage arenas RK45 needs. A worker
//!    thread creates one workspace and reuses it for every batch; the
//!    `ensure_*` sizing calls run once per sampling run and are no-ops
//!    at steady state.
//! 2. [`Solver::sample_into`] is the allocation-free entry point:
//!    `sample_into(field, x0, &mut ws)` leaves the result in the
//!    workspace and returns a borrow of it. `NsSolver` and the five
//!    generic steppers implement it with zero per-step allocation and
//!    **bit-identical** arithmetic to their allocating `sample` (the
//!    per-element operation order is unchanged; equivalence is enforced
//!    by `tests/sample_into_equiv.rs`). Solvers without a dedicated
//!    implementation (the exponential integrators) fall back to
//!    `sample` transparently. `rk45_into` is the adaptive analogue.
//! 3. [`field::Field::eval_into`] writes the velocity directly into a
//!    caller buffer (a history-arena row, a stage register), so the
//!    PJRT-backed `ModelField` can skip the padded-bucket staging copy
//!    when a batch lines up with a compiled bucket.
//!
//! Scope of the claim: with the pooled device-lane runtime
//! (`runtime/client.rs`, DESIGN.md §5) the whole eval path is
//! allocation-free at steady state — the solver-side combine reuses the
//! workspace, and a bucket-aligned `ModelField::eval_into` rides pooled
//! request/response buffers through the lane RPC while the backend
//! writes velocities in place (`Backend::exec_into`). `perf_layers`
//! measures allocations per eval with a counting global allocator.
//!
//! `sample` remains the simple allocating reference path — benches
//! (`perf_layers`) time the two against each other, and the equivalence
//! tests pin them together.

pub mod exponential;
pub mod field;
pub mod generic;
pub mod ns;
pub mod rk45;
pub mod scheduler;
pub mod taxonomy;
pub mod workspace;

use anyhow::Result;

use field::Field;

pub use workspace::SampleWorkspace;

/// A fixed-NFE sampling solver.
pub trait Solver: Send + Sync {
    fn name(&self) -> String;

    /// Number of velocity-field evaluations one `sample` performs.
    fn nfe(&self) -> usize;

    /// Drive `x0` (row-major [batch, dim]) to an approximation of x(1).
    fn sample(&self, field: &dyn Field, x0: &[f32]) -> Result<Vec<f32>>;

    /// Buffer-reusing variant of `sample`: all scratch lives in `ws`, the
    /// result is left in the workspace and returned as a borrow. Must be
    /// bit-identical to `sample`. The default falls back to `sample` for
    /// solvers without a dedicated allocation-free implementation.
    fn sample_into<'w>(
        &self,
        field: &dyn Field,
        x0: &[f32],
        ws: &'w mut SampleWorkspace,
    ) -> Result<&'w [f32]> {
        let out = self.sample(field, x0)?;
        Ok(ws.store_result(out))
    }
}

impl Solver for ns::NsSolver {
    fn name(&self) -> String {
        format!("ns{}", self.nfe())
    }

    fn nfe(&self) -> usize {
        self.a.len()
    }

    fn sample(&self, field: &dyn Field, x0: &[f32]) -> Result<Vec<f32>> {
        NsSolver::sample(self, field, x0)
    }

    fn sample_into<'w>(
        &self,
        field: &dyn Field,
        x0: &[f32],
        ws: &'w mut SampleWorkspace,
    ) -> Result<&'w [f32]> {
        NsSolver::sample_into(self, field, x0, ws)
    }
}

pub use ns::NsSolver;

/// Construct a named baseline solver at a given NFE — the registry the
/// CLI, server and benches share. `sched` is the model's scheduler
/// (needed by the dedicated solvers).
pub fn baseline(
    name: &str,
    nfe: usize,
    sched: scheduler::Scheduler,
) -> Result<Box<dyn Solver>> {
    Ok(match name {
        "euler" => Box::new(generic::Euler::new(nfe)),
        "midpoint" => Box::new(generic::Midpoint::new(nfe)),
        "heun" => Box::new(generic::Heun::new(nfe)),
        "rk4" => Box::new(generic::Rk4::new(nfe)),
        "ab2" => Box::new(generic::Ab2::new(nfe)),
        "ddim" => Box::new(exponential::Ddim::new(sched, nfe)),
        "dpmpp1" => Box::new(exponential::DpmPp::new(sched, nfe, 1)),
        "dpmpp" | "dpmpp2m" => Box::new(exponential::DpmPp::new(sched, nfe, 2)),
        // Euler on EDM's rho-grid (the EDM discretization of §3.3.2)
        "euler_edm" => Box::new(generic::Euler {
            times: exponential::edm_times(nfe, sched, 7.0),
        }),
        // NS-form equivalents (exercise Algorithm 1 on the same math)
        "euler_ns" => Box::new(taxonomy::euler_ns(&generic::uniform_times(nfe))),
        "midpoint_ns" => Box::new(taxonomy::midpoint_ns(nfe)),
        other => anyhow::bail!("unknown baseline solver '{other}'"),
    })
}

/// All baseline names `baseline` accepts (for CLI help / sweeps).
pub const BASELINES: &[&str] = &[
    "euler", "midpoint", "heun", "rk4", "ab2", "ddim", "dpmpp1", "dpmpp2m",
];
