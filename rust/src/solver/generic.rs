//! Generic (stationary) ODE solvers of §3.3.1: Runge-Kutta family and
//! Adams-Bashforth multistep, implemented as *direct* steppers.
//!
//! These serve three roles: (i) baselines in every benchmark, (ii) the
//! cross-check targets for `taxonomy` (direct stepping must equal the
//! NS-coefficient form bit-for-bit in exact arithmetic), and (iii) BNS
//! initialization references.

use anyhow::Result;

use super::field::Field;
use super::workspace::SampleWorkspace;
use super::Solver;

/// Time grids.
pub fn uniform_times(n: usize) -> Vec<f64> {
    (0..=n).map(|i| i as f64 / n as f64).collect()
}

/// Euler (RK1): x_{i+1} = x_i + h_i u(t_i, x_i). NFE = steps.
pub struct Euler {
    pub times: Vec<f64>,
}

impl Euler {
    pub fn new(nfe: usize) -> Self {
        Euler { times: uniform_times(nfe) }
    }
}

impl Solver for Euler {
    fn name(&self) -> String {
        format!("euler{}", self.times.len() - 1)
    }

    fn nfe(&self) -> usize {
        self.times.len() - 1
    }

    fn sample(&self, field: &dyn Field, x0: &[f32]) -> Result<Vec<f32>> {
        let mut x = x0.to_vec();
        for w in self.times.windows(2) {
            let h = (w[1] - w[0]) as f32;
            let u = field.eval(w[0], &x)?;
            for (xv, uv) in x.iter_mut().zip(u.iter()) {
                *xv += h * uv;
            }
        }
        Ok(x)
    }

    fn sample_into<'w>(
        &self,
        field: &dyn Field,
        x0: &[f32],
        ws: &'w mut SampleWorkspace,
    ) -> Result<&'w [f32]> {
        ws.ensure_stages(x0.len(), 1);
        {
            let x = &mut ws.x;
            let [u, ..] = &mut ws.stage;
            x.copy_from_slice(x0);
            for w in self.times.windows(2) {
                let h = (w[1] - w[0]) as f32;
                field.eval_into(w[0], x, u)?;
                for (xv, uv) in x.iter_mut().zip(u.iter()) {
                    *xv += h * uv;
                }
            }
        }
        Ok(&ws.x)
    }
}

/// RK-Midpoint (RK2): NFE = 2 * macro steps.
pub struct Midpoint {
    pub macro_times: Vec<f64>,
}

impl Midpoint {
    /// `nfe` must be even.
    pub fn new(nfe: usize) -> Self {
        assert!(nfe % 2 == 0, "midpoint needs even NFE");
        Midpoint { macro_times: uniform_times(nfe / 2) }
    }
}

impl Solver for Midpoint {
    fn name(&self) -> String {
        format!("midpoint{}", (self.macro_times.len() - 1) * 2)
    }

    fn nfe(&self) -> usize {
        (self.macro_times.len() - 1) * 2
    }

    fn sample(&self, field: &dyn Field, x0: &[f32]) -> Result<Vec<f32>> {
        let mut x = x0.to_vec();
        for w in self.macro_times.windows(2) {
            let h = w[1] - w[0];
            let u1 = field.eval(w[0], &x)?;
            let xi: Vec<f32> = x
                .iter()
                .zip(u1.iter())
                .map(|(&xv, &uv)| xv + (0.5 * h) as f32 * uv)
                .collect();
            let u2 = field.eval(w[0] + 0.5 * h, &xi)?;
            for (xv, uv) in x.iter_mut().zip(u2.iter()) {
                *xv += h as f32 * uv;
            }
        }
        Ok(x)
    }

    fn sample_into<'w>(
        &self,
        field: &dyn Field,
        x0: &[f32],
        ws: &'w mut SampleWorkspace,
    ) -> Result<&'w [f32]> {
        ws.ensure_stages(x0.len(), 3);
        {
            let x = &mut ws.x;
            let [u1, xi, u2, ..] = &mut ws.stage;
            x.copy_from_slice(x0);
            for w in self.macro_times.windows(2) {
                let h = w[1] - w[0];
                field.eval_into(w[0], x, u1)?;
                for ((o, &xv), &uv) in xi.iter_mut().zip(x.iter()).zip(u1.iter()) {
                    *o = xv + (0.5 * h) as f32 * uv;
                }
                field.eval_into(w[0] + 0.5 * h, xi, u2)?;
                for (xv, uv) in x.iter_mut().zip(u2.iter()) {
                    *xv += h as f32 * uv;
                }
            }
        }
        Ok(&ws.x)
    }
}

/// Heun (explicit trapezoid, RK2): NFE = 2 * macro steps.
pub struct Heun {
    pub macro_times: Vec<f64>,
}

impl Heun {
    pub fn new(nfe: usize) -> Self {
        assert!(nfe % 2 == 0, "heun needs even NFE");
        Heun { macro_times: uniform_times(nfe / 2) }
    }
}

impl Solver for Heun {
    fn name(&self) -> String {
        format!("heun{}", (self.macro_times.len() - 1) * 2)
    }

    fn nfe(&self) -> usize {
        (self.macro_times.len() - 1) * 2
    }

    fn sample(&self, field: &dyn Field, x0: &[f32]) -> Result<Vec<f32>> {
        let mut x = x0.to_vec();
        for w in self.macro_times.windows(2) {
            let h = w[1] - w[0];
            let u1 = field.eval(w[0], &x)?;
            let xe: Vec<f32> = x
                .iter()
                .zip(u1.iter())
                .map(|(&xv, &uv)| xv + h as f32 * uv)
                .collect();
            let u2 = field.eval(w[1].min(1.0 - 1e-9), &xe)?;
            for ((xv, &a), &b) in x.iter_mut().zip(u1.iter()).zip(u2.iter()) {
                *xv += (0.5 * h) as f32 * (a + b);
            }
        }
        Ok(x)
    }

    fn sample_into<'w>(
        &self,
        field: &dyn Field,
        x0: &[f32],
        ws: &'w mut SampleWorkspace,
    ) -> Result<&'w [f32]> {
        ws.ensure_stages(x0.len(), 3);
        {
            let x = &mut ws.x;
            let [u1, xe, u2, ..] = &mut ws.stage;
            x.copy_from_slice(x0);
            for w in self.macro_times.windows(2) {
                let h = w[1] - w[0];
                field.eval_into(w[0], x, u1)?;
                for ((o, &xv), &uv) in xe.iter_mut().zip(x.iter()).zip(u1.iter()) {
                    *o = xv + h as f32 * uv;
                }
                field.eval_into(w[1].min(1.0 - 1e-9), xe, u2)?;
                for ((xv, &a), &b) in x.iter_mut().zip(u1.iter()).zip(u2.iter()) {
                    *xv += (0.5 * h) as f32 * (a + b);
                }
            }
        }
        Ok(&ws.x)
    }
}

/// Classic RK4: NFE = 4 * macro steps.
pub struct Rk4 {
    pub macro_times: Vec<f64>,
}

impl Rk4 {
    pub fn new(nfe: usize) -> Self {
        assert!(nfe % 4 == 0, "rk4 needs NFE divisible by 4");
        Rk4 { macro_times: uniform_times(nfe / 4) }
    }
}

impl Solver for Rk4 {
    fn name(&self) -> String {
        format!("rk4_{}", (self.macro_times.len() - 1) * 4)
    }

    fn nfe(&self) -> usize {
        (self.macro_times.len() - 1) * 4
    }

    fn sample(&self, field: &dyn Field, x0: &[f32]) -> Result<Vec<f32>> {
        let mut x = x0.to_vec();
        let axpy = |x: &[f32], k: &[f32], c: f64| -> Vec<f32> {
            x.iter().zip(k.iter()).map(|(&a, &b)| a + c as f32 * b).collect()
        };
        for w in self.macro_times.windows(2) {
            let h = w[1] - w[0];
            let k1 = field.eval(w[0], &x)?;
            let k2 = field.eval(w[0] + 0.5 * h, &axpy(&x, &k1, 0.5 * h))?;
            let k3 = field.eval(w[0] + 0.5 * h, &axpy(&x, &k2, 0.5 * h))?;
            let k4 = field.eval((w[0] + h).min(1.0 - 1e-9), &axpy(&x, &k3, h))?;
            for i in 0..x.len() {
                x[i] += (h / 6.0) as f32
                    * (k1[i] + 2.0 * k2[i] + 2.0 * k3[i] + k4[i]);
            }
        }
        Ok(x)
    }

    fn sample_into<'w>(
        &self,
        field: &dyn Field,
        x0: &[f32],
        ws: &'w mut SampleWorkspace,
    ) -> Result<&'w [f32]> {
        ws.ensure_stages(x0.len(), 5);
        {
            let x = &mut ws.x;
            let [k1, k2, k3, k4, xi] = &mut ws.stage;
            x.copy_from_slice(x0);
            let axpy_into = |out: &mut [f32], x: &[f32], k: &[f32], c: f64| {
                for ((o, &a), &b) in out.iter_mut().zip(x.iter()).zip(k.iter()) {
                    *o = a + c as f32 * b;
                }
            };
            for w in self.macro_times.windows(2) {
                let h = w[1] - w[0];
                field.eval_into(w[0], x, k1)?;
                axpy_into(xi, x, k1, 0.5 * h);
                field.eval_into(w[0] + 0.5 * h, xi, k2)?;
                axpy_into(xi, x, k2, 0.5 * h);
                field.eval_into(w[0] + 0.5 * h, xi, k3)?;
                axpy_into(xi, x, k3, h);
                field.eval_into((w[0] + h).min(1.0 - 1e-9), xi, k4)?;
                for i in 0..x.len() {
                    x[i] += (h / 6.0) as f32
                        * (k1[i] + 2.0 * k2[i] + 2.0 * k3[i] + k4[i]);
                }
            }
        }
        Ok(&ws.x)
    }
}

/// 2-step Adams-Bashforth with Euler bootstrap (variable step form).
pub struct Ab2 {
    pub times: Vec<f64>,
}

impl Ab2 {
    pub fn new(nfe: usize) -> Self {
        Ab2 { times: uniform_times(nfe) }
    }
}

impl Solver for Ab2 {
    fn name(&self) -> String {
        format!("ab2_{}", self.times.len() - 1)
    }

    fn nfe(&self) -> usize {
        self.times.len() - 1
    }

    fn sample(&self, field: &dyn Field, x0: &[f32]) -> Result<Vec<f32>> {
        let mut x = x0.to_vec();
        let mut prev_u: Option<Vec<f32>> = None;
        for i in 0..self.times.len() - 1 {
            let h = self.times[i + 1] - self.times[i];
            let u = field.eval(self.times[i], &x)?;
            match &prev_u {
                None => {
                    for (xv, uv) in x.iter_mut().zip(u.iter()) {
                        *xv += h as f32 * uv;
                    }
                }
                Some(pu) => {
                    let hp = self.times[i] - self.times[i - 1];
                    let w1 = h * (1.0 + h / (2.0 * hp));
                    let w0 = -h * h / (2.0 * hp);
                    for ((xv, &a), &b) in x.iter_mut().zip(u.iter()).zip(pu.iter()) {
                        *xv += (w1 as f32) * a + (w0 as f32) * b;
                    }
                }
            }
            prev_u = Some(u);
        }
        Ok(x)
    }

    fn sample_into<'w>(
        &self,
        field: &dyn Field,
        x0: &[f32],
        ws: &'w mut SampleWorkspace,
    ) -> Result<&'w [f32]> {
        ws.ensure_stages(x0.len(), 2);
        {
            let x = &mut ws.x;
            let [ua, ub, ..] = &mut ws.stage;
            x.copy_from_slice(x0);
            // u and prev_u alternate between the two stage registers.
            let mut bufs = [ua, ub];
            let mut have_prev = false;
            for i in 0..self.times.len() - 1 {
                let h = self.times[i + 1] - self.times[i];
                let (cur, prev) = bufs.split_at_mut(1);
                field.eval_into(self.times[i], x, &mut *cur[0])?;
                if !have_prev {
                    for (xv, uv) in x.iter_mut().zip(cur[0].iter()) {
                        *xv += h as f32 * uv;
                    }
                    have_prev = true;
                } else {
                    let hp = self.times[i] - self.times[i - 1];
                    let w1 = h * (1.0 + h / (2.0 * hp));
                    let w0 = -h * h / (2.0 * hp);
                    for ((xv, &a), &b) in x.iter_mut().zip(cur[0].iter()).zip(prev[0].iter()) {
                        *xv += (w1 as f32) * a + (w0 as f32) * b;
                    }
                }
                bufs.swap(0, 1);
            }
        }
        Ok(&ws.x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::field::{LinearField, NonlinearField};

    /// Empirical order of accuracy: error ratio when halving h should be
    /// ~2^order.
    fn order_of(solver_at: impl Fn(usize) -> Box<dyn Solver>, base_nfe: usize) -> f64 {
        let f = NonlinearField { dim: 1 };
        let x0 = vec![0.8f32];
        // dense reference
        let reference = Rk4::new(512).sample(&f, &x0).unwrap()[0] as f64;
        let e1 = (solver_at(base_nfe).sample(&f, &x0).unwrap()[0] as f64 - reference).abs();
        let e2 = (solver_at(base_nfe * 2).sample(&f, &x0).unwrap()[0] as f64 - reference).abs();
        (e1 / e2).log2()
    }

    #[test]
    fn euler_is_first_order() {
        let p = order_of(|n| Box::new(Euler::new(n)), 16);
        assert!((0.7..1.4).contains(&p), "order {p}");
    }

    #[test]
    fn midpoint_is_second_order() {
        let p = order_of(|n| Box::new(Midpoint::new(n)), 16);
        assert!((1.6..2.6).contains(&p), "order {p}");
    }

    #[test]
    fn heun_is_second_order() {
        let p = order_of(|n| Box::new(Heun::new(n)), 16);
        assert!((1.6..2.6).contains(&p), "order {p}");
    }

    #[test]
    fn ab2_is_second_order() {
        let p = order_of(|n| Box::new(Ab2::new(n)), 16);
        assert!((1.5..2.8).contains(&p), "order {p}");
    }

    #[test]
    fn rk4_solves_linear_exactly_enough() {
        let f = LinearField { dim: 2, k: -1.3, c: 0.7 };
        let x0 = vec![1.0f32, -2.0];
        let out = Rk4::new(32).sample(&f, &x0).unwrap();
        for (o, &x) in out.iter().zip(x0.iter()) {
            assert!((o - f.exact_at_1(x)).abs() < 1e-5, "{o} vs {}", f.exact_at_1(x));
        }
    }

    #[test]
    fn accuracy_hierarchy_on_nonlinear() {
        // at equal NFE = 16: rk4 < midpoint < euler error (generic order)
        let f = NonlinearField { dim: 1 };
        let x0 = vec![0.8f32];
        let reference = Rk4::new(512).sample(&f, &x0).unwrap()[0] as f64;
        let err = |s: &dyn Solver| (s.sample(&f, &x0).unwrap()[0] as f64 - reference).abs();
        let (ee, em, er) = (
            err(&Euler::new(16)),
            err(&Midpoint::new(16)),
            err(&Rk4::new(16)),
        );
        assert!(ee > em && em > er, "euler {ee}, midpoint {em}, rk4 {er}");
    }
}
