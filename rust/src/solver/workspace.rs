//! Preallocated scratch for the allocation-free sampling hot path.
//!
//! A serving worker owns one `SampleWorkspace` for its whole lifetime and
//! passes it to every `Solver::sample_into` / `rk45_into` call. All
//! per-step buffers — the NS history arena, RK stage registers, the RK45
//! f64 state — live here, so in steady state (after the first batch of a
//! given size warms the buffers) a sampling run performs **zero heap
//! allocation per step**. See the module docs in `solver/mod.rs` for the
//! design rationale.
//!
//! Buffers only ever grow; `ensure_*` is called once per sampling run
//! (not per step) and is a no-op once capacity covers the batch size.

/// Reusable buffers for one lockstep sampling run over a row-major
/// `[batch, dim]` state of `len = batch * dim` f32 elements.
#[derive(Default)]
pub struct SampleWorkspace {
    /// Current state x_i; holds the final sample after `sample_into`.
    pub(crate) x: Vec<f32>,
    /// General-purpose stage registers (RK k-values, midpoint/Heun
    /// intermediate states, AB2 velocity history, RK45 f32 staging).
    pub(crate) stage: [Vec<f32>; 5],
    /// Flat `[nfe, len]` velocity-history arena for the NS combine
    /// (replaces the seed `Vec<Vec<f32>>` per-step allocations).
    pub(crate) hist: Vec<f32>,
    /// RK45 f64 state.
    pub(crate) x64: Vec<f64>,
    /// RK45 flat `[7, len]` f64 stage arena.
    pub(crate) k64: Vec<f64>,
    /// RK45 f64 scratch: stage input, 5th- and 4th-order candidates.
    pub(crate) s64: [Vec<f64>; 3],
}

/// Size `buf` to exactly `len` elements. A true no-op when the length is
/// unchanged (the steady-state case): every workspace buffer is fully
/// written before it is read (states via `copy_from_slice`, history rows
/// and stage registers via `eval_into`), so surviving contents from a
/// previous run are never observable and no zeroing pass is needed.
/// Shared with the training-side `distill::grad::GradWorkspace`, which
/// follows the same only-ever-grow, fully-written-before-read
/// discipline for its tangent arenas (DESIGN.md §8).
pub(crate) fn reset_f32(buf: &mut Vec<f32>, len: usize) {
    buf.resize(len, 0.0);
}

pub(crate) fn reset_f64(buf: &mut Vec<f64>, len: usize) {
    buf.resize(len, 0.0);
}

impl SampleWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// The result of the last `sample_into` run (row-major `[batch, dim]`).
    pub fn out(&self) -> &[f32] {
        &self.x
    }

    /// Adopt an externally produced result (the `sample` fallback path of
    /// solvers that have no dedicated buffer-reusing implementation).
    pub(crate) fn store_result(&mut self, out: Vec<f32>) -> &[f32] {
        self.x = out;
        &self.x
    }

    /// Size the state + first `stages` stage registers for `len` elements.
    pub(crate) fn ensure_stages(&mut self, len: usize, stages: usize) {
        reset_f32(&mut self.x, len);
        for s in self.stage.iter_mut().take(stages) {
            reset_f32(s, len);
        }
    }

    /// Size the state + the `[nfe, len]` history arena (NS sampling).
    pub(crate) fn ensure_hist(&mut self, nfe: usize, len: usize) {
        reset_f32(&mut self.x, len);
        reset_f32(&mut self.hist, nfe * len);
    }

    /// Size the f64 RK45 buffers plus two f32 staging registers used for
    /// the field's f32 interface.
    pub(crate) fn ensure_rk45(&mut self, len: usize) {
        reset_f32(&mut self.x, len);
        for s in self.stage.iter_mut().take(2) {
            reset_f32(s, len);
        }
        reset_f64(&mut self.x64, len);
        reset_f64(&mut self.k64, 7 * len);
        for s in self.s64.iter_mut() {
            reset_f64(s, len);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_track_requested_sizes() {
        let mut ws = SampleWorkspace::new();
        ws.ensure_hist(4, 8);
        assert_eq!(ws.x.len(), 8);
        assert_eq!(ws.hist.len(), 32);
        let cap = ws.hist.capacity();
        // shrinking the logical size keeps capacity (no realloc on the
        // next grow back) — contents are don't-care, every buffer is
        // fully written before being read
        ws.ensure_hist(2, 4);
        assert_eq!(ws.hist.len(), 8);
        assert_eq!(ws.hist.capacity(), cap);
        ws.ensure_hist(4, 8);
        assert_eq!(ws.hist.len(), 32);
        assert_eq!(ws.hist.capacity(), cap);
    }

    #[test]
    fn store_result_is_out() {
        let mut ws = SampleWorkspace::new();
        let r = ws.store_result(vec![1.0, 2.0]).to_vec();
        assert_eq!(r, ws.out());
    }
}
