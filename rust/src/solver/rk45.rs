//! Adaptive Dormand-Prince RK45 — the ground-truth solver (Shampine 1986
//! in the paper). Mirrors python/compile/ode.py: same tableau, same step
//! control, so GT samples agree across the build and request paths.

use anyhow::{bail, Result};

use super::field::Field;
use super::workspace::SampleWorkspace;

const C: [f64; 7] = [0.0, 1.0 / 5.0, 3.0 / 10.0, 4.0 / 5.0, 8.0 / 9.0, 1.0, 1.0];
const B5: [f64; 7] = [
    35.0 / 384.0,
    0.0,
    500.0 / 1113.0,
    125.0 / 192.0,
    -2187.0 / 6784.0,
    11.0 / 84.0,
    0.0,
];
const B4: [f64; 7] = [
    5179.0 / 57600.0,
    0.0,
    7571.0 / 16695.0,
    393.0 / 640.0,
    -92097.0 / 339200.0,
    187.0 / 2100.0,
    1.0 / 40.0,
];

fn a_row(i: usize) -> &'static [f64] {
    const A1: [f64; 1] = [1.0 / 5.0];
    const A2: [f64; 2] = [3.0 / 40.0, 9.0 / 40.0];
    const A3: [f64; 3] = [44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0];
    const A4: [f64; 4] = [19372.0 / 6561.0, -25360.0 / 2187.0, 64448.0 / 6561.0, -212.0 / 729.0];
    const A5: [f64; 5] = [
        9017.0 / 3168.0,
        -355.0 / 33.0,
        46732.0 / 5247.0,
        49.0 / 176.0,
        -5103.0 / 18656.0,
    ];
    const A6: [f64; 6] = [
        35.0 / 384.0,
        0.0,
        500.0 / 1113.0,
        125.0 / 192.0,
        -2187.0 / 6784.0,
        11.0 / 84.0,
    ];
    match i {
        1 => &A1,
        2 => &A2,
        3 => &A3,
        4 => &A4,
        5 => &A5,
        6 => &A6,
        _ => unreachable!(),
    }
}

#[derive(Debug, Clone)]
pub struct Rk45Opts {
    pub rtol: f64,
    pub atol: f64,
    pub h0: f64,
    pub max_nfe: usize,
}

impl Default for Rk45Opts {
    fn default() -> Self {
        Rk45Opts { rtol: 1e-5, atol: 1e-5, h0: 0.05, max_nfe: 10_000 }
    }
}

/// Integrate dx/dt = u(t, x) from 0 to 1 adaptively (batched, shared step
/// size with an RMS error norm over the whole batch — matches ode.py).
/// Returns (x1, nfe). Allocating convenience wrapper over [`rk45_into`].
pub fn rk45(field: &dyn Field, x0: &[f32], opts: &Rk45Opts) -> Result<(Vec<f32>, usize)> {
    let mut ws = SampleWorkspace::new();
    let (out, nfe) = rk45_into(field, x0, opts, &mut ws)?;
    Ok((out.to_vec(), nfe))
}

/// Buffer-reusing RK45: every f64 stage / candidate buffer and the f32
/// field-interface staging buffers live in `ws`, so the adaptive loop is
/// allocation-free in steady state. Arithmetic order matches the seed
/// allocating implementation exactly.
pub fn rk45_into<'w>(
    field: &dyn Field,
    x0: &[f32],
    opts: &Rk45Opts,
    ws: &'w mut SampleWorkspace,
) -> Result<(&'w [f32], usize)> {
    let n = x0.len();
    ws.ensure_rk45(n);
    let mut nfe = 0usize;
    {
        let x = &mut ws.x64;
        let k = &mut ws.k64; // flat [7, n] stage arena
        let [xi, x5, x4] = &mut ws.s64;
        let [xf, uf, ..] = &mut ws.stage;
        for (d, &v) in x.iter_mut().zip(x0.iter()) {
            *d = v as f64;
        }
        let mut t = 0.0f64;
        let mut h = opts.h0;

        // f64 state -> the field's f32 interface -> f64, via reused staging
        fn eval_into(
            field: &dyn Field,
            t: f64,
            xin: &[f64],
            out: &mut [f64],
            xf: &mut [f32],
            uf: &mut [f32],
        ) -> Result<()> {
            for (s, &v) in xf.iter_mut().zip(xin.iter()) {
                *s = v as f32;
            }
            field.eval_into(t.min(1.0 - 1e-9), xf, uf)?;
            for (o, &v) in out.iter_mut().zip(uf.iter()) {
                *o = v as f64;
            }
            Ok(())
        }

        {
            let (k1, _) = k.split_at_mut(n);
            eval_into(field, t, x, k1, xf, uf)?;
        }
        nfe += 1;
        while t < 1.0 - 1e-12 {
            h = h.min(1.0 - t);
            for i in 1..7 {
                xi.copy_from_slice(x);
                for (j, &a) in a_row(i).iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let kj = &k[j * n..(j + 1) * n];
                    for (d, &kv) in xi.iter_mut().zip(kj.iter()) {
                        *d += h * a * kv;
                    }
                }
                let (_, ki) = k.split_at_mut(i * n);
                eval_into(field, t + C[i] * h, xi, &mut ki[..n], xf, uf)?;
                nfe += 1;
            }
            x5.copy_from_slice(x);
            x4.copy_from_slice(x);
            for j in 0..7 {
                let kj = &k[j * n..(j + 1) * n];
                for i in 0..n {
                    x5[i] += h * B5[j] * kj[i];
                    x4[i] += h * B4[j] * kj[i];
                }
            }
            let mut err2 = 0.0;
            for i in 0..n {
                let scale = opts.atol + opts.rtol * x[i].abs().max(x5[i].abs());
                let e = (x5[i] - x4[i]) / scale;
                err2 += e * e;
            }
            let err = (err2 / n as f64).sqrt();
            if err <= 1.0 {
                t += h;
                x.copy_from_slice(x5);
                k.copy_within(6 * n..7 * n, 0); // FSAL: k1 <- k7
            }
            let factor = 0.9 * err.max(1e-10).powf(-0.2);
            h *= factor.clamp(0.2, 5.0);
            if nfe > opts.max_nfe {
                bail!("rk45 exceeded max_nfe = {} (err = {:.3e})", opts.max_nfe, err);
            }
        }
    }
    for (o, &v) in ws.x.iter_mut().zip(ws.x64.iter()) {
        *o = v as f32;
    }
    Ok((&ws.x, nfe))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::field::{GaussianTargetField, LinearField, NonlinearField};
    use crate::solver::generic::Rk4;
    use crate::solver::scheduler::Scheduler;
    use crate::solver::Solver;

    #[test]
    fn linear_exact() {
        let f = LinearField { dim: 3, k: -1.1, c: 0.6 };
        let x0 = vec![1.0f32, 0.0, -2.0];
        let (out, nfe) = rk45(&f, &x0, &Rk45Opts::default()).unwrap();
        for (o, &x) in out.iter().zip(x0.iter()) {
            assert!((o - f.exact_at_1(x)).abs() < 1e-4, "{o} vs {}", f.exact_at_1(x));
        }
        assert!(nfe < 200, "nfe {nfe}");
    }

    #[test]
    fn tighter_tolerance_more_steps() {
        let f = NonlinearField { dim: 4 };
        let x0 = vec![0.5f32, -0.5, 1.0, 2.0];
        let (_, n1) = rk45(&f, &x0, &Rk45Opts { rtol: 1e-3, atol: 1e-3, ..Default::default() }).unwrap();
        let (_, n2) = rk45(&f, &x0, &Rk45Opts { rtol: 1e-8, atol: 1e-8, ..Default::default() }).unwrap();
        assert!(n2 > n1, "{n2} !> {n1}");
    }

    #[test]
    fn matches_dense_rk4() {
        let f = GaussianTargetField { dim: 2, sched: Scheduler::FmOt, mu: 0.3, s1: 0.4 };
        let x0 = vec![0.9f32, -1.4];
        let (a, _) = rk45(&f, &x0, &Rk45Opts::default()).unwrap();
        let b = Rk4::new(512).sample(&f, &x0).unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn nfe_budget_enforced() {
        let f = NonlinearField { dim: 1 };
        let r = rk45(&f, &[1.0], &Rk45Opts { rtol: 1e-12, atol: 1e-14, max_nfe: 20, ..Default::default() });
        assert!(r.is_err());
    }
}
