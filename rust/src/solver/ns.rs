//! The Non-Stationary solver (paper §3.1): eq. 11/12 representation plus
//! Algorithm 1 sampling, and the JSON interchange with the python-side
//! BNS/BST trainer (python/compile/bns.py emits, we consume).

use anyhow::{bail, Context, Result};

use super::field::Field;
use super::workspace::SampleWorkspace;
use crate::util::json::Json;

/// theta of eq. 12: a time grid T_n and per-step (a_i, b_i) with
/// x_{i+1} = a_i x_0 + sum_{j<=i} b_ij u_j. `b` is dense lower-triangular.
#[derive(Debug, Clone, PartialEq)]
pub struct NsSolver {
    pub times: Vec<f64>, // n+1 entries, times[0] = 0, times[n] = 1
    pub a: Vec<f64>,     // n entries
    pub b: Vec<Vec<f64>>, // row i has i+1 entries
}

/// Metadata carried by distilled-solver artifacts (solver JSON files).
#[derive(Debug, Clone, Default)]
pub struct SolverMeta {
    pub kind: String, // "bns" | "bst" | "init"
    pub model: String,
    pub guidance: f64,
    pub sigma0: f64,
    pub init: String,
    pub val_psnr: f64,
    pub init_val_psnr: f64,
    pub iters: u64,
    pub forwards: u64,
    pub gt_nfe: u64,
}

impl NsSolver {
    pub fn nfe(&self) -> usize {
        self.a.len()
    }

    /// Parameter-space dimension n(n+5)/2 + 1 of the paper (§3.2), minus
    /// the two pinned endpoint times.
    pub fn num_params(&self) -> usize {
        let n = self.nfe();
        n * (n + 5) / 2 + 1 - 2
    }

    pub fn validate(&self) -> Result<()> {
        let n = self.nfe();
        if self.times.len() != n + 1 {
            bail!("times must have n+1 = {} entries, got {}", n + 1, self.times.len());
        }
        // Non-finite coefficients in a corrupt distilled artifact would
        // otherwise propagate NaNs silently into served samples.
        if let Some(t) = self.times.iter().find(|t| !t.is_finite()) {
            bail!("times contain a non-finite entry ({t})");
        }
        if let Some(a) = self.a.iter().find(|a| !a.is_finite()) {
            bail!("a contains a non-finite entry ({a})");
        }
        for (i, row) in self.b.iter().enumerate() {
            if let Some(b) = row.iter().find(|b| !b.is_finite()) {
                bail!("b row {i} contains a non-finite entry ({b})");
            }
        }
        if self.times[0].abs() > 1e-9 || (self.times[n] - 1.0).abs() > 1e-6 {
            bail!("times must start at 0 and end at 1");
        }
        for w in self.times.windows(2) {
            if w[1] <= w[0] {
                bail!("times must be strictly increasing ({} !< {})", w[0], w[1]);
            }
        }
        for (i, row) in self.b.iter().enumerate() {
            if row.len() != i + 1 {
                bail!("b row {} must have {} entries, got {}", i, i + 1, row.len());
            }
        }
        if self.b.len() != n {
            bail!("b must have n = {} rows", n);
        }
        Ok(())
    }

    /// Algorithm 1: Non-Stationary sampling over a batched field.
    /// `x0` is row-major [batch, dim]; returns x_n of the same shape.
    pub fn sample(&self, field: &dyn Field, x0: &[f32]) -> Result<Vec<f32>> {
        let mut x = x0.to_vec();
        let mut hist: Vec<Vec<f32>> = Vec::with_capacity(self.nfe());
        let mut acc = vec![0f32; x0.len()];
        for i in 0..self.nfe() {
            hist.push(field.eval(self.times[i], &x)?);
            // x_{i+1} = a_i x_0 + sum_j b_ij u_j  (the ns_update hot op)
            let a = self.a[i] as f32;
            for (o, &x0v) in acc.iter_mut().zip(x0.iter()) {
                *o = a * x0v;
            }
            for (j, row_b) in self.b[i].iter().enumerate() {
                let bj = *row_b as f32;
                if bj == 0.0 {
                    continue;
                }
                for (o, &uv) in acc.iter_mut().zip(hist[j].iter()) {
                    *o += bj * uv;
                }
            }
            std::mem::swap(&mut x, &mut acc);
        }
        Ok(x)
    }

    /// Allocation-free Algorithm 1: identical math to `sample`, but the
    /// velocity history lives in the workspace's flat `[nfe, len]` arena
    /// and the `a_i·x0 + Σ_j b_ij·u_j` combine is the *fused* streamed
    /// pass from `kernels::ns_combine_into` — all history terms applied
    /// to an L1-resident block of the state register while it is hot,
    /// one pass over x instead of one AXPY pass per nonzero coefficient,
    /// zero heap allocation per step in steady state. The per-element
    /// operation order matches `sample` exactly (seed `a·x0`, add terms
    /// j-ascending, skip exact zeros), so outputs are bit-identical
    /// (enforced by tests/sample_into_equiv.rs).
    pub fn sample_into<'w>(
        &self,
        field: &dyn Field,
        x0: &[f32],
        ws: &'w mut SampleWorkspace,
    ) -> Result<&'w [f32]> {
        let len = x0.len();
        let n = self.nfe();
        ws.ensure_hist(n, len);
        ws.x.copy_from_slice(x0);
        for i in 0..n {
            // u_i = u(t_i, x_i) written straight into its arena row
            field.eval_into(self.times[i], &ws.x, &mut ws.hist[i * len..(i + 1) * len])?;
            // x_{i+1} = a_i x_0 + sum_j b_ij u_j — x_i is dead once u_i
            // is recorded, so the fused combine overwrites x in place,
            // streaming rows 0..=i of the arena.
            crate::kernels::ns_combine_into(
                self.a[i] as f32,
                x0,
                &self.b[i],
                &ws.hist[..(i + 1) * len],
                len,
                &mut ws.x,
            );
        }
        Ok(&ws.x)
    }

    /// Like `sample` but keeps every trajectory iterate (diagnostics).
    pub fn sample_trajectory(&self, field: &dyn Field, x0: &[f32]) -> Result<Vec<Vec<f32>>> {
        let mut traj = vec![x0.to_vec()];
        let mut hist: Vec<Vec<f32>> = Vec::new();
        for i in 0..self.nfe() {
            let x = traj.last().unwrap();
            hist.push(field.eval(self.times[i], x)?);
            let mut next: Vec<f32> = x0.iter().map(|&v| self.a[i] as f32 * v).collect();
            for (j, row_b) in self.b[i].iter().enumerate() {
                let bj = *row_b as f32;
                for (o, &uv) in next.iter_mut().zip(hist[j].iter()) {
                    *o += bj * uv;
                }
            }
            traj.push(next);
        }
        Ok(traj)
    }

    // -- JSON interchange -----------------------------------------------

    pub fn from_json(j: &Json) -> Result<(NsSolver, SolverMeta)> {
        let times = j.get("times").as_f64_vec().context("solver json: times")?;
        let a = j.get("a").as_f64_vec().context("solver json: a")?;
        let b = j
            .get("b")
            .as_arr()
            .context("solver json: b")?
            .iter()
            .map(|row| row.as_f64_vec().context("solver json: b row"))
            .collect::<Result<Vec<_>>>()?;
        let solver = NsSolver { times, a, b };
        solver.validate()?;
        let g = |k: &str| j.get(k).as_f64().unwrap_or(0.0);
        let s = |k: &str| j.get(k).as_str().unwrap_or("").to_string();
        let meta = SolverMeta {
            kind: s("kind"),
            model: s("model"),
            guidance: g("guidance"),
            sigma0: if j.get("sigma0") == &Json::Null { 1.0 } else { g("sigma0") },
            init: s("init"),
            val_psnr: g("val_psnr"),
            init_val_psnr: g("init_val_psnr"),
            iters: g("iters") as u64,
            forwards: g("forwards") as u64,
            gt_nfe: g("gt_nfe") as u64,
        };
        Ok((solver, meta))
    }

    pub fn from_json_str(s: &str) -> Result<(NsSolver, SolverMeta)> {
        let j = Json::parse(s).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&j)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("times", Json::arr_f64(&self.times)),
            ("a", Json::arr_f64(&self.a)),
            (
                "b",
                Json::Arr(self.b.iter().map(|row| Json::arr_f64(row)).collect()),
            ),
        ])
    }

    /// Coefficients plus the full [`SolverMeta`] provenance — the
    /// artifact format `from_json` reads back and the router's
    /// `solvers_for` filters on. `to_json` alone drops every meta field,
    /// so rust-side emission (the distill CLI, refine outputs) must use
    /// this or the solver loses kind/model/guidance/val_psnr provenance.
    pub fn to_json_with_meta(&self, meta: &SolverMeta) -> Json {
        Json::obj(vec![
            ("times", Json::arr_f64(&self.times)),
            ("a", Json::arr_f64(&self.a)),
            (
                "b",
                Json::Arr(self.b.iter().map(|row| Json::arr_f64(row)).collect()),
            ),
            ("kind", Json::Str(meta.kind.clone())),
            ("model", Json::Str(meta.model.clone())),
            ("guidance", Json::Num(meta.guidance)),
            ("sigma0", Json::Num(meta.sigma0)),
            ("init", Json::Str(meta.init.clone())),
            ("val_psnr", Json::Num(meta.val_psnr)),
            ("init_val_psnr", Json::Num(meta.init_val_psnr)),
            ("iters", Json::Num(meta.iters as f64)),
            ("forwards", Json::Num(meta.forwards as f64)),
            ("gt_nfe", Json::Num(meta.gt_nfe as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::field::LinearField;

    fn euler_direct(f: &dyn Field, x0: &[f32], n: usize) -> Vec<f32> {
        let mut x = x0.to_vec();
        for i in 0..n {
            let t = i as f64 / n as f64;
            let u = f.eval(t, &x).unwrap();
            for (xv, uv) in x.iter_mut().zip(u.iter()) {
                *xv += (1.0 / n as f64) as f32 * uv;
            }
        }
        x
    }

    fn euler_ns(n: usize) -> NsSolver {
        // hand-built: x_{i+1} = x_i + h u_i, reduced form a_i = 1,
        // b_ij = h for all j <= i.
        let h = 1.0 / n as f64;
        NsSolver {
            times: (0..=n).map(|i| i as f64 * h).collect(),
            a: vec![1.0; n],
            b: (0..n).map(|i| vec![h; i + 1]).collect(),
        }
    }

    #[test]
    fn algorithm1_matches_euler() {
        let f = LinearField { dim: 3, k: -0.8, c: 0.4 };
        let x0 = vec![1.0f32, -0.5, 2.0];
        let s = euler_ns(8);
        s.validate().unwrap();
        let a = s.sample(&f, &x0).unwrap();
        let b = euler_direct(&f, &x0, 8);
        for (u, v) in a.iter().zip(b.iter()) {
            assert!((u - v).abs() < 1e-5, "{u} vs {v}");
        }
    }

    #[test]
    fn json_roundtrip() {
        let s = euler_ns(5);
        let j = s.to_json().to_string();
        let (s2, _) = NsSolver::from_json_str(&j).unwrap();
        assert_eq!(s, s2);
    }

    #[test]
    fn json_with_meta_roundtrip() {
        let s = euler_ns(5);
        let meta = SolverMeta {
            kind: "bns".into(),
            model: "img_fm_ot".into(),
            guidance: 1.5,
            sigma0: 0.75,
            init: "midpoint".into(),
            val_psnr: 37.25,
            init_val_psnr: 31.5,
            iters: 400,
            forwards: 123_456,
            gt_nfe: 512,
        };
        let j = s.to_json_with_meta(&meta).to_string();
        let (s2, m2) = NsSolver::from_json_str(&j).unwrap();
        assert_eq!(s, s2);
        assert_eq!(m2.kind, meta.kind);
        assert_eq!(m2.model, meta.model);
        assert_eq!(m2.guidance, meta.guidance);
        assert_eq!(m2.sigma0, meta.sigma0);
        assert_eq!(m2.init, meta.init);
        assert_eq!(m2.val_psnr, meta.val_psnr);
        assert_eq!(m2.init_val_psnr, meta.init_val_psnr);
        assert_eq!(m2.iters, meta.iters);
        assert_eq!(m2.forwards, meta.forwards);
        assert_eq!(m2.gt_nfe, meta.gt_nfe);
    }

    #[test]
    fn validate_rejects_bad() {
        let mut s = euler_ns(4);
        s.times[2] = s.times[1]; // non-monotone
        assert!(s.validate().is_err());
        let mut s = euler_ns(4);
        s.b[2].push(0.0); // wrong row length
        assert!(s.validate().is_err());
        let mut s = euler_ns(4);
        s.times[4] = 0.9; // wrong endpoint
        assert!(s.validate().is_err());
    }

    #[test]
    fn validate_rejects_non_finite() {
        let mut s = euler_ns(4);
        s.a[1] = f64::NAN;
        assert!(s.validate().is_err());
        let mut s = euler_ns(4);
        s.b[2][0] = f64::INFINITY;
        assert!(s.validate().is_err());
        let mut s = euler_ns(4);
        s.times[1] = f64::NAN;
        assert!(s.validate().is_err());
    }

    #[test]
    fn from_json_rejects_non_finite() {
        // JSON has no NaN literal, but overflow parses to +inf — a corrupt
        // artifact must not reach the serving path.
        let s = euler_ns(3);
        let j = s.to_json().to_string().replacen("1,", "1e999,", 1);
        assert!(NsSolver::from_json_str(&j).is_err(), "{j}");
    }

    #[test]
    fn sample_into_bit_identical_to_sample() {
        use crate::solver::workspace::SampleWorkspace;
        let f = LinearField { dim: 3, k: -0.8, c: 0.4 };
        let x0 = vec![1.0f32, -0.5, 2.0, 0.25, -1.5, 0.75];
        let s = euler_ns(8);
        let a = s.sample(&f, &x0).unwrap();
        let mut ws = SampleWorkspace::new();
        let b = s.sample_into(&f, &x0, &mut ws).unwrap();
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn num_params_formula() {
        // n(n+5)/2 + 1 - 2; e.g. paper Table 3: n=4 -> 18, n=8 -> 52,
        // n=16 -> 168.
        assert_eq!(euler_ns(4).num_params(), 17); // 18 incl. one endpoint convention
        assert_eq!(euler_ns(8).num_params(), 51);
        assert_eq!(euler_ns(16).num_params(), 167);
    }

    #[test]
    fn trajectory_has_n_plus_1_points() {
        let f = LinearField { dim: 2, k: 0.3, c: 0.0 };
        let s = euler_ns(6);
        let traj = s.sample_trajectory(&f, &[1.0, 2.0]).unwrap();
        assert_eq!(traj.len(), 7);
        let last = s.sample(&f, &[1.0, 2.0]).unwrap();
        assert_eq!(traj.last().unwrap(), &last);
    }
}
