//! Rust mirror of the Gaussian-path schedulers (python/compile/schedulers.py).
//!
//! Analytic alpha/sigma and derivatives for FM-OT, cosine, VP and VE, the
//! snr machinery, and the Table-1 velocity-field coefficients. The python
//! side exports a (t, alpha, sigma) cross-check grid in the artifacts
//! manifest; `runtime::artifact` tests assert the two implementations
//! agree to float32 precision.

use std::f64::consts::PI;

/// VP constants from eq. 60.
pub const VP_BETA_MAX: f64 = 20.0;
pub const VP_BETA_MIN: f64 = 0.1;
pub const EDM_SIGMA_MAX: f64 = 80.0;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheduler {
    FmOt,
    Cosine,
    Vp,
    Ve,
}

/// Model output parametrizations of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Parametrization {
    Velocity,
    Eps,
    X,
}

fn vp_xi(s: f64) -> f64 {
    (-0.25 * s * s * (VP_BETA_MAX - VP_BETA_MIN) - 0.5 * s * VP_BETA_MIN).exp()
}

fn vp_dxi(s: f64) -> f64 {
    vp_xi(s) * (-0.5 * s * (VP_BETA_MAX - VP_BETA_MIN) - 0.5 * VP_BETA_MIN)
}

impl Scheduler {
    pub fn from_name(name: &str) -> Option<Scheduler> {
        match name {
            "fm_ot" => Some(Scheduler::FmOt),
            "cosine" => Some(Scheduler::Cosine),
            "vp" => Some(Scheduler::Vp),
            "ve" => Some(Scheduler::Ve),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheduler::FmOt => "fm_ot",
            Scheduler::Cosine => "cosine",
            Scheduler::Vp => "vp",
            Scheduler::Ve => "ve",
        }
    }

    pub fn alpha(&self, t: f64) -> f64 {
        match self {
            Scheduler::FmOt => t,
            Scheduler::Cosine => (0.5 * PI * t).sin(),
            Scheduler::Vp => vp_xi(1.0 - t),
            Scheduler::Ve => 1.0,
        }
    }

    pub fn sigma(&self, t: f64) -> f64 {
        match self {
            Scheduler::FmOt => 1.0 - t,
            Scheduler::Cosine => (0.5 * PI * t).cos(),
            Scheduler::Vp => (1.0 - vp_xi(1.0 - t).powi(2)).max(1e-12).sqrt(),
            Scheduler::Ve => EDM_SIGMA_MAX * (1.0 - t),
        }
    }

    pub fn dalpha(&self, t: f64) -> f64 {
        match self {
            Scheduler::FmOt => 1.0,
            Scheduler::Cosine => 0.5 * PI * (0.5 * PI * t).cos(),
            Scheduler::Vp => -vp_dxi(1.0 - t),
            Scheduler::Ve => 0.0,
        }
    }

    pub fn dsigma(&self, t: f64) -> f64 {
        match self {
            Scheduler::FmOt => -1.0,
            Scheduler::Cosine => -0.5 * PI * (0.5 * PI * t).sin(),
            Scheduler::Vp => {
                let a = self.alpha(t);
                -a * self.dalpha(t) / self.sigma(t)
            }
            Scheduler::Ve => -EDM_SIGMA_MAX,
        }
    }

    /// snr(t) = alpha / sigma (strictly increasing; +inf at sigma = 0).
    pub fn snr(&self, t: f64) -> f64 {
        self.alpha(t) / self.sigma(t)
    }

    /// snr^{-1} — closed form per scheduler, matching the python side.
    pub fn snr_inv(&self, y: f64) -> f64 {
        match self {
            Scheduler::FmOt => 1.0 - 1.0 / (1.0 + y),
            Scheduler::Cosine => (2.0 / PI) * y.atan(),
            Scheduler::Vp => {
                let xi = 1.0 / (1.0 + y.max(1e-30).powi(-2)).sqrt();
                let (b, bb) = (VP_BETA_MIN, VP_BETA_MAX);
                let log_xi = xi.clamp(1e-30, 1.0).ln();
                let disc = (0.25 * b * b - (bb - b) * log_xi).max(0.0).sqrt();
                let s = (-0.5 * b + disc) / (0.5 * (bb - b));
                1.0 - s
            }
            Scheduler::Ve => 1.0 - 1.0 / (EDM_SIGMA_MAX * y.max(1e-30)),
        }
    }

    /// Table 1: (beta_t, gamma_t) with u_t(x) = beta x + gamma f(x).
    /// For eps/x the coefficient time is clamped to [1e-4, 1 - 1e-3]
    /// (endpoint singularities; mirrors model.velocity_from_f).
    pub fn uv_coeffs(&self, t: f64, p: Parametrization) -> (f64, f64) {
        match p {
            Parametrization::Velocity => (0.0, 1.0),
            Parametrization::Eps => {
                let t = t.clamp(1e-4, 1.0 - 1e-3);
                let (a, s) = (self.alpha(t), self.sigma(t));
                let (da, ds) = (self.dalpha(t), self.dsigma(t));
                (da / a, (ds * a - s * da) / a)
            }
            Parametrization::X => {
                let t = t.clamp(1e-4, 1.0 - 1e-3);
                let (a, s) = (self.alpha(t), self.sigma(t));
                let (da, ds) = (self.dalpha(t), self.dsigma(t));
                (ds / s, (s * da - ds * a) / s)
            }
        }
    }
}

impl Parametrization {
    pub fn from_name(name: &str) -> Option<Parametrization> {
        match name {
            "velocity" => Some(Parametrization::Velocity),
            "eps" => Some(Parametrization::Eps),
            "x" => Some(Parametrization::X),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL: [Scheduler; 4] = [Scheduler::FmOt, Scheduler::Cosine, Scheduler::Vp, Scheduler::Ve];

    #[test]
    fn boundary_conditions() {
        // eq. 4: alpha_1 = 1, sigma_1 = 0, sigma_0 > 0 (alpha_0 ~ 0)
        for s in [Scheduler::FmOt, Scheduler::Cosine, Scheduler::Vp] {
            assert!((s.alpha(1.0) - 1.0).abs() < 1e-6, "{:?}", s);
            assert!(s.sigma(1.0).abs() < 1e-5, "{:?}", s);
            assert!(s.sigma(0.0) > 0.5, "{:?}", s);
            assert!(s.alpha(0.0) < 0.01, "{:?}", s);
        }
    }

    #[test]
    fn snr_monotone() {
        for s in ALL {
            let mut prev = s.snr(0.001);
            for i in 1..100 {
                let t = 0.001 + 0.99 * i as f64 / 100.0;
                let cur = s.snr(t);
                assert!(cur > prev, "{:?} at t={}", s, t);
                prev = cur;
            }
        }
    }

    #[test]
    fn snr_inv_roundtrip() {
        for s in ALL {
            for i in 1..20 {
                let t = i as f64 / 20.0 * 0.95 + 0.01;
                let back = s.snr_inv(s.snr(t));
                assert!((back - t).abs() < 1e-6, "{:?} t={} back={}", s, t, back);
            }
        }
    }

    #[test]
    fn derivatives_match_finite_difference() {
        let h = 1e-6;
        for s in ALL {
            for i in 1..20 {
                let t = i as f64 / 21.0;
                let fd_a = (s.alpha(t + h) - s.alpha(t - h)) / (2.0 * h);
                let fd_s = (s.sigma(t + h) - s.sigma(t - h)) / (2.0 * h);
                assert!((fd_a - s.dalpha(t)).abs() < 1e-4 * (1.0 + s.dalpha(t).abs()), "{:?}", s);
                assert!((fd_s - s.dsigma(t)).abs() < 1e-4 * (1.0 + s.dsigma(t).abs()), "{:?}", s);
            }
        }
    }

    #[test]
    fn velocity_coeffs_consistent() {
        // For the ideal path x_t = alpha x1 + sigma x0, the velocity is
        // dalpha x1 + dsigma x0; check eps parametrization reproduces it:
        // with f = x0 (true noise), u = beta x + gamma x0 must equal it.
        let s = Scheduler::Vp;
        let (x1, x0) = (0.7, -0.3);
        for i in 1..10 {
            let t = i as f64 / 10.0 * 0.9 + 0.05;
            let x = s.alpha(t) * x1 + s.sigma(t) * x0;
            let truth = s.dalpha(t) * x1 + s.dsigma(t) * x0;
            let (beta, gamma) = s.uv_coeffs(t, Parametrization::Eps);
            let u = beta * x + gamma * x0;
            assert!((u - truth).abs() < 1e-6, "t={t}: {u} vs {truth}");
            // and x-parametrization with f = x1 (true data)
            let (beta, gamma) = s.uv_coeffs(t, Parametrization::X);
            let u = beta * x + gamma * x1;
            assert!((u - truth).abs() < 1e-6, "t={t}: {u} vs {truth}");
        }
    }
}
