//! Dedicated exponential-integrator solvers (§3.3.2): DDIM and
//! DPM-Solver++ (1S / 2M), implemented directly against a velocity field.
//!
//! Both are linear-in-(x, u) update rules, so `taxonomy` can also express
//! them as NS coefficients; the unit tests check the two forms coincide,
//! which is the computational content of Theorem 3.2's exponential branch.
//!
//! The model is exposed to us as a *velocity* field (eq. 5), so each step
//! first inverts Table 1 to recover the eps- or x-prediction:
//!   f = (u - beta x) / gamma.

use anyhow::Result;

use super::field::Field;
use super::scheduler::{Parametrization, Scheduler};
use super::Solver;

/// DDIM (Song et al. 2022) = exponential Euler on the eps prediction:
///   x_{i+1} = (a_{i+1}/a_i) x_i + (s_{i+1} - a_{i+1} s_i / a_i) eps_i.
/// Requires alpha(t_0) > 0, so for FM schedulers pass a grid starting at
/// t_0 = eps > 0 (`shifted_times`).
pub struct Ddim {
    pub sched: Scheduler,
    pub times: Vec<f64>,
}

/// Uniform grid on [t0, 1] for solvers singular at t = 0.
pub fn shifted_times(nfe: usize, t0: f64) -> Vec<f64> {
    (0..=nfe).map(|i| t0 + (1.0 - t0) * i as f64 / nfe as f64).collect()
}

/// EDM's rho-schedule (Karras et al. 2022) mapped to model time via the
/// snr correspondence — the "particular time discretization" the paper
/// notes EDM stacks on its VE scheduler change. Usable with any solver.
pub fn edm_times(nfe: usize, sched: Scheduler, rho: f64) -> Vec<f64> {
    let (smin, smax) = (2e-3f64, crate::solver::scheduler::EDM_SIGMA_MAX);
    let mut t: Vec<f64> = (0..=nfe)
        .map(|j| {
            let frac = j as f64 / nfe as f64;
            let sig = (smax.powf(1.0 / rho)
                + frac * (smin.powf(1.0 / rho) - smax.powf(1.0 / rho)))
            .powf(rho);
            sched.snr_inv(1.0 / sig).clamp(0.0, 1.0)
        })
        .collect();
    t[0] = 0.0;
    t[nfe] = 1.0;
    // enforce strict monotonicity against clamp plateaus
    for i in 1..t.len() {
        if t[i] <= t[i - 1] {
            t[i] = t[i - 1] + 1e-9;
        }
    }
    t[nfe] = 1.0;
    t
}

impl Ddim {
    pub fn new(sched: Scheduler, nfe: usize) -> Self {
        let t0 = if sched.alpha(0.0) > 1e-6 { 0.0 } else { 0.05 };
        Ddim { sched, times: shifted_times(nfe, t0) }
    }

    fn eps_from_u(&self, t: f64, x: &[f32], u: &[f32]) -> Vec<f32> {
        let (beta, gamma) = self.sched.uv_coeffs(t, Parametrization::Eps);
        x.iter()
            .zip(u.iter())
            .map(|(&xv, &uv)| ((uv as f64 - beta * xv as f64) / gamma) as f32)
            .collect()
    }
}

impl Solver for Ddim {
    fn name(&self) -> String {
        format!("ddim{}", self.times.len() - 1)
    }

    fn nfe(&self) -> usize {
        self.times.len() - 1
    }

    fn sample(&self, field: &dyn Field, x0: &[f32]) -> Result<Vec<f32>> {
        let mut x = x0.to_vec();
        for w in self.times.windows(2) {
            let (t0, t1) = (w[0], w[1]);
            let (a0, s0) = (self.sched.alpha(t0), self.sched.sigma(t0));
            let (a1, s1) = (self.sched.alpha(t1), self.sched.sigma(t1));
            let u = field.eval(t0, &x)?;
            let eps = self.eps_from_u(t0, &x, &u);
            let cx = a1 / a0;
            let ce = s1 - a1 * s0 / a0;
            for (xv, &ev) in x.iter_mut().zip(eps.iter()) {
                *xv = (cx * *xv as f64 + ce * ev as f64) as f32;
            }
        }
        Ok(x)
    }
}

/// DPM-Solver++ (Lu et al. 2022b): exponential integrator on the
/// x-prediction; order 1 (1S) or 2 (2M, multistep). Regular at both
/// endpoints for all schedulers here (see python/compile/ns.py).
pub struct DpmPp {
    pub sched: Scheduler,
    pub times: Vec<f64>,
    pub order: usize,
}

impl DpmPp {
    pub fn new(sched: Scheduler, nfe: usize, order: usize) -> Self {
        assert!(order == 1 || order == 2);
        DpmPp { sched, times: super::generic::uniform_times(nfe), order }
    }

    fn xhat_from_u(&self, t: f64, x: &[f32], u: &[f32]) -> Vec<f32> {
        let (beta, gamma) = self.sched.uv_coeffs(t, Parametrization::X);
        x.iter()
            .zip(u.iter())
            .map(|(&xv, &uv)| ((uv as f64 - beta * xv as f64) / gamma) as f32)
            .collect()
    }

    fn lambda(&self, t: f64) -> f64 {
        self.sched.alpha(t).max(1e-30).ln() - self.sched.sigma(t).max(1e-30).ln()
    }
}

impl Solver for DpmPp {
    fn name(&self) -> String {
        format!("dpmpp{}m{}", self.order, self.times.len() - 1)
    }

    fn nfe(&self) -> usize {
        self.times.len() - 1
    }

    fn sample(&self, field: &dyn Field, x0: &[f32]) -> Result<Vec<f32>> {
        let n = self.times.len() - 1;
        let mut x = x0.to_vec();
        let mut prev: Option<(Vec<f32>, f64)> = None; // (xhat_{i-1}, h_{i-1})
        for (i, w) in self.times.windows(2).enumerate() {
            let (t0, t1) = (w[0], w[1]);
            let (s0, s1) = (self.sched.sigma(t0), self.sched.sigma(t1));
            let a1 = self.sched.alpha(t1);
            let h = self.lambda(t1) - self.lambda(t0);
            let u = field.eval(t0, &x)?;
            let xhat = self.xhat_from_u(t0, &x, &u);
            // `lower_order_final` (as in the reference DPM-Solver++): the
            // last step's lambda jump is unbounded when sigma(1) = 0, and
            // 2nd-order extrapolation across it diverges — drop to order 1.
            let use_second = self.order >= 2 && prev.is_some() && i + 1 < n;
            let d: Vec<f32> = match (&prev, use_second) {
                (Some((ph, phh)), true) => {
                    let r = phh / h;
                    let c1 = 1.0 + 1.0 / (2.0 * r);
                    let c0 = -1.0 / (2.0 * r);
                    xhat.iter()
                        .zip(ph.iter())
                        .map(|(&a, &b)| (c1 * a as f64 + c0 * b as f64) as f32)
                        .collect()
                }
                _ => xhat.clone(),
            };
            let cx = s1 / s0;
            let cd = a1 * (1.0 - (-h).exp());
            for (xv, &dv) in x.iter_mut().zip(d.iter()) {
                *xv = (cx * *xv as f64 + cd * dv as f64) as f32;
            }
            prev = Some((xhat, h));
        }
        Ok(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::field::GaussianTargetField;
    use crate::solver::generic::{Euler, Rk4};

    /// On a Gaussian-target FM-OT field, DPM++ should beat Euler at equal
    /// NFE (the trajectory has the exponential structure DPM exploits).
    #[test]
    fn dpmpp_beats_euler_on_gaussian_field() {
        let f = GaussianTargetField { dim: 4, sched: Scheduler::FmOt, mu: 0.4, s1: 0.3 };
        let x0 = vec![1.2f32, -0.7, 0.3, 2.0];
        let reference = Rk4::new(512).sample(&f, &x0).unwrap();
        let err = |out: &[f32]| -> f64 {
            out.iter()
                .zip(reference.iter())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let e_euler = err(&Euler::new(8).sample(&f, &x0).unwrap());
        let e_dpm1 = err(&DpmPp::new(Scheduler::FmOt, 8, 1).sample(&f, &x0).unwrap());
        let e_dpm2 = err(&DpmPp::new(Scheduler::FmOt, 8, 2).sample(&f, &x0).unwrap());
        assert!(e_dpm1 < e_euler, "dpm1 {e_dpm1} vs euler {e_euler}");
        assert!(e_dpm2 < e_dpm1, "dpm2 {e_dpm2} vs dpm1 {e_dpm1}");
    }

    /// DPM++(1S) on a *pure Gaussian* target solves the ODE exactly in one
    /// step family sense: with a perfect x-prediction constant in lambda it
    /// is exact; with our field it should at least converge fast.
    #[test]
    fn dpmpp_converges() {
        let f = GaussianTargetField { dim: 2, sched: Scheduler::Vp, mu: -0.2, s1: 0.5 };
        let x0 = vec![0.9f32, -1.1];
        let reference = Rk4::new(512).sample(&f, &x0).unwrap();
        let err = |o: &[f32]| {
            o.iter()
                .zip(reference.iter())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let e6 = err(&DpmPp::new(Scheduler::Vp, 6, 2).sample(&f, &x0).unwrap());
        let e24 = err(&DpmPp::new(Scheduler::Vp, 24, 2).sample(&f, &x0).unwrap());
        let e96 = err(&DpmPp::new(Scheduler::Vp, 96, 2).sample(&f, &x0).unwrap());
        // monotone convergence; the final lambda jump to sigma ~ 0 keeps
        // the absolute floor above machine precision (lower_order_final).
        assert!(e24 < e6 && e96 < e24, "{e6} -> {e24} -> {e96}");
        assert!(e96 < 5e-3, "e96 {e96}");
    }

    #[test]
    fn edm_times_monotone_and_bounded() {
        for sched in [Scheduler::FmOt, Scheduler::Vp, Scheduler::Cosine] {
            let t = edm_times(12, sched, 7.0);
            assert_eq!(t[0], 0.0);
            assert_eq!(t[12], 1.0);
            for w in t.windows(2) {
                assert!(w[1] > w[0], "{:?}: {:?}", sched, t);
            }
        }
    }

    #[test]
    fn ddim_requires_positive_alpha_start() {
        let d = Ddim::new(Scheduler::FmOt, 8);
        assert!(d.times[0] > 0.0); // auto-shifted
        let d = Ddim::new(Scheduler::Vp, 8);
        assert_eq!(d.times[0], 0.0); // VP has alpha_0 > 0
    }

    #[test]
    fn ddim_converges_gaussian_vp() {
        // DDIM is first order; assert convergence toward the RK4-dense
        // reference as NFE grows (VP's lambda range is wide, so absolute
        // error at low NFE is legitimately large).
        let f = GaussianTargetField { dim: 2, sched: Scheduler::Vp, mu: 0.3, s1: 0.4 };
        let x0 = vec![0.5f32, -0.5];
        let reference = Rk4::new(512).sample(&f, &x0).unwrap();
        let err = |o: &[f32]| -> f64 {
            o.iter().zip(reference.iter()).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>().sqrt()
        };
        let e8 = err(&Ddim::new(Scheduler::Vp, 8).sample(&f, &x0).unwrap());
        let e64 = err(&Ddim::new(Scheduler::Vp, 64).sample(&f, &x0).unwrap());
        assert!(e64 < e8, "{e64} !< {e8}");
        assert!(e64 < 5e-2, "e64 {e64}");
    }
}
