//! bns-serve CLI: serve / sample / solvers / models / bench-quick.
//!
//! Hand-rolled arg parsing (clap is not resolvable offline, DESIGN.md §3).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use bns_serve::coordinator::batcher::{TenantPolicy, TenantSpec};
use bns_serve::coordinator::{server, Engine, EngineConfig, Fleet, FleetConfig, SolverSpec};
use bns_serve::runtime::{ArtifactStore, Runtime, RuntimeConfig};
use bns_serve::util::stats::psnr;

const USAGE: &str = "\
bns-serve — Bespoke Non-Stationary solver serving (ICML 2024 repro)

USAGE:
  bns-serve serve   [--addr 127.0.0.1:7878] [--artifacts DIR] [--workers N]
                    [--lanes N]  (device lanes; default = workers, forced
                     to 1 when built with --features pjrt)
                    [--reactors N]      (connection-plane reactor threads;
                     default 2 — see PROTOCOL.md + README runbook)
                    [--max-inflight R]  (admission budget: sample rows
                     admitted but unanswered; beyond it requests are
                     rejected with err=overloaded; default 4096)
                    [--deadline-ms MS]  (default per-request deadline when
                     the request carries none; queued work past it is shed
                     with err=deadline_exceeded; default: no deadline)
                    [--lane-exec-timeout-ms MS]  (per-exec watchdog: a lane
                     that exceeds it is declared wedged and respawned under
                     a new generation; default 30000 — DESIGN.md §11)
                    [--breaker-threshold N]  (consecutive batch failures
                     that open a model's circuit breaker, 0 disables;
                     default 5)
                    [--breaker-cooldown-ms MS]  (open-breaker reject window
                     before one half-open probe; default 1000)
                    [--trace-capacity N]  (span slots in the tracing ring;
                     0 disables tracing entirely; default 4096 —
                     DESIGN.md §12, `trace` op in PROTOCOL.md)
                    [--trace-out FILE]  (periodically export the trace
                     ring as JSON lines via atomic rename; default: off)
                    [--mlp-pool-threads N]  (intra-lane row-pool threads
                     for bns_mlp_field models; 0 = auto (min(cores, 8)),
                     1 = inline. Pure throughput knob: outputs are
                     bit-identical for any value — DESIGN.md §13)
                    [--shards N]  (in-process engine shards behind one
                     front door; model ids route by consistent hashing;
                     default 1 — DESIGN.md §14)
                    [--tenants SPEC]  (weighted-fair tenancy policy:
                     comma-separated name:weight[:quota_rows] entries;
                     the reserved name 'default' sets the policy for
                     tenants without an explicit entry; quota_rows bounds
                     a tenant's parked backlog, 0 = reject at the queue
                     bound; e.g. --tenants \"default:1:64,batch:4:256\")
  bns-serve sample  --model NAME [--solver auto|euler|midpoint|dpmpp2m|<artifact>]
                    [--nfe N] [--guidance W] [--labels 0,1,2] [--seed S]
                    [--out samples.json] [--artifacts DIR]
  bns-serve compare --model NAME [--nfe N] [--guidance W] [--artifacts DIR]
                    (PSNR of every solver vs RK45 ground truth)
  bns-serve distill --model NAME --nfe N [--guidance W] [--iters K]
                    [--init euler|midpoint|rk4|auto|<artifact>] [--out FILE]
                    [--method adam|spsa] [--pairs P] [--val-pairs V]
                    [--batch B] [--lr R] [--seed S] [--threads T]
                    [--lanes L] [--teacher-cache FILE] [--register]
                    (rust-native solver distillation against the deployed
                     field — first-order Adam on analytic gradients by
                     default, zeroth-order SPSA via --method spsa; no
                     python needed. --threads fans teacher generation AND
                     the wavefront gradient chunks, --lanes replicates
                     the model across device lanes for both; results are
                     bit-identical for any --threads/--lanes. --register
                     adds the artifact to the store so `serve`/`sample`
                     route to it immediately)
  bns-serve solvers [--artifacts DIR]    list distilled solver artifacts
  bns-serve models  [--artifacts DIR]    list AOT model artifacts
";

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(k) = args[i].strip_prefix("--") {
            let v = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            flags.insert(k.to_string(), v);
        }
        i += 1;
    }
    flags
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprint!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = args[0].clone();
    let flags = parse_flags(&args[1..]);
    if let Err(e) = run(&cmd, &flags) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parse a `--tenants` spec: comma-separated `name:weight[:quota_rows]`
/// entries. The reserved name `default` sets the policy applied to tenants
/// without an explicit entry (and to untenanted requests).
fn parse_tenant_policy(spec: &str) -> Result<TenantPolicy> {
    let mut policy = TenantPolicy::default();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let mut parts = entry.split(':');
        let name = parts.next().unwrap_or("").trim();
        anyhow::ensure!(!name.is_empty(), "--tenants entry '{entry}' has an empty name");
        let weight: u32 = parts
            .next()
            .with_context(|| format!("--tenants entry '{entry}' missing a weight"))?
            .trim()
            .parse()
            .with_context(|| format!("--tenants entry '{entry}': bad weight"))?;
        let quota_rows: usize = match parts.next() {
            Some(q) => q
                .trim()
                .parse()
                .with_context(|| format!("--tenants entry '{entry}': bad quota_rows"))?,
            None => 0,
        };
        anyhow::ensure!(
            parts.next().is_none(),
            "--tenants entry '{entry}' has trailing fields (want name:weight[:quota_rows])"
        );
        anyhow::ensure!(weight >= 1, "--tenants entry '{entry}': weight must be >= 1");
        if name == "default" {
            policy.default_weight = weight;
            policy.default_quota_rows = quota_rows;
        } else {
            policy.tenants.insert(name.to_string(), TenantSpec { weight, quota_rows });
        }
    }
    Ok(policy)
}

fn load_store(flags: &HashMap<String, String>) -> Result<Arc<ArtifactStore>> {
    let dir = flags
        .get("artifacts")
        .map(|s| s.into())
        .unwrap_or_else(bns_serve::default_artifacts_dir);
    Ok(Arc::new(ArtifactStore::load(&dir).with_context(|| {
        format!("loading artifacts from {} (run `make artifacts` first)", dir.display())
    })?))
}

/// Shared tail of the `distill` subcommand: write the artifact
/// (coefficients + full meta) and, under `--register`, add it to the
/// store's manifest so `serve`/`sample` route to it immediately.
fn finish_distill(
    store: &ArtifactStore,
    flags: &HashMap<String, String>,
    model: &str,
    guidance: f32,
    nfe: usize,
    solver: &bns_serve::solver::NsSolver,
    meta: &bns_serve::solver::ns::SolverMeta,
) -> Result<()> {
    let default_name = format!("{model}_w{guidance}_nfe{nfe}_bns");
    let out = flags.get("out").cloned().unwrap_or(format!("{default_name}.json"));
    std::fs::write(&out, solver.to_json_with_meta(meta).to_string())?;
    println!("wrote {out}");
    if flags.contains_key("register") {
        let name = std::path::Path::new(&out)
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or(&default_name)
            .to_string();
        bns_serve::bench_util::add_solver_artifact(&store.root, &name, solver, meta)?;
        println!(
            "registered '{name}' in {} (route via --solver {name} or auto at nfe={nfe})",
            store.root.join("manifest.json").display()
        );
    }
    Ok(())
}

fn run(cmd: &str, flags: &HashMap<String, String>) -> Result<()> {
    match cmd {
        "serve" => {
            let store = load_store(flags)?;
            let workers: usize = flags.get("workers").map(|s| s.parse()).transpose()?.unwrap_or(2);
            let lanes: usize =
                flags.get("lanes").map(|s| s.parse()).transpose()?.unwrap_or(workers);
            let reactors: usize =
                flags.get("reactors").map(|s| s.parse()).transpose()?.unwrap_or(2);
            let max_inflight: usize =
                flags.get("max-inflight").map(|s| s.parse()).transpose()?.unwrap_or(4096);
            let deadline_ms: Option<u64> =
                flags.get("deadline-ms").map(|s| s.parse()).transpose()?;
            let lane_exec_timeout_ms: u64 = flags
                .get("lane-exec-timeout-ms")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(30_000);
            let breaker_threshold: u32 =
                flags.get("breaker-threshold").map(|s| s.parse()).transpose()?.unwrap_or(5);
            let breaker_cooldown_ms: u64 =
                flags.get("breaker-cooldown-ms").map(|s| s.parse()).transpose()?.unwrap_or(1000);
            let trace_capacity: usize =
                flags.get("trace-capacity").map(|s| s.parse()).transpose()?.unwrap_or(4096);
            let trace_out = flags.get("trace-out").map(std::path::PathBuf::from);
            let mlp_pool_threads: usize =
                flags.get("mlp-pool-threads").map(|s| s.parse()).transpose()?.unwrap_or(0);
            let shards: usize = flags.get("shards").map(|s| s.parse()).transpose()?.unwrap_or(1);
            let tenants = match flags.get("tenants") {
                Some(spec) => parse_tenant_policy(spec)?,
                None => TenantPolicy::default(),
            };
            anyhow::ensure!(shards >= 1, "--shards must be >= 1 (got 0)");
            anyhow::ensure!(reactors >= 1, "--reactors must be >= 1 (got 0)");
            anyhow::ensure!(max_inflight >= 1, "--max-inflight must be >= 1 (got 0)");
            anyhow::ensure!(
                lane_exec_timeout_ms >= 1,
                "--lane-exec-timeout-ms must be >= 1 (got 0)"
            );
            let rt = Arc::new(Runtime::with_config(RuntimeConfig {
                lanes,
                lane_exec_timeout: std::time::Duration::from_millis(lane_exec_timeout_ms),
                mlp_pool_threads,
                ..Default::default()
            })?);
            eprintln!(
                "[bns-serve] {} device lane(s) on '{}', {shards} shard(s) x \
                 {workers} worker(s), {reactors} reactor(s), max-inflight \
                 {max_inflight} rows/shard, default deadline {}",
                rt.num_lanes(),
                rt.platform(),
                deadline_ms.map(|ms| format!("{ms}ms")).unwrap_or("none".into()),
            );
            let engine_cfg = EngineConfig {
                workers,
                max_inflight_rows: max_inflight,
                breaker_threshold,
                breaker_cooldown_ms,
                trace_capacity,
                batcher: bns_serve::coordinator::batcher::BatcherConfig {
                    tenants,
                    ..Default::default()
                },
                ..Default::default()
            };
            let fleet = Fleet::start(store.clone(), rt, FleetConfig { shards, engine: engine_cfg })?;
            if let Some(path) = trace_out {
                // detached exporter: snapshot the ring every 2 s and
                // atomically replace the file, so observers always read a
                // complete JSON-lines document (util::fsio::write_atomic)
                let tracer = fleet.tracer().clone();
                std::thread::Builder::new()
                    .name("bns-trace-export".into())
                    .spawn(move || loop {
                        std::thread::sleep(std::time::Duration::from_secs(2));
                        if let Err(e) =
                            bns_serve::util::fsio::write_atomic(&path, &tracer.render_jsonl())
                        {
                            eprintln!("[bns-serve] trace export failed: {e:#}");
                        }
                    })
                    .context("spawning trace exporter thread")?;
            }
            let addr = flags.get("addr").cloned().unwrap_or("127.0.0.1:7878".into());
            let cfg = bns_serve::coordinator::ServerConfig {
                reactors,
                default_deadline_ms: deadline_ms,
                ..Default::default()
            };
            server::serve_fleet(&addr, cfg, fleet)?;
            Ok(())
        }
        "sample" => {
            let store = load_store(flags)?;
            let rt = Arc::new(Runtime::cpu()?);
            let engine = Engine::start(store.clone(), rt, EngineConfig::default())?;
            let model = flags.get("model").context("--model required")?.clone();
            let nfe: usize = flags.get("nfe").map(|s| s.parse()).transpose()?.unwrap_or(8);
            let guidance: f32 =
                flags.get("guidance").map(|s| s.parse()).transpose()?.unwrap_or(0.0);
            let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
            let labels: Vec<i32> = flags
                .get("labels")
                .map(|s| s.split(',').map(|x| x.trim().parse().unwrap_or(0)).collect())
                .unwrap_or_else(|| vec![0, 1, 2, 3]);
            let spec = server::parse_solver_spec(
                flags.get("solver").map(|s| s.as_str()).unwrap_or("auto"),
                nfe,
            );
            let out = engine.sample_blocking(&model, labels, guidance, spec, seed)?;
            println!(
                "solver={} nfe={} forwards={} exec={}us dim={}",
                out.solver_used, out.nfe, out.forwards, out.exec_us, out.dim
            );
            if let Some(path) = flags.get("out") {
                let j = bns_serve::util::json::Json::obj(vec![
                    ("dim", bns_serve::util::json::Json::Num(out.dim as f64)),
                    ("samples", bns_serve::util::json::Json::arr_f32(&out.samples)),
                ]);
                std::fs::write(path, j.to_string())?;
                println!("wrote {path}");
            } else {
                let head: Vec<f32> = out.samples.iter().take(8).copied().collect();
                println!("samples[0][..8] = {head:?}");
            }
            engine.shutdown();
            Ok(())
        }
        "compare" => {
            let store = load_store(flags)?;
            let rt = Arc::new(Runtime::cpu()?);
            let engine = Engine::start(store.clone(), rt, EngineConfig::default())?;
            let model = flags.get("model").context("--model required")?.clone();
            let nfe: usize = flags.get("nfe").map(|s| s.parse()).transpose()?.unwrap_or(8);
            let guidance: f32 =
                flags.get("guidance").map(|s| s.parse()).transpose()?.unwrap_or(0.0);
            let info = store.model(&model)?;
            let labels: Vec<i32> = (0..16).map(|i| (i % info.num_classes) as i32).collect();
            let seed = 42u64;
            let gt = engine
                .sample_blocking(&model, labels.clone(), guidance, SolverSpec::GroundTruth, seed)?;
            println!("GT (rk45): nfe={}", gt.nfe);
            let mut specs: Vec<(String, SolverSpec)> = vec![
                ("auto (BNS-first)".into(), SolverSpec::Auto { nfe }),
                ("euler".into(), SolverSpec::Baseline { name: "euler".into(), nfe }),
                ("dpmpp2m".into(), SolverSpec::Baseline { name: "dpmpp2m".into(), nfe }),
            ];
            if nfe % 2 == 0 {
                specs.push((
                    "midpoint".into(),
                    SolverSpec::Baseline { name: "midpoint".into(), nfe },
                ));
            }
            println!("{:<24} {:>6} {:>10}", "solver", "NFE", "PSNR(dB)");
            for (label, spec) in specs {
                let out = engine.sample_blocking(&model, labels.clone(), guidance, spec, seed)?;
                println!(
                    "{:<24} {:>6} {:>10.2}   ({})",
                    label,
                    out.nfe,
                    psnr(&out.samples, &gt.samples),
                    out.solver_used
                );
            }
            engine.shutdown();
            Ok(())
        }
        "distill" => {
            let store = load_store(flags)?;
            let model = flags.get("model").context("--model required")?.clone();
            let nfe: usize = flags.get("nfe").context("--nfe required")?.parse()?;
            let guidance: f32 =
                flags.get("guidance").map(|s| s.parse()).transpose()?.unwrap_or(0.0);
            let iters: usize = flags.get("iters").map(|s| s.parse()).transpose()?.unwrap_or(300);
            let pairs: usize = flags.get("pairs").map(|s| s.parse()).transpose()?.unwrap_or(32);
            let val_pairs: usize =
                flags.get("val-pairs").map(|s| s.parse()).transpose()?.unwrap_or(16);
            let batch: usize = flags.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(16);
            let lr: f64 = flags.get("lr").map(|s| s.parse()).transpose()?.unwrap_or(8e-3);
            let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(7);
            let threads: usize =
                flags.get("threads").map(|s| s.parse()).transpose()?.unwrap_or(4);
            let lanes: usize = flags.get("lanes").map(|s| s.parse()).transpose()?.unwrap_or(1);
            // one consistent worker/lane pair drives teacher generation
            // and the gradient fan-out alike — 0 is a config error, not
            // a silent "no parallelism"
            anyhow::ensure!(threads >= 1, "--threads must be >= 1 (got 0)");
            anyhow::ensure!(lanes >= 1, "--lanes must be >= 1 (got 0)");
            let method = flags.get("method").map(|s| s.as_str()).unwrap_or("adam");
            let init = flags
                .get("init")
                .or_else(|| flags.get("from"))
                .map(|s| s.as_str())
                .unwrap_or("auto");
            let rt = Arc::new(Runtime::with_lanes(lanes)?);
            let info = store.model(&model)?.clone();
            // one conditioned source recipe for both optimizers: labels
            // cycle the model's classes, one pair per row; the model is
            // replicated across every device lane so chunked fan-outs
            // (teacher RK45, wavefront gradients) drive all of them
            let make_src = |count: usize| -> Result<bns_serve::distill::ConditionedModel> {
                let labels: Vec<i32> =
                    (0..count).map(|i| (i % info.num_classes) as i32).collect();
                bns_serve::distill::ConditionedModel::replicated(&rt, &info, labels, guidance)
            };

            if method == "spsa" {
                let init_solver = if init.contains("_nfe") {
                    store.solver(init)?.solver.clone()
                } else {
                    bns_serve::solver::taxonomy::init_ns(init, nfe)?
                };
                let src = make_src(pairs)?;
                let cfg = bns_serve::distill::RefineConfig {
                    iters,
                    pairs,
                    batch,
                    seed,
                    threads,
                    ..Default::default()
                };
                println!("refining {model} w={guidance} nfe={nfe} for {iters} SPSA iters...");
                let (refined, report) =
                    bns_serve::distill::refine_with(&src, &init_solver, info.dim, &cfg)?;
                println!(
                    "psnr: {:.2} -> {:.2} dB  (nfe spent: {})",
                    report.initial_psnr, report.final_psnr, report.nfe_spent
                );
                let meta = bns_serve::solver::ns::SolverMeta {
                    kind: "bns".into(),
                    model: model.clone(),
                    guidance: guidance as f64,
                    sigma0: 1.0,
                    init: init.to_string(),
                    val_psnr: report.final_psnr,
                    init_val_psnr: report.initial_psnr,
                    iters: report.iters as u64,
                    forwards: report.nfe_spent as u64,
                    gt_nfe: report.gt_nfe,
                };
                finish_distill(&store, flags, &model, guidance, refined.nfe(), &refined, &meta)?;
                return Ok(());
            }

            // first-order path: teacher + minibatches conditioned per row
            let src = make_src(pairs + val_pairs)?;
            let cfg = bns_serve::distill::TrainConfig {
                iters,
                pairs,
                val_pairs,
                batch,
                lr,
                seed,
                threads,
                init: init.to_string(),
                teacher_cache: flags.get("teacher-cache").map(std::path::PathBuf::from),
                teacher_scope: format!("{model}|w={guidance}"),
                ..Default::default()
            };
            println!(
                "distilling {model} w={guidance} nfe={nfe}: {iters} Adam iters, \
                 {pairs}+{val_pairs} teacher pairs, init={init}, {} lane(s), {threads} thread(s)...",
                rt.num_lanes()
            );
            let t0 = std::time::Instant::now();
            let (solver, report) = if init.contains("_nfe") {
                let art = store.solver(init)?.clone();
                bns_serve::distill::train_from(&src, info.dim, &art.solver, &art.name, &cfg)?
            } else {
                bns_serve::distill::train(&src, info.dim, nfe, &cfg)?
            };
            let secs = t0.elapsed().as_secs_f64();
            println!(
                "val psnr: {:.2} -> {:.2} dB  ({} iters in {:.1}s = {:.1} iters/s; \
                 forwards {}, teacher nfe/traj {})",
                report.init_val_psnr,
                report.final_val_psnr,
                report.iters,
                secs,
                report.iters as f64 / secs.max(1e-9),
                report.forwards,
                report.gt_nfe
            );
            let meta = report.meta(&model, guidance as f64);
            // name by the solver's actual NFE (an artifact init may
            // differ from --nfe)
            finish_distill(&store, flags, &model, guidance, solver.nfe(), &solver, &meta)?;
            Ok(())
        }
        "solvers" => {
            let store = load_store(flags)?;
            println!(
                "{:<40} {:>5} {:>5} {:>7} {:>10} {:>10}",
                "name", "kind", "nfe", "w", "val_psnr", "params"
            );
            for s in store.solvers.values() {
                println!(
                    "{:<40} {:>5} {:>5} {:>7.2} {:>10.2} {:>10}",
                    s.name,
                    s.meta.kind,
                    s.solver.nfe(),
                    s.meta.guidance,
                    s.meta.val_psnr,
                    s.solver.num_params()
                );
            }
            Ok(())
        }
        "models" => {
            let store = load_store(flags)?;
            for (name, m) in &store.models {
                println!(
                    "{:<20} dim={:<5} scheduler={:<7} param={:<9} buckets={:?}",
                    name,
                    m.dim,
                    m.scheduler.name(),
                    format!("{:?}", m.parametrization),
                    m.buckets.iter().map(|b| b.batch).collect::<Vec<_>>()
                );
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}
