//! bns-lint CLI: gate the repo's static invariant catalog (DESIGN.md §10).
//!
//! Usage:
//!   bns_lint [--root <repo-root>] [--max-pragmas <n>] [--json]
//!
//! Exit status: 0 when the tree is clean (and, if a budget applies, the
//! pragma count is within it); 1 on any violation; 2 on usage/IO errors.
//!
//! Without `--root`, the repo root is found by walking up from
//! `CARGO_MANIFEST_DIR` (when run via `cargo run`) or from the current
//! directory. `--max-pragmas` overrides the checked-in
//! `rust/src/analysis/pragma_budget`; ci.sh passes it under STRICT=1 so
//! the allowlist can only shrink PR-over-PR.

use std::path::PathBuf;
use std::process::ExitCode;

use bns_serve::analysis;

struct Opts {
    root: Option<PathBuf>,
    max_pragmas: Option<usize>,
    json: bool,
}

fn parse_args() -> Result<Opts, String> {
    let mut opts = Opts {
        root: None,
        max_pragmas: None,
        json: false,
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => {
                let v = args.next().ok_or("--root needs a path")?;
                opts.root = Some(PathBuf::from(v));
            }
            "--max-pragmas" => {
                let v = args.next().ok_or("--max-pragmas needs a count")?;
                let n: usize = v
                    .parse()
                    .map_err(|_| format!("--max-pragmas: not a count: {v}"))?;
                opts.max_pragmas = Some(n);
            }
            "--json" => opts.json = true,
            other => return Err(format!("unknown flag: {other}")),
        }
    }
    Ok(opts)
}

fn main() -> ExitCode {
    let opts = match parse_args() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("bns-lint: {e}");
            eprintln!("usage: bns_lint [--root <repo-root>] [--max-pragmas <n>] [--json]");
            return ExitCode::from(2);
        }
    };
    let root = opts.root.or_else(|| {
        let start = std::env::var_os("CARGO_MANIFEST_DIR")
            .map(PathBuf::from)
            .or_else(|| std::env::current_dir().ok())?;
        analysis::find_root(&start)
    });
    let Some(root) = root else {
        eprintln!("bns-lint: could not locate the repo root (try --root)");
        return ExitCode::from(2);
    };
    let report = match analysis::run(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("bns-lint: {e:#}");
            return ExitCode::from(2);
        }
    };
    let budget = opts.max_pragmas.or_else(|| analysis::pragma_budget(&root));
    let over_budget = budget.map_or(false, |b| report.pragmas > b);

    if opts.json {
        print_json(&report, budget);
    } else {
        print_text(&report, budget, over_budget);
    }
    if report.violations.is_empty() && !over_budget {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

fn print_text(report: &analysis::LintReport, budget: Option<usize>, over_budget: bool) {
    for v in &report.violations {
        if v.line > 0 {
            println!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
        } else {
            println!("{}: [{}] {}", v.file, v.rule, v.msg);
        }
    }
    let summary: Vec<String> = report
        .counts()
        .iter()
        .map(|(r, n)| format!("{r}={n}"))
        .collect();
    println!(
        "bns-lint: {} file(s), {} violation(s) [{}], {} pragma(s){}",
        report.files_scanned,
        report.violations.len(),
        summary.join(" "),
        report.pragmas,
        match budget {
            Some(b) => format!(" (budget {b})"),
            None => String::new(),
        }
    );
    if over_budget {
        println!(
            "bns-lint: pragma budget exceeded: {} > {} (shrink the allowlist or justify raising rust/src/analysis/pragma_budget)",
            report.pragmas,
            budget.unwrap_or(0)
        );
    }
}

fn print_json(report: &analysis::LintReport, budget: Option<usize>) {
    // Tiny hand-rolled emitter; the violation fields are all simple.
    let mut out = String::from("{\"violations\":[");
    for (i, v) in report.violations.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"file\":\"{}\",\"line\":{},\"rule\":\"{}\",\"msg\":\"{}\"}}",
            escape(&v.file),
            v.line,
            v.rule,
            escape(&v.msg)
        ));
    }
    out.push_str(&format!(
        "],\"pragmas\":{},\"files_scanned\":{},\"budget\":{}}}",
        report.pragmas,
        report.files_scanned,
        match budget {
            Some(b) => b.to_string(),
            None => "null".to_string(),
        }
    ));
    println!("{out}");
}

fn escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => vec!['\\', '"'],
            '\\' => vec!['\\', '\\'],
            '\n' => vec!['\\', 'n'],
            c => vec![c],
        })
        .collect()
}
