//! The first-order BNS trainer: Adam over the shared theta space, driven
//! by the exact analytic gradients of `distill::grad` — the rust-native
//! counterpart of the python build-time trainer (Algorithm 2), closing
//! the train → artifact → serve loop without python.
//!
//! Per run: taxonomy-based initialization (§3.1, `taxonomy::init_ns`),
//! a cached teacher-trajectory set (`distill::teacher`, thread-fanned
//! RK45 through the deployed field), shuffled minibatches with per-row
//! conditioning (`DistillField::bind_rows`), held-out validation-PSNR
//! tracking with best-checkpoint selection, and a report carrying the
//! full `SolverMeta` provenance for artifact emission
//! (`NsSolver::to_json_with_meta`).
//!
//! The inner loop is the wavefront gradient engine (`GradFan`,
//! DESIGN.md §8): minibatch rows fan over `cfg.threads` workers in fixed
//! chunks — the *same* `threads` that fans teacher generation — every
//! buffer (minibatch indices/rows, the candidate solver, the gradient
//! tapes, the theta chain rule, Adam moments) is reused across
//! iterations, so a steady-state Adam step performs zero hot-loop heap
//! allocation; only the periodic validation pass allocates.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::distill::adam::Adam;
use crate::distill::grad::{sample_loss, GradFan};
use crate::distill::teacher::{sample_indices_into, DistillField, TeacherSet};
use crate::distill::theta::{pack, unpack, unpack_into, ThetaGrad};
use crate::solver::field::Field;
use crate::solver::ns::{NsSolver, SolverMeta};
use crate::solver::taxonomy::init_ns;
use crate::util::rng::Pcg32;
use crate::util::stats::psnr_from_log_mse;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub iters: usize,
    /// Training pairs (the teacher set holds `pairs + val_pairs`).
    pub pairs: usize,
    /// Held-out pairs for validation-PSNR tracking / checkpointing.
    pub val_pairs: usize,
    pub batch: usize,
    pub lr: f64,
    pub seed: u64,
    /// Worker fan-out for teacher generation *and* the gradient engine
    /// (one consistent knob; must be ≥ 1). Fixed-size chunking keeps
    /// teacher pairs and gradients bit-identical for any value.
    pub threads: usize,
    /// Taxonomy init: euler | midpoint | rk4 | auto (§3.1).
    pub init: String,
    /// Validate (and maybe checkpoint) every this many iterations.
    pub val_every: usize,
    /// Optional teacher-set disk cache (reused when
    /// (dim, pairs, seed, teacher_scope) match).
    pub teacher_cache: Option<PathBuf>,
    /// Cache-key component for what the teacher pairs depend on beyond
    /// (dim, pairs, seed) — set to e.g. "model|w=guidance" when caching,
    /// so a cache generated through one field never trains another.
    pub teacher_scope: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            iters: 300,
            pairs: 32,
            val_pairs: 16,
            batch: 16,
            lr: 8e-3,
            seed: 7,
            threads: 1,
            init: "auto".into(),
            val_every: 10,
            teacher_cache: None,
            teacher_scope: String::new(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct TrainReport {
    pub init_name: String,
    pub init_val_psnr: f64,
    pub final_val_psnr: f64,
    pub iters: usize,
    /// Model forward passes spent training (rows × forwards_per_eval,
    /// JVPs accounted at their true [`crate::solver::field::Field::jvp_cost`]:
    /// two evals per tangent under central differences, cheaper for
    /// closed forms).
    pub forwards: u64,
    /// Mean RK45 NFE per teacher trajectory.
    pub gt_nfe: u64,
    /// Total RK45 eval calls spent generating the teacher set.
    pub gt_evals: u64,
    /// (iteration, validation PSNR) trajectory.
    pub history: Vec<(usize, f64)>,
    /// Wall seconds generating (or loading) the teacher set — the
    /// `phase_breakdown` section of BENCH_distill.json.
    pub teacher_gen_s: f64,
    /// Wall seconds in the wavefront gradient fan (`GradFan::compute`).
    pub wavefront_jvp_s: f64,
    /// Wall seconds in the theta chain rule + Adam update.
    pub adam_step_s: f64,
    /// Wall seconds validating / best-checkpointing (incl. the init
    /// validation pass).
    pub checkpoint_s: f64,
}

impl TrainReport {
    /// Full provenance for artifact emission (`to_json_with_meta`).
    pub fn meta(&self, model: &str, guidance: f64) -> SolverMeta {
        SolverMeta {
            kind: "bns".into(),
            model: model.into(),
            guidance,
            sigma0: 1.0,
            init: self.init_name.clone(),
            val_psnr: self.final_val_psnr,
            init_val_psnr: self.init_val_psnr,
            iters: self.iters as u64,
            forwards: self.forwards,
            gt_nfe: self.gt_nfe,
        }
    }
}

/// Distill an NFE-`nfe` solver against `src`, starting from the
/// taxonomy init named in `cfg.init`.
pub fn train(
    src: &dyn DistillField,
    dim: usize,
    nfe: usize,
    cfg: &TrainConfig,
) -> Result<(NsSolver, TrainReport)> {
    let init = init_ns(&cfg.init, nfe)?;
    train_from(src, dim, &init, &cfg.init, cfg)
}

/// Distill starting from an explicit initial solver (e.g. a previously
/// distilled artifact being re-tuned at new serving conditions).
pub fn train_from(
    src: &dyn DistillField,
    dim: usize,
    init: &NsSolver,
    init_name: &str,
    cfg: &TrainConfig,
) -> Result<(NsSolver, TrainReport)> {
    init.validate()?;
    let n = init.nfe();
    anyhow::ensure!(cfg.iters > 0, "iters must be positive");
    anyhow::ensure!(cfg.pairs > 0 && cfg.val_pairs > 0, "need training and validation pairs");
    anyhow::ensure!(cfg.threads >= 1, "threads must be >= 1 (got 0)");
    // with an empty scope the cache key degenerates to (dim, pairs,
    // seed) and pairs generated through a *different* field would be
    // silently reused — refuse rather than train on foreign ground truth
    anyhow::ensure!(
        cfg.teacher_cache.is_none() || !cfg.teacher_scope.is_empty(),
        "teacher_cache requires a non-empty teacher_scope (e.g. \"model|w=guidance\") \
         so cached pairs are never reused across fields"
    );

    // trainer phase spans: coarse wall-clock accumulators surfaced in the
    // report (and from there in BENCH_distill.json's phase_breakdown) —
    // Instant reads only, so the hot loop stays allocation-free
    let mut t_jvp = Duration::ZERO;
    let mut t_adam = Duration::ZERO;
    let mut t_ckpt = Duration::ZERO;

    let total_pairs = cfg.pairs + cfg.val_pairs;
    let t_phase = Instant::now();
    let teacher = TeacherSet::load_or_generate(
        cfg.teacher_cache.as_deref(),
        src,
        dim,
        total_pairs,
        cfg.seed,
        cfg.threads,
        &cfg.teacher_scope,
    )?;
    let teacher_gen = t_phase.elapsed();
    let fpe = src.full().forwards_per_eval() as u64;

    // held-out validation split: the trailing val_pairs rows
    let vidx: Vec<usize> = (cfg.pairs..total_pairs).collect();
    let vfield = src.bind_rows(&vidx)?;
    let (mut vx0, mut vx1) = (Vec::new(), Vec::new());
    teacher.gather(&vidx, &mut vx0, &mut vx1);

    let mut theta = pack(init);
    let mut forwards: u64 = 0;
    let t0 = Instant::now();
    let init_loss = sample_loss(init, &vfield, &vx0, &vx1, dim)?;
    t_ckpt += t0.elapsed();
    forwards += cfg.val_pairs as u64 * fpe * n as u64;
    let init_val_psnr = psnr_from_log_mse(init_loss);

    let mut best = (theta.clone(), init_loss);
    let mut adam = Adam::new(theta.len(), cfg.lr);
    // separate stream from the teacher's noise draws
    let mut rng = Pcg32::seeded(cfg.seed.wrapping_add(0x5eed_1d8a));
    let mut history: Vec<(usize, f64)> = Vec::new();
    let bsz = cfg.batch.min(cfg.pairs).max(1);
    // hot-loop state, allocated once and reused every Adam step: the
    // wavefront gradient fan (chunk slots, workspaces, lane-pinned
    // bindings), the candidate solver, the theta chain rule, and the
    // minibatch index buffer — the loop body below is allocation-free
    // at steady state (measured in benches/distill_bench.rs)
    let mut fan = GradFan::new();
    let mut tgrad = ThetaGrad::new();
    let mut gtheta: Vec<f64> = Vec::new();
    let mut solver_buf = init.clone();
    let mut idx: Vec<usize> = Vec::new();

    for k in 0..cfg.iters {
        sample_indices_into(&mut rng, cfg.pairs, bsz, &mut idx);
        unpack_into(&theta, n, &mut solver_buf);
        let t0 = Instant::now();
        fan.compute(&solver_buf, src, &teacher, &idx, dim, cfg.threads)?;
        t_jvp += t0.elapsed();
        forwards += fpe * fan.row_evals;
        let t0 = Instant::now();
        tgrad.apply(&theta, n, &fan.d_times, &fan.d_a, &fan.d_b, &mut gtheta);
        if gtheta.iter().any(|v| !v.is_finite()) {
            // a pathological minibatch (e.g. clamped loss) must not
            // poison the Adam moments — skip the step, keep training
            t_adam += t0.elapsed();
            continue;
        }
        // linear lr decay to zero: near the optimum Adam at a fixed lr
        // orbits at step-size radius instead of settling (the needed
        // coefficient corrections are often smaller than one step);
        // decaying lets the iterates converge, best-checkpointing keeps
        // whatever point validated best along the way
        adam.lr = cfg.lr * (1.0 - k as f64 / cfg.iters as f64);
        adam.step(&mut theta, &gtheta);
        t_adam += t0.elapsed();

        if (cfg.val_every > 0 && (k + 1) % cfg.val_every == 0) || k + 1 == cfg.iters {
            let t0 = Instant::now();
            let cand = unpack(&theta, n);
            if cand.validate().is_ok() {
                let l = sample_loss(&cand, &vfield, &vx0, &vx1, dim)?;
                forwards += cfg.val_pairs as u64 * fpe * n as u64;
                history.push((k + 1, psnr_from_log_mse(l)));
                if l < best.1 {
                    best = (theta.clone(), l);
                }
            }
            t_ckpt += t0.elapsed();
        }
    }

    let solver = unpack(&best.0, n);
    solver.validate()?;
    let report = TrainReport {
        init_name: init_name.to_string(),
        init_val_psnr,
        final_val_psnr: psnr_from_log_mse(best.1),
        iters: cfg.iters,
        forwards,
        gt_nfe: teacher.gt_nfe,
        gt_evals: teacher.gt_evals,
        history,
        teacher_gen_s: teacher_gen.as_secs_f64(),
        wavefront_jvp_s: t_jvp.as_secs_f64(),
        adam_step_s: t_adam.as_secs_f64(),
        checkpoint_s: t_ckpt.as_secs_f64(),
    };
    Ok((solver, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distill::spsa::{refine, RefineConfig};
    use crate::distill::teacher::UniformField;
    use crate::solver::field::GaussianTargetField;
    use crate::solver::scheduler::Scheduler;

    fn field() -> GaussianTargetField {
        GaussianTargetField { dim: 4, sched: Scheduler::FmOt, mu: 0.4, s1: 0.3 }
    }

    /// The acceptance gate: the distilled NFE=8 solver beats its
    /// midpoint taxonomy init by ≥ 2 dB validation PSNR, and beats the
    /// zeroth-order SPSA refiner given a larger iteration budget, both
    /// measured on a common fresh ground-truth evaluation set.
    #[test]
    fn distilled_nfe8_beats_midpoint_init_and_spsa() {
        let f = field();
        let src = UniformField(&f);
        let cfg = TrainConfig {
            iters: 400,
            pairs: 32,
            val_pairs: 12,
            batch: 12,
            init: "midpoint".into(),
            ..Default::default()
        };
        let (solver, report) = train(&src, 4, 8, &cfg).unwrap();
        solver.validate().unwrap();
        assert_eq!(solver.nfe(), 8);
        assert!(
            report.final_val_psnr >= report.init_val_psnr + 2.0,
            "trainer gained only {:.2} dB ({:.2} -> {:.2})",
            report.final_val_psnr - report.init_val_psnr,
            report.init_val_psnr,
            report.final_val_psnr
        );
        assert!(!report.history.is_empty());
        assert!(report.forwards > 0 && report.gt_nfe > 0);

        // SPSA from the same init at an *equal NFE budget*: convert the
        // trainer's row-forwards into SPSA iterations (each SPSA iter
        // spends 2·nfe evals on `batch` rows)
        let spsa_iters =
            ((report.forwards as usize) / (2 * 8 * 12)).clamp(1000, 10_000);
        let init = crate::solver::taxonomy::init_ns("midpoint", 8).unwrap();
        let scfg =
            RefineConfig { iters: spsa_iters, pairs: 32, batch: 12, ..Default::default() };
        let (spsa_solver, _) = refine(&init, &f, 4, &scfg).unwrap();

        // common fresh eval set (seed disjoint from both training runs)
        let eval = TeacherSet::generate(&src, 4, 24, 999, 1).unwrap();
        let l_adam = sample_loss(&solver, &f, &eval.x0, &eval.x1, 4).unwrap();
        let l_spsa = sample_loss(&spsa_solver, &f, &eval.x0, &eval.x1, 4).unwrap();
        assert!(
            psnr_from_log_mse(l_adam) > psnr_from_log_mse(l_spsa),
            "first-order {:.2} dB must beat SPSA {:.2} dB",
            psnr_from_log_mse(l_adam),
            psnr_from_log_mse(l_spsa)
        );
    }

    /// Best-checkpoint selection: the returned solver can never be worse
    /// on the validation split than the init it started from.
    #[test]
    fn never_worse_than_init_on_validation() {
        let f = field();
        let src = UniformField(&f);
        // absurd lr: steps diverge, but the best checkpoint (possibly
        // the init itself) is returned
        let cfg = TrainConfig {
            iters: 30,
            pairs: 8,
            val_pairs: 6,
            batch: 8,
            lr: 10.0,
            init: "euler".into(),
            ..Default::default()
        };
        let (solver, report) = train(&src, 4, 4, &cfg).unwrap();
        solver.validate().unwrap();
        assert!(
            report.final_val_psnr >= report.init_val_psnr - 1e-9,
            "{} < {}",
            report.final_val_psnr,
            report.init_val_psnr
        );
    }

    #[test]
    fn teacher_cache_is_reused() {
        let f = field();
        let src = UniformField(&f);
        let path = std::env::temp_dir()
            .join(format!("bns-trainer-cache-{}.json", std::process::id()));
        std::fs::remove_file(&path).ok();
        let cfg = TrainConfig {
            iters: 5,
            pairs: 6,
            val_pairs: 4,
            batch: 6,
            teacher_cache: Some(path.clone()),
            teacher_scope: "gauss-test|w=0".into(),
            init: "euler".into(),
            ..Default::default()
        };
        // caching without a scope is refused (cross-field reuse hazard)
        let mut bad = cfg.clone();
        bad.teacher_scope = String::new();
        assert!(train(&src, 4, 4, &bad).is_err());
        let (_, r1) = train(&src, 4, 4, &cfg).unwrap();
        assert!(path.exists(), "cache file must be written");
        let (_, r2) = train(&src, 4, 4, &cfg).unwrap();
        // identical teacher set (cached) -> identical deterministic run
        assert_eq!(r1.final_val_psnr.to_bits(), r2.final_val_psnr.to_bits());
        assert_eq!(r1.gt_nfe, r2.gt_nfe);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn report_meta_carries_provenance() {
        let f = field();
        let src = UniformField(&f);
        let cfg = TrainConfig {
            iters: 5,
            pairs: 6,
            val_pairs: 4,
            batch: 6,
            init: "euler".into(),
            ..Default::default()
        };
        let (_, report) = train(&src, 4, 4, &cfg).unwrap();
        let meta = report.meta("img_fm_ot", 0.5);
        assert_eq!(meta.kind, "bns");
        assert_eq!(meta.model, "img_fm_ot");
        assert_eq!(meta.guidance, 0.5);
        assert_eq!(meta.init, "euler");
        assert_eq!(meta.iters, 5);
        assert_eq!(meta.forwards, report.forwards);
        assert_eq!(meta.gt_nfe, report.gt_nfe);
        assert!((meta.val_psnr - report.final_val_psnr).abs() < 1e-12);
        // phase spans: every phase ran, none is negative
        assert!(report.teacher_gen_s > 0.0, "teacher phase timed");
        assert!(report.wavefront_jvp_s > 0.0, "JVP phase timed");
        assert!(report.adam_step_s > 0.0, "Adam phase timed");
        assert!(report.checkpoint_s > 0.0, "checkpoint phase timed");
    }
}
