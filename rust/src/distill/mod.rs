//! Rust-native BNS solver distillation: optimize eq. 12's <200-parameter
//! non-stationary solver against an RK45 teacher through the *deployed*
//! field — no python required, closing the train → artifact → serve loop
//! on the serving side.
//!
//! Why this exists: Algorithm 2 runs at build time, but a deployed
//! service meets conditions the build never saw — a new guidance scale,
//! a drifting input distribution, an NFE the build didn't distill.
//! Module map:
//!
//! * `theta`   — the shared eq. 12 reparameterization (log-increment
//!   times with pinned endpoints) + its exact chain rule;
//! * `teacher` — the teacher-trajectory store: RK45 ground-truth pairs
//!   generated once (thread-fanned in fixed chunks, bit-identical for
//!   any thread count), disk-cached, with per-row conditioning
//!   (`DistillField`) and the shared unbiased minibatch sampler;
//! * `grad`    — exact first-order gradients of the eq. 13 log-MSE loss
//!   through Algorithm 1, computed as a step-major *wavefront*: all
//!   parameter tangents share the recorded base points, so each step
//!   pushes every live tangent through the field in one batched
//!   `Field::jvp_batch_into` dispatch (O(n) device round trips per
//!   minibatch instead of O(n³); JVPs only — compiled executables have
//!   no transpose). `GradWorkspace` keeps the tapes allocation-free;
//!   `GradFan` fans minibatch chunks across threads and device lanes
//!   with bit-identical results for any thread count;
//! * `adam`    — the Adam optimizer substrate;
//! * `trainer` — the first-order training loop: taxonomy init (§3.1),
//!   validation-PSNR best-checkpoint selection, `SolverMeta` provenance;
//! * `spsa`    — the zeroth-order (gradient-free) refiner, kept for
//!   fields where JVPs are impractical; shares theta, teacher pairs and
//!   minibatching with the trainer.
//!
//! Both optimizers emit solvers in the same JSON artifact format the
//! build-time trainer uses (`NsSolver::to_json_with_meta`), so they load
//! and route like any python-distilled solver. DESIGN.md §7 has the
//! system-level walkthrough.

pub mod adam;
pub mod grad;
pub mod spsa;
pub mod teacher;
pub mod theta;
pub mod trainer;

pub use adam::Adam;
pub use grad::{
    log_mse_loss, loss_and_grad, sample_loss, GradFan, GradWorkspace, LossGrad, GRAD_CHUNK,
};
pub use spsa::{refine, refine_with, RefineConfig, RefineReport};
pub use teacher::{
    sample_indices, sample_indices_into, BoundField, ConditionedModel, DistillField, TeacherSet,
    UniformField,
};
pub use trainer::{train, train_from, TrainConfig, TrainReport};
