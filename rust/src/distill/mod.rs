//! Request-path solver refinement: adapt BNS coefficients **in rust**,
//! no Python required.
//!
//! Why this exists: Algorithm 2 runs at build time, but a deployed
//! service meets conditions the build never saw — a new guidance scale,
//! a drifting input distribution, an NFE the build didn't distill. This
//! module closes the loop on the serving side: generate a small set of
//! RK45 ground-truth pairs through the *deployed* PJRT field, then
//! refine an NS solver's theta against the paper's PSNR loss (eq. 13)
//! with SPSA (simultaneous-perturbation stochastic approximation) —
//! gradient-free, so it works through the compiled executable where
//! autodiff is unavailable.
//!
//! This is deliberately the same parameter space as eq. 12 (the rust
//! mirror of theta), so refined solvers serialize to the same JSON
//! artifacts and route like any build-time BNS solver.

pub mod spsa;

pub use spsa::{refine, RefineConfig, RefineReport};
