//! The shared theta reparameterization of eq. 12 with pinned endpoints —
//! one parameter space for every rust-side optimizer (the first-order
//! Adam trainer and the zeroth-order SPSA refiner), mirroring the python
//! trainer so solvers stay valid by construction:
//!
//!   theta = [ log-increments z_0..z_{n-1} | a_0..a_{n-1} | b rows ]
//!
//! Times are recovered by normalizing the positive increments e^{z_k}
//! to sum to one (softmax-style), so `times` is always strictly
//! increasing with T_0 = 0 and T_n = 1. `a` and `b` map through
//! unchanged. `grad_to_theta` is the exact chain rule of `unpack`,
//! used by the analytic trainer to pull solver-space gradients back
//! into theta space.

use crate::solver::ns::NsSolver;

/// Parameters in theta for an NFE-n solver: n increments + n a's +
/// n(n+1)/2 b entries.
pub fn theta_len(n: usize) -> usize {
    2 * n + n * (n + 1) / 2
}

pub fn pack(solver: &NsSolver) -> Vec<f64> {
    let n = solver.nfe();
    let mut theta = Vec::with_capacity(theta_len(n));
    for w in solver.times.windows(2) {
        theta.push((w[1] - w[0]).max(1e-9).ln());
    }
    theta.extend_from_slice(&solver.a);
    for row in &solver.b {
        theta.extend_from_slice(row);
    }
    theta
}

pub fn unpack(theta: &[f64], n: usize) -> NsSolver {
    let mut solver = NsSolver { times: Vec::new(), a: Vec::new(), b: Vec::new() };
    unpack_into(theta, n, &mut solver);
    solver
}

/// `unpack` into a reused solver — the trainer's hot loop rebuilds the
/// candidate solver every Adam step, and this keeps that rebuild free of
/// heap allocation at steady state (times/a/b rows only ever reuse their
/// capacity). Identical arithmetic to `unpack`.
pub fn unpack_into(theta: &[f64], n: usize, solver: &mut NsSolver) {
    debug_assert_eq!(theta.len(), theta_len(n));
    let total: f64 = theta[..n].iter().map(|z| z.exp()).sum();
    solver.times.clear();
    solver.times.push(0.0);
    let mut acc = 0.0;
    for z in &theta[..n] {
        acc += z.exp() / total;
        solver.times.push(acc.min(1.0));
    }
    solver.times[n] = 1.0;
    solver.a.clear();
    solver.a.extend_from_slice(&theta[n..2 * n]);
    solver.b.truncate(n);
    while solver.b.len() < n {
        solver.b.push(Vec::new());
    }
    let mut off = 2 * n;
    for (i, row) in solver.b.iter_mut().enumerate() {
        row.clear();
        row.extend_from_slice(&theta[off..off + i + 1]);
        off += i + 1;
    }
}

/// Chain rule of `unpack`: map a gradient in solver space — `d_times`
/// over `times[0..=n]` (endpoints pinned, so entries 0 and n are
/// ignored), `d_a`, and the lower-triangular `d_b` — into theta space.
///
/// With w_k = e^{z_k}, S = Σ w and T_i = (Σ_{k<i} w_k)/S:
///   ∂T_i/∂z_m = w_m · (1[m < i] − T_i) / S,
/// and a/b pass through unchanged.
pub fn grad_to_theta(
    theta: &[f64],
    n: usize,
    d_times: &[f64],
    d_a: &[f64],
    d_b: &[Vec<f64>],
) -> Vec<f64> {
    let mut flat = Vec::with_capacity(n * (n + 1) / 2);
    for row in d_b {
        flat.extend_from_slice(row);
    }
    let mut scratch = ThetaGrad::new();
    let mut g = Vec::new();
    scratch.apply(theta, n, d_times, d_a, &flat, &mut g);
    g
}

/// Reusable scratch for the allocation-free chain rule: the trainer's
/// hot loop calls [`ThetaGrad::apply`] once per Adam step, and after the
/// first step nothing here touches the heap. `d_b_flat` is the
/// lower-triangular `d_b` with rows concatenated (row i at offset
/// i·(i+1)/2) — the layout the wavefront gradient engine produces.
#[derive(Default)]
pub struct ThetaGrad {
    /// [w_0..w_{n-1} | T_0..T_n] — the softmax weights and times of
    /// `unpack` needed by the time-increment Jacobian.
    wts: Vec<f64>,
}

impl ThetaGrad {
    pub fn new() -> Self {
        Self::default()
    }

    /// Same arithmetic as the original `grad_to_theta`, writing into a
    /// reused `out` buffer.
    pub fn apply(
        &mut self,
        theta: &[f64],
        n: usize,
        d_times: &[f64],
        d_a: &[f64],
        d_b_flat: &[f64],
        out: &mut Vec<f64>,
    ) {
        debug_assert_eq!(theta.len(), theta_len(n));
        debug_assert_eq!(d_times.len(), n + 1);
        debug_assert_eq!(d_a.len(), n);
        debug_assert_eq!(d_b_flat.len(), n * (n + 1) / 2);
        self.wts.clear();
        self.wts.resize(2 * n + 1, 0.0);
        let (w, ts) = self.wts.split_at_mut(n);
        for (wi, z) in w.iter_mut().zip(theta[..n].iter()) {
            *wi = z.exp();
        }
        let total: f64 = w.iter().sum();
        let mut acc = 0.0;
        for i in 0..n {
            acc += w[i] / total;
            ts[i + 1] = acc.min(1.0);
        }
        out.clear();
        out.resize(theta.len(), 0.0);
        for (m, gm) in out.iter_mut().enumerate().take(n) {
            let mut s = 0.0;
            for i in 1..n {
                // T_n is pinned to 1 by unpack; its derivative is zero.
                let ind = if m < i { 1.0 } else { 0.0 };
                s += d_times[i] * w[m] * (ind - ts[i]) / total;
            }
            *gm = s;
        }
        out[n..2 * n].copy_from_slice(d_a);
        out[2 * n..].copy_from_slice(d_b_flat);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::taxonomy::euler_ns;

    #[test]
    fn pack_unpack_roundtrip() {
        let s = euler_ns(&[0.0, 0.2, 0.55, 1.0]);
        let theta = pack(&s);
        assert_eq!(theta.len(), theta_len(3));
        let s2 = unpack(&theta, 3);
        for (a, b) in s.times.iter().zip(&s2.times) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        assert_eq!(s.a, s2.a);
        assert_eq!(s.b, s2.b);
    }

    #[test]
    fn unpack_always_valid() {
        // arbitrary theta (including extreme increments) must give a
        // valid solver: strictly increasing times, pinned endpoints
        let n = 5;
        let mut theta = vec![0.0; theta_len(n)];
        for (i, t) in theta.iter_mut().enumerate() {
            *t = ((i * 37 % 17) as f64 - 8.0) * 0.5;
        }
        unpack(&theta, n).validate().unwrap();
    }

    /// The time part of `grad_to_theta` is the exact Jacobian of the
    /// times produced by `unpack` (checked against central differences).
    #[test]
    fn time_chain_rule_matches_finite_differences() {
        let n = 4;
        let s = euler_ns(&[0.0, 0.1, 0.35, 0.7, 1.0]);
        let theta = pack(&s);
        // probe dL/dz for the synthetic loss L = Σ_i c_i · T_i
        let c = [0.0, 0.3, -0.7, 1.1, 0.0];
        let d_a = vec![0.0; n];
        let d_b: Vec<Vec<f64>> = (0..n).map(|i| vec![0.0; i + 1]).collect();
        let g = grad_to_theta(&theta, n, &c, &d_a, &d_b);
        let h = 1e-6;
        for m in 0..n {
            let mut tp = theta.clone();
            tp[m] += h;
            let mut tm = theta.clone();
            tm[m] -= h;
            let lp: f64 =
                unpack(&tp, n).times.iter().zip(&c).map(|(t, ci)| t * ci).sum();
            let lm: f64 =
                unpack(&tm, n).times.iter().zip(&c).map(|(t, ci)| t * ci).sum();
            let fd = (lp - lm) / (2.0 * h);
            assert!((g[m] - fd).abs() < 1e-6, "z_{m}: {} vs {}", g[m], fd);
        }
    }

    #[test]
    fn a_and_b_pass_through() {
        let n = 3;
        let s = euler_ns(&[0.0, 0.4, 0.8, 1.0]);
        let theta = pack(&s);
        let d_times = vec![0.0; n + 1];
        let d_a = vec![1.0, 2.0, 3.0];
        let d_b = vec![vec![4.0], vec![5.0, 6.0], vec![7.0, 8.0, 9.0]];
        let g = grad_to_theta(&theta, n, &d_times, &d_a, &d_b);
        assert_eq!(&g[n..2 * n], &d_a[..]);
        assert_eq!(&g[2 * n..], &[4.0, 5.0, 6.0, 7.0, 8.0, 9.0]);
    }
}
