//! Exact first-order gradients of the eq. 13 log-MSE loss through
//! Algorithm 1 — the analytic core of the native BNS trainer.
//!
//! Algorithm 1 is the lower-triangular recursion
//!   x_{i+1} = a_i·x0 + Σ_{j≤i} b_ij·u_j,   u_j = u(t_j, x_j),
//! so every parameter reaches the loss along two kinds of path: the
//! *direct* linear path through its own combine row, and the
//! *field-mediated* paths where moving x_k moves every later velocity
//! u_k, u_{k+1}, … . The reverse part — the per-sample loss adjoint
//! λ = ∂loss/∂x_n and the closed-form direct terms — costs nothing; the
//! field-mediated part is computed by exact tangent (forward-sensitivity)
//! propagation: for each parameter, inject its seed tangent at its
//! combine row and push it through the remaining steps with one
//! [`Field::jvp`] per step, which also carries the time-grid gradients
//! via the `dt` tangent. Only JVPs are required — never a transposed
//! field Jacobian, which a compiled (PJRT/stub) executable cannot
//! provide — and the result is exact up to the field's own `jvp`
//! accuracy (closed form for the analytic fields, central differences —
//! exact on the affine stub fields — otherwise).
//!
//! Cost: O(n²) tangent propagations of ≤ n JVP calls each (n = NFE),
//! ~n³/6 batched JVPs per minibatch — negligible against the teacher
//! RK45 cost for the paper's n ≤ 16 regime.

use anyhow::Result;

use crate::solver::field::Field;
use crate::solver::ns::NsSolver;

/// Loss plus the full solver-space gradient for one minibatch.
pub struct LossGrad {
    /// eq. 13: mean over samples of ln(per-sample MSE).
    pub loss: f64,
    /// ∂loss/∂times over `times[0..=n]`; the pinned endpoints (0 and n)
    /// are identically zero.
    pub d_times: Vec<f64>,
    pub d_a: Vec<f64>,
    /// Lower-triangular, same shape as `NsSolver::b`.
    pub d_b: Vec<Vec<f64>>,
    /// `Field::jvp` calls made (each costs two evals under the default
    /// central-difference implementation — the accounting upper bound).
    pub jvp_calls: usize,
}

/// eq. 13 training loss: mean over samples of the log of the per-sample
/// MSE between `out` and the teacher endpoint `x1`.
pub fn log_mse_loss(out: &[f32], x1: &[f32], dim: usize) -> f64 {
    debug_assert_eq!(out.len(), x1.len());
    let samples = out.len() / dim;
    let mut acc = 0.0;
    for s in 0..samples {
        let mse: f64 = out[s * dim..(s + 1) * dim]
            .iter()
            .zip(&x1[s * dim..(s + 1) * dim])
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / dim as f64;
        // NaN guard: f64::max(NaN, eps) returns eps, which would make a
        // diverged solver (inf - inf in the f32 combine) look like the
        // best loss ever seen — score it as the worst instead
        acc += if mse.is_nan() { f64::INFINITY } else { mse.max(1e-20).ln() };
    }
    acc / samples as f64
}

/// Sample with `solver` and return the eq. 13 loss (no gradient) — the
/// validation/SPSA evaluation path.
pub fn sample_loss(
    solver: &NsSolver,
    field: &dyn Field,
    x0: &[f32],
    x1: &[f32],
    dim: usize,
) -> Result<f64> {
    let out = solver.sample(field, x0)?;
    Ok(log_mse_loss(&out, x1, dim))
}

/// One tangent propagation through the recorded trajectory.
///
/// The tangent is injected either as δx_{start} = `seed` (the derivative
/// of the combine row `start-1` w.r.t. its own a/b entry), or — when
/// `time_step` is set — as a pure time tangent δt = 1 at that step's
/// velocity eval. Returns λ·δx_n and counts the JVPs spent.
fn propagate(
    solver: &NsSolver,
    field: &dyn Field,
    xs: &[Vec<f32>],
    lambda: &[f64],
    start: usize,
    seed: Option<&[f32]>,
    time_step: Option<usize>,
    jvp_calls: &mut usize,
) -> Result<f64> {
    let n = solver.nfe();
    let len = lambda.len();
    debug_assert!(seed.is_some() != time_step.is_some());
    let first = time_step.unwrap_or(start);
    // δu_j for j in [first, n); None = identically zero
    let mut dus: Vec<Option<Vec<f32>>> = vec![None; n];
    let mut dx = vec![0f32; len];
    let mut dx_nonzero = false;
    for k in first..=n {
        // δx_k = [seed if k == start] + Σ_{j<k} b_{k-1,j}·δu_j
        if k > first || time_step.is_none() {
            dx.fill(0.0);
            dx_nonzero = false;
            if seed.is_some() && k == start {
                dx.copy_from_slice(seed.unwrap());
                dx_nonzero = true;
            }
            if k > first {
                for (j, &bj) in solver.b[k - 1].iter().enumerate() {
                    if let Some(du) = dus[j].as_ref() {
                        let bj = bj as f32;
                        if bj == 0.0 {
                            continue;
                        }
                        for (o, &d) in dx.iter_mut().zip(du.iter()) {
                            *o += bj * d;
                        }
                        dx_nonzero = true;
                    }
                }
            }
        }
        if k == n {
            break;
        }
        // δu_k = J_k·δx_k + ∂u/∂t·δt_k
        let dt = if time_step == Some(k) { 1.0 } else { 0.0 };
        if dx_nonzero || dt != 0.0 {
            dus[k] = Some(field.jvp(solver.times[k], &xs[k], &dx, dt)?);
            *jvp_calls += 1;
        }
    }
    Ok(lambda.iter().zip(dx.iter()).map(|(&l, &d)| l * d as f64).sum())
}

/// Loss and exact ∂loss/∂(times, a, b) for one minibatch of teacher
/// pairs (`x0`, `x1`, row-major `[samples, dim]`).
pub fn loss_and_grad(
    solver: &NsSolver,
    field: &dyn Field,
    x0: &[f32],
    x1: &[f32],
    dim: usize,
) -> Result<LossGrad> {
    let n = solver.nfe();
    let len = x0.len();
    let samples = len / dim;
    anyhow::ensure!(samples > 0 && len == samples * dim, "x0 must be [samples, dim]");
    anyhow::ensure!(x1.len() == len, "x1 must match x0");

    // forward, recording the trajectory and velocities (same op order as
    // `sample`, so the loss here equals the loss of the sampled output)
    let mut xs: Vec<Vec<f32>> = Vec::with_capacity(n + 1);
    xs.push(x0.to_vec());
    let mut us: Vec<Vec<f32>> = Vec::with_capacity(n);
    for i in 0..n {
        us.push(field.eval(solver.times[i], &xs[i])?);
        let a = solver.a[i] as f32;
        let mut next: Vec<f32> = x0.iter().map(|&v| a * v).collect();
        for (j, &bj) in solver.b[i].iter().enumerate() {
            let bj = bj as f32;
            if bj == 0.0 {
                continue;
            }
            for (o, &uv) in next.iter_mut().zip(us[j].iter()) {
                *o += bj * uv;
            }
        }
        xs.push(next);
    }

    // loss + adjoint λ = ∂loss/∂x_n (f64 per element)
    let xn = &xs[n];
    let mut loss = 0.0;
    let mut lambda = vec![0f64; len];
    for s in 0..samples {
        let mut mse = 0.0;
        for k in 0..dim {
            let d = (xn[s * dim + k] - x1[s * dim + k]) as f64;
            mse += d * d;
        }
        mse /= dim as f64;
        // NaN scores as the worst loss (see log_mse_loss), never the best
        loss += if mse.is_nan() { f64::INFINITY } else { mse.max(1e-20).ln() };
        // in the clamp region (and for non-finite mse) the loss is
        // treated as flat: adjoint is zero there
        let c = if mse.is_finite() && mse > 1e-20 {
            2.0 / (samples as f64 * dim as f64 * mse)
        } else {
            0.0
        };
        for k in 0..dim {
            lambda[s * dim + k] = c * (xn[s * dim + k] - x1[s * dim + k]) as f64;
        }
    }
    loss /= samples as f64;

    let mut jvp_calls = 0usize;
    let mut d_a = vec![0.0; n];
    let mut d_b: Vec<Vec<f64>> = (0..n).map(|i| vec![0.0; i + 1]).collect();
    let mut d_times = vec![0.0; n + 1];
    for i in 0..n {
        // row i injects into x_{i+1}: seed x0 for a_i, u_j for b_ij
        d_a[i] =
            propagate(solver, field, &xs, &lambda, i + 1, Some(x0), None, &mut jvp_calls)?;
        for j in 0..=i {
            d_b[i][j] = propagate(
                solver,
                field,
                &xs,
                &lambda,
                i + 1,
                Some(&us[j]),
                None,
                &mut jvp_calls,
            )?;
        }
    }
    for (i, d) in d_times.iter_mut().enumerate().take(n).skip(1) {
        // t_0 = 0 is pinned and t_n = 1 is never an eval time
        *d = propagate(solver, field, &xs, &lambda, i, None, Some(i), &mut jvp_calls)?;
    }
    Ok(LossGrad { loss, d_times, d_a, d_b, jvp_calls })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distill::theta::{grad_to_theta, pack, unpack};
    use crate::solver::field::{GaussianTargetField, LinearField, NonlinearField};
    use crate::solver::scheduler::Scheduler;
    use crate::solver::taxonomy::euler_ns;
    use crate::util::rng::Pcg32;

    /// Analytic theta-space gradient vs central finite differences of
    /// the same loss, per parameter — the subsystem's correctness gate.
    fn grad_check(field: &dyn Field, dim: usize, label: &str) {
        let n = 3;
        // non-uniform grid + slightly perturbed coefficients so no
        // parameter sits at a symmetric point
        let mut solver = euler_ns(&[0.0, 0.22, 0.61, 1.0]);
        solver.a[1] = 0.93;
        solver.b[2][0] = 0.07;
        solver.b[1][1] *= 1.1;
        let mut rng = Pcg32::seeded(42);
        let x0 = rng.normal_vec(4 * dim);
        let x1: Vec<f32> = rng.normal_vec(4 * dim).iter().map(|v| v * 0.5).collect();

        let theta = pack(&solver);
        let g = loss_and_grad(&solver, field, &x0, &x1, dim).unwrap();
        let gt = grad_to_theta(&theta, n, &g.d_times, &g.d_a, &g.d_b);
        assert!(g.jvp_calls > 0);

        let h = 1e-3;
        for (m, &gm) in gt.iter().enumerate() {
            let mut tp = theta.clone();
            tp[m] += h;
            let mut tm = theta.clone();
            tm[m] -= h;
            let lp = sample_loss(&unpack(&tp, n), field, &x0, &x1, dim).unwrap();
            let lm = sample_loss(&unpack(&tm, n), field, &x0, &x1, dim).unwrap();
            let fd = (lp - lm) / (2.0 * h);
            let tol = 3e-2 * gm.abs().max(fd.abs()) + 2e-3;
            assert!(
                (gm - fd).abs() <= tol,
                "{label} theta[{m}]: analytic {gm} vs fd {fd}"
            );
        }
    }

    #[test]
    fn gradient_check_linear_field() {
        grad_check(&LinearField { dim: 3, k: -0.8, c: 0.4 }, 3, "linear");
    }

    #[test]
    fn gradient_check_gaussian_target_field() {
        grad_check(
            &GaussianTargetField { dim: 3, sched: Scheduler::FmOt, mu: 0.4, s1: 0.3 },
            3,
            "gaussian",
        );
    }

    #[test]
    fn gradient_check_nonlinear_field() {
        grad_check(&NonlinearField { dim: 2 }, 2, "nonlinear");
    }

    #[test]
    fn loss_matches_sample_loss() {
        let f = GaussianTargetField { dim: 2, sched: Scheduler::Vp, mu: -0.1, s1: 0.5 };
        let s = euler_ns(&[0.0, 0.3, 0.7, 1.0]);
        let mut rng = Pcg32::seeded(7);
        let x0 = rng.normal_vec(6);
        let x1 = rng.normal_vec(6);
        let g = loss_and_grad(&s, &f, &x0, &x1, 2).unwrap();
        let l = sample_loss(&s, &f, &x0, &x1, 2).unwrap();
        assert!((g.loss - l).abs() < 1e-12, "{} vs {l}", g.loss);
    }

    /// A diverged solver (NaN/inf samples) must score as the *worst*
    /// loss — `f64::max(NaN, eps)` returns eps, which would otherwise
    /// make garbage look like the best checkpoint ever seen.
    #[test]
    fn non_finite_samples_score_worst_not_best() {
        let y = vec![0.0f32; 4];
        let nan = vec![f32::NAN, 0.0, 0.25, 0.0];
        assert_eq!(log_mse_loss(&nan, &y, 2), f64::INFINITY);
        let inf = vec![f32::INFINITY, 0.0, 0.25, 0.0];
        assert_eq!(log_mse_loss(&inf, &y, 2), f64::INFINITY);
    }

    /// On a time-independent field the time gradients must vanish (the
    /// trajectory does not depend on where the velocities are sampled).
    #[test]
    fn time_grads_vanish_on_autonomous_field() {
        let f = LinearField { dim: 2, k: -0.5, c: 0.2 };
        let s = euler_ns(&[0.0, 0.2, 0.5, 1.0]);
        let mut rng = Pcg32::seeded(9);
        let x0 = rng.normal_vec(4);
        let x1 = rng.normal_vec(4);
        let g = loss_and_grad(&s, &f, &x0, &x1, 2).unwrap();
        for (i, d) in g.d_times.iter().enumerate() {
            assert!(d.abs() < 1e-9, "d_times[{i}] = {d}");
        }
    }
}
