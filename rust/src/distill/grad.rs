//! Exact first-order gradients of the eq. 13 log-MSE loss through
//! Algorithm 1 — the analytic core of the native BNS trainer, organized
//! as a **step-major wavefront**.
//!
//! Algorithm 1 is the lower-triangular recursion
//!   x_{i+1} = a_i·x0 + Σ_{j≤i} b_ij·u_j,   u_j = u(t_j, x_j),
//! so every parameter reaches the loss along two kinds of path: the
//! *direct* linear path through its own combine row, and the
//! *field-mediated* paths where moving x_k moves every later velocity
//! u_k, u_{k+1}, … . The reverse part — the per-sample loss adjoint
//! λ = ∂loss/∂x_n and the closed-form direct terms — costs nothing; the
//! field-mediated part is exact tangent (forward-sensitivity)
//! propagation. Only JVPs are required — never a transposed field
//! Jacobian, which a compiled (PJRT/stub) executable cannot provide.
//!
//! # The wavefront
//!
//! The PR 3 implementation was *parameter-major*: one tangent
//! propagation per parameter, each spending one `Field::jvp` (= one
//! device round trip) per remaining step — ~n³/6 serial round trips per
//! minibatch. But every tangent of every parameter is linearized at the
//! **same** recorded base points (t_k, x_k), so the loop nests swap: at
//! step k, *all* live tangents go through the field in **one**
//! [`Field::jvp_batch_into`] call. Device round trips per minibatch drop
//! from O(n³) to exactly n−1 (one per interior step), while the total
//! eval *work* — and therefore the honest `forwards` accounting via
//! [`Field::jvp_cost`] — is unchanged.
//!
//! Parameters are ordered by the step their tangent first exists
//! (`wavefront step`): step s introduces the time parameter t_s (a pure
//! δt tangent at its own eval) and row s−1's a/b parameters (their seed
//! appears in x_s). The live set at step k is therefore a *prefix* of
//! this ordering, which makes the tangent-history arena a ragged
//! `[step, live(step), len]` stack with contiguous slabs — no per-tangent
//! allocation anywhere.
//!
//! All state lives in a reusable [`GradWorkspace`] (the gradient-side
//! analogue of `solver::workspace::SampleWorkspace`, sharing its
//! only-ever-grow discipline): trajectory and velocity arenas for the
//! forward recording pass, the tangent slabs, the stacked JVP
//! staging buffers, and the gradient outputs. A steady-state gradient
//! evaluation allocates nothing.
//!
//! [`GradFan`] fans minibatch rows across worker threads in fixed
//! [`GRAD_CHUNK`]-row chunks (the same determinism scheme as
//! `distill::teacher`): chunk boundaries and the final reduction order
//! never depend on the thread count, so gradients are **bit-identical**
//! for any `threads` value, and lane-replicated sources
//! (`ConditionedModel::replicated`) pin chunk c to device lane
//! c mod lanes so the fan-out drives every lane.

use anyhow::Result;

use crate::distill::teacher::{BoundField, DistillField, TeacherSet};
use crate::solver::field::Field;
use crate::solver::ns::NsSolver;
use crate::solver::workspace::{reset_f32, reset_f64};
use crate::util::stats::log_mse_term;

/// Rows per gradient chunk. Fixed (never derived from the thread count)
/// so chunk boundaries — and with them the finite-difference step
/// normalization inside a chunk's JVPs and the f64 reduction order —
/// are identical for any parallelism.
pub const GRAD_CHUNK: usize = 8;

/// Loss plus the full solver-space gradient for one minibatch.
pub struct LossGrad {
    /// eq. 13: mean over samples of ln(per-sample MSE).
    pub loss: f64,
    /// ∂loss/∂times over `times[0..=n]`; the pinned endpoints (0 and n)
    /// are identically zero.
    pub d_times: Vec<f64>,
    pub d_a: Vec<f64>,
    /// Lower-triangular, same shape as `NsSolver::b`.
    pub d_b: Vec<Vec<f64>>,
    /// Batched JVP dispatches made — one logical stacked eval per
    /// interior step, exactly n−1 per chunk (vs one dispatch per
    /// (parameter, step) — ~n³/6 — on the sequential path). Each
    /// dispatch still bucket-chunks on the device (§5): realized RPCs
    /// scale with tangent rows / max compiled bucket, every RPC
    /// carrying a full bucket of useful rows, where the sequential path
    /// paid a latency-bound pair of batch-sized RPCs per tangent.
    pub jvp_round_trips: u64,
    /// Field evaluations charged for those JVPs ([`Field::jvp_cost`]):
    /// 2 per tangent under central differences, the true (cheaper) cost
    /// for closed-form fields. The total eval *work* of the gradient —
    /// what `forwards` accounting meters — unlike the round-trip count,
    /// which the wavefront collapses.
    pub jvp_evals: u64,
    /// Row-evaluations spent: Σ over chunks of rows·(n + jvp_evals) —
    /// multiply by `forwards_per_eval` for model forward passes.
    pub row_evals: u64,
}

/// eq. 13 training loss: mean over samples of the log of the per-sample
/// MSE between `out` and the teacher endpoint `x1`. The NaN/clamp edge
/// cases live in `util::stats::log_mse_term`, shared with the adjoint
/// loop of the gradient engine.
pub fn log_mse_loss(out: &[f32], x1: &[f32], dim: usize) -> f64 {
    debug_assert_eq!(out.len(), x1.len());
    let samples = out.len() / dim;
    let mut acc = 0.0;
    for s in 0..samples {
        let mse: f64 = out[s * dim..(s + 1) * dim]
            .iter()
            .zip(&x1[s * dim..(s + 1) * dim])
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / dim as f64;
        acc += log_mse_term(mse).0;
    }
    acc / samples as f64
}

/// Sample with `solver` and return the eq. 13 loss (no gradient) — the
/// validation/SPSA evaluation path.
pub fn sample_loss(
    solver: &NsSolver,
    field: &dyn Field,
    x0: &[f32],
    x1: &[f32],
    dim: usize,
) -> Result<f64> {
    let out = solver.sample(field, x0)?;
    Ok(log_mse_loss(&out, x1, dim))
}

// ---------------------------------------------------------------------------
// Parameter ordering
// ---------------------------------------------------------------------------

/// Where a parameter's gradient lands in (d_times, d_a, d_b).
#[derive(Clone, Copy, Debug)]
enum ParamKind {
    /// t_i, 1 ≤ i ≤ n−1 (endpoints pinned): a pure δt = 1 tangent at
    /// eval step i.
    Time(usize),
    /// a_i: seed δx_{i+1} = x0.
    A(usize),
    /// b_ij: seed δx_{i+1} = u_j.
    B(usize, usize),
}

#[derive(Clone, Copy, Debug)]
struct ParamInfo {
    kind: ParamKind,
    /// Wavefront step where this parameter's tangent first exists —
    /// the injection step of its seed (a/b: i+1) or of its time tangent
    /// (t_i: i). Parameters are sorted by `start`, so the live set at
    /// any step is a prefix of the ordering.
    start: usize,
}

// ---------------------------------------------------------------------------
// Workspace
// ---------------------------------------------------------------------------

/// Preallocated scratch for one wavefront gradient evaluation — the
/// gradient-side analogue of `SampleWorkspace`: a worker owns one for
/// its lifetime and every buffer only ever grows, so a steady-state
/// Adam step performs zero heap allocation in the gradient.
#[derive(Default)]
pub struct GradWorkspace {
    /// NFE the derived layout below was built for (0 = not yet built).
    n: usize,
    /// All n(n+5)/2 − 1 free parameters, sorted by wavefront start step.
    params: Vec<ParamInfo>,
    /// live[k] = #parameters with start ≤ k, for k in 0..=n.
    live: Vec<usize>,
    /// Element offset of tangent slab k (interior steps 1..=n−1) in
    /// `dus`, in units of `len`: slab k holds rows 0..live[k].
    slab_row: Vec<usize>,
    /// Total tangent rows across all slabs.
    dus_rows: usize,
    /// Recorded trajectory, flat [n+1, len].
    xs: Vec<f32>,
    /// Recorded velocities, flat [n, len].
    us: Vec<f32>,
    /// Per-element loss adjoint λ = ∂loss/∂x_n (f64).
    lambda: Vec<f64>,
    /// Ragged tangent-history arena: slab k at `slab_row[k]·len`, row r
    /// holding δu_k of parameter ordinal r.
    dus: Vec<f32>,
    /// Structural-nonzero flag per (slab, row): false = that tangent was
    /// identically zero at that step (no JVP spent, treated as zero by
    /// later combines) — mirrors the `Option<Vec>` of the old
    /// parameter-major path.
    du_set: Vec<bool>,
    /// Stacked tangent staging for one `jvp_batch_into` call.
    tg: Vec<f32>,
    tg_out: Vec<f32>,
    dts: Vec<f64>,
    /// Parameter ordinal of each stacked row.
    sel: Vec<usize>,
    /// Final-combine scratch (one tangent).
    dx: Vec<f32>,
    /// Gradient outputs (d_b lower-triangular rows concatenated:
    /// row i at offset i·(i+1)/2).
    pub d_times: Vec<f64>,
    pub d_a: Vec<f64>,
    pub d_b: Vec<f64>,
}

impl GradWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// (Re)build the parameter layout for NFE `n` and size every buffer
    /// for `len`-element states. No-op at steady state.
    fn ensure(&mut self, n: usize, len: usize) {
        if self.n != n {
            self.n = n;
            self.params.clear();
            for s in 1..=n {
                if s < n {
                    self.params.push(ParamInfo { kind: ParamKind::Time(s), start: s });
                }
                self.params.push(ParamInfo { kind: ParamKind::A(s - 1), start: s });
                for j in 0..s {
                    self.params.push(ParamInfo { kind: ParamKind::B(s - 1, j), start: s });
                }
            }
            debug_assert_eq!(self.params.len(), n * (n + 5) / 2 - 1);
            self.live.clear();
            self.live.resize(n + 1, 0);
            for k in 0..=n {
                self.live[k] = self.params.iter().take_while(|p| p.start <= k).count();
            }
            self.slab_row.clear();
            self.slab_row.resize(n.max(1), 0);
            let mut rows = 0usize;
            for k in 1..n {
                self.slab_row[k] = rows;
                rows += self.live[k];
            }
            self.dus_rows = rows;
        }
        reset_f32(&mut self.xs, (n + 1) * len);
        reset_f32(&mut self.us, n * len);
        reset_f64(&mut self.lambda, len);
        reset_f32(&mut self.dus, self.dus_rows * len);
        self.du_set.resize(self.dus_rows, false);
        let live_max = if n >= 2 { self.live[n - 1] } else { 0 };
        reset_f32(&mut self.tg, live_max * len);
        reset_f32(&mut self.tg_out, live_max * len);
        reset_f64(&mut self.dts, live_max);
        self.sel.resize(live_max, 0);
        reset_f32(&mut self.dx, len);
        reset_f64(&mut self.d_times, n + 1);
        reset_f64(&mut self.d_a, n);
        reset_f64(&mut self.d_b, n * (n + 1) / 2);
    }
}

/// Per-evaluation counters (loss is the *sum* of per-sample terms; the
/// caller normalizes by the minibatch total).
struct WaveOut {
    loss_sum: f64,
    jvp_round_trips: u64,
    jvp_evals: u64,
    row_evals: u64,
}

// ---------------------------------------------------------------------------
// The wavefront
// ---------------------------------------------------------------------------

/// One wavefront gradient evaluation over `x0`/`x1` (row-major
/// `[samples, dim]`). The adjoint is scaled by `total_samples` — the
/// full minibatch size — so per-chunk gradients from a fanned minibatch
/// sum directly. Gradients land in `ws.d_times` / `ws.d_a` / `ws.d_b`.
fn wavefront(
    solver: &NsSolver,
    field: &dyn Field,
    x0: &[f32],
    x1: &[f32],
    dim: usize,
    total_samples: usize,
    ws: &mut GradWorkspace,
) -> Result<WaveOut> {
    let n = solver.nfe();
    let len = x0.len();
    let samples = len / dim;
    anyhow::ensure!(samples > 0 && len == samples * dim, "x0 must be [samples, dim]");
    anyhow::ensure!(x1.len() == len, "x1 must match x0");
    ws.ensure(n, len);
    let GradWorkspace {
        params,
        live,
        slab_row,
        xs,
        us,
        lambda,
        dus,
        du_set,
        tg,
        tg_out,
        dts,
        sel,
        dx,
        d_times,
        d_a,
        d_b,
        ..
    } = ws;

    // ---- forward, recording trajectory + velocities (same op order as
    // `sample`, so the loss here equals the loss of the sampled output)
    xs[..len].copy_from_slice(x0);
    for i in 0..n {
        // u_i = u(t_i, x_i) written straight into its arena row
        field.eval_into(
            solver.times[i],
            &xs[i * len..(i + 1) * len],
            &mut us[i * len..(i + 1) * len],
        )?;
        // x_{i+1} = a_i·x0 + Σ_j b_ij·u_j (op order matches `sample`)
        let next = &mut xs[(i + 1) * len..(i + 2) * len];
        let a = solver.a[i] as f32;
        for (o, &x0v) in next.iter_mut().zip(x0.iter()) {
            *o = a * x0v;
        }
        for (j, &bj) in solver.b[i].iter().enumerate() {
            let bj = bj as f32;
            if bj == 0.0 {
                continue;
            }
            for (o, &uv) in next.iter_mut().zip(us[j * len..(j + 1) * len].iter()) {
                *o += bj * uv;
            }
        }
    }

    // ---- loss + adjoint λ = ∂loss/∂x_n (scaled by the fan total)
    let xn = &xs[n * len..(n + 1) * len];
    let mut loss_sum = 0.0;
    for s in 0..samples {
        let mut mse = 0.0;
        for k in 0..dim {
            let d = (xn[s * dim + k] - x1[s * dim + k]) as f64;
            mse += d * d;
        }
        mse /= dim as f64;
        let (term, diffable) = log_mse_term(mse);
        loss_sum += term;
        // in the clamp region (and for non-finite mse) the loss is
        // treated as flat: adjoint is zero there
        let c = if diffable {
            2.0 / (total_samples as f64 * dim as f64 * mse)
        } else {
            0.0
        };
        for k in 0..dim {
            lambda[s * dim + k] = c * (xn[s * dim + k] - x1[s * dim + k]) as f64;
        }
    }

    // ---- the wavefront: at each interior step k, every live tangent
    // goes through the field in one batched JVP
    let mut jvp_round_trips = 0u64;
    let mut jvp_evals = 0u64;
    for k in 1..n {
        let mut t_cnt = 0usize;
        for (r, p) in params.iter().take(live[k]).enumerate() {
            // δx_k = [seed if k == start] + Σ_{j<k} b_{k-1,j}·δu_j
            let row = &mut tg[t_cnt * len..(t_cnt + 1) * len];
            let mut structural = false;
            row.fill(0.0);
            if p.start == k {
                match p.kind {
                    ParamKind::A(_) => {
                        row.copy_from_slice(x0);
                        structural = true;
                    }
                    ParamKind::B(_, j) => {
                        row.copy_from_slice(&us[j * len..(j + 1) * len]);
                        structural = true;
                    }
                    ParamKind::Time(_) => {}
                }
            }
            for j in p.start..k {
                if !du_set[slab_row[j] + r] {
                    continue;
                }
                let bj = solver.b[k - 1][j] as f32;
                if bj == 0.0 {
                    continue;
                }
                let du = &dus[(slab_row[j] + r) * len..(slab_row[j] + r + 1) * len];
                for (o, &d) in row.iter_mut().zip(du.iter()) {
                    *o += bj * d;
                }
                structural = true;
            }
            let dt = match p.kind {
                ParamKind::Time(i) if i == k => 1.0,
                _ => 0.0,
            };
            if structural || dt != 0.0 {
                dts[t_cnt] = dt;
                sel[t_cnt] = r;
                t_cnt += 1;
            } else {
                du_set[slab_row[k] + r] = false;
            }
        }
        if t_cnt > 0 {
            field.jvp_batch_into(
                solver.times[k],
                &xs[k * len..(k + 1) * len],
                &tg[..t_cnt * len],
                &dts[..t_cnt],
                &mut tg_out[..t_cnt * len],
            )?;
            jvp_round_trips += 1;
            jvp_evals += field.jvp_cost(&dts[..t_cnt]) as u64;
            for (q, &r) in sel[..t_cnt].iter().enumerate() {
                dus[(slab_row[k] + r) * len..(slab_row[k] + r + 1) * len]
                    .copy_from_slice(&tg_out[q * len..(q + 1) * len]);
                du_set[slab_row[k] + r] = true;
            }
        }
    }

    // ---- final combine at k = n and the λ dot product
    d_times.iter_mut().for_each(|d| *d = 0.0);
    for (r, p) in params.iter().enumerate() {
        dx.fill(0.0);
        if p.start == n {
            match p.kind {
                ParamKind::A(_) => dx.copy_from_slice(x0),
                ParamKind::B(_, j) => dx.copy_from_slice(&us[j * len..(j + 1) * len]),
                // Time params end at n-1 by construction (ParamMap stamps their
                // last influenced step); a Time param with start == n contributes
                // nothing here, so leave dx zeroed rather than panicking.
                ParamKind::Time(_) => {}
            }
        }
        for j in p.start..n {
            if !du_set[slab_row[j] + r] {
                continue;
            }
            let bj = solver.b[n - 1][j] as f32;
            if bj == 0.0 {
                continue;
            }
            let du = &dus[(slab_row[j] + r) * len..(slab_row[j] + r + 1) * len];
            for (o, &d) in dx.iter_mut().zip(du.iter()) {
                *o += bj * d;
            }
        }
        let d: f64 = lambda.iter().zip(dx.iter()).map(|(&l, &v)| l * v as f64).sum();
        match p.kind {
            ParamKind::Time(i) => d_times[i] = d,
            ParamKind::A(i) => d_a[i] = d,
            ParamKind::B(i, j) => d_b[i * (i + 1) / 2 + j] = d,
        }
    }

    Ok(WaveOut {
        loss_sum,
        jvp_round_trips,
        jvp_evals,
        row_evals: samples as u64 * (n as u64 + jvp_evals),
    })
}

/// Loss and exact ∂loss/∂(times, a, b) for one minibatch of teacher
/// pairs (`x0`, `x1`, row-major `[samples, dim]`) — the wavefront engine
/// over a fresh workspace, as a single chunk. The trainer's hot loop
/// uses [`GradFan`] instead (reused workspaces, thread/lane fan-out).
pub fn loss_and_grad(
    solver: &NsSolver,
    field: &dyn Field,
    x0: &[f32],
    x1: &[f32],
    dim: usize,
) -> Result<LossGrad> {
    let mut ws = GradWorkspace::new();
    let samples = x0.len() / dim.max(1);
    let out = wavefront(solver, field, x0, x1, dim, samples, &mut ws)?;
    let n = solver.nfe();
    let d_b = (0..n)
        .map(|i| ws.d_b[i * (i + 1) / 2..i * (i + 1) / 2 + i + 1].to_vec())
        .collect();
    Ok(LossGrad {
        loss: out.loss_sum / samples as f64,
        d_times: ws.d_times.clone(),
        d_a: ws.d_a.clone(),
        d_b,
        jvp_round_trips: out.jvp_round_trips,
        jvp_evals: out.jvp_evals,
        row_evals: out.row_evals,
    })
}

// ---------------------------------------------------------------------------
// Minibatch fan-out
// ---------------------------------------------------------------------------

/// One chunk's persistent state: gathered pair rows, the (rebindable)
/// row-conditioned field, and the chunk's gradient contribution.
struct ChunkSlot<'s> {
    x0: Vec<f32>,
    x1: Vec<f32>,
    bound: Option<BoundField<'s>>,
    loss_sum: f64,
    d_times: Vec<f64>,
    d_a: Vec<f64>,
    d_b: Vec<f64>,
    jvp_round_trips: u64,
    jvp_evals: u64,
    row_evals: u64,
    err: Option<anyhow::Error>,
}

impl Default for ChunkSlot<'_> {
    fn default() -> Self {
        ChunkSlot {
            x0: Vec::new(),
            x1: Vec::new(),
            bound: None,
            loss_sum: 0.0,
            d_times: Vec::new(),
            d_a: Vec::new(),
            d_b: Vec::new(),
            jvp_round_trips: 0,
            jvp_evals: 0,
            row_evals: 0,
            err: None,
        }
    }
}

fn run_slot(solver: &NsSolver, slot: &mut ChunkSlot<'_>, dim: usize, total: usize, ws: &mut GradWorkspace) {
    // compute() binds every slot before dispatch; an unbound slot reports
    // a structured error instead of tearing down the worker thread.
    let Some(field) = slot.bound.as_ref() else {
        slot.err = Some(anyhow::anyhow!("gradient chunk slot ran before binding rows"));
        return;
    };
    match wavefront(solver, field, &slot.x0, &slot.x1, dim, total, ws) {
        Ok(out) => {
            slot.loss_sum = out.loss_sum;
            slot.jvp_round_trips = out.jvp_round_trips;
            slot.jvp_evals = out.jvp_evals;
            slot.row_evals = out.row_evals;
            slot.d_times.clear();
            slot.d_times.extend_from_slice(&ws.d_times);
            slot.d_a.clear();
            slot.d_a.extend_from_slice(&ws.d_a);
            slot.d_b.clear();
            slot.d_b.extend_from_slice(&ws.d_b);
            slot.err = None;
        }
        Err(e) => slot.err = Some(e),
    }
}

/// The trainer's gradient engine: fans a minibatch over fixed
/// [`GRAD_CHUNK`]-row chunks (each rebinding its rows' conditioning and,
/// for lane-replicated sources, pinned to device lane chunk mod lanes),
/// runs them across up to `threads` persistent-workspace workers, and
/// reduces the per-chunk gradients in fixed chunk order — so the result
/// is bit-identical for any thread count, and a steady-state call
/// allocates nothing (`threads` = 1; with more threads the only
/// steady-state allocations are the scoped worker stacks).
#[derive(Default)]
pub struct GradFan<'s> {
    slots: Vec<ChunkSlot<'s>>,
    wss: Vec<GradWorkspace>,
    /// Data-pointer identity of the source the slot bindings were built
    /// from (0 = none yet). Rebinding is only valid against the same
    /// source — `rebind_rows` swaps row conditioning, not the underlying
    /// field — so a `compute` with a different `src` drops every
    /// binding and binds fresh instead of silently evaluating gradients
    /// through the previous source.
    src_id: usize,
    /// eq. 13 minibatch loss of the last `compute`.
    pub loss: f64,
    /// Combined gradient of the last `compute` (`d_b` flat
    /// lower-triangular, row i at offset i·(i+1)/2).
    pub d_times: Vec<f64>,
    pub d_a: Vec<f64>,
    pub d_b: Vec<f64>,
    /// Batched JVP dispatches (≤ (n−1)·ceil(batch/GRAD_CHUNK)).
    pub jvp_round_trips: u64,
    pub jvp_evals: u64,
    /// Σ rows·(n + jvp_evals) — multiply by `forwards_per_eval` for
    /// model forward passes.
    pub row_evals: u64,
}

impl<'s> GradFan<'s> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Evaluate loss + gradient of `solver` on the teacher pairs `idx`,
    /// conditioned per row through `src`, fanned over `threads` workers.
    /// Returns the loss; gradients are in `d_times`/`d_a`/`d_b`.
    pub fn compute(
        &mut self,
        solver: &NsSolver,
        src: &'s dyn DistillField,
        teacher: &TeacherSet,
        idx: &[usize],
        dim: usize,
        threads: usize,
    ) -> Result<f64> {
        let n = solver.nfe();
        let total = idx.len();
        anyhow::ensure!(total > 0, "empty minibatch");
        anyhow::ensure!(threads >= 1, "threads must be >= 1 (got 0)");
        let nchunks = (total + GRAD_CHUNK - 1) / GRAD_CHUNK;
        if self.slots.len() < nchunks {
            self.slots.resize_with(nchunks, ChunkSlot::default);
        }
        let src_id = src as *const dyn DistillField as *const () as usize;
        if self.src_id != src_id {
            // a different source: stale bindings must not be rebound
            // (they would keep the old source's field/replica)
            for slot in self.slots.iter_mut() {
                slot.bound = None;
            }
            self.src_id = src_id;
        }
        for (c, slot) in self.slots.iter_mut().enumerate().take(nchunks) {
            let rows = &idx[c * GRAD_CHUNK..total.min((c + 1) * GRAD_CHUNK)];
            teacher.gather(rows, &mut slot.x0, &mut slot.x1);
            match slot.bound.as_mut() {
                Some(b) => src.rebind_rows(b, rows)?,
                None => slot.bound = Some(src.bind_chunk(rows, c)?),
            }
        }
        let workers = threads.min(nchunks).max(1);
        if self.wss.len() < workers {
            self.wss.resize_with(workers, GradWorkspace::new);
        }
        if workers == 1 {
            let ws = &mut self.wss[0];
            for slot in self.slots.iter_mut().take(nchunks) {
                run_slot(solver, slot, dim, total, ws);
            }
        } else {
            let per = (nchunks + workers - 1) / workers;
            let slots = &mut self.slots[..nchunks];
            std::thread::scope(|scope| {
                for (chunk, ws) in slots.chunks_mut(per).zip(self.wss.iter_mut()) {
                    scope.spawn(move || {
                        for slot in chunk {
                            run_slot(solver, slot, dim, total, ws);
                        }
                    });
                }
            });
        }
        // first error in chunk order (deterministic)
        for slot in self.slots.iter_mut().take(nchunks) {
            if let Some(e) = slot.err.take() {
                return Err(e.context("gradient chunk"));
            }
        }
        // fixed-order reduction: chunk 0, 1, 2, … regardless of workers
        reset_f64(&mut self.d_times, n + 1);
        reset_f64(&mut self.d_a, n);
        reset_f64(&mut self.d_b, n * (n + 1) / 2);
        self.d_times.iter_mut().for_each(|d| *d = 0.0);
        self.d_a.iter_mut().for_each(|d| *d = 0.0);
        self.d_b.iter_mut().for_each(|d| *d = 0.0);
        let mut loss_sum = 0.0;
        self.jvp_round_trips = 0;
        self.jvp_evals = 0;
        self.row_evals = 0;
        for slot in self.slots.iter().take(nchunks) {
            loss_sum += slot.loss_sum;
            for (o, &v) in self.d_times.iter_mut().zip(slot.d_times.iter()) {
                *o += v;
            }
            for (o, &v) in self.d_a.iter_mut().zip(slot.d_a.iter()) {
                *o += v;
            }
            for (o, &v) in self.d_b.iter_mut().zip(slot.d_b.iter()) {
                *o += v;
            }
            self.jvp_round_trips += slot.jvp_round_trips;
            self.jvp_evals += slot.jvp_evals;
            self.row_evals += slot.row_evals;
        }
        self.loss = loss_sum / total as f64;
        Ok(self.loss)
    }
}

// ---------------------------------------------------------------------------
// Reference oracle (the PR 3 parameter-major path) + tests
// ---------------------------------------------------------------------------

/// The original parameter-major implementation, kept verbatim as the
/// correctness oracle for the wavefront: one tangent propagation per
/// parameter, one `Field::jvp` round trip per (parameter, step).
#[cfg(test)]
mod reference {
    use super::*;

    #[allow(clippy::too_many_arguments)]
    fn propagate(
        solver: &NsSolver,
        field: &dyn Field,
        xs: &[Vec<f32>],
        lambda: &[f64],
        start: usize,
        seed: Option<&[f32]>,
        time_step: Option<usize>,
        jvp_calls: &mut usize,
    ) -> Result<f64> {
        let n = solver.nfe();
        let len = lambda.len();
        debug_assert!(seed.is_some() != time_step.is_some());
        let first = time_step.unwrap_or(start);
        let mut dus: Vec<Option<Vec<f32>>> = vec![None; n];
        let mut dx = vec![0f32; len];
        let mut dx_nonzero = false;
        for k in first..=n {
            if k > first || time_step.is_none() {
                dx.fill(0.0);
                dx_nonzero = false;
                if seed.is_some() && k == start {
                    dx.copy_from_slice(seed.unwrap());
                    dx_nonzero = true;
                }
                if k > first {
                    for (j, &bj) in solver.b[k - 1].iter().enumerate() {
                        if let Some(du) = dus[j].as_ref() {
                            let bj = bj as f32;
                            if bj == 0.0 {
                                continue;
                            }
                            for (o, &d) in dx.iter_mut().zip(du.iter()) {
                                *o += bj * d;
                            }
                            dx_nonzero = true;
                        }
                    }
                }
            }
            if k == n {
                break;
            }
            let dt = if time_step == Some(k) { 1.0 } else { 0.0 };
            if dx_nonzero || dt != 0.0 {
                dus[k] = Some(field.jvp(solver.times[k], &xs[k], &dx, dt)?);
                *jvp_calls += 1;
            }
        }
        Ok(lambda.iter().zip(dx.iter()).map(|(&l, &d)| l * d as f64).sum())
    }

    pub fn loss_and_grad_reference(
        solver: &NsSolver,
        field: &dyn Field,
        x0: &[f32],
        x1: &[f32],
        dim: usize,
    ) -> Result<LossGrad> {
        let n = solver.nfe();
        let len = x0.len();
        let samples = len / dim;
        let mut xs: Vec<Vec<f32>> = Vec::with_capacity(n + 1);
        xs.push(x0.to_vec());
        let mut us: Vec<Vec<f32>> = Vec::with_capacity(n);
        for i in 0..n {
            us.push(field.eval(solver.times[i], &xs[i])?);
            let a = solver.a[i] as f32;
            let mut next: Vec<f32> = x0.iter().map(|&v| a * v).collect();
            for (j, &bj) in solver.b[i].iter().enumerate() {
                let bj = bj as f32;
                if bj == 0.0 {
                    continue;
                }
                for (o, &uv) in next.iter_mut().zip(us[j].iter()) {
                    *o += bj * uv;
                }
            }
            xs.push(next);
        }
        let xn = &xs[n];
        let mut loss = 0.0;
        let mut lambda = vec![0f64; len];
        for s in 0..samples {
            let mut mse = 0.0;
            for k in 0..dim {
                let d = (xn[s * dim + k] - x1[s * dim + k]) as f64;
                mse += d * d;
            }
            mse /= dim as f64;
            loss += if mse.is_nan() { f64::INFINITY } else { mse.max(1e-20).ln() };
            let c = if mse.is_finite() && mse > 1e-20 {
                2.0 / (samples as f64 * dim as f64 * mse)
            } else {
                0.0
            };
            for k in 0..dim {
                lambda[s * dim + k] = c * (xn[s * dim + k] - x1[s * dim + k]) as f64;
            }
        }
        loss /= samples as f64;

        let mut jvp_calls = 0usize;
        let mut d_a = vec![0.0; n];
        let mut d_b: Vec<Vec<f64>> = (0..n).map(|i| vec![0.0; i + 1]).collect();
        let mut d_times = vec![0.0; n + 1];
        for i in 0..n {
            d_a[i] =
                propagate(solver, field, &xs, &lambda, i + 1, Some(x0), None, &mut jvp_calls)?;
            for j in 0..=i {
                d_b[i][j] = propagate(
                    solver,
                    field,
                    &xs,
                    &lambda,
                    i + 1,
                    Some(&us[j]),
                    None,
                    &mut jvp_calls,
                )?;
            }
        }
        for (i, d) in d_times.iter_mut().enumerate().take(n).skip(1) {
            *d = propagate(solver, field, &xs, &lambda, i, None, Some(i), &mut jvp_calls)?;
        }
        Ok(LossGrad {
            loss,
            d_times,
            d_a,
            d_b,
            jvp_round_trips: jvp_calls as u64,
            jvp_evals: 2 * jvp_calls as u64,
            row_evals: samples as u64 * (n + 2 * jvp_calls) as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::reference::loss_and_grad_reference;
    use super::*;
    use crate::distill::teacher::UniformField;
    use crate::distill::theta::{grad_to_theta, pack, unpack};
    use crate::solver::field::{GaussianTargetField, LinearField, NonlinearField};
    use crate::solver::scheduler::Scheduler;
    use crate::solver::taxonomy::euler_ns;
    use crate::util::rng::Pcg32;

    /// Analytic theta-space gradient vs central finite differences of
    /// the same loss, per parameter — the subsystem's correctness gate.
    fn grad_check(field: &dyn Field, dim: usize, label: &str) {
        let n = 3;
        // non-uniform grid + slightly perturbed coefficients so no
        // parameter sits at a symmetric point
        let mut solver = euler_ns(&[0.0, 0.22, 0.61, 1.0]);
        solver.a[1] = 0.93;
        solver.b[2][0] = 0.07;
        solver.b[1][1] *= 1.1;
        let mut rng = Pcg32::seeded(42);
        let x0 = rng.normal_vec(4 * dim);
        let x1: Vec<f32> = rng.normal_vec(4 * dim).iter().map(|v| v * 0.5).collect();

        let theta = pack(&solver);
        let g = loss_and_grad(&solver, field, &x0, &x1, dim).unwrap();
        let gt = grad_to_theta(&theta, n, &g.d_times, &g.d_a, &g.d_b);
        assert!(g.jvp_round_trips > 0);

        let h = 1e-3;
        for (m, &gm) in gt.iter().enumerate() {
            let mut tp = theta.clone();
            tp[m] += h;
            let mut tm = theta.clone();
            tm[m] -= h;
            let lp = sample_loss(&unpack(&tp, n), field, &x0, &x1, dim).unwrap();
            let lm = sample_loss(&unpack(&tm, n), field, &x0, &x1, dim).unwrap();
            let fd = (lp - lm) / (2.0 * h);
            let tol = 3e-2 * gm.abs().max(fd.abs()) + 2e-3;
            assert!(
                (gm - fd).abs() <= tol,
                "{label} theta[{m}]: analytic {gm} vs fd {fd}"
            );
        }
    }

    #[test]
    fn gradient_check_linear_field() {
        grad_check(&LinearField { dim: 3, k: -0.8, c: 0.4 }, 3, "linear");
    }

    #[test]
    fn gradient_check_gaussian_target_field() {
        grad_check(
            &GaussianTargetField { dim: 3, sched: Scheduler::FmOt, mu: 0.4, s1: 0.3 },
            3,
            "gaussian",
        );
    }

    #[test]
    fn gradient_check_nonlinear_field() {
        grad_check(&NonlinearField { dim: 2 }, 2, "nonlinear");
    }

    #[test]
    fn loss_matches_sample_loss() {
        let f = GaussianTargetField { dim: 2, sched: Scheduler::Vp, mu: -0.1, s1: 0.5 };
        let s = euler_ns(&[0.0, 0.3, 0.7, 1.0]);
        let mut rng = Pcg32::seeded(7);
        let x0 = rng.normal_vec(6);
        let x1 = rng.normal_vec(6);
        let g = loss_and_grad(&s, &f, &x0, &x1, 2).unwrap();
        let l = sample_loss(&s, &f, &x0, &x1, 2).unwrap();
        assert!((g.loss - l).abs() < 1e-12, "{} vs {l}", g.loss);
    }

    /// A diverged solver (NaN/inf samples) must score as the *worst*
    /// loss — `f64::max(NaN, eps)` returns eps, which would otherwise
    /// make garbage look like the best checkpoint ever seen.
    #[test]
    fn non_finite_samples_score_worst_not_best() {
        let y = vec![0.0f32; 4];
        let nan = vec![f32::NAN, 0.0, 0.25, 0.0];
        assert_eq!(log_mse_loss(&nan, &y, 2), f64::INFINITY);
        let inf = vec![f32::INFINITY, 0.0, 0.25, 0.0];
        assert_eq!(log_mse_loss(&inf, &y, 2), f64::INFINITY);
    }

    /// On a time-independent field the time gradients must vanish (the
    /// trajectory does not depend on where the velocities are sampled).
    #[test]
    fn time_grads_vanish_on_autonomous_field() {
        let f = LinearField { dim: 2, k: -0.5, c: 0.2 };
        let s = euler_ns(&[0.0, 0.2, 0.5, 1.0]);
        let mut rng = Pcg32::seeded(9);
        let x0 = rng.normal_vec(4);
        let x1 = rng.normal_vec(4);
        let g = loss_and_grad(&s, &f, &x0, &x1, 2).unwrap();
        for (i, d) in g.d_times.iter().enumerate() {
            assert!(d.abs() < 1e-9, "d_times[{i}] = {d}");
        }
    }

    /// Strips every JVP override so the trait's central-difference
    /// default applies — pins the wavefront against the oracle on the
    /// finite-difference path too (both then share the per-call batch
    /// normalization, since the comparison runs single-chunk).
    struct FdOnly<'a>(&'a dyn Field);

    impl Field for FdOnly<'_> {
        fn dim(&self) -> usize {
            self.0.dim()
        }

        fn eval(&self, t: f64, x: &[f32]) -> Result<Vec<f32>> {
            self.0.eval(t, x)
        }
    }

    /// The wavefront must reproduce the parameter-major oracle — same
    /// loss, same gradients — on closed-form and finite-difference
    /// fields, non-uniform grids, and sparse b (zero entries exercise
    /// the structural-liveness bookkeeping).
    #[test]
    fn wavefront_matches_parameter_major_reference() {
        let lin = LinearField { dim: 3, k: -0.8, c: 0.4 };
        let gauss = GaussianTargetField { dim: 3, sched: Scheduler::FmOt, mu: 0.4, s1: 0.3 };
        let nonlin = NonlinearField { dim: 3 };
        let fd = FdOnly(&nonlin);
        let fields: [(&dyn Field, &str); 4] =
            [(&lin, "linear"), (&gauss, "gaussian"), (&nonlin, "nonlinear"), (&fd, "fd")];
        for n in [3usize, 5] {
            let times: Vec<f64> =
                (0..=n).map(|i| (i as f64 / n as f64).powf(1.3)).collect();
            let mut solver = euler_ns(&times);
            solver.a[1] = 0.9;
            solver.b[n - 1][0] = 0.0; // sparse entry: liveness gaps
            if n >= 5 {
                solver.b[3][1] = 0.0;
                solver.b[4][2] *= 1.3;
            }
            let mut rng = Pcg32::seeded(1234 + n as u64);
            let x0 = rng.normal_vec(5 * 3);
            let x1: Vec<f32> = rng.normal_vec(5 * 3).iter().map(|v| v * 0.4).collect();
            for (f, label) in fields.iter() {
                let w = loss_and_grad(&solver, *f, &x0, &x1, 3).unwrap();
                let r = loss_and_grad_reference(&solver, *f, &x0, &x1, 3).unwrap();
                let close = |a: f64, b: f64| (a - b).abs() <= 1e-10 * a.abs().max(b.abs()).max(1e-12);
                assert!(close(w.loss, r.loss), "{label} n={n} loss {} vs {}", w.loss, r.loss);
                for i in 0..=n {
                    assert!(
                        close(w.d_times[i], r.d_times[i]),
                        "{label} n={n} d_times[{i}]: {} vs {}",
                        w.d_times[i],
                        r.d_times[i]
                    );
                }
                for i in 0..n {
                    assert!(
                        close(w.d_a[i], r.d_a[i]),
                        "{label} n={n} d_a[{i}]: {} vs {}",
                        w.d_a[i],
                        r.d_a[i]
                    );
                    for j in 0..=i {
                        assert!(
                            close(w.d_b[i][j], r.d_b[i][j]),
                            "{label} n={n} d_b[{i}][{j}]: {} vs {}",
                            w.d_b[i][j],
                            r.d_b[i][j]
                        );
                    }
                }
                // the wavefront spends the same eval work in O(n) trips
                assert_eq!(w.jvp_round_trips, (n - 1) as u64, "{label} n={n}");
                assert!(r.jvp_round_trips > w.jvp_round_trips, "{label} n={n}");
            }
        }
    }

    /// Device round trips per gradient are O(n): exactly n−1 batched
    /// dispatches per chunk for n = 8 and 16 — versus the oracle's
    /// ~n³/6 sequential calls.
    #[test]
    fn round_trips_linear_in_nfe() {
        let f = GaussianTargetField { dim: 2, sched: Scheduler::FmOt, mu: 0.2, s1: 0.4 };
        for n in [8usize, 16] {
            let times: Vec<f64> = (0..=n).map(|i| i as f64 / n as f64).collect();
            let solver = euler_ns(&times);
            let mut rng = Pcg32::seeded(5);
            let x0 = rng.normal_vec(4 * 2);
            let x1 = rng.normal_vec(4 * 2);
            let g = loss_and_grad(&solver, &f, &x0, &x1, 2).unwrap();
            assert_eq!(g.jvp_round_trips, (n - 1) as u64, "n={n}");
            assert!(g.jvp_round_trips <= n as u64, "n={n}: O(n) bound");
        }
    }

    /// The fanned gradient is bit-identical for any thread count: fixed
    /// chunk boundaries, fixed reduction order.
    #[test]
    fn fanned_gradient_is_thread_count_invariant() {
        let f = GaussianTargetField { dim: 4, sched: Scheduler::FmOt, mu: 0.3, s1: 0.35 };
        let src = UniformField(&f);
        let teacher = TeacherSet::generate(&src, 4, 20, 77, 1).unwrap();
        let times: Vec<f64> = (0..=6).map(|i| i as f64 / 6.0).collect();
        let mut solver = euler_ns(&times);
        solver.a[2] = 0.95;
        let idx: Vec<usize> = (0..20).rev().collect(); // 3 chunks (8+8+4)
        let mut fan1 = GradFan::new();
        let l1 = fan1.compute(&solver, &src, &teacher, &idx, 4, 1).unwrap();
        let mut fan4 = GradFan::new();
        let l4 = fan4.compute(&solver, &src, &teacher, &idx, 4, 4).unwrap();
        assert_eq!(l1.to_bits(), l4.to_bits(), "loss must not depend on threads");
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&fan1.d_times), bits(&fan4.d_times));
        assert_eq!(bits(&fan1.d_a), bits(&fan4.d_a));
        assert_eq!(bits(&fan1.d_b), bits(&fan4.d_b));
        assert_eq!(fan1.jvp_round_trips, fan4.jvp_round_trips);
        assert_eq!(fan1.row_evals, fan4.row_evals);
        // 3 chunks × (n−1) dispatches
        assert_eq!(fan1.jvp_round_trips, 3 * 5);
        // repeat on the same fan (reused slots/workspaces): identical
        let l1b = fan1.compute(&solver, &src, &teacher, &idx, 4, 1).unwrap();
        assert_eq!(l1.to_bits(), l1b.to_bits());
        assert_eq!(bits(&fan1.d_b), bits(&fan4.d_b));
    }

    /// A single-chunk fan reduces to `loss_and_grad` exactly (same
    /// chunking ⇒ same finite-difference normalization ⇒ same bits).
    #[test]
    fn single_chunk_fan_matches_loss_and_grad() {
        let f = NonlinearField { dim: 3 };
        let src = UniformField(&f);
        let teacher = TeacherSet::generate(&src, 3, 8, 21, 1).unwrap();
        let solver = euler_ns(&[0.0, 0.3, 0.65, 1.0]);
        let idx: Vec<usize> = (0..8).collect();
        let mut fan = GradFan::new();
        let loss = fan.compute(&solver, &src, &teacher, &idx, 3, 1).unwrap();
        let g = loss_and_grad(&solver, &f, &teacher.x0, &teacher.x1, 3).unwrap();
        assert_eq!(loss.to_bits(), g.loss.to_bits());
        for i in 0..3 {
            assert_eq!(fan.d_a[i].to_bits(), g.d_a[i].to_bits());
            for j in 0..=i {
                assert_eq!(fan.d_b[i * (i + 1) / 2 + j].to_bits(), g.d_b[i][j].to_bits());
            }
        }
    }
}
