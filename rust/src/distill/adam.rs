//! Adam (Kingma & Ba 2015) over the theta space of `distill::theta` —
//! the optimizer behind the first-order trainer. Offline substrate for
//! what an autodiff stack would get from its optimizer library: plain
//! f64 vectors, bias-corrected first/second moments, no allocation per
//! step after construction.

/// Adam state for a fixed-size parameter vector.
#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl Adam {
    pub fn new(params: usize, lr: f64) -> Adam {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, m: vec![0.0; params], v: vec![0.0; params], t: 0 }
    }

    /// One update: theta -= lr * m̂ / (sqrt(v̂) + eps).
    pub fn step(&mut self, theta: &mut [f64], grad: &[f64]) {
        assert_eq!(theta.len(), self.m.len(), "Adam sized for {} params", self.m.len());
        assert_eq!(grad.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..theta.len() {
            let g = grad[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            theta[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adam on a separable quadratic with very different curvatures —
    /// the diagonal preconditioning must reach both minima.
    #[test]
    fn converges_on_anisotropic_quadratic() {
        let target = [3.0, -1.5, 0.25];
        let scale = [100.0, 1.0, 0.01];
        let mut x = vec![0.0; 3];
        let mut opt = Adam::new(3, 0.05);
        for _ in 0..2000 {
            let g: Vec<f64> =
                (0..3).map(|i| 2.0 * scale[i] * (x[i] - target[i])).collect();
            opt.step(&mut x, &g);
        }
        for (xi, ti) in x.iter().zip(target.iter()) {
            assert!((xi - ti).abs() < 0.05, "{xi} vs {ti}");
        }
    }

    #[test]
    fn first_step_size_is_lr() {
        // with bias correction the very first step is ±lr (up to eps)
        let mut x = vec![0.0];
        let mut opt = Adam::new(1, 0.1);
        opt.step(&mut x, &[123.0]);
        assert!((x[0] + 0.1).abs() < 1e-6, "{}", x[0]);
    }
}
