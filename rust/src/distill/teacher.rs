//! Teacher-trajectory store: RK45 ground-truth `(x0, x1)` pairs generated
//! once through the deployed field, cached to disk, and shared by every
//! distillation run whose `(dim, pairs, seed, scope)` key matches — the
//! caller-supplied `scope` string encodes whatever else the pairs depend
//! on (model name, guidance, label draw), so a cache file is never
//! silently reused across fields it wasn't generated through.
//!
//! Generation fans out across threads in **fixed-size chunks**
//! ([`GT_CHUNK`] rows per RK45 call): the adaptive step control sees the
//! same batches regardless of parallelism, so teacher sets are
//! bit-identical for any `threads` value (pinned by a unit test). Each
//! chunk is integrated through the conditioning of its own rows
//! ([`DistillField::bind_rows`]), so label-conditioned model fields see
//! the right labels per row — the same mechanism the trainer uses for
//! unbiased shuffled minibatches ([`sample_indices`]).

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::runtime::model_field::{LoadedModel, ModelField};
use crate::solver::field::Field;
use crate::solver::rk45::{rk45, Rk45Opts};
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::util::sync::lock_ok;

/// Rows integrated per RK45 call during teacher generation. Fixed (never
/// derived from the thread count) so results don't depend on
/// parallelism: RK45 shares one adaptive step across the rows of a call,
/// so changing the chunking would change the ground truth itself.
pub const GT_CHUNK: usize = 8;

/// A velocity field together with the per-row conditioning needed to
/// evaluate arbitrary row subsets of a teacher set — the seam between
/// the distillation loop (which thinks in pair indices) and the field
/// (which may carry per-row labels).
pub trait DistillField: Sync {
    /// The field bound to the full teacher set (row i ↔ pair i).
    fn full(&self) -> &dyn Field;

    /// Bind the conditioning of a row subset (a minibatch or a
    /// generation chunk): row r of the returned field must see the
    /// conditioning of set row `idx[r]`.
    fn bind_rows(&self, idx: &[usize]) -> Result<BoundField<'_>>;

    /// Re-bind an existing binding (produced by `bind_rows`/`bind_chunk`
    /// of this same source) to a new row subset **without allocating** —
    /// the hot-loop entry: the trainer's gradient fan-out rebinds one
    /// persistent binding per chunk slot every Adam step. The default
    /// falls back to a fresh `bind_rows`.
    fn rebind_rows<'a>(&'a self, bound: &mut BoundField<'a>, idx: &[usize]) -> Result<()> {
        *bound = self.bind_rows(idx)?;
        Ok(())
    }

    /// `bind_rows` for work chunk `chunk` of a deterministic fixed-chunk
    /// fan-out (teacher generation, gradient minibatch chunks). Sources
    /// replicated over device lanes use the chunk ordinal to pin the
    /// binding to a lane replica, so chunks fan across lanes; values must
    /// not depend on the replica. The default ignores the ordinal.
    fn bind_chunk(&self, idx: &[usize], _chunk: usize) -> Result<BoundField<'_>> {
        self.bind_rows(idx)
    }
}

/// A row-subset binding handed out by [`DistillField::bind_rows`] — a
/// concrete enum (not a boxed trait object) so bindings can live in
/// reusable slots and be re-pointed at new rows with zero allocation
/// ([`DistillField::rebind_rows`]).
pub enum BoundField<'a> {
    /// A borrow of an unconditioned field (every row subset is the same).
    Borrowed(&'a dyn Field),
    /// A device model bound to the gathered per-row labels.
    Model(ModelField),
}

impl Field for BoundField<'_> {
    fn dim(&self) -> usize {
        match self {
            BoundField::Borrowed(f) => f.dim(),
            BoundField::Model(m) => m.dim(),
        }
    }

    fn eval(&self, t: f64, x: &[f32]) -> Result<Vec<f32>> {
        match self {
            BoundField::Borrowed(f) => f.eval(t, x),
            BoundField::Model(m) => m.eval(t, x),
        }
    }

    fn eval_into(&self, t: f64, x: &[f32], out: &mut [f32]) -> Result<()> {
        match self {
            BoundField::Borrowed(f) => f.eval_into(t, x, out),
            BoundField::Model(m) => m.eval_into(t, x, out),
        }
    }

    fn forwards_per_eval(&self) -> usize {
        match self {
            BoundField::Borrowed(f) => f.forwards_per_eval(),
            BoundField::Model(m) => m.forwards_per_eval(),
        }
    }

    fn jvp(&self, t: f64, x: &[f32], v: &[f32], dt: f64) -> Result<Vec<f32>> {
        match self {
            BoundField::Borrowed(f) => f.jvp(t, x, v, dt),
            BoundField::Model(m) => m.jvp(t, x, v, dt),
        }
    }

    fn jvp_batch_into(
        &self,
        t: f64,
        x: &[f32],
        tangents: &[f32],
        dts: &[f64],
        out: &mut [f32],
    ) -> Result<()> {
        match self {
            BoundField::Borrowed(f) => f.jvp_batch_into(t, x, tangents, dts, out),
            BoundField::Model(m) => m.jvp_batch_into(t, x, tangents, dts, out),
        }
    }

    fn jvp_cost(&self, dts: &[f64]) -> usize {
        match self {
            BoundField::Borrowed(f) => f.jvp_cost(dts),
            BoundField::Model(m) => m.jvp_cost(dts),
        }
    }
}

/// Conditioning-free fields (the analytic/test fields): every row subset
/// sees the same field.
pub struct UniformField<'a>(pub &'a dyn Field);

impl DistillField for UniformField<'_> {
    fn full(&self) -> &dyn Field {
        self.0
    }

    fn bind_rows(&self, _idx: &[usize]) -> Result<BoundField<'_>> {
        Ok(BoundField::Borrowed(self.0))
    }

    fn rebind_rows<'a>(&'a self, bound: &mut BoundField<'a>, _idx: &[usize]) -> Result<()> {
        // row-independent: the existing borrow is already correct
        debug_assert!(matches!(bound, BoundField::Borrowed(_)));
        Ok(())
    }
}

/// A loaded model plus per-pair labels and guidance — the serving-side
/// conditioning of a teacher set drawn over a label distribution.
/// `bind_rows` re-binds the cached `LoadedModel` to the gathered labels
/// (an `Arc` bump plus one small vec; no recompilation), and
/// `rebind_rows` refreshes an existing binding's label vector in place
/// (no allocation at steady state). With [`ConditionedModel::replicated`]
/// the model is loaded once per device lane and `bind_chunk` pins chunk
/// `c` to replica `c % lanes`, so fixed-chunk fan-outs (teacher
/// generation, gradient minibatch chunks) drive every lane.
pub struct ConditionedModel {
    full: ModelField,
    /// Lane replicas (replica 0 backs `full`); length ≥ 1.
    replicas: Vec<Arc<LoadedModel>>,
}

impl ConditionedModel {
    pub fn new(model: Arc<LoadedModel>, labels: Vec<i32>, guidance: f32) -> ConditionedModel {
        ConditionedModel { replicas: vec![model.clone()], full: model.bind(labels, guidance) }
    }

    /// Load the model once per device lane of `rt` so chunked fan-outs
    /// execute truly concurrently (one compile per lane — outputs are
    /// bit-identical across lanes, so results don't depend on placement).
    pub fn replicated(
        rt: &crate::runtime::Runtime,
        info: &crate::runtime::ModelInfo,
        labels: Vec<i32>,
        guidance: f32,
    ) -> Result<ConditionedModel> {
        let replicas = (0..rt.num_lanes())
            .map(|lane| Ok(Arc::new(LoadedModel::load_on(rt, lane, info)?)))
            .collect::<Result<Vec<_>>>()?;
        Ok(ConditionedModel {
            full: replicas[0].clone().bind(labels, guidance),
            replicas,
        })
    }

    pub fn labels(&self) -> &[i32] {
        &self.full.labels
    }

    /// Number of lane replicas backing chunked fan-outs.
    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    fn gather_labels(&self, idx: &[usize], out: &mut Vec<i32>) -> Result<()> {
        out.clear();
        for &i in idx {
            out.push(
                self.full
                    .labels
                    .get(i)
                    .copied()
                    .with_context(|| format!("pair index {i} out of range"))?,
            );
        }
        Ok(())
    }
}

impl DistillField for ConditionedModel {
    fn full(&self) -> &dyn Field {
        &self.full
    }

    fn bind_rows(&self, idx: &[usize]) -> Result<BoundField<'_>> {
        self.bind_chunk(idx, 0)
    }

    fn rebind_rows<'a>(&'a self, bound: &mut BoundField<'a>, idx: &[usize]) -> Result<()> {
        match bound {
            BoundField::Model(mf) => {
                // keep the binding's replica/lane; only the labels move
                let mut labels = std::mem::take(&mut mf.labels);
                self.gather_labels(idx, &mut labels)?;
                mf.labels = labels;
                Ok(())
            }
            BoundField::Borrowed(_) => {
                *bound = self.bind_rows(idx)?;
                Ok(())
            }
        }
    }

    fn bind_chunk(&self, idx: &[usize], chunk: usize) -> Result<BoundField<'_>> {
        let mut labels = Vec::with_capacity(idx.len());
        self.gather_labels(idx, &mut labels)?;
        let replica = &self.replicas[chunk % self.replicas.len()];
        Ok(BoundField::Model(replica.clone().bind(labels, self.full.guidance)))
    }
}

/// The cached ground-truth pair set.
pub struct TeacherSet {
    pub dim: usize,
    pub pairs: usize,
    pub seed: u64,
    /// Caller-supplied cache-key component for everything the pairs
    /// depend on beyond `(dim, pairs, seed)` — typically
    /// `"model|w=guidance"`. Empty for in-memory (uncached) sets.
    pub scope: String,
    /// Noise inputs, row-major `[pairs, dim]`.
    pub x0: Vec<f32>,
    /// RK45 endpoints, row-major `[pairs, dim]`.
    pub x1: Vec<f32>,
    /// Total RK45 `eval` calls spent generating the set (each call
    /// covers one chunk of up to [`GT_CHUNK`] rows).
    pub gt_evals: u64,
    /// Mean RK45 NFE per trajectory (rows of a chunk share the adaptive
    /// steps, so per-trajectory NFE equals the chunk's eval count).
    pub gt_nfe: u64,
}

fn run_chunk(
    src: &dyn DistillField,
    dim: usize,
    opts: &Rk45Opts,
    chunk: usize,
    xc0: &[f32],
    xc1: &mut [f32],
) -> Result<usize> {
    let rows = xc1.len() / dim;
    let idx: Vec<usize> = (chunk * GT_CHUNK..chunk * GT_CHUNK + rows).collect();
    // chunk-ordinal binding: lane-replicated sources fan chunks across
    // device lanes (values are replica-independent, so GT stays
    // bit-identical for any lane/thread count)
    let field = src.bind_chunk(&idx, chunk)?;
    let (out, nfe) = rk45(&field, xc0, opts)?;
    xc1.copy_from_slice(&out);
    Ok(nfe)
}

impl TeacherSet {
    /// Generate `pairs` ground-truth pairs through `src`, fanning the
    /// fixed-size chunks out over up to `threads` worker threads.
    pub fn generate(
        src: &dyn DistillField,
        dim: usize,
        pairs: usize,
        seed: u64,
        threads: usize,
    ) -> Result<TeacherSet> {
        anyhow::ensure!(pairs > 0, "teacher set needs at least one pair");
        let mut rng = Pcg32::seeded(seed);
        let x0 = rng.normal_vec(pairs * dim);
        let mut x1 = vec![0f32; pairs * dim];
        let opts = Rk45Opts::default();
        let nchunks = (pairs + GT_CHUNK - 1) / GT_CHUNK;
        let workers = threads.max(1).min(nchunks);

        let mut gt_evals = 0u64;
        if workers <= 1 {
            for (ci, (xc0, xc1)) in
                x0.chunks(GT_CHUNK * dim).zip(x1.chunks_mut(GT_CHUNK * dim)).enumerate()
            {
                gt_evals += run_chunk(src, dim, &opts, ci, xc0, xc1)? as u64;
            }
        } else {
            let jobs: Mutex<Vec<(usize, &[f32], &mut [f32])>> = Mutex::new(
                x0.chunks(GT_CHUNK * dim)
                    .zip(x1.chunks_mut(GT_CHUNK * dim))
                    .enumerate()
                    .map(|(ci, (a, b))| (ci, a, b))
                    .collect(),
            );
            let evals = AtomicU64::new(0);
            let errors: Mutex<Vec<anyhow::Error>> = Mutex::new(Vec::new());
            std::thread::scope(|s| {
                for _ in 0..workers {
                    s.spawn(|| loop {
                        let job = lock_ok(&jobs).pop();
                        let (ci, xc0, xc1) = match job {
                            Some(j) => j,
                            None => break,
                        };
                        match run_chunk(src, dim, &opts, ci, xc0, xc1) {
                            Ok(nfe) => {
                                evals.fetch_add(nfe as u64, Ordering::Relaxed);
                            }
                            Err(e) => {
                                lock_ok(&errors).push(e);
                                break;
                            }
                        }
                    });
                }
            });
            if let Some(e) = errors.into_inner().unwrap_or_else(|e| e.into_inner()).pop() {
                return Err(e.context("teacher-trajectory generation"));
            }
            gt_evals = evals.into_inner();
        }
        Ok(TeacherSet {
            dim,
            pairs,
            seed,
            scope: String::new(),
            x0,
            x1,
            gt_evals,
            gt_nfe: gt_evals / nchunks as u64,
        })
    }

    /// Load a cached set if it matches `(dim, pairs, seed, scope)`
    /// exactly — any mismatch (including the field scope) misses, so a
    /// cache generated through one model/guidance never trains another.
    pub fn load_cached(
        path: &Path,
        dim: usize,
        pairs: usize,
        seed: u64,
        scope: &str,
    ) -> Option<TeacherSet> {
        let text = std::fs::read_to_string(path).ok()?;
        let j = Json::parse(&text).ok()?;
        let (cdim, cpairs) = (j.get("dim").as_usize()?, j.get("pairs").as_usize()?);
        let cseed = j.get("seed").as_f64()? as u64;
        let cscope = j.get("scope").as_str().unwrap_or("");
        if cdim != dim || cpairs != pairs || cseed != seed || cscope != scope {
            return None;
        }
        let x0 = j.get("x0").as_f32_vec()?;
        let x1 = j.get("x1").as_f32_vec()?;
        if x0.len() != pairs * dim || x1.len() != pairs * dim {
            return None;
        }
        Some(TeacherSet {
            dim,
            pairs,
            seed,
            scope: scope.to_string(),
            x0,
            x1,
            gt_evals: j.get("gt_evals").as_f64().unwrap_or(0.0) as u64,
            gt_nfe: j.get("gt_nfe").as_f64().unwrap_or(0.0) as u64,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let j = Json::obj(vec![
            ("dim", Json::Num(self.dim as f64)),
            ("pairs", Json::Num(self.pairs as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("scope", Json::Str(self.scope.clone())),
            ("gt_evals", Json::Num(self.gt_evals as f64)),
            ("gt_nfe", Json::Num(self.gt_nfe as f64)),
            ("x0", Json::arr_f32(&self.x0)),
            ("x1", Json::arr_f32(&self.x1)),
        ]);
        // temp + rename: a crash mid-save leaves the previous cache (or
        // none) rather than a truncated file that poisons later runs —
        // load_cached treats any unparseable cache as a miss either way
        crate::util::fsio::write_atomic(path, &j.to_string())
            .with_context(|| format!("writing teacher cache {}", path.display()))
    }

    /// Cache-or-generate: the "generate once" entry the trainer uses.
    /// `scope` joins the cache key (see [`TeacherSet::scope`]).
    pub fn load_or_generate(
        cache: Option<&Path>,
        src: &dyn DistillField,
        dim: usize,
        pairs: usize,
        seed: u64,
        threads: usize,
        scope: &str,
    ) -> Result<TeacherSet> {
        if let Some(path) = cache {
            if let Some(set) = Self::load_cached(path, dim, pairs, seed, scope) {
                return Ok(set);
            }
        }
        let mut set = Self::generate(src, dim, pairs, seed, threads)?;
        set.scope = scope.to_string();
        if let Some(path) = cache {
            set.save(path)?;
        }
        Ok(set)
    }

    /// Gather the pairs `idx` into contiguous row-major minibatch
    /// buffers (reused across iterations by the caller).
    pub fn gather(&self, idx: &[usize], xb0: &mut Vec<f32>, xb1: &mut Vec<f32>) {
        xb0.clear();
        xb1.clear();
        for &i in idx {
            xb0.extend_from_slice(&self.x0[i * self.dim..(i + 1) * self.dim]);
            xb1.extend_from_slice(&self.x1[i * self.dim..(i + 1) * self.dim]);
        }
    }
}

/// `bsz` *distinct* indices drawn uniformly from `[0, total)` via a
/// partial Fisher-Yates shuffle — the unbiased minibatch sampler shared
/// by the Adam trainer and the SPSA refiner (whose contiguous windows
/// used to bias every gradient estimate toward pair order).
pub fn sample_indices(rng: &mut Pcg32, total: usize, bsz: usize) -> Vec<usize> {
    let mut idx = Vec::new();
    sample_indices_into(rng, total, bsz, &mut idx);
    idx
}

/// `sample_indices` into a reused buffer — the trainer's hot loop draws
/// a minibatch every Adam step, and this keeps the draw allocation-free
/// at steady state. Identical draws to `sample_indices` for the same rng
/// stream.
pub fn sample_indices_into(rng: &mut Pcg32, total: usize, bsz: usize, idx: &mut Vec<usize>) {
    let bsz = bsz.min(total);
    idx.clear();
    idx.extend(0..total);
    for i in 0..bsz {
        let j = i + rng.below(total - i);
        idx.swap(i, j);
    }
    idx.truncate(bsz);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::field::GaussianTargetField;
    use crate::solver::scheduler::Scheduler;

    fn test_field() -> GaussianTargetField {
        GaussianTargetField { dim: 3, sched: Scheduler::FmOt, mu: 0.3, s1: 0.4 }
    }

    #[test]
    fn generation_is_thread_count_invariant() {
        let f = test_field();
        let src = UniformField(&f);
        let a = TeacherSet::generate(&src, 3, 20, 11, 1).unwrap();
        let b = TeacherSet::generate(&src, 3, 20, 11, 4).unwrap();
        assert_eq!(a.x0, b.x0);
        assert_eq!(
            a.x1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.x1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "teacher x1 must not depend on the thread count"
        );
        assert_eq!(a.gt_evals, b.gt_evals);
        assert!(a.gt_nfe > 0);
    }

    #[test]
    fn cache_roundtrip_and_mismatch_rejection() {
        let f = test_field();
        let src = UniformField(&f);
        let mut set = TeacherSet::generate(&src, 3, 9, 5, 1).unwrap();
        set.scope = "model-a|w=0.5".into();
        let path = std::env::temp_dir()
            .join(format!("bns-teacher-{}.json", std::process::id()));
        set.save(&path).unwrap();
        let back = TeacherSet::load_cached(&path, 3, 9, 5, "model-a|w=0.5").unwrap();
        assert_eq!(back.x0, set.x0);
        assert_eq!(back.x1, set.x1);
        assert_eq!(back.gt_evals, set.gt_evals);
        assert_eq!(back.scope, set.scope);
        // any key mismatch must miss (forcing regeneration) — including
        // the scope, so another model/guidance never reuses these pairs
        assert!(TeacherSet::load_cached(&path, 3, 9, 6, "model-a|w=0.5").is_none());
        assert!(TeacherSet::load_cached(&path, 3, 8, 5, "model-a|w=0.5").is_none());
        assert!(TeacherSet::load_cached(&path, 2, 9, 5, "model-a|w=0.5").is_none());
        assert!(TeacherSet::load_cached(&path, 3, 9, 5, "model-b|w=0.5").is_none());
        assert!(TeacherSet::load_cached(&path, 3, 9, 5, "").is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sample_indices_distinct_in_range() {
        let mut rng = Pcg32::seeded(3);
        for _ in 0..50 {
            let idx = sample_indices(&mut rng, 13, 6);
            assert_eq!(idx.len(), 6);
            let mut seen = [false; 13];
            for &i in &idx {
                assert!(i < 13);
                assert!(!seen[i], "duplicate index {i}");
                seen[i] = true;
            }
        }
        // bsz == total -> a permutation
        let idx = sample_indices(&mut rng, 7, 7);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..7).collect::<Vec<_>>());
        // bsz > total clamps
        assert_eq!(sample_indices(&mut rng, 3, 9).len(), 3);
    }

    #[test]
    fn gather_picks_the_right_rows() {
        let set = TeacherSet {
            dim: 2,
            pairs: 3,
            seed: 0,
            scope: String::new(),
            x0: vec![0.0, 1.0, 10.0, 11.0, 20.0, 21.0],
            x1: vec![0.5, 1.5, 10.5, 11.5, 20.5, 21.5],
            gt_evals: 0,
            gt_nfe: 0,
        };
        let (mut a, mut b) = (Vec::new(), Vec::new());
        set.gather(&[2, 0], &mut a, &mut b);
        assert_eq!(a, vec![20.0, 21.0, 0.0, 1.0]);
        assert_eq!(b, vec![20.5, 21.5, 0.5, 1.5]);
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;
    use crate::bench_util::{stub_store, StubModel};
    use crate::runtime::Runtime;

    /// `bind_rows` must align labels with the gathered rows — the bug
    /// class the `DistillField` seam exists to prevent.
    #[test]
    fn conditioned_model_binds_matching_labels() {
        let (store, dir) = stub_store(
            "teacher-cond",
            &[StubModel {
                name: "m",
                dim: 2,
                num_classes: 4,
                forwards_per_eval: 1,
                k: -0.4,
                c: 0.0,
                label_scale: 0.5,
                cost: 1,
                buckets: &[4, 8],
            }],
        )
        .unwrap();
        let rt = Runtime::cpu().unwrap();
        let info = store.model("m").unwrap();
        let model = Arc::new(crate::runtime::LoadedModel::load(&rt, info).unwrap());
        let labels = vec![0, 1, 2, 3, 0, 1, 2, 3];
        let src = ConditionedModel::new(model, labels, 0.0);
        let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let full = src.full().eval(0.3, &x).unwrap();
        let idx = [5usize, 2, 7];
        let sub = src.bind_rows(&idx).unwrap();
        let xs: Vec<f32> = idx.iter().flat_map(|&i| x[i * 2..(i + 1) * 2].to_vec()).collect();
        let out = sub.eval(0.3, &xs).unwrap();
        for (r, &i) in idx.iter().enumerate() {
            assert_eq!(
                out[r * 2..(r + 1) * 2],
                full[i * 2..(i + 1) * 2],
                "row {r} (set row {i}) saw the wrong label"
            );
        }
        assert!(src.bind_rows(&[99]).is_err(), "out-of-range index must fail");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// `rebind_rows` must be equivalent to a fresh `bind_rows` — same
    /// labels, same values — while reusing the binding's buffers (the
    /// hot-loop contract the gradient fan relies on).
    #[test]
    fn rebind_rows_matches_fresh_bind() {
        let (store, dir) = stub_store(
            "teacher-rebind",
            &[StubModel {
                name: "m",
                dim: 2,
                num_classes: 4,
                forwards_per_eval: 1,
                k: -0.4,
                c: 0.0,
                label_scale: 0.5,
                cost: 1,
                buckets: &[4, 8],
            }],
        )
        .unwrap();
        let rt = Runtime::cpu().unwrap();
        let info = store.model("m").unwrap();
        let model = Arc::new(crate::runtime::LoadedModel::load(&rt, info).unwrap());
        let src = ConditionedModel::new(model, vec![0, 1, 2, 3, 0, 1], 0.0);
        let x: Vec<f32> = (0..8).map(|i| (i as f32 * 0.21).cos()).collect();
        let mut bound = src.bind_rows(&[0, 1, 2, 3]).unwrap();
        for idx in [[5usize, 2, 0, 4], [1, 1, 3, 0]] {
            src.rebind_rows(&mut bound, &idx).unwrap();
            let fresh = src.bind_rows(&idx).unwrap();
            let a = bound.eval(0.3, &x).unwrap();
            let b = fresh.eval(0.3, &x).unwrap();
            assert_eq!(a, b, "rebind {idx:?} must equal a fresh bind");
        }
        assert!(src.rebind_rows(&mut bound, &[99]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Lane replicas: `bind_chunk` pins chunks round-robin across lanes
    /// and every replica computes identical values.
    #[test]
    fn replicated_chunks_pin_lanes_with_identical_values() {
        let (store, dir) = stub_store(
            "teacher-repl",
            &[StubModel {
                name: "m",
                dim: 2,
                num_classes: 3,
                forwards_per_eval: 1,
                k: -0.6,
                c: 0.1,
                label_scale: 0.25,
                cost: 1,
                buckets: &[4],
            }],
        )
        .unwrap();
        let rt = Runtime::with_lanes(2).unwrap();
        let info = store.model("m").unwrap();
        let labels: Vec<i32> = (0..12).map(|i| (i % 3) as i32).collect();
        let src = ConditionedModel::replicated(&rt, info, labels.clone(), 0.0).unwrap();
        assert_eq!(src.num_replicas(), 2);
        let x: Vec<f32> = (0..8).map(|i| (i as f32 * 0.17).sin()).collect();
        let idx = [0usize, 3, 5, 7];
        let b0 = src.bind_chunk(&idx, 0).unwrap();
        let b1 = src.bind_chunk(&idx, 1).unwrap();
        let (l0, l1) = match (&b0, &b1) {
            (BoundField::Model(m0), BoundField::Model(m1)) => (m0.lane(), m1.lane()),
            _ => panic!("replicated bindings must be model-backed"),
        };
        assert_ne!(l0, l1, "consecutive chunks must land on different lanes");
        assert_eq!(
            b0.eval(0.4, &x).unwrap(),
            b1.eval(0.4, &x).unwrap(),
            "replicas must be value-identical"
        );
        // thread-fanned teacher generation through replicas (2 chunks,
        // one per lane) stays bit-identical to the single-lane
        // single-thread path
        let single = ConditionedModel::new(
            Arc::new(crate::runtime::LoadedModel::load(&rt, info).unwrap()),
            labels,
            0.0,
        );
        let a = TeacherSet::generate(&single, 2, 12, 3, 1).unwrap();
        let b = TeacherSet::generate(&src, 2, 12, 3, 2).unwrap();
        assert_eq!(
            a.x1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.x1.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
