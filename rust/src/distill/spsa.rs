//! SPSA refinement of NS solver coefficients against the PSNR loss —
//! the zeroth-order fallback for fields whose JVP is too expensive or
//! too noisy (the first-order path is `distill::trainer`).
//!
//! Operates in the shared theta space of `distill::theta` (log-increment
//! times with pinned endpoints, raw a/b), draws its ground-truth pairs
//! from the shared teacher store, and samples minibatches with the same
//! unbiased shuffled-index helper as the Adam trainer — contiguous
//! windows used to make every gradient estimate depend on pair order.
//!
//! `refine` is the entry for unconditioned (analytic/test) fields;
//! label-conditioned model fields go through [`refine_with`], whose
//! `DistillField` seam binds the right labels to every generation chunk
//! and minibatch — exactly like the trainer.

use anyhow::Result;

use crate::distill::grad::sample_loss;
use crate::distill::teacher::{sample_indices, DistillField, TeacherSet, UniformField};
use crate::distill::theta::{pack, unpack};
use crate::solver::field::Field;
use crate::solver::ns::NsSolver;
use crate::util::rng::Pcg32;
use crate::util::stats::psnr_from_log_mse;

#[derive(Debug, Clone)]
pub struct RefineConfig {
    pub iters: usize,
    pub pairs: usize,
    pub batch: usize,
    /// SPSA step size (a_k = step / (k + A)^0.602)
    pub step: f64,
    /// SPSA perturbation size (c_k = perturb / (k+1)^0.101)
    pub perturb: f64,
    pub seed: u64,
    /// Teacher-generation fan-out (threads; must be ≥ 1) — the same
    /// knob the Adam trainer's `TrainConfig::threads` plumbs, so the
    /// distill CLI drives both optimizers consistently. Fixed-size
    /// chunking keeps the generated pairs bit-identical for any value.
    pub threads: usize,
}

impl Default for RefineConfig {
    fn default() -> Self {
        RefineConfig {
            iters: 120,
            pairs: 32,
            batch: 16,
            step: 2e-3,
            perturb: 1e-3,
            seed: 7,
            threads: 1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct RefineReport {
    pub initial_psnr: f64,
    pub final_psnr: f64,
    pub iters: usize,
    pub nfe_spent: usize,
    /// Mean RK45 NFE per teacher trajectory (artifact provenance).
    pub gt_nfe: u64,
}

/// Refine `solver` against an *unconditioned* `field` (analytic/test
/// fields, or a model field whose rows are label-uniform). For per-row
/// label conditioning use [`refine_with`].
pub fn refine(
    solver: &NsSolver,
    field: &dyn Field,
    dim: usize,
    cfg: &RefineConfig,
) -> Result<(NsSolver, RefineReport)> {
    refine_with(&UniformField(field), solver, dim, cfg)
}

/// Refine `solver` against a conditioned field source. Ground-truth
/// pairs are produced internally with RK45 through the same source (via
/// the teacher store), and every generation chunk and shuffled minibatch
/// is re-bound to its rows' conditioning — pair i always sees label i.
pub fn refine_with(
    src: &dyn DistillField,
    solver: &NsSolver,
    dim: usize,
    cfg: &RefineConfig,
) -> Result<(NsSolver, RefineReport)> {
    let n = solver.nfe();
    anyhow::ensure!(cfg.threads >= 1, "threads must be >= 1 (got 0)");
    // distinct stream from the teacher's noise draws — perturbation
    // signs and minibatch picks must be independent of the pair data
    // (SPSA's gradient estimate assumes it), same discipline as the
    // Adam trainer's rng
    let mut rng = Pcg32::seeded(cfg.seed.wrapping_add(0x05b5_a5ee));

    // GT pairs through the deployed field (fan-out bit-identical for
    // any thread count)
    let teacher = TeacherSet::generate(src, dim, cfg.pairs, cfg.seed, cfg.threads)?;
    let full = src.full();
    let (x0, x1) = (&teacher.x0, &teacher.x1);
    let mut nfe_spent = teacher.gt_evals as usize;

    let mut theta = pack(solver);
    let p = theta.len();
    let init_loss = sample_loss(solver, full, x0, x1, dim)?;
    nfe_spent += n;
    let initial_psnr = psnr_from_log_mse(init_loss);
    // the init is the first checkpoint candidate: refinement can never
    // return (or --register publish) a solver worse than what it
    // started from — same guarantee as the Adam trainer
    let mut best = (theta.clone(), init_loss);
    let (mut xb0, mut xb1) = (Vec::new(), Vec::new());

    for k in 0..cfg.iters {
        // unbiased minibatch: a shuffled index set, not a contiguous
        // window (shared with the Adam trainer), bound to its own labels
        let bsz = cfg.batch.min(cfg.pairs);
        let idx = sample_indices(&mut rng, cfg.pairs, bsz);
        teacher.gather(&idx, &mut xb0, &mut xb1);
        let bfield = src.bind_rows(&idx)?;

        let ck = cfg.perturb / ((k + 1) as f64).powf(0.101);
        let ak = cfg.step / ((k + 1) as f64 + 10.0).powf(0.602);
        // Rademacher perturbation
        let delta: Vec<f64> =
            (0..p).map(|_| if rng.next_u32() & 1 == 0 { 1.0 } else { -1.0 }).collect();
        let theta_p: Vec<f64> = theta.iter().zip(&delta).map(|(t, d)| t + ck * d).collect();
        let theta_m: Vec<f64> = theta.iter().zip(&delta).map(|(t, d)| t - ck * d).collect();
        let lp = sample_loss(&unpack(&theta_p, n), &bfield, &xb0, &xb1, dim)?;
        let lm = sample_loss(&unpack(&theta_m, n), &bfield, &xb0, &xb1, dim)?;
        nfe_spent += 2 * n;
        let g_scale = (lp - lm) / (2.0 * ck);
        for (t, d) in theta.iter_mut().zip(&delta) {
            *t -= ak * g_scale * d; // SPSA: grad estimate = g_scale / d = g_scale * d (d = ±1)
        }
        // track best on the full pair set every few iters
        if k % 10 == 9 || k + 1 == cfg.iters {
            let l = sample_loss(&unpack(&theta, n), full, x0, x1, dim)?;
            nfe_spent += n;
            if l < best.1 {
                best = (theta.clone(), l);
            }
        }
    }
    let refined = unpack(&best.0, n);
    refined.validate()?;
    let final_psnr = psnr_from_log_mse(best.1);
    Ok((
        refined,
        RefineReport {
            initial_psnr,
            final_psnr,
            iters: cfg.iters,
            nfe_spent,
            gt_nfe: teacher.gt_nfe,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::field::GaussianTargetField;
    use crate::solver::scheduler::Scheduler;
    use crate::solver::taxonomy::euler_ns;

    #[test]
    fn refine_improves_euler_on_gaussian_field() {
        let f = GaussianTargetField { dim: 6, sched: Scheduler::FmOt, mu: 0.4, s1: 0.3 };
        let init = euler_ns(&crate::solver::generic::uniform_times(6));
        let cfg = RefineConfig { iters: 150, pairs: 24, batch: 12, ..Default::default() };
        let (refined, report) = refine(&init, &f, 6, &cfg).unwrap();
        refined.validate().unwrap();
        assert!(
            report.final_psnr > report.initial_psnr + 1.0,
            "no improvement: {} -> {}",
            report.initial_psnr,
            report.final_psnr
        );
    }

    #[test]
    fn refined_solver_serializes() {
        let f = GaussianTargetField { dim: 4, sched: Scheduler::Vp, mu: -0.1, s1: 0.5 };
        let init = euler_ns(&crate::solver::generic::uniform_times(4));
        let cfg = RefineConfig { iters: 20, pairs: 8, batch: 8, ..Default::default() };
        let (refined, _) = refine(&init, &f, 4, &cfg).unwrap();
        let j = refined.to_json().to_string();
        let (back, _) = NsSolver::from_json_str(&j).unwrap();
        assert_eq!(back.nfe(), 4);
    }
}
