//! SPSA refinement of NS solver coefficients against the PSNR loss.
//!
//! theta layout (mirrors eq. 12 with pinned endpoints):
//!   [ log-increments of T_n (n entries) | a (n) | b rows (n(n+1)/2) ]
//! Times are recovered via a softmax-style normalization of positive
//! increments, exactly like the python trainer, so refined solvers stay
//! valid by construction.

use anyhow::Result;

use crate::solver::field::Field;
use crate::solver::ns::NsSolver;
use crate::solver::rk45::{rk45, Rk45Opts};
use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct RefineConfig {
    pub iters: usize,
    pub pairs: usize,
    pub batch: usize,
    /// SPSA step size (a_k = step / (k + A)^0.602)
    pub step: f64,
    /// SPSA perturbation size (c_k = perturb / (k+1)^0.101)
    pub perturb: f64,
    pub seed: u64,
}

impl Default for RefineConfig {
    fn default() -> Self {
        RefineConfig { iters: 120, pairs: 32, batch: 16, step: 2e-3, perturb: 1e-3, seed: 7 }
    }
}

#[derive(Debug, Clone)]
pub struct RefineReport {
    pub initial_psnr: f64,
    pub final_psnr: f64,
    pub iters: usize,
    pub nfe_spent: usize,
}

fn pack(solver: &NsSolver) -> Vec<f64> {
    let n = solver.nfe();
    let mut theta = Vec::with_capacity(n + n + n * (n + 1) / 2);
    for w in solver.times.windows(2) {
        theta.push((w[1] - w[0]).max(1e-9).ln());
    }
    theta.extend_from_slice(&solver.a);
    for row in &solver.b {
        theta.extend_from_slice(row);
    }
    theta
}

fn unpack(theta: &[f64], n: usize) -> NsSolver {
    let incs: Vec<f64> = theta[..n].iter().map(|z| z.exp()).collect();
    let total: f64 = incs.iter().sum();
    let mut times = Vec::with_capacity(n + 1);
    times.push(0.0);
    let mut acc = 0.0;
    for inc in &incs {
        acc += inc / total;
        times.push(acc.min(1.0));
    }
    times[n] = 1.0;
    let a = theta[n..2 * n].to_vec();
    let mut b = Vec::with_capacity(n);
    let mut off = 2 * n;
    for i in 0..n {
        b.push(theta[off..off + i + 1].to_vec());
        off += i + 1;
    }
    NsSolver { times, a, b }
}

fn psnr_loss(solver: &NsSolver, field: &dyn Field, x0: &[f32], x1: &[f32], dim: usize) -> Result<f64> {
    let out = solver.sample(field, x0)?;
    // eq. 13: mean over samples of log per-sample MSE
    let n = out.len() / dim;
    let mut acc = 0.0;
    for i in 0..n {
        let mse: f64 = out[i * dim..(i + 1) * dim]
            .iter()
            .zip(&x1[i * dim..(i + 1) * dim])
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / dim as f64;
        acc += mse.max(1e-20).ln();
    }
    Ok(acc / n as f64)
}

/// Refine `solver` against `field` (labels/guidance already bound).
/// Returns the refined solver plus a report; ground-truth pairs are
/// produced internally with RK45 through the same field.
pub fn refine(
    solver: &NsSolver,
    field: &dyn Field,
    dim: usize,
    cfg: &RefineConfig,
) -> Result<(NsSolver, RefineReport)> {
    let n = solver.nfe();
    let mut rng = Pcg32::seeded(cfg.seed);

    // GT pairs through the deployed field
    let x0 = rng.normal_vec(cfg.pairs * dim);
    let (x1, gt_nfe) = rk45(field, &x0, &Rk45Opts::default())?;
    let mut nfe_spent = gt_nfe;

    let mut theta = pack(solver);
    let p = theta.len();
    let initial_psnr =
        -10.0 * psnr_loss(solver, field, &x0, &x1, dim)? / std::f64::consts::LN_10
            + 10.0 * (4f64).log10();
    let mut best = (theta.clone(), f64::INFINITY);

    for k in 0..cfg.iters {
        // minibatch of pairs
        let bsz = cfg.batch.min(cfg.pairs);
        let start = rng.below(cfg.pairs - bsz + 1);
        let xb0 = &x0[start * dim..(start + bsz) * dim];
        let xb1 = &x1[start * dim..(start + bsz) * dim];

        let ck = cfg.perturb / ((k + 1) as f64).powf(0.101);
        let ak = cfg.step / ((k + 1) as f64 + 10.0).powf(0.602);
        // Rademacher perturbation
        let delta: Vec<f64> =
            (0..p).map(|_| if rng.next_u32() & 1 == 0 { 1.0 } else { -1.0 }).collect();
        let theta_p: Vec<f64> = theta.iter().zip(&delta).map(|(t, d)| t + ck * d).collect();
        let theta_m: Vec<f64> = theta.iter().zip(&delta).map(|(t, d)| t - ck * d).collect();
        let lp = psnr_loss(&unpack(&theta_p, n), field, xb0, xb1, dim)?;
        let lm = psnr_loss(&unpack(&theta_m, n), field, xb0, xb1, dim)?;
        nfe_spent += 2 * n;
        let g_scale = (lp - lm) / (2.0 * ck);
        for (t, d) in theta.iter_mut().zip(&delta) {
            *t -= ak * g_scale * d; // SPSA: grad estimate = g_scale / d = g_scale * d (d = ±1)
        }
        // track best on the full pair set every few iters
        if k % 10 == 9 || k + 1 == cfg.iters {
            let l = psnr_loss(&unpack(&theta, n), field, &x0, &x1, dim)?;
            nfe_spent += n;
            if l < best.1 {
                best = (theta.clone(), l);
            }
        }
    }
    let refined = unpack(&best.0, n);
    refined.validate()?;
    let final_psnr =
        -10.0 * best.1 / std::f64::consts::LN_10 + 10.0 * (4f64).log10();
    Ok((
        refined,
        RefineReport { initial_psnr, final_psnr, iters: cfg.iters, nfe_spent },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::field::GaussianTargetField;
    use crate::solver::scheduler::Scheduler;
    use crate::solver::taxonomy::euler_ns;

    #[test]
    fn pack_unpack_roundtrip() {
        let s = euler_ns(&[0.0, 0.2, 0.55, 1.0]);
        let theta = pack(&s);
        let s2 = unpack(&theta, 3);
        for (a, b) in s.times.iter().zip(&s2.times) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        assert_eq!(s.a, s2.a);
        assert_eq!(s.b, s2.b);
    }

    #[test]
    fn refine_improves_euler_on_gaussian_field() {
        let f = GaussianTargetField { dim: 6, sched: Scheduler::FmOt, mu: 0.4, s1: 0.3 };
        let init = euler_ns(&crate::solver::generic::uniform_times(6));
        let cfg = RefineConfig { iters: 150, pairs: 24, batch: 12, ..Default::default() };
        let (refined, report) = refine(&init, &f, 6, &cfg).unwrap();
        refined.validate().unwrap();
        assert!(
            report.final_psnr > report.initial_psnr + 1.0,
            "no improvement: {} -> {}",
            report.initial_psnr,
            report.final_psnr
        );
    }

    #[test]
    fn refined_solver_serializes() {
        let f = GaussianTargetField { dim: 4, sched: Scheduler::Vp, mu: -0.1, s1: 0.5 };
        let init = euler_ns(&crate::solver::generic::uniform_times(4));
        let cfg = RefineConfig { iters: 20, pairs: 8, batch: 8, ..Default::default() };
        let (refined, _) = refine(&init, &f, 4, &cfg).unwrap();
        let j = refined.to_json().to_string();
        let (back, _) = NsSolver::from_json_str(&j).unwrap();
        assert_eq!(back.nfe(), 4);
    }
}
