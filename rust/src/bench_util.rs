//! Shared harness for benches and examples: artifact loading, evaluation
//! sets, ground-truth generation, metric sweeps, and a plain-text table
//! printer (offline substrate for criterion's reporting).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::runtime::{ArtifactStore, ModelField, ModelInfo, Runtime};
use crate::solver::field::{CountingField, Field};
use crate::solver::ns::{NsSolver, SolverMeta};
use crate::solver::rk45::{rk45, Rk45Opts};
use crate::solver::Solver;
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::util::stats::batch_psnr;

/// Everything a bench needs in one place.
pub struct Bench {
    pub store: Arc<ArtifactStore>,
    pub rt: Arc<Runtime>,
}

impl Bench {
    pub fn init() -> Result<Bench> {
        let dir = crate::default_artifacts_dir();
        let store = Arc::new(ArtifactStore::load(&dir).with_context(|| {
            format!(
                "loading artifacts from {} — run `make artifacts` first",
                dir.display()
            )
        })?);
        let rt = Arc::new(Runtime::cpu()?);
        Ok(Bench { store, rt })
    }

    /// Deterministic eval set: n noise rows + labels for `model`.
    pub fn eval_set(&self, info: &ModelInfo, n: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Pcg32::seeded(seed);
        let x0 = rng.normal_vec(n * info.dim);
        let labels: Vec<i32> = (0..n).map(|_| rng.below(info.num_classes) as i32).collect();
        (x0, labels)
    }

    pub fn field(&self, info: &ModelInfo, labels: Vec<i32>, w: f32) -> Result<ModelField> {
        ModelField::new(&self.rt, info, labels, w)
    }

    /// RK45 ground truth; returns (x1, nfe).
    pub fn ground_truth(&self, field: &dyn Field, x0: &[f32]) -> Result<(Vec<f32>, usize)> {
        rk45(field, x0, &Rk45Opts::default())
    }

    /// PSNR of `solver` against a precomputed GT, on the same x0.
    pub fn solver_psnr(
        &self,
        solver: &dyn Solver,
        field: &dyn Field,
        x0: &[f32],
        gt: &[f32],
        dim: usize,
    ) -> Result<f64> {
        let out = solver.sample(field, x0)?;
        Ok(batch_psnr(&out, gt, dim))
    }

    /// Generate `n` samples with `solver` (chunked over the largest
    /// bucket) and return them row-major — for distribution metrics.
    pub fn generate(
        &self,
        info: &ModelInfo,
        solver: &dyn Solver,
        w: f32,
        n: usize,
        seed: u64,
    ) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(n * info.dim);
        let mut rng = Pcg32::seeded(seed);
        let chunk = 64;
        let mut done = 0;
        while done < n {
            let take = chunk.min(n - done);
            let x0 = rng.normal_vec(take * info.dim);
            let labels: Vec<i32> =
                (0..take).map(|_| rng.below(info.num_classes) as i32).collect();
            let field = self.field(info, labels, w)?;
            out.extend(solver.sample(&field, &x0)?);
            done += take;
        }
        Ok(out)
    }

    /// Same but with RK45 (for GT-FD columns); returns (samples, mean nfe).
    pub fn generate_gt(
        &self,
        info: &ModelInfo,
        w: f32,
        n: usize,
        seed: u64,
    ) -> Result<(Vec<f32>, f64)> {
        let mut out = Vec::with_capacity(n * info.dim);
        let mut rng = Pcg32::seeded(seed);
        let chunk = 64;
        let mut done = 0;
        let mut nfes = 0usize;
        let mut runs = 0usize;
        while done < n {
            let take = chunk.min(n - done);
            let x0 = rng.normal_vec(take * info.dim);
            let labels: Vec<i32> =
                (0..take).map(|_| rng.below(info.num_classes) as i32).collect();
            let field = self.field(info, labels, w)?;
            let (x1, nfe) = self.ground_truth(&field, &x0)?;
            out.extend(x1);
            nfes += nfe;
            runs += 1;
            done += take;
        }
        Ok((out, nfes as f64 / runs as f64))
    }
}

/// Count NFE while sampling (wraps CountingField).
pub fn sample_counting(
    solver: &dyn Solver,
    field: &dyn Field,
    x0: &[f32],
) -> Result<(Vec<f32>, usize)> {
    let cf = CountingField::new(field);
    let out = solver.sample(&cf, x0)?;
    Ok((out, cf.count()))
}

// ---------------------------------------------------------------------------
// stub artifact stores (default-build tests/benches; see runtime/backend.rs)
// ---------------------------------------------------------------------------

/// Description of one stub-backend model (an affine velocity field the
/// stub device backend can "execute"); see `runtime::backend`.
pub struct StubModel<'a> {
    pub name: &'a str,
    pub dim: usize,
    pub num_classes: usize,
    /// Forward passes per eval per row (2 = CFG-composed, 1 = uncond).
    pub forwards_per_eval: usize,
    /// Field: u = k·x + c + label_scale·label (per element).
    pub k: f64,
    pub c: f64,
    /// Per-label bias — nonzero makes outputs label-sensitive, so
    /// cross-lane/pooling corruption tests can detect swapped rows.
    pub label_scale: f64,
    /// Stub compute passes per exec (identical output, `cost`× the wall
    /// time) — lets load benches emulate heavier models.
    pub cost: usize,
    pub buckets: &'a [usize],
}

/// Write a complete, loadable artifact directory (manifest + per-bucket
/// stub model files, no distilled solvers) for the stub device backend.
/// Lets `cargo test` and benches drive the full engine/runtime stack
/// without compiled HLO artifacts.
pub fn write_stub_artifacts(dir: &Path, models: &[StubModel]) -> Result<()> {
    use std::collections::BTreeMap;
    std::fs::create_dir_all(dir.join("models"))?;
    let mut model_entries: BTreeMap<String, Json> = BTreeMap::new();
    for m in models {
        let mut buckets = Vec::new();
        for &b in m.buckets {
            let rel = format!("models/{}_b{b}.stub.json", m.name);
            let spec = Json::obj(vec![(
                "bns_stub_field",
                Json::obj(vec![
                    ("k", Json::Num(m.k)),
                    ("c", Json::Num(m.c)),
                    ("label_scale", Json::Num(m.label_scale)),
                    ("cost", Json::Num(m.cost.max(1) as f64)),
                ]),
            )]);
            crate::util::fsio::write_atomic(&dir.join(&rel), &spec.to_string())?;
            buckets.push(Json::obj(vec![
                ("batch", Json::Num(b as f64)),
                ("path", Json::Str(rel)),
            ]));
        }
        model_entries.insert(
            m.name.to_string(),
            Json::obj(vec![
                ("scheduler", Json::Str("fm_ot".into())),
                ("parametrization", Json::Str("velocity".into())),
                ("dim", Json::Num(m.dim as f64)),
                ("num_classes", Json::Num(m.num_classes as f64)),
                ("null_class", Json::Num(m.num_classes as f64)),
                ("data", Json::Str("images".into())),
                ("forwards_per_eval", Json::Num(m.forwards_per_eval as f64)),
                ("artifacts", Json::Arr(buckets)),
            ]),
        );
    }
    // minimal-but-valid FD-synth block (identity-ish 2-feature extractor)
    let dim = models.first().map(|m| m.dim).unwrap_or(2);
    let hidden = 2;
    let feat_dim = 2;
    let fd = Json::obj(vec![
        ("dim", Json::Num(dim as f64)),
        ("feat_hidden", Json::Num(hidden as f64)),
        ("feat_dim", Json::Num(feat_dim as f64)),
        ("w1", Json::arr_f64(&vec![0.1; dim * hidden])),
        ("b1", Json::arr_f64(&[0.0; 2])),
        ("w2", Json::arr_f64(&[1.0, 0.0, 0.0, 1.0])),
        ("ref_mean", Json::arr_f64(&[0.0, 0.0])),
        ("ref_cov", Json::arr_f64(&[1.0, 0.0, 0.0, 1.0])),
    ]);
    let manifest = Json::obj(vec![
        ("models", Json::Obj(model_entries)),
        ("solvers", Json::Arr(Vec::new())),
        ("fd", fd),
    ]);
    // atomic: a torn manifest would make the whole artifact dir unloadable
    crate::util::fsio::write_atomic(&dir.join("manifest.json"), &manifest.to_string())?;
    Ok(())
}

/// Write `solvers/<name>.json` (coefficients + full `SolverMeta`
/// provenance) under an artifact directory and register it in
/// `manifest.json`, so rust-distilled solvers load exactly like
/// build-time ones on the next `ArtifactStore::load`. Idempotent:
/// re-adding a name overwrites the file and keeps one manifest entry.
pub fn add_solver_artifact(
    dir: &Path,
    name: &str,
    solver: &NsSolver,
    meta: &SolverMeta,
) -> Result<()> {
    solver.validate()?;
    std::fs::create_dir_all(dir.join("solvers"))?;
    let rel = format!("solvers/{name}.json");
    crate::util::fsio::write_atomic(&dir.join(&rel), &solver.to_json_with_meta(meta).to_string())?;
    let mpath = dir.join("manifest.json");
    let text = std::fs::read_to_string(&mpath)
        .with_context(|| format!("reading {}", mpath.display()))?;
    let mut manifest = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
    match &mut manifest {
        Json::Obj(map) => {
            let solvers = map
                .entry("solvers".to_string())
                .or_insert_with(|| Json::Arr(Vec::new()));
            match solvers {
                Json::Arr(v) => {
                    if !v.iter().any(|e| e.as_str() == Some(rel.as_str())) {
                        v.push(Json::Str(rel.clone()));
                    }
                }
                _ => anyhow::bail!("manifest.solvers is not an array"),
            }
        }
        _ => anyhow::bail!("manifest root is not an object"),
    }
    // atomic: registration must never leave a half-written manifest even
    // if the process dies mid-update
    crate::util::fsio::write_atomic(&mpath, &manifest.to_string())?;
    Ok(())
}

/// Write stub artifacts to a per-process temp dir and load them as an
/// `ArtifactStore` — the one-liner tests and benches share. The caller
/// owns cleanup of the returned directory.
pub fn stub_store(tag: &str, models: &[StubModel]) -> Result<(Arc<ArtifactStore>, PathBuf)> {
    let dir = std::env::temp_dir().join(format!("bns-stubstore-{}-{tag}", std::process::id()));
    write_stub_artifacts(&dir, models)?;
    Ok((Arc::new(ArtifactStore::load(&dir)?), dir))
}

/// Description of one seeded `bns_mlp_field` model (the real-compute CPU
/// backend; see `runtime::backend` and `kernels::mlp`). Weights are
/// generated deterministically from `seed` with scales that keep
/// activations O(1) at any depth.
pub struct MlpModelSpec<'a> {
    pub name: &'a str,
    pub dim: usize,
    pub hidden: usize,
    /// Time/label embedding width (even, >= 2).
    pub emb: usize,
    pub depth: usize,
    pub num_classes: usize,
    /// Guided field: 2 forwards per eval (cond + null) and a CFG combine.
    pub cfg: bool,
    pub seed: u64,
    pub buckets: &'a [usize],
}

/// Write a complete, loadable artifact directory of `bns_mlp_field`
/// models (manifest + per-bucket weight files, no distilled solvers) —
/// the real-compute analogue of [`write_stub_artifacts`]. The same seed
/// always emits bit-identical weights, so tests can rebuild equal stores.
pub fn write_mlp_artifacts(dir: &Path, models: &[MlpModelSpec]) -> Result<()> {
    use std::collections::BTreeMap;
    std::fs::create_dir_all(dir.join("models"))?;
    let mut model_entries: BTreeMap<String, Json> = BTreeMap::new();
    for m in models {
        anyhow::ensure!(m.emb >= 2 && m.emb % 2 == 0, "mlp spec: emb must be even and >= 2");
        anyhow::ensure!(m.depth >= 1, "mlp spec: depth must be >= 1");
        let mut rng = Pcg32::seeded(m.seed);
        let mut arr = |n: usize, s: f32| {
            Json::arr_f32(&rng.normal_vec(n).iter().map(|v| v * s).collect::<Vec<_>>())
        };
        let s1 = 0.5 / (m.dim as f32).sqrt();
        let s2 = 0.25 / (m.hidden as f32).sqrt();
        let sm = 0.1 / (m.emb as f32).sqrt();
        let blocks: Vec<Json> = (0..m.depth)
            .map(|_| {
                Json::obj(vec![
                    ("w1", arr(m.dim * m.hidden, s1)),
                    ("b1", arr(m.hidden, 0.05)),
                    ("w2", arr(m.hidden * m.dim, s2)),
                    ("b2", arr(m.dim, 0.01)),
                    ("mw", arr(m.emb * 2 * m.dim, sm)),
                    ("mb", arr(2 * m.dim, 0.01)),
                ])
            })
            .collect();
        let spec = Json::obj(vec![
            ("dim", Json::Num(m.dim as f64)),
            ("hidden", Json::Num(m.hidden as f64)),
            ("emb", Json::Num(m.emb as f64)),
            ("num_classes", Json::Num(m.num_classes as f64)),
            ("null_class", Json::Num(m.num_classes as f64)),
            ("cfg", Json::Bool(m.cfg)),
            ("cls_emb", arr((m.num_classes + 1) * m.emb, 0.2)),
            ("blocks", Json::Arr(blocks)),
        ]);
        let body = Json::obj(vec![("bns_mlp_field", spec)]).to_string();
        let mut buckets = Vec::new();
        for &b in m.buckets {
            // one identical weight file per bucket: the store's bucket
            // chunking expects a path per batch size
            let rel = format!("models/{}_b{b}.mlp.json", m.name);
            crate::util::fsio::write_atomic(&dir.join(&rel), &body)?;
            buckets.push(Json::obj(vec![
                ("batch", Json::Num(b as f64)),
                ("path", Json::Str(rel)),
            ]));
        }
        model_entries.insert(
            m.name.to_string(),
            Json::obj(vec![
                ("scheduler", Json::Str("fm_ot".into())),
                ("parametrization", Json::Str("velocity".into())),
                ("dim", Json::Num(m.dim as f64)),
                ("num_classes", Json::Num(m.num_classes as f64)),
                ("null_class", Json::Num(m.num_classes as f64)),
                ("data", Json::Str("images".into())),
                ("forwards_per_eval", Json::Num(if m.cfg { 2.0 } else { 1.0 })),
                ("artifacts", Json::Arr(buckets)),
            ]),
        );
    }
    let dim = models.first().map(|m| m.dim).unwrap_or(2);
    let hidden = 2;
    let feat_dim = 2;
    let fd = Json::obj(vec![
        ("dim", Json::Num(dim as f64)),
        ("feat_hidden", Json::Num(hidden as f64)),
        ("feat_dim", Json::Num(feat_dim as f64)),
        ("w1", Json::arr_f64(&vec![0.1; dim * hidden])),
        ("b1", Json::arr_f64(&[0.0; 2])),
        ("w2", Json::arr_f64(&[1.0, 0.0, 0.0, 1.0])),
        ("ref_mean", Json::arr_f64(&[0.0, 0.0])),
        ("ref_cov", Json::arr_f64(&[1.0, 0.0, 0.0, 1.0])),
    ]);
    let manifest = Json::obj(vec![
        ("models", Json::Obj(model_entries)),
        ("solvers", Json::Arr(Vec::new())),
        ("fd", fd),
    ]);
    // atomic: a torn manifest would make the whole artifact dir unloadable
    crate::util::fsio::write_atomic(&dir.join("manifest.json"), &manifest.to_string())?;
    Ok(())
}

/// Write mlp artifacts to a per-process temp dir and load them as an
/// `ArtifactStore` — the real-compute sibling of [`stub_store`]. The
/// caller owns cleanup of the returned directory.
pub fn mlp_store(tag: &str, models: &[MlpModelSpec]) -> Result<(Arc<ArtifactStore>, PathBuf)> {
    let dir = std::env::temp_dir().join(format!("bns-mlpstore-{}-{tag}", std::process::id()));
    write_mlp_artifacts(&dir, models)?;
    Ok((Arc::new(ArtifactStore::load(&dir)?), dir))
}

// ---------------------------------------------------------------------------
// reporting
// ---------------------------------------------------------------------------

/// Fixed-width table printer used by every bench.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate().take(ncol) {
                s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Append a result blob to results/<name>.json (created fresh each run).
pub fn write_results(name: &str, j: &Json) -> Result<PathBuf> {
    std::fs::create_dir_all("results")?;
    let path = PathBuf::from(format!("results/{name}.json"));
    crate::util::fsio::write_atomic(&path, &j.to_string())?;
    Ok(path)
}

/// Wall-clock timer helper for §Perf logs.
pub struct Timer(Instant, &'static str);

impl Timer {
    pub fn start(label: &'static str) -> Timer {
        Timer(Instant::now(), label)
    }

    pub fn stop(self) -> f64 {
        let dt = self.0.elapsed().as_secs_f64();
        eprintln!("[time] {}: {:.2}s", self.1, dt);
        dt
    }
}
