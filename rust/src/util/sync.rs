//! Poison-tolerant locking helpers.
//!
//! The serving plane must not panic (bns-lint rule `panic_free`, DESIGN.md
//! §10), and `Mutex::lock().unwrap()` is a panic waiting to happen: a mutex
//! is poisoned only when another thread panicked while holding it, and
//! propagating that panic into a reactor or engine worker would take the
//! whole plane down with it. Every shared structure in this crate guarded
//! by a mutex (metrics counters, compile caches, scratch buffers, teacher
//! job queues) is valid after any partial update — counters may be off by
//! one sample, a cache entry may be absent — so the right recovery is to
//! take the data anyway and keep serving.
//!
//! `lock_ok` / `read_ok` / `write_ok` / `wait_ok` do exactly that: on
//! poison they strip the `PoisonError` wrapper and hand back the guard.

use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock_ok<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Read-lock an `RwLock`, recovering from poison.
pub fn read_ok<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

/// Write-lock an `RwLock`, recovering from poison.
pub fn write_ok<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

/// Block on a condvar, recovering the re-acquired guard from poison.
pub fn wait_ok<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_ok_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock_ok(&m), 7);
    }

    #[test]
    fn rwlock_helpers_round_trip() {
        let l = RwLock::new(1u32);
        *write_ok(&l) = 2;
        assert_eq!(*read_ok(&l), 2);
    }
}
