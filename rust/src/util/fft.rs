//! Radix-2 FFT for the audio perceptual proxies (Tables 6/7 substitutes):
//! spectral-envelope "style" similarity needs power spectra of length-128
//! waveforms. Offline substrate (no FFT crate in the image).

use std::f64::consts::PI;

/// In-place iterative radix-2 Cooley-Tukey over interleaved (re, im).
/// `n` must be a power of two.
pub fn fft_inplace(re: &mut [f64], im: &mut [f64]) {
    let n = re.len();
    assert_eq!(n, im.len());
    assert!(n.is_power_of_two(), "fft length must be a power of two");
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let mut len = 2;
    while len <= n {
        let ang = -2.0 * PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut cr, mut ci) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let (ur, ui) = (re[i + k], im[i + k]);
                let (vr, vi) = (
                    re[i + k + len / 2] * cr - im[i + k + len / 2] * ci,
                    re[i + k + len / 2] * ci + im[i + k + len / 2] * cr,
                );
                re[i + k] = ur + vr;
                im[i + k] = ui + vi;
                re[i + k + len / 2] = ur - vr;
                im[i + k + len / 2] = ui - vi;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Power spectrum (first n/2+1 bins) of a real signal.
pub fn power_spectrum(x: &[f32]) -> Vec<f64> {
    let n = x.len().next_power_of_two();
    let mut re: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    re.resize(n, 0.0);
    let mut im = vec![0.0f64; n];
    fft_inplace(&mut re, &mut im);
    (0..=n / 2).map(|k| re[k] * re[k] + im[k] * im[k]).collect()
}

/// Log-band spectral envelope: mean log-power in `bands` geometric bands.
/// This is the "speaker style" embedding proxy for Table 6.
pub fn spectral_envelope(x: &[f32], bands: usize) -> Vec<f64> {
    let ps = power_spectrum(x);
    let nb = ps.len() - 1; // skip DC
    let mut env = vec![0.0f64; bands];
    let mut cnt = vec![0usize; bands];
    for k in 1..ps.len() {
        // geometric band index
        let frac = (k as f64).ln() / (nb as f64).ln();
        let b = ((frac * bands as f64) as usize).min(bands - 1);
        env[b] += (ps[k] + 1e-12).ln();
        cnt[b] += 1;
    }
    for b in 0..bands {
        if cnt[b] > 0 {
            env[b] /= cnt[b] as f64;
        }
    }
    env
}

/// Cosine similarity between two vectors.
pub fn cosine(a: &[f64], b: &[f64]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
    dot / (na * nb).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_impulse_is_flat() {
        let mut re = vec![0.0; 8];
        let mut im = vec![0.0; 8];
        re[0] = 1.0;
        fft_inplace(&mut re, &mut im);
        for k in 0..8 {
            assert!((re[k] - 1.0).abs() < 1e-12);
            assert!(im[k].abs() < 1e-12);
        }
    }

    #[test]
    fn fft_sine_peaks_at_bin() {
        // sin(2*pi*4*t/64): energy concentrated at bin 4
        let x: Vec<f32> = (0..64)
            .map(|i| (2.0 * PI * 4.0 * i as f64 / 64.0).sin() as f32)
            .collect();
        let ps = power_spectrum(&x);
        let peak = ps
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(peak, 4);
    }

    #[test]
    fn fft_parseval() {
        let x: Vec<f32> = (0..32).map(|i| ((i * 13 % 7) as f32 - 3.0) / 3.0).collect();
        let mut re: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let mut im = vec![0.0; 32];
        fft_inplace(&mut re, &mut im);
        let time_e: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
        let freq_e: f64 = re.iter().zip(&im).map(|(r, i)| r * r + i * i).sum::<f64>() / 32.0;
        assert!((time_e - freq_e).abs() < 1e-9 * time_e.max(1.0));
    }

    #[test]
    fn cosine_bounds() {
        let a = [1.0, 2.0, 3.0];
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-12);
        let b = [-1.0, -2.0, -3.0];
        assert!((cosine(&a, &b) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn envelope_distinguishes_bands() {
        let low: Vec<f32> = (0..128)
            .map(|i| (2.0 * PI * 2.0 * i as f64 / 128.0).sin() as f32)
            .collect();
        let high: Vec<f32> = (0..128)
            .map(|i| (2.0 * PI * 50.0 * i as f64 / 128.0).sin() as f32)
            .collect();
        let el = spectral_envelope(&low, 8);
        let eh = spectral_envelope(&high, 8);
        let sim = cosine(&el, &eh);
        let self_sim = cosine(&el, &el);
        assert!(self_sim > sim, "self {self_sim} vs cross {sim}");
    }
}
