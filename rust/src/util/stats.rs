//! Summary statistics and latency histograms for metrics and benches
//! (offline substrate replacing `criterion`'s internals, DESIGN.md §3).

/// Streaming summary: count / mean / min / max / variance (Welford).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub count: u64,
    pub mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { count: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        let delta = x - self.mean;
        self.mean += delta / self.count as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn var(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            self.m2 / (self.count - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Fixed-boundary log-scale latency histogram (microseconds), suitable for
/// p50/p95/p99 queries without storing samples.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [lo * GROWTH^i, lo * GROWTH^{i+1})
    counts: Vec<u64>,
    lo_us: f64,
    growth: f64,
    pub total: u64,
    pub sum_us: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        // 1us .. ~114s in 96 log buckets (growth 1.21)
        LatencyHistogram { counts: vec![0; 96], lo_us: 1.0, growth: 1.21, total: 0, sum_us: 0.0 }
    }

    fn bucket(&self, us: f64) -> usize {
        if us <= self.lo_us {
            return 0;
        }
        let b = (us / self.lo_us).ln() / self.growth.ln();
        (b as usize).min(self.counts.len() - 1)
    }

    pub fn record(&mut self, dur: std::time::Duration) {
        self.record_us(dur.as_secs_f64() * 1e6);
    }

    pub fn record_us(&mut self, us: f64) {
        let b = self.bucket(us);
        self.counts[b] += 1;
        self.total += 1;
        self.sum_us += us;
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us / self.total as f64
        }
    }

    /// Approximate quantile (upper bound of the containing bucket).
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return self.lo_us * self.growth.powi(i as i32 + 1);
            }
        }
        self.lo_us * self.growth.powi(self.counts.len() as i32)
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
    }
}

/// PSNR between two equal-length f32 buffers, data range [-1, 1]
/// (peak^2 = 4) — matches python/compile/bns.py PEAK_SQ.
pub fn psnr(pred: &[f32], reference: &[f32]) -> f64 {
    assert_eq!(pred.len(), reference.len());
    let mse: f64 = pred
        .iter()
        .zip(reference)
        .map(|(a, b)| {
            let d = (*a - *b) as f64;
            d * d
        })
        .sum::<f64>()
        / pred.len() as f64;
    10.0 * (4.0 / mse.max(1e-20)).log10()
}

/// The eq. 13 per-sample guard, shared by the loss (`distill::grad::
/// log_mse_loss`) and the adjoint loop of the wavefront gradient engine
/// so the NaN/clamp edge cases can never drift apart:
///
/// * a NaN MSE (a diverged solver: `inf - inf` in the f32 combine) scores
///   as the *worst* loss — `f64::max(NaN, eps)` returns eps, which would
///   otherwise make garbage look like the best checkpoint ever seen;
/// * the MSE is clamped below at 1e-20 before the log.
///
/// Returns `(loss term, adjoint live)`: the per-sample `ln(mse)` term,
/// and whether the loss is differentiable at this sample — in the clamp
/// region and for non-finite MSE the loss is treated as flat, so the
/// per-sample adjoint must be zeroed there.
pub fn log_mse_term(mse: f64) -> (f64, bool) {
    if mse.is_nan() {
        (f64::INFINITY, false)
    } else {
        (mse.max(1e-20).ln(), mse.is_finite() && mse > 1e-20)
    }
}

/// PSNR in dB from a mean log-MSE (the eq. 13 training loss), under the
/// same data-range convention as [`psnr`]: range [-1, 1], peak² = 4 —
/// matches python/compile/bns.py PEAK_SQ. Single home for the
/// `-10·log_mse/ln10 + 10·log10(4)` conversion (previously hand-inlined
/// in the SPSA refiner and benches).
pub fn psnr_from_log_mse(log_mse: f64) -> f64 {
    -10.0 * log_mse / std::f64::consts::LN_10 + 10.0 * 4f64.log10()
}

/// SNR in dB of `pred` against `reference` (Fig. 6 convention):
/// 10 log10(|ref|^2 / |ref - pred|^2).
pub fn snr_db(pred: &[f32], reference: &[f32]) -> f64 {
    assert_eq!(pred.len(), reference.len());
    let sig: f64 = reference.iter().map(|x| (*x as f64).powi(2)).sum();
    let err: f64 = pred
        .iter()
        .zip(reference)
        .map(|(a, b)| ((*a - *b) as f64).powi(2))
        .sum();
    10.0 * (sig.max(1e-20) / err.max(1e-20)).log10()
}

/// Mean per-sample PSNR over a batch stored row-major.
pub fn batch_psnr(pred: &[f32], reference: &[f32], dim: usize) -> f64 {
    let n = pred.len() / dim;
    (0..n)
        .map(|i| psnr(&pred[i * dim..(i + 1) * dim], &reference[i * dim..(i + 1) * dim]))
        .sum::<f64>()
        / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_known() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record_us(i as f64);
        }
        let p50 = h.quantile_us(0.5);
        let p95 = h.quantile_us(0.95);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        // log-bucket approximation: within a growth factor of truth
        assert!(p50 > 300.0 && p50 < 800.0, "p50 {p50}");
        assert!(p99 > 700.0 && p99 < 1500.0, "p99 {p99}");
    }

    #[test]
    fn histogram_conservation() {
        let mut h = LatencyHistogram::new();
        for i in 0..500 {
            h.record_us((i * 37 % 10_000) as f64 + 1.0);
        }
        assert_eq!(h.total, 500);
        let mut h2 = LatencyHistogram::new();
        h2.record_us(5.0);
        h2.merge(&h);
        assert_eq!(h2.total, 501);
    }

    #[test]
    fn psnr_identical_is_large() {
        let x = vec![0.25f32; 64];
        assert!(psnr(&x, &x) > 190.0);
    }

    #[test]
    fn psnr_known_value() {
        // constant error of 0.2: mse = 0.04, psnr = 10 log10(4/0.04) = 20
        let a = vec![0.0f32; 32];
        let b = vec![0.2f32; 32];
        assert!((psnr(&b, &a) - 20.0).abs() < 1e-5); // f32 rounding
    }

    /// Pins the data-range convention: log-MSE -> dB must agree with the
    /// direct `psnr` (peak² = 4), on a known value and on random data.
    #[test]
    fn psnr_from_log_mse_matches_psnr() {
        // constant error of 0.2: mse = 0.04 -> 20 dB (same as psnr_known_value)
        assert!((psnr_from_log_mse((0.04f64).ln()) - 20.0).abs() < 1e-9);
        let a: Vec<f32> = (0..48).map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.1).collect();
        let b: Vec<f32> = (0..48).map(|i| ((i * 11 % 23) as f32 - 11.0) * 0.08).collect();
        let mse: f64 = a
            .iter()
            .zip(&b)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            / a.len() as f64;
        assert!((psnr_from_log_mse(mse.ln()) - psnr(&a, &b)).abs() < 1e-9);
    }

    /// Pins the shared eq. 13 guard: NaN scores worst (never best), the
    /// clamp floor applies, and the adjoint is flat exactly in the
    /// clamp/non-finite region.
    #[test]
    fn log_mse_term_guards() {
        assert_eq!(log_mse_term(f64::NAN), (f64::INFINITY, false));
        assert_eq!(log_mse_term(f64::INFINITY), (f64::INFINITY, false));
        assert_eq!(log_mse_term(0.0), ((1e-20f64).ln(), false));
        assert_eq!(log_mse_term(1e-30), ((1e-20f64).ln(), false));
        let (t, live) = log_mse_term(0.04);
        assert!((t - (0.04f64).ln()).abs() < 1e-15);
        assert!(live);
    }

    #[test]
    fn snr_db_known() {
        // ref = 1s, err = 0.1s: snr = 10 log10(1/0.01) = 20
        let r = vec![1.0f32; 16];
        let p = vec![0.9f32; 16];
        assert!((snr_db(&p, &r) - 20.0).abs() < 1e-4); // f32 rounding
    }
}
