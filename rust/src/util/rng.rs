//! PCG64-based PRNG with normal sampling.
//!
//! Offline substrate replacing the `rand` crate (DESIGN.md §3). PCG-XSH-RR
//! 64/32 core; `normal()` uses the Box-Muller transform with caching.
//! Deterministic across platforms (no floating-point in the core), which
//! the batching-equivalence integration tests rely on.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
    cached_normal: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1, cached_normal: None };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use;
    /// modulo bias is < 2^-32 for the small n we draw).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.cached_normal = Some(r * s);
            return r * c;
        }
    }

    /// Fill a f32 buffer with iid standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(17);
        let mut b = Pcg32::seeded(17);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Pcg32::seeded(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Pcg32::seeded(5);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
