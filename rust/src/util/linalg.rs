//! Small dense linear algebra: symmetric eigendecomposition and the
//! matrix square root needed by the Fréchet distance (FD-synth).
//!
//! Offline substrate replacing `nalgebra` (DESIGN.md §3). The cyclic
//! Jacobi rotation method is exact enough (and fast) for the <= 64x64
//! symmetric PSD matrices the metrics use.

/// Dense row-major square matrix.
#[derive(Debug, Clone)]
pub struct Mat {
    pub n: usize,
    pub d: Vec<f64>,
}

impl Mat {
    pub fn zeros(n: usize) -> Mat {
        Mat { n, d: vec![0.0; n * n] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n);
        for i in 0..n {
            m.d[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_rows(n: usize, d: Vec<f64>) -> Mat {
        assert_eq!(d.len(), n * n);
        Mat { n, d }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.d[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.d[i * self.n + j] = v;
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.n, other.n);
        let n = self.n;
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out.d[i * n + j] += a * other.at(k, j);
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let n = self.n;
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                out.set(j, i, self.at(i, j));
            }
        }
        out
    }

    pub fn add(&self, other: &Mat) -> Mat {
        let mut out = self.clone();
        for (a, b) in out.d.iter_mut().zip(other.d.iter()) {
            *a += b;
        }
        out
    }

    pub fn trace(&self) -> f64 {
        (0..self.n).map(|i| self.at(i, i)).sum()
    }

    fn off_diag_norm(&self) -> f64 {
        let mut s = 0.0;
        for i in 0..self.n {
            for j in 0..self.n {
                if i != j {
                    s += self.at(i, j) * self.at(i, j);
                }
            }
        }
        s.sqrt()
    }
}

/// Symmetric eigendecomposition by cyclic Jacobi: A = V diag(w) V^T.
/// Returns (eigenvalues, V with eigenvectors as columns).
pub fn sym_eig(a: &Mat, max_sweeps: usize) -> (Vec<f64>, Mat) {
    let n = a.n;
    let mut m = a.clone();
    let mut v = Mat::eye(n);
    for _ in 0..max_sweeps {
        if m.off_diag_norm() < 1e-12 * (1.0 + m.trace().abs()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.at(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m.at(p, p);
                let aqq = m.at(q, q);
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of m
                for k in 0..n {
                    let mkp = m.at(k, p);
                    let mkq = m.at(k, q);
                    m.set(k, p, c * mkp - s * mkq);
                    m.set(k, q, s * mkp + c * mkq);
                }
                for k in 0..n {
                    let mpk = m.at(p, k);
                    let mqk = m.at(q, k);
                    m.set(p, k, c * mpk - s * mqk);
                    m.set(q, k, s * mpk + c * mqk);
                }
                for k in 0..n {
                    let vkp = v.at(k, p);
                    let vkq = v.at(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }
    let w = (0..n).map(|i| m.at(i, i)).collect();
    (w, v)
}

/// Principal square root of a symmetric PSD matrix (negative eigenvalues
/// from numerical noise are clamped to zero).
pub fn sym_sqrt(a: &Mat) -> Mat {
    let n = a.n;
    let (w, v) = sym_eig(a, 30);
    let mut out = Mat::zeros(n);
    for k in 0..n {
        let s = w[k].max(0.0).sqrt();
        if s == 0.0 {
            continue;
        }
        for i in 0..n {
            let vik = v.at(i, k);
            if vik == 0.0 {
                continue;
            }
            for j in 0..n {
                out.d[i * n + j] += s * vik * v.at(j, k);
            }
        }
    }
    out
}

/// Fréchet distance between Gaussians (m1, c1) and (m2, c2):
///   |m1 - m2|^2 + tr(c1 + c2 - 2 (c1^{1/2} c2 c1^{1/2})^{1/2}).
/// This is the FID formula with our FD-synth feature statistics.
pub fn frechet_distance(m1: &[f64], c1: &Mat, m2: &[f64], c2: &Mat) -> f64 {
    assert_eq!(m1.len(), m2.len());
    let dm: f64 = m1.iter().zip(m2).map(|(a, b)| (a - b) * (a - b)).sum();
    let s1 = sym_sqrt(c1);
    let inner = s1.matmul(c2).matmul(&s1);
    // symmetrize against round-off before the second sqrt
    let inner_t = inner.transpose();
    let mut sym = inner.add(&inner_t);
    for x in sym.d.iter_mut() {
        *x *= 0.5;
    }
    let cross = sym_sqrt(&sym);
    dm + c1.trace() + c2.trace() - 2.0 * cross.trace()
}

/// Sample mean and covariance of rows (n_samples x dim, row-major).
pub fn mean_cov(rows: &[f32], dim: usize) -> (Vec<f64>, Mat) {
    let n = rows.len() / dim;
    assert!(n > 1, "need >= 2 samples for covariance");
    let mut mean = vec![0.0f64; dim];
    for r in 0..n {
        for j in 0..dim {
            mean[j] += rows[r * dim + j] as f64;
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    let mut cov = Mat::zeros(dim);
    for r in 0..n {
        for i in 0..dim {
            let di = rows[r * dim + i] as f64 - mean[i];
            for j in i..dim {
                let dj = rows[r * dim + j] as f64 - mean[j];
                cov.d[i * dim + j] += di * dj;
            }
        }
    }
    for i in 0..dim {
        for j in i..dim {
            let v = cov.at(i, j) / (n - 1) as f64;
            cov.set(i, j, v);
            cov.set(j, i, v);
        }
    }
    (mean, cov)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() < tol, "{a} vs {b}");
    }

    #[test]
    fn eig_diagonal() {
        let mut a = Mat::zeros(3);
        a.set(0, 0, 3.0);
        a.set(1, 1, 1.0);
        a.set(2, 2, 2.0);
        let (mut w, _) = sym_eig(&a, 20);
        w.sort_by(|x, y| x.partial_cmp(y).unwrap());
        approx(w[0], 1.0, 1e-12);
        approx(w[1], 2.0, 1e-12);
        approx(w[2], 3.0, 1e-12);
    }

    #[test]
    fn eig_known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3
        let a = Mat::from_rows(2, vec![2.0, 1.0, 1.0, 2.0]);
        let (mut w, v) = sym_eig(&a, 20);
        w.sort_by(|x, y| x.partial_cmp(y).unwrap());
        approx(w[0], 1.0, 1e-12);
        approx(w[1], 3.0, 1e-12);
        // eigenvectors orthonormal
        let vtv = v.transpose().matmul(&v);
        approx(vtv.at(0, 0), 1.0, 1e-12);
        approx(vtv.at(0, 1), 0.0, 1e-12);
    }

    #[test]
    fn sqrt_squares_back() {
        // random-ish SPD matrix: B B^T + I
        let n = 5;
        let mut b = Mat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                b.set(i, j, ((i * 7 + j * 3) % 11) as f64 / 11.0 - 0.4);
            }
        }
        let a = b.matmul(&b.transpose()).add(&Mat::eye(n));
        let s = sym_sqrt(&a);
        let s2 = s.matmul(&s);
        for i in 0..n {
            for j in 0..n {
                approx(s2.at(i, j), a.at(i, j), 1e-9);
            }
        }
    }

    #[test]
    fn frechet_identical_is_zero() {
        let m = vec![0.3, -1.0, 2.0];
        let mut c = Mat::eye(3);
        c.set(0, 1, 0.2);
        c.set(1, 0, 0.2);
        let d = frechet_distance(&m, &c, &m, &c);
        assert!(d.abs() < 1e-9, "{d}");
    }

    #[test]
    fn frechet_mean_shift() {
        // equal covariances: FD reduces to |dm|^2
        let c = Mat::eye(2);
        let d = frechet_distance(&[0.0, 0.0], &c, &[3.0, 4.0], &c);
        approx(d, 25.0, 1e-9);
    }

    #[test]
    fn frechet_scale() {
        // 1-d gaussians N(0, 1) vs N(0, 4): FD = (sigma1 - sigma2)^2 = 1
        let c1 = Mat::from_rows(1, vec![1.0]);
        let c2 = Mat::from_rows(1, vec![4.0]);
        approx(frechet_distance(&[0.0], &c1, &[0.0], &c2), 1.0, 1e-9);
    }

    #[test]
    fn mean_cov_known() {
        // two points (0,0) and (2,2): mean (1,1), cov [[2,2],[2,2]] (n-1 norm)
        let rows = [0.0f32, 0.0, 2.0, 2.0];
        let (m, c) = mean_cov(&rows, 2);
        approx(m[0], 1.0, 1e-12);
        approx(c.at(0, 0), 2.0, 1e-12);
        approx(c.at(0, 1), 2.0, 1e-12);
    }
}
