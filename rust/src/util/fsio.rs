//! Crash-safe small-file persistence.
//!
//! `std::fs::write` truncates the destination before writing, so a crash
//! (or an injected fault) mid-write leaves a corrupt file where a valid
//! one used to be — a poisoned teacher cache or artifact manifest then
//! breaks every later run. [`write_atomic`] writes to a sibling
//! temporary and renames over the target: on POSIX the rename is atomic,
//! so readers observe either the old contents or the new, never a
//! truncated mix (DESIGN.md §11).

use std::path::Path;

use anyhow::{Context, Result};

/// Write `contents` to `path` atomically: the bytes land in a sibling
/// `<name>.tmp.<pid>` first and are renamed into place, so a crash at
/// any point leaves either the previous file or the complete new one.
///
/// The temporary lives in the same directory as `path` (renames across
/// filesystems are not atomic). A leftover temporary from a crashed
/// earlier run is simply overwritten. Not safe against *concurrent*
/// writers of the same path from one process — callers serialize, as the
/// teacher cache and manifest writers already do.
pub fn write_atomic(path: &Path, contents: &str) -> Result<()> {
    let dir = path.parent().filter(|d| !d.as_os_str().is_empty());
    let file = path
        .file_name()
        .with_context(|| format!("atomic write target {} has no file name", path.display()))?;
    let mut tmp_name = file.to_os_string();
    tmp_name.push(format!(".tmp.{}", std::process::id()));
    let tmp = match dir {
        Some(d) => d.join(&tmp_name),
        None => std::path::PathBuf::from(&tmp_name),
    };
    std::fs::write(&tmp, contents)
        .with_context(|| format!("writing temporary {}", tmp.display()))?;
    if let Err(e) = std::fs::rename(&tmp, path) {
        // don't leave the temporary behind on a failed rename
        let _ = std::fs::remove_file(&tmp);
        return Err(anyhow::Error::new(e)
            .context(format!("renaming {} into {}", tmp.display(), path.display())));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("bns-fsio-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn writes_and_replaces() {
        let dir = tmpdir("replace");
        let p = dir.join("cache.json");
        write_atomic(&p, "{\"v\":1}").unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "{\"v\":1}");
        write_atomic(&p, "{\"v\":2}").unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "{\"v\":2}");
        // no temporary left behind
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_file_name_is_an_error() {
        assert!(write_atomic(Path::new("/"), "x").is_err());
    }
}
