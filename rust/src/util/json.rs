//! Minimal JSON parser/serializer.
//!
//! The image's offline crate set has no `serde`/`serde_json`, so this is a
//! self-contained substrate (DESIGN.md §3): a recursive-descent parser and
//! a writer covering the full JSON grammar, sufficient for the artifact
//! manifest and solver-coefficient files. Numbers are kept as `f64`
//! (everything we exchange is f32-precision or integer-exact below 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are sorted (BTreeMap) so serialization is
/// deterministic — handy for golden tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors (None on type mismatch) ------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]`-style access; returns Null for missing keys/non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Flatten a numeric array to Vec<f64>; None if any element is not a number.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?.iter().map(|v| v.as_f64().map(|x| x as f32)).collect()
    }

    // -- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{}", n));
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", s)))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().map_or(false, |c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().map_or(false, |c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().map_or(false, |c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("utf8"))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // (surrogate pairs unsupported; artifacts are ASCII)
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 code point
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2.5, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(v.get("c").as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"solver":{"a":[1,0.5],"times":[0,0.25,1],"name":"bns"}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn roundtrip_floats() {
        let xs = [0.1f64, -3.25e-7, 1e15, 0.333333333333333, f64::MIN_POSITIVE];
        let j = Json::arr_f64(&xs);
        let back = Json::parse(&j.to_string()).unwrap().as_f64_vec().unwrap();
        for (a, b) in xs.iter().zip(back.iter()) {
            assert!((a - b).abs() <= 1e-15 * a.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ∞\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ∞"));
    }
}
