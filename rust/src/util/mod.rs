//! Offline substrates: the crates this image cannot resolve (serde,
//! rand, criterion, nalgebra, FFT) reimplemented minimally and tested.
//! See DESIGN.md §3 (substitution table) and §4 (inventory).

pub mod fft;
pub mod fsio;
pub mod json;
pub mod linalg;
pub mod rng;
pub mod stats;
pub mod sync;
