//! Runtime device lanes: load artifacts, compile once per (lane, model,
//! batch) bucket, execute from the request path.
//!
//! The concrete executor lives behind `backend::Backend` — real PJRT via
//! the `xla` crate when built with `--features pjrt`, the offline stub
//! backend otherwise (see `backend.rs` for the rationale and the stub
//! artifact format).
//!
//! # Device lanes
//!
//! The runtime owns a configurable set of **lanes**. Each lane is one
//! dedicated thread that owns its own `Backend` instance and its own
//! compile cache — the same discipline as a GPU stream owner, multiplied.
//! Executables (and therefore model fields) are *pinned* to the lane that
//! compiled them, so two engine workers whose models landed on different
//! lanes execute model evals truly concurrently. Under `--features pjrt`
//! the lane count is forced to 1: the PJRT client/executable types are
//! `!Send` (Rc-based wrappers over the C API) and the vendored bindings
//! assume a single process-wide client.
//!
//! # Pooled (zero-allocation) execution
//!
//! `ExeHandle::run_into` is the hot-path RPC. Its request/response
//! buffers live in a per-handle **slot pool**: the x/labels/out vectors
//! travel to the lane inside the message and come back with the reply, so
//! at steady state an eval performs no heap allocation anywhere on the
//! path — the lane channel is a bounded `sync_channel` (preallocated ring,
//! allocation-free sends), each slot's reply channel is a rendezvous
//! `sync_channel(1)`, and the backend writes velocities into the pooled
//! `out` buffer in place (`Backend::exec_into`). To be precise: the claim
//! is zero *allocation*, not zero copy — each eval still pays two bounded
//! memcpys (caller x into the slot, pooled out back into the caller's
//! buffer); eliminating those would require the solver workspace itself
//! to cross the thread boundary. The lane thread wraps backend calls in
//! `catch_unwind` so a panicking backend yields an error reply instead of
//! a wedged caller. `benches/perf_layers.rs` measures allocations per
//! eval with a counting global allocator to pin the claim.
//!
//! The wavefront gradient engine's stacked batched-JVP evals
//! (`ModelField::jvp_batch_into`, DESIGN.md §8) ride this same pooled
//! RPC: one bucketized dispatch carries the `x ± ε·v` rows of every
//! tangent of a training step, so distillation training inherits the
//! zero-allocation steady state — `benches/distill_bench.rs` pins it
//! per Adam step with the same counting-allocator method.
//!
//! # Fault domains & supervision (DESIGN.md §11)
//!
//! A lane is the runtime's fault domain. Three mechanisms keep one bad
//! backend call from wedging the service:
//!
//! * **Exec timeout** — `run_into` waits `RuntimeConfig::
//!   lane_exec_timeout` (CLI `--lane-exec-timeout-ms`) for the lane's
//!   reply; a stalled backend yields a structured error instead of a
//!   parked engine worker. The timed-out slot is *dropped*, never pooled:
//!   its reply channel may still receive a stale reply from the wedged
//!   lane, and pooling it would hand that stale output to a future call
//!   (the lane's late send fails against the dropped receiver without
//!   blocking — rendezvous channel).
//! * **Supervision & respawn** — timeouts and disconnects enqueue a
//!   suspicion `(lane, generation)` to the supervisor thread, which
//!   respawns the lane: fresh thread, fresh `Backend`, generation bumped,
//!   and every artifact previously compiled on the lane eagerly
//!   recompiled from its known path. Stale suspicions (generation already
//!   bumped) are ignored, so one incident triggers one respawn. The old
//!   thread is left to drain and exit on its own — it may be wedged
//!   inside a backend call, and its late replies land on dropped
//!   receivers.
//! * **Generation rebinding** — an `ExeHandle` caches `(sender,
//!   executable id)` under the generation it bound them at; when the
//!   lane's generation moves on, the next `run_into` rebinds against the
//!   respawned lane (compile-cache hit if the supervisor's recompile
//!   succeeded, a fresh compile otherwise) off the hot path.
//!
//! Recovery preserves numerics: executables are pure functions of their
//! artifact file, so a respawned lane's recompiled executable is
//! bit-identical to the original — `tests/chaos.rs` pins this end to
//! end. Deterministic fault schedules for those tests live in
//! `fault.rs` (`RuntimeConfig::fault`).

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::backend;
use super::fault::{FaultBackend, FaultPlan};
use crate::obs::{self, TraceRecorder, TraceStage};
use crate::util::sync::lock_ok;

/// The runtime's view of the engine's trace recorder. The runtime is
/// constructed before the engine (which owns the recorder), so lane and
/// supervisor threads capture this shared cell at spawn time and the
/// engine fills it once via [`Runtime::attach_tracer`]; `get()` is a
/// lock-free read after initialization.
type TracerCell = Arc<OnceLock<Arc<TraceRecorder>>>;

/// Record a span event if a tracer has been attached (allocation-free
/// either way).
fn trace_event(cell: &OnceLock<Arc<TraceRecorder>>, id: u64, stage: TraceStage, a: u64, b: u64) {
    if let Some(rec) = cell.get() {
        rec.record(id, stage, a, b);
    }
}

/// Bounded depth of each lane's request channel. Generous: the channel is
/// a backpressure valve, not a queueing layer — workers block in
/// `run_into` anyway.
const LANE_QUEUE_CAP: usize = 256;

/// Default lane exec timeout: far above any sane batch execution, so it
/// only ever fires on a genuinely wedged backend call.
const DEFAULT_EXEC_TIMEOUT: Duration = Duration::from_millis(30_000);

/// Compiles (and respawned-backend init) get 10x the exec timeout —
/// compilation is legitimately much slower than execution.
const COMPILE_TIMEOUT_FACTOR: u32 = 10;

enum Msg {
    Load {
        path: PathBuf,
        reply: mpsc::SyncSender<Result<u64>>,
    },
    Exec(ExecMsg),
    Platform {
        reply: mpsc::SyncSender<String>,
    },
}

/// One pooled execution request. The buffers are owned by the message
/// while it is in flight and return to the caller inside `ExecReply`.
struct ExecMsg {
    id: u64,
    batch: usize,
    dim: usize,
    t: f32,
    w: f32,
    x: Vec<f32>,
    labels: Vec<i32>,
    out: Vec<f32>,
    reply: mpsc::SyncSender<ExecReply>,
    /// Trace id of the request driving this exec (`obs::NO_TRACE` when
    /// no request context, e.g. warmup or training evals).
    trace: u64,
}

struct ExecReply {
    x: Vec<f32>,
    labels: Vec<i32>,
    out: Vec<f32>,
    result: Result<()>,
}

/// A suspicion report to the lane supervisor, or the shutdown sentinel.
enum SupMsg {
    /// `run_into` timed out or found the lane disconnected at this
    /// generation. The supervisor ignores it if the lane has already
    /// been respawned past `generation`. `trace` is the suspecting
    /// request's trace id, so the eventual respawn lands in the victim's
    /// timeline.
    Suspect { lane: usize, generation: u64, trace: u64 },
    /// Runtime is dropping: exit the supervisor loop.
    Shutdown,
}

/// Per-lane execution counters, shared with the lane thread. `busy_us`
/// is time spent inside the backend — utilization is `busy_us / wall`.
#[derive(Default)]
pub struct LaneStats {
    pub execs: AtomicU64,
    pub busy_us: AtomicU64,
}

/// Point-in-time health of one device lane (the `health` op's `lanes`
/// entries).
#[derive(Debug, Clone, Copy)]
pub struct LaneHealth {
    /// Lane index.
    pub lane: usize,
    /// Total execs served (all generations).
    pub execs: u64,
    /// Total microseconds inside the backend (all generations).
    pub busy_us: u64,
    /// Current generation: 0 at birth, +1 per respawn.
    pub generation: u64,
    /// Times this lane has been respawned by the supervisor.
    pub respawns: u64,
}

/// The mutable, swap-on-respawn part of a lane: the sender feeding the
/// current lane thread and the path -> executable-id compile cache (ids
/// are local to the current generation's backend instance).
struct LaneState {
    tx: mpsc::SyncSender<Msg>,
    cache: HashMap<PathBuf, u64>,
}

/// One lane's shared identity: survives respawns (the supervisor swaps
/// the `LaneState` inside, bumping `generation`). Stats accumulate
/// across generations.
struct LaneShared {
    index: usize,
    state: Mutex<LaneState>,
    generation: AtomicU64,
    respawns: AtomicU64,
    stats: Arc<LaneStats>,
}

/// Runtime construction knobs (see module docs; `Default` = one lane,
/// 30 s exec timeout, no fault injection).
pub struct RuntimeConfig {
    /// Number of device lanes (forced to 1 under `--features pjrt`).
    pub lanes: usize,
    /// How long `run_into` waits for a lane's reply before declaring the
    /// lane wedged (structured error + supervisor respawn).
    pub lane_exec_timeout: Duration,
    /// Deterministic fault-injection plan wrapped around every lane's
    /// backend (chaos testing; `None` in production).
    pub fault: Option<Arc<FaultPlan>>,
    /// Worker threads in each lane's intra-lane `bns_mlp_field` row pool
    /// (0 = auto: `min(available_parallelism, 8)`, 1 = inline). Purely a
    /// throughput knob: samples are bit-identical for any value
    /// (DESIGN.md §13); pinned by `tests/mlp_pool.rs`.
    pub mlp_pool_threads: usize,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig {
            lanes: 1,
            lane_exec_timeout: DEFAULT_EXEC_TIMEOUT,
            fault: None,
            mlp_pool_threads: 0,
        }
    }
}

/// Handle to the device lanes. Cheap to share via Arc.
pub struct Runtime {
    lanes: Vec<Arc<LaneShared>>,
    /// Round-robin cursor for pinning new loads to a lane.
    next: AtomicUsize,
    exec_timeout: Duration,
    fault: Option<Arc<FaultPlan>>,
    /// Senders are kept behind a Mutex for shareability (matching the
    /// lane sender discipline); cloned into each `ExeHandle` so handles
    /// can file suspicions without going through the Runtime.
    sup_tx: Mutex<mpsc::SyncSender<SupMsg>>,
    shutdown: Arc<AtomicBool>,
    /// Shared cell the engine fills with its trace recorder (see
    /// [`Runtime::attach_tracer`]); lane threads and the supervisor hold
    /// clones captured at spawn time.
    tracer: TracerCell,
}

impl Runtime {
    /// Single-lane runtime — the PJRT-safe default.
    pub fn cpu() -> Result<Runtime> {
        Self::with_lanes(1)
    }

    /// Runtime with `n` device lanes and default supervision knobs.
    pub fn with_lanes(n: usize) -> Result<Runtime> {
        Self::with_config(RuntimeConfig { lanes: n, ..RuntimeConfig::default() })
    }

    /// Runtime with explicit supervision/fault-injection configuration.
    /// The lane count is forced to 1 under `--features pjrt` (the PJRT
    /// types are `!Send` and the bindings assume one process-wide
    /// client).
    pub fn with_config(cfg: RuntimeConfig) -> Result<Runtime> {
        let n = if cfg!(feature = "pjrt") { 1 } else { cfg.lanes.max(1) };
        // capacity 64: suspicions are tiny and coalescible — a full queue
        // means respawns are already pending, so droppers just try_send
        let (sup_tx, sup_rx) = mpsc::sync_channel::<SupMsg>(64);
        let tracer: TracerCell = Arc::new(OnceLock::new());
        let mut lanes = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = mpsc::sync_channel::<Msg>(LANE_QUEUE_CAP);
            // capacity 1: the lane sends exactly one init result
            let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
            let stats = Arc::new(LaneStats::default());
            let stats_t = stats.clone();
            let fault_t = cfg.fault.clone();
            let tracer_t = tracer.clone();
            let pool_t = cfg.mlp_pool_threads;
            std::thread::Builder::new()
                .name(format!("bns-lane-{i}"))
                .spawn(move || lane_thread(rx, ready_tx, stats_t, fault_t, tracer_t, i, 0, pool_t))
                .context("spawning device lane thread")?;
            ready_rx
                .recv()
                .context("device lane died during init")??;
            lanes.push(Arc::new(LaneShared {
                index: i,
                state: Mutex::new(LaneState { tx, cache: HashMap::new() }),
                generation: AtomicU64::new(0),
                respawns: AtomicU64::new(0),
                stats,
            }));
        }
        let shutdown = Arc::new(AtomicBool::new(false));
        let lanes_s = lanes.clone();
        let shutdown_s = shutdown.clone();
        let fault_s = cfg.fault.clone();
        let timeout_s = cfg.lane_exec_timeout;
        let tracer_s = tracer.clone();
        let pool_s = cfg.mlp_pool_threads;
        std::thread::Builder::new()
            .name("bns-lane-supervisor".to_string())
            .spawn(move || {
                supervisor_loop(sup_rx, lanes_s, fault_s, tracer_s, shutdown_s, timeout_s, pool_s)
            })
            .context("spawning lane supervisor thread")?;
        Ok(Runtime {
            lanes,
            next: AtomicUsize::new(0),
            exec_timeout: cfg.lane_exec_timeout,
            fault: cfg.fault,
            sup_tx: Mutex::new(sup_tx),
            shutdown,
            tracer,
        })
    }

    /// Attach the engine's trace recorder to the runtime's lane and
    /// supervisor threads so lane-level events (compile, exec, timeout,
    /// respawn, fault injection) land in request timelines. One-shot:
    /// the first attached recorder wins; later calls are ignored.
    pub fn attach_tracer(&self, t: Arc<TraceRecorder>) {
        let _ = self.tracer.set(t);
    }

    pub fn num_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Next lane in round-robin order — the pinning policy for new loads.
    pub fn next_lane(&self) -> usize {
        self.next.fetch_add(1, Ordering::Relaxed) % self.lanes.len()
    }

    /// Per-lane `(execs, busy_us)` counters, indexed by lane.
    pub fn lane_stats(&self) -> Vec<(u64, u64)> {
        self.lanes
            .iter()
            .map(|l| {
                (
                    l.stats.execs.load(Ordering::Relaxed),
                    l.stats.busy_us.load(Ordering::Relaxed),
                )
            })
            .collect()
    }

    /// Per-lane health (counters + supervision state), indexed by lane.
    pub fn lane_health(&self) -> Vec<LaneHealth> {
        self.lanes
            .iter()
            .map(|l| LaneHealth {
                lane: l.index,
                execs: l.stats.execs.load(Ordering::Relaxed),
                busy_us: l.stats.busy_us.load(Ordering::Relaxed),
                generation: l.generation.load(Ordering::Acquire),
                respawns: l.respawns.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Total lane respawns across all lanes.
    pub fn respawns_total(&self) -> u64 {
        self.lanes
            .iter()
            .map(|l| l.respawns.load(Ordering::Relaxed))
            .sum()
    }

    /// Total faults injected by the configured fault plan (0 when no
    /// plan is configured).
    pub fn faults_injected(&self) -> u64 {
        self.fault.as_ref().map(|p| p.injected()).unwrap_or(0)
    }

    pub fn platform(&self) -> String {
        // capacity 1: the lane sends exactly one platform string
        let (reply, rx) = mpsc::sync_channel(1);
        let _ = lock_ok(&self.lanes[0].state).tx.send(Msg::Platform { reply });
        rx.recv().unwrap_or_else(|_| "unknown".into())
    }

    /// Drop `path` from every lane's compiled-executable cache,
    /// returning how many lane entries were evicted. The next
    /// `load_on`/rebind of the path recompiles from the bytes on disk —
    /// the model registry calls this on hot `load`/`unload` so a
    /// re-registered artifact never serves a stale executable (the same
    /// cache-invalidation path a lane respawn drains). Handles already
    /// bound keep their executable id until they rebind, so in-flight
    /// work is unaffected.
    pub fn evict_path(&self, path: &Path) -> usize {
        let mut evicted = 0;
        for l in &self.lanes {
            if lock_ok(&l.state).cache.remove(path).is_some() {
                evicted += 1;
            }
        }
        evicted
    }

    /// Load + compile an artifact on `lane` (cached per lane by path).
    pub fn load_on(&self, lane: usize, path: &Path, batch: usize, dim: usize) -> Result<ExeHandle> {
        let l = self
            .lanes
            .get(lane)
            .ok_or_else(|| anyhow!("lane {lane} out of range ({} lanes)", self.lanes.len()))?;
        // hold the state lock across the compile RPC: concurrent first
        // loads of the same artifact must not compile it twice (the
        // loser's executable would be orphaned in the lane's backend —
        // a duplicate HLO compile + held memory under PJRT). The lane
        // thread never takes this lock, so no deadlock; concurrent loads
        // on one lane serialize, which a compile does anyway.
        let mut compile_us = None;
        let (id, tx, generation) = {
            let mut state = lock_ok(&l.state);
            let id = match state.cache.get(path).copied() {
                Some(id) => id,
                None => {
                    let t0 = Instant::now();
                    // capacity 1: the lane sends exactly one compile result
                    let (reply, rx) = mpsc::sync_channel(1);
                    state
                        .tx
                        .send(Msg::Load { path: path.to_path_buf(), reply })
                        .map_err(|_| anyhow!("device lane gone"))?;
                    let id = rx
                        .recv_timeout(self.exec_timeout.saturating_mul(COMPILE_TIMEOUT_FACTOR))
                        .context("device lane gone or compile timed out")??;
                    state.cache.insert(path.to_path_buf(), id);
                    compile_us = Some(t0.elapsed().as_micros() as u64);
                    id
                }
            };
            (id, state.tx.clone(), l.generation.load(Ordering::Acquire))
        };
        if let Some(us) = compile_us {
            trace_event(&self.tracer, obs::ambient(), TraceStage::LaneCompile, lane as u64, us);
        }
        Ok(ExeHandle {
            shared: l.clone(),
            sup_tx: Mutex::new(lock_ok(&self.sup_tx).clone()),
            bound: Mutex::new(Bound { tx, id, generation }),
            pool: Mutex::new(Vec::new()),
            path: path.to_path_buf(),
            timeout: self.exec_timeout,
            tracer: self.tracer.clone(),
            lane,
            batch,
            dim,
        })
    }

    /// Load + compile on the next round-robin lane.
    pub fn load(&self, path: &Path, batch: usize, dim: usize) -> Result<ExeHandle> {
        self.load_on(self.next_lane(), path, batch, dim)
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        // Stop the supervisor first so no further respawns race the
        // teardown; try_send because a full suspicion queue still drains
        // (each queued suspect sees the shutdown flag and is skipped).
        self.shutdown.store(true, Ordering::Relaxed);
        let _ = lock_ok(&self.sup_tx).try_send(SupMsg::Shutdown);
        // Replace each lane's sender with a disconnected dummy; once every
        // ExeHandle clone is gone too, the lane's recv() errors out and
        // the thread exits. We deliberately do NOT join: an ExeHandle may
        // outlive the Runtime and joining would deadlock — the detached
        // thread exits as soon as the last sender drops.
        for lane in &self.lanes {
            let (dummy, _) = mpsc::sync_channel(1);
            lock_ok(&lane.state).tx = dummy;
        }
    }
}

/// The lane supervisor: serially processes suspicion reports, respawning
/// each genuinely-dead lane exactly once per incident (stale generations
/// are skipped). Exits on the shutdown sentinel, when the runtime sets
/// the shutdown flag, or when every suspicion sender is gone.
fn supervisor_loop(
    rx: mpsc::Receiver<SupMsg>,
    lanes: Vec<Arc<LaneShared>>,
    fault: Option<Arc<FaultPlan>>,
    tracer: TracerCell,
    shutdown: Arc<AtomicBool>,
    exec_timeout: Duration,
    mlp_pool_threads: usize,
) {
    while let Ok(msg) = rx.recv() {
        let (lane, generation, trace) = match msg {
            SupMsg::Shutdown => return,
            SupMsg::Suspect { lane, generation, trace } => (lane, generation, trace),
        };
        if shutdown.load(Ordering::Relaxed) {
            return;
        }
        if let Some(shared) = lanes.get(lane) {
            respawn_lane(
                shared,
                generation,
                fault.clone(),
                &tracer,
                trace,
                exec_timeout,
                mlp_pool_threads,
            );
        }
    }
}

/// Respawn one lane: fresh thread + backend under a bumped generation,
/// then eagerly recompile every artifact the old generation had compiled
/// (so rebinding handles hit the cache instead of paying a compile on
/// the request path). If the suspicion is stale or the new backend fails
/// to initialize, the lane is left as-is — callers keep getting
/// structured errors and a later suspicion retries the respawn.
#[allow(clippy::too_many_arguments)]
fn respawn_lane(
    shared: &Arc<LaneShared>,
    suspect_generation: u64,
    fault: Option<Arc<FaultPlan>>,
    tracer: &TracerCell,
    trace: u64,
    exec_timeout: Duration,
    mlp_pool_threads: usize,
) {
    // Stale suspicion: this incident was already handled. Only the
    // (single) supervisor thread ever bumps generations, so the check
    // does not race.
    if shared.generation.load(Ordering::Acquire) != suspect_generation {
        return;
    }
    let new_generation = suspect_generation + 1;
    // bounded like the original lane channel: same backpressure valve
    let (tx, rx) = mpsc::sync_channel::<Msg>(LANE_QUEUE_CAP);
    // capacity 1: the lane sends exactly one init result
    let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
    let stats = shared.stats.clone();
    let lane = shared.index;
    let tracer_t = tracer.clone();
    let spawned = std::thread::Builder::new()
        .name(format!("bns-lane-{lane}-g{new_generation}"))
        .spawn(move || {
            lane_thread(
                rx, ready_tx, stats, fault, tracer_t, lane, new_generation, mlp_pool_threads,
            )
        });
    if spawned.is_err() {
        return;
    }
    match ready_rx.recv_timeout(exec_timeout.saturating_mul(COMPILE_TIMEOUT_FACTOR)) {
        Ok(Ok(())) => {}
        _ => return,
    }
    let mut state = lock_ok(&shared.state);
    let old_paths: Vec<PathBuf> = state.cache.drain().map(|(p, _)| p).collect();
    state.tx = tx;
    shared.respawns.fetch_add(1, Ordering::Relaxed);
    shared.generation.store(new_generation, Ordering::Release);
    // Eager recompile while still holding the state lock: handles that
    // saw the new generation block in rebind until the cache is warm.
    // Per-path failures are tolerated — the path just drops out of the
    // cache and the owning handle's rebind surfaces the compile error.
    for path in old_paths {
        // capacity 1: the lane sends exactly one compile result
        let (reply, rrx) = mpsc::sync_channel(1);
        if state.tx.send(Msg::Load { path: path.clone(), reply }).is_err() {
            continue;
        }
        if let Ok(Ok(id)) = rrx.recv_timeout(exec_timeout.saturating_mul(COMPILE_TIMEOUT_FACTOR)) {
            state.cache.insert(path, id);
        }
    }
    drop(state);
    // Record under the victim's trace id only after the swap is fully
    // committed — the event marks "service restored", not "respawn
    // attempted".
    trace_event(tracer, trace, TraceStage::LaneRespawn, lane as u64, new_generation);
}

/// One pooled buffer set + its private reply channel. Slots cycle
/// caller -> lane -> caller; their vectors only ever grow, so steady
/// state reuses capacity and allocates nothing.
struct ExecSlot {
    x: Vec<f32>,
    labels: Vec<i32>,
    out: Vec<f32>,
    reply_tx: mpsc::SyncSender<ExecReply>,
    reply_rx: mpsc::Receiver<ExecReply>,
}

impl Default for ExecSlot {
    fn default() -> Self {
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        ExecSlot {
            x: Vec::new(),
            labels: Vec::new(),
            out: Vec::new(),
            reply_tx,
            reply_rx,
        }
    }
}

/// The lane binding an `ExeHandle` currently holds: the sender feeding
/// the lane thread and the backend-local executable id, both valid for
/// `generation` only. When the lane respawns, `run_into` rebinds.
struct Bound {
    tx: mpsc::SyncSender<Msg>,
    id: u64,
    generation: u64,
}

/// A compiled velocity-field executable with the aot.py signature
/// (x [B,D] f32, t [] f32, w [] f32, labels [B] i32) -> (u [B,D] f32,),
/// pinned to the device lane that compiled it (surviving that lane's
/// respawns by rebinding).
pub struct ExeHandle {
    shared: Arc<LaneShared>,
    sup_tx: Mutex<mpsc::SyncSender<SupMsg>>,
    bound: Mutex<Bound>,
    pool: Mutex<Vec<ExecSlot>>,
    /// Artifact path, kept for recompiles after a lane respawn.
    path: PathBuf,
    timeout: Duration,
    /// Shared trace-recorder cell (see [`Runtime::attach_tracer`]).
    tracer: TracerCell,
    /// Lane this executable is pinned to.
    pub lane: usize,
    pub batch: usize,
    pub dim: usize,
}

impl ExeHandle {
    /// Execute on exactly `self.batch` rows, writing the velocities into
    /// `out` (synchronous RPC over pooled buffers; zero heap allocation
    /// at steady state). Waits at most the runtime's lane exec timeout:
    /// a wedged lane yields a structured error (and a supervisor
    /// respawn) instead of a parked caller.
    pub fn run_into(
        &self,
        x: &[f32],
        t: f32,
        w: f32,
        labels: &[i32],
        out: &mut [f32],
    ) -> Result<()> {
        debug_assert_eq!(x.len(), self.batch * self.dim);
        debug_assert_eq!(labels.len(), self.batch);
        debug_assert_eq!(out.len(), self.batch * self.dim);
        let mut slot = lock_ok(&self.pool).pop().unwrap_or_default();
        slot.x.clear();
        slot.x.extend_from_slice(x);
        slot.labels.clear();
        slot.labels.extend_from_slice(labels);
        slot.out.resize(out.len(), 0.0);
        let generation = self.shared.generation.load(Ordering::Acquire);
        let sent = {
            let mut bound = lock_ok(&self.bound);
            if bound.generation != generation {
                if let Err(e) = self.rebind(&mut bound, generation) {
                    drop(bound);
                    lock_ok(&self.pool).push(slot);
                    return Err(e);
                }
            }
            let msg = Msg::Exec(ExecMsg {
                id: bound.id,
                batch: self.batch,
                dim: self.dim,
                t,
                w,
                x: std::mem::take(&mut slot.x),
                labels: std::mem::take(&mut slot.labels),
                out: std::mem::take(&mut slot.out),
                reply: slot.reply_tx.clone(), // bns-lint: allow(hot_path_alloc) — SyncSender clone is an Arc refcount bump, not a heap allocation; perf_layers' counting allocator pins allocs_per_eval at 0
                trace: obs::ambient(),
            });
            bound.tx.send(msg)
        };
        if let Err(mpsc::SendError(msg)) = sent {
            // lane gone: recover the buffers so the slot stays warm
            if let Msg::Exec(m) = msg {
                slot.x = m.x;
                slot.labels = m.labels;
                slot.out = m.out;
            }
            lock_ok(&self.pool).push(slot);
            self.suspect(generation);
            return Err(anyhow!("device lane gone"));
        }
        // The lane always replies (backend panics are caught and turned
        // into error replies) — unless it died or is wedged inside the
        // backend, which the timeout converts into a structured error.
        let reply = match slot.reply_rx.recv_timeout(self.timeout) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // Drop the slot: its reply channel may still receive the
                // wedged lane's late reply, and pooling it would deliver
                // stale output to a future call. The late send fails
                // against the dropped receiver without blocking.
                drop(slot);
                trace_event(
                    &self.tracer,
                    obs::ambient(),
                    TraceStage::LaneTimeout,
                    self.lane as u64,
                    generation,
                );
                self.suspect(generation);
                return Err(anyhow!(
                    "device lane {} exec timed out after {:?} (generation {generation})",
                    self.lane,
                    self.timeout
                ));
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                drop(slot);
                self.suspect(generation);
                return Err(anyhow!("device lane dropped request"));
            }
        };
        slot.x = reply.x;
        slot.labels = reply.labels;
        slot.out = reply.out;
        let result = reply.result;
        if result.is_ok() {
            out.copy_from_slice(&slot.out);
        }
        lock_ok(&self.pool).push(slot);
        result
    }

    /// Re-resolve this handle's lane binding after a respawn: fetch the
    /// current sender and the executable's id on the new backend (cache
    /// hit if the supervisor's eager recompile succeeded, a synchronous
    /// compile otherwise). Off the hot path — runs at most once per
    /// respawn per handle.
    fn rebind(&self, bound: &mut Bound, generation: u64) -> Result<()> {
        let mut state = lock_ok(&self.shared.state);
        let id = match state.cache.get(&self.path).copied() {
            Some(id) => id,
            None => {
                // capacity 1: the lane sends exactly one compile result
                let (reply, rx) = mpsc::sync_channel(1);
                state
                    .tx
                    .send(Msg::Load { path: self.path.clone(), reply })
                    .map_err(|_| anyhow!("device lane gone (rebind)"))?;
                let id = rx
                    .recv_timeout(self.timeout.saturating_mul(COMPILE_TIMEOUT_FACTOR))
                    .context("device lane gone or recompile timed out (rebind)")??;
                state.cache.insert(self.path.clone(), id);
                id
            }
        };
        bound.tx = state.tx.clone();
        bound.id = id;
        // read the generation back under the state lock: if another
        // respawn landed while we were rebinding, the next run_into
        // notices the mismatch and rebinds again
        bound.generation = self.shared.generation.load(Ordering::Acquire);
        Ok(())
    }

    /// File a suspicion with the lane supervisor. `try_send`: a full
    /// queue means respawns are already pending, so dropping is safe.
    fn suspect(&self, generation: u64) {
        let _ = lock_ok(&self.sup_tx).try_send(SupMsg::Suspect {
            lane: self.lane,
            generation,
            trace: obs::ambient(),
        });
    }

    /// Allocating convenience wrapper around `run_into`.
    pub fn run(&self, x: &[f32], t: f32, w: f32, labels: &[i32]) -> Result<Vec<f32>> {
        let mut out = vec![0f32; self.batch * self.dim];
        self.run_into(x, t, w, labels, &mut out)?;
        Ok(out)
    }
}

#[allow(clippy::too_many_arguments)]
fn lane_thread(
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::SyncSender<Result<()>>,
    stats: Arc<LaneStats>,
    fault: Option<Arc<FaultPlan>>,
    tracer: TracerCell,
    lane: usize,
    generation: u64,
    mlp_pool_threads: usize,
) {
    let be = match backend::new_cpu(mlp_pool_threads) {
        Ok(b) => {
            let _ = ready.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    // keep a plan handle outside the backend wrapper: the exec loop
    // detects injections by watching the plan's global counter
    let plan_watch = fault.clone();
    // fault injection wraps the backend per (lane, generation) so chaos
    // schedules can target calls precisely and respawned lanes get a
    // fresh fault stream
    let mut be: Box<dyn backend::Backend> = match fault {
        Some(plan) => Box::new(FaultBackend::new(be, plan, lane, generation)),
        None => be,
    };
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Platform { reply } => {
                let _ = reply.send(be.platform());
            }
            Msg::Load { path, reply } => {
                let r = catch_unwind(AssertUnwindSafe(|| be.load(&path)))
                    .unwrap_or_else(|_| Err(anyhow!("backend panicked during load")));
                let _ = reply.send(r);
            }
            Msg::Exec(m) => {
                let ExecMsg { id, batch, dim, t, w, x, labels, mut out, reply, trace } = m;
                let faults_before = plan_watch.as_ref().map(|p| p.injected()).unwrap_or(0);
                let t0 = Instant::now();
                let result = catch_unwind(AssertUnwindSafe(|| {
                    be.exec_into(id, batch, dim, &x, t, w, &labels, &mut out)
                }))
                .unwrap_or_else(|_| Err(anyhow!("backend panicked during exec")));
                let exec_us = t0.elapsed().as_micros() as u64;
                stats.execs.fetch_add(1, Ordering::Relaxed);
                stats.busy_us.fetch_add(exec_us, Ordering::Relaxed);
                // trace before replying so lane events sequence ahead of
                // the engine's post-reply events (exec_ok, emit)
                if let Some(p) = plan_watch.as_ref() {
                    if p.injected() > faults_before {
                        trace_event(
                            &tracer,
                            trace,
                            TraceStage::FaultInjected,
                            lane as u64,
                            p.last_kind_code(),
                        );
                    }
                }
                trace_event(&tracer, trace, TraceStage::LaneExec, lane as u64, exec_us);
                let _ = reply.send(ExecReply { x, labels, out, result });
            }
        }
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;
    use crate::runtime::fault::{FaultConfig, FaultKind, FaultSpec};

    fn stub_artifact(tag: &str, body: &str) -> (PathBuf, PathBuf) {
        let dir = std::env::temp_dir().join(format!("bns-client-{}-{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.stub.json");
        std::fs::write(&path, body).unwrap();
        (dir, path)
    }

    #[test]
    fn run_into_matches_run_and_reuses_pooled_buffers() {
        let (dir, path) =
        stub_artifact(
            "pool",
            r#"{"bns_stub_field": {"k": -0.5, "c": 0.25, "label_scale": 0.1, "t_scale": 0.5}}"#,
        );
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_on(0, &path, 2, 3).unwrap();
        let x = [1.0f32, 2.0, -1.0, 0.5, 0.0, -2.0];
        let labels = [1, 3];
        let reference = exe.run(&x, 0.4, 0.0, &labels).unwrap();
        let mut out = vec![f32::NAN; 6];
        for i in 0..50 {
            // vary t then restore: the pool must never leak stale values
            let t = if i % 2 == 0 { 0.4 } else { 0.9 };
            exe.run_into(&x, t, 0.0, &labels, &mut out).unwrap();
            if i % 2 == 0 {
                assert_eq!(out, reference, "iteration {i}");
            } else {
                assert_ne!(out, reference, "t must change the stub output");
            }
        }
        assert_eq!(rt.lane_stats()[0].0, 51, "every exec is counted");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lanes_are_independent_and_stats_split() {
        let (dir, path) = stub_artifact("lanes", r#"{"bns_stub_field": {"k": 2.0, "c": 0.0}}"#);
        let rt = Runtime::with_lanes(2).unwrap();
        assert_eq!(rt.num_lanes(), 2);
        let e0 = rt.load_on(0, &path, 1, 2).unwrap();
        let e1 = rt.load_on(1, &path, 1, 2).unwrap();
        assert_eq!(e0.lane, 0);
        assert_eq!(e1.lane, 1);
        let mut a = [0f32; 2];
        let mut b = [0f32; 2];
        e0.run_into(&[1.0, 2.0], 0.0, 0.0, &[0], &mut a).unwrap();
        e1.run_into(&[1.0, 2.0], 0.0, 0.0, &[0], &mut b).unwrap();
        assert_eq!(a, [2.0, 4.0]);
        assert_eq!(a, b, "both lanes compiled the same artifact");
        let stats = rt.lane_stats();
        assert_eq!((stats[0].0, stats[1].0), (1, 1));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn round_robin_pins_loads_across_lanes() {
        let (dir, path) = stub_artifact("rr", r#"{"bns_stub_field": {"k": 1.0, "c": 0.0}}"#);
        let rt = Runtime::with_lanes(3).unwrap();
        let lanes: Vec<usize> = (0..6).map(|_| rt.load(&path, 1, 1).unwrap().lane).collect();
        assert_eq!(lanes, vec![0, 1, 2, 0, 1, 2]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn handle_outlives_runtime() {
        let (dir, path) = stub_artifact("outlive", r#"{"bns_stub_field": {"k": -1.0, "c": 0.0}}"#);
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_on(0, &path, 1, 2).unwrap();
        drop(rt);
        // the lane thread stays alive while the handle holds a sender
        let out = exe.run(&[3.0, -4.0], 0.0, 0.0, &[0]).unwrap();
        assert_eq!(out, vec![-3.0, 4.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_artifact_is_an_error_not_a_hang() {
        let (dir, path) = stub_artifact("bad", "HloModule m\nENTRY main { ... }");
        let rt = Runtime::cpu().unwrap();
        let err = rt.load_on(0, &path, 1, 1).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fault_free_plan_is_a_noop() {
        let (dir, path) = stub_artifact("nofault", r#"{"bns_stub_field": {"k": 2.0, "c": 1.0}}"#);
        let rt = Runtime::with_config(RuntimeConfig {
            fault: Some(FaultPlan::none()),
            ..RuntimeConfig::default()
        })
        .unwrap();
        let exe = rt.load_on(0, &path, 1, 2).unwrap();
        let out = exe.run(&[1.0, -1.0], 0.0, 0.0, &[0]).unwrap();
        assert_eq!(out, vec![3.0, -1.0]);
        assert_eq!(rt.faults_injected(), 0);
        assert_eq!(rt.respawns_total(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn transient_error_and_panic_do_not_kill_the_lane() {
        let (dir, path) = stub_artifact("transient", r#"{"bns_stub_field": {"k": 1.0, "c": 0.0}}"#);
        let plan = Arc::new(FaultPlan::new(FaultConfig {
            schedule: vec![
                FaultSpec { lane: Some(0), call: 0, kind: FaultKind::ExecError },
                FaultSpec { lane: Some(0), call: 1, kind: FaultKind::Panic },
            ],
            ..FaultConfig::default()
        }));
        let rt = Runtime::with_config(RuntimeConfig {
            fault: Some(plan),
            ..RuntimeConfig::default()
        })
        .unwrap();
        let exe = rt.load_on(0, &path, 1, 1).unwrap();
        let mut out = [0f32; 1];
        // call 0: injected transient error, surfaced as a structured Err
        let e = exe.run_into(&[5.0], 0.0, 0.0, &[0], &mut out).unwrap_err();
        assert!(e.to_string().contains("injected transient exec error"), "{e}");
        // call 1: injected panic, caught by the lane thread
        let e = exe.run_into(&[5.0], 0.0, 0.0, &[0], &mut out).unwrap_err();
        assert!(e.to_string().contains("backend panicked during exec"), "{e}");
        // call 2: lane is alive and correct; neither fault caused a respawn
        exe.run_into(&[5.0], 0.0, 0.0, &[0], &mut out).unwrap();
        assert_eq!(out, [5.0]);
        assert_eq!(rt.respawns_total(), 0);
        assert_eq!(rt.faults_injected(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wedged_lane_times_out_respawns_and_recovers_bit_identically() {
        let (dir, path) = stub_artifact("wedge", r#"{"bns_stub_field": {"k": -0.5, "c": 0.25}}"#);
        let plan = Arc::new(FaultPlan::new(FaultConfig {
            schedule: vec![FaultSpec { lane: Some(0), call: 1, kind: FaultKind::Wedge }],
            wedge_ms: 400,
            ..FaultConfig::default()
        }));
        let rt = Runtime::with_config(RuntimeConfig {
            lanes: 1,
            lane_exec_timeout: Duration::from_millis(100),
            fault: Some(plan),
            ..Default::default()
        })
        .unwrap();
        let exe = rt.load_on(0, &path, 1, 2).unwrap();
        let x = [2.0f32, -4.0];
        let baseline = exe.run(&x, 0.0, 0.0, &[0]).unwrap(); // call 0: clean
        // call 1: wedge — run_into must return (structured) instead of hanging
        let t0 = Instant::now();
        let mut out = [0f32; 2];
        let e = exe.run_into(&x, 0.0, 0.0, &[0], &mut out).unwrap_err();
        assert!(e.to_string().contains("timed out"), "{e}");
        assert!(t0.elapsed() < Duration::from_millis(350), "timeout must beat the wedge");
        // the supervisor respawns the lane under a bumped generation
        let deadline = Instant::now() + Duration::from_secs(5);
        while rt.lane_health()[0].generation == 0 {
            assert!(Instant::now() < deadline, "lane was never respawned");
            std::thread::sleep(Duration::from_millis(10));
        }
        let h = rt.lane_health()[0];
        assert_eq!(h.generation, 1);
        assert_eq!(h.respawns, 1);
        // service restored, bit-identical to the pre-fault output
        let after = exe.run(&x, 0.0, 0.0, &[0]).unwrap();
        assert_eq!(after, baseline, "respawned lane must reproduce exactly");
        assert_eq!(rt.respawns_total(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn attached_tracer_sees_lane_events_through_a_respawn() {
        let (dir, path) = stub_artifact("trace", r#"{"bns_stub_field": {"k": 1.0, "c": 0.0}}"#);
        let plan = Arc::new(FaultPlan::new(FaultConfig {
            schedule: vec![FaultSpec { lane: Some(0), call: 1, kind: FaultKind::Wedge }],
            wedge_ms: 400,
            ..FaultConfig::default()
        }));
        let rt = Runtime::with_config(RuntimeConfig {
            lanes: 1,
            lane_exec_timeout: Duration::from_millis(100),
            fault: Some(plan),
            ..Default::default()
        })
        .unwrap();
        let tracer = Arc::new(TraceRecorder::new(256));
        rt.attach_tracer(tracer.clone());
        // the ambient id stands in for an engine request id
        obs::set_ambient(42);
        let exe = rt.load_on(0, &path, 1, 1).unwrap();
        let mut out = [0f32; 1];
        exe.run_into(&[1.0], 0.0, 0.0, &[0], &mut out).unwrap(); // call 0: clean
        let e = exe.run_into(&[1.0], 0.0, 0.0, &[0], &mut out).unwrap_err(); // call 1: wedge
        assert!(e.to_string().contains("timed out"), "{e}");
        obs::clear_ambient();
        // lane_respawn lands when the supervisor finishes; fault_injected
        // lands when the wedged thread finally wakes (~400 ms) — poll for
        // the full set instead of assuming an order
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let stages: Vec<&'static str> =
                tracer.trace_for(42).iter().map(|ev| ev.stage.as_str()).collect();
            let done = ["lane_compile", "lane_exec", "lane_timeout", "lane_respawn", "fault_injected"]
                .iter()
                .all(|s| stages.contains(s));
            if done {
                // the wedge's fault kind code rides in the event payload
                let fi = tracer
                    .trace_for(42)
                    .into_iter()
                    .find(|ev| ev.stage == TraceStage::FaultInjected)
                    .unwrap();
                assert_eq!(fi.b, FaultKind::Wedge.code());
                assert_eq!(fi.a, 0, "lane index rides in a");
                break;
            }
            assert!(Instant::now() < deadline, "timeline incomplete: {stages:?}");
            std::thread::sleep(Duration::from_millis(20));
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
