//! PJRT runtime: load HLO-text artifacts, compile once per (model, batch)
//! bucket, execute from the request path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `PjRtClient::compile` -> `execute`.
//! Text is the interchange (see python/compile/aot.py for why).
//!
//! Threading: the `xla` crate's client/executable types are `!Send`
//! (Rc-based wrappers over the C API), so a dedicated **device thread**
//! owns every PJRT object — the same discipline as a GPU stream owner.
//! Callers talk to it over channels; `ExeHandle::run` is a synchronous
//! RPC. On this CPU target execution is serialized anyway, so the design
//! costs ~1us of channel latency against ~400us executions.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Mutex};

use anyhow::{anyhow, Context, Result};

enum Msg {
    Load {
        path: PathBuf,
        reply: mpsc::Sender<Result<u64>>,
    },
    Exec {
        id: u64,
        batch: usize,
        dim: usize,
        x: Vec<f32>,
        t: f32,
        w: f32,
        labels: Vec<i32>,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Platform {
        reply: mpsc::Sender<String>,
    },
}

/// Handle to the device thread. Cheap to share via Arc.
pub struct Runtime {
    tx: Mutex<mpsc::Sender<Msg>>,
    /// path -> executable id (compile cache)
    cache: Mutex<HashMap<PathBuf, u64>>,
    thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let thread = std::thread::Builder::new()
            .name("pjrt-device".into())
            .spawn(move || device_thread(rx, ready_tx))
            .context("spawning device thread")?;
        ready_rx
            .recv()
            .context("device thread died during init")??;
        Ok(Runtime {
            tx: Mutex::new(tx),
            cache: Mutex::new(HashMap::new()),
            thread: Mutex::new(Some(thread)),
        })
    }

    fn send(&self, msg: Msg) {
        // Sender is !Sync; the mutex makes the handle shareable.
        let _ = self.tx.lock().unwrap().send(msg);
    }

    pub fn platform(&self) -> String {
        let (reply, rx) = mpsc::channel();
        self.send(Msg::Platform { reply });
        rx.recv().unwrap_or_else(|_| "unknown".into())
    }

    /// Load + compile an HLO text artifact (cached by path).
    pub fn load(&self, path: &Path, batch: usize, dim: usize) -> Result<ExeHandle> {
        if let Some(&id) = self.cache.lock().unwrap().get(path) {
            return Ok(ExeHandle { rt_tx: self.tx.lock().unwrap().clone().into(), id, batch, dim });
        }
        let (reply, rx) = mpsc::channel();
        self.send(Msg::Load { path: path.to_path_buf(), reply });
        let id = rx.recv().context("device thread gone")??;
        self.cache.lock().unwrap().insert(path.to_path_buf(), id);
        Ok(ExeHandle { rt_tx: self.tx.lock().unwrap().clone().into(), id, batch, dim })
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        // Replace the sender with a disconnected dummy; once every
        // ExeHandle clone is gone too, the device thread's recv() errors
        // out and it exits. We deliberately do NOT join: an ExeHandle may
        // outlive the Runtime and joining would deadlock — the detached
        // thread exits as soon as the last sender drops.
        let (dummy, _) = mpsc::channel();
        *self.tx.lock().unwrap() = dummy;
        self.thread.lock().unwrap().take();
    }
}

/// A compiled velocity-field executable with the aot.py signature
/// (x [B,D] f32, t [] f32, w [] f32, labels [B] i32) -> (u [B,D] f32,).
pub struct ExeHandle {
    rt_tx: Mutex<mpsc::Sender<Msg>>,
    id: u64,
    pub batch: usize,
    pub dim: usize,
}

impl ExeHandle {
    /// Execute on exactly `self.batch` rows (synchronous RPC).
    pub fn run(&self, x: &[f32], t: f32, w: f32, labels: &[i32]) -> Result<Vec<f32>> {
        debug_assert_eq!(x.len(), self.batch * self.dim);
        debug_assert_eq!(labels.len(), self.batch);
        let (reply, rx) = mpsc::channel();
        {
            let tx = self.rt_tx.lock().unwrap();
            tx.send(Msg::Exec {
                id: self.id,
                batch: self.batch,
                dim: self.dim,
                x: x.to_vec(),
                t,
                w,
                labels: labels.to_vec(),
                reply,
            })
            .map_err(|_| anyhow!("device thread gone"))?;
        }
        rx.recv().map_err(|_| anyhow!("device thread dropped request"))?
    }
}

fn device_thread(rx: mpsc::Receiver<Msg>, ready: mpsc::Sender<Result<()>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow!("PJRT CPU client: {e}")));
            return;
        }
    };
    let mut exes: HashMap<u64, xla::PjRtLoadedExecutable> = HashMap::new();
    let mut next_id = 1u64;
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Platform { reply } => {
                let _ = reply.send(client.platform_name());
            }
            Msg::Load { path, reply } => {
                let r = (|| -> Result<u64> {
                    let proto = xla::HloModuleProto::from_text_file(
                        path.to_str().context("non-utf8 artifact path")?,
                    )
                    .map_err(|e| anyhow!("parsing HLO {}: {e}", path.display()))?;
                    let comp = xla::XlaComputation::from_proto(&proto);
                    let exe = client
                        .compile(&comp)
                        .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
                    let id = next_id;
                    next_id += 1;
                    exes.insert(id, exe);
                    Ok(id)
                })();
                let _ = reply.send(r);
            }
            Msg::Exec { id, batch, dim, x, t, w, labels, reply } => {
                let r = (|| -> Result<Vec<f32>> {
                    let exe = exes.get(&id).context("unknown executable id")?;
                    let xl = xla::Literal::vec1(&x)
                        .reshape(&[batch as i64, dim as i64])
                        .map_err(|e| anyhow!("reshape: {e}"))?;
                    let tl = xla::Literal::scalar(t);
                    let wl = xla::Literal::scalar(w);
                    let ll = xla::Literal::vec1(&labels[..]);
                    let result = exe
                        .execute::<xla::Literal>(&[xl, tl, wl, ll])
                        .map_err(|e| anyhow!("execute: {e}"))?[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow!("to_literal: {e}"))?;
                    let out = result.to_tuple1().map_err(|e| anyhow!("tuple: {e}"))?;
                    out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))
                })();
                let _ = reply.send(r);
            }
        }
    }
}
