//! Runtime device thread: load artifacts, compile once per (model, batch)
//! bucket, execute from the request path.
//!
//! The concrete executor lives behind `backend::Backend` — real PJRT via
//! the `xla` crate when built with `--features pjrt`, the offline stub
//! backend otherwise (see `backend.rs` for the rationale and the stub
//! artifact format).
//!
//! Threading: the PJRT client/executable types are `!Send` (Rc-based
//! wrappers over the C API), so a dedicated **device thread** owns every
//! backend object — the same discipline as a GPU stream owner. Callers
//! talk to it over channels; `ExeHandle::run` is a synchronous RPC. On
//! this CPU target execution is serialized anyway, so the design costs
//! ~1us of channel latency against ~400us executions.
//!
//! TODO(perf): `ExeHandle::run` copies `x`/`labels` into the message and
//! the backend returns a fresh output vector — per-eval allocations that
//! survive the solver-side workspace rewrite. Pooling request/response
//! buffers across the channel would finish the job; it needs a buffer
//! return path, so it is deferred.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Mutex};

use anyhow::{anyhow, Context, Result};

use super::backend;

enum Msg {
    Load {
        path: PathBuf,
        reply: mpsc::Sender<Result<u64>>,
    },
    Exec {
        id: u64,
        batch: usize,
        dim: usize,
        x: Vec<f32>,
        t: f32,
        w: f32,
        labels: Vec<i32>,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Platform {
        reply: mpsc::Sender<String>,
    },
}

/// Handle to the device thread. Cheap to share via Arc.
pub struct Runtime {
    tx: Mutex<mpsc::Sender<Msg>>,
    /// path -> executable id (compile cache)
    cache: Mutex<HashMap<PathBuf, u64>>,
    thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let thread = std::thread::Builder::new()
            .name("pjrt-device".into())
            .spawn(move || device_thread(rx, ready_tx))
            .context("spawning device thread")?;
        ready_rx
            .recv()
            .context("device thread died during init")??;
        Ok(Runtime {
            tx: Mutex::new(tx),
            cache: Mutex::new(HashMap::new()),
            thread: Mutex::new(Some(thread)),
        })
    }

    fn send(&self, msg: Msg) {
        // Sender is !Sync; the mutex makes the handle shareable.
        let _ = self.tx.lock().unwrap().send(msg);
    }

    pub fn platform(&self) -> String {
        let (reply, rx) = mpsc::channel();
        self.send(Msg::Platform { reply });
        rx.recv().unwrap_or_else(|_| "unknown".into())
    }

    /// Load + compile an artifact (cached by path).
    pub fn load(&self, path: &Path, batch: usize, dim: usize) -> Result<ExeHandle> {
        if let Some(&id) = self.cache.lock().unwrap().get(path) {
            return Ok(ExeHandle { rt_tx: self.tx.lock().unwrap().clone().into(), id, batch, dim });
        }
        let (reply, rx) = mpsc::channel();
        self.send(Msg::Load { path: path.to_path_buf(), reply });
        let id = rx.recv().context("device thread gone")??;
        self.cache.lock().unwrap().insert(path.to_path_buf(), id);
        Ok(ExeHandle { rt_tx: self.tx.lock().unwrap().clone().into(), id, batch, dim })
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        // Replace the sender with a disconnected dummy; once every
        // ExeHandle clone is gone too, the device thread's recv() errors
        // out and it exits. We deliberately do NOT join: an ExeHandle may
        // outlive the Runtime and joining would deadlock — the detached
        // thread exits as soon as the last sender drops.
        let (dummy, _) = mpsc::channel();
        *self.tx.lock().unwrap() = dummy;
        self.thread.lock().unwrap().take();
    }
}

/// A compiled velocity-field executable with the aot.py signature
/// (x [B,D] f32, t [] f32, w [] f32, labels [B] i32) -> (u [B,D] f32,).
pub struct ExeHandle {
    rt_tx: Mutex<mpsc::Sender<Msg>>,
    id: u64,
    pub batch: usize,
    pub dim: usize,
}

impl ExeHandle {
    /// Execute on exactly `self.batch` rows (synchronous RPC).
    pub fn run(&self, x: &[f32], t: f32, w: f32, labels: &[i32]) -> Result<Vec<f32>> {
        debug_assert_eq!(x.len(), self.batch * self.dim);
        debug_assert_eq!(labels.len(), self.batch);
        let (reply, rx) = mpsc::channel();
        {
            let tx = self.rt_tx.lock().unwrap();
            tx.send(Msg::Exec {
                id: self.id,
                batch: self.batch,
                dim: self.dim,
                x: x.to_vec(),
                t,
                w,
                labels: labels.to_vec(),
                reply,
            })
            .map_err(|_| anyhow!("device thread gone"))?;
        }
        rx.recv().map_err(|_| anyhow!("device thread dropped request"))?
    }
}

fn device_thread(rx: mpsc::Receiver<Msg>, ready: mpsc::Sender<Result<()>>) {
    let mut be = match backend::new_cpu() {
        Ok(b) => {
            let _ = ready.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Platform { reply } => {
                let _ = reply.send(be.platform());
            }
            Msg::Load { path, reply } => {
                let _ = reply.send(be.load(&path));
            }
            Msg::Exec { id, batch, dim, x, t, w, labels, reply } => {
                let _ = reply.send(be.exec(id, batch, dim, &x, t, w, &labels));
            }
        }
    }
}
