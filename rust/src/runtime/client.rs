//! Runtime device lanes: load artifacts, compile once per (lane, model,
//! batch) bucket, execute from the request path.
//!
//! The concrete executor lives behind `backend::Backend` — real PJRT via
//! the `xla` crate when built with `--features pjrt`, the offline stub
//! backend otherwise (see `backend.rs` for the rationale and the stub
//! artifact format).
//!
//! # Device lanes
//!
//! The runtime owns a configurable set of **lanes**. Each lane is one
//! dedicated thread that owns its own `Backend` instance and its own
//! compile cache — the same discipline as a GPU stream owner, multiplied.
//! Executables (and therefore model fields) are *pinned* to the lane that
//! compiled them, so two engine workers whose models landed on different
//! lanes execute model evals truly concurrently. Under `--features pjrt`
//! the lane count is forced to 1: the PJRT client/executable types are
//! `!Send` (Rc-based wrappers over the C API) and the vendored bindings
//! assume a single process-wide client.
//!
//! # Pooled (zero-allocation) execution
//!
//! `ExeHandle::run_into` is the hot-path RPC. Its request/response
//! buffers live in a per-handle **slot pool**: the x/labels/out vectors
//! travel to the lane inside the message and come back with the reply, so
//! at steady state an eval performs no heap allocation anywhere on the
//! path — the lane channel is a bounded `sync_channel` (preallocated ring,
//! allocation-free sends), each slot's reply channel is a rendezvous
//! `sync_channel(1)`, and the backend writes velocities into the pooled
//! `out` buffer in place (`Backend::exec_into`). To be precise: the claim
//! is zero *allocation*, not zero copy — each eval still pays two bounded
//! memcpys (caller x into the slot, pooled out back into the caller's
//! buffer); eliminating those would require the solver workspace itself
//! to cross the thread boundary. The lane thread wraps backend calls in
//! `catch_unwind` so a panicking backend yields an error reply instead of
//! a wedged caller. `benches/perf_layers.rs` measures allocations per
//! eval with a counting global allocator to pin the claim.
//!
//! The wavefront gradient engine's stacked batched-JVP evals
//! (`ModelField::jvp_batch_into`, DESIGN.md §8) ride this same pooled
//! RPC: one bucketized dispatch carries the `x ± ε·v` rows of every
//! tangent of a training step, so distillation training inherits the
//! zero-allocation steady state — `benches/distill_bench.rs` pins it
//! per Adam step with the same counting-allocator method.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::backend;
use crate::util::sync::lock_ok;

/// Bounded depth of each lane's request channel. Generous: the channel is
/// a backpressure valve, not a queueing layer — workers block in
/// `run_into` anyway.
const LANE_QUEUE_CAP: usize = 256;

enum Msg {
    Load {
        path: PathBuf,
        reply: mpsc::SyncSender<Result<u64>>,
    },
    Exec(ExecMsg),
    Platform {
        reply: mpsc::SyncSender<String>,
    },
}

/// One pooled execution request. The buffers are owned by the message
/// while it is in flight and return to the caller inside `ExecReply`.
struct ExecMsg {
    id: u64,
    batch: usize,
    dim: usize,
    t: f32,
    w: f32,
    x: Vec<f32>,
    labels: Vec<i32>,
    out: Vec<f32>,
    reply: mpsc::SyncSender<ExecReply>,
}

struct ExecReply {
    x: Vec<f32>,
    labels: Vec<i32>,
    out: Vec<f32>,
    result: Result<()>,
}

/// Per-lane execution counters, shared with the lane thread. `busy_us`
/// is time spent inside the backend — utilization is `busy_us / wall`.
#[derive(Default)]
pub struct LaneStats {
    pub execs: AtomicU64,
    pub busy_us: AtomicU64,
}

struct Lane {
    // Senders are !Sync; the mutex makes the handle shareable.
    tx: Mutex<mpsc::SyncSender<Msg>>,
    /// path -> executable id (per-lane compile cache: ids are local to
    /// the lane's backend instance).
    cache: Mutex<HashMap<PathBuf, u64>>,
    stats: Arc<LaneStats>,
}

/// Handle to the device lanes. Cheap to share via Arc.
pub struct Runtime {
    lanes: Vec<Lane>,
    /// Round-robin cursor for pinning new loads to a lane.
    next: AtomicUsize,
}

impl Runtime {
    /// Single-lane runtime — the PJRT-safe default.
    pub fn cpu() -> Result<Runtime> {
        Self::with_lanes(1)
    }

    /// Runtime with `n` device lanes. Forced to 1 under `--features
    /// pjrt` (the PJRT types are `!Send` and the bindings assume one
    /// process-wide client).
    pub fn with_lanes(n: usize) -> Result<Runtime> {
        let n = if cfg!(feature = "pjrt") { 1 } else { n.max(1) };
        let mut lanes = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = mpsc::sync_channel::<Msg>(LANE_QUEUE_CAP);
            // capacity 1: the lane sends exactly one init result
            let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
            let stats = Arc::new(LaneStats::default());
            let stats_t = stats.clone();
            std::thread::Builder::new()
                .name(format!("bns-lane-{i}"))
                .spawn(move || lane_thread(rx, ready_tx, stats_t))
                .context("spawning device lane thread")?;
            ready_rx
                .recv()
                .context("device lane died during init")??;
            lanes.push(Lane {
                tx: Mutex::new(tx),
                cache: Mutex::new(HashMap::new()),
                stats,
            });
        }
        Ok(Runtime { lanes, next: AtomicUsize::new(0) })
    }

    pub fn num_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Next lane in round-robin order — the pinning policy for new loads.
    pub fn next_lane(&self) -> usize {
        self.next.fetch_add(1, Ordering::Relaxed) % self.lanes.len()
    }

    /// Per-lane `(execs, busy_us)` counters, indexed by lane.
    pub fn lane_stats(&self) -> Vec<(u64, u64)> {
        self.lanes
            .iter()
            .map(|l| {
                (
                    l.stats.execs.load(Ordering::Relaxed),
                    l.stats.busy_us.load(Ordering::Relaxed),
                )
            })
            .collect()
    }

    pub fn platform(&self) -> String {
        // capacity 1: the lane sends exactly one platform string
        let (reply, rx) = mpsc::sync_channel(1);
        let _ = lock_ok(&self.lanes[0].tx).send(Msg::Platform { reply });
        rx.recv().unwrap_or_else(|_| "unknown".into())
    }

    /// Load + compile an artifact on `lane` (cached per lane by path).
    pub fn load_on(&self, lane: usize, path: &Path, batch: usize, dim: usize) -> Result<ExeHandle> {
        let l = self
            .lanes
            .get(lane)
            .ok_or_else(|| anyhow!("lane {lane} out of range ({} lanes)", self.lanes.len()))?;
        // hold the cache lock across the compile RPC: concurrent first
        // loads of the same artifact must not compile it twice (the
        // loser's executable would be orphaned in the lane's backend —
        // a duplicate HLO compile + held memory under PJRT). The lane
        // thread never takes this lock, so no deadlock; concurrent loads
        // on one lane serialize, which a compile does anyway.
        let id = {
            let mut cache = lock_ok(&l.cache);
            match cache.get(path).copied() {
                Some(id) => id,
                None => {
                    // capacity 1: the lane sends exactly one compile result
                    let (reply, rx) = mpsc::sync_channel(1);
                    lock_ok(&l.tx)
                        .send(Msg::Load { path: path.to_path_buf(), reply })
                        .map_err(|_| anyhow!("device lane gone"))?;
                    let id = rx.recv().context("device lane gone")??;
                    cache.insert(path.to_path_buf(), id);
                    id
                }
            }
        };
        Ok(ExeHandle {
            tx: Mutex::new(lock_ok(&l.tx).clone()),
            pool: Mutex::new(Vec::new()),
            id,
            lane,
            batch,
            dim,
        })
    }

    /// Load + compile on the next round-robin lane.
    pub fn load(&self, path: &Path, batch: usize, dim: usize) -> Result<ExeHandle> {
        self.load_on(self.next_lane(), path, batch, dim)
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        // Replace each lane's sender with a disconnected dummy; once every
        // ExeHandle clone is gone too, the lane's recv() errors out and
        // the thread exits. We deliberately do NOT join: an ExeHandle may
        // outlive the Runtime and joining would deadlock — the detached
        // thread exits as soon as the last sender drops.
        for lane in &self.lanes {
            let (dummy, _) = mpsc::sync_channel(1);
            *lock_ok(&lane.tx) = dummy;
        }
    }
}

/// One pooled buffer set + its private reply channel. Slots cycle
/// caller -> lane -> caller; their vectors only ever grow, so steady
/// state reuses capacity and allocates nothing.
struct ExecSlot {
    x: Vec<f32>,
    labels: Vec<i32>,
    out: Vec<f32>,
    reply_tx: mpsc::SyncSender<ExecReply>,
    reply_rx: mpsc::Receiver<ExecReply>,
}

impl Default for ExecSlot {
    fn default() -> Self {
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        ExecSlot {
            x: Vec::new(),
            labels: Vec::new(),
            out: Vec::new(),
            reply_tx,
            reply_rx,
        }
    }
}

/// A compiled velocity-field executable with the aot.py signature
/// (x [B,D] f32, t [] f32, w [] f32, labels [B] i32) -> (u [B,D] f32,),
/// pinned to the device lane that compiled it.
pub struct ExeHandle {
    tx: Mutex<mpsc::SyncSender<Msg>>,
    pool: Mutex<Vec<ExecSlot>>,
    id: u64,
    /// Lane this executable is pinned to.
    pub lane: usize,
    pub batch: usize,
    pub dim: usize,
}

impl ExeHandle {
    /// Execute on exactly `self.batch` rows, writing the velocities into
    /// `out` (synchronous RPC over pooled buffers; zero heap allocation
    /// at steady state).
    pub fn run_into(
        &self,
        x: &[f32],
        t: f32,
        w: f32,
        labels: &[i32],
        out: &mut [f32],
    ) -> Result<()> {
        debug_assert_eq!(x.len(), self.batch * self.dim);
        debug_assert_eq!(labels.len(), self.batch);
        debug_assert_eq!(out.len(), self.batch * self.dim);
        let mut slot = lock_ok(&self.pool).pop().unwrap_or_default();
        slot.x.clear();
        slot.x.extend_from_slice(x);
        slot.labels.clear();
        slot.labels.extend_from_slice(labels);
        slot.out.resize(out.len(), 0.0);
        let msg = Msg::Exec(ExecMsg {
            id: self.id,
            batch: self.batch,
            dim: self.dim,
            t,
            w,
            x: std::mem::take(&mut slot.x),
            labels: std::mem::take(&mut slot.labels),
            out: std::mem::take(&mut slot.out),
            reply: slot.reply_tx.clone(), // bns-lint: allow(hot_path_alloc) — SyncSender clone is an Arc refcount bump, not a heap allocation; perf_layers' counting allocator pins allocs_per_eval at 0
        });
        let sent = lock_ok(&self.tx).send(msg);
        if let Err(mpsc::SendError(msg)) = sent {
            // lane gone: recover the buffers so the slot stays warm
            if let Msg::Exec(m) = msg {
                slot.x = m.x;
                slot.labels = m.labels;
                slot.out = m.out;
            }
            lock_ok(&self.pool).push(slot);
            return Err(anyhow!("device lane gone"));
        }
        // The lane always replies (backend panics are caught and turned
        // into error replies), so this only fails if the lane died.
        let reply = match slot.reply_rx.recv() {
            Ok(r) => r,
            Err(_) => return Err(anyhow!("device lane dropped request")),
        };
        slot.x = reply.x;
        slot.labels = reply.labels;
        slot.out = reply.out;
        let result = reply.result;
        if result.is_ok() {
            out.copy_from_slice(&slot.out);
        }
        lock_ok(&self.pool).push(slot);
        result
    }

    /// Allocating convenience wrapper around `run_into`.
    pub fn run(&self, x: &[f32], t: f32, w: f32, labels: &[i32]) -> Result<Vec<f32>> {
        let mut out = vec![0f32; self.batch * self.dim];
        self.run_into(x, t, w, labels, &mut out)?;
        Ok(out)
    }
}

fn lane_thread(
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::SyncSender<Result<()>>,
    stats: Arc<LaneStats>,
) {
    let mut be = match backend::new_cpu() {
        Ok(b) => {
            let _ = ready.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Platform { reply } => {
                let _ = reply.send(be.platform());
            }
            Msg::Load { path, reply } => {
                let r = catch_unwind(AssertUnwindSafe(|| be.load(&path)))
                    .unwrap_or_else(|_| Err(anyhow!("backend panicked during load")));
                let _ = reply.send(r);
            }
            Msg::Exec(m) => {
                let ExecMsg { id, batch, dim, t, w, x, labels, mut out, reply } = m;
                let t0 = Instant::now();
                let result = catch_unwind(AssertUnwindSafe(|| {
                    be.exec_into(id, batch, dim, &x, t, w, &labels, &mut out)
                }))
                .unwrap_or_else(|_| Err(anyhow!("backend panicked during exec")));
                stats.execs.fetch_add(1, Ordering::Relaxed);
                stats
                    .busy_us
                    .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
                let _ = reply.send(ExecReply { x, labels, out, result });
            }
        }
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;

    fn stub_artifact(tag: &str, body: &str) -> (PathBuf, PathBuf) {
        let dir = std::env::temp_dir().join(format!("bns-client-{}-{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.stub.json");
        std::fs::write(&path, body).unwrap();
        (dir, path)
    }

    #[test]
    fn run_into_matches_run_and_reuses_pooled_buffers() {
        let (dir, path) =
        stub_artifact(
            "pool",
            r#"{"bns_stub_field": {"k": -0.5, "c": 0.25, "label_scale": 0.1, "t_scale": 0.5}}"#,
        );
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_on(0, &path, 2, 3).unwrap();
        let x = [1.0f32, 2.0, -1.0, 0.5, 0.0, -2.0];
        let labels = [1, 3];
        let reference = exe.run(&x, 0.4, 0.0, &labels).unwrap();
        let mut out = vec![f32::NAN; 6];
        for i in 0..50 {
            // vary t then restore: the pool must never leak stale values
            let t = if i % 2 == 0 { 0.4 } else { 0.9 };
            exe.run_into(&x, t, 0.0, &labels, &mut out).unwrap();
            if i % 2 == 0 {
                assert_eq!(out, reference, "iteration {i}");
            } else {
                assert_ne!(out, reference, "t must change the stub output");
            }
        }
        assert_eq!(rt.lane_stats()[0].0, 51, "every exec is counted");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lanes_are_independent_and_stats_split() {
        let (dir, path) = stub_artifact("lanes", r#"{"bns_stub_field": {"k": 2.0, "c": 0.0}}"#);
        let rt = Runtime::with_lanes(2).unwrap();
        assert_eq!(rt.num_lanes(), 2);
        let e0 = rt.load_on(0, &path, 1, 2).unwrap();
        let e1 = rt.load_on(1, &path, 1, 2).unwrap();
        assert_eq!(e0.lane, 0);
        assert_eq!(e1.lane, 1);
        let mut a = [0f32; 2];
        let mut b = [0f32; 2];
        e0.run_into(&[1.0, 2.0], 0.0, 0.0, &[0], &mut a).unwrap();
        e1.run_into(&[1.0, 2.0], 0.0, 0.0, &[0], &mut b).unwrap();
        assert_eq!(a, [2.0, 4.0]);
        assert_eq!(a, b, "both lanes compiled the same artifact");
        let stats = rt.lane_stats();
        assert_eq!((stats[0].0, stats[1].0), (1, 1));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn round_robin_pins_loads_across_lanes() {
        let (dir, path) = stub_artifact("rr", r#"{"bns_stub_field": {"k": 1.0, "c": 0.0}}"#);
        let rt = Runtime::with_lanes(3).unwrap();
        let lanes: Vec<usize> = (0..6).map(|_| rt.load(&path, 1, 1).unwrap().lane).collect();
        assert_eq!(lanes, vec![0, 1, 2, 0, 1, 2]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn handle_outlives_runtime() {
        let (dir, path) = stub_artifact("outlive", r#"{"bns_stub_field": {"k": -1.0, "c": 0.0}}"#);
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_on(0, &path, 1, 2).unwrap();
        drop(rt);
        // the lane thread stays alive while the handle holds a sender
        let out = exe.run(&[3.0, -4.0], 0.0, 0.0, &[0]).unwrap();
        assert_eq!(out, vec![-3.0, 4.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_artifact_is_an_error_not_a_hang() {
        let (dir, path) = stub_artifact("bad", "HloModule m\nENTRY main { ... }");
        let rt = Runtime::cpu().unwrap();
        let err = rt.load_on(0, &path, 1, 1).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
