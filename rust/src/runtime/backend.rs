//! Device backends behind the device lane threads in `client.rs`
//! (each lane owns one `Backend` instance; see DESIGN.md §5).
//!
//! The real executor is PJRT via the `xla` crate — which, like
//! serde/tokio/clap, is **not resolvable in the offline build image**
//! (DESIGN.md §3). It is therefore gated behind the `pjrt` cargo feature:
//! enabling it requires vendoring the `xla` crate and adding it to
//! `[dependencies]`. The code paths are otherwise identical — both
//! backends sit behind the same `Backend` trait and the same device
//! thread, so the engine/runtime layers never know which one runs.
//!
//! The default (no-feature) build uses `StubBackend`, which loads two
//! JSON artifact kinds (and refuses real HLO text with an actionable
//! error):
//!
//! * `{"bns_stub_field": {"k": .., "c": ..}}` — the affine velocity field
//!       u[r, d] = k * x[r, d] + c + label_scale * labels[r] + t_scale * t
//!   evaluated in f32. An optional `cost` key repeats the compute pass
//!   (identical output, proportionally more wall time) so load benches
//!   can emulate heavier models. **`cost` is a wall-time knob only**: it
//!   never changes outputs and never feeds `forwards` accounting —
//!   `forwards_per_eval` comes exclusively from the manifest (model
//!   structure: 2 for guided fields, 1 otherwise), a distinction pinned
//!   by `tests/engine_accounting.rs`.
//! * `{"bns_mlp_field": {...}}` — a real-compute time-modulated residual
//!   MLP executed by the CPU kernels in `crate::kernels` (tiled GEMM,
//!   fused resblock; DESIGN.md §13). Weights ship in the JSON. Wide
//!   batches are fanned across a persistent intra-lane `RowPool` whose
//!   thread count is a pure throughput knob — results are bit-identical
//!   for any setting.
//!
//! That keeps the full serving stack (engine, batcher, router,
//! accounting) executable and testable — `cargo test` drives real
//! batches end-to-end through the device thread — without any compiled
//! model. `bench_util::write_stub_artifacts` /
//! `bench_util::write_mlp_artifacts` emit complete artifact directories
//! in these formats.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::kernels::mlp::{forward_rows, MlpModel, MlpScratch};
use crate::kernels::pool::{RowPool, CHUNK_ROWS};

/// A compiled-executable store owned by a device lane thread. Implementors
/// are **not** required to be `Send`/`Sync`: one lane thread owns each
/// backend instance for its whole lifetime (the PJRT types are `!Send`).
pub trait Backend {
    fn platform(&self) -> String;

    /// Load + compile an artifact file; returns a backend-local id.
    fn load(&mut self, path: &Path) -> Result<u64>;

    /// Execute executable `id` on exactly `batch` rows, writing the
    /// velocities into `out` (`len == batch * dim`). Every element of
    /// `out` must be overwritten on success — callers pass pooled
    /// buffers whose prior contents are arbitrary. This is the hot-path
    /// entry: the stub backend computes straight into `out`, PJRT copies
    /// its result literal into `out` once.
    #[allow(clippy::too_many_arguments)]
    fn exec_into(
        &mut self,
        id: u64,
        batch: usize,
        dim: usize,
        x: &[f32],
        t: f32,
        w: f32,
        labels: &[i32],
        out: &mut [f32],
    ) -> Result<()>;

    /// Allocating convenience wrapper around `exec_into`.
    #[allow(clippy::too_many_arguments)]
    fn exec(
        &mut self,
        id: u64,
        batch: usize,
        dim: usize,
        x: &[f32],
        t: f32,
        w: f32,
        labels: &[i32],
    ) -> Result<Vec<f32>> {
        let mut out = vec![0f32; batch * dim];
        self.exec_into(id, batch, dim, x, t, w, labels, &mut out)?;
        Ok(out)
    }
}

/// Construct the CPU backend selected at compile time.
///
/// `mlp_pool_threads` sizes the per-lane `bns_mlp_field` row pool
/// (0 = auto: `min(available_parallelism, 8)`, 1 = inline, no pool). The
/// PJRT backend brings its own threading and ignores it.
pub fn new_cpu(mlp_pool_threads: usize) -> Result<Box<dyn Backend>> {
    #[cfg(feature = "pjrt")]
    {
        let _ = mlp_pool_threads;
        return Ok(Box::new(pjrt::PjrtBackend::new()?));
    }
    #[cfg(not(feature = "pjrt"))]
    Ok(Box::new(StubBackend::with_pool_threads(mlp_pool_threads)))
}

// ---------------------------------------------------------------------------
// Stub backend (default build)
// ---------------------------------------------------------------------------

/// Parameters of one stub affine field artifact. `cost` repeats the
/// (idempotent) compute pass so benches can emulate heavier models:
/// output is identical for any cost, wall time scales with it. It is
/// **not** a forwards-accounting input — only the manifest's
/// `forwards_per_eval` feeds `forwards` totals (DESIGN.md §9).
#[derive(Debug, Clone, Copy)]
struct StubExe {
    k: f32,
    c: f32,
    label_scale: f32,
    t_scale: f32,
    cost: u32,
}

/// One loaded real-compute MLP field: parsed weights (shared with the
/// pool workers) plus the lane-local scratch used for inline execs.
struct MlpExe {
    model: Arc<MlpModel>,
    scratch: MlpScratch,
}

/// One loaded executable of either artifact kind.
enum Exe {
    Affine(StubExe),
    Mlp(MlpExe),
}

/// Offline-build device backend: loads `bns_stub_field` (affine) and
/// `bns_mlp_field` (real CPU compute) JSON artifacts.
pub struct StubBackend {
    exes: Vec<Exe>,
    /// Configured pool width (0 = auto); resolved on first MLP load.
    pool_threads: usize,
    /// Spawned lazily on the first `bns_mlp_field` load, and only when
    /// the resolved width exceeds 1 — stub-only lanes never pay for it.
    pool: Option<RowPool>,
}

impl StubBackend {
    pub fn new() -> Self {
        Self::with_pool_threads(0)
    }

    /// Backend with an explicit intra-lane MLP pool width. 0 = auto
    /// (`min(available_parallelism, 8)`), 1 = always inline.
    pub fn with_pool_threads(pool_threads: usize) -> Self {
        StubBackend { exes: Vec::new(), pool_threads, pool: None }
    }

    fn resolved_pool_threads(&self) -> usize {
        if self.pool_threads > 0 {
            return self.pool_threads;
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
    }
}

impl Default for StubBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for StubBackend {
    fn platform(&self) -> String {
        "stub-cpu".to_string()
    }

    fn load(&mut self, path: &Path) -> Result<u64> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading artifact {}", path.display()))?;
        let trimmed = text.trim_start();
        let json = if trimmed.starts_with('{') {
            crate::util::json::Json::parse(trimmed).ok()
        } else {
            None
        };
        if let Some(j) = &json {
            let spec = j.get("bns_stub_field");
            if spec != &crate::util::json::Json::Null {
                let g = |k: &str, default: f64| spec.get(k).as_f64().unwrap_or(default) as f32;
                self.exes.push(Exe::Affine(StubExe {
                    k: g("k", -1.0),
                    c: g("c", 0.0),
                    label_scale: g("label_scale", 0.0),
                    t_scale: g("t_scale", 0.0),
                    cost: spec.get("cost").as_f64().unwrap_or(1.0).max(1.0) as u32,
                }));
                return Ok(self.exes.len() as u64);
            }
            let spec = j.get("bns_mlp_field");
            if spec != &crate::util::json::Json::Null {
                let model = MlpModel::from_json(spec)
                    .with_context(|| format!("parsing mlp artifact {}", path.display()))?;
                if self.pool.is_none() {
                    let threads = self.resolved_pool_threads();
                    if threads > 1 {
                        // Spawn here, on the (cold) load path, so exec_into
                        // stays allocation-free at steady state.
                        self.pool = Some(RowPool::new(threads)?);
                    }
                }
                self.exes.push(Exe::Mlp(MlpExe {
                    model: Arc::new(model),
                    scratch: MlpScratch::new(),
                }));
                return Ok(self.exes.len() as u64);
            }
        }
        Err(anyhow!(
            "artifact {} is not a bns_stub_field / bns_mlp_field JSON file; executing \
             real HLO artifacts requires the PJRT backend (build with `--features pjrt` \
             and a vendored `xla` crate)",
            path.display()
        ))
    }

    fn exec_into(
        &mut self,
        id: u64,
        batch: usize,
        dim: usize,
        x: &[f32],
        t: f32,
        w: f32,
        labels: &[i32],
        out: &mut [f32],
    ) -> Result<()> {
        let StubBackend { exes, pool, .. } = self;
        let exe = exes
            .get_mut((id as usize).wrapping_sub(1))
            .with_context(|| format!("unknown stub executable id {id}"))?; // bns-lint: allow(hot_path_alloc) — format! sits in with_context's lazy closure; it runs only on the unknown-id error path, never on a successful exec
        anyhow::ensure!(x.len() == batch * dim, "stub exec: x has wrong shape");
        anyhow::ensure!(labels.len() == batch, "stub exec: labels have wrong shape");
        anyhow::ensure!(out.len() == batch * dim, "stub exec: out has wrong shape");
        match exe {
            Exe::Affine(e) => {
                let e = *e;
                for pass in 0..e.cost {
                    for r in 0..batch {
                        let bias = e.c + e.label_scale * labels[r] as f32 + e.t_scale * t;
                        let row = &x[r * dim..(r + 1) * dim];
                        let orow = &mut out[r * dim..(r + 1) * dim];
                        for (o, &xv) in orow.iter_mut().zip(row.iter()) {
                            *o = e.k * xv + bias;
                        }
                    }
                    if pass + 1 < e.cost {
                        // redundant passes write the same values; black_box keeps
                        // the optimizer from collapsing the cost knob
                        std::hint::black_box(&mut *out);
                    }
                }
                Ok(())
            }
            Exe::Mlp(me) => {
                anyhow::ensure!(dim == me.model.dim, "mlp exec: dim mismatch with artifact");
                let max = me.model.num_classes as i32;
                for &l in labels {
                    anyhow::ensure!((0..=max).contains(&l), "mlp exec: label out of range");
                }
                // Pool fan-out pays off only on wide batches; narrow ones
                // run inline. Either path is bit-identical (forward_rows
                // is row-chunk invariant).
                if let Some(p) = pool {
                    if batch >= 2 * CHUNK_ROWS {
                        return p.run_rows(&me.model, batch, dim, x, t, w, labels, out);
                    }
                }
                forward_rows(&me.model, &mut me.scratch, batch, x, t, w, labels, out);
                Ok(())
            }
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT backend (feature = "pjrt"; requires a vendored `xla` crate)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::Path;

    use anyhow::{anyhow, Context, Result};

    use super::Backend;

    /// PJRT CPU client + compiled-executable cache. Pattern follows
    /// /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` ->
    /// `XlaComputation::from_proto` -> `PjRtClient::compile` -> `execute`.
    pub struct PjrtBackend {
        client: xla::PjRtClient,
        exes: HashMap<u64, xla::PjRtLoadedExecutable>,
        next_id: u64,
    }

    impl PjrtBackend {
        pub fn new() -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
            Ok(PjrtBackend { client, exes: HashMap::new(), next_id: 1 })
        }
    }

    impl Backend for PjrtBackend {
        fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn load(&mut self, path: &Path) -> Result<u64> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(|e| anyhow!("parsing HLO {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
            let id = self.next_id;
            self.next_id += 1;
            self.exes.insert(id, exe);
            Ok(id)
        }

        fn exec_into(
            &mut self,
            id: u64,
            batch: usize,
            dim: usize,
            x: &[f32],
            t: f32,
            w: f32,
            labels: &[i32],
            out: &mut [f32],
        ) -> Result<()> {
            let exe = self.exes.get(&id).context("unknown executable id")?;
            let xl = xla::Literal::vec1(x)
                .reshape(&[batch as i64, dim as i64])
                .map_err(|e| anyhow!("reshape: {e}"))?;
            let tl = xla::Literal::scalar(t);
            let wl = xla::Literal::scalar(w);
            let ll = xla::Literal::vec1(labels);
            let result = exe
                .execute::<xla::Literal>(&[xl, tl, wl, ll])
                .map_err(|e| anyhow!("execute: {e}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e}"))?;
            let u = result.to_tuple1().map_err(|e| anyhow!("tuple: {e}"))?;
            let v = u.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
            anyhow::ensure!(
                v.len() == out.len(),
                "executable returned {} values for an output of {}",
                v.len(),
                out.len()
            );
            out.copy_from_slice(&v);
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_backend_loads_and_executes_stub_artifacts() {
        let dir = std::env::temp_dir().join(format!("bns-stub-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m_b2.stub.json");
        std::fs::write(&path, r#"{"bns_stub_field": {"k": -0.5, "c": 0.25}}"#).unwrap();

        let mut b = StubBackend::new();
        let id = b.load(&path).unwrap();
        let out = b.exec(id, 2, 2, &[1.0, 2.0, -1.0, 0.0], 0.3, 0.0, &[0, 1]).unwrap();
        assert_eq!(out, vec![-0.25, -0.75, 0.75, 0.25]);

        // exec_into fully overwrites a dirty pooled buffer
        let mut pooled = vec![f32::NAN; 4];
        b.exec_into(id, 2, 2, &[1.0, 2.0, -1.0, 0.0], 0.3, 0.0, &[0, 1], &mut pooled)
            .unwrap();
        assert_eq!(pooled, out);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stub_cost_knob_does_not_change_output() {
        let dir = std::env::temp_dir().join(format!("bns-stub-cost-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p1 = dir.join("m1.stub.json");
        let p8 = dir.join("m8.stub.json");
        std::fs::write(&p1, r#"{"bns_stub_field": {"k": -0.5, "c": 0.25, "cost": 1}}"#).unwrap();
        std::fs::write(&p8, r#"{"bns_stub_field": {"k": -0.5, "c": 0.25, "cost": 8}}"#).unwrap();
        let mut b = StubBackend::new();
        let id1 = b.load(&p1).unwrap();
        let id8 = b.load(&p8).unwrap();
        let x = [0.4f32, -1.2, 2.0, 0.0];
        let a = b.exec(id1, 2, 2, &x, 0.7, 0.0, &[1, 2]).unwrap();
        let c = b.exec(id8, 2, 2, &x, 0.7, 0.0, &[1, 2]).unwrap();
        assert_eq!(a, c, "cost must scale wall time only, never the values");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mlp_artifact_execs_and_pool_matches_inline_bitwise() {
        use crate::util::json::Json;
        use crate::util::rng::Pcg32;
        let (d, h, e, c) = (8usize, 12usize, 4usize, 3usize);
        let mut rng = Pcg32::seeded(31);
        let mut arr = |n: usize, s: f32| {
            Json::arr_f32(&rng.normal_vec(n).iter().map(|v| v * s).collect::<Vec<_>>())
        };
        let blocks: Vec<Json> = (0..2)
            .map(|_| {
                Json::obj(vec![
                    ("w1", arr(d * h, 0.2)),
                    ("b1", arr(h, 0.05)),
                    ("w2", arr(h * d, 0.1)),
                    ("b2", arr(d, 0.01)),
                    ("mw", arr(e * 2 * d, 0.1)),
                    ("mb", arr(2 * d, 0.01)),
                ])
            })
            .collect();
        let spec = Json::obj(vec![
            ("dim", Json::Num(d as f64)),
            ("hidden", Json::Num(h as f64)),
            ("emb", Json::Num(e as f64)),
            ("num_classes", Json::Num(c as f64)),
            ("null_class", Json::Num(c as f64)),
            ("cfg", Json::Bool(true)),
            ("cls_emb", arr((c + 1) * e, 0.2)),
            ("blocks", Json::Arr(blocks)),
        ]);
        let art = Json::obj(vec![("bns_mlp_field", spec)]).to_string();
        let dir = std::env::temp_dir().join(format!("bns-mlp-be-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m_b32.mlp.json");
        std::fs::write(&path, &art).unwrap();

        let batch = 32usize; // wide enough to take the pool path
        let mut rng2 = Pcg32::seeded(33);
        let x = rng2.normal_vec(batch * d);
        let labels: Vec<i32> = (0..batch).map(|i| (i % (c + 1)) as i32).collect();

        let mut inline = StubBackend::with_pool_threads(1);
        let id = inline.load(&path).unwrap();
        let base = inline.exec(id, batch, d, &x, 0.4, 1.5, &labels).unwrap();
        assert!(base.iter().all(|v| v.is_finite()));

        for threads in [2usize, 4] {
            let mut pooled = StubBackend::with_pool_threads(threads);
            let id = pooled.load(&path).unwrap();
            let got = pooled.exec(id, batch, d, &x, 0.4, 1.5, &labels).unwrap();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = base.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, bb, "pool threads={threads}");
        }

        // out-of-range label is a structured error, not a panic
        let err = inline.exec(id, 1, d, &x[..d], 0.4, 1.5, &[99]).unwrap_err();
        assert!(err.to_string().contains("label"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stub_backend_rejects_real_hlo() {
        let dir = std::env::temp_dir().join(format!("bns-stub-hlo-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m_b2.hlo.txt");
        std::fs::write(&path, "HloModule m\nENTRY main { ... }").unwrap();
        let mut b = StubBackend::new();
        let err = b.load(&path).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
