//! Device backends behind the device lane threads in `client.rs`
//! (each lane owns one `Backend` instance; see DESIGN.md §5).
//!
//! The real executor is PJRT via the `xla` crate — which, like
//! serde/tokio/clap, is **not resolvable in the offline build image**
//! (DESIGN.md §3). It is therefore gated behind the `pjrt` cargo feature:
//! enabling it requires vendoring the `xla` crate and adding it to
//! `[dependencies]`. The code paths are otherwise identical — both
//! backends sit behind the same `Backend` trait and the same device
//! thread, so the engine/runtime layers never know which one runs.
//!
//! The default (no-feature) build uses `StubBackend`: it refuses real
//! HLO-text artifacts with an actionable error, but loads *stub field*
//! artifacts — a JSON file `{"bns_stub_field": {"k": .., "c": ..}}`
//! describing the affine velocity field
//!     u[r, d] = k * x[r, d] + c + label_scale * labels[r] + t_scale * t
//! evaluated in f32. An optional `cost` key repeats the compute pass
//! (identical output, proportionally more wall time) so load benches can
//! emulate heavier models. That keeps the full serving stack (engine, batcher,
//! router, accounting) executable and testable — `cargo test` drives
//! real batches end-to-end through the device thread — without any
//! compiled model. `bench_util::write_stub_artifacts` emits a complete
//! artifact directory in this format.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

/// A compiled-executable store owned by a device lane thread. Implementors
/// are **not** required to be `Send`/`Sync`: one lane thread owns each
/// backend instance for its whole lifetime (the PJRT types are `!Send`).
pub trait Backend {
    fn platform(&self) -> String;

    /// Load + compile an artifact file; returns a backend-local id.
    fn load(&mut self, path: &Path) -> Result<u64>;

    /// Execute executable `id` on exactly `batch` rows, writing the
    /// velocities into `out` (`len == batch * dim`). Every element of
    /// `out` must be overwritten on success — callers pass pooled
    /// buffers whose prior contents are arbitrary. This is the hot-path
    /// entry: the stub backend computes straight into `out`, PJRT copies
    /// its result literal into `out` once.
    #[allow(clippy::too_many_arguments)]
    fn exec_into(
        &mut self,
        id: u64,
        batch: usize,
        dim: usize,
        x: &[f32],
        t: f32,
        w: f32,
        labels: &[i32],
        out: &mut [f32],
    ) -> Result<()>;

    /// Allocating convenience wrapper around `exec_into`.
    #[allow(clippy::too_many_arguments)]
    fn exec(
        &mut self,
        id: u64,
        batch: usize,
        dim: usize,
        x: &[f32],
        t: f32,
        w: f32,
        labels: &[i32],
    ) -> Result<Vec<f32>> {
        let mut out = vec![0f32; batch * dim];
        self.exec_into(id, batch, dim, x, t, w, labels, &mut out)?;
        Ok(out)
    }
}

/// Construct the CPU backend selected at compile time.
pub fn new_cpu() -> Result<Box<dyn Backend>> {
    #[cfg(feature = "pjrt")]
    return Ok(Box::new(pjrt::PjrtBackend::new()?));
    #[cfg(not(feature = "pjrt"))]
    Ok(Box::new(StubBackend::new()))
}

// ---------------------------------------------------------------------------
// Stub backend (default build)
// ---------------------------------------------------------------------------

/// Parameters of one stub affine field artifact. `cost` repeats the
/// (idempotent) compute pass so benches can emulate heavier models:
/// output is identical for any cost, wall time scales with it.
#[derive(Debug, Clone, Copy)]
struct StubExe {
    k: f32,
    c: f32,
    label_scale: f32,
    t_scale: f32,
    cost: u32,
}

/// Offline-build device backend: loads `bns_stub_field` JSON artifacts.
pub struct StubBackend {
    exes: Vec<StubExe>,
}

impl StubBackend {
    pub fn new() -> Self {
        StubBackend { exes: Vec::new() }
    }
}

impl Default for StubBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for StubBackend {
    fn platform(&self) -> String {
        "stub-cpu".to_string()
    }

    fn load(&mut self, path: &Path) -> Result<u64> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading artifact {}", path.display()))?;
        let trimmed = text.trim_start();
        let spec = if trimmed.starts_with('{') {
            crate::util::json::Json::parse(trimmed)
                .ok()
                .map(|j| j.get("bns_stub_field").clone())
                .filter(|s| s != &crate::util::json::Json::Null)
        } else {
            None
        };
        let Some(spec) = spec else {
            return Err(anyhow!(
                "artifact {} is not a bns_stub_field JSON file; executing real HLO \
                 artifacts requires the PJRT backend (build with `--features pjrt` \
                 and a vendored `xla` crate)",
                path.display()
            ));
        };
        let g = |k: &str, default: f64| spec.get(k).as_f64().unwrap_or(default) as f32;
        self.exes.push(StubExe {
            k: g("k", -1.0),
            c: g("c", 0.0),
            label_scale: g("label_scale", 0.0),
            t_scale: g("t_scale", 0.0),
            cost: spec.get("cost").as_f64().unwrap_or(1.0).max(1.0) as u32,
        });
        Ok(self.exes.len() as u64)
    }

    fn exec_into(
        &mut self,
        id: u64,
        batch: usize,
        dim: usize,
        x: &[f32],
        t: f32,
        _w: f32,
        labels: &[i32],
        out: &mut [f32],
    ) -> Result<()> {
        let e = *self
            .exes
            .get(id as usize - 1)
            .with_context(|| format!("unknown stub executable id {id}"))?; // bns-lint: allow(hot_path_alloc) — format! sits in with_context's lazy closure; it runs only on the unknown-id error path, never on a successful exec
        anyhow::ensure!(x.len() == batch * dim, "stub exec: x has wrong shape");
        anyhow::ensure!(labels.len() == batch, "stub exec: labels have wrong shape");
        anyhow::ensure!(out.len() == batch * dim, "stub exec: out has wrong shape");
        for pass in 0..e.cost {
            for r in 0..batch {
                let bias = e.c + e.label_scale * labels[r] as f32 + e.t_scale * t;
                let row = &x[r * dim..(r + 1) * dim];
                let orow = &mut out[r * dim..(r + 1) * dim];
                for (o, &xv) in orow.iter_mut().zip(row.iter()) {
                    *o = e.k * xv + bias;
                }
            }
            if pass + 1 < e.cost {
                // redundant passes write the same values; black_box keeps
                // the optimizer from collapsing the cost knob
                std::hint::black_box(&mut *out);
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// PJRT backend (feature = "pjrt"; requires a vendored `xla` crate)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::Path;

    use anyhow::{anyhow, Context, Result};

    use super::Backend;

    /// PJRT CPU client + compiled-executable cache. Pattern follows
    /// /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` ->
    /// `XlaComputation::from_proto` -> `PjRtClient::compile` -> `execute`.
    pub struct PjrtBackend {
        client: xla::PjRtClient,
        exes: HashMap<u64, xla::PjRtLoadedExecutable>,
        next_id: u64,
    }

    impl PjrtBackend {
        pub fn new() -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
            Ok(PjrtBackend { client, exes: HashMap::new(), next_id: 1 })
        }
    }

    impl Backend for PjrtBackend {
        fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn load(&mut self, path: &Path) -> Result<u64> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(|e| anyhow!("parsing HLO {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
            let id = self.next_id;
            self.next_id += 1;
            self.exes.insert(id, exe);
            Ok(id)
        }

        fn exec_into(
            &mut self,
            id: u64,
            batch: usize,
            dim: usize,
            x: &[f32],
            t: f32,
            w: f32,
            labels: &[i32],
            out: &mut [f32],
        ) -> Result<()> {
            let exe = self.exes.get(&id).context("unknown executable id")?;
            let xl = xla::Literal::vec1(x)
                .reshape(&[batch as i64, dim as i64])
                .map_err(|e| anyhow!("reshape: {e}"))?;
            let tl = xla::Literal::scalar(t);
            let wl = xla::Literal::scalar(w);
            let ll = xla::Literal::vec1(labels);
            let result = exe
                .execute::<xla::Literal>(&[xl, tl, wl, ll])
                .map_err(|e| anyhow!("execute: {e}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e}"))?;
            let u = result.to_tuple1().map_err(|e| anyhow!("tuple: {e}"))?;
            let v = u.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
            anyhow::ensure!(
                v.len() == out.len(),
                "executable returned {} values for an output of {}",
                v.len(),
                out.len()
            );
            out.copy_from_slice(&v);
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_backend_loads_and_executes_stub_artifacts() {
        let dir = std::env::temp_dir().join(format!("bns-stub-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m_b2.stub.json");
        std::fs::write(&path, r#"{"bns_stub_field": {"k": -0.5, "c": 0.25}}"#).unwrap();

        let mut b = StubBackend::new();
        let id = b.load(&path).unwrap();
        let out = b.exec(id, 2, 2, &[1.0, 2.0, -1.0, 0.0], 0.3, 0.0, &[0, 1]).unwrap();
        assert_eq!(out, vec![-0.25, -0.75, 0.75, 0.25]);

        // exec_into fully overwrites a dirty pooled buffer
        let mut pooled = vec![f32::NAN; 4];
        b.exec_into(id, 2, 2, &[1.0, 2.0, -1.0, 0.0], 0.3, 0.0, &[0, 1], &mut pooled)
            .unwrap();
        assert_eq!(pooled, out);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stub_cost_knob_does_not_change_output() {
        let dir = std::env::temp_dir().join(format!("bns-stub-cost-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p1 = dir.join("m1.stub.json");
        let p8 = dir.join("m8.stub.json");
        std::fs::write(&p1, r#"{"bns_stub_field": {"k": -0.5, "c": 0.25, "cost": 1}}"#).unwrap();
        std::fs::write(&p8, r#"{"bns_stub_field": {"k": -0.5, "c": 0.25, "cost": 8}}"#).unwrap();
        let mut b = StubBackend::new();
        let id1 = b.load(&p1).unwrap();
        let id8 = b.load(&p8).unwrap();
        let x = [0.4f32, -1.2, 2.0, 0.0];
        let a = b.exec(id1, 2, 2, &x, 0.7, 0.0, &[1, 2]).unwrap();
        let c = b.exec(id8, 2, 2, &x, 0.7, 0.0, &[1, 2]).unwrap();
        assert_eq!(a, c, "cost must scale wall time only, never the values");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stub_backend_rejects_real_hlo() {
        let dir = std::env::temp_dir().join(format!("bns-stub-hlo-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m_b2.hlo.txt");
        std::fs::write(&path, "HloModule m\nENTRY main { ... }").unwrap();
        let mut b = StubBackend::new();
        let err = b.load(&path).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
