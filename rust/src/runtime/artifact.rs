//! Artifact store: the manifest.json index produced by
//! python/compile/artifacts.py, model metadata, distilled-solver
//! registry, and the FD-synth feature extractor.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::solver::ns::{NsSolver, SolverMeta};
use crate::solver::scheduler::{Parametrization, Scheduler};
use crate::util::json::Json;
use crate::util::linalg::Mat;

/// One lowered (batch-bucket) artifact of a model.
#[derive(Debug, Clone)]
pub struct BucketInfo {
    pub batch: usize,
    pub path: PathBuf,
}

/// Model metadata from the manifest.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub scheduler: Scheduler,
    pub parametrization: Parametrization,
    pub dim: usize,
    pub num_classes: usize,
    pub null_class: usize,
    pub data: String, // "images" | "audio"
    /// Lowered batch buckets, sorted by batch ascending at parse time so
    /// `LoadedModel` never re-sorts (or clones) the list per load.
    pub buckets: Vec<BucketInfo>,
    /// Model forward passes per velocity evaluation per row: 2 for the
    /// CFG-composed artifacts aot.py lowers (cond + uncond branches),
    /// 1 for unconditional/non-CFG models. Manifest key
    /// `forwards_per_eval`, defaulting to 2 for backward compatibility.
    pub forwards_per_eval: usize,
}

/// A distilled solver artifact (BNS / BST / init).
#[derive(Debug, Clone)]
pub struct SolverArtifact {
    pub name: String,
    pub solver: NsSolver,
    pub meta: SolverMeta,
}

/// FD-synth feature extractor + reference statistics.
#[derive(Clone)]
pub struct FdSynth {
    pub dim: usize,
    pub hidden: usize,
    pub feat_dim: usize,
    pub w1: Vec<f32>, // [dim, hidden] row-major
    pub b1: Vec<f32>,
    pub w2: Vec<f32>, // [hidden, feat_dim]
    pub ref_mean: Vec<f64>,
    pub ref_cov: Mat,
}

impl FdSynth {
    /// Map rows [n, dim] -> features [n, feat_dim]: tanh(x W1 + b1) W2.
    pub fn features(&self, rows: &[f32]) -> Vec<f32> {
        let n = rows.len() / self.dim;
        let mut out = vec![0f32; n * self.feat_dim];
        let mut h = vec![0f32; self.hidden];
        for r in 0..n {
            let x = &rows[r * self.dim..(r + 1) * self.dim];
            for j in 0..self.hidden {
                h[j] = self.b1[j];
            }
            for (i, &xv) in x.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &self.w1[i * self.hidden..(i + 1) * self.hidden];
                for j in 0..self.hidden {
                    h[j] += xv * wrow[j];
                }
            }
            for v in h.iter_mut() {
                *v = v.tanh();
            }
            let orow = &mut out[r * self.feat_dim..(r + 1) * self.feat_dim];
            for (j, &hv) in h.iter().enumerate() {
                let wrow = &self.w2[j * self.feat_dim..(j + 1) * self.feat_dim];
                for k in 0..self.feat_dim {
                    orow[k] += hv * wrow[k];
                }
            }
        }
        out
    }

    /// FD-synth of a generated sample set against the dataset reference.
    pub fn fd_to_reference(&self, rows: &[f32]) -> f64 {
        let f = self.features(rows);
        let (m, c) = crate::util::linalg::mean_cov(&f, self.feat_dim);
        crate::util::linalg::frechet_distance(&m, &c, &self.ref_mean, &self.ref_cov)
    }

    /// FD-synth between two generated sets (e.g. n-step vs GT sampler).
    pub fn fd_between(&self, rows_a: &[f32], rows_b: &[f32]) -> f64 {
        let fa = self.features(rows_a);
        let fb = self.features(rows_b);
        let (ma, ca) = crate::util::linalg::mean_cov(&fa, self.feat_dim);
        let (mb, cb) = crate::util::linalg::mean_cov(&fb, self.feat_dim);
        crate::util::linalg::frechet_distance(&ma, &ca, &mb, &cb)
    }
}

/// The loaded artifact store. `Clone` is a deep copy — the registry
/// (coordinator/registry.rs) clones the current store to build the next
/// immutable view on hot load/unload.
#[derive(Clone)]
pub struct ArtifactStore {
    pub root: PathBuf,
    pub models: BTreeMap<String, ModelInfo>,
    pub solvers: BTreeMap<String, SolverArtifact>,
    pub fd: FdSynth,
    pub scheduler_check: Json,
}

impl ArtifactStore {
    pub fn load(root: &Path) -> Result<ArtifactStore> {
        let manifest_path = root.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;

        let mut models = BTreeMap::new();
        for (name, m) in j.get("models").as_obj().context("manifest.models")? {
            let sched = Scheduler::from_name(m.get("scheduler").as_str().unwrap_or(""))
                .with_context(|| format!("model {name}: bad scheduler"))?;
            let param =
                Parametrization::from_name(m.get("parametrization").as_str().unwrap_or(""))
                    .with_context(|| format!("model {name}: bad parametrization"))?;
            let mut buckets = m
                .get("artifacts")
                .as_arr()
                .context("model artifacts")?
                .iter()
                .map(|e| {
                    Ok(BucketInfo {
                        batch: e.get("batch").as_usize().context("bucket batch")?,
                        path: root.join(e.get("path").as_str().context("bucket path")?),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            buckets.sort_by_key(|b| b.batch);
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    scheduler: sched,
                    parametrization: param,
                    dim: m.get("dim").as_usize().context("model dim")?,
                    num_classes: m.get("num_classes").as_usize().context("num_classes")?,
                    null_class: m.get("null_class").as_usize().context("null_class")?,
                    data: m.get("data").as_str().unwrap_or("images").to_string(),
                    buckets,
                    forwards_per_eval: m.get("forwards_per_eval").as_usize().unwrap_or(2),
                },
            );
        }

        let mut solvers = BTreeMap::new();
        for rel in j.get("solvers").as_arr().context("manifest.solvers")? {
            let rel = rel.as_str().context("solver path")?;
            let stext = std::fs::read_to_string(root.join(rel))
                .with_context(|| format!("reading solver {rel}"))?;
            let (solver, meta) = NsSolver::from_json_str(&stext)
                .with_context(|| format!("parsing solver {rel}"))?;
            let name = Path::new(rel)
                .file_stem()
                .and_then(|s| s.to_str())
                .context("solver name")?
                .to_string();
            solvers.insert(name.clone(), SolverArtifact { name, solver, meta });
        }

        let fdj = j.get("fd");
        let feat_dim = fdj.get("feat_dim").as_usize().context("fd.feat_dim")?;
        let cov_flat = fdj.get("ref_cov").as_f64_vec().context("fd.ref_cov")?;
        if cov_flat.len() != feat_dim * feat_dim {
            bail!("fd.ref_cov has {} entries, want {}", cov_flat.len(), feat_dim * feat_dim);
        }
        let fd = FdSynth {
            dim: fdj.get("dim").as_usize().context("fd.dim")?,
            hidden: fdj.get("feat_hidden").as_usize().context("fd.feat_hidden")?,
            feat_dim,
            w1: fdj.get("w1").as_f32_vec().context("fd.w1")?,
            b1: fdj.get("b1").as_f32_vec().context("fd.b1")?,
            w2: fdj.get("w2").as_f32_vec().context("fd.w2")?,
            ref_mean: fdj.get("ref_mean").as_f64_vec().context("fd.ref_mean")?,
            ref_cov: Mat::from_rows(feat_dim, cov_flat),
        };

        Ok(ArtifactStore {
            root: root.to_path_buf(),
            models,
            solvers,
            fd,
            scheduler_check: j.get("scheduler_check").clone(),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models.get(name).with_context(|| format!("unknown model '{name}'"))
    }

    pub fn solver(&self, name: &str) -> Result<&SolverArtifact> {
        self.solvers.get(name).with_context(|| format!("unknown solver '{name}'"))
    }

    /// Distilled solvers for (model, guidance, kind), sorted by NFE.
    pub fn solvers_for(&self, model: &str, guidance: f64, kind: &str) -> Vec<&SolverArtifact> {
        let mut v: Vec<&SolverArtifact> = self
            .solvers
            .values()
            .filter(|s| {
                s.meta.model == model
                    && (s.meta.guidance - guidance).abs() < 1e-9
                    && s.meta.kind == kind
            })
            .collect();
        v.sort_by_key(|s| s.solver.nfe());
        v
    }
}
