//! PJRT-backed velocity field: bridges `solver::field::Field` to the
//! compiled model executables, with batch-bucket selection and padding.

use std::sync::Mutex;

use anyhow::{Context, Result};

use super::artifact::ModelInfo;
use super::client::{ExeHandle, Runtime};
use crate::solver::field::Field;

/// Reusable padding buffers for the off-bucket path of `eval_into`
/// (rows that don't line up with a compiled bucket). One per field;
/// workers each own their field, so the lock is uncontended.
#[derive(Default)]
struct EvalScratch {
    xb: Vec<f32>,
    lb: Vec<i32>,
}

/// A model bound to (labels, guidance): evaluating it at (t, x) runs the
/// CFG-composed artifact. Batch handling: the smallest bucket >= rows is
/// chosen; rows are zero-padded to the bucket (labels padded with the
/// null class so the padding rows still compute *something* valid).
pub struct ModelField {
    pub info: ModelInfo,
    executables: Vec<ExeHandle>, // sorted by batch ascending
    pub labels: Vec<i32>,
    pub guidance: f32,
    scratch: Mutex<EvalScratch>,
}

impl ModelField {
    pub fn new(
        rt: &Runtime,
        info: &ModelInfo,
        labels: Vec<i32>,
        guidance: f32,
    ) -> Result<ModelField> {
        let mut buckets = info.buckets.clone();
        buckets.sort_by_key(|b| b.batch);
        let executables = buckets
            .iter()
            .map(|b| rt.load(&b.path, b.batch, info.dim))
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("loading model '{}'", info.name))?;
        Ok(ModelField {
            info: info.clone(),
            executables,
            labels,
            guidance,
            scratch: Mutex::new(EvalScratch::default()),
        })
    }

    fn pick(&self, rows: usize) -> &ExeHandle {
        self.executables
            .iter()
            .find(|e| e.batch >= rows)
            .unwrap_or_else(|| self.executables.last().unwrap())
    }

    /// Largest compiled bucket (callers chunk above this).
    pub fn max_batch(&self) -> usize {
        self.executables.last().map(|e| e.batch).unwrap_or(1)
    }
}

impl Field for ModelField {
    fn dim(&self) -> usize {
        self.info.dim
    }

    fn eval(&self, t: f64, x: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0f32; x.len()];
        self.eval_into(t, x, &mut out)?;
        Ok(out)
    }

    /// Hot-path evaluation: chunks over buckets, writing each chunk's
    /// output straight into `out`. When a chunk exactly fills a compiled
    /// bucket — the common case once the batcher aligns `max_rows` with
    /// the bucket sizes — the input rows and labels are passed through
    /// without the padded staging copy; only off-bucket tails go through
    /// the (reused, preallocated) padding scratch.
    fn eval_into(&self, t: f64, x: &[f32], out: &mut [f32]) -> Result<()> {
        let dim = self.info.dim;
        let rows = x.len() / dim;
        debug_assert_eq!(rows, self.labels.len(), "labels must match batch");
        debug_assert_eq!(out.len(), x.len(), "output buffer must match x");
        let mut r = 0;
        while r < rows {
            let exe = self.pick(rows - r);
            let take = exe.batch.min(rows - r);
            let ub = if take == exe.batch {
                // bucket-aligned: no padding, no staging copy
                exe.run(&x[r * dim..(r + take) * dim], t as f32, self.guidance, &self.labels[r..r + take])?
            } else {
                // pad up to the bucket through reused scratch
                let mut s = self.scratch.lock().unwrap();
                s.xb.clear();
                s.xb.resize(exe.batch * dim, 0.0);
                s.xb[..take * dim].copy_from_slice(&x[r * dim..(r + take) * dim]);
                s.lb.clear();
                s.lb.resize(exe.batch, self.info.null_class as i32);
                s.lb[..take].copy_from_slice(&self.labels[r..r + take]);
                exe.run(&s.xb, t as f32, self.guidance, &s.lb)?
            };
            out[r * dim..(r + take) * dim].copy_from_slice(&ub[..take * dim]);
            r += take;
        }
        Ok(())
    }

    fn forwards_per_eval(&self) -> usize {
        // CFG-composed artifacts run cond + uncond branches per row; the
        // manifest says which composition a model was lowered with.
        self.info.forwards_per_eval
    }
}
