//! Device-backed velocity field: bridges `solver::field::Field` to the
//! compiled model executables, with batch-bucket selection and padding.
//!
//! Split into two layers so serving workers can cache the expensive part:
//!
//! * [`LoadedModel`] — the per-(worker, model) cacheable object: compiled
//!   bucket executables pinned to one device lane, plus the padding
//!   scratch. Loading resolves buckets and talks to the lane's compile
//!   cache once; engine workers keep these in a per-worker map instead of
//!   re-resolving buckets and re-cloning `ModelInfo` every batch.
//! * [`ModelField`] — a cheap binding of a `LoadedModel` to eval-time
//!   arguments (labels, guidance). Constructed per batch (one `Arc`
//!   bump + moving the already-built labels vector); evaluating it at
//!   (t, x) runs the CFG-composed artifact.
//!
//! Batch handling: the smallest bucket >= rows is chosen; rows are
//! zero-padded to the bucket (labels padded with the null class so the
//! padding rows still compute *something* valid).

use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::artifact::ModelInfo;
use super::client::{ExeHandle, Runtime};
use crate::solver::field::Field;

/// Reusable staging buffers for the off-bucket path of `eval_into`
/// (rows that don't line up with a compiled bucket). One per loaded
/// model; workers each own their models, so the lock is uncontended.
#[derive(Default)]
struct EvalScratch {
    xb: Vec<f32>,
    lb: Vec<i32>,
    ob: Vec<f32>,
}

/// A model's compiled executables, pinned to one device lane. Cacheable:
/// workers load a model once and bind labels/guidance per batch.
pub struct LoadedModel {
    pub info: ModelInfo,
    executables: Vec<ExeHandle>, // sorted by batch ascending
    lane: usize,
    scratch: Mutex<EvalScratch>,
}

impl LoadedModel {
    /// Load + compile every bucket on the runtime's next round-robin lane.
    pub fn load(rt: &Runtime, info: &ModelInfo) -> Result<LoadedModel> {
        Self::load_on(rt, rt.next_lane(), info)
    }

    /// Load + compile every bucket on a specific lane.
    pub fn load_on(rt: &Runtime, lane: usize, info: &ModelInfo) -> Result<LoadedModel> {
        // manifest buckets are sorted by batch at parse time (artifact.rs)
        debug_assert!(
            info.buckets.windows(2).all(|w| w[0].batch <= w[1].batch),
            "ModelInfo.buckets must be sorted by batch"
        );
        let executables = info
            .buckets
            .iter()
            .map(|b| rt.load_on(lane, &b.path, b.batch, info.dim))
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("loading model '{}'", info.name))?;
        anyhow::ensure!(!executables.is_empty(), "model '{}' has no artifacts", info.name);
        Ok(LoadedModel {
            info: info.clone(),
            executables,
            lane,
            scratch: Mutex::new(EvalScratch::default()),
        })
    }

    /// The device lane every executable of this model is pinned to.
    pub fn lane(&self) -> usize {
        self.lane
    }

    fn pick(&self, rows: usize) -> &ExeHandle {
        self.executables
            .iter()
            .find(|e| e.batch >= rows)
            .unwrap_or_else(|| self.executables.last().unwrap())
    }

    /// Largest compiled bucket (callers chunk above this).
    pub fn max_batch(&self) -> usize {
        self.executables.last().map(|e| e.batch).unwrap_or(1)
    }

    /// Bind eval-time arguments, producing a `Field` for one batch.
    /// Consumes the `Arc` handle (one refcount bump at the caller's
    /// `clone`, no other work) — callers keeping the model cached clone
    /// before binding.
    pub fn bind(self: Arc<Self>, labels: Vec<i32>, guidance: f32) -> ModelField {
        ModelField { model: self, labels, guidance }
    }
}

/// A loaded model bound to (labels, guidance) for one sampling run.
pub struct ModelField {
    model: Arc<LoadedModel>,
    pub labels: Vec<i32>,
    pub guidance: f32,
}

impl ModelField {
    /// Load-and-bind in one step (benches/CLI convenience; serving
    /// workers cache the `LoadedModel` and call `bind` instead).
    pub fn new(
        rt: &Runtime,
        info: &ModelInfo,
        labels: Vec<i32>,
        guidance: f32,
    ) -> Result<ModelField> {
        Ok(Arc::new(LoadedModel::load(rt, info)?).bind(labels, guidance))
    }

    pub fn info(&self) -> &ModelInfo {
        &self.model.info
    }

    pub fn lane(&self) -> usize {
        self.model.lane
    }

    pub fn max_batch(&self) -> usize {
        self.model.max_batch()
    }

    /// The underlying cacheable model (for re-binding).
    pub fn model(&self) -> &Arc<LoadedModel> {
        &self.model
    }
}

impl Field for ModelField {
    fn dim(&self) -> usize {
        self.model.info.dim
    }

    fn eval(&self, t: f64, x: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0f32; x.len()];
        self.eval_into(t, x, &mut out)?;
        Ok(out)
    }

    /// Hot-path evaluation: chunks over buckets, the lane backend writing
    /// each chunk's velocities straight into `out`. When a chunk exactly
    /// fills a compiled bucket — the common case once the batcher aligns
    /// `max_rows` with the bucket sizes — the rows, labels, and output
    /// slice pass through the pooled lane RPC with no staging copy and no
    /// allocation; only off-bucket tails go through the (reused,
    /// preallocated) padding scratch.
    fn eval_into(&self, t: f64, x: &[f32], out: &mut [f32]) -> Result<()> {
        let dim = self.model.info.dim;
        let rows = x.len() / dim;
        debug_assert_eq!(rows, self.labels.len(), "labels must match batch");
        debug_assert_eq!(out.len(), x.len(), "output buffer must match x");
        let mut r = 0;
        while r < rows {
            let exe = self.model.pick(rows - r);
            let take = exe.batch.min(rows - r);
            if take == exe.batch {
                // bucket-aligned: no padding, no staging copy
                exe.run_into(
                    &x[r * dim..(r + take) * dim],
                    t as f32,
                    self.guidance,
                    &self.labels[r..r + take],
                    &mut out[r * dim..(r + take) * dim],
                )?;
            } else {
                // pad up to the bucket through reused scratch
                let mut s = self.model.scratch.lock().unwrap();
                let s = &mut *s;
                s.xb.clear();
                s.xb.resize(exe.batch * dim, 0.0);
                s.xb[..take * dim].copy_from_slice(&x[r * dim..(r + take) * dim]);
                s.lb.clear();
                s.lb.resize(exe.batch, self.model.info.null_class as i32);
                s.lb[..take].copy_from_slice(&self.labels[r..r + take]);
                s.ob.resize(exe.batch * dim, 0.0);
                exe.run_into(&s.xb, t as f32, self.guidance, &s.lb, &mut s.ob)?;
                out[r * dim..(r + take) * dim].copy_from_slice(&s.ob[..take * dim]);
            }
            r += take;
        }
        Ok(())
    }

    fn forwards_per_eval(&self) -> usize {
        // CFG-composed artifacts run cond + uncond branches per row; the
        // manifest says which composition a model was lowered with.
        self.model.info.forwards_per_eval
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;
    use crate::bench_util::StubModel;
    use crate::runtime::ArtifactStore;

    fn stub_store(tag: &str) -> (Arc<ArtifactStore>, std::path::PathBuf) {
        crate::bench_util::stub_store(
            &format!("mf-{tag}"),
            &[StubModel {
                name: "m",
                dim: 4,
                num_classes: 3,
                forwards_per_eval: 2,
                k: -0.5,
                c: 0.1,
                label_scale: 0.25,
                cost: 1,
                buckets: &[4, 8],
            }],
        )
        .unwrap()
    }

    #[test]
    fn bind_reuses_loaded_model_and_matches_eval() {
        let (store, dir) = stub_store("bind");
        let rt = Runtime::cpu().unwrap();
        let info = store.model("m").unwrap();
        let model = Arc::new(LoadedModel::load(&rt, info).unwrap());
        let f1 = model.clone().bind(vec![0, 1, 2, 0], 0.0);
        let f2 = model.bind(vec![2, 2, 2, 2], 1.5);
        let x: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let a = f1.eval(0.3, &x).unwrap();
        let mut b = vec![0f32; 16];
        f1.eval_into(0.3, &x, &mut b).unwrap();
        assert_eq!(a, b, "eval_into must match eval bit-for-bit");
        // a different binding of the same model gives different values
        let c = f2.eval(0.3, &x).unwrap();
        assert_ne!(a, c, "labels are eval-time arguments");
        assert_eq!(f1.lane(), f2.lane(), "bindings share the pinned lane");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn off_bucket_rows_equal_bucket_aligned_rows() {
        let (store, dir) = stub_store("pad");
        let rt = Runtime::cpu().unwrap();
        let info = store.model("m").unwrap();
        let model = Arc::new(LoadedModel::load(&rt, info).unwrap());
        // 3 rows -> padded into the 4-bucket
        let f3 = model.clone().bind(vec![0, 1, 2], 0.0);
        // the same 3 rows inside a bucket-aligned 4-row batch
        let f4 = model.bind(vec![0, 1, 2, 0], 0.0);
        let x3: Vec<f32> = (0..12).map(|i| (i as f32).sin()).collect();
        let mut x4 = x3.clone();
        x4.extend_from_slice(&[0.5, -0.5, 1.0, -1.0]);
        let o3 = f3.eval(0.6, &x3).unwrap();
        let o4 = f4.eval(0.6, &x4).unwrap();
        assert_eq!(o3[..], o4[..12], "padding must not perturb real rows");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn concurrent_eval_into_on_shared_field_is_stable() {
        let (store, dir) = stub_store("conc");
        let rt = Arc::new(Runtime::with_lanes(2).unwrap());
        let info = store.model("m").unwrap();
        let model = Arc::new(LoadedModel::load(&rt, info).unwrap());
        let field = Arc::new(model.bind(vec![1, 2, 0, 1], 0.0));
        let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).cos()).collect();
        let expected = field.eval(0.4, &x).unwrap();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let field = field.clone();
            let x = x.clone();
            let expected = expected.clone();
            handles.push(std::thread::spawn(move || {
                let mut out = vec![0f32; x.len()];
                for i in 0..200 {
                    field.eval_into(0.4, &x, &mut out).unwrap();
                    assert_eq!(out, expected, "iteration {i}: pooled buffers corrupted");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
