//! Device-backed velocity field: bridges `solver::field::Field` to the
//! compiled model executables, with batch-bucket selection and padding.
//!
//! Split into two layers so serving workers can cache the expensive part:
//!
//! * [`LoadedModel`] — the per-(worker, model) cacheable object: compiled
//!   bucket executables pinned to one device lane, plus the padding
//!   scratch. Loading resolves buckets and talks to the lane's compile
//!   cache once; engine workers keep these in a per-worker map instead of
//!   re-resolving buckets and re-cloning `ModelInfo` every batch.
//! * [`ModelField`] — a cheap binding of a `LoadedModel` to eval-time
//!   arguments (labels, guidance). Constructed per batch (one `Arc`
//!   bump + moving the already-built labels vector); evaluating it at
//!   (t, x) runs the CFG-composed artifact.
//!
//! Batch handling: the smallest bucket >= rows is chosen; rows are
//! zero-padded to the bucket (labels padded with the null class so the
//! padding rows still compute *something* valid).
//!
//! This layer is backend-kind agnostic: whether a bucket executable is
//! a `bns_stub_field` affine form or a real-compute `bns_mlp_field`
//! residual MLP (kernels layer, DESIGN.md §13) is decided entirely by
//! the artifact the lane loaded. Padding interacts cheaply with the
//! MLP path by design — padded rows are real rows to the kernels, but
//! per-row cost is flat and the intra-lane row pool absorbs the bucket
//! width, so choosing generous buckets costs bandwidth, not latency
//! cliffs.

use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::artifact::ModelInfo;
use super::client::{ExeHandle, Runtime};
use crate::solver::field::Field;
use crate::util::sync::lock_ok;

/// Reusable staging buffers for the off-bucket path of `eval_into`
/// (rows that don't line up with a compiled bucket). One per loaded
/// model; workers each own their models, so the lock is uncontended.
#[derive(Default)]
struct EvalScratch {
    xb: Vec<f32>,
    lb: Vec<i32>,
    ob: Vec<f32>,
}

/// Reusable staging for the stacked batched-JVP path (`jvp_batch_into`):
/// the `x ± ε·v` perturbation rows for every tangent, their tiled
/// labels, and the stacked velocities coming back. One per **binding**
/// (`ModelField`), NOT per loaded model: gradient-fan workers each hold
/// their own persistent binding, and a shared-model scratch would
/// serialize every worker's JVP evals behind one mutex held across the
/// device RPCs. Separate from `EvalScratch` so the stacked eval can
/// still take the off-bucket padding path underneath without
/// re-entering a lock. Empty vectors at construction — a binding that
/// never computes JVPs (the serving path) pays nothing.
#[derive(Default)]
struct JvpScratch {
    xs: Vec<f32>,
    lb: Vec<i32>,
    ob: Vec<f32>,
    /// Per-tangent normalized step size (0.0 marks a zero tangent).
    h: Vec<f64>,
}

/// A model's compiled executables, pinned to one device lane. Cacheable:
/// workers load a model once and bind labels/guidance per batch.
pub struct LoadedModel {
    pub info: ModelInfo,
    executables: Vec<ExeHandle>, // sorted by batch ascending
    lane: usize,
    scratch: Mutex<EvalScratch>,
}

impl LoadedModel {
    /// Load + compile every bucket on the runtime's next round-robin lane.
    pub fn load(rt: &Runtime, info: &ModelInfo) -> Result<LoadedModel> {
        Self::load_on(rt, rt.next_lane(), info)
    }

    /// Load + compile every bucket on a specific lane.
    pub fn load_on(rt: &Runtime, lane: usize, info: &ModelInfo) -> Result<LoadedModel> {
        // manifest buckets are sorted by batch at parse time (artifact.rs)
        debug_assert!(
            info.buckets.windows(2).all(|w| w[0].batch <= w[1].batch),
            "ModelInfo.buckets must be sorted by batch"
        );
        let executables = info
            .buckets
            .iter()
            .map(|b| rt.load_on(lane, &b.path, b.batch, info.dim))
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("loading model '{}'", info.name))?;
        anyhow::ensure!(!executables.is_empty(), "model '{}' has no artifacts", info.name);
        Ok(LoadedModel {
            info: info.clone(),
            executables,
            lane,
            scratch: Mutex::new(EvalScratch::default()),
        })
    }

    /// The device lane every executable of this model is pinned to.
    pub fn lane(&self) -> usize {
        self.lane
    }

    /// Smallest compiled bucket that fits `rows`, falling back to the
    /// largest bucket (callers chunk above it). `None` only for a model
    /// with no compiled buckets, which `load` never constructs.
    fn pick(&self, rows: usize) -> Option<&ExeHandle> {
        self.executables
            .iter()
            .find(|e| e.batch >= rows)
            .or_else(|| self.executables.last())
    }

    /// Largest compiled bucket (callers chunk above this).
    pub fn max_batch(&self) -> usize {
        self.executables.last().map(|e| e.batch).unwrap_or(1)
    }

    /// Bind eval-time arguments, producing a `Field` for one batch.
    /// Consumes the `Arc` handle (one refcount bump at the caller's
    /// `clone`, no other work) — callers keeping the model cached clone
    /// before binding.
    pub fn bind(self: Arc<Self>, labels: Vec<i32>, guidance: f32) -> ModelField {
        ModelField { model: self, labels, guidance, jvp_scratch: Mutex::new(JvpScratch::default()) }
    }
}

/// A loaded model bound to (labels, guidance) for one sampling run.
pub struct ModelField {
    model: Arc<LoadedModel>,
    pub labels: Vec<i32>,
    pub guidance: f32,
    /// Per-binding JVP staging (see [`JvpScratch`]): bindings are what
    /// gradient-fan workers hold, so workers never contend on a shared
    /// scratch while a device RPC is in flight.
    jvp_scratch: Mutex<JvpScratch>,
}

impl ModelField {
    /// Load-and-bind in one step (benches/CLI convenience; serving
    /// workers cache the `LoadedModel` and call `bind` instead).
    pub fn new(
        rt: &Runtime,
        info: &ModelInfo,
        labels: Vec<i32>,
        guidance: f32,
    ) -> Result<ModelField> {
        Ok(Arc::new(LoadedModel::load(rt, info)?).bind(labels, guidance))
    }

    pub fn info(&self) -> &ModelInfo {
        &self.model.info
    }

    pub fn lane(&self) -> usize {
        self.model.lane
    }

    pub fn max_batch(&self) -> usize {
        self.model.max_batch()
    }

    /// The underlying cacheable model (for re-binding).
    pub fn model(&self) -> &Arc<LoadedModel> {
        &self.model
    }

    /// `eval_into` with the per-row labels passed explicitly — the
    /// bucket-chunking core shared by the plain bound-labels path and the
    /// stacked batched-JVP path (whose perturbation rows tile the bound
    /// labels once per tangent sign).
    fn eval_labeled_into(&self, t: f64, x: &[f32], labels: &[i32], out: &mut [f32]) -> Result<()> {
        let dim = self.model.info.dim;
        let rows = x.len() / dim;
        debug_assert_eq!(rows, labels.len(), "labels must match rows");
        debug_assert_eq!(out.len(), x.len(), "output buffer must match x");
        let mut r = 0;
        while r < rows {
            let Some(exe) = self.model.pick(rows - r) else {
                anyhow::bail!("model '{}' has no compiled buckets", self.model.info.name);
            };
            let take = exe.batch.min(rows - r);
            if take == exe.batch {
                // bucket-aligned: no padding, no staging copy
                exe.run_into(
                    &x[r * dim..(r + take) * dim],
                    t as f32,
                    self.guidance,
                    &labels[r..r + take],
                    &mut out[r * dim..(r + take) * dim],
                )?;
            } else {
                // pad up to the bucket through reused scratch
                let mut s = lock_ok(&self.model.scratch);
                let s = &mut *s;
                s.xb.clear();
                s.xb.resize(exe.batch * dim, 0.0);
                s.xb[..take * dim].copy_from_slice(&x[r * dim..(r + take) * dim]);
                s.lb.clear();
                s.lb.resize(exe.batch, self.model.info.null_class as i32);
                s.lb[..take].copy_from_slice(&labels[r..r + take]);
                s.ob.resize(exe.batch * dim, 0.0);
                exe.run_into(&s.xb, t as f32, self.guidance, &s.lb, &mut s.ob)?;
                out[r * dim..(r + take) * dim].copy_from_slice(&s.ob[..take * dim]);
            }
            r += take;
        }
        Ok(())
    }
}

impl Field for ModelField {
    fn dim(&self) -> usize {
        self.model.info.dim
    }

    fn eval(&self, t: f64, x: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0f32; x.len()];
        self.eval_into(t, x, &mut out)?;
        Ok(out)
    }

    /// Hot-path evaluation: chunks over buckets, the lane backend writing
    /// each chunk's velocities straight into `out`. When a chunk exactly
    /// fills a compiled bucket — the common case once the batcher aligns
    /// `max_rows` with the bucket sizes — the rows, labels, and output
    /// slice pass through the pooled lane RPC with no staging copy and no
    /// allocation; only off-bucket tails go through the (reused,
    /// preallocated) padding scratch.
    fn eval_into(&self, t: f64, x: &[f32], out: &mut [f32]) -> Result<()> {
        debug_assert_eq!(
            x.len() / self.model.info.dim,
            self.labels.len(),
            "labels must match batch"
        );
        self.eval_labeled_into(t, x, &self.labels, out)
    }

    fn forwards_per_eval(&self) -> usize {
        // CFG-composed artifacts run cond + uncond branches per row; the
        // manifest says which composition a model was lowered with.
        self.model.info.forwards_per_eval
    }

    /// Wavefront JVP: every tangent shares the base point `(t, x)`, so
    /// all `x ± ε·v` perturbation rows of the dt-free tangents stack into
    /// one bucketized device eval — the stack still chunks over the
    /// compiled buckets underneath, but every resulting RPC carries a
    /// full bucket of useful rows, where sequential `jvp` calls paid a
    /// latency-bound pair of batch-sized RPCs per tangent. Timed
    /// tangents (at most one per wavefront step: a step's own time
    /// parameter) cannot join the stack — the compiled signature takes
    /// one scalar `t` per call — and pay their own `t ± ε·dt` eval pair.
    ///
    /// Arithmetic (per-tangent normalized step, f64 perturbation and
    /// difference) replicates the trait's central-difference default
    /// exactly, so each output row is bit-identical to a sequential
    /// [`Field::jvp`] call; staging lives in the model's reused
    /// `JvpScratch`, so the steady state allocates nothing.
    fn jvp_batch_into(
        &self,
        t: f64,
        x: &[f32],
        tangents: &[f32],
        dts: &[f64],
        out: &mut [f32],
    ) -> Result<()> {
        let len = x.len();
        let tcount = dts.len();
        anyhow::ensure!(
            tangents.len() == tcount * len && out.len() == tangents.len(),
            "jvp_batch_into: tangents [{}] / dts [{}] / out [{}] disagree with x [{len}]",
            tangents.len(),
            dts.len(),
            out.len()
        );
        let rows = len / self.model.info.dim;
        let mut s = lock_ok(&self.jvp_scratch);
        let s = &mut *s;
        // per-tangent normalized step (same formula as the trait default);
        // h = 0 marks a zero tangent whose JVP is identically zero
        s.h.clear();
        let mut spatial = 0usize;
        for (i, &dt) in dts.iter().enumerate() {
            let v = &tangents[i * len..(i + 1) * len];
            let scale = v.iter().fold(dt.abs(), |m, &vi| m.max((vi as f64).abs()));
            let h = if scale == 0.0 { 0.0 } else { 1e-3 / scale };
            s.h.push(h);
            if h != 0.0 && dt == 0.0 {
                spatial += 1;
            }
        }

        // stack [x + h·v ; x - h·v] blocks for every dt-free tangent and
        // tile the bound labels per block — one bucketized dispatch
        s.xs.clear();
        s.xs.resize(2 * spatial * len, 0.0);
        s.lb.clear();
        s.lb.resize(2 * spatial * rows, 0);
        let mut q = 0usize;
        for (i, &dt) in dts.iter().enumerate() {
            let h = s.h[i];
            if h == 0.0 || dt != 0.0 {
                continue;
            }
            let v = &tangents[i * len..(i + 1) * len];
            let (plus, minus) = {
                let base = 2 * q * len;
                let (a, b) = s.xs[base..base + 2 * len].split_at_mut(len);
                (a, b)
            };
            for (((p, m), &xv), &vv) in
                plus.iter_mut().zip(minus.iter_mut()).zip(x.iter()).zip(v.iter())
            {
                *p = (xv as f64 + h * vv as f64) as f32;
                *m = (xv as f64 - h * vv as f64) as f32;
            }
            s.lb[2 * q * rows..(2 * q + 1) * rows].copy_from_slice(&self.labels);
            s.lb[(2 * q + 1) * rows..(2 * q + 2) * rows].copy_from_slice(&self.labels);
            q += 1;
        }
        s.ob.resize(2 * spatial * len, 0.0);
        if spatial > 0 {
            let (xs, ob) = (&s.xs[..2 * spatial * len], &mut s.ob[..2 * spatial * len]);
            self.eval_labeled_into(t, xs, &s.lb, ob)?;
        }
        // two extra blocks past the spatial region for the timed path —
        // appended so un-scattered spatial results are never clobbered
        let tb = 2 * spatial * len;
        s.xs.resize(tb + 2 * len, 0.0);
        s.ob.resize(tb + 2 * len, 0.0);

        // scatter the central differences back into the caller's rows
        q = 0;
        for (i, &dt) in dts.iter().enumerate() {
            let h = s.h[i];
            let o = &mut out[i * len..(i + 1) * len];
            if h == 0.0 {
                o.fill(0.0);
                continue;
            }
            if dt == 0.0 {
                let up = &s.ob[2 * q * len..(2 * q + 1) * len];
                let um = &s.ob[(2 * q + 1) * len..(2 * q + 2) * len];
                for ((ov, &a), &b) in o.iter_mut().zip(up.iter()).zip(um.iter()) {
                    *ov = ((a as f64 - b as f64) / (2.0 * h)) as f32;
                }
                q += 1;
            } else {
                // timed tangent: its own t ± h·dt eval pair in the
                // appended staging blocks
                let v = &tangents[i * len..(i + 1) * len];
                for ((p, &xv), &vv) in
                    s.xs[tb..tb + len].iter_mut().zip(x.iter()).zip(v.iter())
                {
                    *p = (xv as f64 + h * vv as f64) as f32;
                }
                {
                    let (xp, ob) = (&s.xs[tb..tb + len], &mut s.ob[tb..tb + len]);
                    self.eval_labeled_into(t + h * dt, xp, &self.labels, ob)?;
                }
                for ((p, &xv), &vv) in
                    s.xs[tb + len..tb + 2 * len].iter_mut().zip(x.iter()).zip(v.iter())
                {
                    *p = (xv as f64 - h * vv as f64) as f32;
                }
                {
                    let (xm, ob) =
                        (&s.xs[tb + len..tb + 2 * len], &mut s.ob[tb + len..tb + 2 * len]);
                    self.eval_labeled_into(t - h * dt, xm, &self.labels, ob)?;
                }
                for ((ov, &a), &b) in o
                    .iter_mut()
                    .zip(s.ob[tb..tb + len].iter())
                    .zip(s.ob[tb + len..tb + 2 * len].iter())
                {
                    *ov = ((a as f64 - b as f64) / (2.0 * h)) as f32;
                }
            }
        }
        Ok(())
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;
    use crate::bench_util::StubModel;
    use crate::runtime::ArtifactStore;

    fn stub_store(tag: &str) -> (Arc<ArtifactStore>, std::path::PathBuf) {
        crate::bench_util::stub_store(
            &format!("mf-{tag}"),
            &[StubModel {
                name: "m",
                dim: 4,
                num_classes: 3,
                forwards_per_eval: 2,
                k: -0.5,
                c: 0.1,
                label_scale: 0.25,
                cost: 1,
                buckets: &[4, 8],
            }],
        )
        .unwrap()
    }

    #[test]
    fn bind_reuses_loaded_model_and_matches_eval() {
        let (store, dir) = stub_store("bind");
        let rt = Runtime::cpu().unwrap();
        let info = store.model("m").unwrap();
        let model = Arc::new(LoadedModel::load(&rt, info).unwrap());
        let f1 = model.clone().bind(vec![0, 1, 2, 0], 0.0);
        let f2 = model.bind(vec![2, 2, 2, 2], 1.5);
        let x: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let a = f1.eval(0.3, &x).unwrap();
        let mut b = vec![0f32; 16];
        f1.eval_into(0.3, &x, &mut b).unwrap();
        assert_eq!(a, b, "eval_into must match eval bit-for-bit");
        // a different binding of the same model gives different values
        let c = f2.eval(0.3, &x).unwrap();
        assert_ne!(a, c, "labels are eval-time arguments");
        assert_eq!(f1.lane(), f2.lane(), "bindings share the pinned lane");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn off_bucket_rows_equal_bucket_aligned_rows() {
        let (store, dir) = stub_store("pad");
        let rt = Runtime::cpu().unwrap();
        let info = store.model("m").unwrap();
        let model = Arc::new(LoadedModel::load(&rt, info).unwrap());
        // 3 rows -> padded into the 4-bucket
        let f3 = model.clone().bind(vec![0, 1, 2], 0.0);
        // the same 3 rows inside a bucket-aligned 4-row batch
        let f4 = model.bind(vec![0, 1, 2, 0], 0.0);
        let x3: Vec<f32> = (0..12).map(|i| (i as f32).sin()).collect();
        let mut x4 = x3.clone();
        x4.extend_from_slice(&[0.5, -0.5, 1.0, -1.0]);
        let o3 = f3.eval(0.6, &x3).unwrap();
        let o4 = f4.eval(0.6, &x4).unwrap();
        assert_eq!(o3[..], o4[..12], "padding must not perturb real rows");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The stacked batched JVP must be bit-identical to tangent-by-
    /// tangent trait-default `jvp` (central differences through `eval`),
    /// including a timed tangent and a zero tangent, and across repeat
    /// calls (the scratch must never leak state between batches).
    #[test]
    fn jvp_batch_matches_sequential_default_jvp() {
        let (store, dir) = stub_store("jvpb");
        let rt = Runtime::cpu().unwrap();
        let info = store.model("m").unwrap();
        // 3 rows: exercises the off-bucket padding path underneath too
        let field = ModelField::new(&rt, info, vec![0, 1, 2], 0.5).unwrap();
        let len = 12;
        let x: Vec<f32> = (0..len).map(|i| (i as f32 * 0.31).sin()).collect();
        let mut tangents = vec![0f32; 4 * len];
        for (i, v) in tangents.iter_mut().enumerate() {
            // tangent 2 stays identically zero
            *v = if (2 * len..3 * len).contains(&i) { 0.0 } else { ((i * 7 % 13) as f32 - 6.0) * 0.2 };
        }
        let dts = [0.0, 1.0, 0.0, -0.5];
        let mut batch = vec![f32::NAN; tangents.len()];
        for round in 0..3 {
            field.jvp_batch_into(0.4, &x, &tangents, &dts, &mut batch).unwrap();
            for (i, &dt) in dts.iter().enumerate() {
                let seq = field.jvp(0.4, &x, &tangents[i * len..(i + 1) * len], dt).unwrap();
                assert_eq!(
                    seq.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    batch[i * len..(i + 1) * len]
                        .iter()
                        .map(|v| v.to_bits())
                        .collect::<Vec<_>>(),
                    "round {round} tangent {i} (dt={dt})"
                );
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn concurrent_eval_into_on_shared_field_is_stable() {
        let (store, dir) = stub_store("conc");
        let rt = Arc::new(Runtime::with_lanes(2).unwrap());
        let info = store.model("m").unwrap();
        let model = Arc::new(LoadedModel::load(&rt, info).unwrap());
        let field = Arc::new(model.bind(vec![1, 2, 0, 1], 0.0));
        let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).cos()).collect();
        let expected = field.eval(0.4, &x).unwrap();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let field = field.clone();
            let x = x.clone();
            let expected = expected.clone();
            handles.push(std::thread::spawn(move || {
                let mut out = vec![0f32; x.len()];
                for i in 0..200 {
                    field.eval_into(0.4, &x, &mut out).unwrap();
                    assert_eq!(out, expected, "iteration {i}: pooled buffers corrupted");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
