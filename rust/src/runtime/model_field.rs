//! PJRT-backed velocity field: bridges `solver::field::Field` to the
//! compiled model executables, with batch-bucket selection and padding.

use anyhow::{Context, Result};

use super::artifact::ModelInfo;
use super::client::{ExeHandle, Runtime};
use crate::solver::field::Field;

/// A model bound to (labels, guidance): evaluating it at (t, x) runs the
/// CFG-composed artifact. Batch handling: the smallest bucket >= rows is
/// chosen; rows are zero-padded to the bucket (labels padded with the
/// null class so the padding rows still compute *something* valid).
pub struct ModelField {
    pub info: ModelInfo,
    executables: Vec<ExeHandle>, // sorted by batch ascending
    pub labels: Vec<i32>,
    pub guidance: f32,
}

impl ModelField {
    pub fn new(
        rt: &Runtime,
        info: &ModelInfo,
        labels: Vec<i32>,
        guidance: f32,
    ) -> Result<ModelField> {
        let mut buckets = info.buckets.clone();
        buckets.sort_by_key(|b| b.batch);
        let executables = buckets
            .iter()
            .map(|b| rt.load(&b.path, b.batch, info.dim))
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("loading model '{}'", info.name))?;
        Ok(ModelField { info: info.clone(), executables, labels, guidance })
    }

    fn pick(&self, rows: usize) -> &ExeHandle {
        self.executables
            .iter()
            .find(|e| e.batch >= rows)
            .unwrap_or_else(|| self.executables.last().unwrap())
    }

    /// Largest compiled bucket (callers chunk above this).
    pub fn max_batch(&self) -> usize {
        self.executables.last().map(|e| e.batch).unwrap_or(1)
    }
}

impl Field for ModelField {
    fn dim(&self) -> usize {
        self.info.dim
    }

    fn eval(&self, t: f64, x: &[f32]) -> Result<Vec<f32>> {
        let dim = self.info.dim;
        let rows = x.len() / dim;
        debug_assert_eq!(rows, self.labels.len(), "labels must match batch");
        let mut out = Vec::with_capacity(x.len());
        let mut r = 0;
        while r < rows {
            let exe = self.pick(rows - r);
            let take = exe.batch.min(rows - r);
            // pad up to the bucket
            let mut xb = vec![0f32; exe.batch * dim];
            xb[..take * dim].copy_from_slice(&x[r * dim..(r + take) * dim]);
            let mut lb = vec![self.info.null_class as i32; exe.batch];
            lb[..take].copy_from_slice(&self.labels[r..r + take]);
            let ub = exe.run(&xb, t as f32, self.guidance, &lb)?;
            out.extend_from_slice(&ub[..take * dim]);
            r += take;
        }
        Ok(out)
    }

    fn forwards_per_eval(&self) -> usize {
        2 // CFG doubles the effective batch (cond + uncond branches)
    }
}
