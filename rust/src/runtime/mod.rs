//! Request-path runtime: device lanes + artifact store + model fields.
//! Python never runs here; everything is loaded from `artifacts/`.

pub mod artifact;
pub mod backend;
pub mod client;
pub mod fault;
pub mod model_field;

pub use artifact::{ArtifactStore, FdSynth, ModelInfo, SolverArtifact};
pub use client::{ExeHandle, LaneHealth, LaneStats, Runtime, RuntimeConfig};
pub use fault::{FaultBackend, FaultConfig, FaultKind, FaultPlan, FaultSpec};
pub use model_field::{LoadedModel, ModelField};
