//! Request-path runtime: PJRT client + artifact store + model fields.
//! Python never runs here; everything is loaded from `artifacts/`.

pub mod artifact;
pub mod backend;
pub mod client;
pub mod model_field;

pub use artifact::{ArtifactStore, FdSynth, ModelInfo, SolverArtifact};
pub use client::{ExeHandle, Runtime};
pub use model_field::ModelField;
