//! Deterministic fault injection for the device-lane runtime.
//!
//! [`FaultBackend`] wraps any [`Backend`] and injects failures according
//! to a seeded [`FaultPlan`]: transient exec errors, backend panics,
//! latency spikes (stalls), and wedge-forever hangs — each decided
//! purely from `(seed, lane, generation, call_index)`, so a chaos run is
//! exactly reproducible and a respawned lane (bumped generation) does
//! not replay the identical fault stream that killed its predecessor.
//!
//! Two knobs drive injection:
//!
//! * **Probabilistic rates** (`error_per_mille` etc.): a hash of the
//!   coordinates picks a fault class per exec call. Deterministic, but
//!   statistically shaped — good for soak-style chaos tests.
//! * **Explicit schedule** ([`FaultSpec`]): "lane 0, call 3 → Wedge".
//!   Each entry fires at most once, for surgical scenarios (kill exactly
//!   the second exec of lane 1).
//!
//! `max_faults` caps total injections so every schedule converges: after
//! the budget is spent the backend behaves perfectly, which is what lets
//! chaos tests assert bit-identical recovery against a fault-free run.
//!
//! The plan is `Send + Sync` (shared across lane threads via `Arc`); the
//! wrapper itself is constructed inside each lane thread around that
//! lane's own backend, preserving the `Backend: !Send` contract.

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::backend::Backend;

/// What kind of failure to inject on a given exec call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Return a transient `Err` from `exec_into` (retryable).
    ExecError,
    /// Panic inside the backend (exercises the lane's `catch_unwind`).
    Panic,
    /// Sleep `stall_ms`, then execute normally (latency spike; output
    /// is still correct).
    Stall,
    /// Sleep `wedge_ms` — chosen far above the lane exec timeout in
    /// tests — then return an error. Models a wedged device call: the
    /// caller times out and the supervisor respawns the lane long
    /// before the sleeping thread wakes up.
    Wedge,
}

impl FaultKind {
    /// Small stable code carried in `fault_injected` trace events
    /// (`b` payload word); 0 is reserved for "none".
    pub fn code(self) -> u64 {
        match self {
            FaultKind::ExecError => 1,
            FaultKind::Panic => 2,
            FaultKind::Stall => 3,
            FaultKind::Wedge => 4,
        }
    }
}

/// One explicit schedule entry: inject `kind` on the `call`-th exec
/// (0-based, per lane thread lifetime) of lane `lane` (`None` = any
/// lane). Fires at most once.
#[derive(Debug, Clone)]
pub struct FaultSpec {
    /// Restrict to one lane index; `None` matches every lane.
    pub lane: Option<usize>,
    /// 0-based exec-call index within the lane thread's lifetime.
    /// Respawned lanes restart their call counter at 0 but carry a
    /// bumped generation, so a spec written against generation 0 does
    /// not re-fire after respawn (entries are one-shot anyway).
    pub call: u64,
    /// The failure to inject.
    pub kind: FaultKind,
}

/// Configuration of a deterministic fault schedule. `Default` is the
/// all-zero config: no faults, a pure pass-through wrapper.
#[derive(Debug, Clone, Default)]
pub struct FaultConfig {
    /// Seed mixed into every probabilistic decision.
    pub seed: u64,
    /// Per-mille (0..=1000) probability of [`FaultKind::ExecError`].
    pub error_per_mille: u32,
    /// Per-mille probability of [`FaultKind::Panic`].
    pub panic_per_mille: u32,
    /// Per-mille probability of [`FaultKind::Stall`].
    pub stall_per_mille: u32,
    /// Sleep duration for [`FaultKind::Stall`] injections, in ms.
    pub stall_ms: u64,
    /// Sleep duration for [`FaultKind::Wedge`] injections, in ms. Must
    /// stay finite (tests pick a few hundred ms, above the lane exec
    /// timeout) so wedged threads eventually exit and tests terminate.
    pub wedge_ms: u64,
    /// Hard cap on total injected faults across all lanes and
    /// generations; `None` = unlimited. Chaos tests set this so the
    /// system provably converges to fault-free behavior.
    pub max_faults: Option<u64>,
    /// Explicit one-shot entries, checked before the probabilistic
    /// rates.
    pub schedule: Vec<FaultSpec>,
}

/// A shared, thread-safe fault decision engine built from a
/// [`FaultConfig`]. One plan serves every lane (and every respawned
/// generation) of a runtime.
pub struct FaultPlan {
    cfg: FaultConfig,
    /// One-shot latches, parallel to `cfg.schedule`.
    fired: Vec<AtomicBool>,
    injected: AtomicU64,
    /// [`FaultKind::code`] of the most recent injection (0 = none yet).
    /// Diagnostic only — under concurrent lanes a reader may see a
    /// neighbor's kind, which the tracing plane tolerates.
    last_kind: AtomicU64,
}

/// splitmix64 finalizer: a cheap, well-mixed hash for turning fault
/// coordinates into an independent decision stream.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl FaultPlan {
    /// Build a plan from a config.
    pub fn new(cfg: FaultConfig) -> FaultPlan {
        let fired = cfg.schedule.iter().map(|_| AtomicBool::new(false)).collect();
        FaultPlan { cfg, fired, injected: AtomicU64::new(0), last_kind: AtomicU64::new(0) }
    }

    /// A pass-through plan that never injects anything.
    pub fn none() -> Arc<FaultPlan> {
        Arc::new(FaultPlan::new(FaultConfig::default()))
    }

    /// Total faults injected so far (all lanes, all generations).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// [`FaultKind::code`] of the most recent injection, 0 if none yet
    /// (lane threads tag `fault_injected` trace events with this).
    pub fn last_kind_code(&self) -> u64 {
        self.last_kind.load(Ordering::Relaxed)
    }

    /// The configured stall duration.
    fn stall(&self) -> Duration {
        Duration::from_millis(self.cfg.stall_ms)
    }

    /// The configured wedge duration.
    fn wedge(&self) -> Duration {
        Duration::from_millis(self.cfg.wedge_ms)
    }

    /// Decide whether the exec call at `(lane, generation, call)` should
    /// fault, charging the `max_faults` budget when it does. Explicit
    /// schedule entries win over probabilistic rates and fire at most
    /// once each. Pure in its coordinates (modulo the one-shot latches
    /// and the budget), so identical runs inject identical faults.
    pub fn decide(&self, lane: usize, generation: u64, call: u64) -> Option<FaultKind> {
        let kind = self.pick(lane, generation, call)?;
        // charge the global budget; back out if it is exhausted
        if let Some(cap) = self.cfg.max_faults {
            if self.injected.fetch_add(1, Ordering::Relaxed) >= cap {
                self.injected.fetch_sub(1, Ordering::Relaxed);
                return None;
            }
        } else {
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        self.last_kind.store(kind.code(), Ordering::Relaxed);
        Some(kind)
    }

    /// The raw schedule/rate decision, before budget accounting.
    fn pick(&self, lane: usize, generation: u64, call: u64) -> Option<FaultKind> {
        for (i, spec) in self.cfg.schedule.iter().enumerate() {
            let lane_match = spec.lane.unwrap_or(lane) == lane;
            if lane_match && spec.call == call && generation == 0 {
                // one-shot: first caller to flip the latch wins
                if !self.fired[i].swap(true, Ordering::Relaxed) {
                    return Some(spec.kind);
                }
            }
        }
        let total =
            self.cfg.error_per_mille + self.cfg.panic_per_mille + self.cfg.stall_per_mille;
        if total == 0 {
            return None;
        }
        // mix generation in so a respawned lane sees a fresh stream —
        // otherwise call 0 of every generation could fault forever
        let h = mix(self
            .cfg
            .seed
            .wrapping_mul(0x0100_0000_01b3)
            .wrapping_add((lane as u64) << 40)
            .wrapping_add(generation << 20)
            .wrapping_add(call));
        let roll = (h % 1000) as u32;
        if roll < self.cfg.error_per_mille {
            Some(FaultKind::ExecError)
        } else if roll < self.cfg.error_per_mille + self.cfg.panic_per_mille {
            Some(FaultKind::Panic)
        } else if roll < total {
            Some(FaultKind::Stall)
        } else {
            None
        }
    }
}

/// A [`Backend`] wrapper that injects the plan's faults into `exec_into`
/// calls. `platform`/`load` always delegate — fault domains are exec
/// calls, the unit the retry/respawn machinery recovers.
pub struct FaultBackend {
    inner: Box<dyn Backend>,
    plan: Arc<FaultPlan>,
    lane: usize,
    generation: u64,
    calls: u64,
}

impl FaultBackend {
    /// Wrap `inner`, attributing faults to `(lane, generation)`.
    pub fn new(
        inner: Box<dyn Backend>,
        plan: Arc<FaultPlan>,
        lane: usize,
        generation: u64,
    ) -> FaultBackend {
        FaultBackend { inner, plan, lane, generation, calls: 0 }
    }
}

impl Backend for FaultBackend {
    fn platform(&self) -> String {
        self.inner.platform()
    }

    fn load(&mut self, path: &Path) -> Result<u64> {
        self.inner.load(path)
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_into(
        &mut self,
        id: u64,
        batch: usize,
        dim: usize,
        x: &[f32],
        t: f32,
        w: f32,
        labels: &[i32],
        out: &mut [f32],
    ) -> Result<()> {
        let call = self.calls;
        self.calls += 1;
        match self.plan.decide(self.lane, self.generation, call) {
            None => self.inner.exec_into(id, batch, dim, x, t, w, labels, out),
            Some(FaultKind::ExecError) => Err(anyhow::anyhow!(
                "injected transient exec error (lane {}, generation {}, call {call})",
                self.lane,
                self.generation
            )),
            Some(FaultKind::Panic) => {
                // panic_any is a plain function call: the injected panic
                // is real (the lane's catch_unwind converts it into an
                // error reply) without putting a panic macro in
                // non-test runtime code
                std::panic::panic_any("injected backend panic")
            }
            Some(FaultKind::Stall) => {
                std::thread::sleep(self.plan.stall());
                self.inner.exec_into(id, batch, dim, x, t, w, labels, out)
            }
            Some(FaultKind::Wedge) => {
                std::thread::sleep(self.plan.wedge());
                Err(anyhow::anyhow!(
                    "injected wedge (lane {}, generation {}, call {call})",
                    self.lane,
                    self.generation
                ))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_config_never_faults() {
        let plan = FaultPlan::new(FaultConfig::default());
        for lane in 0..4 {
            for call in 0..1000 {
                assert_eq!(plan.decide(lane, 0, call), None);
            }
        }
        assert_eq!(plan.injected(), 0);
    }

    #[test]
    fn decisions_are_deterministic_and_generation_sensitive() {
        let cfg = FaultConfig {
            seed: 7,
            error_per_mille: 100,
            panic_per_mille: 50,
            stall_per_mille: 50,
            ..FaultConfig::default()
        };
        let a = FaultPlan::new(cfg.clone());
        let b = FaultPlan::new(cfg.clone());
        let stream =
            |p: &FaultPlan, g: u64| (0..500).map(|c| p.pick(0, g, c)).collect::<Vec<_>>();
        // identical plans produce identical streams
        assert_eq!(stream(&a, 0), stream(&b, 0));
        // a bumped generation produces a different stream (so a respawn
        // does not deterministically re-hit the same faults)
        assert_ne!(stream(&a, 0), stream(&a, 1));
        // rates are roughly honored: ~20% of 500 calls fault
        let n = stream(&b, 0).iter().flatten().count();
        assert!((50..=150).contains(&n), "faulted {n}/500");
    }

    #[test]
    fn schedule_entries_fire_exactly_once() {
        let cfg = FaultConfig {
            schedule: vec![
                FaultSpec { lane: Some(1), call: 3, kind: FaultKind::Wedge },
                FaultSpec { lane: None, call: 0, kind: FaultKind::ExecError },
            ],
            ..FaultConfig::default()
        };
        let plan = FaultPlan::new(cfg);
        // wildcard-lane entry fires on the first matching call only
        assert_eq!(plan.decide(0, 0, 0), Some(FaultKind::ExecError));
        assert_eq!(plan.decide(2, 0, 0), None);
        // lane-pinned entry: wrong lane never fires it
        assert_eq!(plan.decide(0, 0, 3), None);
        assert_eq!(plan.decide(1, 0, 3), Some(FaultKind::Wedge));
        assert_eq!(plan.decide(1, 0, 3), None);
        // schedule entries never fire on respawned generations
        let plan2 = FaultPlan::new(FaultConfig {
            schedule: vec![FaultSpec { lane: None, call: 0, kind: FaultKind::Panic }],
            ..FaultConfig::default()
        });
        assert_eq!(plan2.decide(0, 1, 0), None);
        assert_eq!(plan2.decide(0, 0, 0), Some(FaultKind::Panic));
        assert_eq!(plan.injected(), 2);
    }

    #[test]
    fn max_faults_caps_injection() {
        let plan = FaultPlan::new(FaultConfig {
            seed: 1,
            error_per_mille: 1000, // every call would fault
            max_faults: Some(3),
            ..FaultConfig::default()
        });
        let n = (0..100).filter(|&c| plan.decide(0, 0, c).is_some()).count();
        assert_eq!(n, 3);
        assert_eq!(plan.injected(), 3);
        // after the budget is spent the plan is a no-op forever
        assert_eq!(plan.decide(0, 5, 0), None);
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn fault_backend_injects_and_counts() {
        use crate::runtime::backend;
        let dir = std::env::temp_dir().join(format!("bns-fault-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let art = dir.join("f.json");
        std::fs::write(
            &art,
            r#"{"bns_stub_field": {"k": -1.0, "c": 0.5, "label_scale": 0.0, "t_scale": 0.0}}"#,
        )
        .unwrap();

        let plan = Arc::new(FaultPlan::new(FaultConfig {
            schedule: vec![FaultSpec { lane: Some(0), call: 1, kind: FaultKind::ExecError }],
            ..FaultConfig::default()
        }));
        let mut be = FaultBackend::new(backend::new_cpu(1).unwrap(), plan.clone(), 0, 0);
        assert_eq!(be.platform(), "stub-cpu");
        let id = be.load(&art).unwrap();
        let x = [2.0f32, 4.0];
        let mut out = [0.0f32; 2];
        // call 0: clean
        be.exec_into(id, 2, 1, &x, 0.0, 1.0, &[0, 0], &mut out).unwrap();
        assert_eq!(out, [-1.5, -3.5]);
        // call 1: injected error
        let err = be.exec_into(id, 2, 1, &x, 0.0, 1.0, &[0, 0], &mut out).unwrap_err();
        assert!(err.to_string().contains("injected transient exec error"), "{err}");
        // call 2: clean again
        be.exec_into(id, 2, 1, &x, 0.0, 1.0, &[0, 0], &mut out).unwrap();
        assert_eq!(plan.injected(), 1);
        assert_eq!(plan.last_kind_code(), FaultKind::ExecError.code());
        std::fs::remove_dir_all(&dir).ok();
    }
}
