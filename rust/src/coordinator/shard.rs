//! Shard router: one front door fanning requests across N in-process
//! engine shards.
//!
//! Each shard is a full [`Engine`] (own dispatch thread, worker pool,
//! batcher, admission budget) — what they share is the fleet plumbing:
//! one model [`Registry`], one trace ring, and one request-id counter
//! ([`EngineShared`]), so models load/unload fleet-wide and ids stay
//! unique across shards. Requests route by **consistent hashing** on the
//! model id (FNV-1a over the name, 64 virtual nodes per shard): a given
//! model always lands on the same shard — so its compiled executables,
//! router cache entries, and batch groups concentrate there — and
//! draining one shard moves only ~K/N models (asserted by
//! `coordinator_props`).
//!
//! Draining a shard ([`Fleet::drain`]) removes it from routing without
//! touching its in-flight work: admitted batches settle normally, new
//! arrivals re-route to the surviving shards. That is the rolling-reload
//! primitive — drain, hot `load` the new artifacts, undrain.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::engine::{Engine, EngineConfig, EngineShared};
use super::metrics::TenantCounters;
use super::registry::Registry;
use super::request::{SampleRequest, ServeError};
use crate::obs::{TraceRecorder, TraceStage};
use crate::runtime::{ArtifactStore, Runtime};
use crate::util::json::Json;

/// Virtual nodes per shard on the hash ring: enough that draining one
/// shard spreads its models roughly evenly over the survivors.
const VNODES: u32 = 64;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64-bit — tiny, allocation-free, and stable across runs (the
/// ring layout must not depend on process-randomized hashing).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Fleet sizing knobs.
#[derive(Clone)]
pub struct FleetConfig {
    /// Engine shards behind the front door (min 1).
    pub shards: usize,
    /// Per-shard engine configuration (each shard gets its own batcher,
    /// workers, and admission budget from this).
    pub engine: EngineConfig,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig { shards: 1, engine: EngineConfig::default() }
    }
}

/// N engine shards behind one consistent-hash front door, sharing a
/// model registry, trace ring, and id space.
pub struct Fleet {
    shards: Vec<Arc<Engine>>,
    /// Per-shard drain flags (indexed like `shards`); drained shards are
    /// skipped by routing but keep settling their in-flight work.
    draining: Vec<AtomicBool>,
    /// Consistent-hash ring: `(vnode hash, shard index)` sorted by hash.
    ring: Vec<(u64, u32)>,
    registry: Arc<Registry>,
    tracer: Arc<TraceRecorder>,
}

fn build_ring(shards: usize) -> Vec<(u64, u32)> {
    let mut ring = Vec::with_capacity(shards * VNODES as usize);
    for s in 0..shards as u32 {
        for v in 0..VNODES {
            let mut buf = [0u8; 8];
            buf[..4].copy_from_slice(&s.to_le_bytes());
            buf[4..].copy_from_slice(&v.to_le_bytes());
            ring.push((fnv1a(&buf), s));
        }
    }
    ring.sort_unstable();
    ring
}

impl Fleet {
    /// Start `cfg.shards` engines over one shared registry (seeded from
    /// `store`), trace ring, and id counter.
    pub fn start(
        store: Arc<ArtifactStore>,
        rt: Arc<Runtime>,
        cfg: FleetConfig,
    ) -> Result<Arc<Fleet>> {
        let n = cfg.shards.max(1);
        let registry = Arc::new(Registry::new(store, &rt));
        let tracer = Arc::new(TraceRecorder::new(cfg.engine.trace_capacity));
        let ids = Arc::new(std::sync::atomic::AtomicU64::new(1));
        let mut shards = Vec::with_capacity(n);
        for _ in 0..n {
            let shared = EngineShared {
                registry: registry.clone(),
                tracer: tracer.clone(),
                ids: ids.clone(),
            };
            shards.push(Arc::new(Engine::start_shared(shared, rt.clone(), cfg.engine.clone())?));
        }
        let draining = (0..n).map(|_| AtomicBool::new(false)).collect();
        Ok(Arc::new(Fleet { shards, draining, ring: build_ring(n), registry, tracer }))
    }

    /// Wrap an already-running single engine as a one-shard fleet — the
    /// compatibility path for `Server::bind` and in-process embedders.
    pub fn from_engine(engine: Arc<Engine>) -> Arc<Fleet> {
        let registry = engine.registry().clone();
        let tracer = engine.tracer.clone();
        Arc::new(Fleet {
            shards: vec![engine],
            draining: vec![AtomicBool::new(false)],
            ring: build_ring(1),
            registry,
            tracer,
        })
    }

    /// Consistent-hash routing: the shard owning `model`, skipping
    /// drained shards clockwise. `None` only when every shard is
    /// draining. Allocation-free — this runs once per request on the
    /// front-door hot path (see `analysis/hot_paths.toml`).
    pub fn shard_for(&self, model: &str) -> Option<usize> {
        let n = self.ring.len();
        if n == 0 {
            return None;
        }
        let h = fnv1a(model.as_bytes());
        let start = match self.ring.binary_search_by(|probe| probe.0.cmp(&h)) {
            Ok(i) => i,
            Err(i) => i % n,
        };
        let mut i = start;
        loop {
            let s = self.ring[i].1 as usize;
            if !self.draining[s].load(Ordering::Relaxed) {
                return Some(s);
            }
            i = (i + 1) % n;
            if i == start {
                return None;
            }
        }
    }

    /// Route and submit: picks the model's shard, delegates to its
    /// engine's admission control, and records a `shard_route` trace
    /// span on success. Rejects with `unavailable` when every shard is
    /// draining.
    pub fn try_submit(&self, req: SampleRequest) -> Result<u64, (SampleRequest, ServeError)> {
        let Some(s) = self.shard_for(&req.model) else {
            return Err((
                req,
                ServeError::unavailable("every shard is draining", 1000),
            ));
        };
        let id = self.shards[s].try_submit(req)?;
        self.tracer.record(id, TraceStage::ShardRoute, s as u64, 0);
        Ok(id)
    }

    /// Mark shard `i` drained (`on = true`) or routable again. Routing
    /// skips drained shards; their in-flight work settles normally.
    /// Out-of-range indices are ignored.
    pub fn drain(&self, i: usize, on: bool) {
        if let Some(d) = self.draining.get(i) {
            d.store(on, Ordering::Relaxed);
        }
    }

    /// Whether shard `i` is currently drained from routing.
    pub fn is_draining(&self, i: usize) -> bool {
        self.draining.get(i).map(|d| d.load(Ordering::Relaxed)).unwrap_or(false)
    }

    /// Number of engine shards.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard `i`'s engine (panics never: callers index via
    /// `num_shards`; out-of-range returns `None`).
    pub fn engine(&self, i: usize) -> Option<&Arc<Engine>> {
        self.shards.get(i)
    }

    /// The fleet-shared model registry (`load`/`unload`/`list_models`).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// The fleet-shared trace ring (`trace` op, `--trace-out`).
    pub fn tracer(&self) -> &Arc<TraceRecorder> {
        &self.tracer
    }

    /// Per-shard gauges for the `stats`/`health` ops: typed reads of
    /// each shard's metrics atomics, no locks beyond the tenant ledger.
    pub fn shards_json(&self) -> Json {
        Json::Arr(
            self.shards
                .iter()
                .enumerate()
                .map(|(i, e)| {
                    let m = &e.metrics;
                    Json::obj(vec![
                        ("shard", Json::Num(i as f64)),
                        ("draining", Json::Bool(self.is_draining(i))),
                        ("requests", Json::Num(m.requests.load(Ordering::Relaxed) as f64)),
                        ("samples", Json::Num(m.samples.load(Ordering::Relaxed) as f64)),
                        ("rejected", Json::Num(m.rejected.load(Ordering::Relaxed) as f64)),
                        (
                            "rejected_quota",
                            Json::Num(m.rejected_quota.load(Ordering::Relaxed) as f64),
                        ),
                        (
                            "inflight_rows",
                            Json::Num(m.inflight_rows.load(Ordering::Relaxed) as f64),
                        ),
                        (
                            "work_queue_depth",
                            Json::Num(m.queue_depth.load(Ordering::Relaxed) as f64),
                        ),
                    ])
                })
                .collect(),
        )
    }

    /// The `stats` op payload: shard-0's counter snapshot at the top
    /// level (bit-compatible with the pre-fleet payload on one shard),
    /// the per-shard gauge array under `shards`, and the fleet-wide
    /// tenant ledger replacing shard-0's local `tenants`.
    pub fn stats_json(&self) -> Json {
        let mut o = match self.shards.first() {
            Some(e) => e.metrics.snapshot_json(),
            None => Json::obj(Vec::new()),
        };
        if let Json::Obj(map) = &mut o {
            map.insert("shards".into(), self.shards_json());
            map.insert("tenants".into(), self.tenants_json());
        }
        o
    }

    /// The `health` op payload: shard-0's fault-domain view (lanes +
    /// breakers — the runtime is shared, so its lanes are fleet-wide)
    /// plus the per-shard gauge array under `shards`.
    pub fn health_json(&self) -> Json {
        let mut o = match self.shards.first() {
            Some(e) => e.health_json(),
            None => Json::obj(Vec::new()),
        };
        if let Json::Obj(map) = &mut o {
            map.insert("shards".into(), self.shards_json());
        }
        o
    }

    /// Fleet-wide per-tenant counters: each shard's tenant ledger summed
    /// by tenant name (the `tenants` key of the `stats` op).
    pub fn tenants_json(&self) -> Json {
        let mut agg: BTreeMap<String, TenantCounters> = BTreeMap::new();
        for e in &self.shards {
            for (name, c) in e.metrics.tenants_snapshot() {
                let t = agg.entry(name).or_default();
                t.requests += c.requests;
                t.samples += c.samples;
                t.rejected_quota += c.rejected_quota;
            }
        }
        Json::Obj(
            agg.into_iter()
                .map(|(name, c)| {
                    (
                        name,
                        Json::obj(vec![
                            ("requests", Json::Num(c.requests as f64)),
                            ("samples", Json::Num(c.samples as f64)),
                            ("rejected_quota", Json::Num(c.rejected_quota as f64)),
                        ]),
                    )
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_deterministic_and_covers_all_shards() {
        let a = build_ring(4);
        let b = build_ring(4);
        assert_eq!(a, b, "ring layout must be stable across builds");
        assert_eq!(a.len(), 4 * VNODES as usize);
        for s in 0..4u32 {
            assert!(a.iter().any(|&(_, x)| x == s), "shard {s} owns no vnodes");
        }
    }

    #[test]
    fn shard_for_is_stable_and_drain_reroutes() {
        let (store, dir) = crate::bench_util::stub_store(
            "shardfor",
            &[crate::bench_util::StubModel {
                name: "m",
                dim: 4,
                num_classes: 2,
                forwards_per_eval: 1,
                k: -0.5,
                c: 0.1,
                label_scale: 0.0,
                cost: 1,
                buckets: &[4],
            }],
        )
        .unwrap();
        let rt = Arc::new(Runtime::cpu().unwrap());
        let fleet = Fleet::start(
            store,
            rt,
            FleetConfig { shards: 3, engine: EngineConfig { workers: 1, ..Default::default() } },
        )
        .unwrap();

        // stable: the same model always routes to the same shard
        let names: Vec<String> = (0..64).map(|i| format!("model-{i}")).collect();
        let homes: Vec<usize> =
            names.iter().map(|n| fleet.shard_for(n).unwrap()).collect();
        for (n, &h) in names.iter().zip(&homes) {
            assert_eq!(fleet.shard_for(n), Some(h));
        }
        // drained shards are skipped; untouched models keep their home
        let victim = homes[0];
        fleet.drain(victim, true);
        for (n, &h) in names.iter().zip(&homes) {
            let now = fleet.shard_for(n).unwrap();
            assert_ne!(now, victim, "drained shard must not be routed to");
            if h != victim {
                assert_eq!(now, h, "models off the drained shard must not move");
            }
        }
        // all shards draining -> no route
        for i in 0..fleet.num_shards() {
            fleet.drain(i, true);
        }
        assert_eq!(fleet.shard_for("m"), None);
        fleet.drain(victim, false);
        assert_eq!(fleet.shard_for(&names[0]), Some(victim), "undrain restores the home");
        std::fs::remove_dir_all(dir).ok();
    }
}
