//! Event-driven TCP front-end: JSON-lines protocol over non-blocking
//! sockets.
//!
//! The full wire specification — every op, request/response field, error
//! code, streaming frame, and worked client examples — lives in
//! **PROTOCOL.md** at the repo root; this header is only an index.
//!
//! Architecture (DESIGN.md §9): one accept thread hands sockets
//! round-robin to a small fixed pool of **reactor** threads
//! (`--reactors`). Each reactor multiplexes its connections with a
//! readiness loop over `TcpStream::set_nonblocking` sockets (std-only —
//! tokio/mio are not resolvable offline, DESIGN.md §3): it drains
//! readable bytes into per-connection line buffers, admits complete
//! requests into the [`Engine`] (which applies the in-flight row budget
//! and per-request deadlines), pumps engine replies and streaming
//! progress events back into per-connection write buffers, and flushes
//! them without ever blocking on a peer. A slow or hung client therefore
//! stalls only its own connection; the seed's thread-per-connection
//! blocking loop stalled a thread per slow peer and queued without
//! bound.
//!
//! Overload never queues silently: admission rejects produce a
//! structured `{"ok":false,"err":"overloaded","retry_after_ms":...}`
//! line immediately (PROTOCOL.md §Errors).

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::engine::Engine;
use super::request::{
    ErrCode, Priority, Progress, SampleRequest, SampleResponse, ServeError, SolverSpec,
};
use super::shard::Fleet;
use crate::runtime::ArtifactStore;
use crate::util::json::Json;

/// Map a wire solver string to a [`SolverSpec`].
///
/// `"auto"` routes BNS-first; `"gt"`/`"rk45"` is adaptive ground truth;
/// anything containing `"_nfe"` is treated as a distilled artifact name;
/// everything else is a named baseline at `nfe`.
///
/// ```
/// use bns_serve::coordinator::server::parse_solver_spec;
/// use bns_serve::coordinator::SolverSpec;
///
/// assert_eq!(parse_solver_spec("auto", 8), SolverSpec::Auto { nfe: 8 });
/// assert_eq!(parse_solver_spec("gt", 8), SolverSpec::GroundTruth);
/// assert_eq!(
///     parse_solver_spec("euler", 4),
///     SolverSpec::Baseline { name: "euler".into(), nfe: 4 }
/// );
/// assert!(matches!(
///     parse_solver_spec("img_fm_ot_w0.5_nfe8_bns", 8),
///     SolverSpec::Distilled { .. }
/// ));
/// ```
pub fn parse_solver_spec(solver: &str, nfe: usize) -> SolverSpec {
    match solver {
        "auto" => SolverSpec::Auto { nfe },
        "gt" | "rk45" => SolverSpec::GroundTruth,
        s if s.contains("_nfe") => SolverSpec::Distilled { name: s.to_string() },
        s => SolverSpec::Baseline { name: s.to_string(), nfe },
    }
}

/// Serving-plane knobs (CLI: `serve --reactors --deadline-ms`).
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Reactor threads multiplexing connections. Two saturate the engine
    /// for typical request sizes; raise for many small-request clients.
    pub reactors: usize,
    /// Reject request lines longer than this with `line_too_long`
    /// (protects the reactor from unbounded buffering).
    pub max_line_bytes: usize,
    /// Default per-request deadline applied when a request carries no
    /// `deadline_ms` of its own (`None` = no default deadline).
    pub default_deadline_ms: Option<u64>,
    /// Reactor sleep when a full pass over its connections moved no
    /// bytes and no events (the readiness-loop idle tick).
    pub idle_poll: Duration,
    /// Drop a connection whose unsent output exceeds this (a peer that
    /// stopped reading while streaming large samples).
    pub max_outbuf_bytes: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            reactors: 2,
            max_line_bytes: 1 << 20,
            default_deadline_ms: None,
            idle_poll: Duration::from_micros(500),
            max_outbuf_bytes: 64 << 20,
        }
    }
}

/// A running serving plane: accept thread + reactor pool. Dropping the
/// handle (or calling [`Server::shutdown`]) stops every thread; open
/// connections are closed, in-flight engine work completes and its
/// replies are discarded.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral test port) over
    /// a single engine, wrapped as a one-shard [`Fleet`]. The `store`
    /// parameter is accepted for API continuity but the serving surface
    /// reads the engine's registry, so hot `load`/`unload` are visible.
    pub fn bind(
        addr: &str,
        cfg: ServerConfig,
        engine: Arc<Engine>,
        store: Arc<ArtifactStore>,
    ) -> Result<Server> {
        let _ = store; // superseded by the engine's registry view
        Server::bind_fleet(addr, cfg, Fleet::from_engine(engine))
    }

    /// Bind `addr` and spawn the accept + reactor threads over a fleet
    /// of engine shards. Returns immediately; use [`Server::local_addr`]
    /// for the bound address.
    pub fn bind_fleet(addr: &str, cfg: ServerConfig, fleet: Arc<Fleet>) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let n_reactors = cfg.reactors.max(1);
        let mut conn_txs = Vec::with_capacity(n_reactors);
        let mut threads = Vec::with_capacity(n_reactors + 1);
        for ri in 0..n_reactors {
            // Bounded handoff: 256 not-yet-adopted sockets per reactor is
            // far beyond any accept burst a reactor can't absorb in one
            // tick; if a reactor ever wedges, the accept thread blocks
            // here instead of queueing sockets without bound.
            let (tx, rx) = mpsc::sync_channel::<TcpStream>(256);
            conn_txs.push(tx);
            let fleet = fleet.clone();
            let stop_r = stop.clone();
            spawn_server_thread(
                &mut threads,
                &stop,
                format!("bns-reactor-{ri}"),
                move || reactor_loop(rx, fleet, stop_r, cfg),
            )?;
        }
        {
            let stop_a = stop.clone();
            spawn_server_thread(&mut threads, &stop, "bns-accept".into(), move || {
                accept_loop(listener, conn_txs, stop_a)
            })?;
        }
        Ok(Server { addr: local, stop, threads })
    }

    /// The bound socket address (resolves port 0 binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    fn shutdown_inner(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    /// Stop accepting, close every connection, and join all threads.
    /// Idempotent; `Drop` performs the same teardown.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Spawn one serving-plane thread, or — if the OS refuses — signal every
/// already-spawned thread to stop, join them, and return the error as a
/// structured failure of [`Server::bind`] instead of panicking.
fn spawn_server_thread(
    threads: &mut Vec<std::thread::JoinHandle<()>>,
    stop: &Arc<AtomicBool>,
    name: String,
    f: impl FnOnce() + Send + 'static,
) -> Result<()> {
    match std::thread::Builder::new().name(name.clone()).spawn(f) {
        Ok(h) => {
            threads.push(h);
            Ok(())
        }
        Err(e) => {
            stop.store(true, Ordering::SeqCst);
            for t in threads.drain(..) {
                let _ = t.join();
            }
            Err(anyhow::Error::new(e).context(format!("spawning server thread {name}")))
        }
    }
}

/// Serve `addr` until the process is killed, with default
/// [`ServerConfig`]. See [`serve_with`] for tunables.
pub fn serve(addr: &str, engine: Arc<Engine>, store: Arc<ArtifactStore>) -> Result<()> {
    serve_with(addr, ServerConfig::default(), engine, store)
}

/// Serve `addr` until the process is killed (the `bns-serve serve`
/// entrypoint): binds a [`Server`] and parks the calling thread.
pub fn serve_with(
    addr: &str,
    cfg: ServerConfig,
    engine: Arc<Engine>,
    store: Arc<ArtifactStore>,
) -> Result<()> {
    let _ = store; // superseded by the engine's registry view
    serve_fleet(addr, cfg, Fleet::from_engine(engine))
}

/// Serve `addr` over a multi-shard fleet until the process is killed
/// (the `bns-serve serve --shards N` entrypoint): binds a [`Server`]
/// and parks the calling thread.
pub fn serve_fleet(addr: &str, cfg: ServerConfig, fleet: Arc<Fleet>) -> Result<()> {
    let server = Server::bind_fleet(addr, cfg, fleet)?;
    eprintln!(
        "[bns-serve] listening on {} ({} reactor(s))",
        server.local_addr(),
        cfg.reactors.max(1)
    );
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

// ---------------------------------------------------------------------------
// accept + reactor loops
// ---------------------------------------------------------------------------

fn accept_loop(
    listener: TcpListener,
    conn_txs: Vec<mpsc::SyncSender<TcpStream>>,
    stop: Arc<AtomicBool>,
) {
    let mut next = 0usize;
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // non-blocking from birth; NODELAY because frames are
                // small and latency-sensitive
                let _ = stream.set_nonblocking(true);
                let _ = stream.set_nodelay(true);
                if conn_txs[next % conn_txs.len()].send(stream).is_err() {
                    return; // reactor gone -> shutting down
                }
                next += 1;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => {
                eprintln!("[bns-serve] accept error: {e}");
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

/// Cap on each connection's `(tag, id)` correlation history for the
/// `trace` op — old entries fall off; their timelines stay reachable by
/// id until the ring overwrites them.
const RECENT_TAGS: usize = 32;

/// Per-request bookkeeping between admission and the terminal reply.
struct PendingReq {
    /// Client asked for streaming frames (`"stream":true`).
    stream: bool,
    /// Client correlation value, echoed verbatim on every frame.
    tag: Option<Json>,
}

/// One multiplexed connection owned by a reactor.
struct Conn {
    stream: TcpStream,
    /// Bytes of the current (incomplete) request line.
    rbuf: Vec<u8>,
    /// Serialized frames awaiting a writable socket.
    obuf: Vec<u8>,
    /// Prefix of `obuf` already written.
    osent: usize,
    reply_tx: mpsc::Sender<SampleResponse>,
    reply_rx: mpsc::Receiver<SampleResponse>,
    prog_tx: mpsc::Sender<Progress>,
    prog_rx: mpsc::Receiver<Progress>,
    pending: HashMap<u64, PendingReq>,
    /// Recently admitted `(tag, id)` pairs, oldest first, capped at
    /// [`RECENT_TAGS`] — lets the `trace` op resolve a client tag to the
    /// engine id its timeline is keyed by. Connection-local on purpose:
    /// tags are a client-side correlation namespace (PROTOCOL.md).
    recent: Vec<(Json, u64)>,
    /// Peer half-closed its write side; finish pending work then drop.
    eof: bool,
    /// Socket error / output overflow; drop immediately.
    dead: bool,
    /// Currently discarding an over-long line (until its newline).
    discarding: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        let (reply_tx, reply_rx) = mpsc::channel(); // bns-lint: allow(bounded_channel) — replies are bounded by the engine's in-flight row budget; a bounded sender here could deadlock an engine worker against a stalled reactor
        let (prog_tx, prog_rx) = mpsc::channel(); // bns-lint: allow(bounded_channel) — progress is drained and coalesced every reactor tick; a bounded sender would let one slow streaming peer stall a whole worker batch
        Conn {
            stream,
            rbuf: Vec::new(),
            obuf: Vec::new(),
            osent: 0,
            reply_tx,
            reply_rx,
            prog_tx,
            prog_rx,
            pending: HashMap::new(),
            recent: Vec::new(),
            eof: false,
            dead: false,
            discarding: false,
        }
    }

    fn enqueue(&mut self, frame: &Json) {
        self.obuf.extend_from_slice(frame.to_string().as_bytes());
        self.obuf.push(b'\n');
    }

    fn finished(&self) -> bool {
        self.dead || (self.eof && self.pending.is_empty() && self.osent == self.obuf.len())
    }
}

fn reactor_loop(
    rx: mpsc::Receiver<TcpStream>,
    fleet: Arc<Fleet>,
    stop: Arc<AtomicBool>,
    cfg: ServerConfig,
) {
    // the connections gauge lives on shard 0 (the front shard); a fleet
    // always has at least one shard
    let Some(engine) = fleet.engine(0).cloned() else { return };
    let mut conns: Vec<Conn> = Vec::new();
    let mut scratch = [0u8; 8192];
    while !stop.load(Ordering::Relaxed) {
        let mut active = false;
        while let Ok(stream) = rx.try_recv() {
            engine.metrics.connections.fetch_add(1, Ordering::Relaxed);
            conns.push(Conn::new(stream));
            active = true;
        }
        for c in conns.iter_mut() {
            active |= pump_read(c, &mut scratch, &fleet, &cfg);
            // progress BEFORE replies: events a worker sent ahead of the
            // terminal reply are flushed while the request is still
            // pending, so a streamed request always frames
            // accepted -> progress... -> result in order
            active |= pump_progress(c);
            active |= pump_replies(c);
            active |= pump_write(c);
            if c.obuf.len() - c.osent > cfg.max_outbuf_bytes {
                c.dead = true; // peer stopped reading; cut it loose
            }
        }
        let before = conns.len();
        conns.retain(|c| !c.finished());
        if conns.len() != before {
            engine
                .metrics
                .connections
                .fetch_sub((before - conns.len()) as u64, Ordering::Relaxed);
            active = true;
        }
        if !active {
            std::thread::sleep(cfg.idle_poll);
        }
    }
    engine.metrics.connections.fetch_sub(conns.len() as u64, Ordering::Relaxed);
}

/// Drain readable bytes; returns true if anything was read or handled.
///
/// The drain is capped per tick (`READ_BUDGET_PER_TICK`) so one
/// fast-pipelining client cannot monopolize its reactor or grow its
/// write buffer past the overflow check between ticks — when the budget
/// runs out the tick stays "active" (no idle sleep) and the remaining
/// bytes are picked up next pass, after every other connection got
/// service.
fn pump_read(
    c: &mut Conn,
    scratch: &mut [u8],
    fleet: &Fleet,
    cfg: &ServerConfig,
) -> bool {
    /// Max bytes ingested per connection per reactor tick.
    const READ_BUDGET_PER_TICK: usize = 128 << 10;
    if c.eof || c.dead {
        return false;
    }
    let mut any = false;
    let mut budget = READ_BUDGET_PER_TICK;
    while budget > 0 {
        let want = scratch.len().min(budget);
        match c.stream.read(&mut scratch[..want]) {
            Ok(0) => {
                c.eof = true;
                // a final line without a trailing newline still counts
                // (`printf '%s' '{"op":"stats"}' | nc -N` style clients)
                if !c.rbuf.is_empty() && !c.discarding {
                    let line = std::mem::take(&mut c.rbuf);
                    handle_request_line(c, &line, fleet, cfg);
                }
                break;
            }
            Ok(n) => {
                any = true;
                budget -= n;
                ingest_chunk(c, &scratch[..n], fleet, cfg);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                c.dead = true;
                break;
            }
        }
    }
    any
}

/// Split a received chunk on newlines: complete lines are handled in
/// place, the trailing fragment accumulates in `rbuf` (bounded by
/// `max_line_bytes` — overflow rejects the line and discards the rest
/// of it, §PROTOCOL `line_too_long`).
fn ingest_chunk(c: &mut Conn, mut bytes: &[u8], fleet: &Fleet, cfg: &ServerConfig) {
    while let Some(pos) = bytes.iter().position(|&b| b == b'\n') {
        let head = &bytes[..pos];
        if c.discarding {
            c.discarding = false; // oversized line fully skipped
        } else if c.rbuf.len() + head.len() > cfg.max_line_bytes {
            reject_oversize(c, cfg);
            c.rbuf.clear(); // line ends here; nothing left to discard
        } else {
            c.rbuf.extend_from_slice(head);
            let line = std::mem::take(&mut c.rbuf);
            handle_request_line(c, &line, fleet, cfg);
            c.rbuf = line; // reuse the allocation
            c.rbuf.clear();
        }
        bytes = &bytes[pos + 1..];
    }
    // trailing fragment (no newline yet)
    if c.discarding || bytes.is_empty() {
        return;
    }
    if c.rbuf.len() + bytes.len() > cfg.max_line_bytes {
        reject_oversize(c, cfg);
        c.rbuf.clear();
        c.discarding = true; // swallow until this line's newline arrives
    } else {
        c.rbuf.extend_from_slice(bytes);
    }
}

fn reject_oversize(c: &mut Conn, cfg: &ServerConfig) {
    let e = ServeError::new(
        ErrCode::LineTooLong,
        format!("request line exceeds {} bytes", cfg.max_line_bytes),
    );
    let frame = error_frame(&e, None, None);
    c.enqueue(&frame);
}

fn handle_request_line(c: &mut Conn, line: &[u8], fleet: &Fleet, cfg: &ServerConfig) {
    let Ok(text) = std::str::from_utf8(line) else {
        let e = ServeError::new(ErrCode::ParseError, "request line is not valid UTF-8");
        let frame = error_frame(&e, None, None);
        c.enqueue(&frame);
        return;
    };
    if text.trim().is_empty() {
        return;
    }
    let req = match Json::parse(text) {
        Ok(j) => j,
        Err(e) => {
            let e = ServeError::new(ErrCode::ParseError, format!("parse error: {e}"));
            let frame = error_frame(&e, None, None);
            c.enqueue(&frame);
            return;
        }
    };
    let tag = match req.get("tag") {
        Json::Null => None,
        t => Some(t.clone()),
    };
    match req.get("op").as_str() {
        Some("sample") => handle_sample(c, &req, tag, fleet, cfg),
        Some("stats") => {
            // shard-0 counters at the top level (identical to the
            // pre-fleet payload on a 1-shard deployment), plus the
            // per-shard gauge array and the fleet-wide tenant ledger
            let mut o = fleet.stats_json();
            if let Json::Obj(map) = &mut o {
                map.insert("ok".into(), Json::Bool(true));
                if let Some(t) = tag {
                    map.insert("tag".into(), t);
                }
            }
            c.enqueue(&o);
        }
        Some("health") => {
            // fault-domain view: lane generations/respawns + breaker
            // states + per-shard drain/queue gauges (PROTOCOL.md
            // §health); `stats` stays the counters op
            let mut o = fleet.health_json();
            if let Json::Obj(map) = &mut o {
                map.insert("ok".into(), Json::Bool(true));
                if let Some(t) = tag {
                    map.insert("tag".into(), t);
                }
            }
            c.enqueue(&o);
        }
        Some("load") => {
            // hot (re)load a model from the artifact root's manifest;
            // lane executables recompile lazily (PROTOCOL.md §load)
            let Some(model) = req.get("model").as_str() else {
                let e = ServeError::new(ErrCode::BadRequest, "missing 'model'");
                let frame = error_frame(&e, None, tag.as_ref());
                c.enqueue(&frame);
                return;
            };
            let frame = match fleet.registry().load(model) {
                Ok(version) => ok_frame(
                    vec![
                        ("ok", Json::Bool(true)),
                        ("model", Json::Str(model.to_string())),
                        ("version", Json::Num(version as f64)),
                    ],
                    tag,
                ),
                Err(e) => {
                    let msg = format!("{e:#}");
                    let code = if msg.contains("not present") {
                        ErrCode::UnknownModel
                    } else {
                        ErrCode::Internal
                    };
                    error_frame(&ServeError::new(code, msg), None, tag.as_ref())
                }
            };
            c.enqueue(&frame);
        }
        Some("unload") => {
            // remove a model from the resident set; in-flight work
            // drains behind a refcount before artifacts evict
            let Some(model) = req.get("model").as_str() else {
                let e = ServeError::new(ErrCode::BadRequest, "missing 'model'");
                let frame = error_frame(&e, None, tag.as_ref());
                c.enqueue(&frame);
                return;
            };
            let frame = match fleet.registry().unload(model) {
                Ok(draining) => ok_frame(
                    vec![
                        ("ok", Json::Bool(true)),
                        ("model", Json::Str(model.to_string())),
                        ("draining", Json::Bool(draining)),
                    ],
                    tag,
                ),
                Err(e) => error_frame(
                    &ServeError::new(ErrCode::UnknownModel, format!("{e:#}")),
                    None,
                    tag.as_ref(),
                ),
            };
            c.enqueue(&frame);
        }
        Some("list_models") => {
            // rich registry view: version, lifecycle state, in-flight
            // refs, and solver provenance per model (PROTOCOL.md)
            let frame = ok_frame(
                vec![
                    ("ok", Json::Bool(true)),
                    ("models", fleet.registry().list_json()),
                ],
                tag,
            );
            c.enqueue(&frame);
        }
        Some("trace") => {
            // request timelines from the tracing plane (PROTOCOL.md
            // §trace): by engine id, by last-N active ids, or by this
            // connection's recent tags. Unknown ids return an empty
            // timeline (the ring may have overwritten it) — not an error.
            let tracer = fleet.tracer().as_ref();
            let mut traces: Vec<Json> = Vec::new();
            if let Some(id) = req.get("id").as_usize() {
                traces.push(tracer.trace_json(id as u64));
            } else if let Some(n) = req.get("last").as_usize() {
                for id in tracer.last_ids(n.min(64)) {
                    traces.push(tracer.trace_json(id));
                }
            } else if let Some(t) = tag.as_ref() {
                for (rt, id) in &c.recent {
                    if rt == t {
                        traces.push(tracer.trace_json(*id));
                    }
                }
            } else {
                let e = ServeError::new(
                    ErrCode::BadRequest,
                    "trace: need 'id', 'last', or a 'tag' sampled on this connection",
                );
                let frame = error_frame(&e, None, None);
                c.enqueue(&frame);
                return;
            }
            let frame = ok_frame(
                vec![
                    ("ok", Json::Bool(true)),
                    ("enabled", Json::Bool(tracer.is_enabled())),
                    ("traces", Json::Arr(traces)),
                ],
                tag,
            );
            c.enqueue(&frame);
        }
        Some("ping") => {
            let frame = ok_frame(
                vec![("ok", Json::Bool(true)), ("op", Json::Str("pong".into()))],
                tag,
            );
            c.enqueue(&frame);
        }
        Some("models") => {
            // current registry view, so hot load/unload are visible here
            let store = fleet.registry().current();
            let frame = ok_frame(
                vec![
                    ("ok", Json::Bool(true)),
                    (
                        "models",
                        Json::Arr(store.models.keys().map(|k| Json::Str(k.clone())).collect()),
                    ),
                ],
                tag,
            );
            c.enqueue(&frame);
        }
        Some("solvers") => {
            let store = fleet.registry().current();
            let frame = ok_frame(
                vec![
                    ("ok", Json::Bool(true)),
                    (
                        "solvers",
                        Json::Arr(
                            store
                                .solvers
                                .values()
                                .map(|s| {
                                    Json::obj(vec![
                                        ("name", Json::Str(s.name.clone())),
                                        ("kind", Json::Str(s.meta.kind.clone())),
                                        ("model", Json::Str(s.meta.model.clone())),
                                        ("nfe", Json::Num(s.solver.nfe() as f64)),
                                        ("guidance", Json::Num(s.meta.guidance)),
                                        ("val_psnr", Json::Num(s.meta.val_psnr)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ],
                tag,
            );
            c.enqueue(&frame);
        }
        other => {
            let e = ServeError::new(ErrCode::UnknownOp, format!("unknown op {other:?}"));
            let frame = error_frame(&e, None, tag.as_ref());
            c.enqueue(&frame);
        }
    }
}

fn handle_sample(c: &mut Conn, req: &Json, tag: Option<Json>, fleet: &Fleet, cfg: &ServerConfig) {
    let bad = |c: &mut Conn, code: ErrCode, msg: String| {
        let frame = error_frame(&ServeError::new(code, msg), None, tag.as_ref());
        c.enqueue(&frame);
    };
    let model = match req.get("model").as_str() {
        Some(m) => m.to_string(),
        None => return bad(c, ErrCode::BadRequest, "missing 'model'".into()),
    };
    if !fleet.registry().has_model(&model) {
        // pre-reject before parsing the rest: cheaper, and the reject is
        // attributed to the model's home shard
        if let Some(e) = fleet.shard_for(&model).and_then(|s| fleet.engine(s)) {
            e.metrics.record_reject();
        }
        return bad(c, ErrCode::UnknownModel, format!("unknown model '{model}'"));
    }
    let labels: Vec<i32> = match req.get("labels").as_f64_vec() {
        Some(v) => v.iter().map(|&x| x as i32).collect(),
        None => return bad(c, ErrCode::BadRequest, "missing 'labels'".into()),
    };
    if labels.is_empty() {
        return bad(c, ErrCode::BadRequest, "'labels' must be non-empty".into());
    }
    let priority = match req.get("priority") {
        Json::Null => Priority::Normal,
        Json::Str(s) => match Priority::parse(s) {
            Some(p) => p,
            None => {
                return bad(
                    c,
                    ErrCode::BadRequest,
                    format!("bad 'priority' '{s}' (want high|normal|low)"),
                )
            }
        },
        _ => return bad(c, ErrCode::BadRequest, "'priority' must be a string".into()),
    };
    let deadline_ms = match req.get("deadline_ms") {
        Json::Null => cfg.default_deadline_ms,
        v => match v.as_f64().filter(|d| *d >= 0.0) {
            Some(d) => Some(d as u64),
            None => {
                return bad(c, ErrCode::BadRequest, "'deadline_ms' must be a number >= 0".into())
            }
        },
    };
    let tenant = match req.get("tenant") {
        Json::Null => None,
        Json::Str(s) => Some(s.clone()),
        _ => return bad(c, ErrCode::BadRequest, "'tenant' must be a string".into()),
    };
    let stream = req.get("stream").as_bool().unwrap_or(false);
    let guidance = req.get("guidance").as_f64().unwrap_or(0.0) as f32;
    let nfe = req.get("nfe").as_usize().unwrap_or(8);
    let solver = parse_solver_spec(req.get("solver").as_str().unwrap_or("auto"), nfe);
    let seed = req.get("seed").as_f64().unwrap_or(0.0) as u64;

    let sreq = SampleRequest {
        id: 0,
        model,
        labels,
        guidance,
        solver,
        seed,
        x0: None,
        enqueued_at: Instant::now(),
        deadline: deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms)),
        priority,
        tenant,
        progress: stream.then(|| c.prog_tx.clone()),
        reply: c.reply_tx.clone(),
    };
    match fleet.try_submit(sreq) {
        Ok(id) => {
            if let Some(t) = tag.as_ref() {
                if c.recent.len() >= RECENT_TAGS {
                    c.recent.remove(0);
                }
                c.recent.push((t.clone(), id));
            }
            c.pending.insert(id, PendingReq { stream, tag: tag.clone() });
            if stream {
                let frame = ok_frame(
                    vec![
                        ("ok", Json::Bool(true)),
                        ("frame", Json::Str("accepted".into())),
                        ("id", Json::Num(id as f64)),
                    ],
                    tag,
                );
                c.enqueue(&frame);
            }
        }
        Err((_req, e)) => {
            let frame = error_frame(&e, None, tag.as_ref());
            c.enqueue(&frame);
        }
    }
}

/// Drain engine replies into result/error frames.
fn pump_replies(c: &mut Conn) -> bool {
    let mut any = false;
    while let Ok(resp) = c.reply_rx.try_recv() {
        any = true;
        // a worker sends all progress events before its terminal reply,
        // but the two travel on separate channels: drain progress once
        // more while this request is still pending, so its last events
        // frame ahead of the result instead of being orphaned
        if c.pending.get(&resp.id).map_or(false, |p| p.stream) {
            pump_progress(c);
        }
        let Some(p) = c.pending.remove(&resp.id) else { continue };
        let frame = match resp.result {
            Ok(out) => {
                let mut pairs = vec![
                    ("ok", Json::Bool(true)),
                    ("id", Json::Num(resp.id as f64)),
                    ("dim", Json::Num(out.dim as f64)),
                    ("nfe", Json::Num(out.nfe as f64)),
                    ("forwards", Json::Num(out.forwards as f64)),
                    ("solver_used", Json::Str(out.solver_used)),
                    ("queue_us", Json::Num(out.queue_us as f64)),
                    ("exec_us", Json::Num(out.exec_us as f64)),
                    ("samples", Json::arr_f32(&out.samples)),
                ];
                if p.stream {
                    pairs.push(("frame", Json::Str("result".into())));
                }
                ok_frame(pairs, p.tag)
            }
            Err(e) => error_frame(&e, Some(resp.id), p.tag.as_ref()),
        };
        c.enqueue(&frame);
    }
    any
}

/// Drain streaming progress, coalesced to the latest event per request
/// (the reactor tick is the natural throttle).
fn pump_progress(c: &mut Conn) -> bool {
    let mut latest: Vec<Progress> = Vec::new();
    while let Ok(p) = c.prog_rx.try_recv() {
        match latest.iter_mut().find(|q| q.id == p.id) {
            Some(q) => *q = p,
            None => latest.push(p),
        }
    }
    if latest.is_empty() {
        return false;
    }
    let mut any = false;
    for p in latest {
        let Some(pd) = c.pending.get(&p.id) else { continue };
        if !pd.stream {
            continue;
        }
        any = true;
        let mut pairs = vec![
            ("ok", Json::Bool(true)),
            ("frame", Json::Str("progress".into())),
            ("id", Json::Num(p.id as f64)),
            ("evals", Json::Num(p.evals as f64)),
        ];
        if let Some(nfe) = p.nfe {
            pairs.push(("nfe", Json::Num(nfe as f64)));
        }
        let frame = ok_frame(pairs, pd.tag.clone());
        c.enqueue(&frame);
    }
    any
}

/// Flush as much of the write buffer as the socket accepts.
fn pump_write(c: &mut Conn) -> bool {
    if c.dead {
        return false;
    }
    let mut any = false;
    while c.osent < c.obuf.len() {
        match c.stream.write(&c.obuf[c.osent..]) {
            Ok(0) => {
                c.dead = true;
                break;
            }
            Ok(n) => {
                c.osent += n;
                any = true;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                c.dead = true;
                break;
            }
        }
    }
    if c.osent == c.obuf.len() {
        c.obuf.clear();
        c.osent = 0;
    } else if c.osent > (64 << 10) {
        c.obuf.drain(..c.osent);
        c.osent = 0;
    }
    any
}

/// Finish a success frame: append the client's `tag` (echoed on every
/// frame per PROTOCOL.md) and build the object.
fn ok_frame(mut pairs: Vec<(&str, Json)>, tag: Option<Json>) -> Json {
    if let Some(t) = tag {
        pairs.push(("tag", t));
    }
    Json::obj(pairs)
}

/// The documented error frame: `{"ok":false,"err":<code>,"error":<msg>}`
/// plus `retry_after_ms` for overload, `id` once one was assigned, and
/// the client's `tag` when present (PROTOCOL.md §Errors).
fn error_frame(e: &ServeError, id: Option<u64>, tag: Option<&Json>) -> Json {
    let mut pairs = vec![
        ("ok", Json::Bool(false)),
        ("err", Json::Str(e.code.as_str().into())),
        ("error", Json::Str(e.msg.clone())),
    ];
    if let Some(ms) = e.retry_after_ms {
        pairs.push(("retry_after_ms", Json::Num(ms as f64)));
    }
    if let Some(id) = id {
        pairs.push(("id", Json::Num(id as f64)));
    }
    if let Some(t) = tag {
        pairs.push(("tag", t.clone()));
    }
    Json::obj(pairs)
}
