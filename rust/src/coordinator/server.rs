//! TCP front-end: JSON-lines protocol over a listener socket.
//!
//! One JSON object per line. Requests:
//!   {"op":"sample","model":"img_fm_ot","labels":[0,3],"guidance":0.0,
//!    "solver":"auto","nfe":8,"seed":7}
//!   {"op":"stats"}
//!   {"op":"models"}
//!   {"op":"solvers"}
//! `solver` is "auto" | "gt" | a baseline name | a distilled artifact
//! name (anything containing "_nfe"). Responses mirror the request with
//! "ok": true/false; sample responses carry the flattened rows.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::Result;

use super::engine::Engine;
use super::request::{SampleRequest, SolverSpec};
use crate::runtime::ArtifactStore;
use crate::util::json::Json;

pub fn parse_solver_spec(solver: &str, nfe: usize) -> SolverSpec {
    match solver {
        "auto" => SolverSpec::Auto { nfe },
        "gt" | "rk45" => SolverSpec::GroundTruth,
        s if s.contains("_nfe") => SolverSpec::Distilled { name: s.to_string() },
        s => SolverSpec::Baseline { name: s.to_string(), nfe },
    }
}

/// Serve until the process is killed. Each connection gets a thread
/// (std-only substrate for tokio; connection counts here are small).
pub fn serve(addr: &str, engine: Arc<Engine>, store: Arc<ArtifactStore>) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("[bns-serve] listening on {addr}");
    for conn in listener.incoming() {
        let conn = match conn {
            Ok(c) => c,
            Err(e) => {
                eprintln!("[bns-serve] accept error: {e}");
                continue;
            }
        };
        let engine = engine.clone();
        let store = store.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(conn, &engine, &store) {
                eprintln!("[bns-serve] connection error: {e:#}");
            }
        });
    }
    Ok(())
}

fn handle_conn(conn: TcpStream, engine: &Engine, store: &ArtifactStore) -> Result<()> {
    let peer = conn.peer_addr()?;
    let mut writer = conn.try_clone()?;
    let reader = BufReader::new(conn);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_line(&line, engine, store);
        writer.write_all(resp.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    let _ = peer;
    Ok(())
}

pub fn handle_line(line: &str, engine: &Engine, store: &ArtifactStore) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return err_json(&format!("parse error: {e}")),
    };
    match req.get("op").as_str() {
        Some("sample") => handle_sample(&req, engine),
        Some("stats") => {
            let mut o = engine.metrics.snapshot_json();
            if let Json::Obj(map) = &mut o {
                map.insert("ok".into(), Json::Bool(true));
            }
            o
        }
        Some("models") => Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "models",
                Json::Arr(store.models.keys().map(|k| Json::Str(k.clone())).collect()),
            ),
        ]),
        Some("solvers") => Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "solvers",
                Json::Arr(
                    store
                        .solvers
                        .values()
                        .map(|s| {
                            Json::obj(vec![
                                ("name", Json::Str(s.name.clone())),
                                ("kind", Json::Str(s.meta.kind.clone())),
                                ("model", Json::Str(s.meta.model.clone())),
                                ("nfe", Json::Num(s.solver.nfe() as f64)),
                                ("guidance", Json::Num(s.meta.guidance)),
                                ("val_psnr", Json::Num(s.meta.val_psnr)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
        other => err_json(&format!("unknown op {other:?}")),
    }
}

fn handle_sample(req: &Json, engine: &Engine) -> Json {
    let model = match req.get("model").as_str() {
        Some(m) => m.to_string(),
        None => return err_json("missing 'model'"),
    };
    let labels: Vec<i32> = match req.get("labels").as_f64_vec() {
        Some(v) => v.iter().map(|&x| x as i32).collect(),
        None => return err_json("missing 'labels'"),
    };
    if labels.is_empty() {
        return err_json("'labels' must be non-empty");
    }
    let guidance = req.get("guidance").as_f64().unwrap_or(0.0) as f32;
    let nfe = req.get("nfe").as_usize().unwrap_or(8);
    let solver = parse_solver_spec(req.get("solver").as_str().unwrap_or("auto"), nfe);
    let seed = req.get("seed").as_f64().unwrap_or(0.0) as u64;

    let (reply, rx) = mpsc::channel();
    engine.submit(SampleRequest {
        id: 0,
        model,
        labels,
        guidance,
        solver,
        seed,
        x0: None,
        enqueued_at: Instant::now(),
        reply,
    });
    match rx.recv() {
        Ok(resp) => match resp.result {
            Ok(out) => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("id", Json::Num(resp.id as f64)),
                ("dim", Json::Num(out.dim as f64)),
                ("nfe", Json::Num(out.nfe as f64)),
                ("forwards", Json::Num(out.forwards as f64)),
                ("solver_used", Json::Str(out.solver_used)),
                ("queue_us", Json::Num(out.queue_us as f64)),
                ("exec_us", Json::Num(out.exec_us as f64)),
                ("samples", Json::arr_f32(&out.samples)),
            ]),
            Err(e) => err_json(&e),
        },
        Err(_) => err_json("engine dropped the request"),
    }
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.to_string()))])
}
