//! Request/response types of the sampling service.
//!
//! Everything a caller exchanges with the [`Engine`](super::Engine) lives
//! here: the solver selection ([`SolverSpec`]), the request/response pair
//! ([`SampleRequest`], [`SampleResponse`]), scheduling hints
//! ([`Priority`], deadlines), streaming progress events ([`Progress`]),
//! and the structured error vocabulary ([`ServeError`], [`ErrCode`]) that
//! the wire protocol (PROTOCOL.md) exposes verbatim as `err` codes.

use std::sync::mpsc;
use std::time::Instant;

/// How the client wants the ODE solved.
#[derive(Debug, Clone, PartialEq)]
pub enum SolverSpec {
    /// A named baseline at a given NFE ("euler", "midpoint", "dpmpp2m", ...).
    Baseline {
        /// Baseline solver name as understood by `solver::baseline`.
        name: String,
        /// Number of velocity-field evaluations.
        nfe: usize,
    },
    /// A distilled solver artifact by exact name.
    Distilled {
        /// Artifact name in the store's manifest.
        name: String,
    },
    /// Router picks the best available solver for (model, guidance, nfe):
    /// BNS artifact if distilled, otherwise the strongest baseline.
    Auto {
        /// Number of velocity-field evaluations.
        nfe: usize,
    },
    /// Ground truth: adaptive RK45 (NFE not fixed).
    GroundTruth,
}

impl SolverSpec {
    /// Stable key for batching: requests with equal keys share an
    /// identical step timeline and can run lockstep.
    pub fn group_key(&self) -> String {
        match self {
            SolverSpec::Baseline { name, nfe } => format!("b:{name}:{nfe}"),
            SolverSpec::Distilled { name } => format!("d:{name}"),
            SolverSpec::Auto { nfe } => format!("a:{nfe}"),
            SolverSpec::GroundTruth => "gt".to_string(),
        }
    }
}

/// Scheduling priority of a request.
///
/// Priorities order *dispatch*, not numerics: batches carrying
/// higher-priority requests are popped from the engine's work queue
/// first, but batching itself still groups purely by step timeline
/// (mixing priorities inside one batch is allowed — the batch runs at
/// the highest priority it contains). Declaration order makes
/// `High < Normal < Low` under `Ord`, so `min()` picks the *most*
/// urgent — use [`Priority::rank`] when an explicit index is clearer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// Dispatched before everything else (interactive traffic).
    High,
    /// The default.
    #[default]
    Normal,
    /// Dispatched only when no higher-priority work is queued (bulk /
    /// offline traffic).
    Low,
}

impl Priority {
    /// Queue index: 0 = high, 1 = normal, 2 = low.
    pub fn rank(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }

    /// Wire-protocol name (`"high"` / `"normal"` / `"low"`).
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }

    /// Parse a wire-protocol priority name; `None` for anything else.
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            "low" => Some(Priority::Low),
            _ => None,
        }
    }
}

/// Machine-readable error code, surfaced verbatim as the `err` field of
/// wire-protocol error responses (see PROTOCOL.md §Errors).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrCode {
    /// The request line was not valid JSON.
    ParseError,
    /// The `op` field named no known operation.
    UnknownOp,
    /// A required field was missing or had the wrong type/value.
    BadRequest,
    /// The named model is not in the artifact store.
    UnknownModel,
    /// A request line exceeded the server's line-length cap.
    LineTooLong,
    /// Admission control rejected the request (in-flight row budget or
    /// queue bound exceeded). Retry after `retry_after_ms`.
    Overloaded,
    /// The request's deadline passed before execution started.
    DeadlineExceeded,
    /// Execution failed after admission (solver/runtime error).
    Internal,
    /// The model's circuit breaker is open after repeated execution
    /// failures; the service refuses new work for it until a half-open
    /// probe succeeds. Retry after `retry_after_ms`.
    Unavailable,
    /// The request's tenant exceeded its weighted-fair queue quota while
    /// other tenants still have headroom. Retry after `retry_after_ms`
    /// (or shed load on the tenant's side).
    QuotaExceeded,
}

impl ErrCode {
    /// Wire-protocol code string (e.g. `"overloaded"`).
    pub fn as_str(self) -> &'static str {
        match self {
            ErrCode::ParseError => "parse_error",
            ErrCode::UnknownOp => "unknown_op",
            ErrCode::BadRequest => "bad_request",
            ErrCode::UnknownModel => "unknown_model",
            ErrCode::LineTooLong => "line_too_long",
            ErrCode::Overloaded => "overloaded",
            ErrCode::DeadlineExceeded => "deadline_exceeded",
            ErrCode::Internal => "internal",
            ErrCode::Unavailable => "unavailable",
            ErrCode::QuotaExceeded => "quota_exceeded",
        }
    }
}

/// A structured service error: a machine-readable code plus a human
/// message, and (for overload rejects) a retry hint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeError {
    /// What went wrong, as a wire-stable code.
    pub code: ErrCode,
    /// Human-readable detail.
    pub msg: String,
    /// For [`ErrCode::Overloaded`] / [`ErrCode::Unavailable`]: suggested
    /// client backoff before retrying — derived from recent execution
    /// latency (overload) or the breaker's remaining cooldown
    /// (unavailable).
    pub retry_after_ms: Option<u64>,
}

impl ServeError {
    /// A plain error with no retry hint.
    pub fn new(code: ErrCode, msg: impl Into<String>) -> ServeError {
        ServeError { code, msg: msg.into(), retry_after_ms: None }
    }

    /// An admission reject carrying a backoff hint.
    pub fn overloaded(msg: impl Into<String>, retry_after_ms: u64) -> ServeError {
        ServeError {
            code: ErrCode::Overloaded,
            msg: msg.into(),
            retry_after_ms: Some(retry_after_ms),
        }
    }

    /// A circuit-breaker reject carrying a backoff hint (the time left
    /// until the breaker's next half-open probe).
    pub fn unavailable(msg: impl Into<String>, retry_after_ms: u64) -> ServeError {
        ServeError {
            code: ErrCode::Unavailable,
            msg: msg.into(),
            retry_after_ms: Some(retry_after_ms),
        }
    }

    /// A weighted-fair tenancy reject carrying a backoff hint.
    pub fn quota_exceeded(msg: impl Into<String>, retry_after_ms: u64) -> ServeError {
        ServeError {
            code: ErrCode::QuotaExceeded,
            msg: msg.into(),
            retry_after_ms: Some(retry_after_ms),
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} ({})", self.msg, self.code.as_str())
    }
}

impl std::error::Error for ServeError {}

/// A streaming progress event: sent after each velocity-field evaluation
/// of a batch containing this request, when the request asked for
/// streaming (`SampleRequest::progress`). Delivery is best-effort —
/// consumers coalesce to the latest event per request.
#[derive(Debug, Clone, Copy)]
pub struct Progress {
    /// Engine-assigned request id.
    pub id: u64,
    /// Velocity-field evaluations completed so far for this batch.
    pub evals: usize,
    /// Planned total evaluations (`None` for adaptive ground truth).
    pub nfe: Option<usize>,
}

/// A sampling request: generate `labels.len()` samples from `model`
/// conditioned on `labels` with CFG scale `guidance`.
#[derive(Debug)]
pub struct SampleRequest {
    /// Engine-assigned id (overwritten by `submit`; callers pass 0).
    pub id: u64,
    /// Model name in the artifact store.
    pub model: String,
    /// Per-row class labels; one output row per label.
    pub labels: Vec<i32>,
    /// CFG guidance scale.
    pub guidance: f32,
    /// Solver selection (see [`SolverSpec`]).
    pub solver: SolverSpec,
    /// Noise seed; x0 is drawn as iid N(0, 1) from this seed so results
    /// are reproducible and the wire format stays small.
    pub seed: u64,
    /// Optional explicit x0 (overrides seed); row-major [n, dim].
    pub x0: Option<Vec<f32>>,
    /// When the request entered the service (for queue-latency metrics).
    pub enqueued_at: Instant,
    /// Absolute deadline: if the request is still queued when this
    /// passes, it is shed with [`ErrCode::DeadlineExceeded`] instead of
    /// executing. A request already running when its deadline passes
    /// completes and delivers (late) — deadlines govern queueing, not
    /// preemption.
    pub deadline: Option<Instant>,
    /// Dispatch priority (see [`Priority`]).
    pub priority: Priority,
    /// Tenant the request is billed to under weighted-fair scheduling.
    /// `None` means the anonymous default tenant (weight 1, shared
    /// queue-bound quota). See DESIGN.md §14.
    pub tenant: Option<String>,
    /// When set, the executing worker streams [`Progress`] events here
    /// (one per velocity-field evaluation of the batch).
    pub progress: Option<mpsc::Sender<Progress>>,
    /// Where the terminal [`SampleResponse`] is delivered.
    pub reply: mpsc::Sender<SampleResponse>,
}

/// The service's answer.
#[derive(Debug, Clone)]
pub struct SampleResponse {
    /// Engine-assigned request id (matches the `submit` return value).
    pub id: u64,
    /// Samples on success, a structured error otherwise.
    pub result: Result<SampleOutput, ServeError>,
}

/// A successful sampling result.
#[derive(Debug, Clone)]
pub struct SampleOutput {
    /// Row-major [n, dim] samples (approximations of x(1)).
    pub samples: Vec<f32>,
    /// Elements per row.
    pub dim: usize,
    /// Velocity-field evaluations the solver performed.
    pub nfe: usize,
    /// Model forward passes (NFE x batch x CFG factor).
    pub forwards: usize,
    /// Name of the solver actually used (after routing).
    pub solver_used: String,
    /// Microseconds spent queued before execution started.
    pub queue_us: u64,
    /// Microseconds spent executing the batch.
    pub exec_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_rank_and_roundtrip() {
        assert!(Priority::High < Priority::Normal && Priority::Normal < Priority::Low);
        for p in [Priority::High, Priority::Normal, Priority::Low] {
            assert_eq!(Priority::parse(p.as_str()), Some(p));
        }
        assert_eq!(Priority::parse("urgent"), None);
        assert_eq!(Priority::default(), Priority::Normal);
        assert_eq!(Priority::High.rank(), 0);
        assert_eq!(Priority::Low.rank(), 2);
    }

    #[test]
    fn serve_error_display_carries_code() {
        let e = ServeError::overloaded("queue full", 25);
        assert_eq!(e.retry_after_ms, Some(25));
        let s = e.to_string();
        assert!(s.contains("queue full") && s.contains("overloaded"), "{s}");
    }
}
