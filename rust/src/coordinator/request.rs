//! Request/response types of the sampling service.

use std::sync::mpsc;
use std::time::Instant;

/// How the client wants the ODE solved.
#[derive(Debug, Clone, PartialEq)]
pub enum SolverSpec {
    /// A named baseline at a given NFE ("euler", "midpoint", "dpmpp2m", ...).
    Baseline { name: String, nfe: usize },
    /// A distilled solver artifact by exact name.
    Distilled { name: String },
    /// Router picks the best available solver for (model, guidance, nfe):
    /// BNS artifact if distilled, otherwise the strongest baseline.
    Auto { nfe: usize },
    /// Ground truth: adaptive RK45 (NFE not fixed).
    GroundTruth,
}

impl SolverSpec {
    /// Stable key for batching: requests with equal keys share an
    /// identical step timeline and can run lockstep.
    pub fn group_key(&self) -> String {
        match self {
            SolverSpec::Baseline { name, nfe } => format!("b:{name}:{nfe}"),
            SolverSpec::Distilled { name } => format!("d:{name}"),
            SolverSpec::Auto { nfe } => format!("a:{nfe}"),
            SolverSpec::GroundTruth => "gt".to_string(),
        }
    }
}

/// A sampling request: generate `labels.len()` samples from `model`
/// conditioned on `labels` with CFG scale `guidance`.
#[derive(Debug)]
pub struct SampleRequest {
    pub id: u64,
    pub model: String,
    pub labels: Vec<i32>,
    pub guidance: f32,
    pub solver: SolverSpec,
    /// Noise seed; x0 is drawn as iid N(0, 1) from this seed so results
    /// are reproducible and the wire format stays small.
    pub seed: u64,
    /// Optional explicit x0 (overrides seed); row-major [n, dim].
    pub x0: Option<Vec<f32>>,
    pub enqueued_at: Instant,
    pub reply: mpsc::Sender<SampleResponse>,
}

/// The service's answer.
#[derive(Debug, Clone)]
pub struct SampleResponse {
    pub id: u64,
    pub result: Result<SampleOutput, String>,
}

#[derive(Debug, Clone)]
pub struct SampleOutput {
    /// Row-major [n, dim] samples (approximations of x(1)).
    pub samples: Vec<f32>,
    pub dim: usize,
    /// Velocity-field evaluations the solver performed.
    pub nfe: usize,
    /// Model forward passes (NFE x batch x CFG factor).
    pub forwards: usize,
    /// Name of the solver actually used (after routing).
    pub solver_used: String,
    pub queue_us: u64,
    pub exec_us: u64,
}

/// Admission-control errors surfaced to clients.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmitError {
    QueueFull,
    UnknownModel(String),
    BadRequest(String),
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::QueueFull => write!(f, "queue full (backpressure)"),
            AdmitError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            AdmitError::BadRequest(m) => write!(f, "bad request: {m}"),
        }
    }
}
