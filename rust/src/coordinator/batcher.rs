//! Dynamic batcher with step-aligned grouping.
//!
//! Diffusion sampling differs from token serving in one key way: the
//! model input carries a *scalar* time t shared by the whole batch, so
//! two requests can share a model evaluation only if their solvers put
//! them at the same t at the same step. The batcher therefore groups
//! requests by `GroupKey = (model, solver group key, guidance)` — within
//! a group every request follows the identical step timeline, so the
//! whole group runs lockstep and every velocity evaluation batches all
//! of its rows (the ODE-sampling analogue of continuous batching; see
//! DESIGN.md §4, vllm_router analogy).
//!
//! Flush policy: a group is dispatched when (a) its pending rows reach
//! `max_rows`, or (b) its oldest request has waited `max_wait`. Both are
//! checked by `poll`, which the engine's dispatch loop drives. Before
//! polling, the dispatch loop calls [`Batcher::shed_expired`] so work
//! whose deadline already passed never reaches a worker (DESIGN.md §9).
//!
//! Priorities do not affect grouping (a group may mix them — the batch
//! runs at the most urgent priority it contains); they order dispatch in
//! the engine's work queue.
//!
//! Tenancy: requests carry an optional tenant. When the grouped stage is
//! at its `max_queued_rows` bound, requests from tenants with a parking
//! quota wait in per-tenant FIFO queues instead of being rejected, and a
//! deficit-weighted round-robin ([`Batcher::promote`], DESIGN.md §14)
//! moves parked work into groups as capacity frees — so under contention
//! tenants receive grouped-stage rows in proportion to their configured
//! weights. A tenant over its parking quota gets a structured
//! [`RejectKind::Quota`] reject; the anonymous tenant (no `tenant`
//! field, quota 0 by default) keeps the pre-tenancy behavior of an
//! immediate capacity reject.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use super::request::{Priority, SampleRequest};

/// Batching identity: requests with equal keys share a step timeline.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GroupKey {
    /// Model name.
    pub model: String,
    /// `SolverSpec::group_key()` of the request's solver.
    pub solver_key: String,
    /// Guidance scale in fixed-point (f32 bits) so the key is Ord/Eq.
    pub guidance_bits: u32,
}

impl GroupKey {
    /// The group key a request batches under.
    pub fn of(req: &SampleRequest) -> GroupKey {
        GroupKey {
            model: req.model.clone(),
            solver_key: req.solver.group_key(),
            guidance_bits: req.guidance.to_bits(),
        }
    }
}

/// A batch ready for execution: requests share a group key.
pub struct Batch {
    /// Shared batching identity of every request inside.
    pub key: GroupKey,
    /// The member requests, FIFO within the group.
    pub requests: Vec<SampleRequest>,
    /// Total sample rows across `requests`.
    pub rows: usize,
    /// Most urgent priority among the member requests; orders the batch
    /// in the engine's work queue.
    pub priority: Priority,
    /// When `poll` closed the batch — the tracing plane measures
    /// form-to-worker-pop dispatch latency from this (DESIGN.md §12).
    pub formed_at: Instant,
}

/// Per-tenant weighted-fair scheduling knobs for one tenant.
#[derive(Debug, Clone, Copy)]
pub struct TenantSpec {
    /// Relative share of grouped-stage rows under contention (≥ 1; 0 is
    /// treated as 1).
    pub weight: u32,
    /// Upper bound on this tenant's parked backlog, in rows. 0 disables
    /// parking: over-capacity pushes reject immediately.
    pub quota_rows: usize,
}

/// Fleet-wide tenancy policy: named tenant specs plus the defaults
/// applied to tenants (including the anonymous one) not listed.
#[derive(Debug, Clone)]
pub struct TenantPolicy {
    /// Weight for tenants without an explicit [`TenantSpec`].
    pub default_weight: u32,
    /// Parking quota (rows) for tenants without an explicit spec. The
    /// default of 0 preserves pre-tenancy semantics: no parking, rejects
    /// at the queue bound.
    pub default_quota_rows: usize,
    /// Explicit per-tenant overrides, keyed by tenant name.
    pub tenants: BTreeMap<String, TenantSpec>,
}

impl Default for TenantPolicy {
    fn default() -> Self {
        TenantPolicy { default_weight: 1, default_quota_rows: 0, tenants: BTreeMap::new() }
    }
}

impl TenantPolicy {
    fn spec_for(&self, tenant: &str) -> TenantSpec {
        match self.tenants.get(tenant) {
            Some(s) => TenantSpec { weight: s.weight.max(1), quota_rows: s.quota_rows },
            None => TenantSpec {
                weight: self.default_weight.max(1),
                quota_rows: self.default_quota_rows,
            },
        }
    }
}

/// Flush/backpressure policy knobs.
#[derive(Clone)]
pub struct BatcherConfig {
    /// Dispatch a group once its pending rows reach this.
    pub max_rows: usize,
    /// Dispatch a group once its oldest request has waited this long.
    pub max_wait: Duration,
    /// Upper bound on grouped rows across all groups (admission control).
    pub max_queued_rows: usize,
    /// Weighted-fair tenancy policy (see [`TenantPolicy`]).
    pub tenants: TenantPolicy,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_rows: 64,
            max_wait: Duration::from_millis(5),
            max_queued_rows: 4096,
            tenants: TenantPolicy::default(),
        }
    }
}

#[derive(Default)]
struct Group {
    requests: Vec<SampleRequest>,
    rows: usize,
    oldest: Option<Instant>,
}

/// Where [`Batcher::push`] put an admitted request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushOutcome {
    /// Joined a batch group directly; eligible for the next flush.
    Grouped,
    /// Parked in its tenant's queue; promoted to a group under
    /// deficit-weighted round-robin as grouped capacity frees.
    Parked,
}

/// Why [`Batcher::push`] rejected a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectKind {
    /// The grouped stage is at `max_queued_rows` and the tenant has no
    /// parking quota (maps to the wire `overloaded` error).
    Capacity,
    /// The tenant's parked backlog would exceed its `quota_rows` (maps
    /// to the wire `quota_exceeded` error).
    Quota,
}

/// A rejected push: the request handed back plus the reject reason.
#[derive(Debug)]
pub struct PushReject {
    /// The request, returned so the caller can reply to it.
    pub req: SampleRequest,
    /// Why it was rejected.
    pub kind: RejectKind,
}

/// Deficit round-robin: rows of grouped-stage credit added per unit of
/// tenant weight each time a tenant reaches the rotation front without
/// enough deficit to promote its head request.
const DRR_QUANTUM: usize = 8;

struct ParkedTenant {
    name: String,
    q: VecDeque<SampleRequest>,
    rows: usize,
    deficit: usize,
    weight: u32,
    quota: usize,
}

/// Single-threaded core (the engine's dispatch thread owns it): push
/// requests, shed expired ones, poll for due batches.
pub struct Batcher {
    /// Policy knobs (public so the dispatch loop can read them).
    pub cfg: BatcherConfig,
    groups: BTreeMap<GroupKey, Group>,
    /// Rows currently inside `groups` (bounded by `max_queued_rows`).
    grouped_rows: usize,
    /// Rows currently parked across all tenant queues.
    parked_rows: usize,
    /// Tenant parking slots; a tenant keeps its slot (and its DRR
    /// bookkeeping) for the batcher's lifetime. Indexed by `order`.
    parked: Vec<ParkedTenant>,
    /// DRR rotation over `parked` indices with non-empty queues.
    order: VecDeque<usize>,
    /// Queued requests carrying a deadline — grouped *and* parked. When
    /// 0 (the common case — deadlines are opt-in), `shed_expired` and
    /// `next_wake` skip their per-request scans entirely.
    deadlined: usize,
}

impl Batcher {
    /// A batcher with the given policy and no queued work.
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher {
            cfg,
            groups: BTreeMap::new(),
            grouped_rows: 0,
            parked_rows: 0,
            parked: Vec::new(),
            order: VecDeque::new(),
            deadlined: 0,
        }
    }

    /// Rows currently queued: grouped plus parked.
    pub fn queued_rows(&self) -> usize {
        self.grouped_rows + self.parked_rows
    }

    /// Rows currently parked across all tenant queues.
    pub fn parked_rows(&self) -> usize {
        self.parked_rows
    }

    /// Per-tenant parked backlog, for metrics: (tenant, parked rows).
    pub fn parked_by_tenant(&self) -> impl Iterator<Item = (&str, usize)> {
        self.parked.iter().filter(|t| t.rows > 0).map(|t| (t.name.as_str(), t.rows))
    }

    fn tenant_slot(&mut self, name: &str) -> usize {
        if let Some(i) = self.parked.iter().position(|t| t.name == name) {
            return i;
        }
        let spec = self.cfg.tenants.spec_for(name);
        self.parked.push(ParkedTenant {
            name: name.to_string(),
            q: VecDeque::new(),
            rows: 0,
            deficit: 0,
            weight: spec.weight,
            quota: spec.quota_rows,
        });
        self.parked.len() - 1
    }

    fn group_insert(
        groups: &mut BTreeMap<GroupKey, Group>,
        grouped_rows: &mut usize,
        req: SampleRequest,
    ) {
        let rows = req.labels.len();
        let key = GroupKey::of(&req);
        let g = groups.entry(key).or_default();
        g.oldest = Some(g.oldest.map_or(req.enqueued_at, |o| o.min(req.enqueued_at)));
        g.rows += rows;
        *grouped_rows += rows;
        g.requests.push(req);
    }

    /// Enqueue. Grouped directly when the tenant has no parked backlog
    /// and the grouped stage has room; parked behind the tenant's queue
    /// (FIFO per tenant) otherwise, up to the tenant's quota; rejected
    /// with a [`RejectKind`] past that.
    pub fn push(&mut self, req: SampleRequest) -> Result<PushOutcome, PushReject> {
        let rows = req.labels.len();
        let slot = self.tenant_slot(req.tenant.as_deref().unwrap_or(""));
        let direct = self.parked[slot].q.is_empty()
            && self.grouped_rows + rows <= self.cfg.max_queued_rows;
        if direct {
            if req.deadline.is_some() {
                self.deadlined += 1;
            }
            Self::group_insert(&mut self.groups, &mut self.grouped_rows, req);
            return Ok(PushOutcome::Grouped);
        }
        let t = &mut self.parked[slot];
        if t.rows + rows > t.quota {
            let kind = if t.quota == 0 { RejectKind::Capacity } else { RejectKind::Quota };
            return Err(PushReject { req, kind });
        }
        if req.deadline.is_some() {
            self.deadlined += 1;
        }
        t.rows += rows;
        self.parked_rows += rows;
        if t.q.is_empty() {
            self.order.push_back(slot);
        }
        t.q.push_back(req);
        Ok(PushOutcome::Parked)
    }

    /// Deficit round-robin pick: index of the tenant whose head request
    /// may be promoted now. Rotates the order, charging each fronted
    /// tenant `weight * DRR_QUANTUM` rows of deficit, until one can
    /// afford its head. `None` when nothing is parked.
    pub fn next_tenant(&mut self) -> Option<usize> {
        loop {
            let i = *self.order.front()?;
            let head_rows = match self.parked[i].q.front() {
                Some(r) => r.labels.len(),
                None => {
                    self.order.pop_front();
                    continue;
                }
            };
            if self.parked[i].deficit >= head_rows {
                return Some(i);
            }
            self.parked[i].deficit += self.parked[i].weight as usize * DRR_QUANTUM;
            self.order.rotate_left(1);
        }
    }

    /// Move parked requests into groups while the grouped stage has
    /// room, in deficit-weighted round-robin order across tenants. The
    /// dispatch loop runs this at the top of every `poll`.
    fn promote(&mut self) {
        while self.parked_rows > 0 {
            let Some(i) = self.next_tenant() else { break };
            let head_rows = match self.parked[i].q.front() {
                Some(r) => r.labels.len(),
                None => continue,
            };
            // An oversized head still promotes into an empty grouped
            // stage (mirroring the oversized-request dispatch rule) so
            // it can never wedge its tenant's queue.
            if self.grouped_rows > 0 && self.grouped_rows + head_rows > self.cfg.max_queued_rows {
                break;
            }
            let Some(req) = self.parked[i].q.pop_front() else { continue };
            let t = &mut self.parked[i];
            t.rows -= head_rows;
            t.deficit -= head_rows;
            self.parked_rows -= head_rows;
            if t.q.is_empty() {
                t.deficit = 0; // no hoarding credit across idle periods
                if let Some(pos) = self.order.iter().position(|&j| j == i) {
                    self.order.remove(pos);
                }
            }
            Self::group_insert(&mut self.groups, &mut self.grouped_rows, req);
        }
    }

    /// Remove and return every queued request whose deadline is at or
    /// before `now` — parked requests included, so work stuck behind a
    /// full grouped stage still sheds on time. The caller replies
    /// `deadline_exceeded` to each. Groups left empty are dropped;
    /// surviving groups keep FIFO order and recompute their flush clock
    /// from the oldest survivor.
    pub fn shed_expired(&mut self, now: Instant) -> Vec<SampleRequest> {
        if self.deadlined == 0 {
            return Vec::new(); // nothing queued carries a deadline
        }
        let expired = |r: &SampleRequest| r.deadline.map_or(false, |d| d <= now);
        let mut shed = Vec::new();
        let mut emptied: Vec<GroupKey> = Vec::new();
        for (key, g) in self.groups.iter_mut() {
            if !g.requests.iter().any(expired) {
                continue; // common case: nothing to shed, no rebuild
            }
            let mut kept = Vec::with_capacity(g.requests.len());
            for req in g.requests.drain(..) {
                if expired(&req) {
                    let rows = req.labels.len();
                    g.rows -= rows;
                    self.grouped_rows -= rows;
                    self.deadlined -= 1;
                    shed.push(req);
                } else {
                    kept.push(req);
                }
            }
            g.requests = kept;
            g.oldest = g.requests.iter().map(|r| r.enqueued_at).min();
            if g.requests.is_empty() {
                emptied.push(key.clone());
            }
        }
        for key in emptied {
            self.groups.remove(&key);
        }
        for i in 0..self.parked.len() {
            if !self.parked[i].q.iter().any(expired) {
                continue;
            }
            let mut kept: VecDeque<SampleRequest> =
                VecDeque::with_capacity(self.parked[i].q.len());
            while let Some(req) = self.parked[i].q.pop_front() {
                if expired(&req) {
                    let rows = req.labels.len();
                    self.parked[i].rows -= rows;
                    self.parked_rows -= rows;
                    self.deadlined -= 1;
                    shed.push(req);
                } else {
                    kept.push_back(req);
                }
            }
            self.parked[i].q = kept;
            if self.parked[i].q.is_empty() {
                self.parked[i].deficit = 0;
                if let Some(pos) = self.order.iter().position(|&j| j == i) {
                    self.order.remove(pos);
                }
            }
        }
        shed
    }

    /// Collect every group due for dispatch at `now`. Promotes parked
    /// work first, so freed grouped capacity refills before the due
    /// check. Groups larger than `max_rows` are split so no batch
    /// exceeds the cap (a single request larger than the cap still
    /// dispatches alone — the runtime chunks it over buckets).
    pub fn poll(&mut self, now: Instant) -> Vec<Batch> {
        if self.parked_rows > 0 {
            self.promote();
        }
        // First pass borrows the map read-only and clones a key only for
        // groups actually due — the common idle tick (nothing due) walks
        // the map without a single heap allocation. (The seed cloned
        // every key — three allocations per group — on every tick.)
        let mut due_keys: Vec<GroupKey> = Vec::new(); // bns-lint: allow(hot_path_alloc) — Vec::new is allocation-free until pushed; pushes happen only for groups actually due
        for (key, g) in &self.groups {
            let timed_out = g
                .oldest
                .map(|t| now.duration_since(t) >= self.cfg.max_wait)
                .unwrap_or(false);
            if g.rows >= self.cfg.max_rows || timed_out {
                due_keys.push(key.clone()); // bns-lint: allow(hot_path_alloc) — clones a key only for a due group; the idle tick never reaches this line
            }
        }
        let mut due = Vec::new(); // bns-lint: allow(hot_path_alloc) — Vec::new is allocation-free until pushed; grows only when batches actually dispatch
        for key in due_keys {
            // a key collected above is still present (nothing else
            // mutates the map between the passes); tolerate its absence
            // rather than panicking the dispatch thread
            let Some(g) = self.groups.remove(&key) else { continue };
            self.grouped_rows -= g.rows;
            // split into <= max_rows chunks preserving FIFO order; the
            // chunk priority is the most urgent (min-ranked) it contains
            let mut cur = Batch {
                key: key.clone(), // bns-lint: allow(hot_path_alloc) — per-dispatched-batch construction; the idle tick allocates nothing (serve_load measures the tick)
                requests: Vec::new(),
                rows: 0,
                priority: Priority::Low,
                formed_at: now,
            };
            for req in g.requests {
                let r = req.labels.len();
                if req.deadline.is_some() {
                    self.deadlined -= 1;
                }
                if cur.rows > 0 && cur.rows + r > self.cfg.max_rows {
                    due.push(std::mem::replace(
                        &mut cur,
                        Batch {
                            key: key.clone(), // bns-lint: allow(hot_path_alloc) — per-split-batch construction on the dispatch path; never runs on the idle tick
                            requests: Vec::new(),
                            rows: 0,
                            priority: Priority::Low,
                            formed_at: now,
                        },
                    ));
                }
                cur.rows += r;
                cur.priority = cur.priority.min(req.priority);
                cur.requests.push(req);
            }
            if cur.rows > 0 {
                due.push(cur);
            }
        }
        // dispatch freed grouped capacity: refill from parked queues now
        // so promoted work rides the very next flush
        if !due.is_empty() && self.parked_rows > 0 {
            self.promote();
        }
        due
    }

    /// Earliest flush deadline across groups (oldest request + max_wait).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.groups
            .values()
            .filter_map(|g| g.oldest)
            .min()
            .map(|t| t + self.cfg.max_wait)
    }

    /// Earliest instant at which the dispatch loop must act: the sooner
    /// of the next flush deadline and the earliest queued request
    /// deadline — across grouped *and* parked requests, so a request
    /// stuck behind a full grouped stage still sheds at its deadline
    /// instead of waiting for the next flush.
    pub fn next_wake(&self) -> Option<Instant> {
        let flush = self.next_deadline();
        if self.deadlined == 0 {
            return flush; // common case: no queued deadline to track
        }
        let expiry = self
            .groups
            .values()
            .flat_map(|g| g.requests.iter().filter_map(|r| r.deadline))
            .chain(
                self.parked
                    .iter()
                    .flat_map(|t| t.q.iter().filter_map(|r| r.deadline)),
            )
            .min();
        match (flush, expiry) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{SampleRequest, SolverSpec};
    use std::sync::mpsc;

    fn req(model: &str, n: usize, solver: SolverSpec, w: f32) -> SampleRequest {
        let (tx, _rx) = mpsc::channel();
        SampleRequest {
            id: 0,
            model: model.into(),
            labels: vec![0; n],
            guidance: w,
            solver,
            seed: 1,
            x0: None,
            enqueued_at: Instant::now(),
            deadline: None,
            priority: Priority::Normal,
            tenant: None,
            progress: None,
            reply: tx,
        }
    }

    fn treq(tenant: &str, model: &str, n: usize) -> SampleRequest {
        let mut r = req(model, n, spec(8), 0.0);
        r.tenant = Some(tenant.to_string());
        r
    }

    fn spec(nfe: usize) -> SolverSpec {
        SolverSpec::Baseline { name: "euler".into(), nfe }
    }

    fn policy(specs: &[(&str, u32, usize)]) -> TenantPolicy {
        let mut p = TenantPolicy::default();
        for &(name, weight, quota_rows) in specs {
            p.tenants.insert(name.to_string(), TenantSpec { weight, quota_rows });
        }
        p
    }

    #[test]
    fn groups_by_key() {
        let mut b = Batcher::new(BatcherConfig { max_rows: 8, ..Default::default() });
        b.push(req("m1", 4, spec(8), 0.0)).unwrap();
        b.push(req("m1", 4, spec(8), 0.0)).unwrap(); // same group: flush at 8
        b.push(req("m2", 2, spec(8), 0.0)).unwrap(); // different model
        let due = b.poll(Instant::now());
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].rows, 8);
        assert_eq!(due[0].key.model, "m1");
        assert_eq!(b.queued_rows(), 2);
    }

    #[test]
    fn different_guidance_not_batched() {
        let mut b = Batcher::new(BatcherConfig { max_rows: 4, ..Default::default() });
        b.push(req("m", 2, spec(8), 0.0)).unwrap();
        b.push(req("m", 2, spec(8), 2.0)).unwrap();
        assert!(b.poll(Instant::now()).is_empty()); // neither group full
    }

    #[test]
    fn timeout_flushes_partial() {
        let mut b = Batcher::new(BatcherConfig {
            max_rows: 64,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        b.push(req("m", 3, spec(8), 0.0)).unwrap();
        assert!(b.poll(Instant::now()).is_empty());
        let later = Instant::now() + Duration::from_millis(5);
        let due = b.poll(later);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].rows, 3);
        assert_eq!(b.queued_rows(), 0);
    }

    #[test]
    fn splits_over_cap_preserving_fifo() {
        let mut b = Batcher::new(BatcherConfig { max_rows: 4, ..Default::default() });
        for i in 0..5 {
            let mut r = req("m", 2, spec(8), 0.0);
            r.id = i;
            b.push(r).unwrap();
        }
        let due = b.poll(Instant::now());
        assert_eq!(due.len(), 3); // 2+2, 2+2, 2
        let ids: Vec<u64> = due.iter().flat_map(|d| d.requests.iter().map(|r| r.id)).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        assert!(due.iter().all(|d| d.rows <= 4));
    }

    #[test]
    fn backpressure_rejects() {
        let mut b = Batcher::new(BatcherConfig { max_queued_rows: 4, ..Default::default() });
        b.push(req("m", 3, spec(8), 0.0)).unwrap();
        let err = b.push(req("m", 3, spec(8), 0.0)).unwrap_err();
        assert_eq!(err.kind, RejectKind::Capacity);
        assert_eq!(b.queued_rows(), 3);
    }

    #[test]
    fn oversized_request_dispatches_alone() {
        let mut b = Batcher::new(BatcherConfig { max_rows: 4, ..Default::default() });
        b.push(req("m", 10, spec(8), 0.0)).unwrap();
        let due = b.poll(Instant::now());
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].rows, 10);
    }

    #[test]
    fn shed_expired_removes_only_expired_and_rebalances() {
        let mut b = Batcher::new(BatcherConfig {
            max_rows: 64,
            max_wait: Duration::from_secs(3600),
            ..Default::default()
        });
        let now = Instant::now();
        let mut dead = req("m", 3, spec(8), 0.0);
        dead.id = 1;
        dead.deadline = Some(now); // expired at `now`
        let mut live = req("m", 2, spec(8), 0.0);
        live.id = 2;
        live.deadline = Some(now + Duration::from_secs(60));
        b.push(dead).unwrap();
        b.push(live).unwrap();
        assert_eq!(b.queued_rows(), 5);

        let shed = b.shed_expired(now);
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].id, 1);
        assert_eq!(b.queued_rows(), 2, "only the live request remains");

        // survivor still flushes (rows/oldest bookkeeping intact)
        let due = b.poll(now + Duration::from_secs(7200));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].requests[0].id, 2);
        assert_eq!(b.queued_rows(), 0);
    }

    #[test]
    fn shed_expired_drops_emptied_groups() {
        let mut b = Batcher::new(BatcherConfig::default());
        let now = Instant::now();
        let mut r = req("m", 4, spec(8), 0.0);
        r.deadline = Some(now);
        b.push(r).unwrap();
        assert_eq!(b.shed_expired(now).len(), 1);
        assert_eq!(b.queued_rows(), 0);
        assert!(b.next_wake().is_none(), "emptied group must not leave a wake time");
    }

    #[test]
    fn next_wake_is_min_of_flush_and_request_deadline() {
        let mut b = Batcher::new(BatcherConfig {
            max_rows: 64,
            max_wait: Duration::from_secs(10),
            ..Default::default()
        });
        assert!(b.next_wake().is_none());
        let now = Instant::now();
        let mut r = req("m", 2, spec(8), 0.0);
        r.deadline = Some(now + Duration::from_millis(50));
        b.push(r).unwrap();
        // request deadline (50ms) is sooner than the flush (10s)
        let wake = b.next_wake().unwrap();
        assert!(wake < now + Duration::from_secs(1), "wake should track the deadline");
        assert!(b.next_deadline().unwrap() > wake);
    }

    #[test]
    fn batch_priority_is_most_urgent_member() {
        let mut b = Batcher::new(BatcherConfig { max_rows: 8, ..Default::default() });
        let mut low = req("m", 2, spec(8), 0.0);
        low.priority = Priority::Low;
        let mut high = req("m", 2, spec(8), 0.0);
        high.priority = Priority::High;
        b.push(low).unwrap();
        b.push(high).unwrap();
        let due = b.poll(Instant::now() + Duration::from_secs(1));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].priority, Priority::High);
        assert_eq!(due[0].requests.len(), 2, "priorities do not split the batch");
    }

    #[test]
    fn tenant_parks_past_capacity_and_promotes_after_drain() {
        let mut b = Batcher::new(BatcherConfig {
            max_rows: 4,
            max_queued_rows: 4,
            tenants: policy(&[("acme", 1, 16)]),
            ..Default::default()
        });
        assert_eq!(b.push(treq("acme", "m", 4)).unwrap(), PushOutcome::Grouped);
        assert_eq!(b.push(treq("acme", "m", 2)).unwrap(), PushOutcome::Parked);
        assert_eq!(b.queued_rows(), 6);
        assert_eq!(b.parked_rows(), 2);
        // first poll dispatches the full group, then promotion refills
        let due = b.poll(Instant::now() + Duration::from_secs(1));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].rows, 4);
        assert_eq!(b.parked_rows(), 0, "freed capacity promotes the parked request");
        let due = b.poll(Instant::now() + Duration::from_secs(2));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].rows, 2);
        assert_eq!(b.queued_rows(), 0);
    }

    #[test]
    fn tenant_quota_rejects_with_quota_kind() {
        let mut b = Batcher::new(BatcherConfig {
            max_queued_rows: 2,
            tenants: policy(&[("acme", 1, 3)]),
            ..Default::default()
        });
        b.push(treq("acme", "m", 2)).unwrap(); // fills the grouped stage
        assert_eq!(b.push(treq("acme", "m", 2)).unwrap(), PushOutcome::Parked);
        let err = b.push(treq("acme", "m", 2)).unwrap_err();
        assert_eq!(err.kind, RejectKind::Quota, "parked 2 + 2 exceeds quota 3");
        // anonymous traffic at the same bound still gets a capacity reject
        let err = b.push(req("m", 1, spec(8), 0.0)).unwrap_err();
        assert_eq!(err.kind, RejectKind::Capacity);
    }

    #[test]
    fn tenant_fifo_is_preserved_through_parking() {
        let mut b = Batcher::new(BatcherConfig {
            max_queued_rows: 1,
            tenants: policy(&[("acme", 1, 16)]),
            ..Default::default()
        });
        let mut first = treq("acme", "m", 1);
        first.id = 1;
        let mut second = treq("acme", "m", 1);
        second.id = 2;
        b.push(first).unwrap(); // grouped
        b.push(second).unwrap(); // parked behind the grouped one
        // even though the grouped stage now has room mid-drain, a third
        // push from the same tenant must park behind the second
        let due = b.poll(Instant::now() + Duration::from_secs(1));
        let ids: Vec<u64> = due.iter().flat_map(|d| d.requests.iter().map(|r| r.id)).collect();
        assert_eq!(ids, vec![1]);
        let mut third = treq("acme", "m", 1);
        third.id = 3;
        b.push(third).unwrap();
        let mut seen = Vec::new();
        for tick in 2..6 {
            let due = b.poll(Instant::now() + Duration::from_secs(tick));
            seen.extend(due.iter().flat_map(|d| d.requests.iter().map(|r| r.id)));
        }
        assert_eq!(seen, vec![2, 3], "per-tenant FIFO survives parking");
    }

    #[test]
    fn weighted_promotion_tracks_weights() {
        let mut b = Batcher::new(BatcherConfig {
            max_rows: 6,
            max_wait: Duration::from_millis(1),
            max_queued_rows: 6,
            tenants: policy(&[("a", 1, 1024), ("b", 2, 1024), ("c", 3, 1024)]),
        });
        // fill the grouped stage so everything after parks
        b.push(req("m0", 6, spec(8), 0.0)).unwrap();
        for _ in 0..120 {
            for t in ["a", "b", "c"] {
                // distinct models so promotion order is visible per batch
                b.push(treq(t, t, 1)).unwrap();
            }
        }
        // drain; count promoted rows per tenant over the first ~180 rows
        let mut counts: BTreeMap<String, usize> = BTreeMap::new();
        let mut total = 0usize;
        let mut tick = 1u64;
        while total < 180 {
            let due = b.poll(Instant::now() + Duration::from_secs(tick));
            tick += 1;
            for batch in &due {
                if batch.key.model == "m0" {
                    continue; // the filler
                }
                for r in &batch.requests {
                    if total < 180 {
                        *counts.entry(batch.key.model.clone()).or_default() += r.labels.len();
                        total += r.labels.len();
                    }
                }
            }
        }
        let (a, bb, c) = (counts["a"] as f64, counts["b"] as f64, counts["c"] as f64);
        let sum = a + bb + c;
        assert!((a / sum - 1.0 / 6.0).abs() < 0.10, "a share {} off", a / sum);
        assert!((bb / sum - 2.0 / 6.0).abs() < 0.10, "b share {} off", bb / sum);
        assert!((c / sum - 3.0 / 6.0).abs() < 0.10, "c share {} off", c / sum);
    }

    #[test]
    fn parked_deadline_drives_next_wake_and_sheds() {
        // Regression for the wake-computation gap: a request parked
        // behind a full grouped stage must still shed at its deadline,
        // and next_wake must report that deadline (not just the flush).
        let mut b = Batcher::new(BatcherConfig {
            max_rows: 64,
            max_wait: Duration::from_secs(10),
            max_queued_rows: 2,
            tenants: policy(&[("acme", 1, 16)]),
        });
        let now = Instant::now();
        b.push(treq("acme", "m", 2)).unwrap(); // fills the grouped stage
        let mut parked = treq("acme", "m", 2);
        parked.id = 7;
        parked.deadline = Some(now + Duration::from_millis(40));
        assert_eq!(b.push(parked).unwrap(), PushOutcome::Parked);
        let wake = b.next_wake().unwrap();
        assert!(
            wake <= now + Duration::from_millis(40),
            "wake must track the parked request's deadline"
        );
        let shed = b.shed_expired(now + Duration::from_millis(41));
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].id, 7, "the parked request sheds at its deadline");
        assert_eq!(b.parked_rows(), 0);
        assert_eq!(b.queued_rows(), 2);
    }
}
