//! Dynamic batcher with step-aligned grouping.
//!
//! Diffusion sampling differs from token serving in one key way: the
//! model input carries a *scalar* time t shared by the whole batch, so
//! two requests can share a model evaluation only if their solvers put
//! them at the same t at the same step. The batcher therefore groups
//! requests by `GroupKey = (model, solver group key, guidance)` — within
//! a group every request follows the identical step timeline, so the
//! whole group runs lockstep and every velocity evaluation batches all
//! of its rows (the ODE-sampling analogue of continuous batching; see
//! DESIGN.md §4, vllm_router analogy).
//!
//! Flush policy: a group is dispatched when (a) its pending rows reach
//! `max_rows`, or (b) its oldest request has waited `max_wait`. Both are
//! checked by `poll`, which the engine's dispatch loop drives. Before
//! polling, the dispatch loop calls [`Batcher::shed_expired`] so work
//! whose deadline already passed never reaches a worker (DESIGN.md §9).
//!
//! Priorities do not affect grouping (a group may mix them — the batch
//! runs at the most urgent priority it contains); they order dispatch in
//! the engine's work queue.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use super::request::{Priority, SampleRequest};

/// Batching identity: requests with equal keys share a step timeline.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GroupKey {
    /// Model name.
    pub model: String,
    /// `SolverSpec::group_key()` of the request's solver.
    pub solver_key: String,
    /// Guidance scale in fixed-point (f32 bits) so the key is Ord/Eq.
    pub guidance_bits: u32,
}

impl GroupKey {
    /// The group key a request batches under.
    pub fn of(req: &SampleRequest) -> GroupKey {
        GroupKey {
            model: req.model.clone(),
            solver_key: req.solver.group_key(),
            guidance_bits: req.guidance.to_bits(),
        }
    }
}

/// A batch ready for execution: requests share a group key.
pub struct Batch {
    /// Shared batching identity of every request inside.
    pub key: GroupKey,
    /// The member requests, FIFO within the group.
    pub requests: Vec<SampleRequest>,
    /// Total sample rows across `requests`.
    pub rows: usize,
    /// Most urgent priority among the member requests; orders the batch
    /// in the engine's work queue.
    pub priority: Priority,
    /// When `poll` closed the batch — the tracing plane measures
    /// form-to-worker-pop dispatch latency from this (DESIGN.md §12).
    pub formed_at: Instant,
}

/// Flush/backpressure policy knobs.
pub struct BatcherConfig {
    /// Dispatch a group once its pending rows reach this.
    pub max_rows: usize,
    /// Dispatch a group once its oldest request has waited this long.
    pub max_wait: Duration,
    /// Upper bound on queued rows across all groups (admission control).
    pub max_queued_rows: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_rows: 64,
            max_wait: Duration::from_millis(5),
            max_queued_rows: 4096,
        }
    }
}

#[derive(Default)]
struct Group {
    requests: Vec<SampleRequest>,
    rows: usize,
    oldest: Option<Instant>,
}

/// Single-threaded core (the engine's dispatch thread owns it): push
/// requests, shed expired ones, poll for due batches.
pub struct Batcher {
    /// Policy knobs (public so the dispatch loop can read them).
    pub cfg: BatcherConfig,
    groups: BTreeMap<GroupKey, Group>,
    queued_rows: usize,
    /// Queued requests carrying a deadline. When 0 (the common case —
    /// deadlines are opt-in), `shed_expired` and `next_wake` skip their
    /// per-request scans entirely.
    deadlined: usize,
}

impl Batcher {
    /// A batcher with the given policy and no queued work.
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher { cfg, groups: BTreeMap::new(), queued_rows: 0, deadlined: 0 }
    }

    /// Rows currently queued across all groups.
    pub fn queued_rows(&self) -> usize {
        self.queued_rows
    }

    /// Enqueue; returns the request back (rejecting it) when over the
    /// queued-row bound.
    pub fn push(&mut self, req: SampleRequest) -> Result<(), SampleRequest> {
        let rows = req.labels.len();
        if self.queued_rows + rows > self.cfg.max_queued_rows {
            return Err(req);
        }
        let key = GroupKey::of(&req);
        let g = self.groups.entry(key).or_default();
        g.oldest.get_or_insert(req.enqueued_at);
        g.rows += rows;
        self.queued_rows += rows;
        if req.deadline.is_some() {
            self.deadlined += 1;
        }
        g.requests.push(req);
        Ok(())
    }

    /// Remove and return every queued request whose deadline is at or
    /// before `now`, so expired work is shed *before* dispatch instead of
    /// wasting a worker. The caller replies `deadline_exceeded` to each.
    /// Groups left empty are dropped; surviving groups keep FIFO order
    /// and recompute their flush clock from the oldest survivor.
    pub fn shed_expired(&mut self, now: Instant) -> Vec<SampleRequest> {
        if self.deadlined == 0 {
            return Vec::new(); // nothing queued carries a deadline
        }
        let mut shed = Vec::new();
        let mut emptied: Vec<GroupKey> = Vec::new();
        for (key, g) in self.groups.iter_mut() {
            let expired = |r: &SampleRequest| r.deadline.map_or(false, |d| d <= now);
            if !g.requests.iter().any(expired) {
                continue; // common case: nothing to shed, no rebuild
            }
            let mut kept = Vec::with_capacity(g.requests.len());
            for req in g.requests.drain(..) {
                if expired(&req) {
                    let rows = req.labels.len();
                    g.rows -= rows;
                    self.queued_rows -= rows;
                    self.deadlined -= 1;
                    shed.push(req);
                } else {
                    kept.push(req);
                }
            }
            g.requests = kept;
            g.oldest = g.requests.iter().map(|r| r.enqueued_at).min();
            if g.requests.is_empty() {
                emptied.push(key.clone());
            }
        }
        for key in emptied {
            self.groups.remove(&key);
        }
        shed
    }

    /// Collect every group due for dispatch at `now`. Groups larger than
    /// `max_rows` are split so no batch exceeds the cap (a single request
    /// larger than the cap still dispatches alone — the runtime chunks it
    /// over buckets).
    pub fn poll(&mut self, now: Instant) -> Vec<Batch> {
        // First pass borrows the map read-only and clones a key only for
        // groups actually due — the common idle tick (nothing due) walks
        // the map without a single heap allocation. (The seed cloned
        // every key — three allocations per group — on every tick.)
        let mut due_keys: Vec<GroupKey> = Vec::new(); // bns-lint: allow(hot_path_alloc) — Vec::new is allocation-free until pushed; pushes happen only for groups actually due
        for (key, g) in &self.groups {
            let timed_out = g
                .oldest
                .map(|t| now.duration_since(t) >= self.cfg.max_wait)
                .unwrap_or(false);
            if g.rows >= self.cfg.max_rows || timed_out {
                due_keys.push(key.clone()); // bns-lint: allow(hot_path_alloc) — clones a key only for a due group; the idle tick never reaches this line
            }
        }
        let mut due = Vec::new(); // bns-lint: allow(hot_path_alloc) — Vec::new is allocation-free until pushed; grows only when batches actually dispatch
        for key in due_keys {
            // a key collected above is still present (nothing else
            // mutates the map between the passes); tolerate its absence
            // rather than panicking the dispatch thread
            let Some(g) = self.groups.remove(&key) else { continue };
            self.queued_rows -= g.rows;
            // split into <= max_rows chunks preserving FIFO order; the
            // chunk priority is the most urgent (min-ranked) it contains
            let mut cur = Batch {
                key: key.clone(), // bns-lint: allow(hot_path_alloc) — per-dispatched-batch construction; the idle tick allocates nothing (serve_load measures the tick)
                requests: Vec::new(),
                rows: 0,
                priority: Priority::Low,
                formed_at: now,
            };
            for req in g.requests {
                let r = req.labels.len();
                if req.deadline.is_some() {
                    self.deadlined -= 1;
                }
                if cur.rows > 0 && cur.rows + r > self.cfg.max_rows {
                    due.push(std::mem::replace(
                        &mut cur,
                        Batch {
                            key: key.clone(), // bns-lint: allow(hot_path_alloc) — per-split-batch construction on the dispatch path; never runs on the idle tick
                            requests: Vec::new(),
                            rows: 0,
                            priority: Priority::Low,
                            formed_at: now,
                        },
                    ));
                }
                cur.rows += r;
                cur.priority = cur.priority.min(req.priority);
                cur.requests.push(req);
            }
            if cur.rows > 0 {
                due.push(cur);
            }
        }
        due
    }

    /// Earliest flush deadline across groups (oldest request + max_wait).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.groups
            .values()
            .filter_map(|g| g.oldest)
            .min()
            .map(|t| t + self.cfg.max_wait)
    }

    /// Earliest instant at which the dispatch loop must act: the sooner
    /// of the next flush deadline and the earliest queued request
    /// deadline (so expiry responses go out on time, not at the next
    /// flush).
    pub fn next_wake(&self) -> Option<Instant> {
        let flush = self.next_deadline();
        if self.deadlined == 0 {
            return flush; // common case: no queued deadline to track
        }
        let expiry = self
            .groups
            .values()
            .flat_map(|g| g.requests.iter().filter_map(|r| r.deadline))
            .min();
        match (flush, expiry) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{SampleRequest, SolverSpec};
    use std::sync::mpsc;

    fn req(model: &str, n: usize, solver: SolverSpec, w: f32) -> SampleRequest {
        let (tx, _rx) = mpsc::channel();
        SampleRequest {
            id: 0,
            model: model.into(),
            labels: vec![0; n],
            guidance: w,
            solver,
            seed: 1,
            x0: None,
            enqueued_at: Instant::now(),
            deadline: None,
            priority: Priority::Normal,
            progress: None,
            reply: tx,
        }
    }

    fn spec(nfe: usize) -> SolverSpec {
        SolverSpec::Baseline { name: "euler".into(), nfe }
    }

    #[test]
    fn groups_by_key() {
        let mut b = Batcher::new(BatcherConfig { max_rows: 8, ..Default::default() });
        b.push(req("m1", 4, spec(8), 0.0)).unwrap();
        b.push(req("m1", 4, spec(8), 0.0)).unwrap(); // same group: flush at 8
        b.push(req("m2", 2, spec(8), 0.0)).unwrap(); // different model
        let due = b.poll(Instant::now());
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].rows, 8);
        assert_eq!(due[0].key.model, "m1");
        assert_eq!(b.queued_rows(), 2);
    }

    #[test]
    fn different_guidance_not_batched() {
        let mut b = Batcher::new(BatcherConfig { max_rows: 4, ..Default::default() });
        b.push(req("m", 2, spec(8), 0.0)).unwrap();
        b.push(req("m", 2, spec(8), 2.0)).unwrap();
        assert!(b.poll(Instant::now()).is_empty()); // neither group full
    }

    #[test]
    fn timeout_flushes_partial() {
        let mut b = Batcher::new(BatcherConfig {
            max_rows: 64,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        b.push(req("m", 3, spec(8), 0.0)).unwrap();
        assert!(b.poll(Instant::now()).is_empty());
        let later = Instant::now() + Duration::from_millis(5);
        let due = b.poll(later);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].rows, 3);
        assert_eq!(b.queued_rows(), 0);
    }

    #[test]
    fn splits_over_cap_preserving_fifo() {
        let mut b = Batcher::new(BatcherConfig { max_rows: 4, ..Default::default() });
        for i in 0..5 {
            let mut r = req("m", 2, spec(8), 0.0);
            r.id = i;
            b.push(r).unwrap();
        }
        let due = b.poll(Instant::now());
        assert_eq!(due.len(), 3); // 2+2, 2+2, 2
        let ids: Vec<u64> = due.iter().flat_map(|d| d.requests.iter().map(|r| r.id)).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        assert!(due.iter().all(|d| d.rows <= 4));
    }

    #[test]
    fn backpressure_rejects() {
        let mut b = Batcher::new(BatcherConfig { max_queued_rows: 4, ..Default::default() });
        b.push(req("m", 3, spec(8), 0.0)).unwrap();
        assert!(b.push(req("m", 3, spec(8), 0.0)).is_err());
        assert_eq!(b.queued_rows(), 3);
    }

    #[test]
    fn oversized_request_dispatches_alone() {
        let mut b = Batcher::new(BatcherConfig { max_rows: 4, ..Default::default() });
        b.push(req("m", 10, spec(8), 0.0)).unwrap();
        let due = b.poll(Instant::now());
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].rows, 10);
    }

    #[test]
    fn shed_expired_removes_only_expired_and_rebalances() {
        let mut b = Batcher::new(BatcherConfig {
            max_rows: 64,
            max_wait: Duration::from_secs(3600),
            ..Default::default()
        });
        let now = Instant::now();
        let mut dead = req("m", 3, spec(8), 0.0);
        dead.id = 1;
        dead.deadline = Some(now); // expired at `now`
        let mut live = req("m", 2, spec(8), 0.0);
        live.id = 2;
        live.deadline = Some(now + Duration::from_secs(60));
        b.push(dead).unwrap();
        b.push(live).unwrap();
        assert_eq!(b.queued_rows(), 5);

        let shed = b.shed_expired(now);
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].id, 1);
        assert_eq!(b.queued_rows(), 2, "only the live request remains");

        // survivor still flushes (rows/oldest bookkeeping intact)
        let due = b.poll(now + Duration::from_secs(7200));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].requests[0].id, 2);
        assert_eq!(b.queued_rows(), 0);
    }

    #[test]
    fn shed_expired_drops_emptied_groups() {
        let mut b = Batcher::new(BatcherConfig::default());
        let now = Instant::now();
        let mut r = req("m", 4, spec(8), 0.0);
        r.deadline = Some(now);
        b.push(r).unwrap();
        assert_eq!(b.shed_expired(now).len(), 1);
        assert_eq!(b.queued_rows(), 0);
        assert!(b.next_wake().is_none(), "emptied group must not leave a wake time");
    }

    #[test]
    fn next_wake_is_min_of_flush_and_request_deadline() {
        let mut b = Batcher::new(BatcherConfig {
            max_rows: 64,
            max_wait: Duration::from_secs(10),
            ..Default::default()
        });
        assert!(b.next_wake().is_none());
        let now = Instant::now();
        let mut r = req("m", 2, spec(8), 0.0);
        r.deadline = Some(now + Duration::from_millis(50));
        b.push(r).unwrap();
        // request deadline (50ms) is sooner than the flush (10s)
        let wake = b.next_wake().unwrap();
        assert!(wake < now + Duration::from_secs(1), "wake should track the deadline");
        assert!(b.next_deadline().unwrap() > wake);
    }

    #[test]
    fn batch_priority_is_most_urgent_member() {
        let mut b = Batcher::new(BatcherConfig { max_rows: 8, ..Default::default() });
        let mut low = req("m", 2, spec(8), 0.0);
        low.priority = Priority::Low;
        let mut high = req("m", 2, spec(8), 0.0);
        high.priority = Priority::High;
        b.push(low).unwrap();
        b.push(high).unwrap();
        let due = b.poll(Instant::now() + Duration::from_secs(1));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].priority, Priority::High);
        assert_eq!(due[0].requests.len(), 2, "priorities do not split the batch");
    }
}
