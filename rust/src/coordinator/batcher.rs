//! Dynamic batcher with step-aligned grouping.
//!
//! Diffusion sampling differs from token serving in one key way: the
//! model input carries a *scalar* time t shared by the whole batch, so
//! two requests can share a model evaluation only if their solvers put
//! them at the same t at the same step. The batcher therefore groups
//! requests by `GroupKey = (model, solver group key, guidance)` — within
//! a group every request follows the identical step timeline, so the
//! whole group runs lockstep and every velocity evaluation batches all
//! of its rows (the ODE-sampling analogue of continuous batching; see
//! DESIGN.md §4, vllm_router analogy).
//!
//! Flush policy: a group is dispatched when (a) its pending rows reach
//! `max_rows`, or (b) its oldest request has waited `max_wait`. Both are
//! checked by `poll`, which the engine's dispatch loop drives.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use super::request::SampleRequest;

#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GroupKey {
    pub model: String,
    pub solver_key: String,
    /// Guidance scale in fixed-point (f32 bits) so the key is Ord/Eq.
    pub guidance_bits: u32,
}

impl GroupKey {
    pub fn of(req: &SampleRequest) -> GroupKey {
        GroupKey {
            model: req.model.clone(),
            solver_key: req.solver.group_key(),
            guidance_bits: req.guidance.to_bits(),
        }
    }
}

/// A batch ready for execution: requests share a group key.
pub struct Batch {
    pub key: GroupKey,
    pub requests: Vec<SampleRequest>,
    pub rows: usize,
}

pub struct BatcherConfig {
    pub max_rows: usize,
    pub max_wait: Duration,
    /// Upper bound on queued rows across all groups (admission control).
    pub max_queued_rows: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_rows: 64,
            max_wait: Duration::from_millis(5),
            max_queued_rows: 4096,
        }
    }
}

#[derive(Default)]
struct Group {
    requests: Vec<SampleRequest>,
    rows: usize,
    oldest: Option<Instant>,
}

/// Single-threaded core (the engine wraps it in a mutex): push requests,
/// poll for due batches.
pub struct Batcher {
    pub cfg: BatcherConfig,
    groups: BTreeMap<GroupKey, Group>,
    queued_rows: usize,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher { cfg, groups: BTreeMap::new(), queued_rows: 0 }
    }

    pub fn queued_rows(&self) -> usize {
        self.queued_rows
    }

    /// Enqueue; returns false (rejecting the request) when over capacity.
    pub fn push(&mut self, req: SampleRequest) -> Result<(), SampleRequest> {
        let rows = req.labels.len();
        if self.queued_rows + rows > self.cfg.max_queued_rows {
            return Err(req);
        }
        let key = GroupKey::of(&req);
        let g = self.groups.entry(key).or_default();
        g.oldest.get_or_insert(req.enqueued_at);
        g.rows += rows;
        self.queued_rows += rows;
        g.requests.push(req);
        Ok(())
    }

    /// Collect every group due for dispatch at `now`. Groups larger than
    /// `max_rows` are split so no batch exceeds the cap (a single request
    /// larger than the cap still dispatches alone — the runtime chunks it
    /// over buckets).
    pub fn poll(&mut self, now: Instant) -> Vec<Batch> {
        // First pass borrows the map read-only and clones a key only for
        // groups actually due — the common idle tick (nothing due) walks
        // the map without a single heap allocation. (The seed cloned
        // every key — three allocations per group — on every tick.)
        let mut due_keys: Vec<GroupKey> = Vec::new();
        for (key, g) in &self.groups {
            let timed_out = g
                .oldest
                .map(|t| now.duration_since(t) >= self.cfg.max_wait)
                .unwrap_or(false);
            if g.rows >= self.cfg.max_rows || timed_out {
                due_keys.push(key.clone());
            }
        }
        let mut due = Vec::new();
        for key in due_keys {
            let g = self.groups.remove(&key).unwrap();
            self.queued_rows -= g.rows;
            // split into <= max_rows chunks preserving FIFO order
            let mut cur = Batch { key: key.clone(), requests: Vec::new(), rows: 0 };
            for req in g.requests {
                let r = req.labels.len();
                if cur.rows > 0 && cur.rows + r > self.cfg.max_rows {
                    due.push(std::mem::replace(
                        &mut cur,
                        Batch { key: key.clone(), requests: Vec::new(), rows: 0 },
                    ));
                }
                cur.rows += r;
                cur.requests.push(req);
            }
            if cur.rows > 0 {
                due.push(cur);
            }
        }
        due
    }

    /// Earliest deadline across groups (for the dispatch loop's sleep).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.groups
            .values()
            .filter_map(|g| g.oldest)
            .min()
            .map(|t| t + self.cfg.max_wait)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{SampleRequest, SolverSpec};
    use std::sync::mpsc;

    fn req(model: &str, n: usize, solver: SolverSpec, w: f32) -> SampleRequest {
        let (tx, _rx) = mpsc::channel();
        SampleRequest {
            id: 0,
            model: model.into(),
            labels: vec![0; n],
            guidance: w,
            solver,
            seed: 1,
            x0: None,
            enqueued_at: Instant::now(),
            reply: tx,
        }
    }

    fn spec(nfe: usize) -> SolverSpec {
        SolverSpec::Baseline { name: "euler".into(), nfe }
    }

    #[test]
    fn groups_by_key() {
        let mut b = Batcher::new(BatcherConfig { max_rows: 8, ..Default::default() });
        b.push(req("m1", 4, spec(8), 0.0)).unwrap();
        b.push(req("m1", 4, spec(8), 0.0)).unwrap(); // same group: flush at 8
        b.push(req("m2", 2, spec(8), 0.0)).unwrap(); // different model
        let due = b.poll(Instant::now());
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].rows, 8);
        assert_eq!(due[0].key.model, "m1");
        assert_eq!(b.queued_rows(), 2);
    }

    #[test]
    fn different_guidance_not_batched() {
        let mut b = Batcher::new(BatcherConfig { max_rows: 4, ..Default::default() });
        b.push(req("m", 2, spec(8), 0.0)).unwrap();
        b.push(req("m", 2, spec(8), 2.0)).unwrap();
        assert!(b.poll(Instant::now()).is_empty()); // neither group full
    }

    #[test]
    fn timeout_flushes_partial() {
        let mut b = Batcher::new(BatcherConfig {
            max_rows: 64,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        b.push(req("m", 3, spec(8), 0.0)).unwrap();
        assert!(b.poll(Instant::now()).is_empty());
        let later = Instant::now() + Duration::from_millis(5);
        let due = b.poll(later);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].rows, 3);
        assert_eq!(b.queued_rows(), 0);
    }

    #[test]
    fn splits_over_cap_preserving_fifo() {
        let mut b = Batcher::new(BatcherConfig { max_rows: 4, ..Default::default() });
        for i in 0..5 {
            let mut r = req("m", 2, spec(8), 0.0);
            r.id = i;
            b.push(r).unwrap();
        }
        let due = b.poll(Instant::now());
        assert_eq!(due.len(), 3); // 2+2, 2+2, 2
        let ids: Vec<u64> = due.iter().flat_map(|d| d.requests.iter().map(|r| r.id)).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        assert!(due.iter().all(|d| d.rows <= 4));
    }

    #[test]
    fn backpressure_rejects() {
        let mut b = Batcher::new(BatcherConfig { max_queued_rows: 4, ..Default::default() });
        b.push(req("m", 3, spec(8), 0.0)).unwrap();
        assert!(b.push(req("m", 3, spec(8), 0.0)).is_err());
        assert_eq!(b.queued_rows(), 3);
    }

    #[test]
    fn oversized_request_dispatches_alone() {
        let mut b = Batcher::new(BatcherConfig { max_rows: 4, ..Default::default() });
        b.push(req("m", 10, spec(8), 0.0)).unwrap();
        let due = b.poll(Instant::now());
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].rows, 10);
    }
}
