//! L3 coordinator: the serving system around BNS sampling.
//!
//! * `request` — request/response types, solver specs, priorities, and
//!   the structured error vocabulary of the wire protocol
//! * `batcher` — step-aligned dynamic batching (the diffusion analogue of
//!   continuous batching: requests sharing a solver timeline run lockstep)
//!   plus deadline shedding
//! * `breaker` — per-model circuit breakers guarding batch dispatch
//!   (consecutive failures open, half-open probe closes)
//! * `router`  — SolverSpec -> concrete solver resolution (BNS-first)
//! * `engine`  — admission control, dispatch + worker threads driving
//!   batched sampling
//! * `registry` — versioned model registry: hot `load`/`unload` with
//!   refcounted drain (the fleet plane, DESIGN.md §14)
//! * `shard`   — consistent-hash shard router fanning one front door
//!   across N in-process engine shards
//! * `metrics` — counters, gauges, and latency histograms (the `stats` op)
//! * `server`  — event-driven TCP JSON-lines front-end (PROTOCOL.md)
//!
//! This module is the crate's public serving API and is kept
//! `missing_docs`-clean: every public item documents itself.

#![warn(missing_docs)]

pub mod batcher;
pub mod breaker;
pub mod engine;
pub mod metrics;
pub mod registry;
pub mod request;
pub mod router;
pub mod server;
pub mod shard;

pub use engine::{Engine, EngineConfig};
pub use registry::Registry;
pub use request::{
    ErrCode, Priority, Progress, SampleOutput, SampleRequest, SampleResponse, ServeError,
    SolverSpec,
};
pub use server::{Server, ServerConfig};
pub use shard::{Fleet, FleetConfig};
