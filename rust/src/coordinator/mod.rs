//! L3 coordinator: the serving system around BNS sampling.
//!
//! * `request` — request/response types and solver specs
//! * `batcher` — step-aligned dynamic batching (the diffusion analogue of
//!   continuous batching: requests sharing a solver timeline run lockstep)
//! * `router`  — SolverSpec -> concrete solver resolution (BNS-first)
//! * `engine`  — dispatch + worker threads driving batched sampling
//! * `metrics` — counters and latency histograms
//! * `server`  — TCP JSON-lines front-end

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use engine::{Engine, EngineConfig};
pub use request::{SampleOutput, SampleRequest, SampleResponse, SolverSpec};
