//! Per-model circuit breakers: stop dispatching to a model whose batches
//! keep failing (a poisoned artifact, a backend that rejects its
//! executable) instead of burning retries and lane respawns fleet-wide.
//!
//! Classic three-state breaker, keyed by model name:
//!
//! * **closed** — normal operation; consecutive batch failures are
//!   counted, successes reset the count.
//! * **open** — after `threshold` consecutive failures. New batches for
//!   the model are rejected up front with [`ErrCode::Unavailable`]
//!   (`retry_after_ms` = time until the next probe) without touching the
//!   runtime.
//! * **half-open** — once `cooldown` elapses, exactly one batch is let
//!   through as a probe; success closes the breaker, failure re-opens it
//!   (and restarts the cooldown). Concurrent batches during a probe are
//!   rejected, so a recovering model sees one speculative batch, not a
//!   thundering herd.
//!
//! Granularity is the *model*, matching the failure domain: a broken
//! artifact fails every batch of that model on every lane, while other
//! models keep serving. Breaker decisions never change numerics — an
//! admitted batch runs exactly as it would without the breaker.
//!
//! [`ErrCode::Unavailable`]: super::request::ErrCode::Unavailable

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::sync::lock_ok;

/// Admission decision for one batch (see [`Breakers::admit`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admit {
    /// Run the batch. `probe` marks the single half-open trial batch —
    /// callers must report its outcome via `on_success`/`on_failure` so
    /// the breaker can close or re-open.
    Proceed {
        /// True when this batch is the half-open probe.
        probe: bool,
    },
    /// Breaker is open: fail the batch's requests with `unavailable`
    /// and this retry hint (ms until the next half-open probe).
    Reject {
        /// Suggested client backoff in milliseconds.
        retry_after_ms: u64,
    },
}

#[derive(Default)]
struct Entry {
    /// Consecutive failures while closed (reset by any success).
    consecutive: u32,
    /// Set while open / half-open: when the breaker tripped or last
    /// re-opened.
    opened_at: Option<Instant>,
    /// A half-open probe batch is currently in flight.
    probing: bool,
}

/// All per-model breakers of one engine.
pub struct Breakers {
    threshold: u32,
    cooldown: Duration,
    map: Mutex<HashMap<String, Entry>>,
}

impl Breakers {
    /// `threshold` consecutive batch failures open a model's breaker;
    /// after `cooldown` a single probe batch may close it again. A
    /// `threshold` of 0 disables breakers entirely (every admit
    /// proceeds).
    pub fn new(threshold: u32, cooldown: Duration) -> Breakers {
        Breakers { threshold, cooldown, map: Mutex::new(HashMap::new()) }
    }

    /// Decide whether a batch for `model` may run now.
    pub fn admit(&self, model: &str) -> Admit {
        if self.threshold == 0 {
            return Admit::Proceed { probe: false };
        }
        let mut map = lock_ok(&self.map);
        let Some(e) = map.get_mut(model) else {
            return Admit::Proceed { probe: false };
        };
        let Some(opened_at) = e.opened_at else {
            return Admit::Proceed { probe: false };
        };
        let elapsed = opened_at.elapsed();
        if elapsed < self.cooldown {
            let remaining = self.cooldown - elapsed;
            return Admit::Reject { retry_after_ms: (remaining.as_millis() as u64).max(1) };
        }
        if e.probing {
            // a probe is already in flight; tell others to come back in
            // roughly one more cooldown
            return Admit::Reject { retry_after_ms: (self.cooldown.as_millis() as u64).max(1) };
        }
        e.probing = true;
        Admit::Proceed { probe: true }
    }

    /// Record a successful batch: closes the breaker (if open) and
    /// resets the failure count.
    pub fn on_success(&self, model: &str) {
        if self.threshold == 0 {
            return;
        }
        let mut map = lock_ok(&self.map);
        if let Some(e) = map.get_mut(model) {
            e.consecutive = 0;
            e.opened_at = None;
            e.probing = false;
        }
    }

    /// Record a failed batch. Returns `true` when this failure
    /// *transitioned* the breaker to open (closed -> open, or a failed
    /// half-open probe re-opening) so callers can count distinct
    /// breaker-open events.
    pub fn on_failure(&self, model: &str) -> bool {
        if self.threshold == 0 {
            return false;
        }
        let mut map = lock_ok(&self.map);
        let e = map.entry(model.to_string()).or_default();
        if e.probing {
            // failed probe: re-open and restart the cooldown
            e.probing = false;
            e.opened_at = Some(Instant::now());
            return true;
        }
        if e.opened_at.is_some() {
            // already open (a batch admitted before the trip finished
            // late); keep the original cooldown clock
            return false;
        }
        e.consecutive = e.consecutive.saturating_add(1);
        if e.consecutive >= self.threshold {
            e.opened_at = Some(Instant::now());
            return true;
        }
        false
    }

    /// Breaker states for the `health` op: one object per model that has
    /// ever failed, `state` in {"closed", "open", "half_open"}.
    pub fn snapshot_json(&self) -> Json {
        let map = lock_ok(&self.map);
        let mut entries: Vec<(&String, &Entry)> = map.iter().collect();
        entries.sort_by(|a, b| a.0.cmp(b.0));
        Json::Arr(
            entries
                .into_iter()
                .map(|(model, e)| {
                    let (state, retry) = match e.opened_at {
                        None => ("closed", None),
                        Some(at) => {
                            let elapsed = at.elapsed();
                            if e.probing || elapsed >= self.cooldown {
                                ("half_open", Some(0))
                            } else {
                                ("open", Some((self.cooldown - elapsed).as_millis() as u64))
                            }
                        }
                    };
                    let mut pairs = vec![
                        ("model", Json::Str(model.clone())),
                        ("state", Json::Str(state.to_string())),
                        ("consecutive_failures", Json::Num(e.consecutive as f64)),
                    ];
                    if let Some(r) = retry {
                        pairs.push(("retry_after_ms", Json::Num(r as f64)));
                    }
                    Json::obj(pairs)
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trips_after_threshold_and_probe_closes() {
        let b = Breakers::new(3, Duration::from_millis(40));
        assert_eq!(b.admit("m"), Admit::Proceed { probe: false });
        assert!(!b.on_failure("m"));
        assert!(!b.on_failure("m"));
        // third consecutive failure trips the breaker (transition = true)
        assert!(b.on_failure("m"));
        match b.admit("m") {
            Admit::Reject { retry_after_ms } => assert!(retry_after_ms <= 40),
            other => panic!("expected reject, got {other:?}"),
        }
        // other models are unaffected
        assert_eq!(b.admit("other"), Admit::Proceed { probe: false });
        std::thread::sleep(Duration::from_millis(50));
        // cooldown elapsed: exactly one probe goes through
        assert_eq!(b.admit("m"), Admit::Proceed { probe: true });
        assert!(matches!(b.admit("m"), Admit::Reject { .. }));
        b.on_success("m");
        assert_eq!(b.admit("m"), Admit::Proceed { probe: false });
    }

    #[test]
    fn failed_probe_reopens_and_success_resets_streak() {
        let b = Breakers::new(2, Duration::from_millis(30));
        assert!(!b.on_failure("m"));
        b.on_success("m"); // streak reset
        assert!(!b.on_failure("m"));
        assert!(b.on_failure("m")); // 2 consecutive -> open
        std::thread::sleep(Duration::from_millis(35));
        assert_eq!(b.admit("m"), Admit::Proceed { probe: true });
        // failed probe re-opens (counts as a fresh open transition)
        assert!(b.on_failure("m"));
        assert!(matches!(b.admit("m"), Admit::Reject { .. }));
    }

    #[test]
    fn zero_threshold_disables_breakers() {
        let b = Breakers::new(0, Duration::from_millis(10));
        for _ in 0..100 {
            assert!(!b.on_failure("m"));
        }
        assert_eq!(b.admit("m"), Admit::Proceed { probe: false });
        assert_eq!(b.snapshot_json(), Json::Arr(Vec::new()));
    }

    #[test]
    fn snapshot_reports_states() {
        let b = Breakers::new(1, Duration::from_secs(60));
        assert!(b.on_failure("bad"));
        b.on_success("good"); // no entry is created for unseen-failure models
        let s = b.snapshot_json().to_string();
        assert!(s.contains("\"model\":\"bad\""), "{s}");
        assert!(s.contains("\"state\":\"open\""), "{s}");
        assert!(s.contains("\"retry_after_ms\""), "{s}");
        assert!(!s.contains("good"), "{s}");
    }
}
