//! Solver routing: resolve a `SolverSpec` against the artifact store.
//!
//! `Auto { nfe }` implements the headline feature — "give me the best
//! solver this service has for (model, guidance, NFE)": a BNS artifact if
//! one was distilled, else BST, else the strongest baseline that divides
//! the NFE (the Thm 3.2 hierarchy top-down).

use anyhow::Result;

use crate::coordinator::request::SolverSpec;
use crate::runtime::ArtifactStore;
use crate::solver::scheduler::Scheduler;
use crate::solver::{baseline, NsSolver, Solver};

/// The routed outcome: a concrete solver plus its reporting name.
pub struct Routed {
    pub solver: RoutedSolver,
    pub name: String,
}

pub enum RoutedSolver {
    Fixed(Box<dyn Solver>),
    /// Adaptive ground truth (RK45 with default tolerances).
    GroundTruth,
}

pub fn route(
    store: &ArtifactStore,
    model: &str,
    guidance: f64,
    sched: Scheduler,
    spec: &SolverSpec,
) -> Result<Routed> {
    match spec {
        SolverSpec::GroundTruth => Ok(Routed {
            solver: RoutedSolver::GroundTruth,
            name: "rk45".into(),
        }),
        SolverSpec::Baseline { name, nfe } => {
            let s = baseline(name, *nfe, sched)?;
            let n = s.name();
            Ok(Routed { solver: RoutedSolver::Fixed(s), name: n })
        }
        SolverSpec::Distilled { name } => {
            let art = store.solver(name)?;
            anyhow::ensure!(
                art.meta.model == model,
                "solver '{}' was distilled for model '{}', not '{}'",
                name,
                art.meta.model,
                model
            );
            Ok(Routed {
                solver: RoutedSolver::Fixed(Box::new(art.solver.clone())),
                name: name.clone(),
            })
        }
        SolverSpec::Auto { nfe } => {
            for kind in ["bns", "bst"] {
                if let Some(art) = store
                    .solvers_for(model, guidance, kind)
                    .into_iter()
                    .find(|s| s.solver.nfe() == *nfe)
                {
                    return Ok(Routed {
                        solver: RoutedSolver::Fixed(Box::new(art.solver.clone())),
                        name: art.name.clone(),
                    });
                }
            }
            // baseline fallback: strongest generic that fits the NFE
            let name = if *nfe % 2 == 0 { "midpoint" } else { "euler" };
            let s = baseline(name, *nfe, sched)?;
            let n = s.name();
            Ok(Routed { solver: RoutedSolver::Fixed(s), name: format!("auto-{n}") })
        }
    }
}

/// Auto-routing table for introspection ("what would NFE=k get?").
pub fn describe_auto(store: &ArtifactStore, model: &str, guidance: f64, nfe: usize) -> String {
    for kind in ["bns", "bst"] {
        if let Some(art) = store
            .solvers_for(model, guidance, kind)
            .into_iter()
            .find(|s| s.solver.nfe() == nfe)
        {
            return art.name.clone();
        }
    }
    if nfe % 2 == 0 {
        format!("auto-midpoint{nfe}")
    } else {
        format!("auto-euler{nfe}")
    }
}

/// Convenience for benches/tests: pull a distilled NS solver or panic
/// with a readable message.
pub fn distilled(store: &ArtifactStore, model: &str, guidance: f64, kind: &str, nfe: usize) -> Result<NsSolver> {
    store
        .solvers_for(model, guidance, kind)
        .into_iter()
        .find(|s| s.solver.nfe() == nfe)
        .map(|s| s.solver.clone())
        .ok_or_else(|| {
            anyhow::anyhow!("no {kind} solver for model={model} w={guidance} nfe={nfe}")
        })
}
