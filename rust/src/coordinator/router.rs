//! Solver routing: resolve a `SolverSpec` against the artifact store.
//!
//! `Auto { nfe }` implements the headline feature — "give me the best
//! solver this service has for (model, guidance, NFE)": a BNS artifact if
//! one was distilled, else BST, else the strongest baseline that divides
//! the NFE (the Thm 3.2 hierarchy top-down: RK4 when 4 | NFE, midpoint
//! when 2 | NFE, Euler otherwise).
//!
//! Routing used to happen from scratch on every batch — including a
//! clone of a distilled solver's dense lower-triangular `b` matrix
//! (O(nfe²) f64s). `RouterCache` memoizes the routed outcome per
//! `(model, guidance, solver key)` behind an `Arc`, so steady-state
//! batches share one immutable solver instance across workers.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::coordinator::batcher::GroupKey;
use crate::coordinator::request::SolverSpec;
use crate::runtime::ArtifactStore;
use crate::solver::scheduler::Scheduler;
use crate::solver::{baseline, NsSolver, Solver};
use crate::util::sync::lock_ok;

/// The routed outcome: a concrete solver plus its reporting name.
pub struct Routed {
    /// The solver instance to run.
    pub solver: RoutedSolver,
    /// Reporting name (surfaced as `solver_used` in responses/metrics).
    pub name: String,
}

/// A resolved solver: fixed-step or adaptive ground truth.
pub enum RoutedSolver {
    /// A fixed-timeline solver (baseline or distilled artifact).
    Fixed(Box<dyn Solver>),
    /// Adaptive ground truth (RK45 with default tolerances).
    GroundTruth,
}

/// Strongest generic baseline that divides `nfe` (Thm 3.2 hierarchy).
fn auto_baseline_name(nfe: usize) -> &'static str {
    if nfe % 4 == 0 {
        "rk4"
    } else if nfe % 2 == 0 {
        "midpoint"
    } else {
        "euler"
    }
}

/// Resolve `spec` against the artifact store for (model, guidance):
/// explicit names resolve directly; `Auto` picks a BNS/BST artifact when
/// one matches the NFE, else the strongest dividing baseline.
pub fn route(
    store: &ArtifactStore,
    model: &str,
    guidance: f64,
    sched: Scheduler,
    spec: &SolverSpec,
) -> Result<Routed> {
    match spec {
        SolverSpec::GroundTruth => Ok(Routed {
            solver: RoutedSolver::GroundTruth,
            name: "rk45".into(),
        }),
        SolverSpec::Baseline { name, nfe } => {
            let s = baseline(name, *nfe, sched)?;
            let n = s.name();
            Ok(Routed { solver: RoutedSolver::Fixed(s), name: n })
        }
        SolverSpec::Distilled { name } => {
            let art = store.solver(name)?;
            anyhow::ensure!(
                art.meta.model == model,
                "solver '{}' was distilled for model '{}', not '{}'",
                name,
                art.meta.model,
                model
            );
            Ok(Routed {
                solver: RoutedSolver::Fixed(Box::new(art.solver.clone())),
                name: name.clone(),
            })
        }
        SolverSpec::Auto { nfe } => {
            for kind in ["bns", "bst"] {
                if let Some(art) = store
                    .solvers_for(model, guidance, kind)
                    .into_iter()
                    .find(|s| s.solver.nfe() == *nfe)
                {
                    return Ok(Routed {
                        solver: RoutedSolver::Fixed(Box::new(art.solver.clone())),
                        name: art.name.clone(),
                    });
                }
            }
            // baseline fallback: strongest generic that fits the NFE
            let s = baseline(auto_baseline_name(*nfe), *nfe, sched)?;
            let n = s.name();
            Ok(Routed { solver: RoutedSolver::Fixed(s), name: format!("auto-{n}") })
        }
    }
}

/// Auto-routing table for introspection ("what would NFE=k get?").
/// Kept consistent with `route`'s `Auto` arm (asserted by unit tests).
pub fn describe_auto(store: &ArtifactStore, model: &str, guidance: f64, nfe: usize) -> String {
    for kind in ["bns", "bst"] {
        if let Some(art) = store
            .solvers_for(model, guidance, kind)
            .into_iter()
            .find(|s| s.solver.nfe() == nfe)
        {
            return art.name.clone();
        }
    }
    // Derive the name exactly the way `route`'s Auto arm does, so the
    // two can never drift. The generic steppers ignore the scheduler,
    // and `auto_baseline_name` guarantees the divisibility their
    // constructors assert.
    match baseline(auto_baseline_name(nfe), nfe, Scheduler::FmOt) {
        Ok(s) => format!("auto-{}", s.name()),
        // unreachable in practice (the generic steppers accept any
        // divisible NFE, which auto_baseline_name guarantees); still,
        // introspection must not panic the serving plane
        Err(_) => format!("auto-{}", auto_baseline_name(nfe)),
    }
}

/// Memoized routing: one resolution (and one dense-`b` clone) per
/// distinct `(model, guidance, solver key)`, shared across workers.
/// Artifact-store views are immutable, so cached entries only go stale
/// when the registry swaps the view — `load`/`unload` call
/// [`RouterCache::invalidate_model`] to drop the affected routes.
///
/// Keyed directly by the batcher's `GroupKey`, so the per-batch lookup
/// borrows the batch's key instead of assembling an owned
/// `(String, u32, String)` triple — a cache hit allocates nothing.
///
/// The key includes the request's guidance scale and solver spec — both
/// client-controlled — so the cache is bounded: once `MAX_ENTRIES`
/// distinct keys exist, further misses resolve uncached (steady
/// workloads keep their hits; an adversarial guidance/NFE sweep degrades
/// to per-batch resolution instead of unbounded growth).
#[derive(Default)]
pub struct RouterCache {
    map: Mutex<HashMap<GroupKey, Arc<Routed>>>,
}

/// Upper bound on cached routes (each distilled entry holds an O(nfe²)
/// dense `b` clone, so keep this modest).
const MAX_ENTRIES: usize = 512;

impl RouterCache {
    /// An empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Resolve the routed solver for a batch group. `spec` must be the
    /// solver spec the key was derived from (`GroupKey::of`); it is only
    /// consulted on a cache miss.
    pub fn resolve(
        &self,
        store: &ArtifactStore,
        key: &GroupKey,
        sched: Scheduler,
        spec: &SolverSpec,
    ) -> Result<Arc<Routed>> {
        debug_assert_eq!(spec.group_key(), key.solver_key, "spec/key mismatch");
        if let Some(r) = lock_ok(&self.map).get(key) {
            return Ok(r.clone());
        }
        let guidance = f32::from_bits(key.guidance_bits) as f64;
        let routed = Arc::new(route(store, &key.model, guidance, sched, spec)?);
        let mut map = lock_ok(&self.map);
        if map.len() < MAX_ENTRIES {
            map.entry(key.clone()).or_insert_with(|| routed.clone());
        }
        Ok(routed)
    }

    /// Drop every cached route for `model`. Called by the registry on
    /// hot `load`/`unload` so routes never outlive the artifact version
    /// they were resolved against.
    pub fn invalidate_model(&self, model: &str) {
        lock_ok(&self.map).retain(|k, _| k.model != model);
    }

    /// Number of memoized routes.
    pub fn len(&self) -> usize {
        lock_ok(&self.map).len()
    }

    /// True when nothing has been resolved yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Convenience for benches/tests: pull a distilled NS solver or panic
/// with a readable message.
pub fn distilled(store: &ArtifactStore, model: &str, guidance: f64, kind: &str, nfe: usize) -> Result<NsSolver> {
    store
        .solvers_for(model, guidance, kind)
        .into_iter()
        .find(|s| s.solver.nfe() == nfe)
        .map(|s| s.solver.clone())
        .ok_or_else(|| {
            anyhow::anyhow!("no {kind} solver for model={model} w={guidance} nfe={nfe}")
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{ArtifactStore, FdSynth};
    use crate::util::json::Json;
    use crate::util::linalg::Mat;

    fn empty_store() -> ArtifactStore {
        ArtifactStore {
            root: std::path::PathBuf::from("."),
            models: Default::default(),
            solvers: Default::default(),
            fd: FdSynth {
                dim: 1,
                hidden: 1,
                feat_dim: 1,
                w1: vec![0.0],
                b1: vec![0.0],
                w2: vec![0.0],
                ref_mean: vec![0.0],
                ref_cov: Mat::from_rows(1, vec![1.0]),
            },
            scheduler_check: Json::Null,
        }
    }

    fn routed_name(store: &ArtifactStore, nfe: usize) -> String {
        route(store, "m", 0.0, Scheduler::FmOt, &SolverSpec::Auto { nfe })
            .unwrap()
            .name
    }

    #[test]
    fn auto_fallback_tiers() {
        let store = empty_store();
        // 4 | nfe -> RK4 (the strongest generic baseline of Thm 3.2)
        assert_eq!(routed_name(&store, 8), "auto-rk4_8");
        assert_eq!(routed_name(&store, 16), "auto-rk4_16");
        // even but not divisible by 4 -> midpoint
        assert_eq!(routed_name(&store, 6), "auto-midpoint6");
        assert_eq!(routed_name(&store, 10), "auto-midpoint10");
        // odd -> euler
        assert_eq!(routed_name(&store, 5), "auto-euler5");
        assert_eq!(routed_name(&store, 7), "auto-euler7");
    }

    #[test]
    fn describe_auto_matches_route() {
        let store = empty_store();
        for nfe in [4usize, 5, 6, 7, 8, 10, 12, 15, 16, 20] {
            assert_eq!(
                describe_auto(&store, "m", 0.0, nfe),
                routed_name(&store, nfe),
                "nfe {nfe}"
            );
        }
    }

    #[test]
    fn cache_returns_shared_instance() {
        let store = empty_store();
        let cache = RouterCache::new();
        let spec = SolverSpec::Auto { nfe: 8 };
        let key = |w: f32| GroupKey {
            model: "m".into(),
            solver_key: spec.group_key(),
            guidance_bits: w.to_bits(),
        };
        let a = cache.resolve(&store, &key(0.0), Scheduler::FmOt, &spec).unwrap();
        let b = cache.resolve(&store, &key(0.0), Scheduler::FmOt, &spec).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second resolve must hit the cache");
        assert_eq!(cache.len(), 1);
        // a different guidance is a different cache entry
        let c = cache.resolve(&store, &key(1.5), Scheduler::FmOt, &spec).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.len(), 2);
    }
}
